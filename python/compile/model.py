# L2 top level: losses, optimizers, and the build-time graph constructors
# (init / train_step / infer / export) that aot.py lowers to HLO artifacts.
#
# Everything here is *positional-flat* at the artifact boundary: the Rust
# runtime carries training state as an opaque ordered list of f32 tensors and
# the manifest (aot.py) records the (path, shape) layout. The algorithm
# ('a2q' | 'qat' | 'float') and model topology are static per artifact; the
# (M, N, P) bit widths, learning rate and PRNG seed are runtime inputs.

import jax
import jax.numpy as jnp

from .models import REGISTRY  # noqa: F401  (re-exported for aot/tests)
from . import layers

REG_LAMBDA = 1e-3  # paper B: lambda for L_reg = sum_l sum_i max(t_i - T_i, 0)
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def task_loss(spec, out, y):
    """Cross-entropy for classifiers (y: f32 labels), MSE for SR (y: image)."""
    if spec.task == "classify":
        labels = y.astype(jnp.int32)
        logz = jax.nn.logsumexp(out, axis=-1)
        picked = jnp.take_along_axis(out, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - picked)
    return jnp.mean((out - y) ** 2)


def total_loss(spec, alg, params, x, y, bits):
    out, reg = spec.apply(alg, params, x, bits, train=True)
    return task_loss(spec, out, y) + REG_LAMBDA * reg


# ---------------------------------------------------------------------------
# optimizers (decoupled so the Rust coordinator only supplies lr per step;
# schedules live in Rust)
# ---------------------------------------------------------------------------


def _is_weight(path):
    """Weight decay applies to direction vectors v only, not scales/biases."""
    return path and getattr(path[-1], "key", None) == "v"


def _tree_wd(params, grads, wd):
    return jax.tree_util.tree_map_with_path(
        lambda path, g, p: g + wd * p if _is_weight(path) else g, grads, params
    )


def sgd_step(spec, params, mom, grads, lr):
    grads = _tree_wd(params, grads, spec.weight_decay)
    mom = jax.tree.map(lambda m, g: spec.momentum * m + g, mom, grads)
    params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
    return params, mom


def adam_step(spec, params, m, v, step, grads, lr):
    grads = _tree_wd(params, grads, spec.weight_decay)
    m = jax.tree.map(lambda a, g: ADAM_B1 * a + (1 - ADAM_B1) * g, m, grads)
    v = jax.tree.map(lambda a, g: ADAM_B2 * a + (1 - ADAM_B2) * g * g, v, grads)
    bc1 = 1.0 - ADAM_B1**step
    bc2 = 1.0 - ADAM_B2**step
    params = jax.tree.map(
        lambda p, a, b: p - lr * (a / bc1) / (jnp.sqrt(b / bc2) + ADAM_EPS),
        params,
        m,
        v,
    )
    return params, m, v


# ---------------------------------------------------------------------------
# state flattening
# ---------------------------------------------------------------------------


def init_state(spec, key):
    """(params, opt...) pytree for the model's optimizer, plus a step counter."""
    params = spec.init(key)
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    if spec.optimizer == "sgd":
        return {"params": params, "mom": zeros(), "step": jnp.zeros(())}
    return {"params": params, "m": zeros(), "v": zeros(), "step": jnp.zeros(())}


def state_paths(state):
    """Stable (path, shape) layout of the flattened state for the manifest."""
    leaves = jax.tree_util.tree_leaves_with_path(state)
    return [
        ("/".join(str(getattr(k, "key", k)) for k in path), list(leaf.shape))
        for path, leaf in leaves
    ]


def flatten(tree):
    return jax.tree_util.tree_leaves(tree)


def unflatten_like(tree, leaves):
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree), leaves)


# ---------------------------------------------------------------------------
# graph constructors (one positional-flat callable per artifact)
# ---------------------------------------------------------------------------


def make_init(spec):
    """seed f32[] -> flat initial training state."""

    def fn(seed):
        key = jax.random.PRNGKey(seed.astype(jnp.int32))
        return tuple(flatten(init_state(spec, key)))

    return fn


def make_train_step(spec, alg):
    """(*state, x, y, bits f32[3], lr f32[]) -> (*state', loss)."""
    template = init_state(spec, jax.random.PRNGKey(0))
    n_leaves = len(flatten(template))

    def fn(*args):
        state_leaves = args[:n_leaves]
        x, y, bits, lr = args[n_leaves:]
        state = unflatten_like(template, list(state_leaves))
        params = state["params"]
        bits3 = (bits[0], bits[1], bits[2])
        loss, grads = jax.value_and_grad(total_loss, argnums=2)(
            spec, alg, params, x, y, bits3
        )
        step = state["step"] + 1.0
        if spec.optimizer == "sgd":
            params, mom = sgd_step(spec, params, state["mom"], grads, lr)
            new_state = {"params": params, "mom": mom, "step": step}
        else:
            params, m, v = adam_step(spec, params, state["m"], state["v"], step, grads, lr)
            new_state = {"params": params, "m": m, "v": v, "step": step}
        return tuple(flatten(new_state)) + (loss,)

    return fn, n_leaves, template


def make_infer(spec, alg):
    """(*params, x, bits f32[3]) -> model output (logits or SR image)."""
    p_template = spec.init(jax.random.PRNGKey(0))
    n_leaves = len(flatten(p_template))

    def fn(*args):
        param_leaves = args[:n_leaves]
        x, bits = args[n_leaves:]
        params = unflatten_like(p_template, list(param_leaves))
        out, _ = spec.apply(alg, params, x, (bits[0], bits[1], bits[2]), train=False)
        return (out,)

    return fn, n_leaves, p_template


def make_export(spec, alg):
    """(*params, bits f32[3]) -> per-qlayer (w_int [C,K], s [C,1], b [C]).

    This is the deployment boundary: integer codes + scales feed the Rust
    accsim (bit-exact overflow checks) and the FINN estimator (weight /
    threshold storage). Runs the fused Pallas export kernel
    (layers.export_weight).
    """
    from .models.common import pick

    p_template = spec.init(jax.random.PRNGKey(0))
    n_leaves = len(flatten(p_template))

    def fn(*args):
        param_leaves = args[:n_leaves]
        (bits,) = args[n_leaves:]
        params = unflatten_like(p_template, list(param_leaves))
        bits3 = (bits[0], bits[1], bits[2])
        outs = []
        for q in spec.qlayers:
            lp = params[q.name]
            m = pick(bits3, q.m_bits)
            n = pick(bits3, q.n_bits)
            p = pick(bits3, q.p_bits)
            w_int, s = layers.export_weight(
                alg, lp["v"], lp["d"], lp["t"], m, n, p, 1.0 if q.x_signed else 0.0
            )
            outs += [w_int, s, lp["b"]]
        return tuple(outs)

    return fn, n_leaves, p_template
