# L2 building blocks: quantized layers with STE gradients, calling the L1
# Pallas kernels on the forward path.
#
# Gradient strategy (matches brevitas semantics):
#   * the elementwise quantizer core  q(x) = clip(rnd(x/s), lo, hi) * s  is a
#     custom_vjp primitive `qcore`: forward runs the Pallas affine kernel,
#     backward implements the clipped straight-through estimator (STE [3])
#     plus the LSQ-style scale gradient
#        dq/dx = 1{lo <= rnd(x/s) <= hi}
#        dq/ds = q_int - 1{in range} * x/s
#   * everything around the core (the A2Q weight-normalization reparam
#     w = 2^min(T,t) * v / ||v||_1, the 2^d scale, the regularizer) is plain
#     jnp and differentiates natively.
#   * the Pallas tiled matmul also gets a custom_vjp (dx = g W, dW = g^T x)
#     because pallas_call has no autodiff rule.
#
# Bit widths (M, N, P) are *runtime scalars* threaded through every layer so a
# single AOT artifact serves the entire (M, N, P) grid search from Rust.

import jax
import jax.numpy as jnp

from .kernels.affine import affine_quantize
from .kernels.a2q import a2q_quantize
from .kernels.intmm import int_matmul

LN2 = 0.6931471805599453


# ---------------------------------------------------------------------------
# qcore: elementwise quantizer with STE backward
# ---------------------------------------------------------------------------


@jax.custom_vjp
def qcore(x, s, bits, signed, rtz):
    """clip(rnd(x / s), n(bits, signed), p(bits, signed)) * s.

    x: [R, C]; s: [R, 1] or [1, 1] (pre-broadcast by callers); bits/signed/rtz
    are f32 scalars (runtime). Returns (dequantized, integer_codes).
    """
    q, qi = affine_quantize(x, jnp.broadcast_to(s, (x.shape[0], 1)), bits, signed, rtz)
    return q, qi


def _qcore_fwd(x, s, bits, signed, rtz):
    out = qcore(x, s, bits, signed, rtz)
    return out, (x, s, bits, signed, out[1])


def _qcore_bwd(res, cts):
    x, s, bits, signed, qi = res
    g, _ = cts  # no gradient flows through the integer codes
    lo = jnp.where(signed > 0.5, -(2.0 ** (bits - 1.0)), 0.0)
    hi = jnp.where(signed > 0.5, 2.0 ** (bits - 1.0) - 1.0, 2.0**bits - 1.0)
    u = x / s
    in_range = jnp.asarray((u >= lo) & (u <= hi), jnp.float32)
    gx = g * in_range
    # dq/ds = qi - 1{in} * u   (for clipped elements dq/ds = lo or hi = qi).
    gs_elem = g * (qi - in_range * u)
    gs = jnp.sum(gs_elem, axis=-1, keepdims=True)
    if s.shape[0] == 1:
        gs = jnp.sum(gs, axis=0, keepdims=True)
    return gx, gs, jnp.zeros(()), jnp.zeros(()), jnp.zeros(())


qcore.defvjp(_qcore_fwd, _qcore_bwd)


# ---------------------------------------------------------------------------
# matmul with VJP around the Pallas kernel
# ---------------------------------------------------------------------------


@jax.custom_vjp
def qmatmul(x, w):
    """y[b, c] = sum_k x[b, k] w[c, k] via the Pallas MXU-tiled kernel."""
    return int_matmul(x, w)


def _qmm_fwd(x, w):
    return int_matmul(x, w), (x, w)


def _qmm_bwd(res, g):
    x, w = res
    return int_matmul(g, w.T), int_matmul(g.T, x.T)


qmatmul.defvjp(_qmm_fwd, _qmm_bwd)


# ---------------------------------------------------------------------------
# weight quantizers
# ---------------------------------------------------------------------------


def a2q_weight(v, d, t, m_bits, n_bits, p_bits, x_signed):
    """A2Q weight quantizer (paper Eq. 20-23) with training gradients.

    v [C, K], d [C, 1], t [C, 1]. Returns (w_q [C, K], reg) where
    reg = sum_i max(t_i - T_i, 0), the penalty of paper Sec. 4.1 that keeps t
    from drifting above its accumulator cap T.
    """
    s = 2.0**d
    cap = x_signed + jnp.log2(2.0 ** (p_bits - 1.0) - 1.0) + d - n_bits
    g = 2.0 ** jnp.minimum(cap, t)
    l1 = jnp.sum(jnp.abs(v), axis=-1, keepdims=True)
    w_cont = g * v / jnp.where(l1 == 0.0, 1.0, l1)
    w_q, _ = qcore(w_cont, s, m_bits, jnp.float32(1.0), jnp.float32(1.0))
    reg = jnp.sum(jnp.maximum(t - cap, 0.0))
    return w_q, reg


def qat_weight(v, d, m_bits):
    """Baseline-QAT weight quantizer: per-channel symmetric affine, half-even."""
    s = 2.0**d
    w_q, _ = qcore(v, s, m_bits, jnp.float32(1.0), jnp.float32(0.0))
    return w_q, jnp.zeros(())


def quantize_weight(alg, v, d, t, m_bits, n_bits, p_bits, x_signed):
    """Dispatch on the (static) algorithm: 'a2q' | 'qat' | 'float'."""
    if alg == "a2q":
        return a2q_weight(v, d, t, m_bits, n_bits, p_bits, x_signed)
    if alg == "qat":
        return qat_weight(v, d, m_bits)
    if alg == "float":
        return v, jnp.zeros(())
    raise ValueError(f"unknown alg {alg!r}")


def export_weight(alg, v, d, t, m_bits, n_bits, p_bits, x_signed):
    """Integer codes + scale for deployment (Rust accsim / FINN estimator).

    Runs the *full-pipeline* Pallas kernel (a2q_quantize) so the export path
    exercises the fused kernel, not the training decomposition.
    """
    if alg == "a2q":
        _, w_int, s = a2q_quantize(v, d, t, m_bits, n_bits, p_bits, x_signed)
        return w_int, s
    if alg in ("qat", "float"):
        s = 2.0**d
        _, w_int = affine_quantize(v, s, m_bits, 1.0, False)
        return w_int, jnp.broadcast_to(s, (v.shape[0], 1))
    raise ValueError(f"unknown alg {alg!r}")


# ---------------------------------------------------------------------------
# activation quantizer
# ---------------------------------------------------------------------------


def quant_act(alg, x, d_act, n_bits, signed):
    """Per-tensor activation quantizer (standard QAT; used by both algorithms,
    paper Sec. 4.1 end). x may be 2D [B, F] or 4D [B, H, W, C]."""
    if alg == "float":
        return x
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    s = (2.0**d_act).reshape(1, 1)
    q, _ = qcore(x2, s, n_bits, jnp.asarray(signed, jnp.float32), jnp.float32(0.0))
    return q.reshape(shape)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def init_dense(key, k_in, c_out, scale=1.0):
    """Parameters for a quantized dense layer: v [C, K], d/t [C, 1], b [C]."""
    v = jax.random.normal(key, (c_out, k_in)) * (scale / jnp.sqrt(k_in))
    return _with_qparams(v, c_out)


def init_conv(key, kh, kw, c_in, c_out, groups=1):
    """Parameters for a conv layer stored flat as v [C_out, K=kh*kw*(c_in/groups)]."""
    k = kh * kw * (c_in // groups)
    v = jax.random.normal(key, (c_out, k)) * jnp.sqrt(2.0 / k)
    p = _with_qparams(v, c_out)
    return p


def _with_qparams(v, c_out):
    max_abs = jnp.maximum(jnp.max(jnp.abs(v), axis=-1, keepdims=True), 1e-8)
    d = jnp.log2(max_abs / 127.0)  # init as if M = 8
    t = jnp.log2(jnp.maximum(jnp.sum(jnp.abs(v), axis=-1, keepdims=True), 1e-8))
    return {"v": v, "d": d, "t": t, "b": jnp.zeros((c_out,))}


def init_act(init_scale_log2=-5.0):
    """Per-tensor activation quantizer parameter (log2 scale)."""
    return {"d": jnp.full((1, 1), init_scale_log2)}


def dense(alg, p, x, m_bits, n_bits, p_bits, x_signed):
    """Quantized dense layer over pre-quantized input x [B, K]."""
    w_q, reg = quantize_weight(alg, p["v"], p["d"], p["t"], m_bits, n_bits, p_bits, x_signed)
    y = qmatmul(x, w_q) + p["b"][None, :]
    return y, reg


def conv2d(alg, p, x, m_bits, n_bits, p_bits, x_signed, kh, kw, c_in, c_out, stride=1, groups=1):
    """Quantized conv layer; weights live flat as [C_out, K] for the per-channel
    quantizers (each output channel's accumulator sees K = kh*kw*(c_in/groups)
    MACs -- the granularity of paper Eq. 15), reshaped to HWIO for lax.conv."""
    w_q, reg = quantize_weight(alg, p["v"], p["d"], p["t"], m_bits, n_bits, p_bits, x_signed)
    w = w_q.reshape(c_out, kh, kw, c_in // groups).transpose(1, 2, 3, 0)
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    return y + p["b"][None, None, None, :], reg


def nn_upsample(x, r):
    """Nearest-neighbor resize by integer factor r (NNRC upsampling, paper B.2)."""
    x = jnp.repeat(x, r, axis=1)
    return jnp.repeat(x, r, axis=2)


def avg_pool_global(x):
    return jnp.mean(x, axis=(1, 2))
