# AOT export: lower every (model, alg, mode) graph to HLO *text* plus a JSON
# manifest describing the artifact interface for the Rust runtime.
#
# HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
# HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
# version behind the published `xla` rust crate) rejects; the HLO text parser
# reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
#
# Python runs ONCE at `make artifacts`; after that the Rust binary is fully
# self-contained: it initializes, trains, evaluates and exports models purely
# by executing these artifacts via PJRT.

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .models import REGISTRY

ALGS = ("a2q", "qat", "float")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shapes_of(tree):
    return [list(l.shape) for l in M.flatten(tree)]


def lower_model(spec, out_dir, algs, verbose=True):
    """Lower init/train/infer/export for one model; return its manifest dict."""
    name = spec.name
    bs = spec.batch_size
    x_shape = [bs, *spec.input_shape]
    y_shape = [bs] if spec.task == "classify" else [bs, *spec.target_shape]

    files = {}

    def emit(tag, fn, arg_specs):
        fname = f"{name}_{tag}.hlo.txt"
        path = os.path.join(out_dir, fname)
        # keep_unused=True: the artifact interface is positional and fixed;
        # graphs that ignore an input (e.g. the float baseline ignores `bits`)
        # must still accept it so the Rust runtime can treat every train step
        # identically.
        lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        files[tag] = fname
        if verbose:
            print(f"  {fname}: {len(text)//1024} KiB")
        return path

    # --- init (alg-independent: quantizer params are part of the state for
    # every algorithm, float simply ignores them) ------------------------------
    emit("init", M.make_init(spec), [_spec(())])

    template = M.init_state(spec, jax.random.PRNGKey(0))
    state_layout = M.state_paths(template)
    params_layout = M.state_paths(template["params"])
    state_specs = [_spec(s) for _, s in state_layout]
    param_specs = [_spec(s) for _, s in params_layout]

    per_alg = {}
    for alg in algs:
        train_fn, _, _ = M.make_train_step(spec, alg)
        emit(f"{alg}_train", train_fn, state_specs + [_spec(x_shape), _spec(y_shape), _spec([3]), _spec(())])

        infer_fn, _, _ = M.make_infer(spec, alg)
        emit(f"{alg}_infer", infer_fn, param_specs + [_spec(x_shape), _spec([3])])

        entry = {"train": files[f"{alg}_train"], "infer": files[f"{alg}_infer"]}
        if alg != "float":
            export_fn, _, _ = M.make_export(spec, alg)
            emit(f"{alg}_export", export_fn, param_specs + [_spec([3])])
            entry["export"] = files[f"{alg}_export"]
        per_alg[alg] = entry

    export_outputs = []
    for q in spec.qlayers:
        export_outputs += [
            {"layer": q.name, "tensor": "w_int", "shape": [q.c_out, q.k]},
            {"layer": q.name, "tensor": "s", "shape": [q.c_out, 1]},
            {"layer": q.name, "tensor": "b", "shape": [q.c_out]},
        ]

    manifest = spec.manifest()
    manifest.update(
        {
            "init": files["init"],
            "algs": per_alg,
            "state": [{"path": p, "shape": s} for p, s in state_layout],
            "params": [{"path": p, "shape": s} for p, s in params_layout],
            "export_outputs": export_outputs,
            "train_inputs": {"x": x_shape, "y": y_shape, "bits": [3], "lr": []},
        }
    )
    return manifest


def input_fingerprint():
    """Hash of the compile package, so `make artifacts` can skip clean rebuilds."""
    root = os.path.dirname(__file__)
    h = hashlib.sha256()
    for dirpath, _, fnames in sorted(os.walk(root)):
        for fn in sorted(fnames):
            if fn.endswith(".py"):
                with open(os.path.join(dirpath, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument("--models", default=",".join(REGISTRY), help="comma-separated subset")
    ap.add_argument("--algs", default=",".join(ALGS))
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    models = [m for m in args.models.split(",") if m]
    algs = [a for a in args.algs.split(",") if a]

    index = {"fingerprint": input_fingerprint(), "models": {}}
    for name in models:
        spec = REGISTRY[name]
        print(f"[aot] lowering {name} (bs={spec.batch_size}, K*={spec.largest_k()})")
        manifest = lower_model(spec, out_dir, algs)
        mpath = os.path.join(out_dir, f"{name}.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
        index["models"][name] = f"{name}.json"

    with open(os.path.join(out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"[aot] wrote {len(models)} manifests to {out_dir}")


if __name__ == "__main__":
    main()
