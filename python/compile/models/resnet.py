# ResNet18-style residual CNN for synthetic CIFAR (paper B.1): 3x3 stem with
# stride/padding 1, no max pool, and *convolutional* shortcuts (the paper
# found conv shortcuts superior to identity for quantized residual blocks).
# Depth/width-reduced to two residual stages for the 16x16 substrate.

import jax

from .. import layers
from .common import ModelSpec, QLayer, pick

H = W = 16
C_IN = 3
W0, W1, W2 = 32, 64, 128
N_CLASSES = 10


def init(key):
    ks = jax.random.split(key, 9)
    return {
        "stem": layers.init_conv(ks[0], 3, 3, C_IN, W0),
        "b1c1": layers.init_conv(ks[1], 3, 3, W0, W1),
        "b1c2": layers.init_conv(ks[2], 3, 3, W1, W1),
        "b1sc": layers.init_conv(ks[3], 1, 1, W0, W1),
        "b2c1": layers.init_conv(ks[4], 3, 3, W1, W2),
        "b2c2": layers.init_conv(ks[5], 3, 3, W2, W2),
        "b2sc": layers.init_conv(ks[6], 1, 1, W1, W2),
        "head": layers.init_dense(ks[7], W2, N_CLASSES),
        "aq": {f"a{i}": layers.init_act() for i in range(6)},
    }


def apply(alg, params, x, bits, train):
    m, n, p = (pick(bits, s) for s in ("M", "N", "P"))
    aq = params["aq"]
    regs = []

    def conv(name, h, kh, cin, cout, stride, mm, nn, pp):
        y, reg = layers.conv2d(alg, params[name], h, mm, nn, pp, 0.0, kh, kh, cin, cout, stride)
        regs.append(reg)
        return y

    def act(h, key, bitsv):
        return layers.quant_act(alg, jax.nn.relu(h), aq[key]["d"], bitsv, 0.0)

    h = act(conv("stem", x, 3, C_IN, W0, 1, 8.0, 8.0, 32.0), "a0", n)

    # residual stage 1: W0 -> W1, stride 2, conv shortcut
    y = act(conv("b1c1", h, 3, W0, W1, 2, m, n, p), "a1", n)
    y = conv("b1c2", y, 3, W1, W1, 1, m, n, p)
    sc = conv("b1sc", h, 1, W0, W1, 2, m, n, p)
    h = act(y + sc, "a2", n)

    # residual stage 2: W1 -> W2, stride 2, conv shortcut
    y = act(conv("b2c1", h, 3, W1, W2, 2, m, n, p), "a3", n)
    y = conv("b2c2", y, 3, W2, W2, 1, m, n, p)
    sc = conv("b2sc", h, 1, W1, W2, 2, m, n, p)
    h = act(y + sc, "a4", 8.0)  # feeds the 8-bit head

    h = layers.avg_pool_global(h)
    logits, reg = layers.dense(alg, params["head"], h, 8.0, 8.0, 32.0, 0.0)
    regs.append(reg)
    return logits, sum(regs)


SPEC = ModelSpec(
    name="resnet",
    input_shape=(H, W, C_IN),
    batch_size=64,
    task="classify",
    n_classes=N_CLASSES,
    optimizer="sgd",
    lr=5e-2,
    weight_decay=1e-5,
    init=init,
    apply=apply,
    qlayers=[
        QLayer("stem", "conv", W0, 9 * C_IN, 8, 8, 32, False, 16, 16, 3, 3, C_IN),
        QLayer("b1c1", "conv", W1, 9 * W0, "M", "N", "P", False, 8, 8, 3, 3, W0, 2),
        QLayer("b1c2", "conv", W1, 9 * W1, "M", "N", "P", False, 8, 8, 3, 3, W1),
        QLayer("b1sc", "conv", W1, W0, "M", "N", "P", False, 8, 8, 1, 1, W0, 2),
        QLayer("b2c1", "conv", W2, 9 * W1, "M", "N", "P", False, 4, 4, 3, 3, W1, 2),
        QLayer("b2c2", "conv", W2, 9 * W2, "M", "N", "P", False, 4, 4, 3, 3, W2),
        QLayer("b2sc", "conv", W2, W1, "M", "N", "P", False, 4, 4, 1, 1, W1, 2),
        QLayer("head", "dense", N_CLASSES, W2, 8, 8, 32, False, c_in=W2),
    ],
)
