# ESPCN-style single-image super-resolution network (paper B.2): the
# sub-pixel convolution is replaced by a nearest-neighbor resize convolution
# (NNRC), exactly as the paper does for hardware friendliness. 3x upscaling
# of grayscale synthetic-BSD patches.

import jax

from .. import layers
from .common import ModelSpec, QLayer, pick

H = W = 16
FACTOR = 3
W0, W1 = 32, 32


def init(key):
    ks = jax.random.split(key, 5)
    return {
        "c1": layers.init_conv(ks[0], 5, 5, 1, W0),
        "c2": layers.init_conv(ks[1], 3, 3, W0, W1),
        "c3": layers.init_conv(ks[2], 3, 3, W1, W1),
        "out": layers.init_conv(ks[3], 3, 3, W1, 1),
        "aq": {f"a{i}": layers.init_act() for i in range(3)} | {"out": layers.init_act(-8.0)},
    }


def apply(alg, params, x, bits, train):
    m, n, p = (pick(bits, s) for s in ("M", "N", "P"))
    aq = params["aq"]
    regs = []

    def conv(name, h, kh, cin, cout, mm, nn, pp):
        y, reg = layers.conv2d(alg, params[name], h, mm, nn, pp, 0.0, kh, kh, cin, cout, 1)
        regs.append(reg)
        return y

    def act(h, key, bitsv):
        return layers.quant_act(alg, jax.nn.relu(h), aq[key]["d"], bitsv, 0.0)

    h = act(conv("c1", x, 5, 1, W0, 8.0, 8.0, 32.0), "a0", n)
    h = act(conv("c2", h, 3, W0, W1, m, n, p), "a1", n)
    h = act(conv("c3", h, 3, W1, W1, m, n, p), "a2", 8.0)  # feeds 8-bit output layer
    h = layers.nn_upsample(h, FACTOR)
    y = conv("out", h, 3, W1, 1, 8.0, 8.0, 32.0)
    # Output layer carries 8-bit unsigned activations (paper fixes the output
    # layer to 8-bit weights *and* activations).
    y = layers.quant_act(alg, y, aq["out"]["d"], 8.0, 0.0)
    return y, sum(regs)


SPEC = ModelSpec(
    name="espcn",
    input_shape=(H, W, 1),
    batch_size=16,
    task="sr",
    sr_factor=FACTOR,
    optimizer="adam",
    lr=1e-3,
    weight_decay=1e-4,
    init=init,
    apply=apply,
    qlayers=[
        QLayer("c1", "conv", W0, 25, 8, 8, 32, False, 16, 16, 5, 5, 1),
        QLayer("c2", "conv", W1, 9 * W0, "M", "N", "P", False, 16, 16, 3, 3, W0),
        QLayer("c3", "conv", W1, 9 * W1, "M", "N", "P", False, 16, 16, 3, 3, W1),
        QLayer("out", "conv", 1, 9 * W1, 8, 8, 32, False, 48, 48, 3, 3, W1),
    ],
)
