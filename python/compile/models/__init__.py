# Model zoo registry: the paper's four benchmark topologies (paper Sec. 5.1)
# plus the 1-layer binary-MNIST model of the motivating example (Fig. 2).

from . import cnn, espcn, mlp, resnet, unet

REGISTRY = {
    s.name: s for s in (mlp.SPEC, cnn.SPEC, resnet.SPEC, espcn.SPEC, unet.SPEC)
}

__all__ = ["REGISTRY"]
