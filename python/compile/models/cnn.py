# MobileNetV1-style depthwise-separable CNN for synthetic CIFAR (paper B.1,
# width/depth-reduced for the 16x16 synthetic substrate -- see DESIGN.md
# substitution table). First/last layers are fixed at 8-bit weights and
# activations with unconstrained (32-bit) accumulators, as in the paper;
# every hidden layer uses the runtime (M, N, P) triple.

import jax
import jax.numpy as jnp

from .. import layers
from .common import ModelSpec, QLayer, pick

H = W = 16
C_IN = 3
WIDTHS = (32, 64, 128, 128)
N_CLASSES = 10


def init(key):
    ks = jax.random.split(key, 9)
    w0, w1, w2, w3 = WIDTHS
    return {
        "stem": layers.init_conv(ks[0], 3, 3, C_IN, w0),
        "dw1": layers.init_conv(ks[1], 3, 3, w0, w0, groups=w0),
        "pw1": layers.init_conv(ks[2], 1, 1, w0, w1),
        "dw2": layers.init_conv(ks[3], 3, 3, w1, w1, groups=w1),
        "pw2": layers.init_conv(ks[4], 1, 1, w1, w2),
        "dw3": layers.init_conv(ks[5], 3, 3, w2, w2, groups=w2),
        "pw3": layers.init_conv(ks[6], 1, 1, w2, w3),
        "head": layers.init_dense(ks[7], w3, N_CLASSES),
        "aq": {f"a{i}": layers.init_act() for i in range(7)},
    }


def apply(alg, params, x, bits, train):
    m, n, p = (pick(bits, s) for s in ("M", "N", "P"))
    w0, w1, w2, w3 = WIDTHS
    aq = params["aq"]
    regs = []

    def block(name, h, kh, cin, cout, stride, groups, mm, nn, pp, aq_bits, aq_key):
        y, reg = layers.conv2d(
            alg, params[name], h, mm, nn, pp, 0.0, kh, kh, cin, cout, stride, groups
        )
        regs.append(reg)
        y = jax.nn.relu(y)
        return layers.quant_act(alg, y, aq[aq_key]["d"], aq_bits, 0.0)

    h = block("stem", x, 3, C_IN, w0, 1, 1, 8.0, 8.0, 32.0, n, "a0")
    h = block("dw1", h, 3, w0, w0, 2, w0, m, n, p, n, "a1")
    h = block("pw1", h, 1, w0, w1, 1, 1, m, n, p, n, "a2")
    h = block("dw2", h, 3, w1, w1, 2, w1, m, n, p, n, "a3")
    h = block("pw2", h, 1, w1, w2, 1, 1, m, n, p, n, "a4")
    h = block("dw3", h, 3, w2, w2, 1, w2, m, n, p, n, "a5")
    h = block("pw3", h, 1, w2, w3, 1, 1, m, n, p, 8.0, "a6")  # feeds 8-bit head
    h = layers.avg_pool_global(h)
    logits, reg = layers.dense(alg, params["head"], h, 8.0, 8.0, 32.0, 0.0)
    regs.append(reg)
    return logits, sum(regs)


def _q(name, kind, cout, k, m, n, p, oh, ow, kh, cin, stride=1, groups=1):
    return QLayer(name, kind, cout, k, m, n, p, False, oh, ow, kh, kh, cin, stride, groups)


w0, w1, w2, w3 = WIDTHS
SPEC = ModelSpec(
    name="cnn",
    input_shape=(H, W, C_IN),
    batch_size=64,
    task="classify",
    n_classes=N_CLASSES,
    optimizer="sgd",
    lr=5e-2,
    weight_decay=1e-5,
    init=init,
    apply=apply,
    qlayers=[
        _q("stem", "conv", w0, 9 * C_IN, 8, 8, 32, 16, 16, 3, C_IN),
        _q("dw1", "dwconv", w0, 9, "M", "N", "P", 8, 8, 3, w0, 2, w0),
        _q("pw1", "conv", w1, w0, "M", "N", "P", 8, 8, 1, w0),
        _q("dw2", "dwconv", w1, 9, "M", "N", "P", 4, 4, 3, w1, 2, w1),
        _q("pw2", "conv", w2, w1, "M", "N", "P", 4, 4, 1, w1),
        _q("dw3", "dwconv", w2, 9, "M", "N", "P", 4, 4, 3, w2, 1, w2),
        _q("pw3", "conv", w3, w2, "M", "N", "P", 4, 4, 1, w2),
        QLayer("head", "dense", N_CLASSES, w3, 8, 8, 32, False, c_in=w3),
    ],
)
