# UNet-style super-resolution network (paper B.2, shrunk): encoder/decoder
# with additive skip connections (the paper replaces concatenations with
# additions), transposed convolutions replaced by NNRCs, and a final NNRC 3x
# stage for upscaling. Grayscale synthetic-BSD patches.

import jax

from .. import layers
from .common import ModelSpec, QLayer, pick

H = W = 16
FACTOR = 3
W0, W1 = 16, 32


def init(key):
    ks = jax.random.split(key, 7)
    return {
        "enc1": layers.init_conv(ks[0], 3, 3, 1, W0),
        "down": layers.init_conv(ks[1], 3, 3, W0, W1),
        "bott": layers.init_conv(ks[2], 3, 3, W1, W1),
        "up": layers.init_conv(ks[3], 3, 3, W1, W0),
        "dec1": layers.init_conv(ks[4], 3, 3, W0, W0),
        "out": layers.init_conv(ks[5], 3, 3, W0, 1),
        "aq": {f"a{i}": layers.init_act() for i in range(5)} | {"out": layers.init_act(-8.0)},
    }


def apply(alg, params, x, bits, train):
    m, n, p = (pick(bits, s) for s in ("M", "N", "P"))
    aq = params["aq"]
    regs = []

    def conv(name, h, cin, cout, stride, mm, nn, pp):
        y, reg = layers.conv2d(alg, params[name], h, mm, nn, pp, 0.0, 3, 3, cin, cout, stride)
        regs.append(reg)
        return y

    def act(h, key, bitsv):
        return layers.quant_act(alg, jax.nn.relu(h), aq[key]["d"], bitsv, 0.0)

    e1 = act(conv("enc1", x, 1, W0, 1, 8.0, 8.0, 32.0), "a0", n)  # 16x16xW0
    h = act(conv("down", e1, W0, W1, 2, m, n, p), "a1", n)  # 8x8xW1
    h = act(conv("bott", h, W1, W1, 1, m, n, p), "a2", n)  # 8x8xW1
    h = layers.nn_upsample(h, 2)  # 16x16xW1
    h = act(conv("up", h, W1, W0, 1, m, n, p), "a3", n)  # 16x16xW0
    h = h + e1  # additive skip (paper B.2)
    h = act(conv("dec1", h, W0, W0, 1, m, n, p), "a4", 8.0)  # feeds 8-bit out
    h = layers.nn_upsample(h, FACTOR)  # 48x48xW0
    y = conv("out", h, W0, 1, 1, 8.0, 8.0, 32.0)
    y = layers.quant_act(alg, y, aq["out"]["d"], 8.0, 0.0)
    return y, sum(regs)


SPEC = ModelSpec(
    name="unet",
    input_shape=(H, W, 1),
    batch_size=16,
    task="sr",
    sr_factor=FACTOR,
    optimizer="adam",
    lr=1e-3,
    weight_decay=1e-4,
    init=init,
    apply=apply,
    qlayers=[
        QLayer("enc1", "conv", W0, 9, 8, 8, 32, False, 16, 16, 3, 3, 1),
        QLayer("down", "conv", W1, 9 * W0, "M", "N", "P", False, 8, 8, 3, 3, W0, 2),
        QLayer("bott", "conv", W1, 9 * W1, "M", "N", "P", False, 8, 8, 3, 3, W1),
        QLayer("up", "conv", W0, 9 * W1, "M", "N", "P", False, 16, 16, 3, 3, W1),
        QLayer("dec1", "conv", W0, 9 * W0, "M", "N", "P", False, 16, 16, 3, 3, W0),
        QLayer("out", "conv", 1, 9 * W0, 8, 8, 32, False, 48, 48, 3, 3, W0),
    ],
)
