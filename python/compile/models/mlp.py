# The paper's motivating model (Fig. 2 / Appendix A): a 1-layer linear QNN
# classifying binary MNIST. Inputs are 784-dimensional 1-bit unsigned vectors
# (N = 1), weights are 8-bit (M = 8), and the accumulator target P is the
# runtime variable under study: the data-type bound (Eq. 8) gives P = 19 at
# K = 784, and Fig. 2 sweeps P below it.

import jax

from .. import layers
from .common import ModelSpec, QLayer, pick

K_IN = 784
N_CLASSES = 2


def init(key):
    return {"fc": layers.init_dense(key, K_IN, N_CLASSES)}


def apply(alg, params, x, bits, train):
    # x is exactly representable in 1 bit ({0, 1}); no input quantizer needed.
    _, _, p_bits = bits
    p = pick(bits, "P")
    logits, reg = layers.dense(alg, params["fc"], x, 8.0, 1.0, p, 0.0)
    return logits, reg


SPEC = ModelSpec(
    name="mlp",
    input_shape=(K_IN,),
    batch_size=128,
    task="classify",
    n_classes=N_CLASSES,
    optimizer="sgd",
    lr=1e-2,
    weight_decay=1e-5,
    init=init,
    apply=apply,
    qlayers=[
        QLayer("fc", "dense", N_CLASSES, K_IN, 8, 1, "P", False, c_in=K_IN),
    ],
)
