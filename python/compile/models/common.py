# Shared model-zoo plumbing: the ModelSpec contract every model module
# implements, and the QLayer metadata that flows into the artifact manifest so
# the Rust coordinator (FINN estimator, accsim, export) knows each layer's
# geometry without re-deriving it from HLO.

from dataclasses import dataclass, field
from typing import Callable, List, Tuple


@dataclass
class QLayer:
    """Metadata for one quantized layer (the unit paper Eq. 15 constrains).

    Attributes:
      name:     stable identifier, also the pytree key of its parameters.
      kind:     'dense' | 'conv' | 'dwconv'
      c_out:    output channels (== number of accumulators).
      k:        dot-product length per accumulator (kh*kw*c_in/groups).
      m_bits:   'M' for the runtime hidden-layer width, or a fixed int (8).
      n_bits:   'N' for runtime, or fixed int (8 for data/head, 1 for bMNIST).
      p_bits:   'P' for runtime accumulator target, or fixed int (32).
      x_signed: whether this layer's *input* is signed (False after ReLU
                quant / unsigned image data).
      out_h/out_w: spatial size of the output feature map (1 for dense) --
                used by the FINN estimator for stream folding.
      kh/kw/c_in/stride/groups: conv geometry (dense: kh=kw=1, c_in=k).
    """

    name: str
    kind: str
    c_out: int
    k: int
    m_bits: object
    n_bits: object
    p_bits: object
    x_signed: bool
    out_h: int = 1
    out_w: int = 1
    kh: int = 1
    kw: int = 1
    c_in: int = 0
    stride: int = 1
    groups: int = 1

    def manifest(self):
        return {
            "name": self.name,
            "kind": self.kind,
            "c_out": self.c_out,
            "k": self.k,
            "m_bits": self.m_bits if isinstance(self.m_bits, int) else str(self.m_bits),
            "n_bits": self.n_bits if isinstance(self.n_bits, int) else str(self.n_bits),
            "p_bits": self.p_bits if isinstance(self.p_bits, int) else str(self.p_bits),
            "x_signed": self.x_signed,
            "out_h": self.out_h,
            "out_w": self.out_w,
            "kh": self.kh,
            "kw": self.kw,
            "c_in": self.c_in,
            "stride": self.stride,
            "groups": self.groups,
        }


@dataclass
class ModelSpec:
    """Contract between the model zoo, aot.py and the Rust coordinator.

    apply(alg, params, x, bits, train) -> (output, reg) where bits is the
    (M, N, P) runtime scalar triple and alg in {'a2q', 'qat', 'float'} is a
    *static* structural choice (one artifact per (model, alg)).
    """

    name: str
    input_shape: Tuple[int, ...]  # per-sample, NHWC (or flat for mlp)
    batch_size: int
    task: str  # 'classify' | 'sr'
    n_classes: int = 0
    sr_factor: int = 0
    optimizer: str = "sgd"  # 'sgd' | 'adam'
    lr: float = 1e-2
    weight_decay: float = 1e-5
    momentum: float = 0.9
    init: Callable = None
    apply: Callable = None
    qlayers: List[QLayer] = field(default_factory=list)

    @property
    def target_shape(self):
        if self.task == "classify":
            return ()
        h, w, _ = self.input_shape
        return (h * self.sr_factor, w * self.sr_factor, 1)

    def largest_k(self):
        """K* = argmax_l K_l: the layer that sets the model's data-type bound
        on the accumulator (paper Sec. 5.1)."""
        return max(q.k for q in self.qlayers)

    def manifest(self):
        return {
            "name": self.name,
            "input_shape": list(self.input_shape),
            "batch_size": self.batch_size,
            "task": self.task,
            "n_classes": self.n_classes,
            "sr_factor": self.sr_factor,
            "optimizer": self.optimizer,
            "lr": self.lr,
            "weight_decay": self.weight_decay,
            "largest_k": self.largest_k(),
            "qlayers": [q.manifest() for q in self.qlayers],
        }


def pick(bits, spec_val):
    """Resolve a QLayer bit-width spec against the runtime (M, N, P) triple."""
    m, n, p = bits
    if spec_val == "M":
        return m
    if spec_val == "N":
        return n
    if spec_val == "P":
        return p
    return float(spec_val)
