# L1 Pallas kernel: the baseline uniform affine quantizer (paper Eq. 1/2).
#
# This is the standard QAT fake-quant operator with z = 0 and half-even
# rounding -- used for (a) the baseline-QAT weight quantizer the paper
# compares against in Figs. 4/6, and (b) all activation quantizers (both
# algorithms quantize activations the standard way, paper Sec. 4.1 end).
#
# Elementwise with a row-broadcast scale, so the BlockSpec tiles rows and
# keeps full rows in VMEM; bit-width bounds are runtime scalars.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SCALAR_SPEC = pl.BlockSpec((1, 1), lambda *_: (0, 0))


def _scalar(x):
    return jnp.asarray(x, jnp.float32).reshape(1, 1)


def _affine_kernel(x_ref, s_ref, bits_ref, sig_ref, rtz_ref, q_ref, qi_ref):
    """One row-block of the affine quantizer.

    x_ref:    [Rb, C] values
    s_ref:    [Rb, 1] scale (caller broadcasts per-tensor scales to rows)
    bits_ref: [1, 1]  bit width
    sig_ref:  [1, 1]  1.0 if the quantized domain is signed
    rtz_ref:  [1, 1]  1.0 -> round-toward-zero, 0.0 -> half-even (Eq. 1)
    """
    x = x_ref[...]
    s = s_ref[...]
    bits = bits_ref[0, 0]
    sig = sig_ref[0, 0]
    rtz = rtz_ref[0, 0]

    lo = jnp.where(sig > 0.5, -(2.0 ** (bits - 1.0)), 0.0)
    hi = jnp.where(sig > 0.5, 2.0 ** (bits - 1.0) - 1.0, 2.0**bits - 1.0)
    u = x / s
    r = jnp.where(rtz > 0.5, jnp.trunc(u), jnp.round(u))
    q = jnp.clip(r, lo, hi)
    q_ref[...] = q * s
    qi_ref[...] = q


def _row_block(r, c):
    budget = 256 * 1024 // 4
    rb = max(1, min(r, budget // max(c, 1)))
    if rb >= 8:
        rb -= rb % 8
    return rb


@functools.partial(jax.jit, static_argnames=())
def affine_quantize(x, scale, bits, signed, rtz=False):
    """Pallas uniform affine quantizer over a [R, C] tensor.

    `scale` may be per-tensor (scalar) or per-row ([R] / [R, 1]); it is
    broadcast to rows before entering the kernel. Mirrors
    ref.ref_affine_quantize (rtz=False) / ref.ref_rtz_quantize (rtz=True).
    Returns (dequantized, integer_codes).
    """
    x = jnp.asarray(x, jnp.float32)
    r, c = x.shape
    s = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(-1, 1), (r, 1))
    rb = _row_block(r, c)
    grid = (pl.cdiv(r, rb),)

    out = pl.pallas_call(
        _affine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, c), lambda i: (i, 0)),
            pl.BlockSpec((rb, 1), lambda i: (i, 0)),
            _SCALAR_SPEC,
            _SCALAR_SPEC,
            _SCALAR_SPEC,
        ],
        out_specs=[
            pl.BlockSpec((rb, c), lambda i: (i, 0)),
            pl.BlockSpec((rb, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.float32),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
        ],
        interpret=True,
    )(
        x,
        s,
        _scalar(bits),
        _scalar(1.0 if signed is True else 0.0 if signed is False else signed),
        _scalar(1.0 if rtz is True else 0.0 if rtz is False else rtz),
    )
    return tuple(out)
