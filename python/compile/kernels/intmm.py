# L1 Pallas kernel: tiled quantized matmul  y[b, c] = sum_k x[b, k] w[c, k].
#
# This is the fixed-point dot product of paper Fig. 1 as lowered for a TPU:
# MXU-aligned (up to 128x128x128) tiles, accumulation in the output block
# across the K grid axis (the classic revisiting-accumulator Pallas pattern).
#
# Numerics note: the kernel accumulates in fp32. fp32 holds every integer up
# to 2^24 exactly, so for quantized operands the emulation is *bit-exact*
# whenever all partial sums fit in 24 bits -- which A2Q's constraint
# guarantees for every P <= 24 we evaluate (paper's range is P <= 32 on the
# register, but the magnitude bound is 2^(P-1)-1 with P <= 24 in all our
# sweeps). The Rust `accsim` substrate performs the wide-register bit-exact
# check for arbitrary P.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # x block: [bm, bk], w block: [bn, bk] -> contribution [bm, bn] on the MXU.
    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _tile(n, target):
    return min(n, target)


@functools.partial(jax.jit, static_argnames=())
def int_matmul(x, w):
    """Pallas tiled matmul: x [B, K] times w [C, K] transposed -> [B, C].

    Mirrors ref.ref_int_matmul. Operands are quantized values carried in
    fp32 (see module docstring for why this is exact in the A2Q regime).
    """
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    b, k = x.shape
    c, k2 = w.shape
    assert k == k2, f"contraction mismatch: {k} vs {k2}"

    bm, bn, bk = _tile(b, 128), _tile(c, 128), _tile(k, 128)

    # Zero-pad every axis to a tile multiple: interpret-mode edge blocks read
    # unspecified padding, and the K axis is contracted so garbage there would
    # pollute *valid* outputs. Zero padding keeps the sum exact.
    bp, cp, kp = -(-b // bm) * bm, -(-c // bn) * bn, -(-k // bk) * bk
    if (bp, kp) != (b, k):
        x = jnp.pad(x, ((0, bp - b), (0, kp - k)))
    if (cp, kp) != (c, k):
        w = jnp.pad(w, ((0, cp - c), (0, kp - k)))
    grid = (bp // bm, cp // bn, kp // bk)

    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bn, bk), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, cp), jnp.float32),
        interpret=True,
    )(x, w)
    return out[:b, :c]
