# Pure-jnp correctness oracles for the Pallas kernels.
#
# Everything here is the *reference semantics* of the paper's operators:
#
#   * `ref_affine_quantize`   -- standard uniform affine quantizer (paper Eq. 1/2)
#     with half-even rounding, the baseline-QAT weight/activation quantizer.
#   * `ref_rtz_quantize`      -- scale / round-toward-zero / clip / dequantize,
#     the elementwise core of A2Q (paper Eq. 20).
#   * `ref_a2q_quantize`      -- the full accumulator-aware weight quantizer
#     (paper Eq. 20-23): per-channel l1 weight normalization with the norm
#     parameter g = 2^min(T, t) clamped by the accumulator bound.
#   * `ref_int_matmul`        -- plain matmul oracle for the tiled kernel.
#
# The Pallas kernels in a2q.py / affine.py / intmm.py must match these
# bit-for-bit (fp32) under pytest/hypothesis sweeps.

import jax.numpy as jnp


def round_half_even(x):
    """Half-way rounding |.| used by the baseline QAT quantizer (Eq. 1)."""
    return jnp.round(x)


def round_toward_zero(x):
    """Round-toward-zero |.| used by A2Q (paper footnote 2).

    Functionally different from floor/ceil: trunc(-1.5) = -1, floor(-1.5) = -2.
    Prevents any upward rounding in magnitude that could push the l1 norm of
    the quantized weights past the accumulator constraint.
    """
    return jnp.trunc(x)


def int_bounds(bits, signed):
    """Representation range [n, p] of a `bits`-wide integer (paper Sec. 2.1)."""
    bits = jnp.asarray(bits, jnp.float32)
    signed = jnp.asarray(signed, bool)
    n = jnp.where(signed, -(2.0 ** (bits - 1.0)), 0.0)
    p = jnp.where(signed, 2.0 ** (bits - 1.0) - 1.0, 2.0**bits - 1.0)
    return n, p


def ref_affine_quantize(x, scale, bits, signed):
    """Baseline QAT quantizer: dequantize(quantize(x)) with z = 0.

    q = clip(round_half_even(x / s), n, p) * s        (Eq. 1 + Eq. 2)

    `scale` broadcasts against `x` (per-tensor () or per-channel [C, 1]).
    Returns (dequantized, integer_codes).
    """
    n, p = int_bounds(bits, signed)
    q = jnp.clip(round_half_even(x / scale), n, p)
    return q * scale, q


def ref_rtz_quantize(x, scale, bits, signed):
    """A2Q elementwise core: scale -> round-toward-zero -> clip -> dequantize."""
    n, p = int_bounds(bits, signed)
    q = jnp.clip(round_toward_zero(x / scale), n, p)
    return q * scale, q


def a2q_norm_cap(p_bits, n_bits, x_signed, d):
    """log2 cap T on the norm parameter t (paper Eq. 23).

    T = 1_signed(x) + log2(2^(P-1) - 1) + d - N
    """
    sig = jnp.asarray(x_signed, jnp.float32)
    return (
        sig
        + jnp.log2(2.0 ** (jnp.asarray(p_bits, jnp.float32) - 1.0) - 1.0)
        + d
        - jnp.asarray(n_bits, jnp.float32)
    )


def ref_a2q_quantize(v, d, t, m_bits, n_bits, p_bits, x_signed):
    """Accumulator-aware weight quantizer (paper Eq. 20-23), reference semantics.

    Args:
      v:       [C, K] float32 weight direction parameters (one row per output
               channel; conv weights are reshaped to [C_out, K]).
      d:       [C, 1] per-channel log2 scale  (s = 2^d).
      t:       [C, 1] per-channel log2 norm   (g = 2^min(T, t)).
      m_bits:  weight bit width M (clip range of the integer codes).
      n_bits:  *input activation* bit width N feeding this layer.
      p_bits:  target accumulator bit width P.
      x_signed: 1.0 if the layer input is signed, else 0.0.

    Returns (w_q, w_int, s) with w_q = w_int * s, and by construction
      ||w_int||_1 <= (2^(P-1) - 1) * 2^(1_signed(x) - N)   per channel (Eq. 15),
    which is the guaranteed-overflow-avoidance condition.
    """
    v = jnp.asarray(v, jnp.float32)
    s = 2.0**d
    cap = a2q_norm_cap(p_bits, n_bits, x_signed, d)
    g = 2.0 ** jnp.minimum(cap, t)
    l1 = jnp.sum(jnp.abs(v), axis=-1, keepdims=True)
    # Guard the degenerate all-zero row: g * v / l1 -> 0 like brevitas does.
    w_cont = g * v / jnp.where(l1 == 0.0, 1.0, l1)
    n, p = int_bounds(m_bits, True)  # weights are always signed
    w_int = jnp.clip(round_toward_zero(w_cont / s), n, p)
    return w_int * s, w_int, s


def ref_l1_cap(p_bits, n_bits, x_signed):
    """Upper bound on the *integer* weight l1 norm (paper Eq. 15, s-normalized).

    ||w_int||_1 <= (2^(P-1) - 1) * 2^(1_signed(x) - N)
    """
    sig = jnp.asarray(x_signed, jnp.float32)
    return (2.0 ** (jnp.asarray(p_bits, jnp.float32) - 1.0) - 1.0) * 2.0 ** (
        sig - jnp.asarray(n_bits, jnp.float32)
    )


def ref_int_matmul(x, w):
    """Oracle for the tiled matmul kernel: y[b, c] = sum_k x[b, k] w[c, k]."""
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32).T
