# L1 Pallas kernel: the A2Q accumulator-aware weight quantizer (paper Eq. 20-23).
#
# The quantizer is a per-output-channel reduction (the l1 norm of the direction
# vector v) followed by an elementwise map (scale, round-toward-zero, clip,
# dequantize). We tile the [C, K] weight matrix along the channel axis with a
# BlockSpec so each grid step holds one block of channels fully in VMEM,
# computes the row norms once, and applies the elementwise pipeline -- the
# HBM<->VMEM schedule FINN expresses with PE/SIMD unrolling (see DESIGN.md
# "Hardware-Adaptation").
#
# interpret=True is mandatory on this image: real-TPU lowering emits a Mosaic
# custom-call the CPU PJRT plugin cannot execute. Interpret mode lowers the
# kernel to plain HLO, which is exactly what the Rust runtime loads.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# All scalar operands enter the kernel as [1, 1] f32 arrays: Pallas kernel
# arguments must be refs, and a (1, 1) block is the simplest portable way to
# feed runtime scalars (bit widths are *runtime inputs* so a single AOT
# artifact serves the whole (M, N, P) grid search).
_SCALAR_SPEC = pl.BlockSpec((1, 1), lambda *_: (0, 0))


def _scalar(x):
    return jnp.asarray(x, jnp.float32).reshape(1, 1)


def _a2q_kernel(v_ref, d_ref, t_ref, m_ref, n_ref, p_ref, sig_ref, wq_ref, wi_ref, s_ref):
    """One channel-block of the A2Q quantizer.

    v_ref:   [Cb, K]  direction parameters
    d_ref:   [Cb, 1]  log2 scale        (s = 2^d)
    t_ref:   [Cb, 1]  log2 norm         (g = 2^min(T, t))
    m/n/p_ref, sig_ref: [1,1] runtime scalars M, N, P, 1_signed(x)
    wq_ref:  [Cb, K]  dequantized weights  (w_int * s)
    wi_ref:  [Cb, K]  integer codes
    s_ref:   [Cb, 1]  per-channel scale
    """
    v = v_ref[...]
    d = d_ref[...]
    t = t_ref[...]
    m_bits = m_ref[0, 0]
    n_bits = n_ref[0, 0]
    p_bits = p_ref[0, 0]
    sig = sig_ref[0, 0]

    s = 2.0**d
    # Accumulator-bound cap on the norm parameter (Eq. 23):
    #   T = 1_signed(x) + log2(2^(P-1) - 1) + d - N
    cap = sig + jnp.log2(2.0 ** (p_bits - 1.0) - 1.0) + d - n_bits
    g = 2.0 ** jnp.minimum(cap, t)

    # Per-channel l1 norm: one reduction per row, computed once per block.
    l1 = jnp.sum(jnp.abs(v), axis=-1, keepdims=True)
    w_cont = g * v / jnp.where(l1 == 0.0, 1.0, l1)

    # scale -> round-toward-zero -> clip -> dequantize (Eq. 20).
    lo = -(2.0 ** (m_bits - 1.0))
    hi = 2.0 ** (m_bits - 1.0) - 1.0
    w_int = jnp.clip(jnp.trunc(w_cont / s), lo, hi)

    wq_ref[...] = w_int * s
    wi_ref[...] = w_int
    s_ref[...] = s


def _channel_block(c, k):
    """Channel-block size: keep a [Cb, K] f32 block within ~256 KiB of VMEM."""
    budget = 256 * 1024 // 4  # floats per block
    cb = max(1, min(c, budget // max(k, 1)))
    # Prefer sublane-aligned blocks when we have the headroom (TPU tiling is
    # (8, 128) for f32); interpret mode does not care but the structure should
    # be the one a real TPU would want.
    if cb >= 8:
        cb -= cb % 8
    return cb


@functools.partial(jax.jit, static_argnames=())
def a2q_quantize(v, d, t, m_bits, n_bits, p_bits, x_signed):
    """Pallas A2Q weight quantizer over a [C, K] weight matrix.

    Mirrors ref.ref_a2q_quantize (the pure-jnp oracle) exactly; see that
    docstring for the math. Returns (w_q, w_int, s).
    """
    v = jnp.asarray(v, jnp.float32)
    c, k = v.shape
    cb = _channel_block(c, k)
    grid = (pl.cdiv(c, cb),)

    out = pl.pallas_call(
        _a2q_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cb, k), lambda i: (i, 0)),
            pl.BlockSpec((cb, 1), lambda i: (i, 0)),
            pl.BlockSpec((cb, 1), lambda i: (i, 0)),
            _SCALAR_SPEC,
            _SCALAR_SPEC,
            _SCALAR_SPEC,
            _SCALAR_SPEC,
        ],
        out_specs=[
            pl.BlockSpec((cb, k), lambda i: (i, 0)),
            pl.BlockSpec((cb, k), lambda i: (i, 0)),
            pl.BlockSpec((cb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, k), jnp.float32),
            jax.ShapeDtypeStruct((c, k), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ],
        interpret=True,
    )(
        v,
        jnp.asarray(d, jnp.float32).reshape(c, 1),
        jnp.asarray(t, jnp.float32).reshape(c, 1),
        _scalar(m_bits),
        _scalar(n_bits),
        _scalar(p_bits),
        _scalar(x_signed),
    )
    return tuple(out)
