# Model-zoo contract tests: shapes, trainability (loss decreases over a few
# steps for every (model, alg)), the overflow-impossibility invariant on
# exported integer weights, and manifest consistency (QLayer metadata vs the
# actual parameter tensors -- the Rust coordinator trusts this metadata).

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref
from compile.models import REGISTRY
from compile.models.common import pick

BITS = jnp.array([6.0, 6.0, 16.0])


def fake_batch(spec, key):
    kx, ky = jax.random.split(key)
    x = jax.random.uniform(kx, (spec.batch_size, *spec.input_shape))
    if spec.name == "mlp":
        x = jnp.round(x)  # 1-bit binary inputs
    else:
        x = jnp.round(x * 255.0) / 255.0  # 8-bit image grid
    if spec.task == "classify":
        y = jnp.asarray(
            jax.random.randint(ky, (spec.batch_size,), 0, spec.n_classes), jnp.float32
        )
    else:
        y = jax.random.uniform(ky, (spec.batch_size, *spec.target_shape))
    return x, y


@pytest.mark.parametrize("name", list(REGISTRY))
@pytest.mark.parametrize("alg", ["a2q", "qat", "float"])
def test_apply_shapes(name, alg):
    spec = REGISTRY[name]
    params = spec.init(jax.random.PRNGKey(0))
    x, _ = fake_batch(spec, jax.random.PRNGKey(1))
    out, reg = spec.apply(alg, params, x, tuple(BITS), train=True)
    if spec.task == "classify":
        assert out.shape == (spec.batch_size, spec.n_classes)
    else:
        assert out.shape == (spec.batch_size, *spec.target_shape)
    assert np.isfinite(np.asarray(out)).all()
    assert float(reg) >= 0.0


@pytest.mark.parametrize("name", list(REGISTRY))
@pytest.mark.parametrize("alg", ["a2q", "qat"])
def test_train_step_decreases_loss(name, alg):
    spec = REGISTRY[name]
    fn, n_leaves, template = M.make_train_step(spec, alg)
    fn = jax.jit(fn)
    state = M.flatten(M.init_state(spec, jax.random.PRNGKey(0)))
    x, y = fake_batch(spec, jax.random.PRNGKey(1))
    lr = jnp.asarray(spec.lr, jnp.float32)
    losses = []
    for _ in range(8):
        out = fn(*state, x, y, BITS, lr)
        state, loss = list(out[:-1]), float(out[-1])
        losses.append(loss)
        assert np.isfinite(loss)
    # memorizing a single repeated batch must make progress
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", list(REGISTRY))
def test_export_respects_l1_cap(name):
    """Paper Eq. 15 on every layer of every model, straight from the export
    graph the Rust side consumes."""
    spec = REGISTRY[name]
    # a short burst of training so d/t move off their init
    fn, _, _ = M.make_train_step(spec, "a2q")
    fn = jax.jit(fn)
    state = M.flatten(M.init_state(spec, jax.random.PRNGKey(0)))
    x, y = fake_batch(spec, jax.random.PRNGKey(1))
    for _ in range(3):
        out = fn(*state, x, y, BITS, jnp.asarray(spec.lr, jnp.float32))
        state = list(out[:-1])
    st = M.unflatten_like(M.init_state(spec, jax.random.PRNGKey(0)), state)
    params = st["params"]

    export_fn, _, _ = M.make_export(spec, "a2q")
    outs = jax.jit(export_fn)(*M.flatten(params), BITS)
    bits3 = tuple(float(b) for b in BITS)
    for i, q in enumerate(spec.qlayers):
        w_int = np.asarray(outs[3 * i])
        n = pick(bits3, q.n_bits)
        p = pick(bits3, q.p_bits)
        cap = float(ref.ref_l1_cap(p, n, 1.0 if q.x_signed else 0.0))
        row_l1 = np.abs(w_int).sum(axis=1)
        assert (row_l1 <= cap + 1e-3).all(), (q.name, row_l1.max(), cap)


@pytest.mark.parametrize("name", list(REGISTRY))
def test_qlayer_metadata_matches_params(name):
    """The manifest geometry the Rust FINN estimator trusts must match the
    actual tensors: v is [c_out, k], and k = kh*kw*c_in/groups for convs."""
    spec = REGISTRY[name]
    params = spec.init(jax.random.PRNGKey(0))
    for q in spec.qlayers:
        v = params[q.name]["v"]
        assert v.shape == (q.c_out, q.k), (q.name, v.shape, (q.c_out, q.k))
        if q.kind in ("conv", "dwconv"):
            assert q.k == q.kh * q.kw * (q.c_in // q.groups), q.name
        assert q.out_h >= 1 and q.out_w >= 1


@pytest.mark.parametrize("name", list(REGISTRY))
def test_init_state_layout_is_stable(name):
    spec = REGISTRY[name]
    s1 = M.state_paths(M.init_state(spec, jax.random.PRNGKey(0)))
    s2 = M.state_paths(M.init_state(spec, jax.random.PRNGKey(7)))
    assert s1 == s2
    # params is a prefix-consistent subtree: every param path appears in state
    ppaths = {p for p, _ in M.state_paths(M.init_state(spec, jax.random.PRNGKey(0))["params"])}
    spaths = {p.split("/", 1)[1] for p, _ in s1 if p.startswith("params/")}
    assert ppaths == spaths


def test_largest_k_matches_paper_mlp():
    """Fig. 2 setup: K = 784, N = 1, M = 8 -> data-type bound P = 19."""
    spec = REGISTRY["mlp"]
    assert spec.largest_k() == 784
    k, n_bits, m_bits = 784.0, 1.0, 8.0
    alpha = np.log2(k) + n_bits + m_bits - 1.0 - 0.0
    p_min = np.ceil(alpha + np.log2(1 + 2.0**-alpha) + 1.0)
    assert p_min == 19.0


@pytest.mark.parametrize("name", ["mlp", "cnn"])
def test_a2q_sparsity_grows_as_p_shrinks(name):
    """Paper Sec. 5.2.1: tightening P raises unstructured weight sparsity."""
    spec = REGISTRY[name]
    fn, _, _ = M.make_train_step(spec, "a2q")
    fn = jax.jit(fn)
    export_fn, _, _ = M.make_export(spec, "a2q")
    export_fn = jax.jit(export_fn)
    x, y = fake_batch(spec, jax.random.PRNGKey(1))

    def sparsity_at(p_bits):
        bits = jnp.array([6.0, 6.0, p_bits])
        state = M.flatten(M.init_state(spec, jax.random.PRNGKey(0)))
        for _ in range(10):
            out = fn(*state, x, y, bits, jnp.asarray(spec.lr, jnp.float32))
            state = list(out[:-1])
        st = M.unflatten_like(M.init_state(spec, jax.random.PRNGKey(0)), state)
        outs = export_fn(*M.flatten(st["params"]), bits)
        total = nz = 0
        for i in range(len(spec.qlayers)):
            w = np.asarray(outs[3 * i])
            total += w.size
            nz += (w == 0).sum()
        return nz / total

    assert sparsity_at(10.0) > sparsity_at(24.0)
