# STE/gradient semantics of the L2 layer library: the custom_vjp rules around
# the Pallas kernels must implement the clipped straight-through estimator and
# the LSQ-style scale gradient, and the A2Q reparameterization must be
# trainable (non-zero, finite gradients into v, d and t).

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers


def test_qcore_ste_passthrough_in_range():
    """Inside the clip range, dq/dx = 1 (STE [3]: grad of rounding = 1)."""
    x = jnp.array([[0.4, -0.3, 1.2]])
    s = jnp.ones((1, 1)) * 0.5

    def f(x):
        q, _ = layers.qcore(x, s, jnp.float32(8.0), jnp.float32(1.0), jnp.float32(0.0))
        return jnp.sum(q)

    g = jax.grad(f)(x)
    np.testing.assert_array_equal(np.asarray(g), np.ones_like(g))


def test_qcore_ste_zero_outside_range():
    """Clipped elements receive zero input gradient (clipped STE)."""
    x = jnp.array([[100.0, -100.0, 0.1]])
    s = jnp.ones((1, 1)) * 0.1  # 100/0.1 = 1000 >> 127

    def f(x):
        q, _ = layers.qcore(x, s, jnp.float32(8.0), jnp.float32(1.0), jnp.float32(0.0))
        return jnp.sum(q)

    g = np.asarray(jax.grad(f)(x))
    assert g[0, 0] == 0.0 and g[0, 1] == 0.0 and g[0, 2] == 1.0


def test_qcore_scale_gradient_clipped_elements():
    """For saturated elements dq/ds = clip bound (the LSQ gradient)."""
    x = jnp.array([[100.0]])
    s = jnp.ones((1, 1)) * 0.1

    def f(s):
        q, _ = layers.qcore(x, s, jnp.float32(8.0), jnp.float32(1.0), jnp.float32(0.0))
        return jnp.sum(q)

    g = float(jax.grad(f)(s)[0, 0])
    assert abs(g - 127.0) < 1e-5  # dq/ds = p = 127 for a saturated-positive elem


def test_qmatmul_grads_match_dense_matmul():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 40))
    w = jax.random.normal(jax.random.PRNGKey(1), (5, 40))

    def f(x, w):
        return jnp.sum(jnp.sin(layers.qmatmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(x @ w.T))

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-5, atol=1e-5)


def test_a2q_weight_gradients_finite_and_nonzero():
    key = jax.random.PRNGKey(2)
    v = jax.random.normal(key, (6, 64))
    d = jnp.full((6, 1), -4.0)
    t = jnp.full((6, 1), 1.0)

    def f(v, d, t):
        w_q, reg = layers.a2q_weight(v, d, t, 6.0, 6.0, 16.0, 0.0)
        return jnp.sum(w_q**2) + reg

    gv, gd, gt = jax.grad(f, argnums=(0, 1, 2))(v, d, t)
    for g in (gv, gd, gt):
        assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(gv).max()) > 0.0
    assert float(jnp.abs(gt).max()) > 0.0  # norm parameter is actually learned


def test_a2q_regularizer_activates_above_cap():
    """reg = sum max(t - T, 0): zero when t is far below T, positive above."""
    v = jnp.ones((2, 16))
    d = jnp.zeros((2, 1))
    # T = 0 + log2(2^15 - 1) + 0 - 8 ~= 6.99  for P=16, N=8, unsigned
    _, reg_lo = layers.a2q_weight(v, d, jnp.full((2, 1), -3.0), 8.0, 8.0, 16.0, 0.0)
    _, reg_hi = layers.a2q_weight(v, d, jnp.full((2, 1), 10.0), 8.0, 8.0, 16.0, 0.0)
    assert float(reg_lo) == 0.0
    assert float(reg_hi) > 0.0


def test_quant_act_shapes_4d():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 16))
    d = jnp.full((1, 1), -5.0)
    y = layers.quant_act("qat", x, d, 6.0, 0.0)
    assert y.shape == x.shape
    assert float(y.min()) >= 0.0  # unsigned domain


def test_quant_act_float_passthrough():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 10))
    y = layers.quant_act("float", x, jnp.zeros((1, 1)), 8.0, 0.0)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_export_weight_matches_training_quantizer():
    """The fused export kernel and the training decomposition must agree on
    the integer codes (same Eq. 20 pipeline, two implementations)."""
    key = jax.random.PRNGKey(3)
    v = jax.random.normal(key, (4, 32))
    d = jnp.full((4, 1), -4.0)
    t = jnp.full((4, 1), 0.5)
    args = (v, d, t, 6.0, 4.0, 14.0, 0.0)
    w_q_train, _ = layers.a2q_weight(*args)
    w_int, s = layers.export_weight("a2q", *args)
    np.testing.assert_allclose(
        np.asarray(w_q_train), np.asarray(w_int * s), rtol=0, atol=1e-7
    )


def test_nn_upsample():
    x = jnp.arange(4.0).reshape(1, 2, 2, 1)
    y = layers.nn_upsample(x, 3)
    assert y.shape == (1, 6, 6, 1)
    assert float(y[0, 0, 0, 0]) == 0.0 and float(y[0, 5, 5, 0]) == 3.0
    # every 3x3 cell is constant
    np.testing.assert_array_equal(np.asarray(y[0, :3, :3, 0]), np.zeros((3, 3)))
