# Kernel-vs-oracle correctness: the CORE L1 signal. Hypothesis sweeps shapes
# and bit widths; every Pallas kernel must match its pure-jnp reference in
# ref.py exactly (fp32 bit-for-bit for the quantizers, tight allclose for the
# MXU-tiled matmul).

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.a2q import a2q_quantize
from compile.kernels.affine import affine_quantize
from compile.kernels.intmm import int_matmul

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def rng_array(seed, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------------------
# affine quantizer
# ---------------------------------------------------------------------------


@given(
    r=st.integers(1, 40),
    c=st.integers(1, 70),
    bits=st.integers(2, 8),
    signed=st.booleans(),
    rtz=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_affine_matches_ref(r, c, bits, signed, rtz, seed):
    x = rng_array(seed, (r, c), scale=3.0)
    s = 0.05 + (seed % 7) * 0.01
    q, qi = affine_quantize(x, s, float(bits), signed, rtz)
    if rtz:
        rq, ri = ref.ref_rtz_quantize(x, s, float(bits), signed)
    else:
        rq, ri = ref.ref_affine_quantize(x, s, float(bits), signed)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(rq))
    np.testing.assert_array_equal(np.asarray(qi), np.asarray(ri))


@given(bits=st.integers(2, 8), signed=st.booleans(), seed=st.integers(0, 100))
def test_affine_codes_in_range(bits, signed, seed):
    x = rng_array(seed, (16, 16), scale=10.0)
    _, qi = affine_quantize(x, 0.03, float(bits), signed)
    lo = -(2 ** (bits - 1)) if signed else 0
    hi = 2 ** (bits - 1) - 1 if signed else 2**bits - 1
    assert qi.min() >= lo and qi.max() <= hi


def test_affine_per_channel_scale():
    x = rng_array(3, (8, 32))
    s = jnp.linspace(0.01, 0.2, 8).reshape(8, 1)
    q, _ = affine_quantize(x, s, 8.0, True)
    rq, _ = ref.ref_affine_quantize(x, s, 8.0, True)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(rq))


def test_rtz_is_trunc_not_floor():
    x = jnp.array([[-1.5, -0.7, 0.7, 1.5]])
    _, qi = affine_quantize(x, 1.0, 8.0, True, rtz=True)
    np.testing.assert_array_equal(np.asarray(qi)[0], [-1.0, 0.0, 0.0, 1.0])


def test_zero_preserved():
    """z = 0 mapping: zero is exactly representable (paper Sec. 2.1)."""
    x = jnp.zeros((4, 4))
    q, qi = affine_quantize(x, 0.1, 8.0, True)
    assert float(jnp.abs(q).max()) == 0.0
    assert float(jnp.abs(qi).max()) == 0.0


# ---------------------------------------------------------------------------
# A2Q quantizer
# ---------------------------------------------------------------------------


@given(
    c=st.integers(1, 24),
    k=st.integers(1, 200),
    m=st.integers(3, 8),
    n=st.integers(1, 8),
    p=st.integers(8, 24),
    signed=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_a2q_matches_ref(c, k, m, n, p, signed, seed):
    v = rng_array(seed, (c, k))
    d = jnp.full((c, 1), -4.0) + (seed % 5) * 0.3
    t = jnp.full((c, 1), 2.0)
    sig = 1.0 if signed else 0.0
    out = a2q_quantize(v, d, t, float(m), float(n), float(p), sig)
    refo = ref.ref_a2q_quantize(v, d, t, float(m), float(n), float(p), sig)
    for a, b in zip(out, refo):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(
    c=st.integers(1, 16),
    k=st.integers(1, 300),
    n=st.integers(1, 8),
    p=st.integers(6, 24),
    signed=st.booleans(),
    t_off=st.floats(-2.0, 12.0),
    seed=st.integers(0, 2**16),
)
def test_a2q_l1_constraint_always_holds(c, k, n, p, signed, t_off, seed):
    """THE paper guarantee (Eq. 15): whatever v, d, t are, the integer codes
    satisfy ||w_int||_1 <= (2^(P-1)-1) * 2^(1signed - N) per channel -- which
    is exactly the no-overflow condition for any N-bit input stream."""
    v = rng_array(seed, (c, k), scale=2.0)
    d = jnp.full((c, 1), -5.0)
    t = jnp.full((c, 1), float(t_off))  # even t far above its cap T
    sig = 1.0 if signed else 0.0
    _, w_int, _ = a2q_quantize(v, d, t, 8.0, float(n), float(p), sig)
    cap = float(ref.ref_l1_cap(float(p), float(n), sig))
    row_l1 = np.abs(np.asarray(w_int)).sum(axis=1)
    assert (row_l1 <= cap + 1e-3).all(), (row_l1.max(), cap)


def test_a2q_zero_row_is_safe():
    v = jnp.zeros((3, 50))
    d = jnp.full((3, 1), -4.0)
    t = jnp.full((3, 1), 2.0)
    wq, wi, s = a2q_quantize(v, d, t, 8.0, 4.0, 16.0, 0.0)
    assert np.isfinite(np.asarray(wq)).all()
    assert float(jnp.abs(wi).max()) == 0.0


def test_a2q_norm_decreases_with_p():
    """Tightening P must monotonically shrink the admissible l1 norm."""
    v = rng_array(0, (4, 128))
    d = jnp.full((4, 1), -6.0)
    t = jnp.full((4, 1), 8.0)  # ask for a big norm; the cap must bind
    norms = []
    for p in (20.0, 16.0, 12.0, 10.0, 8.0):
        _, wi, _ = a2q_quantize(v, d, t, 8.0, 8.0, p, 0.0)
        norms.append(float(jnp.abs(wi).sum(-1).max()))
    assert norms == sorted(norms, reverse=True)
    assert norms[-1] < norms[0]


# ---------------------------------------------------------------------------
# tiled matmul
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 200),
    k=st.integers(1, 300),
    c=st.integers(1, 150),
    seed=st.integers(0, 2**16),
)
def test_intmm_matches_ref(b, k, c, seed):
    x = rng_array(seed, (b, k))
    w = rng_array(seed + 1, (c, k))
    got = int_matmul(x, w)
    want = ref.ref_int_matmul(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@given(b=st.integers(1, 64), k=st.integers(1, 256), c=st.integers(1, 64), seed=st.integers(0, 99))
def test_intmm_exact_on_integers(b, k, c, seed):
    """Integer operands small enough that all partial sums fit in 24 bits must
    be reproduced exactly (the fp32-accumulation argument from intmm.py)."""
    kx = jax.random.PRNGKey(seed)
    x = jnp.asarray(jax.random.randint(kx, (b, k), -15, 16), jnp.float32)
    w = jnp.asarray(jax.random.randint(jax.random.PRNGKey(seed + 1), (c, k), -7, 8), jnp.float32)
    got = int_matmul(x, w)
    want = ref.ref_int_matmul(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_intmm_tile_edges():
    """Shapes straddling the 128-tile boundary (127/128/129)."""
    for b, k, c in [(127, 129, 128), (128, 128, 128), (129, 257, 1), (1, 1, 1)]:
        x = rng_array(b, (b, k))
        w = rng_array(c, (c, k))
        np.testing.assert_allclose(
            np.asarray(int_matmul(x, w)),
            np.asarray(ref.ref_int_matmul(x, w)),
            rtol=1e-5,
            atol=1e-4,
        )
