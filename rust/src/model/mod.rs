//! Multi-layer quantized network abstraction: the model-level substrate the
//! network-scale experiments run on.
//!
//! Every headline result of the paper is *network*-level — accuracy,
//! sparsity and FPGA resources are reported for whole MLPs/CNNs/ResNets —
//! and accumulator constraints compound across layers through the
//! inter-layer requantization step (De Bruin et al., "Quantization of DNNs
//! for Accumulator-constrained Processors"). This module supplies the
//! missing abstraction:
//!
//! * [`ActQuant`] — one activation-boundary quantizer (N bits, signedness,
//!   scale): the `x_signed` / `n_bits` contract every layer's input obeys.
//! * [`QLayer`] — a quantized dense layer: integer weights
//!   ([`crate::quant::QTensor`]) plus the quantizer its inputs arrive on.
//! * [`QNetwork`] — a stack of chained [`QLayer`]s, built either from
//!   exported runtime artifacts ([`QNetwork::new`] over `to_qtensor()`
//!   triples) or synthesized directly via
//!   [`crate::quant::a2q::a2q_quantize_row`] ([`QNetwork::synthesize`]) and
//!   calibrated over the synthetic datasets ([`QNetwork::calibrate`]).
//! * [`network_forward_ref`] — the *reference semantics* of a network
//!   forward pass: the scalar per-layer walk
//!   ([`crate::accsim::qlinear_forward_ref`]) composed layer by layer with
//!   explicit requantization. The fused engine
//!   ([`crate::accsim::NetworkPlan`]) is property-tested bit-identical to
//!   this composition.
//!
//! The requantization contract between layers `l` and `l+1`:
//! dequantize layer `l`'s accumulator (`acc * s_w[c] * s_x + bias[c]`),
//! rescale onto layer `l+1`'s activation grid (`/ scale`), round to nearest,
//! then clamp into the N-bit signed/unsigned integer range — so the next
//! layer's `x_signed` / `n_bits` contract is enforced at the boundary no
//! matter what the register model upstream produced.

pub mod netfile;
pub mod qnetwork;

pub use netfile::{fnv1a64, load_network, parse_synth_spec, save_network};
pub use qnetwork::{network_forward_ref, ActQuant, NetSpec, QLayer, QNetwork, SynthQuant};
