//! `QNetwork` on disk: a self-contained JSON format for deploying trained
//! or synthesized networks to the serving layer.
//!
//! The export-artifact path ([`QNetwork::from_exported`]) needs a manifest
//! and a live training backend; a *served* model needs neither — just the
//! integer codes, scales and activation grids. This module is that
//! deployment boundary: [`save_network`] writes everything a
//! [`crate::accsim::NetworkPlan`] consumes, [`load_network`] reads it back
//! with trust-boundary validation (NaN/inf, non-integral codes, shape and
//! chain mismatches, out-of-range bit widths all become descriptive typed
//! errors — a malformed model file must never panic a long-running
//! server). [`fnv1a64`] supplies the stable content hash the serve plan
//! cache keys on, and [`parse_synth_spec`] the compact
//! `name:784x64x10:m4n4p16` notation `a2q serve --models` uses to stand up
//! synthetic networks without any file at all.

use std::path::Path;

use anyhow::Result;

use crate::json::Json;
use crate::model::{ActQuant, NetSpec, QLayer, QNetwork, SynthQuant};
use crate::quant::QTensor;

/// FNV-1a 64-bit: the plan-cache content hash. Stable across platforms and
/// processes (unlike `DefaultHasher`), cheap, and good enough for a cache
/// keyed by a handful of models.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn layer_to_json(l: &QLayer) -> Json {
    Json::obj(vec![
        ("name", Json::str(&l.name)),
        ("c_out", Json::num(l.weights.c_out as f64)),
        ("k", Json::num(l.weights.k as f64)),
        ("codes", Json::arr(l.weights.codes.iter().map(|c| Json::num(*c as f64)))),
        ("scales", Json::from_f32s(&l.weights.scales)),
        ("bias", Json::from_f32s(&l.weights.bias)),
        ("in_bits", Json::num(l.in_quant.n_bits as f64)),
        ("in_signed", Json::Bool(l.in_quant.signed)),
        ("in_scale", Json::num(l.in_quant.scale as f64)),
        ("m_bits", Json::num(l.m_bits as f64)),
        ("p_bits", Json::num(l.p_bits as f64)),
    ])
}

/// Serialize a network (including calibrated activation scales) to JSON
/// text. Integer codes round-trip exactly: they are far below 2^53.
pub fn network_to_json(net: &QNetwork) -> Json {
    Json::obj(vec![
        ("name", Json::str(&net.name)),
        ("layers", Json::arr(net.layers.iter().map(layer_to_json))),
    ])
}

fn layer_from_json(li: usize, v: &Json) -> Result<QLayer> {
    let name = v.get("name")?.as_str()?.to_string();
    let c_out = v.get("c_out")?.as_usize()?;
    let k = v.get("k")?.as_usize()?;
    anyhow::ensure!(c_out > 0 && k > 0, "layer {li} ({name}): degenerate shape [{c_out}, {k}]");
    let raw = v.get("codes")?.as_arr()?;
    anyhow::ensure!(
        raw.len() == c_out * k,
        "layer {li} ({name}): {} codes for shape [{c_out}, {k}]",
        raw.len()
    );
    let mut codes = Vec::with_capacity(raw.len());
    for (i, c) in raw.iter().enumerate() {
        let n = c.as_f64()?;
        anyhow::ensure!(
            n.is_finite() && n.fract() == 0.0 && n.abs() < 9e15,
            "layer {li} ({name}): code at [{}, {}] is not a finite integer: {n}",
            i / k,
            i % k
        );
        codes.push(n as i64);
    }
    let read_f32s = |key: &str| -> Result<Vec<f32>> {
        let arr = v.get(key)?.as_arr()?;
        anyhow::ensure!(
            arr.len() == c_out,
            "layer {li} ({name}): {} {key} for {c_out} channels",
            arr.len()
        );
        arr.iter().map(|x| Ok(x.as_f64()? as f32)).collect()
    };
    let scales = read_f32s("scales")?;
    for (c, s) in scales.iter().enumerate() {
        anyhow::ensure!(
            s.is_finite() && *s > 0.0,
            "layer {li} ({name}): scale for channel {c} must be finite and positive, got {s}"
        );
    }
    let bias = read_f32s("bias")?;
    for (c, b) in bias.iter().enumerate() {
        anyhow::ensure!(b.is_finite(), "layer {li} ({name}): bias for channel {c} is not finite");
    }
    let in_bits = v.get("in_bits")?.as_u32()?;
    anyhow::ensure!(
        (1..=32).contains(&in_bits),
        "layer {li} ({name}): activation bits {in_bits} outside 1..=32"
    );
    let m_bits = v.get("m_bits")?.as_u32()?;
    anyhow::ensure!(
        (1..=32).contains(&m_bits),
        "layer {li} ({name}): weight bits {m_bits} outside 1..=32"
    );
    let p_bits = v.get("p_bits")?.as_u32()?;
    anyhow::ensure!(
        (1..=63).contains(&p_bits),
        "layer {li} ({name}): accumulator bits {p_bits} outside 1..=63 (simulated in i64)"
    );
    let in_scale = v.get("in_scale")?.as_f64()? as f32;
    anyhow::ensure!(
        in_scale.is_finite() && in_scale > 0.0,
        "layer {li} ({name}): activation scale must be finite and positive, got {in_scale}"
    );
    Ok(QLayer {
        name,
        weights: QTensor { codes, scales, bias, c_out, k },
        in_quant: ActQuant::new(in_bits, v.get("in_signed")?.as_bool()?, in_scale),
        m_bits,
        p_bits,
    })
}

/// Deserialize a network from JSON, validating every field a panic could
/// hide behind. Chain mismatches are caught by [`QNetwork::new`].
pub fn network_from_json(v: &Json) -> Result<QNetwork> {
    let name = v.get("name")?.as_str()?.to_string();
    let layers = v
        .get("layers")?
        .as_arr()?
        .iter()
        .enumerate()
        .map(|(li, l)| layer_from_json(li, l))
        .collect::<Result<Vec<_>>>()?;
    QNetwork::new(name, layers)
}

/// Write a network model file (crash-safe: temp file + atomic rename, the
/// same discipline as checkpoint saves).
pub fn save_network(net: &QNetwork, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(format!(".tmp.{}", std::process::id()));
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, network_to_json(net).to_string())?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    Ok(())
}

/// Load and validate a network model file.
pub fn load_network(path: &Path) -> Result<QNetwork> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading model file {}: {e}", path.display()))?;
    let v = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing model file {}: {e}", path.display()))?;
    network_from_json(&v).map_err(|e| e.context(format!("model file {}", path.display())))
}

/// Parse the compact synthetic-model notation the serve CLI accepts:
/// `name:W0xW1x...xWn:mMnNpP`, e.g. `mlp:784x64x10:m4n4p16` — an
/// A2Q-constrained network with those layer widths at weight bits M,
/// activation bits N and accumulator target P (unsigned input grid, the
/// image-style default). Returns the model name and the [`NetSpec`] to
/// synthesize.
pub fn parse_synth_spec(spec: &str) -> Result<(String, NetSpec)> {
    let mut parts = spec.split(':');
    let (name, widths, bits) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(a), Some(b), Some(c), None) => (a.trim(), b.trim(), c.trim()),
        _ => anyhow::bail!("synth spec {spec:?} is not name:W0xW1x..:mMnNpP"),
    };
    anyhow::ensure!(!name.is_empty(), "synth spec {spec:?} has an empty model name");
    let widths = widths
        .split('x')
        .map(|w| {
            w.trim()
                .parse::<usize>()
                .map_err(|e| anyhow::anyhow!("synth spec {spec:?} width {w:?}: {e}"))
        })
        .collect::<Result<Vec<_>>>()?;
    anyhow::ensure!(widths.len() >= 2, "synth spec {spec:?} needs >= 2 widths");
    let rest = bits
        .strip_prefix('m')
        .ok_or_else(|| anyhow::anyhow!("synth spec {spec:?} bits {bits:?} must start with m"))?;
    let (m, rest) = rest
        .split_once('n')
        .ok_or_else(|| anyhow::anyhow!("synth spec {spec:?} bits {bits:?} missing n"))?;
    let (n, p) = rest
        .split_once('p')
        .ok_or_else(|| anyhow::anyhow!("synth spec {spec:?} bits {bits:?} missing p"))?;
    let parse_bits = |tag: &str, s: &str| -> Result<u32> {
        s.parse::<u32>().map_err(|e| anyhow::anyhow!("synth spec {spec:?} {tag}={s:?}: {e}"))
    };
    Ok((
        name.to_string(),
        NetSpec {
            widths,
            m_bits: parse_bits("m", m)?,
            n_bits: parse_bits("n", n)?,
            p_bits: parse_bits("p", p)?,
            x_signed: false,
            quant: SynthQuant::A2q,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    fn sample_net() -> QNetwork {
        let spec = NetSpec {
            widths: vec![10, 6, 3],
            m_bits: 4,
            n_bits: 3,
            p_bits: 12,
            x_signed: false,
            quant: SynthQuant::A2q,
        };
        let mut net = QNetwork::synthesize(&spec, 7).unwrap();
        let sample = crate::tensor::Tensor::new(
            vec![4, 10],
            (0..40).map(|i| (i % 5) as f32 * 0.21).collect(),
        );
        net.calibrate(&sample);
        net
    }

    #[test]
    fn save_load_round_trip_is_bit_exact() {
        let net = sample_net();
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("net.json");
        save_network(&net, &path).unwrap();
        let back = load_network(&path).unwrap();
        assert_eq!(back.name, net.name);
        assert_eq!(back.depth(), net.depth());
        for (a, b) in back.layers.iter().zip(&net.layers) {
            assert_eq!(a.weights.codes, b.weights.codes);
            assert_eq!(a.weights.scales, b.weights.scales);
            assert_eq!(a.weights.bias, b.weights.bias);
            assert_eq!(a.in_quant, b.in_quant);
            assert_eq!((a.m_bits, a.p_bits), (b.m_bits, b.p_bits));
        }
    }

    #[test]
    fn malformed_model_files_load_as_typed_errors() {
        let net = sample_net();
        let good = network_to_json(&net).to_string();
        let corrupt = |from: &str, to: &str, needle: &str| {
            let text = good.replacen(from, to, 1);
            assert_ne!(text, good, "corruption {from:?} -> {to:?} did not apply");
            let err = network_from_json(&Json::parse(&text).unwrap()).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "error {msg:?} should mention {needle:?}");
        };
        // Out-of-range widths.
        corrupt("\"in_bits\":3", "\"in_bits\":40", "outside 1..=32");
        corrupt("\"p_bits\":12", "\"p_bits\":64", "outside 1..=63");
        // Shape drift: fewer codes than c_out * k claims.
        corrupt("\"c_out\":6", "\"c_out\":7", "codes for shape");
        // Truncated files fail at parse, not later.
        assert!(load_network(Path::new("/nonexistent/net.json")).is_err());
        let dir = TempDir::new().unwrap();
        let torn = dir.path().join("torn.json");
        std::fs::write(&torn, &good[..good.len() / 2]).unwrap();
        let err = load_network(&torn).unwrap_err();
        assert!(format!("{err:#}").contains("parsing model file"), "{err:#}");
    }

    #[test]
    fn non_finite_and_non_integral_fields_are_rejected() {
        let net = sample_net();
        let v = network_to_json(&net);
        // Splice a bad code in via the parsed tree (the writer refuses to
        // emit NaN, so corrupt structurally).
        let with_code = |code: Json| {
            let mut root = v.clone();
            if let Json::Obj(m) = &mut root {
                if let Some(Json::Arr(layers)) = m.get_mut("layers") {
                    if let Json::Obj(l0) = &mut layers[0] {
                        if let Some(Json::Arr(codes)) = l0.get_mut("codes") {
                            codes[0] = code;
                        }
                    }
                }
            }
            root
        };
        let err = network_from_json(&with_code(Json::num(0.5))).unwrap_err();
        assert!(format!("{err:#}").contains("finite integer"), "{err:#}");
        let err = network_from_json(&with_code(Json::str("NaN"))).unwrap_err();
        assert!(format!("{err:#}").contains("expected number"), "{err:#}");
    }

    #[test]
    fn synth_spec_parses_and_rejects() {
        let (name, spec) = parse_synth_spec("mlp:784x64x10:m4n4p16").unwrap();
        assert_eq!(name, "mlp");
        assert_eq!(spec.widths, vec![784, 64, 10]);
        assert_eq!((spec.m_bits, spec.n_bits, spec.p_bits), (4, 4, 16));
        assert_eq!(spec.quant, SynthQuant::A2q);
        for bad in
            ["mlp", "mlp:16x4", "mlp:16x4:m4n4", ":16x4:m4n4p12", "mlp:16:m4n4p12", "m:ax4:m4n4p12"]
        {
            assert!(parse_synth_spec(bad).is_err(), "{bad:?} should be rejected");
        }
        // The synthesized network is actually loadable at those bits.
        let net = QNetwork::synthesize(&parse_synth_spec("t:12x5:m4n3p12").unwrap().1, 1).unwrap();
        assert_eq!(net.input_dim(), 12);
        assert_eq!(net.output_dim(), 5);
    }

    #[test]
    fn fnv_hash_is_stable_and_distinguishes() {
        // Pinned reference values (FNV-1a 64 test vectors).
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a64(b"mlp:16x4:m4n4p12"), fnv1a64(b"mlp:16x4:m4n4p14"));
    }
}
