//! `QNetwork`: stacked A2Q-quantized dense layers with explicit inter-layer
//! requantization, plus the scalar reference forward the fused
//! [`crate::accsim::NetworkPlan`] is property-tested against.

use anyhow::Result;

use crate::accsim::{
    qlinear_forward, qlinear_forward_ref, quantize_inputs, AccMode, IntMatrix, NetworkStats,
};
use crate::finn::estimate::{BitSpec, LayerGeom};
use crate::quant::quantizer::{A2qPlusQuantizer, A2qQuantizer, WeightQuantizer};
use crate::quant::QTensor;
use crate::rng::Rng;
use crate::runtime::{ExportedLayer, ModelManifest};
use crate::tensor::Tensor;

/// One activation-boundary quantizer: the integer grid a layer's inputs
/// arrive on. `quantize` is the requantization step of the inter-layer
/// contract: rescale -> round -> clamp to the N-bit (un)signed range.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ActQuant {
    /// Activation bit width N.
    pub n_bits: u32,
    /// Whether the grid is signed (hidden boundaries) or unsigned (e.g.
    /// binary/image inputs).
    pub signed: bool,
    /// Grid step: float value = code * scale.
    pub scale: f32,
}

impl ActQuant {
    pub fn new(n_bits: u32, signed: bool, scale: f32) -> ActQuant {
        assert!((1..=32).contains(&n_bits), "activation bits {n_bits} outside 1..=32");
        assert!(scale > 0.0, "activation scale must be positive, got {scale}");
        ActQuant { n_bits, signed, scale }
    }

    /// Representable code range: `[-2^(N-1), 2^(N-1)-1]` signed,
    /// `[0, 2^N - 1]` unsigned.
    pub fn int_range(&self) -> (i64, i64) {
        if self.signed {
            (-(1i64 << (self.n_bits - 1)), (1i64 << (self.n_bits - 1)) - 1)
        } else {
            (0, (1i64 << self.n_bits) - 1)
        }
    }

    /// Quantize a float batch `[batch, k]` onto this grid (the standard
    /// activation quantizer, zero-point 0): rescale, round to nearest, clamp
    /// into the integer range — shared by the fused network engine and the
    /// scalar reference so requantization is bit-identical in both.
    pub fn quantize(&self, x: &Tensor) -> IntMatrix {
        quantize_inputs(x, self.scale, self.n_bits, self.signed)
    }

    /// The allocation-free core of [`Self::quantize`]: requantize a flat
    /// dequantized-activation buffer into the caller's code buffer
    /// (cleared, then filled). This is the inter-layer path of the fused
    /// network engine — same [`crate::accsim::quantize_code`] step per
    /// element as [`Self::quantize`], so the two are bit-identical, minus
    /// the `Tensor`/[`IntMatrix`] round trip.
    pub fn quantize_slice_into(&self, data: &[f32], out: &mut Vec<i64>) {
        let (lo, hi) = self.int_range();
        out.clear();
        out.reserve(data.len());
        out.extend(data.iter().map(|v| crate::accsim::quantize_code(*v, self.scale, lo, hi)));
    }
}

/// A quantized dense layer: integer weights plus the quantizer its inputs
/// obey, and the bit-width metadata the bounds/FINN substrates consume.
#[derive(Clone, Debug)]
pub struct QLayer {
    pub name: String,
    /// Integer weight codes with per-channel scales and float biases.
    pub weights: QTensor,
    /// The grid this layer's *inputs* arrive on.
    pub in_quant: ActQuant,
    /// Weight bit width M the codes were quantized to.
    pub m_bits: u32,
    /// Target accumulator width P the layer was trained/synthesized for.
    pub p_bits: u32,
}

/// Which weight quantizer [`QNetwork::synthesize`] pushes channels through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SynthQuant {
    /// Paper A2Q ([`A2qQuantizer`]): every channel satisfies the Eq. 15 cap,
    /// so P-bit accumulation is overflow-free by construction.
    A2q,
    /// A2Q+ ([`A2qPlusQuantizer`]): zero-centered channels, same guarantee,
    /// never more integer norm than plain A2Q on the same draws.
    A2qPlus,
    /// Plain per-channel affine quantization with no accumulator cap — the
    /// baseline-QAT regime where narrow registers actually overflow.
    Affine,
}

impl SynthQuant {
    /// The accumulator-aware quantizer behind this mode (None for Affine).
    pub fn quantizer(self) -> Option<&'static dyn WeightQuantizer> {
        match self {
            SynthQuant::A2q => Some(&A2qQuantizer),
            SynthQuant::A2qPlus => Some(&A2qPlusQuantizer),
            SynthQuant::Affine => None,
        }
    }

    /// Whether synthesized channels carry the Eq. 15 guarantee.
    pub fn constrained(self) -> bool {
        self != SynthQuant::Affine
    }
}

/// Shape and bit-width specification for [`QNetwork::synthesize`].
#[derive(Clone, Debug)]
pub struct NetSpec {
    /// Layer widths including the input: `[k_in, h_1, ..., c_out]`.
    pub widths: Vec<usize>,
    /// Weight bits M.
    pub m_bits: u32,
    /// Activation bits N (all boundaries).
    pub n_bits: u32,
    /// Target accumulator width P.
    pub p_bits: u32,
    /// Whether the *network input* grid is signed (hidden boundaries are
    /// always signed: pre-activations carry both signs).
    pub x_signed: bool,
    /// The weight quantizer: accumulator-constrained (A2Q / A2Q+) or the
    /// unconstrained affine baseline.
    pub quant: SynthQuant,
}

/// A stack of chained quantized layers: layer `i+1`'s input dimension is
/// layer `i`'s output channel count, and its [`ActQuant`] defines the
/// requantization applied between them.
#[derive(Clone, Debug)]
pub struct QNetwork {
    pub name: String,
    pub layers: Vec<QLayer>,
}

impl QNetwork {
    /// Assemble from explicit layers (e.g. export-artifact `to_qtensor()`
    /// triples), validating the chain.
    pub fn new(name: impl Into<String>, layers: Vec<QLayer>) -> Result<QNetwork> {
        anyhow::ensure!(!layers.is_empty(), "QNetwork needs at least one layer");
        for i in 1..layers.len() {
            anyhow::ensure!(
                layers[i].weights.k == layers[i - 1].weights.c_out,
                "layer {} ({}) input dim {} does not chain to previous c_out {}",
                i,
                layers[i].name,
                layers[i].weights.k,
                layers[i - 1].weights.c_out
            );
        }
        Ok(QNetwork { name: name.into(), layers })
    }

    /// Assemble a network straight from a training backend's export — the
    /// train -> export -> accsim/FINN bridge. Layer metadata (input bit
    /// widths, signedness, target P) comes from the manifest's qlayers
    /// resolved at the run's `(M, N, P)`; activation scales start at 1.0,
    /// so run [`Self::calibrate`] over a sample batch before simulating.
    ///
    /// Fails for non-dense layer kinds (conv exports don't map onto the
    /// dense accsim substrate), and validates the export like a trust
    /// boundary: NaN/inf or non-integral weights, shape/geometry
    /// mismatches, and out-of-range resolved bit widths become descriptive
    /// typed errors instead of downstream panics.
    pub fn from_exported(
        name: impl Into<String>,
        exported: &[ExportedLayer],
        manifest: &ModelManifest,
        bits: (u32, u32, u32),
    ) -> Result<QNetwork> {
        anyhow::ensure!(
            exported.len() == manifest.qlayers.len(),
            "{} exported layers vs {} manifest qlayers",
            exported.len(),
            manifest.qlayers.len()
        );
        let (m, n, p) = bits;
        let mut layers = Vec::with_capacity(exported.len());
        for (layer, meta) in exported.iter().zip(&manifest.qlayers) {
            anyhow::ensure!(
                meta.kind == "dense",
                "layer {} is {:?}; only dense exports chain into a QNetwork",
                meta.name,
                meta.kind
            );
            let n_res = meta.n_bits.to_bitspec()?.resolve(m, n, p);
            let p_res = meta.p_bits.to_bitspec()?.resolve(m, n, p);
            let m_res = meta.m_bits.to_bitspec()?.resolve(m, n, p);
            anyhow::ensure!(
                (1..=32).contains(&n_res),
                "layer {}: activation bits {n_res} outside 1..=32",
                meta.name
            );
            anyhow::ensure!(
                (1..=32).contains(&m_res),
                "layer {}: weight bits {m_res} outside 1..=32",
                meta.name
            );
            anyhow::ensure!(
                (1..=63).contains(&p_res),
                "layer {}: accumulator bits {p_res} outside 1..=63 (simulated in i64)",
                meta.name
            );
            let weights = layer.try_to_qtensor()?;
            anyhow::ensure!(
                weights.c_out == meta.c_out && weights.k == meta.k,
                "layer {}: exported weights [{}, {}] do not match manifest geometry [{}, {}]",
                meta.name,
                weights.c_out,
                weights.k,
                meta.c_out,
                meta.k
            );
            layers.push(QLayer {
                name: meta.name.clone(),
                weights,
                in_quant: ActQuant::new(n_res, meta.x_signed, 1.0),
                m_bits: m_res,
                p_bits: p_res,
            });
        }
        QNetwork::new(name, layers)
    }

    /// Synthesize a network directly from the A2Q weight quantizer: each
    /// channel is a Gaussian direction vector pushed through
    /// [`a2q_quantize_row`] (constrained) or a plain affine quantizer
    /// (unconstrained). Activation scales start at 1.0 — run
    /// [`Self::calibrate`] over a sample batch before simulating.
    pub fn synthesize(spec: &NetSpec, seed: u64) -> Result<QNetwork> {
        anyhow::ensure!(spec.widths.len() >= 2, "NetSpec needs >= 2 widths (input + 1 layer)");
        anyhow::ensure!(spec.widths.iter().all(|w| *w > 0), "zero width in {:?}", spec.widths);
        anyhow::ensure!((2..=8).contains(&spec.m_bits), "M={} outside 2..=8", spec.m_bits);
        anyhow::ensure!((1..=8).contains(&spec.n_bits), "N={} outside 1..=8", spec.n_bits);
        anyhow::ensure!((2..=48).contains(&spec.p_bits), "P={} outside 2..=48", spec.p_bits);
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(spec.widths.len() - 1);
        for li in 0..spec.widths.len() - 1 {
            let (k, c_out) = (spec.widths[li], spec.widths[li + 1]);
            let in_signed = if li == 0 { spec.x_signed } else { true };
            let in_quant = ActQuant::new(spec.n_bits, in_signed, 1.0);
            let mut codes = Vec::with_capacity(c_out * k);
            let mut scales = Vec::with_capacity(c_out);
            for _ in 0..c_out {
                let v: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
                if let Some(q) = spec.quant.quantizer() {
                    // Cap target far above the Eq. 23 ceiling so the
                    // accumulator constraint (not t) binds.
                    let (w_int, s) = q.quantize_row(
                        &v,
                        -6.0,
                        30.0,
                        spec.m_bits,
                        spec.n_bits,
                        spec.p_bits,
                        in_signed,
                    );
                    codes.extend(w_int.iter().map(|w| *w as i64));
                    scales.push(s);
                } else {
                    let hi = (2f32.powi(spec.m_bits as i32 - 1) - 1.0).max(1.0);
                    let vmax = v.iter().fold(0f32, |a, x| a.max(x.abs())).max(1e-6);
                    let s = vmax / hi;
                    codes.extend(v.iter().map(|x| (x / s).round().clamp(-hi - 1.0, hi) as i64));
                    scales.push(s);
                }
            }
            layers.push(QLayer {
                name: format!("dense{li}"),
                weights: QTensor { codes, scales, bias: vec![0.0; c_out], c_out, k },
                in_quant,
                m_bits: spec.m_bits,
                p_bits: spec.p_bits,
            });
        }
        QNetwork::new("qnet", layers)
    }

    /// Set every boundary's activation scale from a wide-register forward
    /// over a float sample batch `[batch, input_dim]`, so requantized
    /// activations span their N-bit grids instead of clamping degenerately.
    /// Deterministic: same sample, same scales.
    pub fn calibrate(&mut self, sample: &Tensor) {
        assert_eq!(sample.cols(), self.input_dim(), "calibration batch width");
        let absmax = |d: &[f32]| d.iter().fold(0f32, |a, v| a.max(v.abs()));
        let grid_hi = |q: &ActQuant| q.int_range().1.max(1) as f32;
        let m0 = absmax(sample.data());
        self.layers[0].in_quant.scale =
            if m0 > 0.0 { m0 / grid_hi(&self.layers[0].in_quant) } else { 1.0 };
        let mut x = self.layers[0].in_quant.quantize(sample);
        for li in 0..self.layers.len() - 1 {
            let out = {
                let layer = &self.layers[li];
                qlinear_forward(&x, layer.in_quant.scale, &layer.weights, AccMode::Wide).out
            };
            let m = absmax(out.data());
            self.layers[li + 1].in_quant.scale =
                if m > 0.0 { m / grid_hi(&self.layers[li + 1].in_quant) } else { 1.0 };
            x = self.layers[li + 1].in_quant.quantize(&out);
        }
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Input dimension of the first layer.
    pub fn input_dim(&self) -> usize {
        self.layers[0].weights.k
    }

    /// Output channel count of the last layer.
    pub fn output_dim(&self) -> usize {
        self.layers[self.layers.len() - 1].weights.c_out
    }

    /// Total MACs one batch row costs across all layers (sizing heuristic
    /// for the engine's worker count).
    pub fn macs_per_row(&self) -> usize {
        self.layers.iter().map(|l| l.weights.c_out.saturating_mul(l.weights.k)).sum()
    }

    /// Per-layer max per-channel integer-weight l1 norms (the weight-norm
    /// bound inputs, Eq. 13).
    pub fn layer_l1_norms(&self) -> Vec<f64> {
        self.layers.iter().map(|l| l.weights.max_l1() as f64).collect()
    }

    /// Per-layer unstructured weight sparsity (paper §5.2.1).
    pub fn layer_sparsity(&self) -> Vec<f64> {
        self.layers.iter().map(|l| l.weights.sparsity()).collect()
    }

    /// FINN-estimator geometry: one dense MVAU per layer with the layer's
    /// actual M/N widths fixed and the accumulator exposed as the runtime P
    /// variable, so [`crate::finn::estimate_network`] consumes a simulated
    /// network exactly like a manifest-backed one.
    pub fn geoms(&self) -> Vec<LayerGeom> {
        self.layers
            .iter()
            .map(|l| LayerGeom {
                name: l.name.clone(),
                kind: "dense".into(),
                c_out: l.weights.c_out,
                k: l.weights.k,
                m_spec: BitSpec::Fixed(l.m_bits),
                n_spec: BitSpec::Fixed(l.in_quant.n_bits),
                p_spec: BitSpec::P,
                x_signed: l.in_quant.signed,
                out_h: 1,
                out_w: 1,
                kh: 1,
                c_in: l.weights.k,
                stride: 1,
            })
            .collect()
    }

    /// `(M, N, P)` grid point for [`crate::finn::estimate_qnetwork`]: the
    /// geometry fixes M/N per layer, so only P (the largest layer target)
    /// is ever consulted.
    pub fn grid_bits(&self) -> (u32, u32, u32) {
        let p = self.layers.iter().map(|l| l.p_bits).max().unwrap_or(32);
        (self.layers[0].m_bits, self.layers[0].in_quant.n_bits, p)
    }
}

/// Reference semantics of a network forward under one register model: the
/// scalar per-layer walk composed layer by layer, requantizing through each
/// boundary's [`ActQuant`]. One full MAC traversal per layer per call — the
/// ground truth [`crate::accsim::NetworkPlan`] is property-tested against,
/// and the baseline the `network_forward` bench measures speedups from.
pub fn network_forward_ref(net: &QNetwork, x: &IntMatrix, mode: AccMode) -> NetworkStats {
    let depth = net.depth();
    let mut layer_stats = Vec::with_capacity(depth);
    let mut cur = x.clone();
    let mut last = None;
    for (li, layer) in net.layers.iter().enumerate() {
        let r = qlinear_forward_ref(&cur, layer.in_quant.scale, &layer.weights, mode);
        layer_stats.push(r.stats.clone());
        if li + 1 < depth {
            cur = net.layers[li + 1].in_quant.quantize(&r.out);
        }
        last = Some(r);
    }
    let last = last.expect("QNetwork::new guarantees >= 1 layer");
    NetworkStats { out: last.out, out_wide: last.out_wide, layer_stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::a2q::row_satisfies_cap;

    fn spec(widths: Vec<usize>, quant: SynthQuant) -> NetSpec {
        NetSpec { widths, m_bits: 4, n_bits: 3, p_bits: 12, x_signed: false, quant }
    }

    #[test]
    fn synthesize_chains_and_caps() {
        let net = QNetwork::synthesize(&spec(vec![12, 8, 5], SynthQuant::A2q), 3).unwrap();
        assert_eq!(net.depth(), 2);
        assert_eq!(net.input_dim(), 12);
        assert_eq!(net.output_dim(), 5);
        assert_eq!(net.macs_per_row(), 12 * 8 + 8 * 5);
        // every synthesized channel satisfies the Eq. 15 cap
        for layer in &net.layers {
            for c in 0..layer.weights.c_out {
                let row: Vec<f32> = layer.weights.row(c).iter().map(|w| *w as f32).collect();
                let ok = row_satisfies_cap(&row, 12, 3, layer.in_quant.signed);
                assert!(ok, "{}/{c} violates the cap", layer.name);
            }
        }
        // hidden boundary is signed, input unsigned
        assert!(!net.layers[0].in_quant.signed);
        assert!(net.layers[1].in_quant.signed);
    }

    #[test]
    fn unconstrained_uses_full_code_range() {
        let net = QNetwork::synthesize(&spec(vec![64, 16], SynthQuant::Affine), 1).unwrap();
        // affine quantization to 4 bits hits the +/-7 rails
        assert_eq!(net.layers[0].weights.max_abs_code(), 7);
    }

    #[test]
    fn chain_mismatch_rejected() {
        let a = QNetwork::synthesize(&spec(vec![6, 4], SynthQuant::A2q), 0).unwrap();
        let b = QNetwork::synthesize(&spec(vec![5, 3], SynthQuant::A2q), 0).unwrap();
        let err = QNetwork::new("bad", vec![a.layers[0].clone(), b.layers[0].clone()]);
        assert!(err.is_err());
    }

    #[test]
    fn calibrate_sets_positive_scales_and_fills_grid() {
        let mut net = QNetwork::synthesize(&spec(vec![10, 7, 4], SynthQuant::A2q), 9).unwrap();
        let sample = Tensor::new(vec![3, 10], (0..30).map(|i| (i % 5) as f32 * 0.2).collect());
        net.calibrate(&sample);
        for layer in &net.layers {
            assert!(layer.in_quant.scale > 0.0);
        }
        // the input grid now spans the sample: max value maps to the top code
        let x = net.layers[0].in_quant.quantize(&sample);
        let (_, hi) = net.layers[0].in_quant.int_range();
        assert_eq!(x.abs_max(), hi);
    }

    #[test]
    fn act_quant_clamps_and_resigns() {
        let q = ActQuant::new(3, true, 0.5);
        assert_eq!(q.int_range(), (-4, 3));
        let x = Tensor::new(vec![1, 4], vec![10.0, -10.0, 0.6, -0.24]);
        let m = q.quantize(&x);
        assert_eq!(m.row(0), &[3, -4, 1, 0]);
        let u = ActQuant::new(2, false, 1.0);
        assert_eq!(u.int_range(), (0, 3));
        assert_eq!(u.quantize(&x).row(0), &[3, 0, 1, 0]);
    }

    #[test]
    fn quantize_slice_into_matches_quantize() {
        let q = ActQuant::new(3, true, 0.37);
        let x = Tensor::new(vec![2, 3], vec![10.0, -10.0, 0.61, -0.24, 1.11, -0.9]);
        let m = q.quantize(&x);
        let mut buf = vec![42i64; 1]; // stale contents must be cleared
        q.quantize_slice_into(x.data(), &mut buf);
        assert_eq!(buf.as_slice(), m.data());
    }

    #[test]
    fn geoms_expose_runtime_p_and_chain() {
        let net = QNetwork::synthesize(&spec(vec![12, 8, 5], SynthQuant::A2q), 3).unwrap();
        let geoms = net.geoms();
        assert_eq!(geoms.len(), 2);
        assert!(geoms.iter().all(|g| g.p_spec == BitSpec::P && g.kind == "dense"));
        assert_eq!(geoms[1].k, 8);
        assert_eq!(net.grid_bits(), (4, 3, 12));
        assert_eq!(net.layer_l1_norms().len(), 2);
    }

    #[test]
    fn reference_forward_propagates_and_records_stats() {
        let mut net = QNetwork::synthesize(&spec(vec![9, 6, 3], SynthQuant::A2q), 5).unwrap();
        let sample = Tensor::new(vec![4, 9], (0..36).map(|i| (i % 7) as f32 * 0.1).collect());
        net.calibrate(&sample);
        let x = net.layers[0].in_quant.quantize(&sample);
        let r = network_forward_ref(&net, &x, AccMode::Wide);
        assert_eq!(r.out.shape(), &[4, 3]);
        assert_eq!(r.layer_stats.len(), 2);
        assert_eq!(r.layer_stats[0].dots, 4 * 6);
        assert_eq!(r.layer_stats[1].dots, 4 * 3);
        // wide register never overflows and equals the reference output
        assert_eq!(r.out.data(), r.out_wide.data());
        assert_eq!(r.layer_stats.iter().map(|s| s.overflow_events).sum::<u64>(), 0);
    }

    #[test]
    fn a2q_plus_synthesis_keeps_cap_with_no_more_norm() {
        let a = QNetwork::synthesize(&spec(vec![20, 10, 4], SynthQuant::A2q), 13).unwrap();
        let p = QNetwork::synthesize(&spec(vec![20, 10, 4], SynthQuant::A2qPlus), 13).unwrap();
        for (la, lp) in a.layers.iter().zip(&p.layers) {
            for c in 0..lp.weights.c_out {
                let row: Vec<f32> = lp.weights.row(c).iter().map(|w| *w as f32).collect();
                assert!(row_satisfies_cap(&row, 12, 3, lp.in_quant.signed), "{}/{c}", lp.name);
            }
            // same seed => same Gaussian draws: the centered quantizer never
            // spends more integer norm than plain A2Q, channel by channel
            for (np, na) in lp.weights.row_l1().iter().zip(la.weights.row_l1()) {
                assert!(*np <= na, "{}: {np} > {na}", lp.name);
            }
        }
    }

    #[test]
    fn from_exported_chains_native_training_into_the_simulators() {
        use crate::datasets::{self, Split};
        use crate::runtime::{NativeBackend, TrainBackend};

        let be = NativeBackend::new("artifacts");
        let manifest = be.manifest("mlp3").unwrap();
        let bits = (4u32, 4u32, 14u32);
        let ds = datasets::by_name("synth_mnist", 128, 64, 0).unwrap();
        let idx: Vec<usize> = (0..manifest.batch_size).collect();
        let b = ds.gather(Split::Train, &idx);
        let mut state = be.init(&manifest, 1.0).unwrap();
        for _ in 0..4 {
            be.train_step(&manifest, "a2q", &mut state, &b.x, &b.y, bits, 0.05).unwrap();
        }
        let exported = be.export(&manifest, "a2q", &state, bits).unwrap();
        let mut net = QNetwork::from_exported("mlp3", &exported, &manifest, bits).unwrap();
        assert_eq!(net.depth(), 3);
        assert_eq!(net.input_dim(), 784);
        // layer-0 inputs are the 1-bit binary grid, hidden boundaries N-bit
        assert_eq!(net.layers[0].in_quant.n_bits, 1);
        assert_eq!(net.layers[1].in_quant.n_bits, 4);
        let eval = ds.gather(Split::Test, &(0..32).collect::<Vec<_>>());
        net.calibrate(&eval.x);
        let x = net.layers[0].in_quant.quantize(&eval.x);
        // the trained network is overflow-free at its target width
        let r = network_forward_ref(&net, &x, AccMode::Wrap { p_bits: bits.2 });
        for (li, s) in r.layer_stats.iter().enumerate() {
            assert_eq!(s.overflow_events, 0, "layer {li} overflowed at the A2Q target");
        }
        // and prices straight through the FINN estimator
        let est = crate::finn::estimate_qnetwork(
            &net,
            crate::finn::estimate::AccumulatorPolicy::A2qTarget(bits.2),
            crate::finn::estimate::DEFAULT_CYCLES_BUDGET,
        );
        assert!(est.total_luts() > 0.0);
    }

    #[test]
    fn from_exported_rejects_malformed_exports_with_typed_errors() {
        use crate::runtime::{NativeBackend, TrainBackend};

        let be = NativeBackend::new("artifacts");
        let manifest = be.manifest("mlp").unwrap();
        let bits = (4u32, 4u32, 14u32);
        let state = be.init(&manifest, 1.0).unwrap();
        let exported = be.export(&manifest, "a2q", &state, bits).unwrap();
        // The pristine export loads cleanly.
        QNetwork::from_exported("mlp", &exported, &manifest, bits).unwrap();

        let expect_err = |exported: &[ExportedLayer], bits, needle: &str| {
            let err = QNetwork::from_exported("mlp", exported, &manifest, bits).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "error {msg:?} should mention {needle:?}");
        };

        // NaN weight code (would silently round to garbage in to_qtensor).
        let mut bad = exported.clone();
        bad[0].w_int.data_mut()[3] = f32::NAN;
        expect_err(&bad, bits, "finite integer");

        // Non-integral weight code.
        let mut bad = exported.clone();
        bad[0].w_int.data_mut()[0] = 0.5;
        expect_err(&bad, bits, "finite integer");

        // Infinite per-channel scale.
        let mut bad = exported.clone();
        bad[0].s.data_mut()[0] = f32::INFINITY;
        expect_err(&bad, bits, "finite and positive");

        // NaN bias.
        let mut bad = exported.clone();
        bad[0].b.data_mut()[0] = f32::NAN;
        expect_err(&bad, bits, "not finite");

        // Weight shape disagreeing with the manifest geometry.
        let mut bad = exported.clone();
        let c_out = manifest.qlayers[0].c_out;
        bad[0].w_int = Tensor::new(vec![c_out, 2], vec![1.0; c_out * 2]);
        expect_err(&bad, bits, "manifest geometry");

        // Layer count mismatch.
        expect_err(&[], bits, "manifest qlayers");

        // Out-of-range resolved accumulator width.
        expect_err(&exported, (4u32, 4u32, 0u32), "outside 1..=63");
    }

    #[test]
    fn constrained_network_is_overflow_free_at_target_p() {
        let mut net = QNetwork::synthesize(&spec(vec![16, 10, 4], SynthQuant::A2q), 11).unwrap();
        let sample = Tensor::new(vec![5, 16], (0..80).map(|i| (i % 9) as f32 * 0.11).collect());
        net.calibrate(&sample);
        let x = net.layers[0].in_quant.quantize(&sample);
        let r = network_forward_ref(&net, &x, AccMode::Wrap { p_bits: 12 });
        for (li, s) in r.layer_stats.iter().enumerate() {
            assert_eq!(s.overflow_events, 0, "layer {li} overflowed at the A2Q target");
        }
        assert_eq!(r.out.data(), r.out_wide.data());
    }
}
