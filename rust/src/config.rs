//! Experiment configuration: JSON-loadable, CLI-overridable, validated.
//!
//! Two levels: a [`RunConfig`] describes one training run (model, algorithm,
//! bit widths, schedule); a [`SweepConfig`] describes a grid search over the
//! quantization design space (paper §5.1: M = N in 5..8, P from the
//! data-type bound down to 10 bits below it).

use crate::json::Json;
use crate::quant::bounds::{data_type_bound, DotShape};

use anyhow::Result;

/// One training run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub model: String,
    /// 'a2q' | 'qat' | 'float'
    pub alg: String,
    /// Weight bits M for hidden layers.
    pub m: u32,
    /// Activation bits N for hidden layers.
    pub n: u32,
    /// Target accumulator bits P for hidden layers.
    pub p: u32,
    /// Whether the model's hidden-layer activations are signed integers
    /// (drives the `1_signed(x)` term of every accumulator bound; the
    /// standard zoo uses unsigned post-activation grids, hence false).
    pub x_signed: bool,
    /// Optimizer steps.
    pub steps: u64,
    /// Dataset + init seed.
    pub seed: u64,
    /// Override the model's default learning rate.
    pub lr: Option<f64>,
    /// Multiplicative LR decay factor, applied every `lr_decay_every` steps
    /// (paper B trains with epoch-wise step decay).
    pub lr_decay: f64,
    pub lr_decay_every: u64,
    /// Synthetic dataset sizes.
    pub n_train: usize,
    pub n_test: usize,
    /// Fraction of the step budget spent pre-training the float model before
    /// switching to the quantized graph (paper B.1 initializes from float
    /// models pre-trained to convergence). Ignored for alg == "float".
    pub float_warmup_frac: f64,
}

pub const DEFAULT_N_TRAIN: usize = 2048;
pub const DEFAULT_N_TEST: usize = 512;

impl RunConfig {
    pub fn new(model: &str, alg: &str, m: u32, n: u32, p: u32, steps: u64) -> Self {
        RunConfig {
            model: model.into(),
            alg: alg.into(),
            m,
            n,
            p,
            x_signed: false,
            steps,
            seed: 0,
            lr: None,
            lr_decay: 0.5,
            lr_decay_every: 200,
            n_train: DEFAULT_N_TRAIN,
            n_test: DEFAULT_N_TEST,
            float_warmup_frac: 0.4,
        }
    }

    pub fn bits(&self) -> (u32, u32, u32) {
        (self.m, self.n, self.p)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            matches!(self.alg.as_str(), "a2q" | "a2q_plus" | "qat" | "float"),
            "unknown algorithm {:?}",
            self.alg
        );
        anyhow::ensure!((2..=8).contains(&self.m), "M={} outside 2..=8", self.m);
        anyhow::ensure!((1..=8).contains(&self.n), "N={} outside 1..=8", self.n);
        anyhow::ensure!((4..=32).contains(&self.p), "P={} outside 4..=32", self.p);
        anyhow::ensure!(self.steps > 0, "steps must be positive");
        anyhow::ensure!(self.n_train > 0 && self.n_test > 0, "empty dataset");
        anyhow::ensure!(self.lr.map_or(true, |l| l > 0.0), "lr must be positive");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.float_warmup_frac),
            "float_warmup_frac must be in [0, 1)"
        );
        Ok(())
    }

    /// The LR at a given step under the decay schedule.
    pub fn lr_at(&self, base_lr: f64, step: u64) -> f64 {
        base_lr * self.lr_decay.powi((step / self.lr_decay_every.max(1)) as i32)
    }

    // ---------------------------------------------------------------- JSON

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("alg", Json::str(&self.alg)),
            ("m", Json::num(self.m as f64)),
            ("n", Json::num(self.n as f64)),
            ("p", Json::num(self.p as f64)),
            ("x_signed", Json::Bool(self.x_signed)),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
            (
                "lr",
                self.lr.map(Json::num).unwrap_or(Json::Null),
            ),
            ("lr_decay", Json::num(self.lr_decay)),
            ("lr_decay_every", Json::num(self.lr_decay_every as f64)),
            ("n_train", Json::num(self.n_train as f64)),
            ("n_test", Json::num(self.n_test as f64)),
            ("float_warmup_frac", Json::num(self.float_warmup_frac)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let mut cfg = RunConfig::new(
            v.get("model")?.as_str()?,
            v.get("alg")?.as_str()?,
            v.get("m")?.as_u32()?,
            v.get("n")?.as_u32()?,
            v.get("p")?.as_u32()?,
            v.get("steps")?.as_u64()?,
        );
        if let Some(s) = v.opt("seed") {
            cfg.seed = s.as_u64()?;
        }
        // Absent in pre-QNetwork records: defaults to the zoo's unsigned grids.
        if let Some(s) = v.opt("x_signed") {
            cfg.x_signed = s.as_bool()?;
        }
        if let Some(lr) = v.opt("lr") {
            cfg.lr = match lr {
                Json::Null => None,
                other => Some(other.as_f64()?),
            };
        }
        if let Some(d) = v.opt("lr_decay") {
            cfg.lr_decay = d.as_f64()?;
        }
        if let Some(d) = v.opt("lr_decay_every") {
            cfg.lr_decay_every = d.as_u64()?;
        }
        if let Some(d) = v.opt("n_train") {
            cfg.n_train = d.as_usize()?;
        }
        if let Some(d) = v.opt("n_test") {
            cfg.n_test = d.as_usize()?;
        }
        if let Some(d) = v.opt("float_warmup_frac") {
            cfg.float_warmup_frac = d.as_f64()?;
        }
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let cfg = Self::from_json(&Json::parse(&text)?)?;
        cfg.validate()?;
        Ok(cfg)
    }
}

/// A grid search over the quantization design space.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub models: Vec<String>,
    pub algs: Vec<String>,
    /// Uniform M = N values to sweep (paper: 5..8).
    pub mn_values: Vec<u32>,
    /// Accumulator offsets below each config's data-type bound
    /// (paper: down to a 10-bit reduction).
    pub p_offsets: Vec<u32>,
    pub steps: u64,
    pub seed: u64,
    pub n_train: usize,
    pub n_test: usize,
}

impl SweepConfig {
    /// Paper-shaped default grid, scaled for CPU budgets.
    pub fn default_grid(models: Vec<String>, steps: u64) -> Self {
        SweepConfig {
            models,
            algs: vec!["a2q".into(), "qat".into()],
            mn_values: vec![6, 8],
            p_offsets: vec![0, 2, 4, 6, 8, 10],
            steps,
            seed: 0,
            n_train: DEFAULT_N_TRAIN,
            n_test: DEFAULT_N_TEST,
        }
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let strs = |key: &str| -> Result<Vec<String>> {
            v.get(key)?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect()
        };
        let nums = |key: &str| -> Result<Vec<u32>> {
            v.get(key)?.as_arr()?.iter().map(|n| n.as_u32()).collect()
        };
        let mut cfg = SweepConfig::default_grid(strs("models")?, v.get("steps")?.as_u64()?);
        if v.opt("algs").is_some() {
            cfg.algs = strs("algs")?;
        }
        if v.opt("mn_values").is_some() {
            cfg.mn_values = nums("mn_values")?;
        }
        if v.opt("p_offsets").is_some() {
            cfg.p_offsets = nums("p_offsets")?;
        }
        if let Some(s) = v.opt("seed") {
            cfg.seed = s.as_u64()?;
        }
        if let Some(s) = v.opt("n_train") {
            cfg.n_train = s.as_usize()?;
        }
        if let Some(s) = v.opt("n_test") {
            cfg.n_test = s.as_usize()?;
        }
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Expand to concrete runs. `largest_k` is the model's K* so the grid is
    /// anchored at the model's data-type bound (paper §5.1).
    pub fn expand_for_model(&self, model: &str, largest_k: usize) -> Vec<RunConfig> {
        let mut out = Vec::new();
        for &mn in &self.mn_values {
            let dt = data_type_bound(DotShape {
                k: largest_k,
                m_bits: mn,
                n_bits: mn,
                x_signed: false,
            })
            .min(32);
            for &off in &self.p_offsets {
                let p = dt.saturating_sub(off).max(4);
                // The accumulator-aware algorithms treat P as a free design
                // variable (one run per P, per quantizer).
                for alg in ["a2q", "a2q_plus"] {
                    if self.algs.iter().any(|a| a == alg) {
                        let mut rc = RunConfig::new(model, alg, mn, mn, p, self.steps);
                        rc.seed = self.seed;
                        rc.n_train = self.n_train;
                        rc.n_test = self.n_test;
                        out.push(rc);
                    }
                }
            }
            // The QAT baseline is accumulator-oblivious: its training is
            // identical for every P, and its only *safe* deployment width is
            // the data-type bound. One run per (M, N).
            if self.algs.iter().any(|a| a == "qat") {
                let mut rc = RunConfig::new(model, "qat", mn, mn, dt, self.steps);
                rc.seed = self.seed;
                rc.n_train = self.n_train;
                rc.n_test = self.n_test;
                out.push(rc);
            }
        }
        if self.algs.iter().any(|a| a == "float") {
            // One float reference per model: bit widths are ignored by the
            // float graph; pin them for a stable resume key.
            let mut rc = RunConfig::new(model, "float", 8, 8, 32, self.steps);
            rc.seed = self.seed;
            rc.n_train = self.n_train;
            rc.n_test = self.n_test;
            out.push(rc);
        }
        // The QAT heuristic cannot act on P (its effective accumulator is
        // its data-type bound): dedup identical tuples.
        out.sort_by(|a, b| {
            (a.alg.clone(), a.m, a.n, a.p).cmp(&(b.alg.clone(), b.m, b.n, b.p))
        });
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = RunConfig::new("cnn", "a2q", 6, 6, 16, 100);
        assert!(c.validate().is_ok());
        c.alg = "magic".into();
        assert!(c.validate().is_err());
        c.alg = "qat".into();
        c.m = 9;
        assert!(c.validate().is_err());
    }

    #[test]
    fn lr_schedule() {
        let c = RunConfig::new("cnn", "a2q", 6, 6, 16, 1000);
        assert_eq!(c.lr_at(1.0, 0), 1.0);
        assert_eq!(c.lr_at(1.0, 199), 1.0);
        assert_eq!(c.lr_at(1.0, 200), 0.5);
        assert_eq!(c.lr_at(1.0, 400), 0.25);
    }

    #[test]
    fn json_round_trip() {
        let mut c = RunConfig::new("espcn", "qat", 5, 5, 14, 50);
        c.lr = Some(2e-3);
        c.seed = 7;
        c.x_signed = true;
        let back = RunConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn sweep_from_json_defaults() {
        let v = Json::parse(r#"{"models": ["mlp"], "steps": 25}"#).unwrap();
        let s = SweepConfig::from_json(&v).unwrap();
        assert_eq!(s.models, vec!["mlp"]);
        assert_eq!(s.mn_values, vec![6, 8]);
    }

    #[test]
    fn sweep_expansion_anchored_at_bound() {
        let mut sweep = SweepConfig::default_grid(vec!["mlp".into()], 10);
        sweep.algs.push("float".into());
        let runs = sweep.expand_for_model("mlp", 784);
        assert!(!runs.is_empty());
        let dt = data_type_bound(DotShape { k: 784, m_bits: 8, n_bits: 8, x_signed: false });
        assert!(runs.iter().any(|r| r.m == 8 && r.p == dt && r.alg == "a2q"));
        assert_eq!(runs.iter().filter(|r| r.alg == "float").count(), 1);
        assert!(runs.iter().all(|r| r.p >= 4 && r.p <= 32));
        for r in &runs {
            r.validate().unwrap();
        }
    }

    #[test]
    fn a2q_plus_validates_and_expands_per_p() {
        let c = RunConfig::new("mlp", "a2q_plus", 6, 6, 16, 100);
        assert!(c.validate().is_ok());
        let mut sweep = SweepConfig::default_grid(vec!["mlp".into()], 10);
        sweep.algs = vec!["a2q".into(), "a2q_plus".into()];
        sweep.mn_values = vec![6];
        sweep.p_offsets = vec![0, 4];
        let runs = sweep.expand_for_model("mlp", 784);
        assert_eq!(runs.iter().filter(|r| r.alg == "a2q_plus").count(), 2);
        assert_eq!(runs.len(), 4);
    }

    #[test]
    fn sweep_dedups() {
        let mut sweep = SweepConfig::default_grid(vec!["mlp".into()], 10);
        sweep.p_offsets = vec![0, 0, 0];
        let runs = sweep.expand_for_model("mlp", 784);
        let mut uniq = runs.clone();
        uniq.dedup();
        assert_eq!(runs.len(), uniq.len());
    }
}
