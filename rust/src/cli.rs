//! Minimal command-line parsing (offline replacement for `clap`).
//!
//! Grammar: `a2q [--global value]... <subcommand> [--flag value | --flag=value]...`
//! Unknown flags are an error; every flag takes a value except those
//! registered as boolean switches. A flag may repeat; scalar accessors
//! read the last occurrence, [`Args::all_strs`] returns every one.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments: positional subcommand words + `--flag` values.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse raw args (without argv[0]). `switches` lists boolean flags that
    /// take no value (`--foo` == `--foo true`).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, switches: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(flag) = arg.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    out.push_flag(k, v.to_string());
                } else if switches.contains(&flag) {
                    // optional explicit value: --flag true/false
                    match iter.peek().map(|s| s.as_str()) {
                        Some("true") | Some("false") => {
                            let v = iter.next().unwrap();
                            out.push_flag(flag, v);
                        }
                        _ => {
                            out.push_flag(flag, "true".to_string());
                        }
                    }
                } else {
                    let v = iter
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("flag --{flag} needs a value"))?;
                    out.push_flag(flag, v);
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    fn push_flag(&mut self, key: &str, value: String) {
        self.flags.entry(key.to_string()).or_default().push(value);
    }

    fn last(&self, key: &str) -> Option<&String> {
        self.flags.get(key).and_then(|vs| vs.last())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.last(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.last(key).cloned()
    }

    /// Every occurrence of a repeatable flag, in command-line order
    /// (empty when the flag was never given).
    pub fn all_strs(&self, key: &str) -> Vec<String> {
        self.flags.get(key).cloned().unwrap_or_default()
    }

    pub fn num_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.last(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.last(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(other) => bail!("--{key} expects true/false, got {other:?}"),
        }
    }

    /// Comma-separated list flag.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &str) -> Result<Vec<T>>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.str_or(key, default);
        raw.split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| {
                t.trim()
                    .parse::<T>()
                    .map_err(|e| anyhow::anyhow!("--{key} item {t:?}: {e}"))
            })
            .collect()
    }

    /// Error on flags not in the accepted set (catches typos).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), &["verbose"]).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["train", "--model", "cnn", "--steps=100", "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.str_or("model", "x"), "cnn");
        assert_eq!(a.num_or("steps", 0u64).unwrap(), 100);
        assert!(a.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn defaults() {
        let a = parse(&["sweep"]);
        assert_eq!(a.str_or("model", "cnn"), "cnn");
        assert_eq!(a.num_or("m", 6u32).unwrap(), 6);
        assert!(!a.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn lists() {
        let a = parse(&["x", "--mn", "5, 6,8"]);
        assert_eq!(a.list_or::<u32>("mn", "").unwrap(), vec![5, 6, 8]);
        let b = parse(&["x"]);
        assert_eq!(b.list_or::<u32>("mn", "6,8").unwrap(), vec![6, 8]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["--model".to_string()], &[]).is_err());
    }

    #[test]
    fn unknown_flag_check() {
        let a = parse(&["x", "--modle", "cnn"]);
        assert!(a.check_known(&["model"]).is_err());
        assert!(a.check_known(&["modle"]).is_ok());
    }

    #[test]
    fn switch_with_explicit_value() {
        let a = parse(&["x", "--verbose", "false"]);
        assert!(!a.bool_or("verbose", true).unwrap());
    }

    #[test]
    fn repeated_flags_keep_every_value_and_scalars_read_the_last() {
        let a = parse(&["x", "--require", "a:b", "--require=c:d", "--require", "e:f"]);
        assert_eq!(a.all_strs("require"), vec!["a:b", "c:d", "e:f"]);
        assert_eq!(a.str_or("require", ""), "e:f", "scalar access is last-wins");
        assert!(a.all_strs("absent").is_empty());
        assert!(a.check_known(&["require"]).is_ok());
    }
}
