//! Tiny CSV / markdown emitters for the figure reports.

use std::path::Path;

/// Write rows as CSV (first row = header). Creates parent dirs.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Write a markdown table with a title. Creates parent dirs.
pub fn write_markdown(
    path: &Path,
    title: &str,
    header: &[&str],
    rows: &[Vec<String>],
) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = format!("# {title}\n\n");
    out.push_str(&format!("| {} |\n", header.join(" | ")));
    out.push_str(&format!("|{}|\n", header.iter().map(|_| "---").collect::<Vec<_>>().join("|")));
    for row in rows {
        out.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Format a float with fixed precision for tables.
pub fn f(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_md() {
        let dir = crate::testutil::TempDir::new().unwrap();
        let rows = vec![vec!["1".to_string(), "2.5".to_string()]];
        let csv = dir.path().join("x/t.csv");
        write_csv(&csv, &["a", "b"], &rows).unwrap();
        assert!(std::fs::read_to_string(&csv).unwrap().contains("1,2.5"));
        let md = dir.path().join("t.md");
        write_markdown(&md, "T", &["a", "b"], &rows).unwrap();
        let text = std::fs::read_to_string(&md).unwrap();
        assert!(text.contains("| 1 | 2.5 |"));
    }
}
