//! Figs. 4 and 5: consume grid-search [`RunRecord`]s.
//!
//! * **Fig. 4** — Pareto frontier of task performance vs target accumulator
//!   width P, A2Q against the baseline-QAT heuristic. A2Q exposes P as a free
//!   variable (its records carry their trained P); the QAT heuristic can only
//!   reach the data-type bound implied by its (M, N) choice (paper §5.2), so
//!   its points sit at `P = data_type_bound(K*, M, N)`.
//! * **Fig. 5** — mean ± std of exported-weight sparsity and of task
//!   performance relative to the float baseline, as functions of P (M = N
//!   configs, averaged across models, paper §5.2.1).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::RunRecord;
use crate::pareto::{frontier, Point};
use crate::quant::bounds::{data_type_bound, DotShape};

use super::render::{f, write_csv};

/// Fig. 4 data for one model: per-algorithm Pareto frontiers over (P, perf).
#[derive(Clone, Debug)]
pub struct Fig4Model {
    pub model: String,
    pub float_perf: Option<f64>,
    /// (alg, frontier of (P, perf))
    pub frontiers: Vec<(String, Vec<Point<(u32, u32)>>)>,
}

/// Effective accumulator width of a record under its algorithm's semantics.
fn effective_p(rec: &RunRecord, largest_k: usize) -> u32 {
    if matches!(rec.config.alg.as_str(), "a2q" | "a2q_plus") {
        rec.config.p
    } else {
        // heuristic baseline: the guaranteed-safe P for its data types,
        // with the activation signedness taken from the record's config (a
        // signed-input model's bound is one bit tighter, Eq. 8).
        data_type_bound(DotShape {
            k: largest_k,
            m_bits: rec.config.m,
            n_bits: rec.config.n,
            x_signed: rec.config.x_signed,
        })
        .min(32)
    }
}

/// Build Fig. 4 for every model present in the records.
pub fn fig4(records: &[RunRecord], largest_k: &BTreeMap<String, usize>) -> Vec<Fig4Model> {
    let mut models: Vec<String> = records.iter().map(|r| r.config.model.clone()).collect();
    models.sort();
    models.dedup();

    models
        .into_iter()
        .map(|model| {
            let k = *largest_k.get(&model).unwrap_or(&1);
            let float_perf = records
                .iter()
                .filter(|r| r.config.model == model && r.config.alg == "float")
                .map(|r| r.perf)
                .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v))));
            let mut frontiers = Vec::new();
            for alg in ["a2q", "a2q_plus", "qat"] {
                let pts: Vec<Point<(u32, u32)>> = records
                    .iter()
                    .filter(|r| r.config.model == model && r.config.alg == alg)
                    .map(|r| Point {
                        cost: effective_p(r, k) as f64,
                        perf: r.perf,
                        tag: (r.config.m, r.config.n),
                    })
                    .collect();
                if !pts.is_empty() {
                    frontiers.push((alg.to_string(), frontier(&pts)));
                }
            }
            Fig4Model { model, float_perf, frontiers }
        })
        .collect()
}

/// Emit `results/fig4_<model>.csv`.
pub fn emit_fig4(models: &[Fig4Model], out_dir: &Path) -> Result<()> {
    for m in models {
        let mut rows = Vec::new();
        for (alg, front) in &m.frontiers {
            for p in front {
                rows.push(vec![
                    alg.clone(),
                    f(p.cost, 0),
                    f(p.perf, 4),
                    p.tag.0.to_string(),
                    p.tag.1.to_string(),
                ]);
            }
        }
        if let Some(fp) = m.float_perf {
            rows.push(vec!["float".into(), "32".into(), f(fp, 4), "-".into(), "-".into()]);
        }
        write_csv(
            &out_dir.join(format!("fig4_{}.csv", m.model)),
            &["alg", "P", "perf", "M", "N"],
            &rows,
        )?;
    }
    Ok(())
}

/// One Fig. 5 row: stats at accumulator width P.
#[derive(Clone, Debug)]
pub struct Fig5Row {
    pub p_bits: u32,
    pub sparsity_mean: f64,
    pub sparsity_std: f64,
    pub rel_perf_mean: f64,
    pub rel_perf_std: f64,
    pub n: usize,
}

fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    (m, v.sqrt())
}

/// Build Fig. 5 from A2Q records with M = N, relative to each model's float
/// reference.
pub fn fig5(records: &[RunRecord]) -> Vec<Fig5Row> {
    let float_ref: BTreeMap<String, f64> = records
        .iter()
        .filter(|r| r.config.alg == "float")
        .map(|r| (r.config.model.clone(), r.perf))
        .collect();

    let mut by_p: BTreeMap<u32, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for r in records {
        if !matches!(r.config.alg.as_str(), "a2q" | "a2q_plus") || r.config.m != r.config.n {
            continue;
        }
        let Some(&fp) = float_ref.get(&r.config.model) else { continue };
        if fp <= 0.0 {
            continue;
        }
        let e = by_p.entry(r.config.p).or_default();
        e.0.push(r.sparsity);
        e.1.push(r.perf / fp);
    }
    by_p.into_iter()
        .map(|(p, (sp, rp))| {
            let (sm, ss) = mean_std(&sp);
            let (rm, rs) = mean_std(&rp);
            Fig5Row {
                p_bits: p,
                sparsity_mean: sm,
                sparsity_std: ss,
                rel_perf_mean: rm,
                rel_perf_std: rs,
                n: sp.len(),
            }
        })
        .collect()
}

/// Emit `results/fig5.csv`.
pub fn emit_fig5(rows: &[Fig5Row], out_dir: &Path) -> Result<()> {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.p_bits.to_string(),
                f(r.sparsity_mean, 4),
                f(r.sparsity_std, 4),
                f(r.rel_perf_mean, 4),
                f(r.rel_perf_std, 4),
                r.n.to_string(),
            ]
        })
        .collect();
    write_csv(
        &out_dir.join("fig5.csv"),
        &["P", "sparsity_mean", "sparsity_std", "rel_perf_mean", "rel_perf_std", "n"],
        &table,
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn rec(model: &str, alg: &str, mn: u32, p: u32, perf: f64, sparsity: f64) -> RunRecord {
        RunRecord {
            config: RunConfig::new(model, alg, mn, mn, p, 10),
            perf,
            sparsity,
            l1_norms: vec![10.0],
            guarantee_ok: true,
            final_loss: 0.1,
            first_loss: 1.0,
            train_secs: 0.0,
        }
    }

    #[test]
    fn fig4_qat_sits_at_its_bound() {
        let recs = vec![
            rec("mlp", "a2q", 8, 12, 0.9, 0.5),
            rec("mlp", "qat", 8, 12, 0.95, 0.1), // p ignored for qat
            rec("mlp", "float", 8, 32, 0.97, 0.0),
        ];
        let mut lk = BTreeMap::new();
        lk.insert("mlp".to_string(), 784usize);
        let out = fig4(&recs, &lk);
        assert_eq!(out.len(), 1);
        let qat_front = &out[0].frontiers.iter().find(|(a, _)| a == "qat").unwrap().1;
        // data-type bound for K=784, M=N=8 unsigned
        let dt = data_type_bound(DotShape { k: 784, m_bits: 8, n_bits: 8, x_signed: false });
        assert_eq!(qat_front[0].cost, dt as f64);
        let a2q_front = &out[0].frontiers.iter().find(|(a, _)| a == "a2q").unwrap().1;
        assert_eq!(a2q_front[0].cost, 12.0);
        assert_eq!(out[0].float_perf, Some(0.97));
    }

    #[test]
    fn fig4_signed_inputs_tighten_the_qat_bound() {
        let mut signed = rec("mlp", "qat", 8, 12, 0.95, 0.1);
        signed.config.x_signed = true;
        let mut lk = BTreeMap::new();
        lk.insert("mlp".to_string(), 784usize);
        let out = fig4(&[signed], &lk);
        let qat_front = &out[0].frontiers.iter().find(|(a, _)| a == "qat").unwrap().1;
        let dt_signed =
            data_type_bound(DotShape { k: 784, m_bits: 8, n_bits: 8, x_signed: true });
        let dt_unsigned =
            data_type_bound(DotShape { k: 784, m_bits: 8, n_bits: 8, x_signed: false });
        assert_eq!(qat_front[0].cost, dt_signed as f64);
        assert_eq!(dt_signed + 1, dt_unsigned); // one bit saved, actually used
    }

    #[test]
    fn fig5_aggregates_by_p() {
        let recs = vec![
            rec("mlp", "float", 8, 32, 1.0, 0.0),
            rec("cnn", "float", 8, 32, 0.8, 0.0),
            rec("mlp", "a2q", 6, 12, 0.9, 0.6),
            rec("cnn", "a2q", 6, 12, 0.4, 0.8),
            rec("mlp", "a2q", 6, 16, 0.99, 0.3),
        ];
        let rows = fig5(&recs);
        assert_eq!(rows.len(), 2);
        let r12 = rows.iter().find(|r| r.p_bits == 12).unwrap();
        assert_eq!(r12.n, 2);
        assert!((r12.sparsity_mean - 0.7).abs() < 1e-9);
        assert!((r12.rel_perf_mean - (0.9 / 1.0 + 0.4 / 0.8) / 2.0).abs() < 1e-9);
    }
}
