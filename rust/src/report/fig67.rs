//! Figs. 6 and 7: HW-SW co-design — LUT utilization vs task performance
//! under the four accumulator policies (paper §5.3), plus the compute/memory
//! breakdown of the A2Q Pareto-optimal points (§5.3.1) and the abstract's
//! headline "up to 2.3x LUT reduction at 99.2% of float accuracy".

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::RunRecord;
use crate::finn::estimate::{estimate_network, AccumulatorPolicy, DEFAULT_CYCLES_BUDGET};
use crate::finn::LayerGeom;
use crate::pareto::{frontier, Point};

use super::render::{f, write_csv};

/// Tag carried on each Fig. 6 point: the grid config it came from.
#[derive(Clone, Debug)]
pub struct CfgTag {
    pub m: u32,
    pub n: u32,
    pub p: u32,
    pub compute: f64,
    pub memory: f64,
}

/// Fig. 6 for one model: four (setting -> frontier) curves.
#[derive(Clone, Debug)]
pub struct Fig6Model {
    pub model: String,
    pub float_perf: Option<f64>,
    pub settings: Vec<(String, Vec<Point<CfgTag>>)>,
}

/// The co-design settings of paper §5.3, plus the A2Q+ quantizer at the
/// same target-P policy.
pub fn settings() -> Vec<(&'static str, &'static str)> {
    vec![
        ("qat_fixed32", "qat"),
        ("qat_datatype", "qat"),
        ("qat_ptm", "qat"),
        ("a2q", "a2q"),
        ("a2q_plus", "a2q_plus"),
    ]
}

fn policy_for(setting: &str, p: u32) -> AccumulatorPolicy {
    match setting {
        "qat_fixed32" => AccumulatorPolicy::Fixed32,
        "qat_datatype" => AccumulatorPolicy::DataTypeBound,
        "qat_ptm" => AccumulatorPolicy::WeightNorm,
        "a2q" | "a2q_plus" => AccumulatorPolicy::A2qTarget(p),
        other => unreachable!("unknown setting {other}"),
    }
}

/// Build Fig. 6 from grid records + per-model layer geometry.
pub fn fig6(
    records: &[RunRecord],
    geoms: &BTreeMap<String, Vec<LayerGeom>>,
) -> Vec<Fig6Model> {
    let mut models: Vec<String> = records.iter().map(|r| r.config.model.clone()).collect();
    models.sort();
    models.dedup();

    models
        .into_iter()
        .filter(|m| geoms.contains_key(m))
        .map(|model| {
            let g = &geoms[&model];
            let float_perf = records
                .iter()
                .filter(|r| r.config.model == model && r.config.alg == "float")
                .map(|r| r.perf)
                .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v))));

            let mut out_settings = Vec::new();
            for (setting, alg) in settings() {
                let pts: Vec<Point<CfgTag>> = records
                    .iter()
                    .filter(|r| r.config.model == model && r.config.alg == alg)
                    .map(|r| {
                        let bits = (r.config.m, r.config.n, r.config.p);
                        let est = estimate_network(
                            g,
                            bits,
                            policy_for(setting, r.config.p),
                            Some(&r.l1_norms),
                            DEFAULT_CYCLES_BUDGET,
                        );
                        Point {
                            cost: est.total_luts(),
                            perf: r.perf,
                            tag: CfgTag {
                                m: r.config.m,
                                n: r.config.n,
                                p: r.config.p,
                                compute: est.total.compute,
                                memory: est.total.memory,
                            },
                        }
                    })
                    .collect();
                if !pts.is_empty() {
                    out_settings.push((setting.to_string(), frontier(&pts)));
                }
            }
            Fig6Model { model, float_perf, settings: out_settings }
        })
        .collect()
}

/// Emit `results/fig6_<model>.csv` and `results/fig7_<model>.csv`.
pub fn emit(models: &[Fig6Model], out_dir: &Path) -> Result<()> {
    for m in models {
        let mut rows6 = Vec::new();
        for (setting, front) in &m.settings {
            for p in front {
                rows6.push(vec![
                    setting.clone(),
                    f(p.cost, 0),
                    f(p.perf, 4),
                    p.tag.m.to_string(),
                    p.tag.n.to_string(),
                    p.tag.p.to_string(),
                ]);
            }
        }
        if let Some(fp) = m.float_perf {
            rows6.push(vec!["float".into(), "-".into(), f(fp, 4), "-".into(), "-".into(), "-".into()]);
        }
        write_csv(
            &out_dir.join(format!("fig6_{}.csv", m.model)),
            &["setting", "luts", "perf", "M", "N", "P"],
            &rows6,
        )?;

        // Fig. 7: breakdown of the A2Q frontier points.
        if let Some((_, front)) = m.settings.iter().find(|(s, _)| s == "a2q") {
            let rows7: Vec<Vec<String>> = front
                .iter()
                .map(|p| {
                    vec![
                        p.tag.m.to_string(),
                        p.tag.n.to_string(),
                        p.tag.p.to_string(),
                        f(p.tag.compute, 0),
                        f(p.tag.memory, 0),
                        f(p.perf, 4),
                    ]
                })
                .collect();
            write_csv(
                &out_dir.join(format!("fig7_{}.csv", m.model)),
                &["M", "N", "P", "lut_compute", "lut_memory", "perf"],
                &rows7,
            )?;
        }
    }
    Ok(())
}

/// The abstract's headline: best LUT reduction of A2Q vs the fixed-32-bit
/// baseline among A2Q points retaining >= `rel_floor` of float performance.
/// Returns (reduction_factor, rel_perf_at_that_point).
pub fn headline_reduction(m: &Fig6Model, rel_floor: f64) -> Option<(f64, f64)> {
    let float = m.float_perf?;
    let fixed = m.settings.iter().find(|(s, _)| s == "qat_fixed32")?;
    let a2q = m.settings.iter().find(|(s, _)| s == "a2q")?;
    // baseline cost: cheapest fixed-32 point retaining rel_floor
    let base = fixed
        .1
        .iter()
        .filter(|p| p.perf / float >= rel_floor)
        .map(|p| p.cost)
        .fold(f64::INFINITY, f64::min);
    let mut best: Option<(f64, f64)> = None;
    for p in &a2q.1 {
        let rel = p.perf / float;
        if rel >= rel_floor && base.is_finite() && p.cost > 0.0 {
            let red = base / p.cost;
            if best.map_or(true, |(b, _)| red > b) {
                best = Some((red, rel));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::finn::estimate::BitSpec;

    fn geoms() -> Vec<LayerGeom> {
        vec![LayerGeom {
            name: "l".into(),
            kind: "conv".into(),
            c_out: 32,
            k: 288,
            m_spec: BitSpec::M,
            n_spec: BitSpec::N,
            p_spec: BitSpec::P,
            x_signed: false,
            out_h: 8,
            out_w: 8,
            kh: 3,
            c_in: 32,
            stride: 1,
        }]
    }

    fn rec(alg: &str, mn: u32, p: u32, perf: f64) -> RunRecord {
        RunRecord {
            config: RunConfig::new("m", alg, mn, mn, p, 10),
            perf,
            sparsity: 0.4,
            l1_norms: vec![100.0],
            guarantee_ok: true,
            final_loss: 0.0,
            first_loss: 1.0,
            train_secs: 0.0,
        }
    }

    #[test]
    fn a2q_frontier_cheaper_than_fixed32() {
        let recs = vec![
            rec("qat", 8, 32, 0.95),
            rec("a2q", 8, 14, 0.94),
            rec("float", 8, 32, 0.96),
        ];
        let mut g = BTreeMap::new();
        g.insert("m".to_string(), geoms());
        let out = fig6(&recs, &g);
        assert_eq!(out.len(), 1);
        let fixed = &out[0].settings.iter().find(|(s, _)| s == "qat_fixed32").unwrap().1;
        let a2q = &out[0].settings.iter().find(|(s, _)| s == "a2q").unwrap().1;
        assert!(a2q[0].cost < fixed[0].cost);
        // headline exists and exceeds 1x
        let (red, rel) = headline_reduction(&out[0], 0.9).unwrap();
        assert!(red > 1.0);
        assert!(rel >= 0.9);
    }
}
