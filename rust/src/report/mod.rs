//! Per-figure report generators: each paper figure/table has a function that
//! runs (or consumes) the relevant experiments and emits CSV + markdown into
//! a results directory. The CLI (`a2q figure <id>`) and the criterion
//! benches both drive these.
//!
//! | paper artifact | module | output |
//! |---|---|---|
//! | Fig. 2 (overflow impact, 1-layer bMNIST) | [`fig2`] | `results/fig2.csv` |
//! | Fig. 3 (bound comparison)               | [`fig3`] | `results/fig3.csv` |
//! | Fig. 4 (perf vs P Pareto)               | [`fig45`] | `results/fig4_<model>.csv` |
//! | Fig. 5 (sparsity vs P)                  | [`fig45`] | `results/fig5.csv` |
//! | Fig. 6 (LUTs vs perf Pareto)            | [`fig67`] | `results/fig6_<model>.csv` |
//! | Fig. 7 (LUT breakdown)                  | [`fig67`] | `results/fig7_<model>.csv` |
//! | Fig. 8 (re-ordering under saturation)   | [`fig8`] | `results/fig8.csv` |
//! | Fig. 2 network variant (overflow by depth) | [`fig2`] | `results/fig2_network.csv` |
//! | Fig. 3 network variant (bounds/sparsity by depth) | [`fig3`] | `results/fig3_network.csv` |

// Every figure generator is available in the default build: fig8 and
// fig2's training-backed pipeline are generic over the
// [`crate::runtime::TrainBackend`] (native trainer by default, PJRT under
// the `xla` feature); the record-driven figures (fig3/fig45/fig67) and the
// QNetwork-driven network variants (fig2::run_network / fig3::run_network,
// fed by `a2q netsim`) are pure host code.
pub mod fig2;
pub mod fig3;
pub mod fig45;
pub mod fig67;
pub mod fig8;
pub mod render;

pub use render::{write_csv, write_markdown};
