//! Fig. 2 / Appendix A: the impact of overflow on the 1-layer binary-MNIST
//! QNN (K = 784, M = 8, N = 1, data-type bound P = 19).
//!
//! Pipeline (all from Rust against the AOT artifacts):
//! 1. train the `mlp` with baseline QAT (32-bit assumption);
//! 2. export its integer weights; for each P below the bound, run *bit-exact*
//!    integer inference over the test set under wraparound and saturating
//!    accumulators ([`crate::accsim`]), recording overflow rate, MAE on the
//!    logits vs the wide register, and top-1 accuracy;
//! 3. re-train the same model from the same seed with A2Q at each target P
//!    and record its accuracy (overflow-free by construction — asserted).

use std::path::Path;

use anyhow::Result;

use crate::accsim::{qlinear_forward, qlinear_forward_multi, AccMode};
use crate::accsim::matmul::quantize_inputs;
use crate::config::RunConfig;
use crate::coordinator::Trainer;
use crate::datasets::Split;
use crate::metrics;
use crate::runtime::Engine;

use super::render::{f, write_csv, write_markdown};

/// One row of the figure: behaviour of each scheme at accumulator width P.
#[derive(Clone, Debug)]
pub struct Fig2Row {
    pub p_bits: u32,
    pub overflow_rate_wrap: f64,
    pub mae_wrap: f64,
    pub acc_wrap: f64,
    pub mae_sat: f64,
    pub acc_sat: f64,
    pub acc_a2q: f64,
    pub a2q_overflows: u64,
}

pub struct Fig2Report {
    pub acc_wide: f64,
    pub rows: Vec<Fig2Row>,
}

/// Run the experiment. `p_values` defaults to 10..=20 (the paper sweeps
/// below the 19-bit bound); `steps` sizes each training run.
pub fn run(
    engine: &Engine,
    p_values: &[u32],
    steps: u64,
    eval_samples: usize,
    seed: u64,
) -> Result<Fig2Report> {
    // --- 1. baseline QAT training (accumulator-oblivious) -------------------
    let mut qat_cfg = RunConfig::new("mlp", "qat", 8, 1, 32, steps);
    qat_cfg.seed = seed;
    let trainer = Trainer::new(engine, &qat_cfg)?;
    let qat = trainer.run(&qat_cfg)?;
    let layer = qat.exported.as_ref().unwrap()[0].to_qtensor();

    // Integer test inputs: binary pixels are exactly the 1-bit codes.
    let n_eval = eval_samples.min(trainer.dataset.len(Split::Test));
    let idx: Vec<usize> = (0..n_eval).collect();
    let batch = trainer.dataset.gather(Split::Test, &idx);
    let x_int = quantize_inputs(&batch.x, 1.0, 1, false);
    let labels = batch.y.data();

    // --- 2. simulate P-bit deployment of the QAT model -----------------------
    // One fused pass over the MACs simulates the wide reference AND
    // wraparound AND saturation at every requested width (the old code
    // re-walked the weights once for wide plus 2x per P).
    let modes: Vec<AccMode> = std::iter::once(AccMode::Wide)
        .chain(p_values.iter().flat_map(|&p| {
            [AccMode::Wrap { p_bits: p }, AccMode::Saturate { p_bits: p }]
        }))
        .collect();
    let sims = qlinear_forward_multi(&x_int, 1.0, &layer, &modes);

    let (c, n) = metrics::top1_accuracy(&sims[0].out, labels, n_eval);
    let acc_wide = c as f64 / n as f64;

    let mut rows = Vec::new();
    for (pi, &p) in p_values.iter().enumerate() {
        let wrap = &sims[1 + 2 * pi];
        let sat = &sims[2 + 2 * pi];
        let (cw, _) = metrics::top1_accuracy(&wrap.out, labels, n_eval);
        let (cs, _) = metrics::top1_accuracy(&sat.out, labels, n_eval);

        // --- 3. A2Q re-trained at target P, same seed ------------------------
        let mut a2q_cfg = RunConfig::new("mlp", "a2q", 8, 1, p, steps);
        a2q_cfg.seed = seed;
        let a2q = trainer.run(&a2q_cfg)?;
        anyhow::ensure!(a2q.guarantee_ok, "A2Q Eq. 15 audit failed at P={p}");
        let a2q_layer = a2q.exported.as_ref().unwrap()[0].to_qtensor();
        let a2q_sim = qlinear_forward(&x_int, 1.0, &a2q_layer, AccMode::Wrap { p_bits: p });
        // The theorem in action: wraparound at P bits must be a no-op.
        anyhow::ensure!(
            a2q_sim.stats.overflow_events == 0,
            "A2Q overflowed at P={p}: {} events",
            a2q_sim.stats.overflow_events
        );
        let (ca, _) = metrics::top1_accuracy(&a2q_sim.out, labels, n_eval);

        rows.push(Fig2Row {
            p_bits: p,
            overflow_rate_wrap: wrap.stats.overflow_rate(),
            mae_wrap: metrics::logit_mae(&wrap.out, &wrap.out_wide),
            acc_wrap: cw as f64 / n_eval as f64,
            mae_sat: metrics::logit_mae(&sat.out, &sat.out_wide),
            acc_sat: cs as f64 / n_eval as f64,
            acc_a2q: ca as f64 / n_eval as f64,
            a2q_overflows: a2q_sim.stats.overflow_events,
        });
    }
    Ok(Fig2Report { acc_wide, rows })
}

/// Emit `results/fig2.csv` + `results/fig2.md`.
pub fn emit(report: &Fig2Report, out_dir: &Path) -> Result<()> {
    let header = [
        "P",
        "overflow_rate",
        "mae_wrap",
        "acc_wrap",
        "mae_sat",
        "acc_sat",
        "acc_a2q",
        "a2q_overflow_events",
    ];
    let rows: Vec<Vec<String>> = report
        .rows
        .iter()
        .map(|r| {
            vec![
                r.p_bits.to_string(),
                f(r.overflow_rate_wrap, 4),
                f(r.mae_wrap, 4),
                f(r.acc_wrap, 4),
                f(r.mae_sat, 4),
                f(r.acc_sat, 4),
                f(r.acc_a2q, 4),
                r.a2q_overflows.to_string(),
            ]
        })
        .collect();
    write_csv(&out_dir.join("fig2.csv"), &header, &rows)?;
    write_markdown(
        &out_dir.join("fig2.md"),
        &format!(
            "Fig. 2 — overflow impact on the 1-layer binary-MNIST QNN (32-bit acc reference accuracy {:.4})",
            report.acc_wide
        ),
        &header,
        &rows,
    )?;
    Ok(())
}
