//! Fig. 2 / Appendix A: the impact of overflow on the 1-layer binary-MNIST
//! QNN (K = 784, M = 8, N = 1, data-type bound P = 19).
//!
//! Pipeline (all from Rust against the AOT artifacts):
//! 1. train the `mlp` with baseline QAT (32-bit assumption);
//! 2. export its integer weights; for each P below the bound, run *bit-exact*
//!    integer inference over the test set under wraparound and saturating
//!    accumulators ([`crate::accsim`]), recording overflow rate, MAE on the
//!    logits vs the wide register, and top-1 accuracy;
//! 3. re-train the same model from the same seed with A2Q at each target P
//!    and record its accuracy (overflow-free by construction — asserted).
//!
//! The training-backed pipeline ([`run`]) is generic over the
//! [`TrainBackend`], so the default build regenerates it through the native
//! pure-Rust trainer (the PJRT engine serves it under the `xla` feature).
//! The **network variant** ([`run_network`] / [`emit_network`]) needs no
//! training at all: it forwards a whole [`QNetwork`] under every width in
//! one fused [`NetworkPlan`] pass and reports overflow rate *per layer
//! depth* — the axis the single-layer figure cannot show, and where
//! accumulator constraints visibly compound through inter-layer
//! requantization.

use std::path::Path;

use anyhow::Result;

use crate::accsim::matmul::quantize_inputs;
use crate::accsim::{qlinear_forward, qlinear_forward_multi};
use crate::accsim::{AccMode, IntMatrix, NetworkPlan};
use crate::config::RunConfig;
use crate::coordinator::Trainer;
use crate::datasets::Split;
use crate::metrics;
use crate::model::QNetwork;
use crate::runtime::TrainBackend;
use crate::tensor::Tensor;

use super::render::{f, write_csv, write_markdown};

/// One row of the figure: behaviour of each scheme at accumulator width P.
#[derive(Clone, Debug)]
pub struct Fig2Row {
    pub p_bits: u32,
    pub overflow_rate_wrap: f64,
    pub mae_wrap: f64,
    pub acc_wrap: f64,
    pub mae_sat: f64,
    pub acc_sat: f64,
    pub acc_a2q: f64,
    pub a2q_overflows: u64,
}

pub struct Fig2Report {
    pub acc_wide: f64,
    pub rows: Vec<Fig2Row>,
}

/// Run the experiment. `p_values` defaults to 10..=20 (the paper sweeps
/// below the 19-bit bound); `steps` sizes each training run.
pub fn run<B: TrainBackend + ?Sized>(
    backend: &B,
    p_values: &[u32],
    steps: u64,
    eval_samples: usize,
    seed: u64,
) -> Result<Fig2Report> {
    // --- 1. baseline QAT training (accumulator-oblivious) -------------------
    let mut qat_cfg = RunConfig::new("mlp", "qat", 8, 1, 32, steps);
    qat_cfg.seed = seed;
    let trainer = Trainer::new(backend, &qat_cfg)?;
    let qat = trainer.run(&qat_cfg)?;
    let layer = qat.exported.as_ref().unwrap()[0].to_qtensor();

    // Integer test inputs: binary pixels are exactly the 1-bit codes.
    let n_eval = eval_samples.min(trainer.dataset.len(Split::Test));
    let idx: Vec<usize> = (0..n_eval).collect();
    let batch = trainer.dataset.gather(Split::Test, &idx);
    let x_int = quantize_inputs(&batch.x, 1.0, 1, false);
    let labels = batch.y.data();

    // --- 2. simulate P-bit deployment of the QAT model -----------------------
    // One fused pass over the MACs simulates the wide reference AND
    // wraparound AND saturation at every requested width (the old code
    // re-walked the weights once for wide plus 2x per P).
    let modes: Vec<AccMode> = std::iter::once(AccMode::Wide)
        .chain(p_values.iter().flat_map(|&p| {
            [AccMode::Wrap { p_bits: p }, AccMode::Saturate { p_bits: p }]
        }))
        .collect();
    let sims = qlinear_forward_multi(&x_int, 1.0, &layer, &modes);

    let (c, n) = metrics::top1_accuracy(&sims[0].out, labels, n_eval);
    let acc_wide = c as f64 / n as f64;

    let mut rows = Vec::new();
    for (pi, &p) in p_values.iter().enumerate() {
        let wrap = &sims[1 + 2 * pi];
        let sat = &sims[2 + 2 * pi];
        let (cw, _) = metrics::top1_accuracy(&wrap.out, labels, n_eval);
        let (cs, _) = metrics::top1_accuracy(&sat.out, labels, n_eval);

        // --- 3. A2Q re-trained at target P, same seed ------------------------
        let mut a2q_cfg = RunConfig::new("mlp", "a2q", 8, 1, p, steps);
        a2q_cfg.seed = seed;
        let a2q = trainer.run(&a2q_cfg)?;
        anyhow::ensure!(a2q.guarantee_ok, "A2Q Eq. 15 audit failed at P={p}");
        let a2q_layer = a2q.exported.as_ref().unwrap()[0].to_qtensor();
        let a2q_sim = qlinear_forward(&x_int, 1.0, &a2q_layer, AccMode::Wrap { p_bits: p });
        // The theorem in action: wraparound at P bits must be a no-op.
        anyhow::ensure!(
            a2q_sim.stats.overflow_events == 0,
            "A2Q overflowed at P={p}: {} events",
            a2q_sim.stats.overflow_events
        );
        let (ca, _) = metrics::top1_accuracy(&a2q_sim.out, labels, n_eval);

        rows.push(Fig2Row {
            p_bits: p,
            overflow_rate_wrap: wrap.stats.overflow_rate(),
            mae_wrap: metrics::logit_mae(&wrap.out, &wrap.out_wide),
            acc_wrap: cw as f64 / n_eval as f64,
            mae_sat: metrics::logit_mae(&sat.out, &sat.out_wide),
            acc_sat: cs as f64 / n_eval as f64,
            acc_a2q: ca as f64 / n_eval as f64,
            a2q_overflows: a2q_sim.stats.overflow_events,
        });
    }
    Ok(Fig2Report { acc_wide, rows })
}

/// Emit `results/fig2.csv` + `results/fig2.md`.
pub fn emit(report: &Fig2Report, out_dir: &Path) -> Result<()> {
    let header = [
        "P",
        "overflow_rate",
        "mae_wrap",
        "acc_wrap",
        "mae_sat",
        "acc_sat",
        "acc_a2q",
        "a2q_overflow_events",
    ];
    let rows: Vec<Vec<String>> = report
        .rows
        .iter()
        .map(|r| {
            vec![
                r.p_bits.to_string(),
                f(r.overflow_rate_wrap, 4),
                f(r.mae_wrap, 4),
                f(r.acc_wrap, 4),
                f(r.mae_sat, 4),
                f(r.acc_sat, 4),
                f(r.acc_a2q, 4),
                r.a2q_overflows.to_string(),
            ]
        })
        .collect();
    write_csv(&out_dir.join("fig2.csv"), &header, &rows)?;
    write_markdown(
        &out_dir.join("fig2.md"),
        &format!(
            "Fig. 2 — overflow impact on the 1-layer binary-MNIST QNN (32-bit acc reference accuracy {:.4})",
            report.acc_wide
        ),
        &header,
        &rows,
    )?;
    Ok(())
}

/// One row of the network variant: behaviour of layer `layer` at width P
/// (network-level MAE/accuracy repeated on every layer row of that P).
#[derive(Clone, Debug)]
pub struct Fig2NetRow {
    pub p_bits: u32,
    pub layer: usize,
    /// MAC-level overflow rate of this layer under wraparound.
    pub overflow_rate_wrap: f64,
    /// Fraction of this layer's dot products that overflowed at least once.
    pub dot_frac_wrap: f64,
    /// MAC-level overflow rate under inner-loop saturation.
    pub overflow_rate_sat: f64,
    /// Network-level MAE of the wraparound final logits vs the *all-wide*
    /// forward (every layer wide), so corruption compounded through earlier
    /// layers is measured — not just the last layer's register error.
    pub mae_wrap: f64,
    /// Top-1 accuracy under wraparound (None without labels).
    pub acc_wrap: Option<f64>,
    pub acc_sat: Option<f64>,
}

/// The network variant of Fig. 2.
#[derive(Clone, Debug)]
pub struct Fig2NetReport {
    /// Wide-register top-1 accuracy (None without labels).
    pub acc_wide: Option<f64>,
    pub depth: usize,
    /// One row per (P, layer), P-major.
    pub rows: Vec<Fig2NetRow>,
}

/// XLA-free network variant: forward `x_int` through the whole network
/// under the wide reference plus wraparound and saturation at every width
/// in `p_values` — one fused [`NetworkPlan`] pass — and report per-layer
/// overflow alongside network-level error/accuracy. `threads` pins the
/// worker count (None = auto).
pub fn run_network(
    net: &QNetwork,
    x_int: &IntMatrix,
    labels: Option<&[f32]>,
    p_values: &[u32],
    threads: Option<usize>,
) -> Fig2NetReport {
    let modes: Vec<AccMode> = std::iter::once(AccMode::Wide)
        .chain(
            p_values
                .iter()
                .flat_map(|&p| [AccMode::Wrap { p_bits: p }, AccMode::Saturate { p_bits: p }]),
        )
        .collect();
    let plan = NetworkPlan::new(net, &modes);
    let sims = match threads {
        Some(t) => plan.execute_threads(x_int, t),
        None => plan.execute(x_int),
    };
    let n_eval = x_int.rows();
    let acc = |out: &Tensor| {
        labels.map(|l| {
            let (c, n) = metrics::top1_accuracy(out, l, n_eval);
            c as f64 / n.max(1) as f64
        })
    };
    let acc_wide = acc(&sims[0].out);
    let mut rows = Vec::with_capacity(p_values.len() * net.depth());
    for (pi, &p) in p_values.iter().enumerate() {
        let wrap = &sims[1 + 2 * pi];
        let sat = &sims[2 + 2 * pi];
        // Baseline = the all-wide forward (sims[0]), NOT wrap.out_wide: the
        // per-mode local wide shares wrap's corrupted upstream activations,
        // which would cancel exactly the compounding this figure exists to
        // show.
        let mae_wrap = metrics::logit_mae(&wrap.out, &sims[0].out);
        let acc_wrap = acc(&wrap.out);
        let acc_sat = acc(&sat.out);
        for layer in 0..net.depth() {
            rows.push(Fig2NetRow {
                p_bits: p,
                layer,
                overflow_rate_wrap: wrap.layer_stats[layer].overflow_rate(),
                dot_frac_wrap: wrap.layer_stats[layer].dot_overflow_fraction(),
                overflow_rate_sat: sat.layer_stats[layer].overflow_rate(),
                mae_wrap,
                acc_wrap,
                acc_sat,
            });
        }
    }
    Fig2NetReport { acc_wide, depth: net.depth(), rows }
}

/// Emit `results/fig2_network.csv` + `.md`.
pub fn emit_network(report: &Fig2NetReport, out_dir: &Path) -> Result<()> {
    let header = [
        "P",
        "layer",
        "overflow_rate_wrap",
        "dot_frac_wrap",
        "overflow_rate_sat",
        "mae_wrap",
        "acc_wrap",
        "acc_sat",
    ];
    let opt = |v: Option<f64>| v.map(|a| f(a, 4)).unwrap_or_else(|| "-".into());
    let rows: Vec<Vec<String>> = report
        .rows
        .iter()
        .map(|r| {
            vec![
                r.p_bits.to_string(),
                r.layer.to_string(),
                f(r.overflow_rate_wrap, 4),
                f(r.dot_frac_wrap, 4),
                f(r.overflow_rate_sat, 4),
                f(r.mae_wrap, 4),
                opt(r.acc_wrap),
                opt(r.acc_sat),
            ]
        })
        .collect();
    write_csv(&out_dir.join("fig2_network.csv"), &header, &rows)?;
    let acc = report.acc_wide.map(|a| format!("{a:.4}")).unwrap_or_else(|| "n/a".into());
    write_markdown(
        &out_dir.join("fig2_network.md"),
        &format!(
            "Fig. 2 (network variant) — per-layer overflow over a {}-layer QNetwork \
             (wide-register accuracy {acc})",
            report.depth
        ),
        &header,
        &rows,
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NetSpec, SynthQuant};

    #[test]
    fn network_variant_reports_per_layer_rows() {
        let spec = NetSpec {
            widths: vec![8, 6, 3],
            m_bits: 5,
            n_bits: 4,
            p_bits: 10,
            x_signed: false,
            quant: SynthQuant::Affine,
        };
        let mut net = QNetwork::synthesize(&spec, 4).unwrap();
        let sample =
            Tensor::new(vec![6, 8], (0..48).map(|i| (i % 5) as f32 * 0.21).collect());
        net.calibrate(&sample);
        let x = net.layers[0].in_quant.quantize(&sample);
        let labels = vec![0.0f32; 6];
        let rep = run_network(&net, &x, Some(&labels), &[6, 20], Some(2));
        assert_eq!(rep.depth, 2);
        assert_eq!(rep.rows.len(), 4); // 2 widths x 2 layers
        assert!(rep.acc_wide.is_some());
        // a 20-bit register is above this net's data-type bound: no overflow
        let wide_enough: Vec<_> = rep.rows.iter().filter(|r| r.p_bits == 20).collect();
        assert!(wide_enough.iter().all(|r| r.overflow_rate_wrap == 0.0));
        // without labels the accuracy columns are empty, not fabricated
        let unlabeled = run_network(&net, &x, None, &[6], None);
        assert!(unlabeled.acc_wide.is_none());
        assert!(unlabeled.rows.iter().all(|r| r.acc_wrap.is_none()));
        let dir = crate::testutil::TempDir::new().unwrap();
        emit_network(&rep, dir.path()).unwrap();
        assert!(dir.path().join("fig2_network.csv").exists());
    }
}
