//! Fig. 8 / Appendix A.1: breaking associativity. Randomly re-order the MACs
//! of the trained 1-layer model's dot products under inner-loop saturating
//! accumulation, and compare against modelling overflow only at the final
//! result (outer loop) — which is what prior work does and which misses the
//! intermediate partial sums entirely.

use std::path::Path;

use anyhow::Result;

use crate::accsim::dot::{dot_accumulate, AccMode};
use crate::accsim::matmul::quantize_inputs;
use crate::accsim::ReorderScratch;
use crate::config::RunConfig;
use crate::coordinator::Trainer;
use crate::datasets::Split;
use crate::metrics;
use crate::rng::Rng;
use crate::runtime::TrainBackend;
use crate::tensor::Tensor;

use super::render::{f, write_csv, write_markdown};

/// Distribution of MAE / accuracy across random MAC orderings.
#[derive(Clone, Debug)]
pub struct Fig8Report {
    pub p_bits: u32,
    pub n_perms: usize,
    /// Per-permutation (MAE on logits vs wide, top-1 accuracy): inner-loop
    /// saturation model.
    pub inner: Vec<(f64, f64)>,
    /// Outer-loop (final-only) model: order-invariant single point.
    pub outer_mae: f64,
    pub outer_acc: f64,
    /// Wide-register baseline accuracy.
    pub acc_wide: f64,
}

impl Fig8Report {
    pub fn inner_mae_mean(&self) -> f64 {
        self.inner.iter().map(|(m, _)| m).sum::<f64>() / self.inner.len().max(1) as f64
    }

    pub fn inner_acc_spread(&self) -> (f64, f64) {
        let lo = self.inner.iter().map(|(_, a)| *a).fold(f64::INFINITY, f64::min);
        let hi = self.inner.iter().map(|(_, a)| *a).fold(f64::NEG_INFINITY, f64::max);
        (lo, hi)
    }

    pub fn distinct_inner_maes(&self) -> usize {
        let mut v: Vec<u64> = self.inner.iter().map(|(m, _)| m.to_bits()).collect();
        v.sort_unstable();
        v.dedup();
        v.len()
    }
}

/// Train the mlp with baseline QAT, then run the re-ordering study at P.
pub fn run<B: TrainBackend + ?Sized>(
    backend: &B,
    p_bits: u32,
    n_perms: usize,
    steps: u64,
    eval_samples: usize,
    seed: u64,
) -> Result<Fig8Report> {
    let mut cfg = RunConfig::new("mlp", "qat", 8, 1, 32, steps);
    cfg.seed = seed;
    let trainer = Trainer::new(backend, &cfg)?;
    let outcome = trainer.run(&cfg)?;
    let layer = outcome.exported.as_ref().unwrap()[0].to_qtensor();

    let n_eval = eval_samples.min(trainer.dataset.len(Split::Test));
    let idx: Vec<usize> = (0..n_eval).collect();
    let batch = trainer.dataset.gather(Split::Test, &idx);
    let x_int = quantize_inputs(&batch.x, 1.0, 1, false);
    let labels = batch.y.data();
    let k = layer.k;

    // Reference logits under the wide register / outer-loop model (flat
    // IntMatrix rows, no permutation).
    let logits_plain = |mode: AccMode| -> Tensor {
        let mut out = Tensor::zeros(vec![n_eval, layer.c_out]);
        for (bi, xb) in x_int.iter_rows().enumerate() {
            for c in 0..layer.c_out {
                let value = dot_accumulate(xb, layer.row(c), mode).value;
                out.data_mut()[bi * layer.c_out + c] =
                    value as f32 * layer.scales[c] + layer.bias[c];
            }
        }
        out
    };

    let wide = logits_plain(AccMode::Wide);
    let (cw, nw) = metrics::top1_accuracy(&wide, labels, n_eval);
    let acc_wide = cw as f64 / nw as f64;

    let outer = logits_plain(AccMode::SaturateFinal { p_bits });
    let (co, _) = metrics::top1_accuracy(&outer, labels, n_eval);
    let outer_mae = metrics::logit_mae(&outer, &wide);
    let outer_acc = co as f64 / n_eval as f64;

    // Permutation study: one scratch serves every (permutation, sample,
    // channel) gather — no per-dot allocation.
    let mut rng = Rng::new(seed ^ 0xf18_8);
    let mut scratch = ReorderScratch::new();
    scratch.reset(k);
    let mut inner = Vec::with_capacity(n_perms);
    for _ in 0..n_perms {
        scratch.shuffle(&mut rng);
        let mut l = Tensor::zeros(vec![n_eval, layer.c_out]);
        for (bi, xb) in x_int.iter_rows().enumerate() {
            for c in 0..layer.c_out {
                let (xp, wp) = scratch.gathered(xb, layer.row(c));
                let value = dot_accumulate(xp, wp, AccMode::Saturate { p_bits }).value;
                l.data_mut()[bi * layer.c_out + c] =
                    value as f32 * layer.scales[c] + layer.bias[c];
            }
        }
        let (ci, _) = metrics::top1_accuracy(&l, labels, n_eval);
        inner.push((metrics::logit_mae(&l, &wide), ci as f64 / n_eval as f64));
    }

    Ok(Fig8Report { p_bits, n_perms, inner, outer_mae, outer_acc, acc_wide })
}

/// Emit `results/fig8.csv` (per-permutation) + `results/fig8.md` (summary).
pub fn emit(report: &Fig8Report, out_dir: &Path) -> Result<()> {
    let rows: Vec<Vec<String>> = report
        .inner
        .iter()
        .enumerate()
        .map(|(i, (mae, acc))| vec![i.to_string(), f(*mae, 5), f(*acc, 4)])
        .collect();
    write_csv(&out_dir.join("fig8.csv"), &["perm", "mae_inner", "acc_inner"], &rows)?;
    let (lo, hi) = report.inner_acc_spread();
    write_markdown(
        &out_dir.join("fig8.md"),
        &format!("Fig. 8 — re-ordering under saturation at P = {}", report.p_bits),
        &["quantity", "value"],
        &[
            vec!["wide-register accuracy".into(), f(report.acc_wide, 4)],
            vec!["outer-loop (final-only) MAE".into(), f(report.outer_mae, 5)],
            vec!["outer-loop accuracy".into(), f(report.outer_acc, 4)],
            vec!["inner-loop MAE mean".into(), f(report.inner_mae_mean(), 5)],
            vec!["inner-loop acc min".into(), f(lo, 4)],
            vec!["inner-loop acc max".into(), f(hi, 4)],
            vec![
                "distinct inner MAEs".into(),
                report.distinct_inner_maes().to_string(),
            ],
        ],
    )?;
    Ok(())
}
