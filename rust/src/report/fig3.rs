//! Fig. 3: accumulator bit-width bounds — data-type bound (Eq. 8) versus the
//! weight-norm bound (Eq. 12) over dot-product size K and data bit width,
//! with the weight bound sampled over 1000 discrete-Gaussian weight draws
//! (median / min / max), exactly as the paper's plot.

use std::path::Path;

use anyhow::Result;

use crate::model::QNetwork;
use crate::quant::bounds::{
    data_type_bound, data_type_bound_exact, weight_bound, weight_bound_exact, DotShape,
};
use crate::rng::Rng;

use super::render::{f, write_csv, write_markdown};

#[derive(Clone, Debug)]
pub struct Fig3Row {
    pub k: usize,
    pub bits: u32, // M = N ("data bit width")
    pub data_type_bound: f64,
    pub weight_bound_median: f64,
    pub weight_bound_min: f64,
    pub weight_bound_max: f64,
}

/// Sample one K-dim weight vector from a discrete Gaussian quantized to
/// signed M bits (the paper's sampling) and return its l1 norm.
fn sample_l1(rng: &mut Rng, k: usize, m_bits: u32) -> f64 {
    let max = 2f64.powi(m_bits as i32 - 1) - 1.0;
    let sigma = max / 3.0; // 3-sigma fills the code range
    let mut l1 = 0.0;
    for _ in 0..k {
        let w = (rng.normal() * sigma).round().clamp(-max - 1.0, max);
        l1 += w.abs();
    }
    l1
}

/// Compute the figure across `ks` x `bit_values` (x is unsigned, as plotted).
pub fn run(ks: &[usize], bit_values: &[u32], n_draws: usize, seed: u64) -> Vec<Fig3Row> {
    let mut rng = Rng::new(seed);
    let mut rows = Vec::new();
    for &bits in bit_values {
        for &k in ks {
            let dt = data_type_bound_exact(DotShape {
                k,
                m_bits: bits,
                n_bits: bits,
                x_signed: false,
            });
            let mut wbs: Vec<f64> = (0..n_draws)
                .map(|_| {
                    let l1 = sample_l1(&mut rng, k, bits);
                    weight_bound_exact(l1, bits, false)
                })
                .collect();
            wbs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            rows.push(Fig3Row {
                k,
                bits,
                data_type_bound: dt,
                weight_bound_median: wbs[wbs.len() / 2],
                weight_bound_min: wbs[0],
                weight_bound_max: *wbs.last().unwrap(),
            });
        }
    }
    rows
}

/// One layer of the network variant: the bound comparison taken down the
/// *depth* of an actual [`QNetwork`] — each layer's data-type bound against
/// the weight-norm bound its real (synthesized or exported) integer weights
/// achieve, plus the weight sparsity at that depth (paper §5.2.1).
#[derive(Clone, Debug)]
pub struct Fig3NetRow {
    pub layer: usize,
    pub name: String,
    pub k: usize,
    pub m_bits: u32,
    pub n_bits: u32,
    pub x_signed: bool,
    /// Max per-channel integer-weight l1 norm.
    pub l1_max: f64,
    /// Data-type lower bound on P (Eq. 8).
    pub data_type_bound: u32,
    /// Weight-norm lower bound on P from the actual l1 (Eq. 12), never
    /// reported looser than the data-type bound.
    pub weight_bound: u32,
    pub sparsity: f64,
}

/// Network variant: per-layer bounds and sparsity by depth.
pub fn run_network(net: &QNetwork) -> Vec<Fig3NetRow> {
    net.layers
        .iter()
        .enumerate()
        .map(|(layer, l)| {
            let shape = DotShape {
                k: l.weights.k,
                m_bits: l.m_bits,
                n_bits: l.in_quant.n_bits,
                x_signed: l.in_quant.signed,
            };
            let dt = data_type_bound(shape);
            let l1_max = l.weights.max_l1() as f64;
            let wb = weight_bound(l1_max, l.in_quant.n_bits, l.in_quant.signed);
            Fig3NetRow {
                layer,
                name: l.name.clone(),
                k: l.weights.k,
                m_bits: l.m_bits,
                n_bits: l.in_quant.n_bits,
                x_signed: l.in_quant.signed,
                l1_max,
                data_type_bound: dt,
                weight_bound: wb.min(dt),
                sparsity: l.weights.sparsity(),
            }
        })
        .collect()
}

/// Emit `results/fig3_network.csv` + `.md`.
pub fn emit_network(rows: &[Fig3NetRow], out_dir: &Path) -> Result<()> {
    let header =
        ["layer", "name", "K", "M", "N", "x_signed", "l1_max", "dt_bound", "wn_bound", "sparsity"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.layer.to_string(),
                r.name.clone(),
                r.k.to_string(),
                r.m_bits.to_string(),
                r.n_bits.to_string(),
                r.x_signed.to_string(),
                f(r.l1_max, 1),
                r.data_type_bound.to_string(),
                r.weight_bound.to_string(),
                f(r.sparsity, 4),
            ]
        })
        .collect();
    write_csv(&out_dir.join("fig3_network.csv"), &header, &table)?;
    write_markdown(
        &out_dir.join("fig3_network.md"),
        "Fig. 3 (network variant) — per-layer accumulator bounds and sparsity by depth",
        &header,
        &table,
    )?;
    Ok(())
}

/// Emit `results/fig3.csv` + `.md`.
pub fn emit(rows: &[Fig3Row], out_dir: &Path) -> Result<()> {
    let header = ["K", "data_bits", "data_type_bound", "wb_median", "wb_min", "wb_max"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.k.to_string(),
                r.bits.to_string(),
                f(r.data_type_bound, 3),
                f(r.weight_bound_median, 3),
                f(r.weight_bound_min, 3),
                f(r.weight_bound_max, 3),
            ]
        })
        .collect();
    write_csv(&out_dir.join("fig3.csv"), &header, &table)?;
    write_markdown(
        &out_dir.join("fig3.md"),
        "Fig. 3 — accumulator bound comparison (1000 discrete-Gaussian draws)",
        &header,
        &table,
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_bound_tighter_than_data_type_bound() {
        let rows = run(&[64, 256, 1024], &[4, 8], 200, 0);
        for r in &rows {
            assert!(
                r.weight_bound_max <= r.data_type_bound + 1e-9,
                "K={} bits={}: wb_max {} > dt {}",
                r.k,
                r.bits,
                r.weight_bound_max,
                r.data_type_bound
            );
            assert!(r.weight_bound_min <= r.weight_bound_median);
            assert!(r.weight_bound_median <= r.weight_bound_max);
        }
    }

    #[test]
    fn network_variant_bounds_are_consistent() {
        use crate::model::{NetSpec, QNetwork, SynthQuant};
        let spec = NetSpec {
            widths: vec![32, 16, 8],
            m_bits: 4,
            n_bits: 3,
            p_bits: 10,
            x_signed: false,
            quant: SynthQuant::A2q,
        };
        let net = QNetwork::synthesize(&spec, 7).unwrap();
        let rows = run_network(&net);
        assert_eq!(rows.len(), 2);
        for (li, r) in rows.iter().enumerate() {
            assert_eq!(r.layer, li);
            assert!(r.weight_bound <= r.data_type_bound, "{}", r.name);
            // A2Q-constrained weights: the weight-norm bound certifies the
            // synthesis target (or better).
            assert!(r.weight_bound <= 10, "{} bound {}", r.name, r.weight_bound);
            assert!((0.0..=1.0).contains(&r.sparsity));
        }
        // hidden boundary is signed, input unsigned
        assert!(!rows[0].x_signed);
        assert!(rows[1].x_signed);
    }

    #[test]
    fn bounds_grow_with_k() {
        let rows = run(&[32, 1024], &[6], 50, 1);
        assert!(rows[1].data_type_bound > rows[0].data_type_bound);
        assert!(rows[1].weight_bound_median > rows[0].weight_bound_median);
    }
}
