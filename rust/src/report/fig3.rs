//! Fig. 3: accumulator bit-width bounds — data-type bound (Eq. 8) versus the
//! weight-norm bound (Eq. 12) over dot-product size K and data bit width,
//! with the weight bound sampled over 1000 discrete-Gaussian weight draws
//! (median / min / max), exactly as the paper's plot.

use std::path::Path;

use anyhow::Result;

use crate::quant::bounds::{data_type_bound_exact, weight_bound_exact, DotShape};
use crate::rng::Rng;

use super::render::{f, write_csv, write_markdown};

#[derive(Clone, Debug)]
pub struct Fig3Row {
    pub k: usize,
    pub bits: u32, // M = N ("data bit width")
    pub data_type_bound: f64,
    pub weight_bound_median: f64,
    pub weight_bound_min: f64,
    pub weight_bound_max: f64,
}

/// Sample one K-dim weight vector from a discrete Gaussian quantized to
/// signed M bits (the paper's sampling) and return its l1 norm.
fn sample_l1(rng: &mut Rng, k: usize, m_bits: u32) -> f64 {
    let max = 2f64.powi(m_bits as i32 - 1) - 1.0;
    let sigma = max / 3.0; // 3-sigma fills the code range
    let mut l1 = 0.0;
    for _ in 0..k {
        let w = (rng.normal() * sigma).round().clamp(-max - 1.0, max);
        l1 += w.abs();
    }
    l1
}

/// Compute the figure across `ks` x `bit_values` (x is unsigned, as plotted).
pub fn run(ks: &[usize], bit_values: &[u32], n_draws: usize, seed: u64) -> Vec<Fig3Row> {
    let mut rng = Rng::new(seed);
    let mut rows = Vec::new();
    for &bits in bit_values {
        for &k in ks {
            let dt = data_type_bound_exact(DotShape {
                k,
                m_bits: bits,
                n_bits: bits,
                x_signed: false,
            });
            let mut wbs: Vec<f64> = (0..n_draws)
                .map(|_| {
                    let l1 = sample_l1(&mut rng, k, bits);
                    weight_bound_exact(l1, bits, false)
                })
                .collect();
            wbs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            rows.push(Fig3Row {
                k,
                bits,
                data_type_bound: dt,
                weight_bound_median: wbs[wbs.len() / 2],
                weight_bound_min: wbs[0],
                weight_bound_max: *wbs.last().unwrap(),
            });
        }
    }
    rows
}

/// Emit `results/fig3.csv` + `.md`.
pub fn emit(rows: &[Fig3Row], out_dir: &Path) -> Result<()> {
    let header = ["K", "data_bits", "data_type_bound", "wb_median", "wb_min", "wb_max"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.k.to_string(),
                r.bits.to_string(),
                f(r.data_type_bound, 3),
                f(r.weight_bound_median, 3),
                f(r.weight_bound_min, 3),
                f(r.weight_bound_max, 3),
            ]
        })
        .collect();
    write_csv(&out_dir.join("fig3.csv"), &header, &table)?;
    write_markdown(
        &out_dir.join("fig3.md"),
        "Fig. 3 — accumulator bound comparison (1000 discrete-Gaussian draws)",
        &header,
        &table,
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_bound_tighter_than_data_type_bound() {
        let rows = run(&[64, 256, 1024], &[4, 8], 200, 0);
        for r in &rows {
            assert!(
                r.weight_bound_max <= r.data_type_bound + 1e-9,
                "K={} bits={}: wb_max {} > dt {}",
                r.k,
                r.bits,
                r.weight_bound_max,
                r.data_type_bound
            );
            assert!(r.weight_bound_min <= r.weight_bound_median);
            assert!(r.weight_bound_median <= r.weight_bound_max);
        }
    }

    #[test]
    fn bounds_grow_with_k() {
        let rows = run(&[32, 1024], &[6], 50, 1);
        assert!(rows[1].data_type_bound > rows[0].data_type_bound);
        assert!(rows[1].weight_bound_median > rows[0].weight_bound_median);
    }
}
