//! Metrics sink: append-only JSONL of run records, with resume support.

use std::io::{BufRead, Write};
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::RunConfig;
use crate::json::Json;

/// The durable record of one grid-search run.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub config: RunConfig,
    /// Final-eval task performance (accuracy in [0,1] or PSNR dB).
    pub perf: f64,
    /// Exported-weight unstructured sparsity over constrained layers.
    pub sparsity: f64,
    /// Per-layer max per-channel integer l1 norms.
    pub l1_norms: Vec<f64>,
    /// Eq. 15 audit result.
    pub guarantee_ok: bool,
    pub final_loss: f64,
    pub first_loss: f64,
    pub train_secs: f64,
}

impl RunRecord {
    pub fn from_outcome(o: &super::trainer::TrainOutcome) -> Self {
        RunRecord {
            config: o.config.clone(),
            perf: o.perf,
            sparsity: o.sparsity,
            l1_norms: o.l1_norms.clone(),
            guarantee_ok: o.guarantee_ok,
            final_loss: o.loss_history.last().map(|(_, l)| *l).unwrap_or(f64::NAN),
            first_loss: o.loss_history.first().map(|(_, l)| *l).unwrap_or(f64::NAN),
            train_secs: o.train_secs,
        }
    }

    /// Identity key for resume (config uniquely identifies a run).
    pub fn key(cfg: &RunConfig) -> String {
        format!(
            "{}|{}|{}|{}|{}|{}|{}",
            cfg.model, cfg.alg, cfg.m, cfg.n, cfg.p, cfg.steps, cfg.seed
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("config", self.config.to_json()),
            ("perf", Json::num(self.perf)),
            ("sparsity", Json::num(self.sparsity)),
            ("l1_norms", Json::from_f64s(&self.l1_norms)),
            ("guarantee_ok", Json::Bool(self.guarantee_ok)),
            ("final_loss", Json::num(self.final_loss)),
            ("first_loss", Json::num(self.first_loss)),
            ("train_secs", Json::num(self.train_secs)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(RunRecord {
            config: RunConfig::from_json(v.get("config")?)?,
            perf: v.get("perf")?.as_f64()?,
            sparsity: v.get("sparsity")?.as_f64()?,
            l1_norms: v.get("l1_norms")?.as_f64_vec()?,
            guarantee_ok: v.get("guarantee_ok")?.as_bool()?,
            final_loss: v.get("final_loss")?.as_f64()?,
            first_loss: v.get("first_loss")?.as_f64()?,
            train_secs: v.get("train_secs")?.as_f64()?,
        })
    }
}

/// Append-only JSONL sink.
pub struct MetricsSink {
    path: PathBuf,
}

impl MetricsSink {
    pub fn new(path: impl AsRef<Path>) -> Self {
        MetricsSink { path: path.as_ref().to_path_buf() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record (creates parent dirs / file on first use).
    pub fn append(&self, record: &RunRecord) -> Result<()> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{}", record.to_json().to_string())?;
        Ok(())
    }

    /// Load every record currently on disk (empty if the file is absent).
    pub fn load(&self) -> Result<Vec<RunRecord>> {
        let file = match std::fs::File::open(&self.path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(e.into()),
        };
        let mut out = Vec::new();
        for (i, line) in std::io::BufReader::new(file).lines().enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let rec = RunRecord::from_json(&Json::parse(&line)?)
                .map_err(|e| anyhow::anyhow!("{:?} line {}: {e}", self.path, i + 1))?;
            out.push(rec);
        }
        Ok(out)
    }

    /// Keys of configs already completed (for resume).
    pub fn completed_keys(&self) -> Result<std::collections::HashSet<String>> {
        Ok(self
            .load()?
            .iter()
            .map(|r| RunRecord::key(&r.config))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    fn record(p: u32) -> RunRecord {
        RunRecord {
            config: RunConfig::new("mlp", "a2q", 8, 8, p, 10),
            perf: 0.9,
            sparsity: 0.5,
            l1_norms: vec![12.0],
            guarantee_ok: true,
            final_loss: 0.1,
            first_loss: 0.7,
            train_secs: 1.0,
        }
    }

    #[test]
    fn append_and_load_round_trip() {
        let dir = TempDir::new().unwrap();
        let sink = MetricsSink::new(dir.path().join("runs.jsonl"));
        sink.append(&record(16)).unwrap();
        sink.append(&record(12)).unwrap();
        let recs = sink.load().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].config.p, 12);
        assert_eq!(recs[0].l1_norms, vec![12.0]);
    }

    #[test]
    fn missing_file_is_empty() {
        let dir = TempDir::new().unwrap();
        let sink = MetricsSink::new(dir.path().join("nope.jsonl"));
        assert!(sink.load().unwrap().is_empty());
        assert!(sink.completed_keys().unwrap().is_empty());
    }

    #[test]
    fn resume_keys() {
        let dir = TempDir::new().unwrap();
        let sink = MetricsSink::new(dir.path().join("runs.jsonl"));
        sink.append(&record(16)).unwrap();
        let keys = sink.completed_keys().unwrap();
        assert!(keys.contains(&RunRecord::key(&record(16).config)));
        assert!(!keys.contains(&RunRecord::key(&record(12).config)));
    }
}
