//! Grid-search scheduler: a pool of backend worker threads plus a streaming
//! result channel.
//!
//! PJRT handles are not `Send`, so each worker thread *constructs* its
//! backend from a [`BackendKind`] (which is `Send + Copy`) and pulls jobs
//! from an atomic-counter queue; the native backend rides the same protocol,
//! so one scheduler serves both. Native sweeps fan out over
//! [`sweep_workers`] threads (each job is deterministic given its config,
//! so any worker count produces the identical record set); PJRT stays
//! pinned to a single worker, which also preserves its per-model compiled-
//! executable cache. Results stream out to the JSONL sink as they finish,
//! and configs already completed on disk are skipped (resume).
//!
//! A job that panics is caught per-job ([`std::panic::catch_unwind`]) and
//! surfaces as an error naming the failing config, not a bare "worker
//! panicked".

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use anyhow::Result;

use crate::config::{RunConfig, SweepConfig};
use crate::runtime::{make_backend, BackendKind, NativeBackend, TrainBackend};

use super::sink::{MetricsSink, RunRecord};
use super::trainer::Trainer;

/// Expand a sweep against the manifests the chosen backend would resolve
/// (needs K* per model; native-registry models need no artifacts on disk).
pub fn expand_sweep(
    cfg: &SweepConfig,
    kind: BackendKind,
    artifacts_dir: &Path,
) -> Result<Vec<RunConfig>> {
    let mut runs = Vec::new();
    for model in &cfg.models {
        let manifest = kind.load_manifest(artifacts_dir, model)?;
        runs.extend(cfg.expand_for_model(model, manifest.largest_k));
    }
    Ok(runs)
}

/// Default worker-pool size for a sweep of `jobs` configs: native jobs fan
/// out to the hardware (override with `A2Q_SWEEP_WORKERS`); PJRT is pinned
/// to one worker (its handles are not `Send`, and one worker keeps the
/// compiled-executable cache warm).
pub fn sweep_workers(kind: BackendKind, jobs: usize) -> usize {
    let cap = match kind {
        BackendKind::Pjrt => 1,
        BackendKind::Native => crate::linalg::env_threads("A2Q_SWEEP_WORKERS")
            .unwrap_or_else(crate::linalg::hardware_workers),
    };
    cap.min(jobs).max(1)
}

fn job_label(rc: &RunConfig) -> String {
    format!("{} {} M={} N={} P={} seed={}", rc.model, rc.alg, rc.m, rc.n, rc.p, rc.seed)
}

fn panic_msg(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Run one job, converting a panic into an error that names the config.
fn run_job(backend: &dyn TrainBackend, rc: &RunConfig) -> Result<RunRecord> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let trainer = Trainer::new(backend, rc)?;
        let outcome = trainer.run(rc)?;
        Ok(RunRecord::from_outcome(&outcome))
    }))
    .unwrap_or_else(|payload| {
        Err(anyhow::anyhow!(
            "sweep worker panicked on config [{}]: {}",
            job_label(rc),
            panic_msg(payload)
        ))
    })
    .map_err(|e: anyhow::Error| e.context(format!("sweep job [{}] failed", job_label(rc))))
}

/// Run every config in the sweep over an explicit worker-pool size,
/// appending records to `sink_path` as they complete. Returns all records
/// (existing + new) at the end. `workers` is clamped to 1 for PJRT; any
/// native worker count yields the identical record set (each job is
/// deterministic given its config, and the native backend itself is
/// bit-identical at any thread count).
pub fn run_sweep_with_workers(
    cfg: SweepConfig,
    kind: BackendKind,
    artifacts_dir: PathBuf,
    sink_path: PathBuf,
    verbose: bool,
    workers: usize,
) -> Result<Vec<RunRecord>> {
    let sink = MetricsSink::new(&sink_path);
    let done = sink.completed_keys()?;
    let all = expand_sweep(&cfg, kind, &artifacts_dir)?;
    let todo: Vec<RunConfig> = all
        .into_iter()
        .filter(|r| !done.contains(&RunRecord::key(r)))
        .collect();
    let total = todo.len();
    let workers = match kind {
        BackendKind::Pjrt => 1,
        BackendKind::Native => workers.max(1).min(total.max(1)),
    };
    if verbose {
        println!(
            "[sweep] {} configs to run on {} {:?} worker(s) ({} already complete in {:?})",
            total,
            workers,
            kind,
            done.len(),
            sink_path
        );
    }

    let (tx, rx) = mpsc::channel::<Result<RunRecord>>();
    let next = AtomicUsize::new(0);
    let mut finished = 0usize;
    let mut first_err: Option<anyhow::Error> = None;

    {
        let todo = &todo;
        let next = &next;
        let artifacts_dir = &artifacts_dir;
        std::thread::scope(|s| {
            for _ in 0..workers {
                let tx = tx.clone();
                s.spawn(move || {
                    // Each worker owns its backend. When the pool is wider
                    // than one, native backends pin their internal GEMM
                    // fan-out to one thread — the parallelism budget is
                    // spent across jobs, not inside each one.
                    let backend: Box<dyn TrainBackend> = match kind {
                        BackendKind::Native if workers > 1 => {
                            Box::new(NativeBackend::new(artifacts_dir).with_threads(1))
                        }
                        _ => match make_backend(kind, artifacts_dir) {
                            Ok(b) => b,
                            Err(e) => {
                                let _ = tx.send(Err(e));
                                return;
                            }
                        },
                    };
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= todo.len() {
                            break;
                        }
                        if tx.send(run_job(backend.as_ref(), &todo[i])).is_err() {
                            break; // scheduler gone
                        }
                    }
                });
            }
            drop(tx);
            for result in rx {
                match result {
                    Ok(record) => {
                        if let Err(e) = sink.append(&record) {
                            first_err = Some(e);
                            break;
                        }
                        finished += 1;
                        if verbose {
                            println!(
                                "[sweep] {}/{} {} {} M={} N={} P={} -> perf {:.4} sparsity {:.3} ({:.1}s)",
                                finished,
                                total,
                                record.config.model,
                                record.config.alg,
                                record.config.m,
                                record.config.n,
                                record.config.p,
                                record.perf,
                                record.sparsity,
                                record.train_secs,
                            );
                        }
                    }
                    Err(e) => {
                        first_err = Some(e);
                        break;
                    }
                }
            }
            // Dropping the receiver makes the workers' next send fail, so
            // they drain out and the scope join returns promptly.
        });
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    sink.load()
}

/// Run every config in the sweep with the default worker-pool size
/// ([`sweep_workers`]), appending records to `sink_path` as they complete.
pub fn run_sweep(
    cfg: SweepConfig,
    kind: BackendKind,
    artifacts_dir: PathBuf,
    sink_path: PathBuf,
    verbose: bool,
) -> Result<Vec<RunRecord>> {
    // Size the pool from the *expanded* job count so one-job sweeps stay
    // inline; the heavy expansion is re-done inside (it is cheap — manifest
    // resolution only).
    let jobs = expand_sweep(&cfg, kind, &artifacts_dir)?.len();
    let workers = sweep_workers(kind, jobs);
    run_sweep_with_workers(cfg, kind, artifacts_dir, sink_path, verbose, workers)
}

/// Synchronous single-run helper used by the CLI `train` command and tests.
pub fn run_single(kind: BackendKind, artifacts_dir: &Path, rc: &RunConfig) -> Result<RunRecord> {
    let backend = make_backend(kind, artifacts_dir)?;
    let trainer = Trainer::new(backend.as_ref(), rc)?;
    let outcome = trainer.run(rc)?;
    Ok(RunRecord::from_outcome(&outcome))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ExportedLayer, ModelManifest, TrainState};
    use crate::tensor::Tensor;
    use crate::testutil::TempDir;

    #[test]
    fn native_sweep_runs_and_resumes_without_artifacts() {
        let dir = TempDir::new().unwrap();
        let mut cfg = SweepConfig::default_grid(vec!["mlp".into()], 6);
        cfg.mn_values = vec![8];
        cfg.p_offsets = vec![8];
        cfg.algs = vec!["a2q".into()];
        cfg.n_train = 96;
        cfg.n_test = 32;
        let sink = dir.path().join("runs.jsonl");
        let recs = run_sweep(
            cfg.clone(),
            BackendKind::Native,
            dir.path().to_path_buf(),
            sink.clone(),
            false,
        )
        .unwrap();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].guarantee_ok, "native sweep must keep the guarantee");
        // resume: nothing left to do, records preserved
        let again =
            run_sweep(cfg, BackendKind::Native, dir.path().to_path_buf(), sink, false).unwrap();
        assert_eq!(again.len(), 1);
    }

    #[test]
    fn multi_worker_sweep_matches_the_single_worker_scheduler() {
        let dir = TempDir::new().unwrap();
        let mut cfg = SweepConfig::default_grid(vec!["mlp".into(), "mlp3".into()], 4);
        cfg.mn_values = vec![6];
        cfg.p_offsets = vec![0, 4];
        cfg.algs = vec!["a2q".into(), "qat".into()];
        cfg.n_train = 96;
        cfg.n_test = 32;
        let run = |workers: usize, sink: &str| {
            run_sweep_with_workers(
                cfg.clone(),
                BackendKind::Native,
                dir.path().to_path_buf(),
                dir.path().join(sink),
                false,
                workers,
            )
            .unwrap()
        };
        let mut one = run(1, "one.jsonl");
        let mut many = run(3, "many.jsonl");
        assert!(one.len() > 2, "expected a multi-job sweep, got {}", one.len());
        let key = |r: &RunRecord| RunRecord::key(&r.config);
        one.sort_by_key(key);
        many.sort_by_key(key);
        assert_eq!(one.len(), many.len());
        for (a, b) in one.iter().zip(&many) {
            assert_eq!(key(a), key(b));
            assert_eq!(a.perf, b.perf, "{}", key(a));
            assert_eq!(a.sparsity, b.sparsity, "{}", key(a));
            assert_eq!(a.l1_norms, b.l1_norms, "{}", key(a));
            assert_eq!(a.guarantee_ok, b.guarantee_ok, "{}", key(a));
            assert_eq!(a.final_loss, b.final_loss, "{}", key(a));
        }
    }

    #[test]
    fn job_errors_name_the_failing_config() {
        let dir = TempDir::new().unwrap();
        // steps = 0 fails RunConfig validation inside the job
        let cfg = SweepConfig::default_grid(vec!["mlp".into()], 0);
        let err = run_sweep(
            cfg,
            BackendKind::Native,
            dir.path().to_path_buf(),
            dir.path().join("runs.jsonl"),
            false,
        )
        .unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("sweep job [mlp"), "error must name the config: {text}");
    }

    /// A backend whose `init` panics: drives the catch_unwind path.
    struct PanickyBackend;

    impl TrainBackend for PanickyBackend {
        fn name(&self) -> &'static str {
            "panicky"
        }
        fn manifest(&self, model: &str) -> Result<ModelManifest> {
            crate::runtime::native::native_manifest(model)
                .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))
        }
        fn init(&self, _m: &ModelManifest, _seed: f32) -> Result<TrainState> {
            panic!("synthetic backend panic");
        }
        fn train_step(
            &self,
            _m: &ModelManifest,
            _alg: &str,
            _state: &mut TrainState,
            _x: &Tensor,
            _y: &Tensor,
            _bits: (u32, u32, u32),
            _lr: f32,
        ) -> Result<f32> {
            unreachable!()
        }
        fn infer(
            &self,
            _m: &ModelManifest,
            _alg: &str,
            _state: &TrainState,
            _x: &Tensor,
            _bits: (u32, u32, u32),
        ) -> Result<Tensor> {
            unreachable!()
        }
        fn export(
            &self,
            _m: &ModelManifest,
            _alg: &str,
            _state: &TrainState,
            _bits: (u32, u32, u32),
        ) -> Result<Vec<ExportedLayer>> {
            unreachable!()
        }
    }

    #[test]
    fn a_panicking_job_surfaces_its_config_not_a_bare_panic() {
        let mut rc = RunConfig::new("mlp", "a2q", 8, 1, 12, 5);
        rc.n_train = 64;
        rc.n_test = 32;
        let err = run_job(&PanickyBackend, &rc).unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("panicked"), "{text}");
        assert!(text.contains("mlp a2q M=8 N=1 P=12"), "{text}");
        assert!(text.contains("synthetic backend panic"), "{text}");
    }

    #[test]
    fn run_single_native_mlp3() {
        let dir = TempDir::new().unwrap();
        let mut rc = RunConfig::new("mlp3", "a2q", 4, 4, 14, 10);
        rc.n_train = 96;
        rc.n_test = 32;
        let record = run_single(BackendKind::Native, dir.path(), &rc).unwrap();
        assert!(record.guarantee_ok);
        assert_eq!(record.l1_norms.len(), 3);
    }

    #[test]
    fn sweep_workers_pins_pjrt_and_caps_by_jobs() {
        assert_eq!(sweep_workers(BackendKind::Pjrt, 64), 1);
        assert_eq!(sweep_workers(BackendKind::Native, 1), 1);
        assert!(sweep_workers(BackendKind::Native, 64) >= 1);
    }
}
