//! Grid-search scheduler: a dedicated backend worker thread plus a
//! streaming result channel.
//!
//! PJRT handles are not `Send`, so the worker thread *constructs* its
//! backend from a [`BackendKind`] (which is `Send + Copy`) and executes
//! jobs sequentially; the native backend rides the same protocol so one
//! scheduler serves both. Results stream out to the JSONL sink as they
//! finish, and configs already completed on disk are skipped (resume).

use std::path::{Path, PathBuf};
use std::sync::mpsc;

use anyhow::Result;

use crate::config::{RunConfig, SweepConfig};
use crate::runtime::{make_backend, BackendKind};

use super::sink::{MetricsSink, RunRecord};
use super::trainer::Trainer;

/// Expand a sweep against the manifests the chosen backend would resolve
/// (needs K* per model; native-registry models need no artifacts on disk).
pub fn expand_sweep(
    cfg: &SweepConfig,
    kind: BackendKind,
    artifacts_dir: &Path,
) -> Result<Vec<RunConfig>> {
    let mut runs = Vec::new();
    for model in &cfg.models {
        let manifest = kind.load_manifest(artifacts_dir, model)?;
        runs.extend(cfg.expand_for_model(model, manifest.largest_k));
    }
    Ok(runs)
}

/// Run every config in the sweep, appending records to `sink_path` as they
/// complete. Returns all records (existing + new) at the end.
pub fn run_sweep(
    cfg: SweepConfig,
    kind: BackendKind,
    artifacts_dir: PathBuf,
    sink_path: PathBuf,
    verbose: bool,
) -> Result<Vec<RunRecord>> {
    let sink = MetricsSink::new(&sink_path);
    let done = sink.completed_keys()?;
    let all = expand_sweep(&cfg, kind, &artifacts_dir)?;
    let todo: Vec<RunConfig> = all
        .into_iter()
        .filter(|r| !done.contains(&RunRecord::key(r)))
        .collect();
    let total = todo.len();
    if verbose {
        println!(
            "[sweep] {} configs to run ({} already complete in {:?})",
            total,
            done.len(),
            sink_path
        );
    }

    let (tx, rx) = mpsc::channel::<Result<RunRecord>>();

    // Dedicated worker thread: owns the backend, runs jobs in order. The
    // PJRT engine caches compiled executables per model, so consecutive
    // configs of the same model reuse compilation; the native backend is
    // stateless between runs.
    let worker = std::thread::spawn(move || {
        let backend = match make_backend(kind, &artifacts_dir) {
            Ok(b) => b,
            Err(e) => {
                let _ = tx.send(Err(e));
                return;
            }
        };
        for rc in todo {
            let result = (|| {
                let trainer = Trainer::new(backend.as_ref(), &rc)?;
                let outcome = trainer.run(&rc)?;
                Ok(RunRecord::from_outcome(&outcome))
            })();
            if tx.send(result).is_err() {
                break; // scheduler gone
            }
        }
    });

    let mut finished = 0usize;
    for result in rx {
        let record = result?;
        sink.append(&record)?;
        finished += 1;
        if verbose {
            println!(
                "[sweep] {}/{} {} {} M={} N={} P={} -> perf {:.4} sparsity {:.3} ({:.1}s)",
                finished,
                total,
                record.config.model,
                record.config.alg,
                record.config.m,
                record.config.n,
                record.config.p,
                record.perf,
                record.sparsity,
                record.train_secs,
            );
        }
    }
    worker.join().map_err(|_| anyhow::anyhow!("sweep worker panicked"))?;
    sink.load()
}

/// Synchronous single-run helper used by the CLI `train` command and tests.
pub fn run_single(kind: BackendKind, artifacts_dir: &Path, rc: &RunConfig) -> Result<RunRecord> {
    let backend = make_backend(kind, artifacts_dir)?;
    let trainer = Trainer::new(backend.as_ref(), rc)?;
    let outcome = trainer.run(rc)?;
    Ok(RunRecord::from_outcome(&outcome))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    #[test]
    fn native_sweep_runs_and_resumes_without_artifacts() {
        let dir = TempDir::new().unwrap();
        let mut cfg = SweepConfig::default_grid(vec!["mlp".into()], 6);
        cfg.mn_values = vec![8];
        cfg.p_offsets = vec![8];
        cfg.algs = vec!["a2q".into()];
        cfg.n_train = 96;
        cfg.n_test = 32;
        let sink = dir.path().join("runs.jsonl");
        let recs = run_sweep(
            cfg.clone(),
            BackendKind::Native,
            dir.path().to_path_buf(),
            sink.clone(),
            false,
        )
        .unwrap();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].guarantee_ok, "native sweep must keep the guarantee");
        // resume: nothing left to do, records preserved
        let again =
            run_sweep(cfg, BackendKind::Native, dir.path().to_path_buf(), sink, false).unwrap();
        assert_eq!(again.len(), 1);
    }

    #[test]
    fn run_single_native_mlp3() {
        let dir = TempDir::new().unwrap();
        let mut rc = RunConfig::new("mlp3", "a2q", 4, 4, 14, 10);
        rc.n_train = 96;
        rc.n_test = 32;
        let record = run_single(BackendKind::Native, dir.path(), &rc).unwrap();
        assert!(record.guarantee_ok);
        assert_eq!(record.l1_norms.len(), 3);
    }
}
