//! Grid-search scheduler: a dedicated PJRT worker thread plus a streaming
//! result channel.
//!
//! PJRT handles are not `Send`, so one OS thread owns the
//! [`Engine`](crate::runtime::Engine) and executes jobs sequentially (XLA's
//! CPU backend parallelizes inside each executable); the scheduler streams
//! jobs in, streams [`RunRecord`]s out to the JSONL sink as they finish, and
//! skips configs already completed on disk (resume).

use std::path::{Path, PathBuf};
use std::sync::mpsc;

use anyhow::Result;

use crate::config::{RunConfig, SweepConfig};
use crate::runtime::{artifact::ModelManifest, Engine};

use super::sink::{MetricsSink, RunRecord};
use super::trainer::Trainer;

/// Expand a sweep against the manifests on disk (needs K* per model).
pub fn expand_sweep(cfg: &SweepConfig, artifacts_dir: &Path) -> Result<Vec<RunConfig>> {
    let mut runs = Vec::new();
    for model in &cfg.models {
        let manifest = ModelManifest::load(artifacts_dir, model)?;
        runs.extend(cfg.expand_for_model(model, manifest.largest_k));
    }
    Ok(runs)
}

/// Run every config in the sweep, appending records to `sink_path` as they
/// complete. Returns all records (existing + new) at the end.
pub fn run_sweep(
    cfg: SweepConfig,
    artifacts_dir: PathBuf,
    sink_path: PathBuf,
    verbose: bool,
) -> Result<Vec<RunRecord>> {
    let sink = MetricsSink::new(&sink_path);
    let done = sink.completed_keys()?;
    let all = expand_sweep(&cfg, &artifacts_dir)?;
    let todo: Vec<RunConfig> = all
        .into_iter()
        .filter(|r| !done.contains(&RunRecord::key(r)))
        .collect();
    let total = todo.len();
    if verbose {
        println!(
            "[sweep] {} configs to run ({} already complete in {:?})",
            total,
            done.len(),
            sink_path
        );
    }

    let (tx, rx) = mpsc::channel::<Result<RunRecord>>();

    // Dedicated PJRT worker thread: owns the Engine, runs jobs in order.
    // Trainers (and their compiled executables) are cached per model by the
    // Engine's compile cache, so consecutive configs of the same model reuse
    // compilation.
    let worker = std::thread::spawn(move || {
        let engine = match Engine::new(&artifacts_dir) {
            Ok(e) => e,
            Err(e) => {
                let _ = tx.send(Err(e));
                return;
            }
        };
        for rc in todo {
            let result = (|| {
                let trainer = Trainer::new(&engine, &rc)?;
                let outcome = trainer.run(&rc)?;
                Ok(RunRecord::from_outcome(&outcome))
            })();
            if tx.send(result).is_err() {
                break; // scheduler gone
            }
        }
    });

    let mut finished = 0usize;
    for result in rx {
        let record = result?;
        sink.append(&record)?;
        finished += 1;
        if verbose {
            println!(
                "[sweep] {}/{} {} {} M={} N={} P={} -> perf {:.4} sparsity {:.3} ({:.1}s)",
                finished,
                total,
                record.config.model,
                record.config.alg,
                record.config.m,
                record.config.n,
                record.config.p,
                record.perf,
                record.sparsity,
                record.train_secs,
            );
        }
    }
    worker.join().map_err(|_| anyhow::anyhow!("sweep worker panicked"))?;
    sink.load()
}

/// Synchronous single-run helper used by the CLI `train` command and tests.
pub fn run_single(artifacts_dir: &Path, rc: &RunConfig) -> Result<RunRecord> {
    let engine = Engine::new(artifacts_dir)?;
    let trainer = Trainer::new(&engine, rc)?;
    let outcome = trainer.run(rc)?;
    Ok(RunRecord::from_outcome(&outcome))
}
