//! Single-run training driver: epochs over a synthetic dataset, LR schedule,
//! evaluation, deployment export and the overflow-guarantee audit.
//!
//! Generic over the [`TrainBackend`] — the same loop drives the native
//! pure-Rust backend (default build) and the PJRT artifact executor
//! (`xla` feature).

use std::time::Instant;

use anyhow::Result;

use crate::config::RunConfig;
use crate::datasets::{self, Dataset, Split};
use crate::finn::estimate::BitSpec;
use crate::metrics::{self, LossTracker};
use crate::quant::a2q::row_satisfies_cap;
use crate::rng::Rng;
use crate::runtime::{ExportedLayer, ModelManifest, TrainBackend, TrainState};
use crate::tensor::Tensor;

/// Everything a finished run produces.
pub struct TrainOutcome {
    pub config: RunConfig,
    /// (step, loss) for every optimizer step.
    pub loss_history: Vec<(u64, f64)>,
    /// Test-set task performance: top-1 accuracy in [0,1] or PSNR in dB.
    pub perf: f64,
    /// Unstructured sparsity of the exported integer weights (hidden layers).
    pub sparsity: f64,
    /// Per-layer max per-channel integer l1 norm (for PTM bounds, Fig. 6).
    pub l1_norms: Vec<f64>,
    /// Whether every layer's exported codes satisfy Eq. 15 at its (N, P).
    pub guarantee_ok: bool,
    /// Final training state (for checkpointing / further analysis).
    pub state: TrainState,
    /// Exported deployment layers (None for the float baseline).
    pub exported: Option<Vec<ExportedLayer>>,
    /// Wall-clock seconds spent in the step loop.
    pub train_secs: f64,
}

/// Drives one model against one dataset on any [`TrainBackend`].
pub struct Trainer<'e, B: TrainBackend + ?Sized> {
    backend: &'e B,
    pub manifest: ModelManifest,
    pub dataset: Dataset,
}

impl<'e, B: TrainBackend + ?Sized> Trainer<'e, B> {
    /// Set up for `cfg.model`, generating its default synthetic dataset.
    pub fn new(backend: &'e B, cfg: &RunConfig) -> Result<Self> {
        let manifest = backend.manifest(&cfg.model)?;
        let ds_name = datasets::default_for_model(&cfg.model);
        let dataset = datasets::by_name(ds_name, cfg.n_train, cfg.n_test, cfg.seed)?;
        Ok(Trainer { backend, manifest, dataset })
    }

    /// With an explicit dataset (tests, custom workloads).
    pub fn with_dataset(backend: &'e B, model: &str, dataset: Dataset) -> Result<Self> {
        let manifest = backend.manifest(model)?;
        Ok(Trainer { backend, manifest, dataset })
    }

    /// Run the full training loop + evaluation + export for one config.
    pub fn run(&self, cfg: &RunConfig) -> Result<TrainOutcome> {
        cfg.validate()?;
        let bits = cfg.bits();
        let base_lr = cfg.lr.unwrap_or(self.manifest.lr);
        let bs = self.manifest.batch_size;

        let mut state = self.backend.init(&self.manifest, cfg.seed as f32)?;
        let mut rng = Rng::new(cfg.seed ^ 0x7a31_9e55);
        let mut tracker = LossTracker::new(0.05);
        let mut step = 0u64;
        let t0 = Instant::now();

        // The paper initializes QNNs from float models pre-trained to
        // convergence (Appendix B.1). The state layout is algorithm-
        // independent, so we emulate that by spending the first
        // `float_warmup_frac` of the budget on the float train artifact and
        // switching to the quantized one afterwards.
        let warmup = if cfg.alg == "float" {
            0
        } else {
            (cfg.steps as f64 * cfg.float_warmup_frac) as u64
        };

        'outer: loop {
            for idx in self.dataset.epoch(Split::Train, bs, &mut rng) {
                if step >= cfg.steps {
                    break 'outer;
                }
                let batch = self.dataset.gather(Split::Train, &idx);
                let lr = cfg.lr_at(base_lr, step) as f32;
                if warmup > 0 && step == warmup {
                    // Switching float -> quantized: re-calibrate the
                    // quantizer parameters from the warmed-up weights (what
                    // brevitas does when loading a float checkpoint).
                    self.recalibrate_quantizers(&mut state, cfg)?;
                }
                let alg = if step < warmup { "float" } else { cfg.alg.as_str() };
                let loss = self.backend.train_step(
                    &self.manifest,
                    alg,
                    &mut state,
                    &batch.x,
                    &batch.y,
                    bits,
                    lr,
                )?;
                anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
                tracker.push(step, loss as f64);
                step += 1;
            }
            if step >= cfg.steps {
                break;
            }
        }
        let train_secs = t0.elapsed().as_secs_f64();

        let perf = self.evaluate(&state, &cfg.alg, bits)?;
        let (exported, sparsity, l1_norms, guarantee_ok) = if cfg.alg == "float" {
            (None, 0.0, Vec::new(), true)
        } else {
            let layers = self.backend.export(&self.manifest, &cfg.alg, &state, bits)?;
            let (sp, l1s, ok) = self.audit(&layers, bits);
            (Some(layers), sp, l1s, ok)
        };

        Ok(TrainOutcome {
            config: cfg.clone(),
            loss_history: tracker.history.clone(),
            perf,
            sparsity,
            l1_norms,
            guarantee_ok,
            state,
            exported,
            train_secs,
        })
    }

    /// Re-initialize per-channel quantizer parameters from the *current*
    /// weights: `d = log2(max|v_c| / (2^(M-1)-1))`, `t = log2(||v_c||_1)`
    /// (the same rules `layers._with_qparams` applies at init), and clear
    /// their momentum/Adam slots so the optimizer does not drag them back
    /// toward the stale values.
    fn recalibrate_quantizers(&self, state: &mut TrainState, cfg: &RunConfig) -> Result<()> {
        let find = |path: &str| self.manifest.state.iter().position(|e| e.path == path);
        for q in &self.manifest.qlayers {
            let m_bits = match q.m_bits.to_bitspec()? {
                BitSpec::Fixed(v) => v,
                _ => cfg.m,
            };
            let vi = find(&format!("params/{}/v", q.name))
                .ok_or_else(|| anyhow::anyhow!("missing v for {}", q.name))?;
            // Borrow the weight rows once, derive both parameter vectors,
            // then write — no tensor clone.
            let (d_vals, t_vals): (Vec<f32>, Vec<f32>) = {
                let v = &state.leaves[vi];
                (0..v.rows())
                    .map(|c| crate::quant::quantizer::init_qparams_row(v.row(c), m_bits))
                    .unzip()
            };
            for (name, vals) in [("d", &d_vals), ("t", &t_vals)] {
                let Some(pi) = find(&format!("params/{}/{}", q.name, name)) else {
                    continue;
                };
                state.leaves[pi].data_mut().copy_from_slice(vals);
                // zero the optimizer slots for this leaf (mom / m / v trees)
                for prefix in ["mom", "m", "v"] {
                    if let Some(oi) = find(&format!("{prefix}/{}/{}", q.name, name)) {
                        state.leaves[oi].data_mut().fill(0.0);
                    }
                }
            }
        }
        Ok(())
    }

    /// Test-set performance at the given bit widths.
    pub fn evaluate(&self, state: &TrainState, alg: &str, bits: (u32, u32, u32)) -> Result<f64> {
        let bs = self.manifest.batch_size;
        if self.manifest.task == "classify" {
            let (mut correct, mut total) = (0u64, 0u64);
            for (idx, n_valid) in self.dataset.eval_batches(Split::Test, bs) {
                let b = self.dataset.gather(Split::Test, &idx);
                let logits = self.backend.infer(&self.manifest, alg, state, &b.x, bits)?;
                let (c, n) = metrics::top1_accuracy(&logits, b.y.data(), n_valid);
                correct += c;
                total += n;
            }
            Ok(correct as f64 / total.max(1) as f64)
        } else {
            let (mut sse_acc, mut count) = (0.0f64, 0u64);
            for (idx, n_valid) in self.dataset.eval_batches(Split::Test, bs) {
                let b = self.dataset.gather(Split::Test, &idx);
                let pred = self.backend.infer(&self.manifest, alg, state, &b.x, bits)?;
                let (s, n) = metrics::sse(&pred, &b.y, n_valid);
                sse_acc += s;
                count += n;
            }
            Ok(metrics::psnr_from_sse(sse_acc, count))
        }
    }

    /// Sparsity / l1 norms / Eq. 15 audit over exported hidden layers.
    ///
    /// Algorithm-independent: for A2Q/A2Q+ the guarantee holds on *every*
    /// layer at its resolved (N, P) by construction; QAT has no guarantee
    /// and is audited informationally (its `guarantee_ok` reports whether
    /// it happened to satisfy Eq. 15).
    fn audit(&self, layers: &[ExportedLayer], bits: (u32, u32, u32)) -> (f64, Vec<f64>, bool) {
        let (m, n, p) = bits;
        let mut zeros = 0usize;
        let mut total = 0usize;
        let mut l1_norms = Vec::with_capacity(layers.len());
        let mut ok = true;
        for (layer, meta) in layers.iter().zip(&self.manifest.qlayers) {
            let q = layer.to_qtensor();
            // sparsity over hidden (runtime-P) layers, matching Fig. 5 which
            // studies the constrained layers
            if meta.p_bits.to_bitspec().map(|b| b.is_runtime_p()).unwrap_or(false) {
                zeros += q.codes.iter().filter(|c| **c == 0).count();
                total += q.codes.len();
            }
            l1_norms.push(q.max_l1() as f64);
            let n_res = meta
                .n_bits
                .to_bitspec()
                .map(|b| b.resolve(m, n, p))
                .unwrap_or(8);
            let p_res = meta
                .p_bits
                .to_bitspec()
                .map(|b| b.resolve(m, n, p))
                .unwrap_or(32);
            if matches!(meta.p_bits.to_bitspec(), Ok(BitSpec::P)) {
                for c in 0..q.c_out {
                    let row: Vec<f32> = q.row(c).iter().map(|v| *v as f32).collect();
                    if !row_satisfies_cap(&row, p_res, n_res, meta.x_signed) {
                        ok = false;
                    }
                }
            }
        }
        let sparsity = if total == 0 { 0.0 } else { zeros as f64 / total as f64 };
        (sparsity, l1_norms, ok)
    }

    /// Run inference over the test set and return raw outputs (figure code).
    pub fn infer_test(
        &self,
        state: &TrainState,
        alg: &str,
        bits: (u32, u32, u32),
        max_batches: usize,
    ) -> Result<Vec<(Tensor, Tensor, usize)>> {
        let bs = self.manifest.batch_size;
        let mut out = Vec::new();
        for (idx, n_valid) in self.dataset.eval_batches(Split::Test, bs).into_iter().take(max_batches) {
            let b = self.dataset.gather(Split::Test, &idx);
            let pred = self.backend.infer(&self.manifest, alg, state, &b.x, bits)?;
            out.push((pred, b.y, n_valid));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    #[test]
    fn native_run_trains_audits_and_guarantees() {
        let be = NativeBackend::new("artifacts");
        for alg in ["a2q", "a2q_plus"] {
            let mut cfg = RunConfig::new("mlp", alg, 8, 1, 12, 30);
            cfg.n_train = 256;
            cfg.n_test = 64;
            let trainer = Trainer::new(&be, &cfg).unwrap();
            let out = trainer.run(&cfg).unwrap();
            assert!(out.guarantee_ok, "{alg}: Eq. 15 audit failed");
            assert_eq!(out.loss_history.len(), 30);
            assert!(out.perf.is_finite());
            assert!(!out.l1_norms.is_empty());
            let layers = out.exported.as_ref().unwrap();
            assert_eq!(layers.len(), trainer.manifest.qlayers.len());
            // the cap at (N=1, P=12) actually binds the exported codes
            let cap = crate::quant::a2q::l1_cap(12, 1, false);
            assert!(out.l1_norms.iter().all(|l| *l <= cap + 1e-6), "{alg}: {:?}", out.l1_norms);
        }
    }

    #[test]
    fn native_adam_manifest_trains_and_exercises_moment_slots() {
        let be = NativeBackend::new("artifacts");
        let mut cfg = RunConfig::new("mlp3_adam", "a2q", 4, 4, 14, 20);
        cfg.n_train = 128;
        cfg.n_test = 32;
        let trainer = Trainer::new(&be, &cfg).unwrap();
        assert_eq!(trainer.manifest.optimizer, "adam");
        let out = trainer.run(&cfg).unwrap();
        assert!(out.guarantee_ok, "adam: Eq. 15 audit failed");
        assert!(out.perf.is_finite());
        assert!(out.loss_history.iter().all(|(_, l)| l.is_finite()));
        // the Adam slots in the state layout actually moved
        for slot in ["m/fc0/v", "v/fc0/v", "m/fc2/b", "v/fc2/b"] {
            let i = trainer.manifest.state.iter().position(|e| e.path == slot).unwrap();
            assert!(
                out.state.leaves[i].data().iter().any(|v| *v != 0.0),
                "adam moment slot {slot} never updated"
            );
        }
    }

    #[test]
    fn native_float_baseline_skips_export() {
        let be = NativeBackend::new("artifacts");
        let mut cfg = RunConfig::new("mlp", "float", 8, 1, 16, 10);
        cfg.n_train = 128;
        cfg.n_test = 32;
        let trainer = Trainer::new(&be, &cfg).unwrap();
        let out = trainer.run(&cfg).unwrap();
        assert!(out.exported.is_none());
        assert!(out.guarantee_ok);
        assert_eq!(out.sparsity, 0.0);
    }

    #[test]
    fn warmup_recalibration_keeps_training_stable() {
        let be = NativeBackend::new("artifacts");
        let mut cfg = RunConfig::new("mlp3", "a2q", 4, 4, 14, 20);
        cfg.n_train = 128;
        cfg.n_test = 32;
        cfg.float_warmup_frac = 0.5; // force the float -> a2q switch mid-run
        let trainer = Trainer::new(&be, &cfg).unwrap();
        let out = trainer.run(&cfg).unwrap();
        assert!(out.guarantee_ok);
        assert!(out.loss_history.iter().all(|(_, l)| l.is_finite()));
    }

    #[test]
    fn dyn_backend_works_through_the_trait_object() {
        let be: Box<dyn TrainBackend> =
            crate::runtime::make_backend(crate::runtime::BackendKind::Native, "artifacts".as_ref())
                .unwrap();
        let mut cfg = RunConfig::new("mlp", "qat", 8, 1, 20, 8);
        cfg.n_train = 128;
        cfg.n_test = 32;
        let trainer = Trainer::new(be.as_ref(), &cfg).unwrap();
        let out = trainer.run(&cfg).unwrap();
        assert!(out.exported.is_some());
    }
}
