//! Checkpointing: serialize a training state (flattened leaves + the
//! manifest's layout) to a single JSON file, restore it later.
//!
//! JSON-of-f32 keeps the format debuggable and dependency-free; our largest
//! state (cnn + SGD momentum) is a few MB on disk, well within budget. The
//! layout recorded alongside the data lets restore detect drift between the
//! checkpoint and the current artifacts.

use std::path::Path;

use anyhow::Result;

use crate::json::Json;
use crate::runtime::{ModelManifest, TrainState};
use crate::tensor::Tensor;

struct Entry {
    path: String,
    shape: Vec<usize>,
    data: Vec<f32>,
}

pub struct Checkpoint {
    pub model: String,
    pub alg: String,
    pub step: u64,
    entries: Vec<Entry>,
}

impl Checkpoint {
    /// Capture the current state.
    pub fn capture(
        manifest: &ModelManifest,
        alg: &str,
        step: u64,
        state: &TrainState,
    ) -> Result<Self> {
        let tensors = state.to_tensors()?;
        anyhow::ensure!(tensors.len() == manifest.state.len(), "state length drift");
        let entries = tensors
            .iter()
            .zip(&manifest.state)
            .map(|(t, meta)| Entry {
                path: meta.path.clone(),
                shape: t.shape().to_vec(),
                data: t.data().to_vec(),
            })
            .collect();
        Ok(Checkpoint { model: manifest.name.clone(), alg: alg.to_string(), step, entries })
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("alg", Json::str(&self.alg)),
            ("step", Json::num(self.step as f64)),
            (
                "entries",
                Json::arr(self.entries.iter().map(|e| {
                    Json::obj(vec![
                        ("path", Json::str(&e.path)),
                        ("shape", Json::from_usizes(&e.shape)),
                        ("data", Json::from_f32s(&e.data)),
                    ])
                })),
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        let entries = v
            .get("entries")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(Entry {
                    path: e.get("path")?.as_str()?.to_string(),
                    shape: e.get("shape")?.as_usize_vec()?,
                    data: e
                        .get("data")?
                        .as_arr()?
                        .iter()
                        .map(|x| Ok(x.as_f64()? as f32))
                        .collect::<Result<Vec<f32>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Checkpoint {
            model: v.get("model")?.as_str()?.to_string(),
            alg: v.get("alg")?.as_str()?.to_string(),
            step: v.get("step")?.as_u64()?,
            entries,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Restore into a device-resident state, validating the layout against
    /// the manifest (shape or path drift is an error, not a crash later).
    pub fn restore(&self, manifest: &ModelManifest) -> Result<TrainState> {
        anyhow::ensure!(
            self.model == manifest.name,
            "checkpoint is for {}, manifest is {}",
            self.model,
            manifest.name
        );
        anyhow::ensure!(
            self.entries.len() == manifest.state.len(),
            "checkpoint has {} leaves, manifest {}",
            self.entries.len(),
            manifest.state.len()
        );
        let mut tensors = Vec::with_capacity(self.entries.len());
        for (e, meta) in self.entries.iter().zip(&manifest.state) {
            anyhow::ensure!(e.path == meta.path, "leaf {} vs {}", e.path, meta.path);
            anyhow::ensure!(
                e.shape == meta.shape,
                "shape drift on {}: {:?} vs {:?}",
                e.path,
                e.shape,
                meta.shape
            );
            tensors.push(Tensor::new(e.shape.clone(), e.data.clone()));
        }
        TrainState::from_tensors(&tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{NativeBackend, TrainBackend};
    use crate::testutil::TempDir;

    #[test]
    fn native_round_trip_is_bit_exact() {
        let be = NativeBackend::new("artifacts");
        let manifest = be.manifest("mlp3").unwrap();
        let state = be.init(&manifest, 5.0).unwrap();
        let ckpt = Checkpoint::capture(&manifest, "a2q", 7, &state).unwrap();
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("state.json");
        ckpt.save(&path).unwrap();
        let restored = Checkpoint::load(&path).unwrap().restore(&manifest).unwrap();
        assert_eq!(restored.leaves.len(), state.leaves.len());
        for (a, b) in restored.leaves.iter().zip(&state.leaves) {
            assert_eq!(a.shape(), b.shape());
            assert_eq!(a.data(), b.data(), "restore must be bit-exact");
        }
        // drift detection: a different model's manifest is rejected
        let other = be.manifest("mlp").unwrap();
        assert!(ckpt.restore(&other).is_err());
    }
}
