//! Checkpointing: serialize a training state (flattened leaves + the
//! manifest's layout) to a single JSON file, restore it later.
//!
//! JSON-of-f32 keeps the format debuggable and dependency-free; our largest
//! state (cnn + SGD momentum) is a few MB on disk, well within budget. The
//! layout recorded alongside the data lets restore detect drift between the
//! checkpoint and the current artifacts.

use std::path::Path;

use anyhow::Result;

use crate::json::Json;
use crate::runtime::{ModelManifest, TrainState};
use crate::tensor::Tensor;

struct Entry {
    path: String,
    shape: Vec<usize>,
    data: Vec<f32>,
}

pub struct Checkpoint {
    pub model: String,
    pub alg: String,
    pub step: u64,
    entries: Vec<Entry>,
}

impl Checkpoint {
    /// Capture the current state.
    pub fn capture(
        manifest: &ModelManifest,
        alg: &str,
        step: u64,
        state: &TrainState,
    ) -> Result<Self> {
        let tensors = state.to_tensors()?;
        anyhow::ensure!(tensors.len() == manifest.state.len(), "state length drift");
        let entries = tensors
            .iter()
            .zip(&manifest.state)
            .map(|(t, meta)| Entry {
                path: meta.path.clone(),
                shape: t.shape().to_vec(),
                data: t.data().to_vec(),
            })
            .collect();
        Ok(Checkpoint { model: manifest.name.clone(), alg: alg.to_string(), step, entries })
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("alg", Json::str(&self.alg)),
            ("step", Json::num(self.step as f64)),
            (
                "entries",
                Json::arr(self.entries.iter().map(|e| {
                    Json::obj(vec![
                        ("path", Json::str(&e.path)),
                        ("shape", Json::from_usizes(&e.shape)),
                        ("data", Json::from_f32s(&e.data)),
                    ])
                })),
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        let entries = v
            .get("entries")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(Entry {
                    path: e.get("path")?.as_str()?.to_string(),
                    shape: e.get("shape")?.as_usize_vec()?,
                    data: e
                        .get("data")?
                        .as_arr()?
                        .iter()
                        .map(|x| Ok(x.as_f64()? as f32))
                        .collect::<Result<Vec<f32>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Checkpoint {
            model: v.get("model")?.as_str()?.to_string(),
            alg: v.get("alg")?.as_str()?.to_string(),
            step: v.get("step")?.as_u64()?,
            entries,
        })
    }

    /// Crash-safe save: the JSON is written to a sibling temp file and
    /// atomically renamed over `path`, so a sweep killed mid-write leaves
    /// either the previous complete checkpoint or the new one — never a
    /// truncated file. (Same-directory rename stays on one filesystem,
    /// which is what makes the rename atomic; the PID suffix keeps
    /// concurrent writers from clobbering each other's temp files.)
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut tmp = path.as_os_str().to_os_string();
        tmp.push(format!(".tmp.{}", std::process::id()));
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, self.to_json().to_string())?;
        if let Err(e) = std::fs::rename(&tmp, path) {
            // Don't leave the temp file behind on a failed publish.
            let _ = std::fs::remove_file(&tmp);
            return Err(e.into());
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Restore into a device-resident state, validating the layout against
    /// the manifest (shape or path drift is an error, not a crash later).
    pub fn restore(&self, manifest: &ModelManifest) -> Result<TrainState> {
        anyhow::ensure!(
            self.model == manifest.name,
            "checkpoint is for {}, manifest is {}",
            self.model,
            manifest.name
        );
        anyhow::ensure!(
            self.entries.len() == manifest.state.len(),
            "checkpoint has {} leaves, manifest {}",
            self.entries.len(),
            manifest.state.len()
        );
        let mut tensors = Vec::with_capacity(self.entries.len());
        for (e, meta) in self.entries.iter().zip(&manifest.state) {
            anyhow::ensure!(e.path == meta.path, "leaf {} vs {}", e.path, meta.path);
            anyhow::ensure!(
                e.shape == meta.shape,
                "shape drift on {}: {:?} vs {:?}",
                e.path,
                e.shape,
                meta.shape
            );
            tensors.push(Tensor::new(e.shape.clone(), e.data.clone()));
        }
        TrainState::from_tensors(&tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{NativeBackend, TrainBackend};
    use crate::testutil::TempDir;

    #[test]
    fn native_round_trip_is_bit_exact() {
        let be = NativeBackend::new("artifacts");
        let manifest = be.manifest("mlp3").unwrap();
        let state = be.init(&manifest, 5.0).unwrap();
        let ckpt = Checkpoint::capture(&manifest, "a2q", 7, &state).unwrap();
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("state.json");
        ckpt.save(&path).unwrap();
        let restored = Checkpoint::load(&path).unwrap().restore(&manifest).unwrap();
        assert_eq!(restored.leaves.len(), state.leaves.len());
        for (a, b) in restored.leaves.iter().zip(&state.leaves) {
            assert_eq!(a.shape(), b.shape());
            assert_eq!(a.data(), b.data(), "restore must be bit-exact");
        }
        // drift detection: a different model's manifest is rejected
        let other = be.manifest("mlp").unwrap();
        assert!(ckpt.restore(&other).is_err());
    }

    #[test]
    fn torn_write_never_corrupts_a_published_checkpoint() {
        let be = NativeBackend::new("artifacts");
        let manifest = be.manifest("mlp").unwrap();
        let state = be.init(&manifest, 2.0).unwrap();
        let ckpt = Checkpoint::capture(&manifest, "a2q", 3, &state).unwrap();
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("state.json");
        ckpt.save(&path).unwrap();

        // Simulate a writer killed mid-write: a truncated temp file sits
        // next to the published checkpoint. Load must see only the complete
        // file, untouched by the torn write.
        let good = std::fs::read_to_string(&path).unwrap();
        let tmp = dir.path().join(format!("state.json.tmp.{}", std::process::id()));
        std::fs::write(&tmp, &good[..good.len() / 3]).unwrap();
        let restored = Checkpoint::load(&path).unwrap().restore(&manifest).unwrap();
        assert_eq!(restored.leaves.len(), state.leaves.len());

        // A fresh save replaces both atomically and cleans up the stale
        // temp file's name by renaming over it.
        let ckpt2 = Checkpoint::capture(&manifest, "a2q", 4, &state).unwrap();
        ckpt2.save(&path).unwrap();
        assert!(!tmp.exists(), "save must not leave its temp file behind");
        assert_eq!(Checkpoint::load(&path).unwrap().step, 4);

        // And the failure mode this guards against: a torn *published* file
        // (the pre-atomic-rename hazard) fails loudly at load, not later.
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(Checkpoint::load(&path).is_err(), "truncated JSON must be a typed load error");
    }
}
