//! The L3 coordinator: the training loop, the (M, N, P) grid-search
//! scheduler, checkpointing and the metrics sink.
//!
//! Threading model: PJRT handles (`xla::PjRtClient` and friends) hold raw
//! pointers and are not `Send`, so all executions happen on one dedicated
//! worker thread that owns the [`crate::runtime::Engine`]; the tokio side
//! ([`sweep`]) feeds it jobs over a channel, streams results to the JSONL
//! sink, and supports resume by skipping configs already on disk. XLA's CPU
//! backend parallelizes *inside* each executable, so a single worker already
//! saturates the machine for our workloads.

// The training/sweep drivers execute PJRT artifacts and are gated behind
// the `xla` feature; the metrics sink (JSONL records the figure generators
// consume) is pure host code and always available.
#[cfg(feature = "xla")]
pub mod checkpoint;
pub mod sink;
#[cfg(feature = "xla")]
pub mod sweep;
#[cfg(feature = "xla")]
pub mod trainer;

pub use sink::{MetricsSink, RunRecord};
#[cfg(feature = "xla")]
pub use sweep::run_sweep;
#[cfg(feature = "xla")]
pub use trainer::{TrainOutcome, Trainer};
