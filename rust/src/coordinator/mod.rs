//! The L3 coordinator: the training loop, the (M, N, P) grid-search
//! scheduler, checkpointing and the metrics sink — all generic over the
//! [`crate::runtime::TrainBackend`], so the default build trains natively
//! and the `xla` build drives PJRT artifacts through the same drivers.
//!
//! Threading model: each sweep worker thread *constructs* its backend from
//! a `Send + Copy` [`crate::runtime::BackendKind`] (PJRT handles hold raw
//! pointers and are not `Send`); the scheduler fans jobs over a pool of
//! such workers (native backends — one per worker; PJRT pinned to a single
//! worker), streams results to the JSONL sink, and supports resume by
//! skipping configs already on disk. Job panics are caught per-job and
//! reported with the failing config.

pub mod checkpoint;
pub mod sink;
pub mod sweep;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use sink::{MetricsSink, RunRecord};
pub use sweep::{run_single, run_sweep, run_sweep_with_workers, sweep_workers};
pub use trainer::{TrainOutcome, Trainer};
