//! Kernel dispatch layer shared by the two blocked GEMM cores (the f32
//! core in [`crate::linalg`] and the integer core in
//! [`crate::accsim::gemm`]).
//!
//! Three paths compute the same MR×NR register tile:
//!
//! * **Scalar** — the original blocked loops, kept byte-identical as the
//!   portable fallback and the property-test reference;
//! * **Simd** — explicit microkernels behind runtime feature detection
//!   (AVX2+FMA on x86_64, NEON on aarch64): an f32 FMA tile and an i16
//!   pairwise-widening integer tile (`madd`-style: two adjacent MAC steps
//!   multiply into exact i32 pair sums, then widen to i64 accumulators);
//! * **SparseSimd** — the packed operand additionally records, per
//!   NR-column panel, a compressed k-major nonzero list when the panel's
//!   density falls at or below [`SPARSE_PANEL_DENSITY`]; the inner loop
//!   then touches only nonzero weights. Dense panels of the same operand
//!   still ride the SIMD tile. A2Q's L1 budget (Eq. 15) makes tightly
//!   constrained layers mostly zeros, so this converts the overflow
//!   guarantee directly into throughput.
//!
//! Dispatch is a plan-time decision per packed operand: an explicit force
//! (plan/backend API) wins, then the `A2Q_KERNEL` environment variable
//! (`scalar` | `simd` | `sparse`; read once, invalid values ignored), then
//! a density heuristic. Exactness contracts: the integer tiles are
//! bit-identical to the scalar reference (i64 accumulation is exact; the
//! i16 pair sums cannot overflow i32 because packing excludes the -32768
//! weight code and the x operand is rejected outside ±32767); the f32 FMA
//! tile changes rounding versus mul+add but keeps the strict per-element
//! `kk` order, so results remain bit-identical across row partitionings
//! (thread counts) *within* a path.
//!
//! Alongside the MR×NR GEMM tiles, this module also hosts the
//! **delta-column kernels** of the incremental accumulator engine
//! ([`crate::accsim::stream`]): `acc[c] += w[c][j] * d` over one
//! feature-major column, as a scalar reference plus a 4-lane i64 SIMD
//! widening kernel — exact i64 either way, so every path is bit-identical.

use std::sync::OnceLock;

use super::{MR, NR};

// The microkernels hard-code the tile contract (one __m256 per lane row,
// 4+4 i64 accumulators); keep the shared constants honest.
const _: () = assert!(MR == 4 && NR == 8);

/// Per-panel (and whole-operand) density at or below which the sparse
/// compressed layout is used instead of the dense tile.
pub const SPARSE_PANEL_DENSITY: f64 = 0.5;

/// Which kernel implementation a packed operand runs through. A plan-time
/// decision per layer — see the module doc for the precedence chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// Portable blocked scalar loops (the reference).
    Scalar,
    /// Explicit SIMD microkernel on every panel (falls back to scalar at
    /// run time when the CPU lacks the features).
    Simd,
    /// Compressed nonzero traversal for low-density panels, SIMD tile for
    /// the dense remainder.
    SparseSimd,
}

impl KernelPath {
    /// Parse an `A2Q_KERNEL`-style name. `sparse` and `sparse_simd` are
    /// synonyms.
    pub fn parse(s: &str) -> Option<KernelPath> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelPath::Scalar),
            "simd" => Some(KernelPath::Simd),
            "sparse" | "sparse_simd" => Some(KernelPath::SparseSimd),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Simd => "simd",
            KernelPath::SparseSimd => "sparse",
        }
    }

    /// Pick a path for an operand of the given nonzero `density`:
    /// `A2Q_KERNEL` override first, then sparse below the threshold, then
    /// SIMD when the CPU supports it.
    pub fn choose(density: f64) -> KernelPath {
        if let Some(p) = env_kernel() {
            return p;
        }
        if density <= SPARSE_PANEL_DENSITY {
            KernelPath::SparseSimd
        } else if simd_available() {
            KernelPath::Simd
        } else {
            KernelPath::Scalar
        }
    }
}

/// Runtime feature detection for the explicit SIMD tiles: AVX2+FMA on
/// x86_64, NEON on aarch64, false elsewhere. The result never changes
/// within a process, and the detection macros cache internally.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(target_arch = "aarch64")]
    {
        std::arch::is_aarch64_feature_detected!("neon")
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// Test-only injection seam for [`env_kernel`]: the `OnceLock` cache makes
/// real-env tests order-dependent (whichever test reads first pins the
/// value for the whole process), so unit tests inject a pretend
/// `A2Q_KERNEL` per thread instead of touching the environment.
/// `Some(None)` simulates an unset/invalid variable.
#[cfg(test)]
thread_local! {
    static ENV_KERNEL_OVERRIDE: std::cell::Cell<Option<Option<KernelPath>>> =
        const { std::cell::Cell::new(None) };
}

/// Run `f` with [`env_kernel`] pinned to `v` on the current thread (tests
/// only; see [`ENV_KERNEL_OVERRIDE`]). Restores the previous override even
/// though tests normally nest at most one level.
#[cfg(test)]
pub(crate) fn with_env_kernel_override<R>(v: Option<KernelPath>, f: impl FnOnce() -> R) -> R {
    let prev = ENV_KERNEL_OVERRIDE.with(|c| c.replace(Some(v)));
    let r = f();
    ENV_KERNEL_OVERRIDE.with(|c| c.set(prev));
    r
}

/// The `A2Q_KERNEL` override, read once per process. Unknown values are
/// ignored (auto dispatch), so stale scripts cannot break runs.
fn env_kernel() -> Option<KernelPath> {
    #[cfg(test)]
    if let Some(v) = ENV_KERNEL_OVERRIDE.with(|c| c.get()) {
        return v;
    }
    static CACHE: OnceLock<Option<KernelPath>> = OnceLock::new();
    *CACHE.get_or_init(|| std::env::var("A2Q_KERNEL").ok().as_deref().and_then(KernelPath::parse))
}

/// How one NR-column panel of a packed operand is traversed.
#[derive(Clone, Copy, Debug)]
pub(crate) enum PanelKind {
    /// Dense k-major tile (scalar or SIMD microkernel).
    Dense,
    /// Compressed traversal over `SparsePanels` entries `start..end`.
    Sparse { start: usize, end: usize },
}

/// Compressed panel layout built at pack time for the `SparseSimd` path:
/// per low-density panel, the k-major list of nonzero weights as parallel
/// `(k index, lane, value)` arrays. Panels above the density threshold stay
/// [`PanelKind::Dense`] and keep using the dense tile.
#[derive(Default)]
pub(crate) struct SparsePanels<T> {
    pub(crate) kinds: Vec<PanelKind>,
    pub(crate) k_idx: Vec<u32>,
    pub(crate) lane: Vec<u8>,
    pub(crate) val: Vec<T>,
}

impl<T> SparsePanels<T> {
    pub(crate) fn clear(&mut self) {
        self.kinds.clear();
        self.k_idx.clear();
        self.lane.clear();
        self.val.clear();
    }

    /// Panel kind lookup that degrades to Dense when no sparse layout was
    /// built (Scalar/Simd paths leave `kinds` empty).
    pub(crate) fn kind(&self, pi: usize) -> PanelKind {
        self.kinds.get(pi).copied().unwrap_or(PanelKind::Dense)
    }
}

/// Scan dense NR-column panels (layout `panels[pi * k * NR + kk * NR + j]`,
/// `n` real columns) and build the compressed layout for every panel whose
/// density is at or below [`SPARSE_PANEL_DENSITY`]. Padding lanes are zero
/// and never produce entries; density is measured over the `k * nc` real
/// slots. A free function over the raw buffers so packers can call it while
/// owning both the panels and the sparse pools.
pub(crate) fn build_sparse_panels<T: Copy + Default + PartialEq>(
    out: &mut SparsePanels<T>,
    panels: &[T],
    k: usize,
    n: usize,
) {
    out.clear();
    let zero = T::default();
    for pi in 0..n.div_ceil(NR) {
        let panel = &panels[pi * k * NR..(pi + 1) * k * NR];
        let nc = NR.min(n - pi * NR);
        let slots = k * nc;
        if slots == 0 {
            out.kinds.push(PanelKind::Dense);
            continue;
        }
        let nnz = panel.iter().filter(|v| **v != zero).count();
        if nnz as f64 / slots as f64 > SPARSE_PANEL_DENSITY {
            out.kinds.push(PanelKind::Dense);
            continue;
        }
        let start = out.val.len();
        for kk in 0..k {
            for (j, &v) in panel[kk * NR..kk * NR + NR].iter().enumerate() {
                if v != zero {
                    out.k_idx.push(kk as u32);
                    out.lane.push(j as u8);
                    out.val.push(v);
                }
            }
        }
        out.kinds.push(PanelKind::Sparse { start, end: out.val.len() });
    }
}

/// One dense f32 MR×NR tile: accumulate `a[r0..r0+mr, 0..k] · panel` into
/// `acc` (caller-zeroed). `use_simd` routes to the FMA microkernel when the
/// caller has confirmed [`simd_available`]; otherwise (and on other
/// architectures) the scalar loop runs — byte-identical to the original
/// blocked inner loop.
#[inline]
pub(crate) fn dense_tile_f32(
    panel: &[f32],
    k: usize,
    a: &[f32],
    r0: usize,
    mr: usize,
    use_simd: bool,
    acc: &mut [f32; MR * NR],
) {
    #[cfg(target_arch = "x86_64")]
    if use_simd {
        // Safety: callers only pass use_simd=true after simd_available().
        unsafe { x86::tile_f32(panel, k, a, r0, mr, acc) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if use_simd {
        // Safety: NEON is mandatory on aarch64 and detected by the caller.
        unsafe { neon::tile_f32(panel, k, a, r0, mr, acc) };
        return;
    }
    let _ = use_simd;
    for kk in 0..k {
        let wrow = &panel[kk * NR..kk * NR + NR];
        for mi in 0..mr {
            let xv = a[(r0 + mi) * k + kk];
            let lane = &mut acc[mi * NR..mi * NR + NR];
            for j in 0..NR {
                lane[j] += xv * wrow[j];
            }
        }
    }
}

/// One dense i16 MR×NR tile into i64 accumulators (caller-zeroed). Only
/// called when the caller confirmed [`simd_available`] and the operands fit
/// the overflow-free ranges (weights != -32768, |x| <= 32767); the
/// non-SIMD-architecture body is a plain widening loop so the crate still
/// compiles everywhere.
#[inline]
pub(crate) fn dense_tile_i16(
    panel: &[i16],
    k: usize,
    x: &[i16],
    r0: usize,
    mr: usize,
    acc: &mut [i64; MR * NR],
) {
    #[cfg(target_arch = "x86_64")]
    {
        // Safety: callers gate on simd_available() (AVX2 present).
        unsafe { x86::tile_i16(panel, k, x, r0, mr, acc) }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // Safety: NEON is mandatory on aarch64 and detected by the caller.
        unsafe { neon::tile_i16(panel, k, x, r0, mr, acc) }
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        for kk in 0..k {
            let wrow = &panel[kk * NR..kk * NR + NR];
            for mi in 0..mr {
                let xv = x[(r0 + mi) * k + kk] as i64;
                let lane = &mut acc[mi * NR..mi * NR + NR];
                for (l, &w) in lane.iter_mut().zip(wrow) {
                    *l += xv * w as i64;
                }
            }
        }
    }
}

/// Scalar reference for one feature-major delta column of i32 codes:
/// `acc[c] += col[c] * d`. Exact (i32 * i64 widened to i64), the
/// property-test baseline for the SIMD kernel below.
#[inline]
pub(crate) fn delta_col_scalar_i32(col: &[i32], d: i64, acc: &mut [i64]) {
    debug_assert_eq!(col.len(), acc.len());
    for (a, &w) in acc.iter_mut().zip(col) {
        *a += w as i64 * d;
    }
}

/// Scalar delta column over i64 codes (the beyond-i32 fallback layout).
#[inline]
pub(crate) fn delta_col_scalar_i64(col: &[i64], d: i64, acc: &mut [i64]) {
    debug_assert_eq!(col.len(), acc.len());
    for (a, &w) in acc.iter_mut().zip(col) {
        *a += w * d;
    }
}

/// Dispatched delta column over i32 codes: `acc[c] += col[c] * d` for every
/// channel. `use_simd` routes to the 4-lane i64 widening kernel when the
/// caller confirmed [`simd_available`] — on x86_64 only while `d` itself
/// fits i32 (`_mm256_mul_epi32` multiplies the signed low 32 bits of each
/// lane, so both operands must be exact there); a wider `d` and every
/// non-SIMD configuration take the scalar reference. All paths accumulate
/// in exact i64, so results are bit-identical by construction.
#[inline]
pub(crate) fn delta_col_i32(col: &[i32], d: i64, acc: &mut [i64], use_simd: bool) {
    #[cfg(target_arch = "x86_64")]
    if use_simd && i32::try_from(d).is_ok() {
        // Safety: callers only pass use_simd=true after simd_available().
        unsafe { x86::delta_col_i32(col, d as i32, acc) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if use_simd {
        // Safety: NEON is mandatory on aarch64 and detected by the caller.
        unsafe { neon::delta_col_i32(col, d, acc) };
        return;
    }
    let _ = use_simd;
    delta_col_scalar_i32(col, d, acc);
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// The f32 FMA tile: one `__m256` per accumulator row (NR = 8), strict
    /// `kk` order preserved, totals *stored over* the caller-zeroed `acc`.
    ///
    /// # Safety
    /// Requires AVX2 and FMA (callers gate on `simd_available`). Slice
    /// bounds: `panel` holds `k * NR` values, `a` covers rows
    /// `r0..r0 + mr` of width `k`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn tile_f32(
        panel: &[f32],
        k: usize,
        a: &[f32],
        r0: usize,
        mr: usize,
        acc: &mut [f32; MR * NR],
    ) {
        debug_assert!(panel.len() >= k * NR);
        debug_assert!(a.len() >= (r0 + mr) * k);
        let mut vacc = [_mm256_setzero_ps(); MR];
        for kk in 0..k {
            let w = _mm256_loadu_ps(panel.as_ptr().add(kk * NR));
            for (mi, v) in vacc.iter_mut().enumerate().take(mr) {
                let xv = _mm256_set1_ps(*a.get_unchecked((r0 + mi) * k + kk));
                *v = _mm256_fmadd_ps(xv, w, *v);
            }
        }
        for (mi, v) in vacc.iter().enumerate().take(mr) {
            _mm256_storeu_ps(acc.as_mut_ptr().add(mi * NR), *v);
        }
    }

    /// The i16 pairwise-widening integer tile: adjacent MAC steps
    /// `kk, kk+1` interleave into `madd` pair sums (exact in i32 because
    /// packing excludes -32768 weight codes and x is pre-narrowed to
    /// ±32767: |pair sum| <= 2 * 32767^2 < 2^31), then sign-extend to the
    /// four low / four high i64 accumulator lanes every step. Bit-identical
    /// to the scalar i64 reference. Totals are *stored over* the
    /// caller-zeroed `acc`.
    ///
    /// # Safety
    /// Requires AVX2 (callers gate on `simd_available`). Slice bounds:
    /// `panel` holds `k * NR` values, `x` covers rows `r0..r0 + mr` of
    /// width `k`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn tile_i16(
        panel: &[i16],
        k: usize,
        x: &[i16],
        r0: usize,
        mr: usize,
        acc: &mut [i64; MR * NR],
    ) {
        debug_assert!(panel.len() >= k * NR);
        debug_assert!(x.len() >= (r0 + mr) * k);
        let mut lo = [_mm256_setzero_si256(); MR];
        let mut hi = [_mm256_setzero_si256(); MR];
        let mut kk = 0;
        while kk < k {
            let wk = _mm_loadu_si128(panel.as_ptr().add(kk * NR) as *const __m128i);
            let wk1 = if kk + 1 < k {
                _mm_loadu_si128(panel.as_ptr().add((kk + 1) * NR) as *const __m128i)
            } else {
                _mm_setzero_si128()
            };
            // Interleave the two weight rows: lanes 0..3 / 4..7 become
            // [w[kk][j], w[kk+1][j]] i16 pairs matching madd's operand
            // layout.
            let wlo = _mm_unpacklo_epi16(wk, wk1);
            let whi = _mm_unpackhi_epi16(wk, wk1);
            for mi in 0..mr {
                let x0 = *x.get_unchecked((r0 + mi) * k + kk);
                let x1 =
                    if kk + 1 < k { *x.get_unchecked((r0 + mi) * k + kk + 1) } else { 0i16 };
                let xv =
                    _mm_set1_epi32((x0 as u16 as u32 | ((x1 as u16 as u32) << 16)) as i32);
                let p0 = _mm_madd_epi16(wlo, xv);
                let p1 = _mm_madd_epi16(whi, xv);
                lo[mi] = _mm256_add_epi64(lo[mi], _mm256_cvtepi32_epi64(p0));
                hi[mi] = _mm256_add_epi64(hi[mi], _mm256_cvtepi32_epi64(p1));
            }
            kk += 2;
        }
        for mi in 0..mr {
            _mm256_storeu_si256(acc.as_mut_ptr().add(mi * NR) as *mut __m256i, lo[mi]);
            _mm256_storeu_si256(acc.as_mut_ptr().add(mi * NR + 4) as *mut __m256i, hi[mi]);
        }
    }

    /// The 4-lane i64 delta-column kernel: sign-extend four i32 codes to
    /// i64 lanes (`cvtepi32_epi64` keeps the low 32 bits exact), multiply
    /// by the splatted delta with `_mm256_mul_epi32` (signed low-32 ×
    /// signed low-32 → exact i64 product, which is why the caller requires
    /// `d` to fit i32), and add into the i64 accumulators. Exact, hence
    /// bit-identical to [`super::delta_col_scalar_i32`].
    ///
    /// # Safety
    /// Requires AVX2 (callers gate on `simd_available`). `col` and `acc`
    /// must be the same length.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn delta_col_i32(col: &[i32], d: i32, acc: &mut [i64]) {
        debug_assert_eq!(col.len(), acc.len());
        let n = acc.len();
        let n4 = n / 4 * 4;
        let dv = _mm256_set1_epi64x(d as i64);
        let mut c = 0;
        while c < n4 {
            let cv = _mm256_cvtepi32_epi64(_mm_loadu_si128(col.as_ptr().add(c) as *const __m128i));
            let prod = _mm256_mul_epi32(cv, dv);
            let av = _mm256_loadu_si256(acc.as_ptr().add(c) as *const __m256i);
            _mm256_storeu_si256(
                acc.as_mut_ptr().add(c) as *mut __m256i,
                _mm256_add_epi64(av, prod),
            );
            c += 4;
        }
        for i in n4..n {
            *acc.get_unchecked_mut(i) += *col.get_unchecked(i) as i64 * d as i64;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{MR, NR};

    /// NEON-pinned f32 tile: the `target_feature` attribute lets LLVM emit
    /// vector FMA over the plain loops (accumulating into the caller-zeroed
    /// `acc`, strict `kk` order per element).
    ///
    /// # Safety
    /// Requires NEON (callers gate on `simd_available`; NEON is mandatory
    /// on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn tile_f32(
        panel: &[f32],
        k: usize,
        a: &[f32],
        r0: usize,
        mr: usize,
        acc: &mut [f32; MR * NR],
    ) {
        for kk in 0..k {
            let wrow = &panel[kk * NR..kk * NR + NR];
            for mi in 0..mr {
                let xv = a[(r0 + mi) * k + kk];
                let lane = &mut acc[mi * NR..mi * NR + NR];
                for (l, &w) in lane.iter_mut().zip(wrow) {
                    *l += xv * w;
                }
            }
        }
    }

    /// NEON-pinned widening i16 tile (exact i64 accumulation, bit-identical
    /// to the scalar reference by construction).
    ///
    /// # Safety
    /// Requires NEON (callers gate on `simd_available`; NEON is mandatory
    /// on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn tile_i16(
        panel: &[i16],
        k: usize,
        x: &[i16],
        r0: usize,
        mr: usize,
        acc: &mut [i64; MR * NR],
    ) {
        for kk in 0..k {
            let wrow = &panel[kk * NR..kk * NR + NR];
            for mi in 0..mr {
                let xv = x[(r0 + mi) * k + kk] as i64;
                let lane = &mut acc[mi * NR..mi * NR + NR];
                for (l, &w) in lane.iter_mut().zip(wrow) {
                    *l += xv * w as i64;
                }
            }
        }
    }

    /// NEON-pinned delta-column kernel (exact i64 widening loop,
    /// bit-identical to the scalar reference by construction).
    ///
    /// # Safety
    /// Requires NEON (callers gate on `simd_available`; NEON is mandatory
    /// on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn delta_col_i32(col: &[i32], d: i64, acc: &mut [i64]) {
        for (a, &w) in acc.iter_mut().zip(col) {
            *a += w as i64 * d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_render_round_trip() {
        for p in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
            assert_eq!(KernelPath::parse(p.as_str()), Some(p));
        }
        assert_eq!(KernelPath::parse("sparse_simd"), Some(KernelPath::SparseSimd));
        assert_eq!(KernelPath::parse(" SIMD "), Some(KernelPath::Simd));
        assert_eq!(KernelPath::parse("avx512"), None);
        assert_eq!(KernelPath::parse(""), None);
    }

    #[test]
    fn sparse_panels_compress_only_low_density_panels() {
        // Two panels over n=10 (nc = 8 and 2), k = 4: first panel dense
        // (all ones), second panel one nonzero in 8 real slots.
        let (k, n) = (4usize, 10usize);
        let mut panels = vec![0f32; n.div_ceil(NR) * k * NR];
        for kk in 0..k {
            for j in 0..NR {
                panels[kk * NR + j] = 1.0;
            }
        }
        let p1 = k * NR;
        panels[p1 + 2 * NR] = 3.0; // panel 1, kk=2, lane 0
        let mut sp = SparsePanels::default();
        build_sparse_panels(&mut sp, &panels, k, n);
        assert_eq!(sp.kinds.len(), 2);
        assert!(matches!(sp.kind(0), PanelKind::Dense));
        match sp.kind(1) {
            PanelKind::Sparse { start, end } => {
                assert_eq!((start, end), (0, 1));
                assert_eq!((sp.k_idx[0], sp.lane[0], sp.val[0]), (2, 0, 3.0));
            }
            PanelKind::Dense => panic!("low-density panel not compressed"),
        }
        // Lookup past the built panels degrades to Dense.
        assert!(matches!(sp.kind(7), PanelKind::Dense));
    }

    #[test]
    fn sparse_entries_are_k_major_and_skip_padding() {
        // n = 3 (one panel, 5 padding lanes), k = 3, half the real slots
        // nonzero in scattered order.
        let (k, n) = (3usize, 3usize);
        let mut panels = vec![0f32; k * NR];
        panels[NR + 1] = 2.0; // kk=1 lane 1
        panels[2] = 1.0; // kk=0 lane 2
        panels[2 * NR] = 4.0; // kk=2 lane 0
        let mut sp = SparsePanels::default();
        build_sparse_panels(&mut sp, &panels, k, n);
        assert!(matches!(sp.kind(0), PanelKind::Sparse { start: 0, end: 3 }));
        assert_eq!(sp.k_idx, vec![0, 1, 2], "entries must be k-major");
        assert_eq!(sp.lane, vec![2, 1, 0]);
        assert_eq!(sp.val, vec![1.0, 2.0, 4.0]);
    }

    #[test]
    fn zero_k_panels_stay_dense() {
        let mut sp = SparsePanels::<f32>::default();
        build_sparse_panels(&mut sp, &[], 0, 5);
        assert_eq!(sp.kinds.len(), 1);
        assert!(matches!(sp.kind(0), PanelKind::Dense));
        assert!(sp.val.is_empty());
    }

    #[test]
    fn simd_tiles_match_the_scalar_tile_when_available() {
        if !simd_available() {
            eprintln!("no SIMD on this host; dispatch falls back to scalar (covered elsewhere)");
            return;
        }
        let mut rng = crate::rng::Rng::new(0x51D);
        for k in [0usize, 1, 2, 5, 8, 33] {
            for mr in 1..=MR {
                // f32 on an integer grid: FMA is exact, must match bitwise.
                let panel: Vec<f32> =
                    (0..k * NR).map(|_| (rng.below(19) as i64 - 9) as f32).collect();
                let a: Vec<f32> =
                    (0..(mr + 1) * k).map(|_| (rng.below(19) as i64 - 9) as f32).collect();
                let mut want = [0f32; MR * NR];
                dense_tile_f32(&panel, k, &a, 1, mr, false, &mut want);
                let mut got = [0f32; MR * NR];
                dense_tile_f32(&panel, k, &a, 1, mr, true, &mut got);
                assert_eq!(got[..mr * NR], want[..mr * NR], "f32 k={k} mr={mr}");

                // i16 at the extreme magnitudes the pack/narrow gates admit.
                let wi: Vec<i16> = (0..k * NR)
                    .map(|i| if i % 3 == 0 { 32767 } else { -32767 + (i % 7) as i16 })
                    .collect();
                let xi: Vec<i16> = (0..(mr + 1) * k)
                    .map(|i| if i % 2 == 0 { -32767 } else { 32767 - (i % 5) as i16 })
                    .collect();
                let mut iwant = [0i64; MR * NR];
                for kk in 0..k {
                    for mi in 0..mr {
                        let xv = xi[(1 + mi) * k + kk] as i64;
                        for j in 0..NR {
                            iwant[mi * NR + j] += xv * wi[kk * NR + j] as i64;
                        }
                    }
                }
                let mut igot = [0i64; MR * NR];
                dense_tile_i16(&wi, k, &xi, 1, mr, &mut igot);
                assert_eq!(igot[..mr * NR], iwant[..mr * NR], "i16 k={k} mr={mr}");
            }
        }
    }

    #[test]
    fn delta_col_kernels_match_scalar_reference() {
        let mut rng = crate::rng::Rng::new(0xDE17A);
        // Lengths straddling the 4-lane width, extreme i32 codes, deltas on
        // both sides of the i32 gate (beyond-i32 deltas must route back to
        // the scalar loop on x86 and still agree).
        for n in [0usize, 1, 3, 4, 5, 8, 21] {
            for d in [0i64, 1, -7, 255, i32::MAX as i64, i32::MIN as i64, (i32::MAX as i64) * 9] {
                let col: Vec<i32> = (0..n)
                    .map(|i| match i % 4 {
                        0 => i32::MAX,
                        1 => i32::MIN + 1,
                        _ => rng.below(2001) as i32 - 1000,
                    })
                    .collect();
                let base: Vec<i64> = (0..n).map(|_| rng.below(1 << 20) as i64 - (1 << 19)).collect();
                let mut want = base.clone();
                delta_col_scalar_i32(&col, d, &mut want);
                for use_simd in [false, simd_available()] {
                    let mut got = base.clone();
                    delta_col_i32(&col, d, &mut got, use_simd);
                    assert_eq!(got, want, "n={n} d={d} simd={use_simd}");
                }
                // The i64 layout's scalar kernel agrees on widened codes.
                let col64: Vec<i64> = col.iter().map(|&v| v as i64).collect();
                let mut got64 = base.clone();
                delta_col_scalar_i64(&col64, d, &mut got64);
                assert_eq!(got64, want, "i64 n={n} d={d}");
            }
        }
    }

    #[test]
    fn kernel_dispatch_precedence_is_force_then_env_then_density() {
        use crate::accsim::gemm::PackedWeights;
        use crate::quant::QTensor;

        // Env (injected through the test seam) beats the density heuristic
        // at both density extremes.
        for p in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
            with_env_kernel_override(Some(p), || {
                assert_eq!(KernelPath::choose(0.0), p, "env should beat low density");
                assert_eq!(KernelPath::choose(1.0), p, "env should beat high density");
            });
        }
        // Unset (or invalid) env falls through to the density heuristic.
        with_env_kernel_override(None, || {
            assert_eq!(KernelPath::choose(SPARSE_PANEL_DENSITY), KernelPath::SparseSimd);
            let dense_want =
                if simd_available() { KernelPath::Simd } else { KernelPath::Scalar };
            assert_eq!(KernelPath::choose(1.0), dense_want);
        });
        // An explicit force beats the env override: pack_with never consults
        // choose(), pack() does.
        let w = QTensor {
            codes: vec![1, 0, -2, 0, 0, 3],
            scales: vec![1.0, 1.0],
            bias: vec![0.0, 0.0],
            c_out: 2,
            k: 3,
        };
        let order = [0usize, 1];
        with_env_kernel_override(Some(KernelPath::SparseSimd), || {
            let forced = PackedWeights::pack_with(&w, &order, KernelPath::Scalar)
                .expect("small codes must pack");
            assert_eq!(forced.path(), KernelPath::Scalar, "force must beat env");
            let auto = PackedWeights::pack(&w, &order).expect("small codes must pack");
            assert_eq!(auto.path(), KernelPath::SparseSimd, "auto must honor env");
        });
    }
}
