//! Shared blocked f32 GEMM core: the float twin of the packed integer
//! engine in [`crate::accsim::gemm`], serving the native training backend's
//! three matrix shapes from one microkernel.
//!
//! One dense layer's train step is three GEMMs over the same weight matrix
//! `W[c_out, k]`:
//!
//! * forward           `Z[B, c_out] = A[B, k] · Wᵀ`        — pack `W` rows
//!   as panel lanes ([`PackedB::pack_t`], the NT variant);
//! * input gradient    `dA[B, k]    = dZ[B, c_out] · W`    — pack `W` as a
//!   row-major `[K, N]` operand ([`PackedB::pack_nn`], the NN variant);
//! * weight gradient   `gW[c_out, k] = dZᵀ · A`            — the TN variant,
//!   expressed as a transpose-into-scratch plus the NN kernel inside the
//!   block-ordered reduction [`grad_reduce`].
//!
//! Design mirrors `accsim/gemm.rs` (which shares this module's [`MR`]/[`NR`]
//! tile): the B operand is packed once into NR-column, k-major panels, then
//! an MR×NR register tile streams each panel over MR-row blocks of A. The
//! MR×NR accumulators are independent, so the inner loop vectorizes without
//! reassociating any single dot product — every output element is the
//! strictly-ordered sum over `kk = 0..k`, which is what makes results
//! *bit-identical regardless of how rows are partitioned*. [`matmul_par`]
//! fans row chunks over `std::thread::scope` workers on that guarantee: any
//! thread count produces the same bits.
//!
//! Reductions over the row dimension (weight/bias gradients) cannot lean on
//! row independence, so [`grad_reduce`] fixes the sum tree instead: rows are
//! cut into [`GRAD_BLOCK`]-row blocks whose partial products are computed
//! independently (in parallel) and then summed serially in block order —
//! the tree shape depends only on the batch size, never the thread count.
//!
//! Thread-count policy lives here too ([`env_threads`], [`hardware_workers`],
//! [`gemm_workers`]) so the accsim engine, the native backend and the sweep
//! scheduler share one heuristic.
//!
//! The inner tile itself is dispatched per packed operand through
//! [`kernel::KernelPath`]: the original scalar loop (reference + portable
//! fallback), an explicit AVX2/FMA (or NEON) microkernel behind runtime
//! feature detection, or a sparse compressed-panel traversal that skips
//! zero weights entirely — see the [`kernel`] module doc for the layout and
//! the `A2Q_KERNEL` override. Every path keeps the strict per-element `kk`
//! order, so the bit-identical-across-thread-counts guarantee holds within
//! any fixed path.

pub mod kernel;

pub use kernel::{simd_available, KernelPath};

use kernel::{build_sparse_panels, PanelKind, SparsePanels};

/// Row-tile height over the M (batch) dimension: rows sharing one panel
/// traversal. Shared with the integer GEMM in [`crate::accsim::gemm`].
pub const MR: usize = 4;
/// Column-tile width: packed B columns per panel (accumulator lanes of the
/// microkernel). Shared with the integer GEMM in [`crate::accsim::gemm`].
pub const NR: usize = 8;

/// Rows per reduction block in [`grad_reduce`]. A *fixed* constant — block
/// boundaries (and therefore the floating-point sum tree) depend only on
/// the batch size, which is what keeps gradients bit-identical at any
/// thread count.
pub const GRAD_BLOCK: usize = 64;

/// Explicit thread-count override from an environment variable (`0` and
/// unparsable values are ignored).
pub fn env_threads(var: &str) -> Option<usize> {
    std::env::var(var).ok()?.parse::<usize>().ok().filter(|n| *n > 0)
}

/// Hardware parallelism (1 when unknown).
pub fn hardware_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Worker count for a GEMM-shaped job of `flops` fused multiply-adds,
/// honoring the `A2Q_NATIVE_THREADS` environment override. Below ~1M flops
/// the pass finishes in well under a millisecond and scoped-thread spawn
/// would dominate, so such jobs run inline.
pub fn gemm_workers(flops: usize) -> usize {
    if let Some(n) = env_threads("A2Q_NATIVE_THREADS") {
        return n;
    }
    if flops < 1_000_000 {
        1
    } else {
        hardware_workers()
    }
}

/// An f32 B operand packed once into NR-column, k-major panels
/// (`panel[kk * NR + j]` is MAC step `kk` of packed column `j`), reusable
/// across calls — repacking into an existing `PackedB` reuses its buffer.
///
/// Packing also fixes the operand's [`KernelPath`]: an explicit
/// [`force_path`](PackedB::force_path) wins, then the `A2Q_KERNEL`
/// environment override, then a density heuristic (see
/// [`KernelPath::choose`]). On the sparse path, low-density panels get a
/// compressed nonzero layout built at pack time.
pub struct PackedB {
    panels: Vec<f32>,
    /// Packed (output) columns.
    n: usize,
    /// MAC depth shared by every column.
    k: usize,
    /// Explicit dispatch override, surviving repacks.
    forced: Option<KernelPath>,
    /// Path chosen by the last pack.
    path: KernelPath,
    /// Compressed panels (populated only on the `SparseSimd` path).
    sparse: SparsePanels<f32>,
}

impl Default for PackedB {
    fn default() -> PackedB {
        PackedB {
            panels: Vec::new(),
            n: 0,
            k: 0,
            forced: None,
            path: KernelPath::Scalar,
            sparse: SparsePanels::default(),
        }
    }
}

impl PackedB {
    pub fn new() -> PackedB {
        PackedB::default()
    }

    /// Packed output columns.
    pub fn n(&self) -> usize {
        self.n
    }

    /// MAC depth.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Pin dispatch to `path` (`None` restores auto). Takes effect at the
    /// next `pack_nn`/`pack_t` call.
    pub fn force_path(&mut self, path: Option<KernelPath>) {
        self.forced = path;
    }

    /// The explicit override, if any (propagated to per-worker packs by
    /// [`grad_reduce`]).
    pub fn forced_path(&self) -> Option<KernelPath> {
        self.forced
    }

    /// The path chosen by the most recent pack.
    pub fn path(&self) -> KernelPath {
        self.path
    }

    fn reset(&mut self, k: usize, n: usize) {
        self.k = k;
        self.n = n;
        let len = n.div_ceil(NR) * k * NR;
        self.panels.clear();
        self.panels.resize(len, 0.0);
    }

    /// Resolve the kernel path from the source operand's density and build
    /// the compressed panels when the sparse path is chosen.
    fn finish_pack(&mut self, b: &[f32]) {
        let density = if b.is_empty() {
            1.0
        } else {
            b.iter().filter(|v| **v != 0.0).count() as f64 / b.len() as f64
        };
        self.path = self.forced.unwrap_or_else(|| KernelPath::choose(density));
        self.sparse.clear();
        if self.path == KernelPath::SparseSimd {
            build_sparse_panels(&mut self.sparse, &self.panels, self.k, self.n);
        }
    }

    /// Pack a row-major `b[k, n]` operand (the NN layout): packed column
    /// `j` is column `j` of `b`.
    pub fn pack_nn(&mut self, b: &[f32], k: usize, n: usize) {
        debug_assert_eq!(b.len(), k * n);
        self.reset(k, n);
        if n != 0 {
            for (ci, chunk) in b.chunks_exact(n).enumerate() {
                // row ci of b scatters across panels at MAC step ci
                for (j, &v) in chunk.iter().enumerate() {
                    let (pi, lane) = (j / NR, j % NR);
                    self.panels[pi * self.k * NR + ci * NR + lane] = v;
                }
            }
        }
        self.finish_pack(b);
    }

    /// Pack a row-major `b[n, k]` operand *transposed* (the NT layout):
    /// packed column `j` is row `j` of `b` — exactly the `[c_out, k]`
    /// weight layout, so `matmul` computes `A · bᵀ`.
    pub fn pack_t(&mut self, b: &[f32], n: usize, k: usize) {
        debug_assert_eq!(b.len(), n * k);
        self.reset(k, n);
        if k != 0 {
            for (j, row) in b.chunks_exact(k).enumerate() {
                let (pi, lane) = (j / NR, j % NR);
                let base = pi * k * NR + lane;
                for (kk, &v) in row.iter().enumerate() {
                    self.panels[base + kk * NR] = v;
                }
            }
        }
        self.finish_pack(b);
    }

    /// `out[m, n] = a[m, k] · B` (overwrites `out`). Each output element is
    /// the in-order sum over `kk = 0..k`, independent of `m` or row-block
    /// boundaries, so any row partition of the same call is bit-identical
    /// (within the packed operand's kernel path — every path preserves the
    /// per-element `kk` order; the sparse path visits its nonzero subset in
    /// the same k-major order).
    pub fn matmul(&self, a: &[f32], m: usize, out: &mut [f32]) {
        debug_assert_eq!(a.len(), m * self.k);
        debug_assert_eq!(out.len(), m * self.n);
        let (k, n) = (self.k, self.n);
        if m == 0 || n == 0 {
            return;
        }
        let use_simd = self.path != KernelPath::Scalar && simd_available();
        for pi in 0..n.div_ceil(NR) {
            let c0 = pi * NR;
            let nc = NR.min(n - c0);
            let panel = &self.panels[pi * k * NR..(pi + 1) * k * NR];
            let kind = self.sparse.kind(pi);
            let mut r0 = 0;
            while r0 < m {
                let mr = MR.min(m - r0);
                let mut acc = [0f32; MR * NR];
                match kind {
                    PanelKind::Sparse { start, end } => {
                        for e in start..end {
                            let kk = self.sparse.k_idx[e] as usize;
                            let lane = self.sparse.lane[e] as usize;
                            let wv = self.sparse.val[e];
                            for mi in 0..mr {
                                acc[mi * NR + lane] += a[(r0 + mi) * k + kk] * wv;
                            }
                        }
                    }
                    PanelKind::Dense => {
                        kernel::dense_tile_f32(panel, k, a, r0, mr, use_simd, &mut acc)
                    }
                }
                for mi in 0..mr {
                    for j in 0..nc {
                        out[(r0 + mi) * n + c0 + j] = acc[mi * NR + j];
                    }
                }
                r0 += mr;
            }
        }
    }
}

/// [`PackedB::matmul`] with the `m` rows fanned over up to `threads` scoped
/// workers writing disjoint output chunks. Bit-identical to the
/// single-threaded call for any thread count (see the module doc).
pub fn matmul_par(b: &PackedB, a: &[f32], m: usize, out: &mut [f32], threads: usize) {
    debug_assert_eq!(a.len(), m * b.k());
    debug_assert_eq!(out.len(), m * b.n());
    let t = threads.max(1).min(m.max(1));
    if t <= 1 || b.n() == 0 {
        return b.matmul(a, m, out);
    }
    // Round chunks up to the MR tile so workers do not split a register
    // tile (a pure perf choice — results do not depend on the split).
    let chunk = m.div_ceil(t).div_ceil(MR) * MR;
    std::thread::scope(|s| {
        for (ci, o) in out.chunks_mut(chunk * b.n()).enumerate() {
            let rows = o.len() / b.n();
            let a_sl = &a[ci * chunk * b.k()..ci * chunk * b.k() + rows * b.k()];
            s.spawn(move || b.matmul(a_sl, rows, o));
        }
    });
}

/// Add a per-column bias to a row-major `out[m, n]` matrix.
pub fn add_bias(out: &mut [f32], m: usize, n: usize, bias: &[f32]) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    for row in out.chunks_exact_mut(n) {
        for (o, b) in row.iter_mut().zip(bias) {
            *o += b;
        }
    }
}

/// Reusable buffers for [`grad_reduce`]: per-block partials plus the
/// transpose/pack scratch of the serial path, so steady-state train steps
/// do not re-allocate (parallel workers still build their own pack —
/// per-worker state cannot be shared, and the fan-out only engages on
/// batches big enough to amortize it).
#[derive(Default)]
pub struct GradScratch {
    gw_blocks: Vec<f32>,
    gb_blocks: Vec<f32>,
    dyt: Vec<f32>,
    pack: PackedB,
}

impl GradScratch {
    /// Pin the kernel path of every pack [`grad_reduce`] performs with this
    /// scratch — including the per-worker packs of the parallel fan-out
    /// (`None` restores auto dispatch).
    pub fn force_path(&mut self, path: Option<KernelPath>) {
        self.pack.force_path(path);
    }
}

/// The backward reduction of one dense layer: `g_w[n, k] = dyᵀ · a` and
/// `g_b[n] = column sums of dy`, for row-major `dy[m, n]` and `a[m, k]`.
///
/// Rows are cut into [`GRAD_BLOCK`]-row blocks; each block's partial
/// product (a small TN GEMM: transpose `dy` into scratch, pack the `a`
/// block, multiply) is computed independently — blocks fan over up to
/// `threads` scoped workers — and the partials are summed serially in
/// block order. The sum tree therefore depends only on `m`, making the
/// result bit-identical at any thread count.
#[allow(clippy::too_many_arguments)]
pub fn grad_reduce(
    dy: &[f32],
    a: &[f32],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
    g_w: &mut [f32],
    g_b: &mut [f32],
    scratch: &mut GradScratch,
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(g_w.len(), n * k);
    debug_assert_eq!(g_b.len(), n);
    g_w.fill(0.0);
    g_b.fill(0.0);
    if m == 0 || n == 0 {
        return;
    }
    let nblocks = m.div_ceil(GRAD_BLOCK);

    let run_block = |bi: usize,
                     gw_out: &mut [f32],
                     gb_out: &mut [f32],
                     dyt: &mut Vec<f32>,
                     pack: &mut PackedB| {
        let r0 = bi * GRAD_BLOCK;
        let rows = GRAD_BLOCK.min(m - r0);
        for (c, g) in gb_out.iter_mut().enumerate() {
            let mut s = 0f32;
            for r in 0..rows {
                s += dy[(r0 + r) * n + c];
            }
            *g = s;
        }
        if k == 0 {
            return;
        }
        // dyᵀ block [n, rows] into scratch, then the NN kernel against the
        // packed a block [rows, k]
        dyt.clear();
        dyt.resize(n * rows, 0.0);
        for r in 0..rows {
            for c in 0..n {
                dyt[c * rows + r] = dy[(r0 + r) * n + c];
            }
        }
        pack.pack_nn(&a[r0 * k..(r0 + rows) * k], rows, k);
        pack.matmul(dyt, n, gw_out);
    };

    let t = threads.max(1).min(nblocks);
    if t <= 1 {
        // Single block: reduce straight into the outputs, no partials.
        if nblocks == 1 {
            run_block(0, g_w, g_b, &mut scratch.dyt, &mut scratch.pack);
            return;
        }
        scratch.gw_blocks.clear();
        scratch.gw_blocks.resize(nblocks * n * k, 0.0);
        scratch.gb_blocks.clear();
        scratch.gb_blocks.resize(nblocks * n, 0.0);
        for bi in 0..nblocks {
            if k == 0 {
                run_block(
                    bi,
                    &mut [0f32; 0][..],
                    &mut scratch.gb_blocks[bi * n..(bi + 1) * n],
                    &mut scratch.dyt,
                    &mut scratch.pack,
                );
            } else {
                run_block(
                    bi,
                    &mut scratch.gw_blocks[bi * n * k..(bi + 1) * n * k],
                    &mut scratch.gb_blocks[bi * n..(bi + 1) * n],
                    &mut scratch.dyt,
                    &mut scratch.pack,
                );
            }
        }
    } else {
        scratch.gw_blocks.clear();
        scratch.gw_blocks.resize(nblocks * n * k, 0.0);
        scratch.gb_blocks.clear();
        scratch.gb_blocks.resize(nblocks * n, 0.0);
        // Static block partition: block work is uniform, and the partials
        // land in block-indexed slots regardless of which worker ran them.
        // Workers build their own packs; any forced kernel path carries
        // over so dispatch cannot differ between serial and parallel runs.
        let bpw = nblocks.div_ceil(t);
        let forced = scratch.pack.forced_path();
        let run_block = &run_block;
        std::thread::scope(|s| {
            let gw_chunks: Vec<Option<&mut [f32]>> = if k == 0 {
                (0..t).map(|_| None).collect()
            } else {
                scratch.gw_blocks.chunks_mut(bpw * n * k).map(Some).collect()
            };
            for ((wi, gb_chunk), gw_chunk) in
                scratch.gb_blocks.chunks_mut(bpw * n).enumerate().zip(gw_chunks)
            {
                s.spawn(move || {
                    let (mut dyt, mut pack) = (Vec::new(), PackedB::new());
                    pack.force_path(forced);
                    let mut gw_blocks = gw_chunk.map(|c| c.chunks_mut(n * k));
                    for (i, gb_out) in gb_chunk.chunks_mut(n).enumerate() {
                        match &mut gw_blocks {
                            Some(it) => run_block(
                                wi * bpw + i,
                                it.next().expect("gw block slice"),
                                gb_out,
                                &mut dyt,
                                &mut pack,
                            ),
                            None => run_block(
                                wi * bpw + i,
                                &mut [0f32; 0][..],
                                gb_out,
                                &mut dyt,
                                &mut pack,
                            ),
                        }
                    }
                });
            }
        });
    }
    // Ordered merge: always block 0, 1, 2, ... — never worker order.
    for bi in 0..nblocks {
        if k > 0 {
            for (g, p) in g_w.iter_mut().zip(&scratch.gw_blocks[bi * n * k..(bi + 1) * n * k]) {
                *g += p;
            }
        }
        for (g, p) in g_b.iter_mut().zip(&scratch.gb_blocks[bi * n..(bi + 1) * n]) {
            *g += p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Small-integer-valued f32 matrices: every product and partial sum is
    /// an exact integer well below 2^24, so the blocked kernel must equal
    /// the naive triple loop *bitwise*, not just within tolerance.
    fn int_mat(rng: &mut Rng, len: usize, amp: usize) -> Vec<f32> {
        (0..len).map(|_| (rng.below(2 * amp + 1) as i64 - amp as i64) as f32).collect()
    }

    fn naive_nt(a: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for r in 0..m {
            for c in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += a[r * k + kk] * w[c * k + kk];
                }
                out[r * n + c] = acc;
            }
        }
        out
    }

    fn naive_nn(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for r in 0..m {
            for c in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += a[r * k + kk] * b[kk * n + c];
                }
                out[r * n + c] = acc;
            }
        }
        out
    }

    #[test]
    fn nt_and_nn_match_naive_exactly_on_integer_grids() {
        let mut rng = Rng::new(0xF32);
        for _ in 0..30 {
            let m = rng.below(13);
            let n = 1 + rng.below(20);
            let k = rng.below(70);
            let a = int_mat(&mut rng, m * k, 9);
            let w = int_mat(&mut rng, n * k, 9); // [n, k] row-major

            let mut pack = PackedB::new();
            pack.pack_t(&w, n, k);
            let mut out = vec![0f32; m * n];
            pack.matmul(&a, m, &mut out);
            assert_eq!(out, naive_nt(&a, &w, m, n, k), "NT {m}x{n}x{k}");

            // the same w reinterpreted row-major [k', n'] for the NN case
            let (kn, nn) = (n, k);
            if nn > 0 {
                let mut pack2 = PackedB::new();
                pack2.pack_nn(&w, kn, nn);
                let a2 = int_mat(&mut rng, m * kn, 9);
                let mut out2 = vec![0f32; m * nn];
                pack2.matmul(&a2, m, &mut out2);
                assert_eq!(out2, naive_nn(&a2, &w, m, nn, kn), "NN {m}x{nn}x{kn}");
            }
        }
    }

    #[test]
    fn pack_reuse_shrinks_and_grows_cleanly() {
        let mut rng = Rng::new(7);
        let mut pack = PackedB::new();
        for (n, k) in [(17, 40), (3, 5), (20, 64), (1, 0)] {
            let w = int_mat(&mut rng, n * k, 5);
            pack.pack_t(&w, n, k);
            let m = 6;
            let a = int_mat(&mut rng, m * k, 5);
            let mut out = vec![0f32; m * n];
            pack.matmul(&a, m, &mut out);
            assert_eq!(out, naive_nt(&a, &w, m, n, k), "reused pack {n}x{k}");
        }
    }

    #[test]
    fn matmul_par_is_bit_identical_at_any_thread_count() {
        let mut rng = Rng::new(0xBEEF);
        let (m, n, k) = (53, 19, 131);
        // genuinely irrational-ish floats: exercises the claim that row
        // partitioning never reassociates a dot product
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let mut pack = PackedB::new();
        pack.pack_t(&w, n, k);
        let mut base = vec![0f32; m * n];
        pack.matmul(&a, m, &mut base);
        for t in [1, 2, 3, 7, 16] {
            let mut out = vec![0f32; m * n];
            matmul_par(&pack, &a, m, &mut out, t);
            assert_eq!(out, base, "threads={t}");
        }
    }

    #[test]
    fn grad_reduce_matches_naive_and_is_thread_invariant() {
        let mut rng = Rng::new(0x6D);
        for (m, n, k) in [(5, 3, 8), (64, 4, 10), (129, 6, 17), (200, 2, 0), (0, 3, 4)] {
            let dy = int_mat(&mut rng, m * n, 4);
            let a = int_mat(&mut rng, m * k, 4);
            // naive reference
            let mut gw_ref = vec![0f32; n * k];
            let mut gb_ref = vec![0f32; n];
            for r in 0..m {
                for c in 0..n {
                    gb_ref[c] += dy[r * n + c];
                    for kk in 0..k {
                        gw_ref[c * k + kk] += dy[r * n + c] * a[r * k + kk];
                    }
                }
            }
            let mut scratch = GradScratch::default();
            let mut base_w = vec![0f32; n * k];
            let mut base_b = vec![0f32; n];
            grad_reduce(&dy, &a, m, n, k, 1, &mut base_w, &mut base_b, &mut scratch);
            // exact on integer grids only when a single block covers m;
            // multi-block sums are still exact integers here (amp 4, m<=200)
            assert_eq!(base_w, gw_ref, "{m}x{n}x{k} weight grad");
            assert_eq!(base_b, gb_ref, "{m}x{n}x{k} bias grad");
            for t in [2, 3, 7] {
                let mut gw = vec![0f32; n * k];
                let mut gb = vec![0f32; n];
                grad_reduce(&dy, &a, m, n, k, t, &mut gw, &mut gb, &mut scratch);
                assert_eq!(gw, base_w, "threads={t}");
                assert_eq!(gb, base_b, "threads={t}");
            }
        }
    }

    #[test]
    fn grad_reduce_thread_invariance_on_real_floats() {
        let mut rng = Rng::new(0xA2);
        let (m, n, k) = (211, 5, 23);
        let dy: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let mut scratch = GradScratch::default();
        let mut base_w = vec![0f32; n * k];
        let mut base_b = vec![0f32; n];
        grad_reduce(&dy, &a, m, n, k, 1, &mut base_w, &mut base_b, &mut scratch);
        for t in [2, 5, 16] {
            let mut gw = vec![0f32; n * k];
            let mut gb = vec![0f32; n];
            grad_reduce(&dy, &a, m, n, k, t, &mut gw, &mut gb, &mut scratch);
            assert_eq!(gw, base_w, "threads={t}");
            assert_eq!(gb, base_b, "threads={t}");
        }
    }

    #[test]
    fn add_bias_adds_per_column() {
        let mut out = vec![1.0f32, 2.0, 3.0, 4.0];
        add_bias(&mut out, 2, 2, &[10.0, 20.0]);
        assert_eq!(out, vec![11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn env_threads_parses_and_ignores_zero() {
        // no env set in tests: just exercise the parse contract via the
        // public worker helpers
        assert!(hardware_workers() >= 1);
        assert_eq!(gemm_workers(10), 1);
    }

    /// Weight matrix with a prescribed fraction of surviving entries, on an
    /// integer grid so every kernel path must match the naive loop bitwise.
    fn sparse_int_mat(rng: &mut Rng, len: usize, amp: usize, keep: f64) -> Vec<f32> {
        (0..len)
            .map(|_| {
                if rng.uniform() < keep {
                    (rng.below(2 * amp + 1) as i64 - amp as i64) as f32
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn every_kernel_path_matches_naive_bitwise_on_integer_grids() {
        let mut rng = Rng::new(0xD15);
        let paths = [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd];
        for keep in [0.0, 0.5, 1.0] {
            for (m, n, k) in [(9, 11, 37), (4, 8, 64), (13, 3, 5), (6, 20, 0), (0, 7, 12)] {
                let a = int_mat(&mut rng, m * k, 9);
                let w = sparse_int_mat(&mut rng, n * k, 9, keep);
                let want = naive_nt(&a, &w, m, n, k);
                for path in paths {
                    let mut pack = PackedB::new();
                    pack.force_path(Some(path));
                    pack.pack_t(&w, n, k);
                    assert_eq!(pack.path(), path);
                    let mut out = vec![0f32; m * n];
                    pack.matmul(&a, m, &mut out);
                    assert_eq!(out, want, "{path:?} keep={keep} {m}x{n}x{k}");
                }
            }
        }
    }

    #[test]
    fn every_kernel_path_is_thread_invariant_on_real_floats() {
        let mut rng = Rng::new(0x7A7);
        let (m, n, k) = (61, 21, 97);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        for keep in [0.5, 1.0] {
            let w: Vec<f32> = (0..n * k)
                .map(|_| if rng.uniform() < keep { rng.normal() as f32 } else { 0.0 })
                .collect();
            for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
                let mut pack = PackedB::new();
                pack.force_path(Some(path));
                pack.pack_t(&w, n, k);
                let mut base = vec![0f32; m * n];
                pack.matmul(&a, m, &mut base);
                for t in [1, 2, 7] {
                    let mut out = vec![0f32; m * n];
                    matmul_par(&pack, &a, m, &mut out, t);
                    assert_eq!(out, base, "{path:?} keep={keep} threads={t}");
                }
            }
        }
    }

    #[test]
    fn sparse_path_agrees_with_scalar_within_tolerance_on_real_floats() {
        // Real (non-grid) values: FMA and zero-skipping may round
        // differently from the scalar loop, but only within f32 epsilon
        // scale — the paths must stay numerically interchangeable.
        let mut rng = Rng::new(0x10E);
        let (m, n, k) = (23, 14, 61);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..n * k)
            .map(|_| if rng.uniform() < 0.3 { rng.normal() as f32 } else { 0.0 })
            .collect();
        let mut outs = Vec::new();
        for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
            let mut pack = PackedB::new();
            pack.force_path(Some(path));
            pack.pack_t(&w, n, k);
            let mut out = vec![0f32; m * n];
            pack.matmul(&a, m, &mut out);
            outs.push(out);
        }
        for alt in &outs[1..] {
            for (i, (x, y)) in outs[0].iter().zip(alt).enumerate() {
                assert!((x - y).abs() <= 1e-4 * (1.0 + x.abs()), "elem {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn grad_reduce_paths_match_and_stay_thread_invariant() {
        let mut rng = Rng::new(0x96D);
        let (m, n, k) = (137, 6, 19);
        let dy = int_mat(&mut rng, m * n, 4);
        let a = sparse_int_mat(&mut rng, m * k, 4, 0.4);
        let mut want_w = vec![0f32; n * k];
        let mut want_b = vec![0f32; n];
        for r in 0..m {
            for c in 0..n {
                want_b[c] += dy[r * n + c];
                for kk in 0..k {
                    want_w[c * k + kk] += dy[r * n + c] * a[r * k + kk];
                }
            }
        }
        for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
            let mut scratch = GradScratch::default();
            scratch.force_path(Some(path));
            let mut base_w = vec![0f32; n * k];
            let mut base_b = vec![0f32; n];
            grad_reduce(&dy, &a, m, n, k, 1, &mut base_w, &mut base_b, &mut scratch);
            assert_eq!(base_w, want_w, "{path:?} weight grad");
            assert_eq!(base_b, want_b, "{path:?} bias grad");
            for t in [2, 7] {
                let mut gw = vec![0f32; n * k];
                let mut gb = vec![0f32; n];
                grad_reduce(&dy, &a, m, n, k, t, &mut gw, &mut gb, &mut scratch);
                assert_eq!(gw, base_w, "{path:?} threads={t}");
                assert_eq!(gb, base_b, "{path:?} threads={t}");
            }
        }
    }
}
