//! Task metrics: top-1 accuracy, PSNR, logit MAE, sparsity and loss tracking
//! (paper §5.1: classification -> top-1, super-resolution -> PSNR).

use crate::tensor::Tensor;

/// Top-1 accuracy of logits `[batch, classes]` against f32 labels, counting
/// only the first `n_valid` rows (eval batches pad by wrapping).
pub fn top1_accuracy(logits: &Tensor, labels: &[f32], n_valid: usize) -> (u64, u64) {
    let classes = logits.cols();
    let mut correct = 0u64;
    for r in 0..n_valid.min(logits.rows()) {
        let row = logits.row(r);
        let mut arg = 0usize;
        for c in 1..classes {
            if row[c] > row[arg] {
                arg = c;
            }
        }
        if arg as f32 == labels[r] {
            correct += 1;
        }
    }
    (correct, n_valid as u64)
}

/// Peak signal-to-noise ratio over a batch of images in [0, 1]:
/// `10 log10(1 / mse)`. Returns (sum of squared error, pixel count) so
/// callers can aggregate exactly across batches before the log.
pub fn sse(pred: &Tensor, target: &Tensor, n_valid: usize) -> (f64, u64) {
    let per = pred.len() / pred.shape()[0];
    let mut acc = 0.0f64;
    for i in 0..n_valid * per {
        let d = (pred.data()[i] - target.data()[i]) as f64;
        acc += d * d;
    }
    (acc, (n_valid * per) as u64)
}

/// Convert aggregated SSE to PSNR in dB (peak = 1.0).
pub fn psnr_from_sse(sse: f64, count: u64) -> f64 {
    if count == 0 {
        return 0.0;
    }
    let mse = (sse / count as f64).max(1e-12);
    10.0 * (1.0 / mse).log10()
}

/// Mean absolute error between two logit tensors (Fig. 2's y-axis: MAE
/// between P-bit and 32-bit accumulator results).
pub fn logit_mae(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| (x - y).abs() as f64)
        .sum::<f64>()
        / a.len() as f64
}

/// Exponentially-smoothed loss tracker for training logs.
#[derive(Clone, Debug)]
pub struct LossTracker {
    ema: Option<f64>,
    alpha: f64,
    pub history: Vec<(u64, f64)>,
}

impl LossTracker {
    pub fn new(alpha: f64) -> Self {
        Self { ema: None, alpha, history: Vec::new() }
    }

    pub fn push(&mut self, step: u64, loss: f64) {
        let e = match self.ema {
            None => loss,
            Some(prev) => prev * (1.0 - self.alpha) + loss * self.alpha,
        };
        self.ema = Some(e);
        self.history.push((step, loss));
    }

    pub fn smoothed(&self) -> Option<f64> {
        self.ema
    }

    pub fn last(&self) -> Option<f64> {
        self.history.last().map(|(_, l)| *l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy() {
        let logits = Tensor::new(vec![3, 2], vec![0.1, 0.9, 0.8, 0.2, 0.4, 0.6]);
        let (c, n) = top1_accuracy(&logits, &[1.0, 0.0, 0.0], 3);
        assert_eq!((c, n), (2, 3));
        // n_valid truncates padded rows
        let (c, n) = top1_accuracy(&logits, &[1.0, 0.0, 0.0], 2);
        assert_eq!((c, n), (2, 2));
    }

    #[test]
    fn psnr_known_value() {
        let a = Tensor::from_vec(vec![0.5; 100]).reshape(vec![1, 100]);
        let b = Tensor::from_vec(vec![0.6; 100]).reshape(vec![1, 100]);
        let (s, n) = sse(&a, &b, 1);
        let p = psnr_from_sse(s, n);
        assert!((p - 20.0).abs() < 1e-4, "psnr {p}"); // mse = 0.01 -> 20 dB (f32 inputs)
    }

    #[test]
    fn identical_images_have_huge_psnr() {
        let a = Tensor::from_vec(vec![0.3; 16]).reshape(vec![1, 16]);
        let (s, n) = sse(&a, &a, 1);
        assert!(psnr_from_sse(s, n) > 100.0);
    }

    #[test]
    fn mae() {
        let a = Tensor::from_vec(vec![1.0, 2.0]);
        let b = Tensor::from_vec(vec![0.0, 4.0]);
        assert_eq!(logit_mae(&a, &b), 1.5);
    }

    #[test]
    fn loss_tracker_smooths() {
        let mut t = LossTracker::new(0.5);
        t.push(0, 4.0);
        t.push(1, 2.0);
        assert_eq!(t.smoothed(), Some(3.0));
        assert_eq!(t.last(), Some(2.0));
        assert_eq!(t.history.len(), 2);
    }
}
