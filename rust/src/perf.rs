//! Perf journaling: machine-readable benchmark records tracked across PRs.
//!
//! The bench harness (`rust/benches/harness.rs`) and the `bench_smoke` test
//! funnel their measurements through this module, which maintains
//! `BENCH_accsim.json` at the repo root (one `{name, ns_per_iter, mac_per_s}`
//! object per bench, merged by name so independent bench binaries don't
//! clobber each other) and refreshes the auto-recorded block of
//! EXPERIMENTS.md §Perf between its `PERF:BEGIN`/`PERF:END` markers.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};

use crate::json::Json;

/// One benchmark measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Stable bench name, e.g. `accsim/psweep25_fused`.
    pub name: String,
    /// Median wall time per iteration in nanoseconds.
    pub ns_per_iter: f64,
    /// Throughput in MACs per second, when the bench has a MAC count.
    pub mac_per_s: Option<f64>,
    /// Measured weight sparsity (fraction of zero codes) of the layer the
    /// bench ran on, when the bench compares kernel dispatch paths.
    pub sparsity: Option<f64>,
}

/// Repository root (the workspace directory holding EXPERIMENTS.md).
///
/// Resolved at *runtime* by walking up from the current directory, so a
/// binary built in one checkout and run from another writes the running
/// checkout's journal; the compile-time CARGO_MANIFEST_DIR is only the
/// fallback when no workspace marker is found above the CWD.
pub fn repo_root() -> PathBuf {
    if let Ok(mut dir) = std::env::current_dir() {
        loop {
            if dir.join("EXPERIMENTS.md").exists()
                || (dir.join("Cargo.toml").exists() && dir.join("rust").is_dir())
            {
                return dir;
            }
            if !dir.pop() {
                break;
            }
        }
    }
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."))
}

/// Default journal path: `<repo>/BENCH_accsim.json`.
pub fn bench_json_path() -> PathBuf {
    repo_root().join("BENCH_accsim.json")
}

/// Default experiments log path: `<repo>/EXPERIMENTS.md`.
pub fn experiments_path() -> PathBuf {
    repo_root().join("EXPERIMENTS.md")
}

fn record_to_json(r: &BenchRecord) -> Json {
    Json::obj(vec![
        ("name", Json::Str(r.name.clone())),
        ("ns_per_iter", Json::Num(r.ns_per_iter)),
        (
            "mac_per_s",
            // A non-finite rate (e.g. a 0ns median divided through) would
            // serialize as invalid JSON and poison the whole journal; drop
            // the rate, keep the record.
            match r.mac_per_s {
                Some(v) if v.is_finite() => Json::Num(v),
                _ => Json::Null,
            },
        ),
        (
            "sparsity",
            match r.sparsity {
                Some(v) if v.is_finite() => Json::Num(v),
                _ => Json::Null,
            },
        ),
    ])
}

fn record_from_json(v: &Json) -> Result<BenchRecord> {
    Ok(BenchRecord {
        name: v.get("name")?.as_str()?.to_string(),
        ns_per_iter: v.get("ns_per_iter")?.as_f64()?,
        mac_per_s: match v.opt("mac_per_s") {
            None | Some(Json::Null) => None,
            Some(other) => Some(other.as_f64()?),
        },
        // journals written before the kernel-dispatch work have no
        // sparsity column; absent parses as None
        sparsity: match v.opt("sparsity") {
            None | Some(Json::Null) => None,
            Some(other) => Some(other.as_f64()?),
        },
    })
}

/// Parse a journal file's contents.
pub fn parse_journal(text: &str) -> Result<Vec<BenchRecord>> {
    match Json::parse(text)? {
        Json::Arr(items) => items.iter().map(record_from_json).collect(),
        other => anyhow::bail!("expected a JSON array of bench records, got {other:?}"),
    }
}

/// Serialize records one-object-per-line (diff-friendly across PRs).
pub fn render_journal(records: &[BenchRecord]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&record_to_json(r).to_string());
        out.push_str(if i + 1 == records.len() { "\n" } else { ",\n" });
    }
    out.push_str("]\n");
    out
}

/// Load the journal at `path` for merging. An absent or blank file —
/// including the checked-in literal two-line empty array a toolchain-less
/// container leaves behind — is an empty journal; any other read or parse
/// failure is an error, so a corrupt journal aborts the merge instead of
/// silently restarting the perf trajectory from scratch.
pub fn load_journal(path: &Path) -> Result<Vec<BenchRecord>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e.into()),
    };
    if text.trim().is_empty() {
        return Ok(Vec::new());
    }
    parse_journal(&text)
}

/// Merge `records` into the journal at `path` (by name; existing entries
/// with the same name are replaced, unknown ones preserved) and write it
/// back sorted by name. Missing/empty journals start fresh; a corrupt one
/// is an error (see [`load_journal`]).
pub fn record_benches_at(records: &[BenchRecord], path: &Path) -> Result<()> {
    let mut merged: Vec<BenchRecord> = load_journal(path)?;
    for r in records {
        match merged.iter_mut().find(|m| m.name == r.name) {
            Some(slot) => *slot = r.clone(),
            None => merged.push(r.clone()),
        }
    }
    merged.sort_by(|a, b| a.name.cmp(&b.name));
    ensure!(
        merged.iter().all(|r| r.ns_per_iter.is_finite()),
        "non-finite ns_per_iter in bench records"
    );
    std::fs::write(path, render_journal(&merged))?;
    Ok(())
}

/// Merge into the default `BENCH_accsim.json`; returns the path written.
pub fn record_benches(records: &[BenchRecord]) -> Result<PathBuf> {
    let path = bench_json_path();
    record_benches_at(records, &path)?;
    Ok(path)
}

/// Markers of the release-bench §Perf block (`cargo bench`).
pub const PERF_BEGIN: &str = "<!-- PERF:BEGIN (auto-recorded; do not edit by hand) -->";
pub const PERF_END: &str = "<!-- PERF:END -->";
/// Markers of the smoke block (`cargo test`, debug profile).
pub const SMOKE_BEGIN: &str = "<!-- PERF-SMOKE:BEGIN (auto-recorded; do not edit by hand) -->";
pub const SMOKE_END: &str = "<!-- PERF-SMOKE:END -->";
/// Markers of the network-forward release block (`cargo bench --bench
/// network_forward`).
pub const NET_BEGIN: &str = "<!-- PERF-NET:BEGIN (auto-recorded; do not edit by hand) -->";
pub const NET_END: &str = "<!-- PERF-NET:END -->";
/// Markers of the network-forward smoke block (`cargo test`, debug profile).
pub const NET_SMOKE_BEGIN: &str =
    "<!-- PERF-NET-SMOKE:BEGIN (auto-recorded; do not edit by hand) -->";
pub const NET_SMOKE_END: &str = "<!-- PERF-NET-SMOKE:END -->";
/// Markers of the native train-step release block (`cargo bench --bench
/// train_step`).
pub const TRAIN_BEGIN: &str = "<!-- PERF-TRAIN:BEGIN (auto-recorded; do not edit by hand) -->";
pub const TRAIN_END: &str = "<!-- PERF-TRAIN:END -->";
/// Markers of the streaming-delta release block (`cargo bench --bench
/// stream_delta`).
pub const STREAM_BEGIN: &str = "<!-- PERF-STREAM:BEGIN (auto-recorded; do not edit by hand) -->";
pub const STREAM_END: &str = "<!-- PERF-STREAM:END -->";
/// Markers of the serving-latency block (`a2q loadgen --journal`).
pub const SERVE_BEGIN: &str = "<!-- PERF-SERVE:BEGIN (auto-recorded; do not edit by hand) -->";
pub const SERVE_END: &str = "<!-- PERF-SERVE:END -->";

/// Replace whatever sits between `begin` and `end` markers in EXPERIMENTS.md
/// with `block`. Returns false (and leaves the file alone) when the file or
/// its markers are absent.
pub fn update_marked_block(begin: &str, end: &str, block: &str) -> Result<bool> {
    let path = experiments_path();
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(false),
        Err(e) => return Err(e.into()),
    };
    let (Some(b), Some(e)) = (text.find(begin), text.find(end)) else {
        return Ok(false);
    };
    ensure!(b < e, "EXPERIMENTS.md markers out of order");
    let mut out = String::with_capacity(text.len() + block.len());
    out.push_str(&text[..b + begin.len()]);
    out.push('\n');
    out.push_str(block.trim_end());
    out.push('\n');
    out.push_str(&text[e..]);
    std::fs::write(&path, out)?;
    Ok(true)
}

/// Render the standard baseline-vs-fused P-sweep comparison block both
/// perf instruments write into EXPERIMENTS.md, so the table format lives
/// in exactly one place. `recorded_by` names the instrument (and profile),
/// `shape` the swept grid.
pub fn render_psweep_block(
    recorded_by: &str,
    baseline: &BenchRecord,
    fused: &BenchRecord,
    shape: &str,
) -> String {
    let speedup = baseline.ns_per_iter / fused.ns_per_iter.max(1.0);
    format!(
        "Last recorded by {recorded_by}:\n\n\
         | bench | ns/iter (median) | M MAC/s |\n|---|---:|---:|\n\
         | {} | {:.0} | {:.0} |\n\
         | {} | {:.0} | {:.0} |\n\n\
         **Fused engine speedup over the per-P scalar baseline: {speedup:.1}x** ({shape}).",
        baseline.name,
        baseline.ns_per_iter,
        baseline.mac_per_s.unwrap_or(0.0) / 1e6,
        fused.name,
        fused.ns_per_iter,
        fused.mac_per_s.unwrap_or(0.0) / 1e6,
    )
}

/// Replace the release-bench block of EXPERIMENTS.md §Perf.
pub fn update_experiments_block(block: &str) -> Result<bool> {
    update_marked_block(PERF_BEGIN, PERF_END, block)
}

/// Replace the smoke (cargo test) block of EXPERIMENTS.md §Perf.
pub fn update_experiments_smoke_block(block: &str) -> Result<bool> {
    update_marked_block(SMOKE_BEGIN, SMOKE_END, block)
}

/// Replace the network-forward release block of EXPERIMENTS.md §Perf.
pub fn update_experiments_net_block(block: &str) -> Result<bool> {
    update_marked_block(NET_BEGIN, NET_END, block)
}

/// Replace the network-forward smoke block of EXPERIMENTS.md §Perf.
pub fn update_experiments_net_smoke_block(block: &str) -> Result<bool> {
    update_marked_block(NET_SMOKE_BEGIN, NET_SMOKE_END, block)
}

/// One measured compute path of the native `train_step` bench.
pub struct TrainRow {
    /// Journal name, e.g. `native/trainstep_mlp3_blocked`.
    pub name: String,
    pub ns_per_iter: f64,
    pub rows_per_s: f64,
}

/// Render a grouped baseline-vs-variants comparison table: rows come in
/// groups sharing an iteration shape, and speedups are reported against
/// each group's first (baseline) row under the `vs {vs_label}` column.
/// Shared by the train-step and streaming-delta EXPERIMENTS.md blocks.
pub fn render_rows_block(
    recorded_by: &str,
    vs_label: &str,
    groups: &[(&str, Vec<TrainRow>)],
) -> String {
    let mut out = format!("Last recorded by {recorded_by}:\n");
    for (shape, rows) in groups {
        out.push_str(&format!(
            "\n**{shape}**\n\n| path | ns/iter (median) | rows/s | vs {vs_label} |\n|---|---:|---:|---:|\n"
        ));
        let base = rows.first().map(|r| r.ns_per_iter).unwrap_or(0.0);
        for r in rows {
            out.push_str(&format!(
                "| {} | {:.0} | {:.0} | {:.2}x |\n",
                r.name,
                r.ns_per_iter,
                r.rows_per_s,
                base / r.ns_per_iter.max(1.0)
            ));
        }
    }
    out
}

/// Render the scalar-reference vs blocked vs batch-parallel comparison the
/// `train_step` bench writes into EXPERIMENTS.md §Perf-Train.
pub fn render_train_block(recorded_by: &str, groups: &[(&str, Vec<TrainRow>)]) -> String {
    render_rows_block(recorded_by, "scalar", groups)
}

/// Render the full-forward vs incremental-delta comparison the
/// `stream_delta` bench writes into EXPERIMENTS.md §Perf-Stream.
pub fn render_stream_block(recorded_by: &str, groups: &[(&str, Vec<TrainRow>)]) -> String {
    render_rows_block(recorded_by, "full fwd", groups)
}

/// Replace the native train-step release block of EXPERIMENTS.md.
pub fn update_experiments_train_block(block: &str) -> Result<bool> {
    update_marked_block(TRAIN_BEGIN, TRAIN_END, block)
}

/// Replace the streaming-delta release block of EXPERIMENTS.md.
pub fn update_experiments_stream_block(block: &str) -> Result<bool> {
    update_marked_block(STREAM_BEGIN, STREAM_END, block)
}

/// Replace the serving-latency block of EXPERIMENTS.md §Perf-Serve.
pub fn update_experiments_serve_block(block: &str) -> Result<bool> {
    update_marked_block(SERVE_BEGIN, SERVE_END, block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    fn rec(name: &str, ns: f64, macs: Option<f64>) -> BenchRecord {
        BenchRecord { name: name.into(), ns_per_iter: ns, mac_per_s: macs, sparsity: None }
    }

    #[test]
    fn journal_round_trip_and_merge() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("BENCH_accsim.json");
        record_benches_at(&[rec("b", 200.0, None), rec("a", 100.0, Some(1e9))], &path).unwrap();
        let loaded = parse_journal(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].name, "a"); // sorted
        assert_eq!(loaded[0].mac_per_s, Some(1e9));
        assert_eq!(loaded[1].mac_per_s, None);

        // merge: replace `a`, keep `b`, add `c`
        record_benches_at(&[rec("a", 50.0, Some(2e9)), rec("c", 1.0, None)], &path).unwrap();
        let loaded = parse_journal(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded[0].ns_per_iter, 50.0);
        assert_eq!(loaded[1].name, "b");
        assert_eq!(loaded[2].name, "c");
    }

    #[test]
    fn non_finite_rate_is_dropped_not_corrupting() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("j.json");
        record_benches_at(&[rec("inf", 1.0, Some(f64::INFINITY)), rec("ok", 2.0, Some(5.0))], &path)
            .unwrap();
        // the journal must stay parseable and keep the record minus the rate
        let loaded = parse_journal(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(loaded[0].name, "inf");
        assert_eq!(loaded[0].mac_per_s, None);
        assert_eq!(loaded[1].mac_per_s, Some(5.0));
    }

    #[test]
    fn train_block_renders_groups_and_speedups() {
        let rows = vec![
            TrainRow {
                name: "native/trainstep_mlp3_scalar".into(),
                ns_per_iter: 1000.0,
                rows_per_s: 10.0,
            },
            TrainRow {
                name: "native/trainstep_mlp3_blocked".into(),
                ns_per_iter: 250.0,
                rows_per_s: 40.0,
            },
        ];
        let block = render_train_block("test", &[("mlp3 @ M4N4P14", rows)]);
        assert!(block.contains("**mlp3 @ M4N4P14**"), "{block}");
        assert!(block.contains("| native/trainstep_mlp3_scalar | 1000 | 10 | 1.00x |"), "{block}");
        assert!(block.contains("| native/trainstep_mlp3_blocked | 250 | 40 | 4.00x |"), "{block}");
    }

    #[test]
    fn absent_and_blank_journals_merge_as_empty() {
        let dir = TempDir::new().unwrap();
        // Absent file.
        let absent = dir.path().join("nope.json");
        assert_eq!(load_journal(&absent).unwrap(), vec![]);
        record_benches_at(&[rec("a", 1.0, None)], &absent).unwrap();
        assert_eq!(load_journal(&absent).unwrap().len(), 1);
        // Truly empty and whitespace-only files.
        for (i, blank) in ["", "  \n\t\n"].iter().enumerate() {
            let p = dir.path().join(format!("blank{i}.json"));
            std::fs::write(&p, blank).unwrap();
            assert_eq!(load_journal(&p).unwrap(), vec![], "{blank:?}");
            record_benches_at(&[rec("x", 2.0, None)], &p).unwrap();
            assert_eq!(load_journal(&p).unwrap().len(), 1, "{blank:?}");
        }
        // The checked-in placeholder: a literal two-line empty array.
        let seed = dir.path().join("seed.json");
        std::fs::write(&seed, "[\n]\n").unwrap();
        assert_eq!(load_journal(&seed).unwrap(), vec![]);
        record_benches_at(&[rec("s", 3.0, None)], &seed).unwrap();
        assert_eq!(load_journal(&seed).unwrap().len(), 1);
    }

    #[test]
    fn corrupt_journal_is_an_error_not_a_silent_restart() {
        let dir = TempDir::new().unwrap();
        let p = dir.path().join("bad.json");
        std::fs::write(&p, "{\"oops\": true}").unwrap();
        assert!(load_journal(&p).is_err());
        // The merge must refuse to clobber the corrupt file.
        assert!(record_benches_at(&[rec("a", 1.0, None)], &p).is_err());
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"oops\": true}");
    }

    #[test]
    fn stream_block_reports_speedup_vs_full_forward() {
        let rows = vec![
            TrainRow {
                name: "accsim/stream_full_forward".into(),
                ns_per_iter: 800.0,
                rows_per_s: 20.0,
            },
            TrainRow {
                name: "accsim/stream_delta_d05".into(),
                ns_per_iter: 200.0,
                rows_per_s: 80.0,
            },
        ];
        let block = render_stream_block("test", &[("layer 64x64 @ d=5%", rows)]);
        assert!(block.contains("vs full fwd"), "{block}");
        assert!(block.contains("| accsim/stream_delta_d05 | 200 | 80 | 4.00x |"), "{block}");
    }

    #[test]
    fn journal_text_is_stable_json() {
        let text = render_journal(&[rec("x", 1.5, Some(3.0))]);
        assert!(text.starts_with("[\n  {"));
        let back = parse_journal(&text).unwrap();
        assert_eq!(back, vec![rec("x", 1.5, Some(3.0))]);
    }

    #[test]
    fn sparsity_round_trips_and_old_journals_still_parse() {
        let mut r = rec("kpath", 9.0, Some(1e6));
        r.sparsity = Some(0.75);
        let back = parse_journal(&render_journal(&[r.clone()])).unwrap();
        assert_eq!(back, vec![r]);
        // journals written before the sparsity column existed
        let old = "[\n  {\"name\": \"x\", \"ns_per_iter\": 2, \"mac_per_s\": null}\n]\n";
        let back = parse_journal(old).unwrap();
        assert_eq!(back[0].sparsity, None);
    }
}
