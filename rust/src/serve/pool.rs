//! Pooled request/reply buffers: the allocation backbone of the serve hot
//! path.
//!
//! Every infer request owns one [`PooledBuf`] for its whole lifetime: the
//! session decodes wire codes straight into its [`IntMatrix`], the buffer
//! rides through admission queue → batcher → worker, the worker encodes the
//! complete wire reply (JSON line or binary frame) into its byte buffer,
//! and the session writes those bytes to the socket. Dropping the buffer —
//! on the happy path, on a shed, on a typed rejection, or while a panic
//! unwinds — returns its storage to the [`BufferPool`], so a warmed server
//! recycles the same handful of allocations forever (pinned by
//! `tests/serve_alloc.rs`).
//!
//! Sizing: the pool retains up to `retain` idle buffers. The server sizes
//! it as `queue_capacity + 2 * workers + 8` — enough for a full admission
//! queue plus every worker's in-flight batch plus sessions mid-decode —
//! so steady state never constructs a fresh buffer and never frees one.
//! Beyond `retain`, returned buffers are simply dropped (a burst shrinks
//! back to the cap instead of holding peak memory forever).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::accsim::IntMatrix;

/// Recycled storage of one spent [`PooledBuf`]: the request codes vector
/// (extracted from its `IntMatrix`) and the reply byte vector, both cleared
/// but keeping their grown capacity.
struct BufParts {
    codes: Vec<i64>,
    reply: Vec<u8>,
}

/// A bounded free-list of request/reply buffer storage.
pub struct BufferPool {
    free: Mutex<Vec<BufParts>>,
    retain: usize,
    /// Buffers constructed fresh because the free list was empty — a
    /// steady-state server stops incrementing this after warmup.
    fresh: AtomicU64,
}

impl BufferPool {
    /// Pool retaining up to `retain` idle buffers. The free list is
    /// pre-reserved so returning a buffer never allocates.
    pub fn new(retain: usize) -> BufferPool {
        let retain = retain.max(1);
        BufferPool {
            free: Mutex::new(Vec::with_capacity(retain)),
            retain,
            fresh: AtomicU64::new(0),
        }
    }

    /// Take a buffer (recycled if available, fresh otherwise). The returned
    /// buffer is empty; callers shape the input with
    /// [`IntMatrix::reset`] via [`PooledBuf::input_mut`].
    pub fn acquire(self: &Arc<Self>) -> PooledBuf {
        let parts = self.free.lock().unwrap().pop();
        let parts = parts.unwrap_or_else(|| {
            self.fresh.fetch_add(1, Ordering::Relaxed);
            BufParts { codes: Vec::new(), reply: Vec::new() }
        });
        PooledBuf {
            pool: Some(Arc::clone(self)),
            input: IntMatrix::from_flat(0, 0, parts.codes),
            reply: parts.reply,
        }
    }

    /// Number of idle buffers currently in the free list.
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// How many buffers were ever constructed fresh (free list empty).
    pub fn fresh_count(&self) -> u64 {
        self.fresh.load(Ordering::Relaxed)
    }

    fn release(&self, mut parts: BufParts) {
        parts.codes.clear();
        parts.reply.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.retain {
            free.push(parts);
        }
        // else: drop outside the pool cap — bursts shrink back down.
    }
}

/// One request's owned buffers: the decoded input codes and the encoded
/// wire reply. Travels by value with the request through every serve stage;
/// its storage returns to the pool on drop (every path — replies, sheds,
/// typed errors, unwinding panics — converges here).
pub struct PooledBuf {
    pool: Option<Arc<BufferPool>>,
    input: IntMatrix,
    reply: Vec<u8>,
}

impl PooledBuf {
    /// A pool-less buffer (dropped storage is simply freed). For tests and
    /// one-shot callers that want the `PooledBuf` API without a server.
    pub fn detached(input: IntMatrix) -> PooledBuf {
        PooledBuf { pool: None, input, reply: Vec::new() }
    }

    /// The decoded request rows.
    pub fn input(&self) -> &IntMatrix {
        &self.input
    }

    /// Mutable access for the session's wire decode
    /// ([`IntMatrix::reset`] to shape, then fill `data_mut`).
    pub fn input_mut(&mut self) -> &mut IntMatrix {
        &mut self.input
    }

    /// The encoded wire reply bytes (written by the worker).
    pub fn reply(&self) -> &[u8] {
        &self.reply
    }

    pub fn reply_mut(&mut self) -> &mut Vec<u8> {
        &mut self.reply
    }
}

impl std::fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PooledBuf")
            .field("rows", &self.input.rows())
            .field("cols", &self.input.cols())
            .field("reply_len", &self.reply.len())
            .field("pooled", &self.pool.is_some())
            .finish()
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            let input = std::mem::replace(&mut self.input, IntMatrix::from_flat(0, 0, Vec::new()));
            let parts =
                BufParts { codes: input.into_data(), reply: std::mem::take(&mut self.reply) };
            pool.release(parts);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_recycle_storage_through_the_pool() {
        let pool = Arc::new(BufferPool::new(4));
        let mut buf = pool.acquire();
        assert_eq!(pool.fresh_count(), 1);
        buf.input_mut().reset(3, 5);
        buf.input_mut().data_mut()[14] = 42;
        buf.reply_mut().extend_from_slice(b"hello");
        let codes_ptr = buf.input().data().as_ptr();
        drop(buf);
        assert_eq!(pool.pooled(), 1);

        // Reacquire: same storage, cleared, no fresh construction.
        let mut buf = pool.acquire();
        assert_eq!(pool.fresh_count(), 1, "recycled, not rebuilt");
        assert_eq!(pool.pooled(), 0);
        assert!(buf.input().is_empty());
        assert!(buf.reply().is_empty());
        buf.input_mut().reset(3, 5);
        assert_eq!(buf.input().data().as_ptr(), codes_ptr, "storage was recycled");
        assert!(buf.input().data().iter().all(|&v| v == 0), "recycled codes are zeroed");
    }

    #[test]
    fn pool_retains_at_most_its_cap() {
        let pool = Arc::new(BufferPool::new(2));
        let bufs: Vec<PooledBuf> = (0..5).map(|_| pool.acquire()).collect();
        assert_eq!(pool.fresh_count(), 5);
        drop(bufs);
        assert_eq!(pool.pooled(), 2, "excess buffers are freed, not hoarded");
    }

    #[test]
    fn detached_buffers_skip_the_pool() {
        let m = IntMatrix::from_flat(2, 2, vec![1, 2, 3, 4]);
        let buf = PooledBuf::detached(m);
        assert_eq!(buf.input().rows(), 2);
        drop(buf); // no pool to return to; must not panic
    }

    #[test]
    fn exhausted_pool_constructs_fresh_then_recovers_to_high_water() {
        // A burst past the retain cap must never fail — acquire() always
        // hands out a buffer, constructing fresh once the free list is dry.
        let pool = Arc::new(BufferPool::new(3));
        let burst: Vec<PooledBuf> = (0..10).map(|_| pool.acquire()).collect();
        assert_eq!(pool.fresh_count(), 10, "every buffer past the empty free list is fresh");
        assert_eq!(pool.pooled(), 0);
        drop(burst);
        // The free list settles at the high-water mark (retain), not at the
        // burst size — the excess storage is freed, not hoarded.
        assert_eq!(pool.pooled(), 3);
        // Steady state after the burst: retain-many concurrent buffers
        // recycle without a single fresh construction.
        let steady: Vec<PooledBuf> = (0..3).map(|_| pool.acquire()).collect();
        assert_eq!(pool.fresh_count(), 10, "post-burst acquires recycle, never rebuild");
        assert_eq!(pool.pooled(), 0);
        drop(steady);
        assert_eq!(pool.pooled(), 3);
        // One past retain is the exact boundary where fresh resumes.
        let held: Vec<PooledBuf> = (0..4).map(|_| pool.acquire()).collect();
        assert_eq!(pool.fresh_count(), 11, "retain+1 concurrent buffers need one fresh build");
        drop(held);
        assert_eq!(pool.pooled(), 3);
    }

    #[test]
    fn shed_and_rejection_paths_return_buffers_to_the_pool() {
        use std::time::Duration;

        use crate::serve::admission::{AdmissionQueue, JobRequest, ReplySlot, ServeStats};
        use crate::serve::error::ServeError;
        use crate::serve::wire::WireFormat;

        let pool = Arc::new(BufferPool::new(8));
        let long = Duration::from_secs(60);
        let mk = |id: u64| {
            let slot = ReplySlot::new();
            let mut buf = pool.acquire();
            buf.input_mut().reset(1, 4);
            (JobRequest::new(id, 7, WireFormat::Json, buf, long, slot.sender()), slot)
        };

        // Overload shed: the refused request's buffer must come back.
        let q = AdmissionQueue::new(1);
        let stats = ServeStats::default();
        let (a, _ra) = mk(1);
        let (b, _rb) = mk(2);
        q.submit(a).unwrap();
        let rejected = q.submit(b).unwrap_err();
        assert!(matches!(rejected.error, ServeError::Overloaded { .. }));
        rejected.request.cancel();
        assert_eq!(pool.pooled(), 1, "cancelled rejection must recycle its buffer");

        // Typed rejection (the worker-panic / shutdown path): same story.
        let (c, rc) = mk(3);
        c.reject(ServeError::WorkerPanicked { batch_seq: 9 });
        assert!(rc.recv().is_err());
        assert_eq!(pool.pooled(), 2, "reject() must recycle its buffer");

        // Drain the admitted request through the queue and close: every
        // buffer this test acquired is back in the free list — nothing
        // leaked through any path.
        let mut batch = Vec::new();
        q.next_batch(4, Duration::ZERO, &stats, &mut batch).unwrap();
        batch.drain(..).for_each(JobRequest::cancel);
        q.close(&stats);
        assert_eq!(pool.pooled(), 3, "all acquired buffers returned");
        assert_eq!(pool.fresh_count(), 3);
    }
}
