//! Replica management: the health state machine, the prober that drives
//! it, and spawned-child lifecycle (spawn, drain-restart, respawn).
//!
//! Each backend replica carries one of four states:
//!
//! ```text
//!        probe ok                probe/forward failure
//!   Up ───────────── Up      Up ──────────────────────▶ Degraded
//!   Degraded ───────▶ Up     Degraded ──(threshold)───▶ Down
//!   Down ───────────▶ Up     Down ────────────────────▶ Down
//!   (admin drain) anything ─▶ Draining ─(resume/restart)▶ Down → Up
//! ```
//!
//! * **Up** — routable. **Degraded** — routable, but it has recent
//!   failures below the breaker threshold (picked only when no Up replica
//!   exists). **Down** — the circuit breaker is open: the proxy never
//!   routes here, but the prober keeps pinging (that *is* the half-open
//!   probe), and one successful pong re-admits the replica. **Draining** —
//!   admin-quiesced: not routable, while its queued/executing work
//!   completes.
//!
//! The breaker counts *consecutive* failures from both probes and proxy
//! forwards; any success resets it. Kill -9 on a replica therefore costs
//! at most `threshold` failed requests (each retried elsewhere) before the
//! router stops sending traffic, and a restarted replica re-enters the
//! pool within one probe interval with no operator action.
//!
//! Drain-to-restart is the zero-loss failover primitive: `drain` marks the
//! replica Draining and forwards the wire drain op (the replica starts
//! refusing new work typed); the prober watches its pong's `in_flight`
//! gauge; at zero a *spawned* replica is killed and respawned fresh
//! (attached replicas wait for an explicit `resume`). Nothing in flight is
//! ever abandoned.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::super::wire;
use crate::json::Json;

/// One backend entry of the router's pool.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// An already-running `a2q serve` at this address. The router never
    /// manages its process — drain holds until an explicit resume.
    Attached(String),
    /// A replica the router spawns itself (`a2q serve --addr 127.0.0.1:0`)
    /// and may kill/respawn: `models` is the child's `--models` value.
    Spawn { models: String, workers: usize },
}

/// The health state machine (see module docs for transitions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    Up,
    Degraded,
    Down,
    Draining,
}

impl HealthState {
    pub fn as_str(self) -> &'static str {
        match self {
            HealthState::Up => "up",
            HealthState::Degraded => "degraded",
            HealthState::Down => "down",
            HealthState::Draining => "draining",
        }
    }
}

/// Router-level counters (the `stats` admin op surfaces them).
#[derive(Debug, Default)]
pub struct RouterStats {
    /// Requests relayed to a backend (each counted once, not per attempt).
    pub forwarded: AtomicU64,
    /// Extra attempts beyond each request's first.
    pub retries: AtomicU64,
    /// Hedge attempts launched (tail-latency duplicates).
    pub hedges: AtomicU64,
    /// Hedges whose duplicate finished first.
    pub hedge_wins: AtomicU64,
    /// Requests shed typed `no_backend` (no routable replica).
    pub shed_no_backend: AtomicU64,
    /// Spawned replicas restarted (drain-restart or crash respawn).
    pub respawns: AtomicU64,
    pub probes_ok: AtomicU64,
    pub probes_failed: AtomicU64,
}

#[derive(Debug)]
struct ReplicaInner {
    addr: String,
    state: HealthState,
    /// Consecutive probe/forward failures (the breaker input).
    failures: u32,
    /// Last pong's in-flight gauge (drain watches this reach zero).
    in_flight: u64,
    /// Last pong's drain flag (stats mirror of the replica's own view).
    reports_draining: bool,
    child: Option<Child>,
}

/// One replica: its spec plus the mutable health state.
#[derive(Debug)]
pub struct Replica {
    spec: BackendSpec,
    inner: Mutex<ReplicaInner>,
}

/// Point-in-time view of one replica (the `stats` admin op's rows).
#[derive(Clone, Debug)]
pub struct ReplicaSnapshot {
    pub addr: String,
    pub state: HealthState,
    pub failures: u32,
    pub in_flight: u64,
    /// What the replica's own last pong said about its drain flag (can lag
    /// or disagree with the router's `state` across a restart).
    pub reports_draining: bool,
    pub spawned: bool,
}

impl ReplicaSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("addr", Json::str(self.addr.as_str())),
            ("state", Json::str(self.state.as_str())),
            ("failures", Json::num(self.failures as f64)),
            ("in_flight", Json::num(self.in_flight as f64)),
            ("reports_draining", Json::Bool(self.reports_draining)),
            ("spawned", Json::Bool(self.spawned)),
        ])
    }
}

/// The router's replica pool. Pick/record methods are called from proxy
/// sessions; probe/respawn from the single prober thread.
pub struct ReplicaSet {
    replicas: Vec<Replica>,
    rr: AtomicUsize,
    breaker_threshold: u32,
    respawn: bool,
}

/// Replica count ceiling: `pick` exclusion travels as a u64 bitmask.
pub const MAX_REPLICAS: usize = 64;

impl ReplicaSet {
    /// Build the pool: attach addresses as given, spawn children for spawn
    /// specs (startup fails if any child fails to come up — a router with
    /// fewer replicas than asked is a silent capacity lie).
    pub fn start(
        specs: &[BackendSpec],
        breaker_threshold: u32,
        respawn: bool,
    ) -> anyhow::Result<ReplicaSet> {
        anyhow::ensure!(!specs.is_empty(), "a2q route needs at least one backend");
        anyhow::ensure!(
            specs.len() <= MAX_REPLICAS,
            "at most {MAX_REPLICAS} replicas (got {})",
            specs.len()
        );
        let mut replicas = Vec::with_capacity(specs.len());
        for spec in specs {
            let (addr, child) = match spec {
                BackendSpec::Attached(addr) => (addr.clone(), None),
                BackendSpec::Spawn { models, workers } => {
                    let (child, addr) = spawn_replica(models, *workers)?;
                    (addr, Some(child))
                }
            };
            replicas.push(Replica {
                spec: spec.clone(),
                inner: Mutex::new(ReplicaInner {
                    addr,
                    // Start Up: backends were just spawned/attached, and a
                    // wrong guess self-corrects within one probe interval.
                    state: HealthState::Up,
                    failures: 0,
                    in_flight: 0,
                    reports_draining: false,
                    child,
                }),
            });
        }
        Ok(ReplicaSet {
            replicas,
            rr: AtomicUsize::new(0),
            breaker_threshold: breaker_threshold.max(1),
            respawn,
        })
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn addr(&self, i: usize) -> String {
        self.replicas[i].inner.lock().unwrap().addr.clone()
    }

    /// Index of the replica currently at `addr` (admin ops name replicas
    /// by address).
    pub fn find(&self, addr: &str) -> Option<usize> {
        self.replicas.iter().position(|r| r.inner.lock().unwrap().addr == addr)
    }

    /// Pick a routable replica, skipping `exclude` (bitmask of indices
    /// already tried this request). Round-robin over Up replicas; if none,
    /// a second pass accepts Degraded (better a shaky replica than a
    /// typed shed). Down and Draining are never picked.
    pub fn pick(&self, exclude: u64) -> Option<usize> {
        let n = self.replicas.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        for accept_degraded in [false, true] {
            for k in 0..n {
                let i = (start + k) % n;
                if exclude & (1u64 << i) != 0 {
                    continue;
                }
                let st = self.replicas[i].inner.lock().unwrap().state;
                let ok = match st {
                    HealthState::Up => true,
                    HealthState::Degraded => accept_degraded,
                    HealthState::Down | HealthState::Draining => false,
                };
                if ok {
                    return Some(i);
                }
            }
        }
        None
    }

    /// A proxy forward (or probe) against replica `i` succeeded: reset the
    /// breaker and re-admit unless the replica is admin-drained.
    pub fn record_success(&self, i: usize) {
        let mut inner = self.replicas[i].inner.lock().unwrap();
        inner.failures = 0;
        if inner.state != HealthState::Draining {
            inner.state = HealthState::Up;
        }
    }

    /// A transport-level failure against replica `i`: count it toward the
    /// breaker; at the threshold the breaker opens (Down).
    pub fn record_failure(&self, i: usize) {
        let mut inner = self.replicas[i].inner.lock().unwrap();
        inner.failures = inner.failures.saturating_add(1);
        if inner.state == HealthState::Draining {
            return; // drain owns the state until restart/resume
        }
        inner.state = if inner.failures >= self.breaker_threshold {
            HealthState::Down
        } else {
            HealthState::Degraded
        };
    }

    /// Admin drain: stop routing to `i` and tell the replica to refuse new
    /// work typed. The prober finishes the job (restart at in-flight zero
    /// for spawned replicas).
    pub fn drain(&self, i: usize, probe_timeout: Duration) -> anyhow::Result<()> {
        send_admin_op(&self.addr(i), wire::OP_DRAIN, probe_timeout)?;
        self.replicas[i].inner.lock().unwrap().state = HealthState::Draining;
        Ok(())
    }

    /// Admin resume: tell the replica to admit work again and put it back
    /// through the probe loop (Down → first pong promotes it Up).
    pub fn resume(&self, i: usize, probe_timeout: Duration) -> anyhow::Result<()> {
        send_admin_op(&self.addr(i), wire::OP_RESUME, probe_timeout)?;
        let mut inner = self.replicas[i].inner.lock().unwrap();
        inner.state = HealthState::Down;
        inner.failures = 0;
        Ok(())
    }

    pub fn snapshot(&self) -> Vec<ReplicaSnapshot> {
        self.replicas
            .iter()
            .map(|r| {
                let inner = r.inner.lock().unwrap();
                ReplicaSnapshot {
                    addr: inner.addr.clone(),
                    state: inner.state,
                    failures: inner.failures,
                    in_flight: inner.in_flight,
                    reports_draining: inner.reports_draining,
                    spawned: matches!(r.spec, BackendSpec::Spawn { .. }),
                }
            })
            .collect()
    }

    /// One prober pass: ping every replica, drive the state machine, and
    /// handle spawned-child lifecycle (crash respawn, drain-restart).
    /// Runs on the single prober thread.
    pub fn probe_all(&self, probe_timeout: Duration, stats: &RouterStats) {
        for i in 0..self.replicas.len() {
            let addr = self.addr(i);
            match probe_once(&addr, probe_timeout) {
                Ok((draining, in_flight)) => {
                    stats.probes_ok.fetch_add(1, Ordering::Relaxed);
                    let restart = {
                        let mut inner = self.replicas[i].inner.lock().unwrap();
                        inner.failures = 0;
                        inner.in_flight = in_flight;
                        inner.reports_draining = draining;
                        match inner.state {
                            // Half-open: a pong from a Down replica is the
                            // re-admission signal.
                            HealthState::Down | HealthState::Degraded => {
                                inner.state = HealthState::Up;
                                false
                            }
                            // Drain complete: a spawned replica restarts
                            // fresh; an attached one waits for resume.
                            HealthState::Draining => {
                                in_flight == 0 && self.respawn && inner.child.is_some()
                            }
                            HealthState::Up => false,
                        }
                    };
                    if restart {
                        self.respawn_replica(i, stats);
                    }
                }
                Err(_) => {
                    stats.probes_failed.fetch_add(1, Ordering::Relaxed);
                    self.record_failure(i);
                    // A spawned child that actually exited (kill -9, crash)
                    // is respawned without waiting for the breaker.
                    let exited = {
                        let mut inner = self.replicas[i].inner.lock().unwrap();
                        match inner.child.as_mut() {
                            Some(c) => c.try_wait().map(|st| st.is_some()).unwrap_or(true),
                            None => false,
                        }
                    };
                    if exited && self.respawn {
                        self.respawn_replica(i, stats);
                    }
                }
            }
        }
    }

    /// Kill (if alive) and respawn a spawned replica's child, installing
    /// the fresh address. The replica re-enters the pool via the probe
    /// loop: Down until its first pong. The spawn itself runs outside the
    /// lock so proxy sessions keep routing around it meanwhile.
    fn respawn_replica(&self, i: usize, stats: &RouterStats) {
        let (models, workers) = match &self.replicas[i].spec {
            BackendSpec::Spawn { models, workers } => (models.clone(), *workers),
            BackendSpec::Attached(_) => return,
        };
        let old = {
            let mut inner = self.replicas[i].inner.lock().unwrap();
            inner.state = HealthState::Down;
            inner.failures = 0;
            inner.child.take()
        };
        if let Some(mut c) = old {
            let _ = c.kill();
            let _ = c.wait();
        }
        // On spawn failure (fork pressure, port exhaustion) the replica
        // stays Down and the next prober pass tries again via `exited`.
        if let Ok((child, addr)) = spawn_replica(&models, workers) {
            stats.respawns.fetch_add(1, Ordering::Relaxed);
            let mut inner = self.replicas[i].inner.lock().unwrap();
            inner.addr = addr;
            inner.child = Some(child);
            inner.in_flight = 0;
            inner.reports_draining = false;
        }
    }

    /// Kill every spawned child (router shutdown).
    pub fn shutdown_children(&self) {
        for r in &self.replicas {
            if let Some(mut c) = r.inner.lock().unwrap().child.take() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }
}

/// One binary health probe: connect, ping, read the pong. Both the connect
/// and the read are bounded by `timeout` — a stalled replica (see the
/// `ping_stall_ms` fault) counts as a failed probe, exactly like a dead
/// one.
fn probe_once(addr: &str, timeout: Duration) -> anyhow::Result<(bool, u64)> {
    use std::net::ToSocketAddrs;
    let sock = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow::anyhow!("no address resolved for {addr}"))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    let mut frame = Vec::with_capacity(wire::PREFIX_LEN + wire::REQ_HEADER_LEN);
    wire::encode_simple_request(&mut frame, wire::OP_PING);
    stream.write_all(&frame)?;
    let mut scratch = Vec::new();
    match wire::read_reply(&mut stream, &mut scratch)? {
        wire::Reply::Pong { draining, in_flight } => Ok((draining, in_flight)),
        // A payload-less ack (pre-drain wire) still proves liveness.
        wire::Reply::Ok { op } if op == wire::OP_PING => Ok((false, 0)),
        other => anyhow::bail!("unexpected ping reply {other:?}"),
    }
}

/// Forward a drain/resume op to a replica and wait for the ack.
fn send_admin_op(addr: &str, op: u8, timeout: Duration) -> anyhow::Result<()> {
    use std::net::ToSocketAddrs;
    let sock = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow::anyhow!("no address resolved for {addr}"))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    let mut frame = Vec::new();
    wire::encode_simple_request(&mut frame, op);
    stream.write_all(&frame)?;
    let mut scratch = Vec::new();
    match wire::read_reply(&mut stream, &mut scratch)? {
        wire::Reply::Ok { op: ack } if ack == op => Ok(()),
        other => anyhow::bail!("unexpected ack for op {op}: {other:?}"),
    }
}

/// Spawn one `a2q serve` child on an ephemeral port and parse the bound
/// address from its startup line. The child's remaining stdout is drained
/// by a detached thread so it can never block on a full pipe.
fn spawn_replica(models: &str, workers: usize) -> anyhow::Result<(Child, String)> {
    // `A2Q_SERVE_BIN` points tests at the real CLI: inside `cargo test`
    // the current executable is the test harness, which cannot serve.
    let exe = match std::env::var_os("A2Q_SERVE_BIN") {
        Some(p) => std::path::PathBuf::from(p),
        None => std::env::current_exe()?,
    };
    let mut child = Command::new(exe)
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--models",
            models,
            "--workers",
            &workers.max(1).to_string(),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .stdin(Stdio::null())
        .spawn()?;
    let stdout = child.stdout.take().ok_or_else(|| anyhow::anyhow!("no child stdout"))?;
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            let _ = child.kill();
            let _ = child.wait();
            anyhow::bail!("spawned replica exited before announcing its address");
        }
        if let Some(rest) = line.trim().strip_prefix("[serve] listening on ") {
            break rest.trim().to_string();
        }
    };
    std::thread::Builder::new()
        .name("a2q-route-child-stdout".to_string())
        .spawn(move || {
            let mut sink = [0u8; 4096];
            let mut r = reader;
            while matches!(r.read(&mut sink), Ok(n) if n > 0) {}
        })
        .ok();
    Ok((child, addr))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn attached_set(n: usize, threshold: u32) -> ReplicaSet {
        let specs: Vec<BackendSpec> =
            (0..n).map(|i| BackendSpec::Attached(format!("127.0.0.1:{}", 7000 + i))).collect();
        ReplicaSet::start(&specs, threshold, false).unwrap()
    }

    #[test]
    fn breaker_opens_at_threshold_and_success_resets_it() {
        let set = attached_set(1, 3);
        assert_eq!(set.snapshot()[0].state, HealthState::Up);
        set.record_failure(0);
        assert_eq!(set.snapshot()[0].state, HealthState::Degraded);
        set.record_failure(0);
        assert_eq!(set.snapshot()[0].state, HealthState::Degraded);
        set.record_failure(0);
        assert_eq!(set.snapshot()[0].state, HealthState::Down, "third strike opens the breaker");
        assert!(set.pick(0).is_none(), "an open breaker is unroutable");
        set.record_success(0);
        assert_eq!(set.snapshot()[0].state, HealthState::Up, "one success re-admits");
        assert_eq!(set.snapshot()[0].failures, 0);
    }

    #[test]
    fn pick_prefers_up_over_degraded_and_honors_exclusion() {
        let set = attached_set(3, 5);
        set.record_failure(0); // 0: Degraded
        for _ in 0..16 {
            let i = set.pick(0).unwrap();
            assert!(i == 1 || i == 2, "Up replicas win over Degraded");
        }
        // With both Up replicas excluded, Degraded is better than a shed.
        assert_eq!(set.pick(0b110), Some(0));
        // Everything excluded: typed shed territory.
        assert_eq!(set.pick(0b111), None);
    }

    #[test]
    fn pick_round_robins_across_up_replicas() {
        let set = attached_set(3, 3);
        let mut seen = [0usize; 3];
        for _ in 0..30 {
            seen[set.pick(0).unwrap()] += 1;
        }
        for (i, &count) in seen.iter().enumerate() {
            assert_eq!(count, 10, "replica {i} must get an equal share");
        }
    }

    #[test]
    fn draining_is_unroutable_but_failure_proof() {
        let set = attached_set(2, 2);
        set.replicas[0].inner.lock().unwrap().state = HealthState::Draining;
        for _ in 0..8 {
            assert_eq!(set.pick(0), Some(1), "draining replicas receive no traffic");
        }
        // Failures during drain must not flip the state to Down (the
        // prober owns the drain-to-restart transition).
        set.record_failure(0);
        assert_eq!(set.snapshot()[0].state, HealthState::Draining);
        // And success (e.g. a probe pong) must not re-admit mid-drain.
        set.record_success(0);
        assert_eq!(set.snapshot()[0].state, HealthState::Draining);
    }

    #[test]
    fn find_locates_replicas_by_address() {
        let set = attached_set(2, 2);
        assert_eq!(set.find("127.0.0.1:7001"), Some(1));
        assert_eq!(set.find("127.0.0.1:9999"), None);
    }
}
