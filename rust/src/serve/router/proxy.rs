//! Per-connection proxy sessions: the router's data plane.
//!
//! One proxy session serves one client connection, speaking whichever
//! protocol the client opened with (same first-byte negotiation as
//! `serve/session.rs`) and holding its own cached connection per replica.
//! The load-bearing invariant is *buffer-then-relay*: a session reads the
//! client's complete request frame before picking a replica, and reads the
//! replica's complete reply frame before relaying a single byte to the
//! client. The client can therefore never observe a torn frame, and a
//! replica that dies mid-reply costs the router a retry, not the client a
//! corrupted stream — which is what makes the retry loop safe (see
//! `retry.rs` for the full argument).
//!
//! Failover shape per request:
//!
//! 1. pick a replica (round-robin over Up, then Degraded; replicas already
//!    tried for this request are excluded while an untried one exists);
//! 2. forward and read the buffered reply; a transport failure feeds the
//!    replica's breaker and moves on; a typed retryable refusal
//!    (`overloaded`, `draining`, `shutting_down`, `worker_panicked`) is
//!    kept as the relay-of-last-resort and the next replica is tried;
//! 3. between attempts: decorrelated-jitter backoff;
//! 4. exhaustion relays the last typed refusal if any replica produced
//!    one, else sheds typed `no_backend` — a client of the router sees
//!    typed outcomes only, never a transport error it didn't cause.
//!
//! Optional hedging (`hedge_ms > 0`) duplicates a slow binary infer onto a
//! second replica after the hedge delay; the first complete reply wins and
//! the loser's socket is shut down. Hedged attempts use fresh connections
//! (cancellation must not poison a cached stream's framing).
//!
//! The proxy buffers live in pooled [`PooledBuf`]s (their reply vectors),
//! so a warmed router's binary relay path allocates nothing per request.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::super::error::ServeError;
use super::super::pool::BufferPool;
use super::super::wire;
use super::replica::{ReplicaSet, RouterStats};
use super::retry::{retryable_code, RetryPolicy};
use crate::json::Json;

/// Everything a proxy session shares with the rest of the router.
pub struct ProxyContext {
    pub replicas: Arc<ReplicaSet>,
    pub stats: Arc<RouterStats>,
    pub retry: RetryPolicy,
    /// Hedge delay for binary infers; 0 disables hedging.
    pub hedge_ms: u64,
    pub connect_timeout_ms: u64,
    /// Admin-op (drain/resume) round-trip timeout.
    pub admin_timeout_ms: u64,
    /// Deadline assumed for backend read timeouts when a request names
    /// none (mirrors the replicas' own default).
    pub default_deadline_ms: u64,
    pub pool: Arc<BufferPool>,
    pub shutdown: Arc<AtomicBool>,
    /// Monotonic session counter; seeds each session's backoff jitter.
    pub session_seq: AtomicU64,
}

impl ProxyContext {
    fn connect_timeout(&self) -> Duration {
        Duration::from_millis(self.connect_timeout_ms.max(1))
    }

    /// Backend read timeout for a request with this deadline budget: the
    /// replica itself sheds at the deadline, so double it plus slack only
    /// fires when the replica is truly wedged.
    fn read_timeout(&self, deadline_ms: u64) -> Duration {
        let d = if deadline_ms == 0 { self.default_deadline_ms } else { deadline_ms };
        Duration::from_millis(d.saturating_mul(2).saturating_add(2000))
    }
}

/// One client connection: peek the first byte, run that protocol's proxy
/// loop until the client hangs up or the router shuts down.
pub fn run_proxy_session(stream: TcpStream, ctx: &Arc<ProxyContext>) {
    let seed = ctx.session_seq.fetch_add(1, Ordering::Relaxed) ^ 0x9e37_79b9_7f4a_7c15;
    let Ok(writer) = stream.try_clone() else { return };
    // The accepted socket's local address IS the router's listen address:
    // the shutdown op uses it to wake the blocked accept loop.
    let listen_addr = stream.local_addr().ok();
    let mut reader = BufReader::new(stream);
    let first = match reader.fill_buf() {
        Ok([]) | Err(_) => return,
        Ok(b) => b[0],
    };
    if first == wire::MAGIC_BYTE0 {
        run_binary_proxy(reader, writer, ctx, listen_addr, seed);
    } else {
        run_json_proxy(reader, writer, ctx, listen_addr, seed);
    }
}

/// Flip the router's shutdown flag once and wake its accept loop.
fn trigger_shutdown(ctx: &ProxyContext, listen_addr: Option<SocketAddr>) {
    if !ctx.shutdown.swap(true, Ordering::SeqCst) {
        if let Some(addr) = listen_addr {
            let _ = TcpStream::connect(addr);
        }
    }
}

// ------------------------------------------------------------ frame moves

/// Byte offset of a request header's `deadline_ms` field in a full frame.
const REQ_DEADLINE_AT: usize = wire::PREFIX_LEN + 20;
/// Byte offset of the op in a full (request or reply) frame.
const FRAME_OP_AT: usize = wire::PREFIX_LEN + 2;
/// Byte offset of a reply frame's status byte ([`ServeError::tag`]).
const REPLY_STATUS_AT: usize = wire::PREFIX_LEN + 3;

fn rd_u64_at(b: &[u8], at: usize) -> u64 {
    let mut v = [0u8; 8];
    v.copy_from_slice(&b[at..at + 8]);
    u64::from_le_bytes(v)
}

/// Read one complete frame (prefix + body) into `buf`, validating the
/// prefix. The outer `Err` is a transport failure; bad framing from a live
/// transport maps to `InvalidData` so callers treat both as "this stream
/// is lost" without losing the EOF-vs-garbage distinction elsewhere.
fn read_frame<R: Read>(r: &mut R, buf: &mut Vec<u8>, min_len: usize) -> io::Result<()> {
    let mut prefix = [0u8; wire::PREFIX_LEN];
    r.read_exact(&mut prefix)?;
    let magic = u32::from_le_bytes([prefix[0], prefix[1], prefix[2], prefix[3]]);
    if magic != wire::MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad frame magic"));
    }
    let len = u32::from_le_bytes([prefix[4], prefix[5], prefix[6], prefix[7]]) as usize;
    if !(min_len..=wire::MAX_FRAME).contains(&len) {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad frame length"));
    }
    buf.clear();
    buf.extend_from_slice(&prefix);
    buf.resize(wire::PREFIX_LEN + len, 0);
    r.read_exact(&mut buf[wire::PREFIX_LEN..])
}

/// The typed code a buffered reply frame carries, if its status byte is a
/// known [`ServeError::tag`] (`None` means success).
fn reply_code(frame: &[u8]) -> Option<&'static str> {
    match frame[REPLY_STATUS_AT] {
        0 => None,
        tag => ServeError::code_for_tag(tag).or(Some("bad_request")),
    }
}

// ---------------------------------------------------------- binary proxy

/// What one forwarded request resolved to.
enum Forward {
    /// A reply frame to relay sits in the response buffer.
    Relay,
    /// Every attempt failed at the transport level and no replica produced
    /// a typed refusal: shed typed `no_backend`.
    Shed,
}

fn run_binary_proxy(
    mut reader: BufReader<TcpStream>,
    mut writer: TcpStream,
    ctx: &Arc<ProxyContext>,
    listen_addr: Option<SocketAddr>,
    seed: u64,
) {
    // Pooled scratch: request frame in, reply frame out. Their storage
    // returns to the router's pool when the session ends.
    let mut req_buf = ctx.pool.acquire();
    let mut rsp_buf = ctx.pool.acquire();
    let mut typed_buf: Vec<u8> = Vec::new();
    let mut conns: Vec<Option<TcpStream>> = (0..ctx.replicas.len()).map(|_| None).collect();
    let mut req_seq = 0u64;
    loop {
        let req = req_buf.reply_mut();
        if read_frame(&mut reader, req, wire::REQ_HEADER_LEN).is_err() {
            return; // client EOF, hangup, or unframeable garbage
        }
        req_seq += 1;
        let rsp = rsp_buf.reply_mut();
        match req[FRAME_OP_AT] {
            // The router answers pings itself: a pong proves *router*
            // liveness; replica health is the prober's job.
            wire::OP_PING => {
                wire::encode_pong(rsp, false, 0);
                if writer.write_all(rsp).is_err() {
                    return;
                }
            }
            wire::OP_SHUTDOWN => {
                wire::encode_ok_empty(rsp, wire::OP_SHUTDOWN);
                let _ = writer.write_all(rsp);
                trigger_shutdown(ctx, listen_addr);
                return;
            }
            wire::OP_INFER => {
                let outcome =
                    forward_binary(ctx, req, rsp, &mut typed_buf, &mut conns, seed ^ req_seq);
                match outcome {
                    Forward::Relay => {
                        if writer.write_all(rsp).is_err() {
                            return;
                        }
                    }
                    Forward::Shed => {
                        ctx.stats.shed_no_backend.fetch_add(1, Ordering::Relaxed);
                        let e = ServeError::NoBackend { replicas: ctx.replicas.len() };
                        wire::encode_binary_err(rsp, wire::OP_INFER, &e);
                        if writer.write_all(rsp).is_err() {
                            return;
                        }
                    }
                }
            }
            // Drain/resume are per-replica admin ops; the binary header has
            // no address field, so they live on the JSON control plane.
            op => {
                let e = ServeError::BadRequest {
                    reason: format!("op {op} is not routable; use the JSON control plane"),
                };
                wire::encode_binary_err(rsp, op, &e);
                if writer.write_all(rsp).is_err() {
                    return;
                }
            }
        }
    }
}

/// Forward one buffered binary infer with retry/hedging. On `Relay` the
/// reply frame to send the client is in `rsp` (possibly swapped in from
/// the saved typed refusal).
fn forward_binary(
    ctx: &Arc<ProxyContext>,
    req: &[u8],
    rsp: &mut Vec<u8>,
    typed: &mut Vec<u8>,
    conns: &mut [Option<TcpStream>],
    seed: u64,
) -> Forward {
    let read_timeout = ctx.read_timeout(rd_u64_at(req, REQ_DEADLINE_AT));
    let mut backoff = ctx.retry.backoff(seed);
    let mut exclude = 0u64;
    let mut have_typed = false;
    let mut attempts = 0u32;
    let max_attempts = ctx.retry.max_attempts.max(1);
    loop {
        // Prefer an untried replica; with every routable replica already
        // tried, retry anywhere (backoff has passed — an overloaded
        // replica may have queue room now). None at all: truly no backend.
        let picked = ctx.replicas.pick(exclude).or_else(|| ctx.replicas.pick(0));
        let Some(i) = picked else {
            return if have_typed {
                std::mem::swap(rsp, typed);
                finish(ctx, attempts);
                Forward::Relay
            } else {
                Forward::Shed
            };
        };
        attempts += 1;
        let res = if ctx.hedge_ms > 0 {
            attempt_hedged(ctx, req, rsp, i, exclude, read_timeout)
        } else {
            attempt_cached(ctx, req, rsp, conns, i, read_timeout).map(|()| i)
        };
        match res {
            Ok(winner) => {
                ctx.replicas.record_success(winner);
                match reply_code(rsp) {
                    Some(code) if retryable_code(code) => {
                        // Keep the refusal as the relay of last resort.
                        std::mem::swap(rsp, typed);
                        have_typed = true;
                        exclude |= 1u64 << winner;
                    }
                    // Success or a non-retryable typed outcome (deadline,
                    // bad request): the client's answer, verbatim.
                    _ => {
                        finish(ctx, attempts);
                        return Forward::Relay;
                    }
                }
            }
            Err(_) => {
                ctx.replicas.record_failure(i);
                conns[i] = None;
                exclude |= 1u64 << i;
            }
        }
        if attempts >= max_attempts {
            return if have_typed {
                std::mem::swap(rsp, typed);
                finish(ctx, attempts);
                Forward::Relay
            } else {
                Forward::Shed
            };
        }
        std::thread::sleep(backoff.next_delay());
    }
}

fn finish(ctx: &ProxyContext, attempts: u32) {
    ctx.stats.forwarded.fetch_add(1, Ordering::Relaxed);
    ctx.stats.retries.fetch_add(attempts.saturating_sub(1) as u64, Ordering::Relaxed);
}

fn connect(addr: &str, connect_timeout: Duration, read_timeout: Duration) -> io::Result<TcpStream> {
    let sock = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "unresolvable backend"))?;
    let stream = TcpStream::connect_timeout(&sock, connect_timeout)?;
    stream.set_read_timeout(Some(read_timeout))?;
    stream.set_nodelay(true)?;
    Ok(stream)
}

/// One attempt over the session's cached connection to replica `i`
/// (connecting it first if needed). On success the complete reply frame is
/// in `rsp`.
fn attempt_cached(
    ctx: &ProxyContext,
    req: &[u8],
    rsp: &mut Vec<u8>,
    conns: &mut [Option<TcpStream>],
    i: usize,
    read_timeout: Duration,
) -> io::Result<()> {
    if conns[i].is_none() {
        conns[i] = Some(connect(&ctx.replicas.addr(i), ctx.connect_timeout(), read_timeout)?);
    }
    let s = conns[i].as_mut().expect("just connected");
    s.set_read_timeout(Some(read_timeout))?;
    s.write_all(req)?;
    read_frame(s, rsp, wire::REPLY_HEADER_LEN)
}

/// One hedged attempt: primary on replica `i`; if no reply lands within
/// the hedge delay, duplicate onto a second replica and take whichever
/// complete reply arrives first. Returns the winning replica's index.
/// Loser sockets are shut down (their detached threads then fail out);
/// all hedge connections are fresh, so no cached stream's framing is ever
/// poisoned by a cancelled exchange.
fn attempt_hedged(
    ctx: &Arc<ProxyContext>,
    req: &[u8],
    rsp: &mut Vec<u8>,
    i: usize,
    exclude: u64,
    read_timeout: Duration,
) -> io::Result<usize> {
    let (tx, rx) = std::sync::mpsc::channel::<(usize, io::Result<Vec<u8>>)>();
    let cancel: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
    let launch = |replica: usize| {
        let ctx = Arc::clone(ctx);
        let req = req.to_vec();
        let tx = tx.clone();
        let cancel = Arc::clone(&cancel);
        std::thread::Builder::new()
            .name("a2q-route-hedge".to_string())
            .spawn(move || {
                let run = || -> io::Result<Vec<u8>> {
                    let mut s =
                        connect(&ctx.replicas.addr(replica), ctx.connect_timeout(), read_timeout)?;
                    cancel.lock().unwrap().push(s.try_clone()?);
                    s.write_all(&req)?;
                    let mut out = Vec::new();
                    read_frame(&mut s, &mut out, wire::REPLY_HEADER_LEN)?;
                    Ok(out)
                };
                let _ = tx.send((replica, run()));
            })
            .ok()
    };
    let mut outstanding = 0u32;
    if launch(i).is_some() {
        outstanding += 1;
    }
    let mut hedged = false;
    let mut last_err: io::Result<usize> = Err(io::Error::other("hedge spawn failed"));
    while outstanding > 0 {
        let received = if hedged || outstanding > 1 {
            rx.recv().map_err(|_| ())
        } else {
            match rx.recv_timeout(Duration::from_millis(ctx.hedge_ms.max(1))) {
                Ok(v) => Ok(v),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    // Primary is slow: duplicate onto a different replica.
                    hedged = true;
                    if let Some(j) = ctx.replicas.pick(exclude | (1u64 << i)) {
                        if launch(j).is_some() {
                            ctx.stats.hedges.fetch_add(1, Ordering::Relaxed);
                            outstanding += 1;
                        }
                    }
                    continue;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Err(()),
            }
        };
        let Ok((replica, result)) = received else { break };
        outstanding -= 1;
        match result {
            Ok(frame) => {
                rsp.clear();
                rsp.extend_from_slice(&frame);
                if hedged && replica != i {
                    ctx.stats.hedge_wins.fetch_add(1, Ordering::Relaxed);
                }
                // First complete reply wins; cut the loser loose.
                for s in cancel.lock().unwrap().drain(..) {
                    let _ = s.shutdown(std::net::Shutdown::Both);
                }
                return Ok(replica);
            }
            Err(e) => {
                if replica != i {
                    // A failed hedge must not mask the primary's outcome,
                    // but it does feed that replica's breaker.
                    ctx.replicas.record_failure(replica);
                }
                last_err = Err(e);
            }
        }
    }
    last_err
}

// ------------------------------------------------------------ JSON proxy

fn err_json(e: &ServeError) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::str(e.code())),
        ("error", Json::str(e.to_string())),
    ])
}

fn bad(reason: impl Into<String>) -> ServeError {
    ServeError::BadRequest { reason: reason.into() }
}

/// The typed code of a line-JSON reply, extracted without a parse: error
/// replies serialize with sorted keys, so they always open `{"code":"..`.
fn json_error_code(line: &str) -> Option<&str> {
    let rest = line.strip_prefix("{\"code\":\"")?;
    rest.split('"').next()
}

/// The router's own `stats` reply: router counters plus one row per
/// replica.
fn router_stats_json(ctx: &ProxyContext) -> Json {
    let s = &ctx.stats;
    let replicas: Vec<Json> = ctx.replicas.snapshot().iter().map(|r| r.to_json()).collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("role", Json::str("router")),
        ("forwarded", Json::num(s.forwarded.load(Ordering::Relaxed) as f64)),
        ("retries", Json::num(s.retries.load(Ordering::Relaxed) as f64)),
        ("hedges", Json::num(s.hedges.load(Ordering::Relaxed) as f64)),
        ("hedge_wins", Json::num(s.hedge_wins.load(Ordering::Relaxed) as f64)),
        ("shed_no_backend", Json::num(s.shed_no_backend.load(Ordering::Relaxed) as f64)),
        ("respawns", Json::num(s.respawns.load(Ordering::Relaxed) as f64)),
        ("probes_ok", Json::num(s.probes_ok.load(Ordering::Relaxed) as f64)),
        ("probes_failed", Json::num(s.probes_failed.load(Ordering::Relaxed) as f64)),
        ("replicas", Json::arr(replicas)),
    ])
}

/// A cached line-JSON connection to one replica.
struct JsonConn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

fn run_json_proxy(
    mut reader: BufReader<TcpStream>,
    mut writer: TcpStream,
    ctx: &Arc<ProxyContext>,
    listen_addr: Option<SocketAddr>,
    seed: u64,
) {
    let mut conns: Vec<Option<JsonConn>> = (0..ctx.replicas.len()).map(|_| None).collect();
    let mut line = String::new();
    let mut reply = String::new();
    let mut wbuf = String::new();
    let mut req_seq = 0u64;
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            continue;
        }
        req_seq += 1;
        let inline: Option<Json> = match Json::parse(&line) {
            Err(e) => Some(err_json(&bad(format!("invalid JSON: {e:#}")))),
            Ok(parsed) => match parsed.get("op").and_then(|v| v.as_str()) {
                Err(_) => Some(err_json(&bad("missing \"op\""))),
                Ok("ping") => {
                    Some(Json::obj(vec![("ok", Json::Bool(true)), ("role", Json::str("router"))]))
                }
                Ok("stats") => Some(router_stats_json(ctx)),
                Ok("drain") => Some(admin_op(ctx, &parsed, true)),
                Ok("resume") => Some(admin_op(ctx, &parsed, false)),
                Ok("shutdown") => {
                    wbuf.clear();
                    Json::obj(vec![("ok", Json::Bool(true))]).write_into(&mut wbuf);
                    wbuf.push('\n');
                    let _ = writer.write_all(wbuf.as_bytes());
                    trigger_shutdown(ctx, listen_addr);
                    return;
                }
                // Data-plane lines relay through the same failover loop as
                // binary infers (model_info rides along: it is read-only
                // and deterministic, so retrying it is equally safe).
                Ok("infer") | Ok("model_info") => {
                    match forward_json(ctx, &line, &mut reply, &mut conns, seed ^ req_seq) {
                        Forward::Relay => None,
                        Forward::Shed => {
                            ctx.stats.shed_no_backend.fetch_add(1, Ordering::Relaxed);
                            let e = ServeError::NoBackend { replicas: ctx.replicas.len() };
                            Some(err_json(&e))
                        }
                    }
                }
                Ok(other) => Some(err_json(&bad(format!("unknown op {other:?}")))),
            },
        };
        let bytes: &[u8] = match &inline {
            Some(json) => {
                wbuf.clear();
                json.write_into(&mut wbuf);
                wbuf.push('\n');
                wbuf.as_bytes()
            }
            None => reply.as_bytes(),
        };
        if writer.write_all(bytes).is_err() {
            return;
        }
    }
}

/// The addressed drain/resume control op: `{"op":"drain","backend":ADDR}`.
fn admin_op(ctx: &ProxyContext, req: &Json, drain: bool) -> Json {
    let op = if drain { "drain" } else { "resume" };
    let addr = match req.get("backend").and_then(|v| v.as_str()) {
        Ok(a) => a.to_string(),
        Err(_) => return err_json(&bad(format!("{op} needs \"backend\" (a replica address)"))),
    };
    let Some(i) = ctx.replicas.find(&addr) else {
        return err_json(&bad(format!("no replica at {addr:?}")));
    };
    let timeout = Duration::from_millis(ctx.admin_timeout_ms.max(1));
    let result =
        if drain { ctx.replicas.drain(i, timeout) } else { ctx.replicas.resume(i, timeout) };
    match result {
        Ok(()) => {
            let state = ctx.replicas.snapshot()[i].state;
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("backend", Json::str(addr)),
                ("state", Json::str(state.as_str())),
            ])
        }
        Err(e) => err_json(&bad(format!("{op} {addr}: {e:#}"))),
    }
}

/// Forward one JSON line with the same pick/retry/exclude loop as the
/// binary path. On `Relay` the backend's reply line is in `reply`.
fn forward_json(
    ctx: &Arc<ProxyContext>,
    line: &str,
    reply: &mut String,
    conns: &mut [Option<JsonConn>],
    seed: u64,
) -> Forward {
    let read_timeout = ctx.read_timeout(0);
    let mut backoff = ctx.retry.backoff(seed);
    let mut exclude = 0u64;
    let mut typed = String::new();
    let mut have_typed = false;
    let mut attempts = 0u32;
    let max_attempts = ctx.retry.max_attempts.max(1);
    loop {
        let picked = ctx.replicas.pick(exclude).or_else(|| ctx.replicas.pick(0));
        let Some(i) = picked else {
            return if have_typed {
                std::mem::swap(reply, &mut typed);
                finish(ctx, attempts);
                Forward::Relay
            } else {
                Forward::Shed
            };
        };
        attempts += 1;
        match attempt_json(ctx, line, reply, conns, i, read_timeout) {
            Ok(()) => {
                ctx.replicas.record_success(i);
                match json_error_code(reply) {
                    Some(code) if retryable_code(code) => {
                        std::mem::swap(reply, &mut typed);
                        have_typed = true;
                        exclude |= 1u64 << i;
                    }
                    _ => {
                        finish(ctx, attempts);
                        return Forward::Relay;
                    }
                }
            }
            Err(_) => {
                ctx.replicas.record_failure(i);
                conns[i] = None;
                exclude |= 1u64 << i;
            }
        }
        if attempts >= max_attempts {
            return if have_typed {
                std::mem::swap(reply, &mut typed);
                finish(ctx, attempts);
                Forward::Relay
            } else {
                Forward::Shed
            };
        }
        std::thread::sleep(backoff.next_delay());
    }
}

fn attempt_json(
    ctx: &ProxyContext,
    line: &str,
    reply: &mut String,
    conns: &mut [Option<JsonConn>],
    i: usize,
    read_timeout: Duration,
) -> io::Result<()> {
    if conns[i].is_none() {
        let stream = connect(&ctx.replicas.addr(i), ctx.connect_timeout(), read_timeout)?;
        let reader = BufReader::new(stream.try_clone()?);
        conns[i] = Some(JsonConn { stream, reader });
    }
    let c = conns[i].as_mut().expect("just connected");
    c.stream.set_read_timeout(Some(read_timeout))?;
    c.stream.write_all(line.as_bytes())?;
    reply.clear();
    match c.reader.read_line(reply)? {
        0 => Err(io::Error::new(io::ErrorKind::UnexpectedEof, "backend closed mid-request")),
        _ => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reply_frames_expose_status_and_deadline_at_fixed_offsets() {
        // Typed refusal: the status byte at its fixed offset maps to the
        // frozen code, which is what the retry decision reads.
        let mut frame = Vec::new();
        let e = ServeError::Overloaded { queued: 8, capacity: 8 };
        wire::encode_binary_err(&mut frame, wire::OP_INFER, &e);
        assert_eq!(frame[REPLY_STATUS_AT], e.tag());
        assert_eq!(reply_code(&frame), Some("overloaded"));
        assert_eq!(frame[FRAME_OP_AT], wire::OP_INFER);

        // Success: status 0, no code.
        wire::encode_pong(&mut frame, false, 3);
        assert_eq!(reply_code(&frame), None);

        // Request deadline field at its fixed offset.
        wire::encode_infer_request(&mut frame, 7, 1, 2, 1234, &[1, -1]);
        assert_eq!(rd_u64_at(&frame, REQ_DEADLINE_AT), 1234);
        assert_eq!(frame[FRAME_OP_AT], wire::OP_INFER);
    }

    #[test]
    fn read_frame_rejects_bad_framing_as_invalid_data() {
        use std::io::Cursor;
        let mut good = Vec::new();
        wire::encode_simple_request(&mut good, wire::OP_PING);
        let mut buf = Vec::new();
        read_frame(&mut Cursor::new(&good[..]), &mut buf, wire::REQ_HEADER_LEN).unwrap();
        assert_eq!(buf, good, "a relayed frame is byte-identical to what arrived");

        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        let e = read_frame(&mut Cursor::new(&bad_magic[..]), &mut buf, wire::REQ_HEADER_LEN)
            .unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);

        let mut truncated = good.clone();
        truncated.truncate(wire::PREFIX_LEN + 4);
        let e = read_frame(&mut Cursor::new(&truncated[..]), &mut buf, wire::REQ_HEADER_LEN)
            .unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn json_error_codes_extract_without_a_parse() {
        let mut line = String::new();
        err_json(&ServeError::Draining).write_into(&mut line);
        assert_eq!(json_error_code(&line), Some("draining"));

        line.clear();
        err_json(&ServeError::NoBackend { replicas: 3 }).write_into(&mut line);
        assert_eq!(json_error_code(&line), Some("no_backend"));

        // Success lines (sorted keys never start with "code") pass through.
        assert_eq!(json_error_code("{\"batch_rows\":1,\"ok\":true}"), None);
        assert_eq!(json_error_code("{\"draining\":false,\"in_flight\":0,\"ok\":true}"), None);
    }

    #[test]
    fn synthesized_no_backend_sheds_decode_typed() {
        let mut frame = Vec::new();
        let e = ServeError::NoBackend { replicas: 4 };
        wire::encode_binary_err(&mut frame, wire::OP_INFER, &e);
        let mut scratch = Vec::new();
        let reply = wire::read_reply(&mut std::io::Cursor::new(&frame[..]), &mut scratch).unwrap();
        match reply {
            wire::Reply::Err { op, tag, message } => {
                assert_eq!(op, wire::OP_INFER);
                assert_eq!(ServeError::code_for_tag(tag), Some("no_backend"));
                assert!(message.contains('4'), "{message}");
            }
            other => panic!("expected typed error, got {other:?}"),
        }
    }
}
