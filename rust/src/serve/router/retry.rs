//! Retry policy: what the router may re-send, and how long it waits.
//!
//! The safety argument comes first. An `infer` against an A2Q replica is
//! idempotent and bit-identical across replicas (the accumulator plan is a
//! pure function of the model hash and the input codes), so re-sending a
//! request can never produce a different answer — only the same answer
//! later. The one thing a retry must never do is duplicate or interleave
//! bytes the client has already started reading; the proxy guarantees that
//! structurally by buffering the complete backend reply before relaying a
//! single byte (see `proxy.rs`), which reduces "is this retry safe?" to
//! "did this outcome leave the request unserved?".
//!
//! Outcomes that leave the request unserved and are therefore retryable:
//!
//! * every transport failure (connect refused/reset, mid-exchange hangup,
//!   read timeout) — the replica died or was killed before completing a
//!   reply;
//! * the typed codes [`retryable_code`] accepts: `overloaded` (another
//!   replica may have queue room), `draining` / `shutting_down` (the
//!   replica is leaving the pool; that is exactly what failover is for)
//!   and `worker_panicked` (per-batch fault isolation on one replica says
//!   nothing about the others).
//!
//! `deadline_exceeded` is typed but NOT retryable: the client's budget is
//! already spent, and re-queueing elsewhere can only blow it further.
//! Request errors (`bad_request`, `unknown_model`, ...) are deterministic —
//! retrying them is pure waste.
//!
//! Between attempts the router sleeps per *decorrelated jitter*: each delay
//! is drawn uniformly from `[base, prev * 3]`, capped. Unlike plain
//! exponential backoff, concurrent sessions that failed together decorrelate
//! after one round instead of thundering back in lockstep.

use std::time::Duration;

use crate::rng::Rng;

/// Retry knobs. `Default` trades at most ~100ms of added latency for
/// riding out a replica kill.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts per request, first try included (1 = never retry).
    pub max_attempts: u32,
    /// Backoff floor per retry.
    pub base_ms: u64,
    /// Backoff ceiling per retry.
    pub cap_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 3, base_ms: 2, cap_ms: 50 }
    }
}

impl RetryPolicy {
    /// A fresh backoff sequence for one request's retry chain. `seed`
    /// varies per session/request so concurrent chains decorrelate.
    pub fn backoff(&self, seed: u64) -> Backoff {
        Backoff {
            base_ms: self.base_ms.max(1),
            cap_ms: self.cap_ms.max(self.base_ms.max(1)),
            prev_ms: self.base_ms.max(1),
            rng: Rng::new(seed),
        }
    }
}

/// Decorrelated-jitter backoff state for one retry chain.
#[derive(Debug)]
pub struct Backoff {
    base_ms: u64,
    cap_ms: u64,
    prev_ms: u64,
    rng: Rng,
}

impl Backoff {
    /// The next delay: uniform in `[base, prev * 3]`, capped.
    pub fn next_delay(&mut self) -> Duration {
        let hi = (self.prev_ms.saturating_mul(3)).min(self.cap_ms).max(self.base_ms);
        let span = (hi - self.base_ms + 1) as usize;
        let ms = self.base_ms + self.rng.below(span) as u64;
        self.prev_ms = ms;
        Duration::from_millis(ms)
    }
}

/// Whether a typed [`ServeError::code`] outcome left the request unserved
/// on a replica that is overloaded, leaving, or faulted — i.e. worth one
/// more attempt elsewhere. See the module docs for the full argument.
///
/// [`ServeError::code`]: crate::serve::ServeError::code
pub fn retryable_code(code: &str) -> bool {
    matches!(code, "overloaded" | "draining" | "shutting_down" | "worker_panicked")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retryable_codes_match_the_failover_contract() {
        for code in ["overloaded", "draining", "shutting_down", "worker_panicked"] {
            assert!(retryable_code(code), "{code} leaves the request unserved elsewhere");
        }
        for code in ["deadline_exceeded", "bad_request", "unknown_model", "load_failed", "ok"] {
            assert!(!retryable_code(code), "{code} must not be retried");
        }
    }

    #[test]
    fn backoff_stays_within_bounds_and_decorrelates() {
        let policy = RetryPolicy { max_attempts: 5, base_ms: 2, cap_ms: 50 };
        let mut a = policy.backoff(1);
        let mut b = policy.backoff(2);
        let (mut da, mut db) = (Vec::new(), Vec::new());
        for _ in 0..32 {
            let (x, y) = (a.next_delay().as_millis() as u64, b.next_delay().as_millis() as u64);
            assert!((2..=50).contains(&x), "delay {x}ms outside [base, cap]");
            assert!((2..=50).contains(&y), "delay {y}ms outside [base, cap]");
            da.push(x);
            db.push(y);
        }
        assert_ne!(da, db, "different seeds must produce different jitter");
    }

    #[test]
    fn degenerate_policies_stay_sane() {
        // cap below base clamps to base; zero base clamps to 1ms.
        let mut z = RetryPolicy { max_attempts: 2, base_ms: 0, cap_ms: 0 }.backoff(7);
        for _ in 0..8 {
            assert_eq!(z.next_delay(), Duration::from_millis(1));
        }
        let mut c = RetryPolicy { max_attempts: 2, base_ms: 10, cap_ms: 3 }.backoff(7);
        for _ in 0..8 {
            assert_eq!(c.next_delay(), Duration::from_millis(10));
        }
    }
}
