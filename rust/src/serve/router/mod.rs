//! `a2q route`: a fault-tolerant shard router in front of N `a2q serve`
//! replicas.
//!
//! The paper's discipline — overflow avoidance as a *guaranteed property*,
//! not a load-dependent accident — extends one tier up: replica failure
//! must be an availability event the serving system absorbs, never a
//! correctness event the client observes. The router makes that concrete.
//! Because an A2Q infer is idempotent and bit-identical across replicas,
//! any single replica's death, drain, or panic is invisible to clients:
//! every request either succeeds (byte-identical to a direct hit) or fails
//! with a typed shed code — never a transport error the client didn't
//! cause, never a torn frame, never a hang.
//!
//! The moving parts:
//!
//! * [`replica`] — the backend pool: the Up/Degraded/Down/Draining health
//!   state machine, the consecutive-failure circuit breaker, spawned-child
//!   lifecycle (crash respawn, drain-restart), and address bookkeeping.
//! * One **prober thread** — binary wire pings every replica each probe
//!   interval, drives the state machine (a pong from a Down replica is the
//!   half-open re-admission), watches drain progress via the pong's
//!   in-flight gauge, and respawns dead or drained spawned children.
//! * [`proxy`] — per-connection data-plane sessions for both wire
//!   protocols: buffer-then-relay forwarding, bounded retry with
//!   decorrelated-jitter backoff, optional tail-latency hedging with
//!   first-wins cancellation, and the JSON control plane (`stats`,
//!   addressed `drain`/`resume`, `shutdown`).
//! * [`retry`] — the frozen policy of *what* may be retried and the
//!   backoff between attempts.
//!
//! Backends come in two flavors: **attached** (`--backend addr`, a process
//! someone else runs) and **spawned** (`--spawn spec`, children the router
//! starts on ephemeral ports and may kill/respawn). A router whose every
//! replica is dead stays up and sheds typed `no_backend`; the prober
//! re-admits replicas automatically as they come back.

pub mod proxy;
pub mod replica;
pub mod retry;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::pool::BufferPool;
pub use proxy::ProxyContext;
pub use replica::{BackendSpec, HealthState, Replica, ReplicaSet, ReplicaSnapshot, RouterStats};
pub use retry::{retryable_code, Backoff, RetryPolicy};

/// Router knobs. `Default` is a sane local profile: fast probes, three
/// attempts per request, hedging off.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Bind address; port 0 picks a free port (tests).
    pub addr: String,
    /// How often the prober pings every replica.
    pub probe_interval_ms: u64,
    /// Per-probe connect/read timeout (also the admin-op timeout).
    pub probe_timeout_ms: u64,
    /// Consecutive failures that open a replica's circuit breaker.
    pub breaker_threshold: u32,
    /// Retry policy for forwarded requests.
    pub retry: RetryPolicy,
    /// Hedge delay for binary infers; 0 disables hedging.
    pub hedge_ms: u64,
    /// Backend connect timeout on the proxy path.
    pub connect_timeout_ms: u64,
    /// Deadline assumed for backend read timeouts when a request names
    /// none; mirror the replicas' `--default-deadline-ms`.
    pub default_deadline_ms: u64,
    /// Respawn spawned replicas that die or complete a drain. Attached
    /// replicas are never respawned regardless.
    pub respawn: bool,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            addr: "127.0.0.1:7979".to_string(),
            probe_interval_ms: 50,
            probe_timeout_ms: 250,
            breaker_threshold: 3,
            retry: RetryPolicy::default(),
            hedge_ms: 0,
            connect_timeout_ms: 1000,
            default_deadline_ms: 1000,
            respawn: true,
        }
    }
}

/// A running router. Dropping it does NOT stop it — call
/// [`Router::shutdown`] then [`Router::join`].
pub struct Router {
    addr: SocketAddr,
    ctx: Arc<ProxyContext>,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    prober_handle: Option<JoinHandle<()>>,
}

impl Router {
    /// Bind, bring up the replica pool (spawning children for spawn
    /// specs), start the prober and the accept loop.
    pub fn start(cfg: &RouterConfig, specs: &[BackendSpec]) -> anyhow::Result<Router> {
        let replicas = Arc::new(ReplicaSet::start(specs, cfg.breaker_threshold, cfg.respawn)?);
        let stats = Arc::new(RouterStats::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        // The proxy only ever needs two buffers per live session; retain a
        // small multiple so concurrent sessions recycle instead of building.
        let pool = Arc::new(BufferPool::new(32));
        let ctx = Arc::new(ProxyContext {
            replicas: Arc::clone(&replicas),
            stats: Arc::clone(&stats),
            retry: cfg.retry,
            hedge_ms: cfg.hedge_ms,
            connect_timeout_ms: cfg.connect_timeout_ms,
            admin_timeout_ms: cfg.probe_timeout_ms,
            default_deadline_ms: cfg.default_deadline_ms,
            pool,
            shutdown: Arc::clone(&shutdown),
            session_seq: AtomicU64::new(1),
        });

        let prober_handle = {
            let replicas = Arc::clone(&replicas);
            let stats = Arc::clone(&stats);
            let shutdown = Arc::clone(&shutdown);
            let interval = Duration::from_millis(cfg.probe_interval_ms.max(1));
            let timeout = Duration::from_millis(cfg.probe_timeout_ms.max(1));
            std::thread::Builder::new()
                .name("a2q-route-prober".to_string())
                .spawn(move || {
                    while !shutdown.load(Ordering::SeqCst) {
                        replicas.probe_all(timeout, &stats);
                        std::thread::sleep(interval);
                    }
                })
                .expect("spawn prober")
        };

        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let accept_handle = {
            let ctx = Arc::clone(&ctx);
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("a2q-route-accept".to_string())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        let Ok(stream) = stream else { continue };
                        let ctx = Arc::clone(&ctx);
                        let _ = std::thread::Builder::new()
                            .name("a2q-route-conn".to_string())
                            .spawn(move || proxy::run_proxy_session(stream, &ctx));
                    }
                })
                .expect("spawn accept loop")
        };

        Ok(Router {
            addr,
            ctx,
            shutdown,
            accept_handle: Some(accept_handle),
            prober_handle: Some(prober_handle),
        })
    }

    /// The actually bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &RouterStats {
        &self.ctx.stats
    }

    pub fn replicas(&self) -> &ReplicaSet {
        &self.ctx.replicas
    }

    /// Stop accepting, stop probing. Live proxy sessions finish with their
    /// clients; spawned children die in [`Router::join`].
    pub fn shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the blocked accept loop so it observes the flag.
        let _ = TcpStream::connect(self.addr);
    }

    /// Wait for the accept loop and prober, then kill spawned children.
    /// Call after [`Router::shutdown`]; joining a live router blocks
    /// forever.
    pub fn join(mut self) {
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.prober_handle.take() {
            let _ = h.join();
        }
        self.ctx.replicas.shutdown_children();
    }
}
