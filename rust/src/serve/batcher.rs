//! Micro-batch execution and the panic-isolated batch worker.
//!
//! [`run_worker`] is the serve hot path's compute stage, built to be
//! steady-state allocation-free: it owns a [`WorkerScratch`] (engine
//! scratch, a concatenation matrix, output/stat buffers, and the batch
//! vector `next_batch` fills), executes each micro-batch through
//! [`SharedNetworkPlan::execute_warm_into`], and encodes every request's
//! complete wire reply straight into that request's pooled byte buffer
//! before responding. Single-request batches (the common case at low
//! concurrency) execute directly out of the request's pooled `IntMatrix` —
//! no concatenation copy at all.
//!
//! Compute runs under `catch_unwind`, so a panic — injected or real —
//! rejects exactly the requests of the poisoned batch with a typed
//! [`ServeError::WorkerPanicked`] and then re-raises to kill the worker
//! thread. The supervisor (in [`super::session`]) observes the death and
//! respawns a fresh worker with fresh scratch; queued requests for other
//! batches never notice. Requests still held by the unwinding batch are
//! also covered by the reply-slot fail-safe (their drop delivers a typed
//! error), so no client ever hangs.
//!
//! [`execute_micro_batch`] remains as the thread-free serving core the
//! property test pins bit-identical to per-request
//! [`NetworkPlan::execute`][crate::accsim::NetworkPlan] across batch
//! compositions; it now runs on the same `execute_warm_into` path the
//! worker uses, so the pin covers the production code.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use super::admission::{AdmissionQueue, JobRequest, ServeStats};
use super::cache::PlanCache;
use super::error::ServeError;
use super::fault::FaultPlan;
use super::wire;
use crate::accsim::{IntMatrix, NetScratch, OverflowStats, SharedNetworkPlan};
use crate::tensor::Tensor;

/// The result of one micro-batch execution, split back per request.
pub struct MicroBatchOutcome {
    /// One `[rows_i, output_dim]` output tensor per input, in input order.
    pub per_request: Vec<Tensor>,
    /// Overflow events summed over every layer of the batch execution.
    pub overflow_events: u64,
    /// Total rows executed.
    pub total_rows: usize,
}

/// Run the concatenation of `inputs` through the plan as one batch and
/// split the dequantized outputs back per input. Bit-identical to executing
/// each input alone: the engine parallelizes over rows with per-row
/// accumulation order fixed, so batch composition is invisible to both
/// outputs and [`OverflowStats`] sums.
pub fn execute_micro_batch(
    plan: &SharedNetworkPlan,
    inputs: &[&IntMatrix],
    scratch: &mut NetScratch,
) -> MicroBatchOutcome {
    let cols = plan.net().input_dim();
    let total_rows: usize = inputs.iter().map(|x| x.rows()).sum();
    let mut batch = IntMatrix::with_capacity(total_rows * cols);
    batch.clear_rows(cols);
    for x in inputs {
        assert_eq!(x.cols(), cols, "request width {} vs model input dim {cols}", x.cols());
        batch.append_rows(x);
    }
    let mut out = Vec::new();
    let mut wide = Vec::new();
    let mut layer_stats = Vec::new();
    plan.execute_warm_into(&batch, scratch, &mut out, &mut wide, &mut layer_stats);
    let overflow_events: u64 = layer_stats.iter().map(|s| s.overflow_events).sum();
    let out_dim = plan.net().output_dim();
    let mut per_request = Vec::with_capacity(inputs.len());
    let mut row = 0usize;
    for x in inputs {
        let rows = x.rows();
        let slice = &out[row * out_dim..(row + rows) * out_dim];
        per_request.push(Tensor::new(vec![rows, out_dim], slice.to_vec()));
        row += rows;
    }
    MicroBatchOutcome { per_request, overflow_events, total_rows }
}

/// Batch sizing knobs a worker drains the queue with.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum input rows per micro-batch.
    pub max_rows: usize,
    /// How long a non-full batch waits for more same-model rows.
    pub window: Duration,
}

/// Everything a batch worker reuses across micro-batches: engine scratch,
/// the multi-request concatenation matrix, the execute outputs, and the
/// batch vector the admission queue fills. One warmup batch per model
/// shape grows these to the working set; after that the loop allocates
/// nothing.
pub struct WorkerScratch {
    net: NetScratch,
    concat: IntMatrix,
    out: Vec<f32>,
    wide: Vec<f32>,
    layer_stats: Vec<OverflowStats>,
    batch: Vec<JobRequest>,
}

impl WorkerScratch {
    /// Scratch sized for a queue: the batch vector can hold every queued
    /// request without growing.
    pub fn for_queue(queue: &AdmissionQueue) -> WorkerScratch {
        WorkerScratch {
            net: NetScratch::default(),
            concat: IntMatrix::with_capacity(0),
            out: Vec::new(),
            wide: Vec::new(),
            layer_stats: Vec::new(),
            batch: Vec::with_capacity(queue.capacity()),
        }
    }
}

/// The batch-worker loop. Runs until [`AdmissionQueue::close`] drains the
/// queue; panics propagate out (by design) after every request of the
/// poisoned batch has been rejected with `WorkerPanicked`.
pub fn run_worker(
    queue: Arc<AdmissionQueue>,
    cache: Arc<PlanCache>,
    stats: Arc<ServeStats>,
    policy: BatchPolicy,
    fault: FaultPlan,
) {
    let mut ws = WorkerScratch::for_queue(&queue);
    loop {
        let WorkerScratch { net, concat, out, wide, layer_stats, batch } = &mut ws;
        let Some(seq) = queue.next_batch(policy.max_rows, policy.window, &stats, batch) else {
            return;
        };
        if let Some(ms) = fault.delay_ms {
            std::thread::sleep(Duration::from_millis(ms));
        }
        let plan = match cache.get(batch[0].model_hash) {
            Ok(plan) => plan,
            Err(e) => {
                // A load failure poisons only this batch, typed — the
                // worker itself keeps draining.
                for req in batch.drain(..) {
                    req.reject(e.clone());
                }
                continue;
            }
        };
        let cols = plan.net().input_dim();
        let inject = fault.panic_batch == Some(seq);
        let outcome = {
            let batch_view: &[JobRequest] = batch;
            catch_unwind(AssertUnwindSafe(|| {
                if inject {
                    panic!("injected fault: panic_batch {seq}");
                }
                // Single-request batches execute straight out of the pooled
                // request buffer; multi-request batches concatenate into
                // the reusable matrix.
                let x: &IntMatrix = if batch_view.len() == 1 {
                    batch_view[0].input()
                } else {
                    concat.clear_rows(cols);
                    for req in batch_view {
                        concat.append_rows(req.input());
                    }
                    concat
                };
                plan.execute_warm_into(x, net, out, wide, layer_stats);
                x.rows()
            }))
        };
        match outcome {
            Ok(total_rows) => {
                let overflow_events: u64 =
                    layer_stats.iter().map(|s| s.overflow_events).sum();
                let out_dim = plan.net().output_dim();
                let mut row = 0usize;
                for mut req in batch.drain(..) {
                    let rows = req.rows();
                    let slice = &out[row * out_dim..(row + rows) * out_dim];
                    row += rows;
                    // The worker encodes the complete wire reply into the
                    // request's pooled byte buffer; the session only
                    // writes bytes to the socket.
                    wire::encode_infer_ok(
                        req.wire,
                        req.reply_buf_mut(),
                        slice,
                        rows,
                        out_dim,
                        overflow_events,
                        seq,
                        total_rows,
                    );
                    req.respond_ok(overflow_events, seq, total_rows);
                    stats.completed.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(payload) => {
                stats.worker_panics.fetch_add(1, Ordering::Relaxed);
                for req in batch.drain(..) {
                    req.reject(ServeError::WorkerPanicked { batch_seq: seq });
                }
                // Kill this worker: its scratch may be mid-mutation. The
                // supervisor respawns a clean replacement.
                resume_unwind(payload);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accsim::AccMode;
    use crate::model::{parse_synth_spec, QNetwork};
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    fn plan() -> SharedNetworkPlan {
        let (_, spec) = parse_synth_spec("t:10x8x4:m4n4p16").unwrap();
        let mut net = QNetwork::synthesize(&spec, 7).unwrap();
        let mut rng = Rng::new(11);
        let data: Vec<f32> = (0..32 * 10).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
        net.calibrate(&Tensor::new(vec![32, 10], data));
        let p = net.grid_bits().2;
        SharedNetworkPlan::new(Arc::new(net), &[AccMode::Wrap { p_bits: p }])
    }

    fn inputs(rng: &mut Rng, rows: usize, cols: usize, hi: i64) -> IntMatrix {
        let data = (0..rows * cols).map(|_| rng.below(hi as usize) as i64).collect();
        IntMatrix::from_flat(rows, cols, data)
    }

    #[test]
    fn micro_batch_is_bit_identical_to_per_request_execution() {
        let plan = plan();
        let mut rng = Rng::new(3);
        let reqs: Vec<IntMatrix> =
            [1usize, 3, 2, 5].iter().map(|&r| inputs(&mut rng, r, 10, 15)).collect();
        let refs: Vec<&IntMatrix> = reqs.iter().collect();
        let mut scratch = NetScratch::default();
        let batched = execute_micro_batch(&plan, &refs, &mut scratch);
        assert_eq!(batched.total_rows, 11);
        let mut solo_events = 0u64;
        for (req, got) in reqs.iter().zip(&batched.per_request) {
            let solo = plan.execute(req);
            assert_eq!(solo[0].out.data(), got.data(), "batched outputs must match solo");
            solo_events += solo[0].layer_stats.iter().map(|s| s.overflow_events).sum::<u64>();
        }
        assert_eq!(batched.overflow_events, solo_events);
        // Warm scratch reuse across calls stays bit-identical too.
        let again = execute_micro_batch(&plan, &refs, &mut scratch);
        for (a, b) in batched.per_request.iter().zip(&again.per_request) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn execute_warm_into_matches_execute_warm() {
        let plan = plan();
        let mut rng = Rng::new(9);
        let x = inputs(&mut rng, 6, 10, 15);
        let mut scratch = NetScratch::default();
        let baseline = plan.execute_warm(&x, &mut scratch);
        let (mut out, mut wide, mut ls) = (Vec::new(), Vec::new(), Vec::new());
        plan.execute_warm_into(&x, &mut scratch, &mut out, &mut wide, &mut ls);
        assert_eq!(baseline[0].out.data(), &out[..], "outputs must match the Tensor path");
        assert_eq!(baseline[0].out_wide.data(), &wide[..]);
        assert_eq!(baseline[0].layer_stats, ls, "per-layer OverflowStats must match");
        // Warm reuse through the same buffers is deterministic.
        plan.execute_warm_into(&x, &mut scratch, &mut out, &mut wide, &mut ls);
        assert_eq!(baseline[0].out.data(), &out[..]);
        assert_eq!(baseline[0].layer_stats, ls);
    }
}
