//! Micro-batch execution and the panic-isolated batch worker.
//!
//! [`execute_micro_batch`] is the pure serving core: concatenate every
//! admitted request's rows into one batch, run it through
//! [`SharedNetworkPlan::execute_warm`] at the plan's efficient batch size,
//! and split the outputs back per request. It is deliberately free of
//! threads, queues and faults so the property test can pin it bit-identical
//! to per-request [`NetworkPlan::execute`][crate::accsim::NetworkPlan]
//! across batch compositions.
//!
//! [`run_worker`] wraps that core in the server's fault boundary: compute
//! runs under `catch_unwind`, so a panic — injected or real — rejects
//! exactly the requests of the poisoned batch with a typed
//! [`ServeError::WorkerPanicked`] and then re-raises to kill the worker
//! thread. The supervisor (in [`super::session`]) observes the death and
//! respawns a fresh worker with fresh scratch; queued requests for other
//! batches never notice.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use super::admission::{AdmissionQueue, JobReply, ServeStats};
use super::cache::PlanCache;
use super::error::ServeError;
use super::fault::FaultPlan;
use crate::accsim::{IntMatrix, NetScratch, SharedNetworkPlan};
use crate::tensor::Tensor;

/// The result of one micro-batch execution, split back per request.
pub struct MicroBatchOutcome {
    /// One `[rows_i, output_dim]` output tensor per input, in input order.
    pub per_request: Vec<Tensor>,
    /// Overflow events summed over every layer of the batch execution.
    pub overflow_events: u64,
    /// Total rows executed.
    pub total_rows: usize,
}

/// Run the concatenation of `inputs` through the plan as one batch and
/// split the dequantized outputs back per input. Bit-identical to executing
/// each input alone: the engine parallelizes over rows with per-row
/// accumulation order fixed, so batch composition is invisible to both
/// outputs and [`OverflowStats`][crate::accsim::OverflowStats] sums.
pub fn execute_micro_batch(
    plan: &SharedNetworkPlan,
    inputs: &[&IntMatrix],
    scratch: &mut NetScratch,
) -> MicroBatchOutcome {
    let cols = plan.net().input_dim();
    let total_rows: usize = inputs.iter().map(|x| x.rows()).sum();
    let mut flat = Vec::with_capacity(total_rows * cols);
    for x in inputs {
        assert_eq!(x.cols(), cols, "request width {} vs model input dim {cols}", x.cols());
        flat.extend_from_slice(x.data());
    }
    let batch = IntMatrix::from_flat(total_rows, cols, flat);
    let stats = plan.execute_warm(&batch, scratch);
    let mode = &stats[0]; // serving plans carry exactly one AccMode
    let overflow_events: u64 = mode.layer_stats.iter().map(|s| s.overflow_events).sum();
    let out_dim = plan.net().output_dim();
    let out = mode.out.data();
    let mut per_request = Vec::with_capacity(inputs.len());
    let mut row = 0usize;
    for x in inputs {
        let rows = x.rows();
        let slice = &out[row * out_dim..(row + rows) * out_dim];
        per_request.push(Tensor::new(vec![rows, out_dim], slice.to_vec()));
        row += rows;
    }
    MicroBatchOutcome { per_request, overflow_events, total_rows }
}

/// Batch sizing knobs a worker drains the queue with.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum input rows per micro-batch.
    pub max_rows: usize,
    /// How long a non-full batch waits for more same-model rows.
    pub window: Duration,
}

/// The batch-worker loop. Runs until [`AdmissionQueue::close`] drains the
/// queue; panics propagate out (by design) after every request of the
/// poisoned batch has been rejected with `WorkerPanicked`.
pub fn run_worker(
    queue: Arc<AdmissionQueue>,
    cache: Arc<PlanCache>,
    stats: Arc<ServeStats>,
    policy: BatchPolicy,
    fault: FaultPlan,
) {
    let mut scratch = NetScratch::default();
    while let Some((seq, batch)) = queue.next_batch(policy.max_rows, policy.window, &stats) {
        if let Some(ms) = fault.delay_ms {
            std::thread::sleep(Duration::from_millis(ms));
        }
        let plan = match cache.get(batch[0].model_hash) {
            Ok(plan) => plan,
            Err(e) => {
                // A load failure poisons only this batch, typed — the
                // worker itself keeps draining.
                for req in batch {
                    req.respond(Err(e.clone()));
                }
                continue;
            }
        };
        let inputs: Vec<&IntMatrix> = batch.iter().map(|r| &r.rows).collect();
        let inject = fault.panic_batch == Some(seq);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if inject {
                panic!("injected fault: panic_batch {seq}");
            }
            execute_micro_batch(&plan, &inputs, &mut scratch)
        }));
        drop(inputs);
        match outcome {
            Ok(result) => {
                let total_rows = result.total_rows;
                for (req, outputs) in batch.into_iter().zip(result.per_request) {
                    req.respond(Ok(JobReply {
                        outputs,
                        overflow_events: result.overflow_events,
                        batch_seq: seq,
                        batch_rows: total_rows,
                    }));
                    stats.completed.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(payload) => {
                stats.worker_panics.fetch_add(1, Ordering::Relaxed);
                for req in batch {
                    req.respond(Err(ServeError::WorkerPanicked { batch_seq: seq }));
                }
                // Kill this worker: its scratch may be mid-mutation. The
                // supervisor respawns a clean replacement.
                resume_unwind(payload);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accsim::AccMode;
    use crate::model::{parse_synth_spec, QNetwork};
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    fn plan() -> SharedNetworkPlan {
        let (_, spec) = parse_synth_spec("t:10x8x4:m4n4p16").unwrap();
        let mut net = QNetwork::synthesize(&spec, 7).unwrap();
        let mut rng = Rng::new(11);
        let data: Vec<f32> = (0..32 * 10).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
        net.calibrate(&Tensor::new(vec![32, 10], data));
        let p = net.grid_bits().2;
        SharedNetworkPlan::new(Arc::new(net), &[AccMode::Wrap { p_bits: p }])
    }

    fn inputs(rng: &mut Rng, rows: usize, cols: usize, hi: i64) -> IntMatrix {
        let data = (0..rows * cols).map(|_| rng.below(hi as usize) as i64).collect();
        IntMatrix::from_flat(rows, cols, data)
    }

    #[test]
    fn micro_batch_is_bit_identical_to_per_request_execution() {
        let plan = plan();
        let mut rng = Rng::new(3);
        let reqs: Vec<IntMatrix> =
            [1usize, 3, 2, 5].iter().map(|&r| inputs(&mut rng, r, 10, 15)).collect();
        let refs: Vec<&IntMatrix> = reqs.iter().collect();
        let mut scratch = NetScratch::default();
        let batched = execute_micro_batch(&plan, &refs, &mut scratch);
        assert_eq!(batched.total_rows, 11);
        let mut solo_events = 0u64;
        for (req, got) in reqs.iter().zip(&batched.per_request) {
            let solo = plan.execute(req);
            assert_eq!(solo[0].out.data(), got.data(), "batched outputs must match solo");
            solo_events += solo[0].layer_stats.iter().map(|s| s.overflow_events).sum::<u64>();
        }
        assert_eq!(batched.overflow_events, solo_events);
        // Warm scratch reuse across calls stays bit-identical too.
        let again = execute_micro_batch(&plan, &refs, &mut scratch);
        for (a, b) in batched.per_request.iter().zip(&again.per_request) {
            assert_eq!(a.data(), b.data());
        }
    }
}
