//! The serve wire formats: length-prefixed binary frames and their
//! line-JSON twin.
//!
//! Both protocols carry the same operations against the same typed
//! [`ServeError`] contract; the binary format exists purely to take wire
//! parsing off the hot path (no JSON tree, no per-row vectors — codes
//! stream straight into a pooled [`crate::accsim::IntMatrix`], replies
//! stream straight out of a pooled byte buffer). A connection picks its
//! protocol implicitly with its first byte: binary frames open with the
//! magic byte `b'A'`, JSON requests open with `{` (or whitespace), and the
//! session peeks once to dispatch (see `serve/session.rs`).
//!
//! # Binary frame layout (all integers little-endian)
//!
//! The magic leads every frame — its first byte (`b'A'`) is what protocol
//! negotiation peeks at, so it must be byte 0 on the wire. The length
//! field counts every byte *after itself* (header rest + payload).
//!
//! Request:
//!
//! ```text
//! u32 magic      -- "A2QB" (0x4251_3241 LE); first byte b'A'
//! u32 len        -- bytes after this field (= REQ_HEADER_LEN + payload), <= MAX_FRAME
//! u16 version    -- 1; anything else is refused typed and the connection closes
//! u8  op         -- 1 = infer, 2 = ping, 3 = shutdown, 4 = drain, 5 = resume
//! u8  reserved   -- 0
//! u64 model_hash -- PlanCache key (fnv1a64 of spec/file bytes)
//! u32 rows
//! u32 cols
//! u64 deadline_ms -- 0 means "use the server default"
//! i64 codes[rows * cols]   -- infer payload; empty for ping/shutdown
//! ```
//!
//! Reply:
//!
//! ```text
//! u32 magic | u32 len | u16 version | u8 op (echoed) | u8 status
//! ```
//!
//! `status` 0 is success; otherwise it is [`ServeError::tag`] and the
//! payload is `u32 msg_len + utf8` of the error's `Display` text. A
//! successful infer reply's payload is `u32 rows | u32 cols |
//! u64 overflow_events | u64 batch_seq | u32 batch_rows |
//! f32 outputs[rows * cols]`; a ping ack carries `u8 draining |
//! u64 in_flight` (the router's health probes read both);
//! shutdown/drain/resume success has no payload.
//!
//! Framing errors (bad magic, wrong version, oversized length) poison the
//! stream — the server replies typed and closes. Recoverable request
//! errors (unknown model, wrong dims, out-of-grid codes) drain the frame's
//! remaining payload first, so the connection stays usable.

use std::fmt::Write as _;
use std::io::{self, Read};

use super::error::ServeError;
use crate::json::write_num;

/// Which encoding a request arrived in (and so which encoding its reply
/// must use). Travels with the request through the admission queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// Newline-delimited JSON objects (the original `a2q serve` protocol).
    Json,
    /// Length-prefixed binary frames defined in this module.
    Binary,
}

/// `"A2QB"` interpreted little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"A2QB");
/// First byte of every binary frame's magic — the protocol-negotiation
/// peek byte (`b'A'`; JSON requests start with `{` or whitespace).
pub const MAGIC_BYTE0: u8 = b'A';
/// Current (and only) wire version. Bump on any layout change.
pub const VERSION: u16 = 1;

pub const OP_INFER: u8 = 1;
pub const OP_PING: u8 = 2;
pub const OP_SHUTDOWN: u8 = 3;
/// Stop admitting new work (typed `draining` refusals) but let queued and
/// in-flight requests complete; the zero-loss half of a router failover.
pub const OP_DRAIN: u8 = 4;
/// Clear a previous drain and admit work again.
pub const OP_RESUME: u8 = 5;

/// Bytes of the frame prefix every frame opens with: magic + length.
pub const PREFIX_LEN: usize = 8;
/// Request header bytes after the length field, before the payload.
pub const REQ_HEADER_LEN: usize = 28;
/// Reply header bytes after the length field, before the payload.
pub const REPLY_HEADER_LEN: usize = 4;
/// Upper bound on `len` (64 MiB): refuses absurd frames before buffering.
pub const MAX_FRAME: usize = 1 << 26;

/// Stack chunk for streaming payload decode/drain — multiple of 8 so i64
/// codes never straddle a chunk boundary.
const CHUNK: usize = 8192;

/// A parsed request frame header (everything but the payload).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestHeader {
    pub op: u8,
    pub model_hash: u64,
    pub rows: u32,
    pub cols: u32,
    pub deadline_ms: u64,
}

fn rd_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([b[at], b[at + 1]])
}

fn rd_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

fn rd_u64(b: &[u8], at: usize) -> u64 {
    let mut v = [0u8; 8];
    v.copy_from_slice(&b[at..at + 8]);
    u64::from_le_bytes(v)
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Validate a frame prefix's magic. A mismatch means the stream cannot be
/// trusted for framing: reply typed and close the connection.
pub fn check_magic(magic: u32) -> Result<(), ServeError> {
    if magic != MAGIC {
        return Err(ServeError::BadRequest {
            reason: format!("bad frame magic {magic:#010x} (want {MAGIC:#010x})"),
        });
    }
    Ok(())
}

/// Validate the version and split out the header fields (the bytes after
/// the length field, magic already checked via [`check_magic`]). A version
/// mismatch also poisons framing: reply typed and close.
pub fn parse_request_header(hdr: &[u8; REQ_HEADER_LEN]) -> Result<RequestHeader, ServeError> {
    let version = rd_u16(hdr, 0);
    if version != VERSION {
        return Err(ServeError::BadRequest {
            reason: format!("unsupported wire version {version} (server speaks {VERSION})"),
        });
    }
    Ok(RequestHeader {
        op: hdr[2],
        model_hash: rd_u64(hdr, 4),
        rows: rd_u32(hdr, 12),
        cols: rd_u32(hdr, 16),
        deadline_ms: rd_u64(hdr, 20),
    })
}

/// Discard exactly `n` payload bytes through a stack chunk (keeps framing
/// intact after a request is refused before its payload matters).
pub fn drain_payload<R: Read>(r: &mut R, mut n: usize) -> io::Result<()> {
    let mut chunk = [0u8; CHUNK];
    while n > 0 {
        let take = n.min(CHUNK);
        r.read_exact(&mut chunk[..take])?;
        n -= take;
    }
    Ok(())
}

/// Stream `rows * cols` little-endian i64 codes into `dst`, validating
/// each against the model's input grid `[lo, hi]`. Allocation-free: codes
/// decode through a stack chunk straight into the (pooled) destination.
///
/// The full payload is always consumed, even after a validation failure —
/// the outer `Ok(Err(..))` carries the typed refusal while the connection
/// keeps its framing. The outer `Err` is a transport failure (hang up).
pub fn read_codes<R: Read>(
    r: &mut R,
    rows: usize,
    cols: usize,
    lo: i64,
    hi: i64,
    dst: &mut [i64],
) -> io::Result<Result<(), ServeError>> {
    debug_assert_eq!(dst.len(), rows * cols);
    let mut chunk = [0u8; CHUNK];
    let mut bad: Option<(usize, i64)> = None;
    let total = rows * cols * 8;
    let mut consumed = 0usize;
    while consumed < total {
        let take = (total - consumed).min(CHUNK);
        r.read_exact(&mut chunk[..take])?;
        let base = consumed / 8;
        for (i, word) in chunk[..take].chunks_exact(8).enumerate() {
            let mut b = [0u8; 8];
            b.copy_from_slice(word);
            let code = i64::from_le_bytes(b);
            if (code < lo || code > hi) && bad.is_none() {
                bad = Some((base + i, code));
            }
            dst[base + i] = code;
        }
        consumed += take;
    }
    Ok(match bad {
        // Identical wording to the JSON path's validation: clients see one
        // error surface regardless of encoding.
        Some((at, code)) => Err(ServeError::BadRequest {
            reason: format!(
                "row {} code {} = {code} outside the model's input grid [{lo}, {hi}]",
                at / cols,
                at % cols
            ),
        }),
        None => Ok(()),
    })
}

// --------------------------------------------------------------- encoders

/// Build an infer request frame (client side: loadgen, tests).
pub fn encode_infer_request(
    out: &mut Vec<u8>,
    model_hash: u64,
    rows: usize,
    cols: usize,
    deadline_ms: u64,
    codes: &[i64],
) {
    assert_eq!(codes.len(), rows * cols, "codes vs {rows}x{cols}");
    out.clear();
    put_u32(out, MAGIC);
    put_u32(out, (REQ_HEADER_LEN + codes.len() * 8) as u32);
    put_u16(out, VERSION);
    out.push(OP_INFER);
    out.push(0); // reserved
    put_u64(out, model_hash);
    put_u32(out, rows as u32);
    put_u32(out, cols as u32);
    put_u64(out, deadline_ms);
    for &c in codes {
        out.extend_from_slice(&c.to_le_bytes());
    }
}

/// Build a payload-less request frame (`OP_PING` / `OP_SHUTDOWN`).
pub fn encode_simple_request(out: &mut Vec<u8>, op: u8) {
    out.clear();
    put_u32(out, MAGIC);
    put_u32(out, REQ_HEADER_LEN as u32);
    put_u16(out, VERSION);
    out.push(op);
    out.push(0);
    put_u64(out, 0); // model_hash
    put_u32(out, 0); // rows
    put_u32(out, 0); // cols
    put_u64(out, 0); // deadline_ms
}

fn put_reply_header(out: &mut Vec<u8>, op: u8, status: u8, payload_len: usize) {
    out.clear();
    put_u32(out, MAGIC);
    put_u32(out, (REPLY_HEADER_LEN + payload_len) as u32);
    put_u16(out, VERSION);
    out.push(op);
    out.push(status);
}

/// Encode a successful binary infer reply into `out` (cleared first).
/// Allocation-free once `out` has grown to the reply size.
pub fn encode_binary_infer_ok(
    out: &mut Vec<u8>,
    outputs: &[f32],
    rows: usize,
    cols: usize,
    overflow_events: u64,
    batch_seq: u64,
    batch_rows: usize,
) {
    assert_eq!(outputs.len(), rows * cols, "outputs vs {rows}x{cols}");
    put_reply_header(out, OP_INFER, 0, 28 + outputs.len() * 4);
    put_u32(out, rows as u32);
    put_u32(out, cols as u32);
    put_u64(out, overflow_events);
    put_u64(out, batch_seq);
    put_u32(out, batch_rows as u32);
    for &v in outputs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a payload-less success reply (shutdown/drain/resume acks).
pub fn encode_ok_empty(out: &mut Vec<u8>, op: u8) {
    put_reply_header(out, op, 0, 0);
}

/// Bytes of a ping ack's payload: `u8 draining | u64 in_flight`.
pub const PONG_PAYLOAD_LEN: usize = 9;

/// Encode a ping ack carrying the replica's drain flag and in-flight
/// count — one cheap probe tells a router both liveness and drain
/// progress.
pub fn encode_pong(out: &mut Vec<u8>, draining: bool, in_flight: u64) {
    put_reply_header(out, OP_PING, 0, PONG_PAYLOAD_LEN);
    out.push(draining as u8);
    put_u64(out, in_flight);
}

/// Encode a typed error reply: `status` is [`ServeError::tag`], payload is
/// the `Display` text. Off the steady-state path, so the formatting may
/// allocate.
pub fn encode_binary_err(out: &mut Vec<u8>, op: u8, e: &ServeError) {
    put_reply_header(out, op, e.tag(), 0);
    put_u32(out, 0); // msg_len, patched below
    let msg_start = out.len();
    let _ = write!(ByteWriter(out), "{e}");
    let msg_len = (out.len() - msg_start) as u32;
    out[msg_start - 4..msg_start].copy_from_slice(&msg_len.to_le_bytes());
    // Patch the frame length (bytes after the len field at offset 4..8).
    let frame_len = (out.len() - PREFIX_LEN) as u32;
    out[4..8].copy_from_slice(&frame_len.to_le_bytes());
}

/// Encode the JSON line for a successful infer reply into `out` (cleared
/// first), byte-identical to serializing the equivalent [`Json`] tree and
/// appending `'\n'` — pinned by this module's tests. Sorted-key order:
/// `batch_rows < batch_seq < ok < outputs < overflow_events`.
///
/// [`Json`]: crate::json::Json
pub fn encode_json_infer_ok(
    out: &mut Vec<u8>,
    outputs: &[f32],
    rows: usize,
    cols: usize,
    overflow_events: u64,
    batch_seq: u64,
    batch_rows: usize,
) {
    assert_eq!(outputs.len(), rows * cols, "outputs vs {rows}x{cols}");
    out.clear();
    let w = &mut ByteWriter(out);
    let _ = w.write_str("{\"batch_rows\":");
    write_num(w, batch_rows as f64);
    let _ = w.write_str(",\"batch_seq\":");
    write_num(w, batch_seq as f64);
    let _ = w.write_str(",\"ok\":true,\"outputs\":[");
    for r in 0..rows {
        if r > 0 {
            let _ = w.write_str(",");
        }
        let _ = w.write_str("[");
        for (c, &v) in outputs[r * cols..(r + 1) * cols].iter().enumerate() {
            if c > 0 {
                let _ = w.write_str(",");
            }
            write_num(w, v as f64);
        }
        let _ = w.write_str("]");
    }
    let _ = w.write_str("],\"overflow_events\":");
    write_num(w, overflow_events as f64);
    let _ = w.write_str("}\n");
}

/// Dispatch the worker-side reply encode on the request's wire format.
#[allow(clippy::too_many_arguments)]
pub fn encode_infer_ok(
    wire: WireFormat,
    out: &mut Vec<u8>,
    outputs: &[f32],
    rows: usize,
    cols: usize,
    overflow_events: u64,
    batch_seq: u64,
    batch_rows: usize,
) {
    match wire {
        WireFormat::Json => {
            encode_json_infer_ok(out, outputs, rows, cols, overflow_events, batch_seq, batch_rows)
        }
        WireFormat::Binary => encode_binary_infer_ok(
            out,
            outputs,
            rows,
            cols,
            overflow_events,
            batch_seq,
            batch_rows,
        ),
    }
}

/// `fmt::Write` over a byte vector: lets integer/float formatting write
/// straight into pooled reply buffers with no intermediate `String`.
pub struct ByteWriter<'a>(pub &'a mut Vec<u8>);

impl std::fmt::Write for ByteWriter<'_> {
    fn write_str(&mut self, s: &str) -> std::fmt::Result {
        self.0.extend_from_slice(s.as_bytes());
        Ok(())
    }
}

// ---------------------------------------------------------- client decode

/// A decoded binary reply (client side — allocates, not on the serve hot
/// path).
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    InferOk {
        rows: usize,
        cols: usize,
        overflow_events: u64,
        batch_seq: u64,
        batch_rows: usize,
        outputs: Vec<f32>,
    },
    /// Payload-less success (shutdown/drain/resume ack, or a legacy ping).
    Ok { op: u8 },
    /// Ping ack with the replica's drain flag and in-flight count.
    Pong { draining: bool, in_flight: u64 },
    /// Typed refusal: `tag` maps to a code via [`ServeError::code_for_tag`].
    Err { op: u8, tag: u8, message: String },
}

/// Read one reply frame into `scratch` and decode it, keeping transport
/// failures separate from protocol violations: the outer `io::Error` (a
/// hangup, reset or read timeout — its `ErrorKind` intact for outcome
/// classification) versus the inner decode error (malformed frame from a
/// live transport). Clients that don't care use [`read_reply`].
pub fn read_reply_frame<R: Read>(
    r: &mut R,
    scratch: &mut Vec<u8>,
) -> io::Result<anyhow::Result<Reply>> {
    let mut prefix = [0u8; PREFIX_LEN];
    r.read_exact(&mut prefix)?;
    let magic = rd_u32(&prefix, 0);
    if magic != MAGIC {
        return Ok(Err(anyhow::anyhow!("bad reply magic {magic:#010x}")));
    }
    let len = rd_u32(&prefix, 4) as usize;
    if !(REPLY_HEADER_LEN..=MAX_FRAME).contains(&len) {
        return Ok(Err(anyhow::anyhow!("bad reply frame length {len}")));
    }
    scratch.clear();
    scratch.resize(len, 0);
    r.read_exact(scratch)?;
    Ok(parse_reply_body(scratch))
}

/// Read and decode one reply frame (client side: loadgen, tests).
pub fn read_reply<R: Read>(r: &mut R, scratch: &mut Vec<u8>) -> anyhow::Result<Reply> {
    read_reply_frame(r, scratch)?
}

/// Decode a reply frame's body (everything after the 8-byte prefix).
fn parse_reply_body(scratch: &[u8]) -> anyhow::Result<Reply> {
    let version = rd_u16(scratch, 0);
    anyhow::ensure!(version == VERSION, "unsupported reply version {version}");
    let op = scratch[2];
    let status = scratch[3];
    let payload = &scratch[REPLY_HEADER_LEN..];
    if status != 0 {
        anyhow::ensure!(payload.len() >= 4, "truncated error payload");
        let msg_len = rd_u32(payload, 0) as usize;
        anyhow::ensure!(payload.len() == 4 + msg_len, "bad error payload length");
        let message = std::str::from_utf8(&payload[4..])?.to_string();
        return Ok(Reply::Err { op, tag: status, message });
    }
    if op == OP_PING && payload.len() >= PONG_PAYLOAD_LEN {
        return Ok(Reply::Pong { draining: payload[0] != 0, in_flight: rd_u64(payload, 1) });
    }
    if op != OP_INFER {
        return Ok(Reply::Ok { op });
    }
    anyhow::ensure!(payload.len() >= 28, "truncated infer payload");
    let rows = rd_u32(payload, 0) as usize;
    let cols = rd_u32(payload, 4) as usize;
    let overflow_events = rd_u64(payload, 8);
    let batch_seq = rd_u64(payload, 16);
    let batch_rows = rd_u32(payload, 24) as usize;
    anyhow::ensure!(payload.len() == 28 + rows * cols * 4, "infer payload vs {rows}x{cols}");
    let outputs = payload[28..]
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Reply::InferOk { rows, cols, overflow_events, batch_seq, batch_rows, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Json;
    use std::io::Cursor;

    #[test]
    fn infer_request_frames_round_trip() {
        let codes: Vec<i64> = vec![3, -2, 0, 7, 1, -5];
        let mut frame = Vec::new();
        encode_infer_request(&mut frame, 0xfeed_beef, 2, 3, 250, &codes);
        assert_eq!(frame.len(), PREFIX_LEN + REQ_HEADER_LEN + 6 * 8);
        assert_eq!(frame[0], MAGIC_BYTE0, "byte 0 on the wire is the negotiation peek byte");

        let mut cur = Cursor::new(&frame[..]);
        let mut prefix = [0u8; PREFIX_LEN];
        cur.read_exact(&mut prefix).unwrap();
        check_magic(u32::from_le_bytes([prefix[0], prefix[1], prefix[2], prefix[3]])).unwrap();
        let len = u32::from_le_bytes([prefix[4], prefix[5], prefix[6], prefix[7]]) as usize;
        assert_eq!(len, REQ_HEADER_LEN + 6 * 8);
        let mut hdr = [0u8; REQ_HEADER_LEN];
        cur.read_exact(&mut hdr).unwrap();
        let h = parse_request_header(&hdr).unwrap();
        assert_eq!(
            h,
            RequestHeader { op: OP_INFER, model_hash: 0xfeed_beef, rows: 2, cols: 3, deadline_ms: 250 }
        );
        let mut dst = vec![0i64; 6];
        read_codes(&mut cur, 2, 3, -8, 7, &mut dst).unwrap().unwrap();
        assert_eq!(dst, codes);
        assert_eq!(cur.position() as usize, frame.len(), "payload fully consumed");
    }

    #[test]
    fn out_of_grid_codes_refuse_typed_but_consume_the_frame() {
        let codes: Vec<i64> = vec![1, 99, 2, -99];
        let mut frame = Vec::new();
        encode_infer_request(&mut frame, 1, 2, 2, 0, &codes);
        let mut cur = Cursor::new(&frame[PREFIX_LEN + REQ_HEADER_LEN..]);
        let mut dst = vec![0i64; 4];
        let err = read_codes(&mut cur, 2, 2, -8, 7, &mut dst).unwrap().unwrap_err();
        assert_eq!(
            err,
            ServeError::BadRequest {
                reason: "row 0 code 1 = 99 outside the model's input grid [-8, 7]".to_string()
            },
            "first violation wins, with the JSON path's exact wording"
        );
        assert_eq!(cur.position() as usize, 4 * 8, "payload drained despite the refusal");
    }

    #[test]
    fn bad_magic_and_version_close_typed() {
        let mut frame = Vec::new();
        encode_simple_request(&mut frame, OP_PING);
        assert_eq!(frame.len(), PREFIX_LEN + REQ_HEADER_LEN);
        assert_eq!(frame[0], MAGIC_BYTE0);
        let mut hdr = [0u8; REQ_HEADER_LEN];
        hdr.copy_from_slice(&frame[PREFIX_LEN..]);
        assert_eq!(parse_request_header(&hdr).unwrap().op, OP_PING);

        check_magic(MAGIC).unwrap();
        let e = check_magic(u32::from_le_bytes(*b"X2QB")).unwrap_err();
        assert_eq!(e.code(), "bad_request");
        assert!(e.to_string().contains("magic"), "{e}");

        let mut bad_version = hdr;
        bad_version[0] = 9;
        let e = parse_request_header(&bad_version).unwrap_err();
        assert_eq!(e.code(), "bad_request");
        assert!(e.to_string().contains("version 9"), "{e}");
    }

    #[test]
    fn binary_replies_round_trip() {
        let outputs = vec![1.5f32, -2.0, 0.25, 3.0];
        let mut frame = Vec::new();
        encode_binary_infer_ok(&mut frame, &outputs, 2, 2, 7, 42, 5);
        let mut scratch = Vec::new();
        let reply = read_reply(&mut Cursor::new(&frame[..]), &mut scratch).unwrap();
        assert_eq!(
            reply,
            Reply::InferOk {
                rows: 2,
                cols: 2,
                overflow_events: 7,
                batch_seq: 42,
                batch_rows: 5,
                outputs
            }
        );

        encode_ok_empty(&mut frame, OP_SHUTDOWN);
        let reply = read_reply(&mut Cursor::new(&frame[..]), &mut scratch).unwrap();
        assert_eq!(reply, Reply::Ok { op: OP_SHUTDOWN });

        encode_pong(&mut frame, true, 17);
        let reply = read_reply(&mut Cursor::new(&frame[..]), &mut scratch).unwrap();
        assert_eq!(reply, Reply::Pong { draining: true, in_flight: 17 });
        encode_pong(&mut frame, false, 0);
        let reply = read_reply(&mut Cursor::new(&frame[..]), &mut scratch).unwrap();
        assert_eq!(reply, Reply::Pong { draining: false, in_flight: 0 });
        // A payload-less ping ack (pre-drain wire) still decodes.
        encode_ok_empty(&mut frame, OP_PING);
        let reply = read_reply(&mut Cursor::new(&frame[..]), &mut scratch).unwrap();
        assert_eq!(reply, Reply::Ok { op: OP_PING });

        let e = ServeError::Overloaded { queued: 8, capacity: 8 };
        encode_binary_err(&mut frame, OP_INFER, &e);
        let reply = read_reply(&mut Cursor::new(&frame[..]), &mut scratch).unwrap();
        assert_eq!(reply, Reply::Err { op: OP_INFER, tag: e.tag(), message: e.to_string() });
        assert_eq!(ServeError::code_for_tag(e.tag()), Some("overloaded"));
    }

    #[test]
    fn json_infer_encode_is_byte_identical_to_the_json_tree() {
        // Mixed integral and fractional outputs exercise both write_num arms.
        let outputs = vec![1.0f32, -0.5, 3.25, 2.0, 0.0, -7.125];
        let mut encoded = Vec::new();
        encode_json_infer_ok(&mut encoded, &outputs, 2, 3, 9, 17, 6);

        let tree = Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "outputs",
                Json::arr(outputs.chunks(3).map(Json::from_f32s).collect::<Vec<_>>()),
            ),
            ("overflow_events", Json::num(9.0)),
            ("batch_seq", Json::num(17.0)),
            ("batch_rows", Json::num(6.0)),
        ]);
        let mut want = tree.to_string();
        want.push('\n');
        assert_eq!(std::str::from_utf8(&encoded).unwrap(), want);
    }
}
