//! `a2q serve`: an overload-safe inference service over the accumulator
//! simulation engine.
//!
//! The serving claim mirrors the paper's: A2Q makes overflow behaviour a
//! *provable property* rather than a load-dependent accident — so a server
//! built on it must extend the same discipline to its own failure modes.
//! Overload and faults degrade latency and per-request availability, never
//! correctness and never the process:
//!
//! * [`cache`] — fixed-capacity concurrent plan cache: model hash →
//!   [`crate::accsim::SharedNetworkPlan`], LRU-evicted, reloaded from
//!   source on demand, validated at the trust boundary with typed errors.
//! * [`admission`] — the bounded queue between connections and workers:
//!   explicit [`ServeError::Overloaded`] at the door, deadline shedding at
//!   dequeue, deadline-aware same-model micro-batching with round-robin
//!   rotation across models.
//! * [`batcher`] — micro-batch execution ([`execute_micro_batch`], pinned
//!   bit-identical to per-request execution) and the `catch_unwind` worker
//!   loop that converts panics into per-batch typed rejections.
//! * [`session`] — the TCP server: accept loop, per-connection sessions,
//!   worker pool and the supervisor that respawns panicked workers.
//! * [`fault`] — the `A2Q_FAULT` injection seam (worker panic, batch
//!   latency, cache-load failure) that lets tests and CI *prove* recovery.
//! * [`loadgen`] — open-loop load generation (either wire format) with
//!   p50/p99 + typed-shed/transport-fault classification and the
//!   §Perf-Serve journal hook.
//! * [`router`] — `a2q route`: the fault-tolerant shard router fronting N
//!   replicas (health probes, circuit breaker, bounded retry, hedging,
//!   zero-loss drain/failover). Replica failure becomes an availability
//!   event, never a correctness event.
//!
//! ## Two wire protocols, one serving core
//!
//! A connection's first byte picks its protocol (see [`session`]):
//!
//! * **Line-JSON** (first byte `{` or whitespace): one JSON object per
//!   line, one JSON reply line per request. Human-debuggable; carries the
//!   control-plane ops (`stats`, `model_info`) as well as `ping` /
//!   `infer` / `shutdown`.
//! * **Binary frames** (first byte `b'A'`, the magic): the
//!   length-prefixed format of [`wire`] — versioned header, i64 codes in,
//!   f32 outputs out, [`ServeError::tag`] status bytes. The
//!   steady-state-allocation-free hot path.
//!
//! Both speak the same typed error contract and produce bit-identical
//! inference results (the serve smoke tests pin JSON ≡ binary across
//! batch shapes and kernel paths). The shared core: [`pool`] hands every
//! request one [`PooledBuf`] (decoded input codes + encoded reply bytes)
//! that travels session → admission → worker → session and returns to the
//! pool on drop, so a warmed server's request→reply path performs no heap
//! allocation (pinned by `tests/serve_alloc.rs`).

pub mod admission;
pub mod batcher;
pub mod cache;
pub mod error;
pub mod fault;
pub mod loadgen;
pub mod pool;
pub mod router;
pub mod session;
pub mod wire;

pub use admission::{
    AdmissionQueue, JobReply, JobRequest, RejectedJob, ReplySlot, ServeStats, StatsSnapshot,
};
pub use batcher::{execute_micro_batch, run_worker, BatchPolicy, MicroBatchOutcome, WorkerScratch};
pub use cache::{ModelSource, PlanCache};
pub use error::ServeError;
pub use fault::FaultPlan;
pub use loadgen::{run_loadgen, LoadReport, LoadgenConfig};
pub use pool::{BufferPool, PooledBuf};
pub use router::{BackendSpec, HealthState, RetryPolicy, Router, RouterConfig, RouterStats};
pub use session::{run_binary_session, ServeConfig, Server};
pub use wire::WireFormat;
