//! `a2q serve`: an overload-safe inference service over the accumulator
//! simulation engine.
//!
//! The serving claim mirrors the paper's: A2Q makes overflow behaviour a
//! *provable property* rather than a load-dependent accident — so a server
//! built on it must extend the same discipline to its own failure modes.
//! Overload and faults degrade latency and per-request availability, never
//! correctness and never the process:
//!
//! * [`cache`] — fixed-capacity concurrent plan cache: model hash →
//!   [`crate::accsim::SharedNetworkPlan`], LRU-evicted, reloaded from
//!   source on demand, validated at the trust boundary with typed errors.
//! * [`admission`] — the bounded queue between connections and workers:
//!   explicit [`ServeError::Overloaded`] at the door, deadline shedding at
//!   dequeue, deadline-aware same-model micro-batching.
//! * [`batcher`] — micro-batch execution ([`execute_micro_batch`], pinned
//!   bit-identical to per-request execution) and the `catch_unwind` worker
//!   loop that converts panics into per-batch typed rejections.
//! * [`session`] — the TCP line-JSON server: accept loop, per-connection
//!   sessions, worker pool and the supervisor that respawns panicked
//!   workers.
//! * [`fault`] — the `A2Q_FAULT` injection seam (worker panic, batch
//!   latency, cache-load failure) that lets tests and CI *prove* recovery.
//! * [`loadgen`] — open-loop load generation with p50/p99 + shed-rate
//!   reporting and the §Perf-Serve journal hook.

pub mod admission;
pub mod batcher;
pub mod cache;
pub mod error;
pub mod fault;
pub mod loadgen;
pub mod session;

pub use admission::{AdmissionQueue, JobReply, JobRequest, ServeStats, StatsSnapshot};
pub use batcher::{execute_micro_batch, run_worker, BatchPolicy, MicroBatchOutcome};
pub use cache::{ModelSource, PlanCache};
pub use error::ServeError;
pub use fault::FaultPlan;
pub use loadgen::{run_loadgen, LoadReport, LoadgenConfig};
pub use session::{ServeConfig, Server};
