//! Open-loop load generator and latency journal for `a2q serve`.
//!
//! Open-loop means the arrival schedule is fixed up front from the target
//! rate — a client never slows down because the server is slow. That is the
//! honest way to measure an overloaded service: a closed loop (wait for the
//! reply, then send) self-throttles to whatever the server can do and hides
//! both queueing delay and shed rate (the coordinated-omission trap).
//! Every connection sends request `i` at `start + i * interval`, sleeping
//! only when ahead of schedule, and records wall latency and the typed
//! outcome code of each reply.
//!
//! The report separates outcomes by the admission-control contract:
//! `ok` (served, bit-exact), `shed_overloaded` / `shed_deadline` /
//! `shed_draining` / `shed_no_backend` (typed rejections — the *expected*
//! overload/failover behaviour), `worker_panicked` (typed fault
//! isolation), the transport classes `conn_refused` / `conn_reset` /
//! `timeout` (the connection failed before a reply arrived — what a router
//! experiment must distinguish from sheds), and `errors_other` (everything
//! that means the contract broke: malformed replies, unexpected codes).
//! Connections reconnect per scheduled request after a transport fault, so
//! a replica restart shows up as a bounded run of transport-classed
//! outcomes, not a dead connection for the rest of the run. Latency
//! percentiles are computed over served requests only — shed requests are
//! availability events, not latency samples.
//!
//! The generator speaks either wire format ([`LoadgenConfig::wire`],
//! `a2q loadgen --wire json|binary`): JSON requests exercise the original
//! line protocol, binary requests the zero-copy frame protocol. Both
//! classify replies through the same typed-code table (binary status tags
//! map through [`ServeError::code_for_tag`]), so the report is directly
//! comparable across formats — that comparison is what the CI serve-smoke
//! job gates on (`serve/wire_binary_rows_per_s` vs
//! `serve/wire_json_rows_per_s`).

use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::error::ServeError;
use super::wire::{self, WireFormat};
use crate::json::Json;
use crate::perf::{self, BenchRecord};
use crate::rng::Rng;

/// Load-generation knobs.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Model name (or decimal hash) to infer against.
    pub model: String,
    /// Aggregate target request rate across all connections.
    pub rps: f64,
    /// How long to generate load.
    pub duration_ms: u64,
    /// Parallel connections the rate is split across.
    pub connections: usize,
    /// Input rows per request.
    pub rows_per_req: usize,
    /// Per-request deadline budget sent to the server.
    pub deadline_ms: u64,
    /// Input-generation seed (deterministic per connection).
    pub seed: u64,
    /// Which wire protocol to drive the server with.
    pub wire: WireFormat,
    /// TCP connect timeout (also bounds per-request reconnect attempts).
    pub connect_timeout_ms: u64,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            addr: "127.0.0.1:7878".to_string(),
            model: "synth".to_string(),
            rps: 200.0,
            duration_ms: 2000,
            connections: 4,
            rows_per_req: 4,
            deadline_ms: 200,
            seed: 1,
            wire: WireFormat::Json,
            connect_timeout_ms: 1000,
        }
    }
}

/// Outcome of one loadgen run, aggregated over all connections.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub sent: u64,
    pub ok: u64,
    pub shed_overloaded: u64,
    pub shed_deadline: u64,
    pub shed_draining: u64,
    pub shed_no_backend: u64,
    pub worker_panicked: u64,
    /// Transport classes: the connection itself failed. A router in front
    /// must drive all three to zero; against a bare replica they separate
    /// "refused at connect" / "died mid-exchange" / "read timed out".
    pub conn_refused: u64,
    pub conn_reset: u64,
    pub timeout: u64,
    pub errors_other: u64,
    /// Latency percentiles over served requests, milliseconds.
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Served input rows per second of generation time.
    pub rows_per_s: f64,
    /// Total overflow events reported for served requests (0 for A2Q
    /// models: overload must never degrade correctness).
    pub overflow_events: u64,
    /// Wall time the run actually took.
    pub elapsed_ms: f64,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One request/reply exchange on an established connection. Returns the
/// reply's outcome code (`"ok"` for success) plus served-path details.
fn exchange(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    line: &str,
) -> anyhow::Result<Json> {
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reply = String::new();
    if reader.read_line(&mut reply)? == 0 {
        anyhow::bail!("server closed the connection");
    }
    Ok(Json::parse(&reply)?)
}

/// How a transport-level failure counts in the report.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TransportClass {
    Refused,
    Reset,
    Timeout,
    Other,
}

/// Map an io error kind onto the report's transport classes. `WouldBlock`
/// is how a socket read timeout surfaces on unix; `UnexpectedEof` is a
/// frame torn mid-read (`read_exact` past a hangup).
fn classify_io(kind: io::ErrorKind) -> TransportClass {
    use io::ErrorKind as K;
    match kind {
        K::ConnectionRefused => TransportClass::Refused,
        K::ConnectionReset
        | K::ConnectionAborted
        | K::BrokenPipe
        | K::NotConnected
        | K::UnexpectedEof => TransportClass::Reset,
        K::WouldBlock | K::TimedOut => TransportClass::Timeout,
        _ => TransportClass::Other,
    }
}

/// A client connection with its buffered read half.
struct Conn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

/// Connect with a bounded timeout (every resolved address gets a try) and
/// a read timeout so a hung peer becomes a classified `timeout`, not a
/// wedged loadgen thread.
fn connect(addr: &str, connect_timeout: Duration, read_timeout: Duration) -> io::Result<Conn> {
    let mut last =
        io::Error::new(io::ErrorKind::InvalidInput, format!("no address resolved for {addr}"));
    for sock_addr in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sock_addr, connect_timeout) {
            Ok(stream) => {
                stream.set_read_timeout(Some(read_timeout))?;
                let reader = BufReader::new(stream.try_clone()?);
                return Ok(Conn { stream, reader });
            }
            Err(e) => last = e,
        }
    }
    Err(last)
}

/// Ask the server for a model's grid (and plan-cache hash) so inputs can
/// be generated on it. Metadata always travels over JSON — binary clients
/// resolve once here, then address the model by hash on the data plane.
fn model_info(addr: &str, model: &str) -> anyhow::Result<(usize, i64, i64, u64)> {
    let mut stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let req = Json::obj(vec![("op", Json::str("model_info")), ("model", Json::str(model))]);
    let reply = exchange(&mut stream, &mut reader, &reply_line(&req))?;
    if !reply.get("ok")?.as_bool()? {
        anyhow::bail!(
            "model_info {model:?} failed: {}",
            reply.opt("error").and_then(|e| e.as_str().ok()).unwrap_or("?")
        );
    }
    let k = reply.get("input_dim")?.as_usize()?;
    let lo = reply.get("code_lo")?.as_f64()? as i64;
    let hi = reply.get("code_hi")?.as_f64()? as i64;
    let hash: u64 = reply.get("hash")?.as_str()?.parse()?;
    Ok((k, lo, hi, hash))
}

fn reply_line(v: &Json) -> String {
    v.to_string()
}

/// Fetch the server's stats counters (`op: stats`) as raw JSON.
pub fn fetch_server_stats(addr: &str) -> anyhow::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    exchange(&mut stream, &mut reader, &reply_line(&Json::obj(vec![("op", Json::str("stats"))])))
}

/// Ask the server to shut down.
pub fn send_shutdown(addr: &str) -> anyhow::Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let line = reply_line(&Json::obj(vec![("op", Json::str("shutdown"))]));
    exchange(&mut stream, &mut reader, &line)?;
    Ok(())
}

/// Run the open-loop load and aggregate the report.
pub fn run_loadgen(cfg: &LoadgenConfig) -> anyhow::Result<LoadReport> {
    anyhow::ensure!(cfg.rps > 0.0, "rps must be positive");
    anyhow::ensure!(cfg.rows_per_req > 0, "rows_per_req must be positive");
    let connections = cfg.connections.max(1);
    let (k, lo, hi, hash) = model_info(&cfg.addr, &cfg.model)?;
    let duration = Duration::from_millis(cfg.duration_ms.max(1));
    let per_conn_interval = Duration::from_secs_f64(connections as f64 / cfg.rps);
    let per_conn_requests =
        ((duration.as_secs_f64() * cfg.rps) / connections as f64).ceil().max(1.0) as u64;
    let cfg = Arc::new(cfg.clone());

    #[derive(Default)]
    struct ConnTally {
        sent: u64,
        ok: u64,
        shed_overloaded: u64,
        shed_deadline: u64,
        shed_draining: u64,
        shed_no_backend: u64,
        worker_panicked: u64,
        conn_refused: u64,
        conn_reset: u64,
        timeout: u64,
        errors_other: u64,
        overflow_events: u64,
        latencies_ms: Vec<f64>,
    }

    impl ConnTally {
        fn count_transport(&mut self, class: TransportClass) {
            match class {
                TransportClass::Refused => self.conn_refused += 1,
                TransportClass::Reset => self.conn_reset += 1,
                TransportClass::Timeout => self.timeout += 1,
                TransportClass::Other => self.errors_other += 1,
            }
        }

        fn count_code(&mut self, code: Option<&str>) {
            match code {
                Some("overloaded") => self.shed_overloaded += 1,
                Some("deadline_exceeded") => self.shed_deadline += 1,
                Some("draining") => self.shed_draining += 1,
                Some("no_backend") => self.shed_no_backend += 1,
                Some("worker_panicked") => self.worker_panicked += 1,
                _ => self.errors_other += 1,
            }
        }
    }

    let started = Instant::now();
    let mut handles = Vec::new();
    for conn_id in 0..connections {
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || -> ConnTally {
            let mut tally = ConnTally {
                latencies_ms: Vec::with_capacity(per_conn_requests as usize),
                ..ConnTally::default()
            };
            let connect_timeout = Duration::from_millis(cfg.connect_timeout_ms.max(1));
            // Generous read ceiling: a healthy server sheds at the request
            // deadline, so anything this late is a transport-level hang.
            let read_timeout = Duration::from_millis(cfg.deadline_ms.saturating_mul(2) + 2000);
            let mut conn: Option<Conn> = None;
            let mut rng = Rng::new(cfg.seed ^ (conn_id as u64).wrapping_mul(0x9e37_79b9));
            let span = (hi - lo + 1).max(1) as usize;
            // Binary-path reusable buffers: codes, the request frame and
            // the reply scratch amortize to zero allocation per request.
            let mut codes: Vec<i64> = Vec::with_capacity(cfg.rows_per_req * k);
            let mut frame: Vec<u8> = Vec::new();
            let mut scratch: Vec<u8> = Vec::new();
            let start = Instant::now();
            for i in 0..per_conn_requests {
                // Open loop: request i fires at its scheduled instant no
                // matter how the previous one fared.
                let due = start + per_conn_interval.mul_f64(i as f64);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                tally.sent += 1;
                // Reconnect per scheduled request after a transport fault:
                // a dead replica costs exactly the requests that land while
                // it is down, never the remainder of the run.
                if conn.is_none() {
                    match connect(&cfg.addr, connect_timeout, read_timeout) {
                        Ok(c) => conn = Some(c),
                        Err(e) => {
                            tally.count_transport(classify_io(e.kind()));
                            continue;
                        }
                    }
                }
                let c = conn.as_mut().expect("connection established above");
                match cfg.wire {
                    WireFormat::Binary => {
                        codes.clear();
                        codes.extend((0..cfg.rows_per_req * k).map(|_| lo + rng.below(span) as i64));
                        wire::encode_infer_request(
                            &mut frame,
                            hash,
                            cfg.rows_per_req,
                            k,
                            cfg.deadline_ms,
                            &codes,
                        );
                        let sent_at = Instant::now();
                        let outcome = match c.stream.write_all(&frame) {
                            Err(e) => Err(e),
                            Ok(()) => wire::read_reply_frame(&mut c.reader, &mut scratch),
                        };
                        match outcome {
                            Ok(Ok(wire::Reply::InferOk { overflow_events, .. })) => {
                                tally.ok += 1;
                                tally.latencies_ms.push(sent_at.elapsed().as_secs_f64() * 1e3);
                                tally.overflow_events += overflow_events;
                            }
                            Ok(Ok(wire::Reply::Err { tag, .. })) => {
                                tally.count_code(ServeError::code_for_tag(tag));
                            }
                            Ok(Ok(_)) | Ok(Err(_)) => {
                                // Unexpected or malformed frame: the stream
                                // may be desynchronized — count it against
                                // the contract and resynchronize by
                                // reconnecting.
                                tally.errors_other += 1;
                                conn = None;
                            }
                            Err(e) => {
                                tally.count_transport(classify_io(e.kind()));
                                conn = None;
                            }
                        }
                    }
                    WireFormat::Json => {
                        let rows: Vec<Json> = (0..cfg.rows_per_req)
                            .map(|_| {
                                let codes = (0..k).map(|_| lo + rng.below(span) as i64);
                                Json::Arr(codes.map(|c| Json::num(c as f64)).collect())
                            })
                            .collect();
                        let req = Json::obj(vec![
                            ("op", Json::str("infer")),
                            ("model", Json::str(cfg.model.as_str())),
                            ("rows", Json::arr(rows)),
                            ("deadline_ms", Json::num(cfg.deadline_ms as f64)),
                        ]);
                        let line = reply_line(&req);
                        let sent_at = Instant::now();
                        let outcome = c
                            .stream
                            .write_all(line.as_bytes())
                            .and_then(|()| c.stream.write_all(b"\n"))
                            .and_then(|()| {
                                let mut reply = String::new();
                                let n = c.reader.read_line(&mut reply)?;
                                Ok((n, reply))
                            });
                        match outcome {
                            Ok((0, _)) => {
                                // Orderly close before any reply bytes:
                                // same class as a mid-exchange reset.
                                tally.conn_reset += 1;
                                conn = None;
                            }
                            Ok((_, reply)) => match Json::parse(&reply) {
                                Ok(reply) => {
                                    let ok = reply
                                        .get("ok")
                                        .and_then(|v| v.as_bool())
                                        .unwrap_or(false);
                                    if ok {
                                        tally.ok += 1;
                                        tally
                                            .latencies_ms
                                            .push(sent_at.elapsed().as_secs_f64() * 1e3);
                                        tally.overflow_events += reply
                                            .opt("overflow_events")
                                            .and_then(|v| v.as_u64().ok())
                                            .unwrap_or(0);
                                    } else {
                                        tally.count_code(
                                            reply.opt("code").and_then(|v| v.as_str().ok()),
                                        );
                                    }
                                }
                                Err(_) => {
                                    tally.errors_other += 1;
                                    conn = None;
                                }
                            },
                            Err(e) => {
                                tally.count_transport(classify_io(e.kind()));
                                conn = None;
                            }
                        }
                    }
                }
            }
            tally
        }));
    }

    let mut report = LoadReport::default();
    let mut latencies: Vec<f64> = Vec::new();
    for h in handles {
        let t = h.join().map_err(|_| anyhow::anyhow!("loadgen connection thread panicked"))?;
        report.sent += t.sent;
        report.ok += t.ok;
        report.shed_overloaded += t.shed_overloaded;
        report.shed_deadline += t.shed_deadline;
        report.shed_draining += t.shed_draining;
        report.shed_no_backend += t.shed_no_backend;
        report.worker_panicked += t.worker_panicked;
        report.conn_refused += t.conn_refused;
        report.conn_reset += t.conn_reset;
        report.timeout += t.timeout;
        report.errors_other += t.errors_other;
        report.overflow_events += t.overflow_events;
        latencies.extend(t.latencies_ms);
    }
    let elapsed = started.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    report.p50_ms = percentile(&latencies, 0.50);
    report.p99_ms = percentile(&latencies, 0.99);
    report.rows_per_s = if elapsed > 0.0 {
        (report.ok * cfg.rows_per_req as u64) as f64 / elapsed
    } else {
        0.0
    };
    report.elapsed_ms = elapsed * 1e3;
    Ok(report)
}

/// Render the report as one JSON object (the `a2q loadgen` stdout line).
pub fn report_json(r: &LoadReport, server_stats: Option<&Json>) -> Json {
    let mut pairs = vec![
        ("sent", Json::num(r.sent as f64)),
        ("ok", Json::num(r.ok as f64)),
        ("shed_overloaded", Json::num(r.shed_overloaded as f64)),
        ("shed_deadline", Json::num(r.shed_deadline as f64)),
        ("shed_draining", Json::num(r.shed_draining as f64)),
        ("shed_no_backend", Json::num(r.shed_no_backend as f64)),
        ("worker_panicked", Json::num(r.worker_panicked as f64)),
        ("conn_refused", Json::num(r.conn_refused as f64)),
        ("conn_reset", Json::num(r.conn_reset as f64)),
        ("timeout", Json::num(r.timeout as f64)),
        ("errors_other", Json::num(r.errors_other as f64)),
        ("overflow_events", Json::num(r.overflow_events as f64)),
        ("p50_ms", Json::num((r.p50_ms * 1e3).round() / 1e3)),
        ("p99_ms", Json::num((r.p99_ms * 1e3).round() / 1e3)),
        ("rows_per_s", Json::num(r.rows_per_s.round())),
        ("elapsed_ms", Json::num(r.elapsed_ms.round())),
    ];
    if let Some(stats) = server_stats {
        pairs.push(("server", stats.clone()));
    }
    Json::obj(pairs)
}

/// Journal row name for a metric under a loadgen label. A label ending in
/// `/` is a namespace: `route/` journals `route/p50`, `route/p99`,
/// `route/rows_per_s` — its own top-level family, comparable against the
/// serve family via `a2q perfcheck --require`. Any other label keeps the
/// legacy `serve/{label}_{metric}` names.
fn journal_name(label: &str, metric: &str) -> String {
    if label.ends_with('/') {
        format!("{label}{metric}")
    } else {
        format!("serve/{label}_{metric}")
    }
}

/// Journal the report (see [`journal_name`] for the naming scheme) and
/// refresh the EXPERIMENTS.md §Perf-Serve block. Latency rows reuse the
/// journal's ns-per-iter convention (p50/p99 wall latency per request;
/// rows/s as its own row), so `a2q perfcheck` can gate on them like any
/// other bench.
pub fn journal_report(label: &str, r: &LoadReport) -> anyhow::Result<std::path::PathBuf> {
    let records = vec![
        BenchRecord {
            name: journal_name(label, "p50"),
            ns_per_iter: r.p50_ms * 1e6,
            mac_per_s: None,
            sparsity: None,
        },
        BenchRecord {
            name: journal_name(label, "p99"),
            ns_per_iter: r.p99_ms * 1e6,
            mac_per_s: None,
            sparsity: None,
        },
        BenchRecord {
            name: journal_name(label, "rows_per_s"),
            ns_per_iter: if r.rows_per_s > 0.0 { 1e9 / r.rows_per_s } else { 0.0 },
            mac_per_s: None,
            sparsity: None,
        },
    ];
    let path = perf::record_benches(&records)?;
    let shed = r.shed_overloaded + r.shed_deadline + r.shed_draining + r.shed_no_backend;
    let transport = r.conn_refused + r.conn_reset + r.timeout;
    let block = format!(
        "Last recorded by `a2q loadgen --journal` ({label}):\n\n\
         | metric | value |\n|---|---|\n\
         | served | {} / {} sent |\n\
         | shed (typed rejections) | {} |\n\
         | transport faults (refused + reset + timeout) | {} |\n\
         | p50 latency | {:.3} ms |\n\
         | p99 latency | {:.3} ms |\n\
         | served rows/s | {:.0} |\n\
         | overflow events (served) | {} |\n",
        r.ok, r.sent, shed, transport, r.p50_ms, r.p99_ms, r.rows_per_s, r.overflow_events
    );
    perf::update_experiments_serve_block(&block)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_interpolate_sanely() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 51.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
    }

    #[test]
    fn report_json_carries_the_contract_counters() {
        let r = LoadReport {
            sent: 10,
            ok: 4,
            shed_overloaded: 2,
            shed_deadline: 1,
            shed_draining: 1,
            conn_refused: 1,
            conn_reset: 1,
            p50_ms: 1.5,
            p99_ms: 4.0,
            rows_per_s: 1234.0,
            ..LoadReport::default()
        };
        let j = report_json(&r, None);
        let text = j.to_string();
        for needle in [
            "\"ok\":4",
            "\"shed_overloaded\":2",
            "\"shed_deadline\":1",
            "\"shed_draining\":1",
            "\"shed_no_backend\":0",
            "\"conn_refused\":1",
            "\"conn_reset\":1",
            "\"timeout\":0",
            "\"sent\":10",
        ] {
            assert!(text.contains(needle), "{needle} missing from {text}");
        }
    }

    #[test]
    fn io_error_kinds_map_to_transport_classes() {
        use io::ErrorKind as K;
        assert_eq!(classify_io(K::ConnectionRefused), TransportClass::Refused);
        for kind in [
            K::ConnectionReset,
            K::ConnectionAborted,
            K::BrokenPipe,
            K::NotConnected,
            K::UnexpectedEof,
        ] {
            assert_eq!(classify_io(kind), TransportClass::Reset, "{kind:?}");
        }
        assert_eq!(classify_io(K::WouldBlock), TransportClass::Timeout);
        assert_eq!(classify_io(K::TimedOut), TransportClass::Timeout);
        assert_eq!(classify_io(K::PermissionDenied), TransportClass::Other);
    }

    #[test]
    fn journal_labels_support_namespaces() {
        assert_eq!(journal_name("route/", "p50"), "route/p50");
        assert_eq!(journal_name("route/", "rows_per_s"), "route/rows_per_s");
        assert_eq!(journal_name("wire_binary", "p99"), "serve/wire_binary_p99");
    }
}
