//! The server: TCP listener, connection sessions, worker pool and the
//! supervisor that keeps it alive through worker panics.
//!
//! Thread topology: one accept loop spawns a session thread per connection;
//! session threads validate requests and submit them to the shared
//! [`AdmissionQueue`]; `workers` batch-worker threads drain it through
//! [`run_worker`]; one supervisor polls the workers and respawns any that
//! died by panic (a normal worker exit only happens when the queue is
//! closed). Every thread communicates through `Arc`s — there is no global
//! state, so in-process tests can run several servers at once.
//!
//! ## Wire protocol
//!
//! Line-delimited JSON over TCP, one request per line, one response line
//! each (keys sorted — [`crate::json`]). Ops:
//!
//! ```text
//! {"op":"ping"}
//! {"op":"model_info","model":"m"}
//! {"op":"infer","model":"m","rows":[[codes...],...],"deadline_ms":100}
//! {"op":"stats"}
//! {"op":"shutdown"}
//! ```
//!
//! Responses carry `"ok":true` plus op-specific fields, or `"ok":false`
//! with the stable [`ServeError::code`] under `"code"` and a human message
//! under `"error"`. Inference inputs are integer codes on the model's
//! layer-0 activation grid (see `model_info` for the grid range);
//! `deadline_ms` is the request's admission-to-execution budget.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{AdmissionQueue, JobRequest, ServeStats, StatsSnapshot};
use super::batcher::{run_worker, BatchPolicy};
use super::cache::{ModelSource, PlanCache};
use super::error::ServeError;
use super::fault::FaultPlan;
use crate::accsim::IntMatrix;
use crate::json::Json;

/// Server knobs. `Default` is a sane single-host profile.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (tests).
    pub addr: String,
    /// Batch-worker threads.
    pub workers: usize,
    /// Admission-queue capacity (requests, not rows).
    pub queue_capacity: usize,
    /// Maximum input rows per micro-batch.
    pub max_batch_rows: usize,
    /// How long a non-full batch waits for more same-model rows.
    pub batch_window_ms: u64,
    /// Deadline budget applied when a request names none.
    pub default_deadline_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            workers: 2,
            queue_capacity: 64,
            max_batch_rows: 64,
            batch_window_ms: 1,
            default_deadline_ms: 1000,
        }
    }
}

/// A running server. Dropping it does NOT stop it — call
/// [`Server::shutdown`] then [`Server::join`].
pub struct Server {
    addr: SocketAddr,
    queue: Arc<AdmissionQueue>,
    stats: Arc<ServeStats>,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    supervisor_handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind, load + validate every model, start workers and supervisor.
    /// Model validation failures abort startup with the typed load error —
    /// a server that cannot serve its models should not come up.
    pub fn start(
        cfg: &ServeConfig,
        models: &[(String, ModelSource)],
        fault: FaultPlan,
    ) -> anyhow::Result<Server> {
        anyhow::ensure!(!models.is_empty(), "a2q serve needs at least one --models entry");
        let cache = Arc::new(PlanCache::new(models.len().max(1), fault));
        for (name, source) in models {
            cache
                .insert_model(name, source.clone())
                .map_err(|e| anyhow::anyhow!("model {name:?}: {e}"))?;
        }
        let queue = Arc::new(AdmissionQueue::new(cfg.queue_capacity));
        let stats = Arc::new(ServeStats::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let policy = BatchPolicy {
            max_rows: cfg.max_batch_rows.max(1),
            window: Duration::from_millis(cfg.batch_window_ms),
        };

        let spawn_worker = {
            let queue = queue.clone();
            let cache = cache.clone();
            let stats = stats.clone();
            move || {
                let queue = queue.clone();
                let cache = cache.clone();
                let stats = stats.clone();
                std::thread::Builder::new()
                    .name("a2q-serve-worker".to_string())
                    .spawn(move || run_worker(queue, cache, stats, policy, fault))
                    .expect("spawn batch worker")
            }
        };
        let mut workers: Vec<JoinHandle<()>> = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            workers.push(spawn_worker());
        }

        // Supervisor: respawn panicked workers until shutdown, then reap.
        let supervisor_handle = {
            let stats = stats.clone();
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("a2q-serve-supervisor".to_string())
                .spawn(move || loop {
                    let mut i = 0;
                    while i < workers.len() {
                        if workers[i].is_finished() {
                            let dead = workers.swap_remove(i);
                            let panicked = dead.join().is_err();
                            if panicked && !shutdown.load(Ordering::SeqCst) {
                                stats.respawns.fetch_add(1, Ordering::Relaxed);
                                workers.push(spawn_worker());
                            }
                        } else {
                            i += 1;
                        }
                    }
                    if shutdown.load(Ordering::SeqCst) && workers.is_empty() {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                })
                .expect("spawn supervisor")
        };

        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let accept_handle = {
            let queue = queue.clone();
            let cache = cache.clone();
            let stats = stats.clone();
            let shutdown = shutdown.clone();
            let default_deadline = Duration::from_millis(cfg.default_deadline_ms.max(1));
            std::thread::Builder::new()
                .name("a2q-serve-accept".to_string())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        let Ok(stream) = stream else { continue };
                        let queue = queue.clone();
                        let cache = cache.clone();
                        let stats = stats.clone();
                        let shutdown = shutdown.clone();
                        let _ = std::thread::Builder::new()
                            .name("a2q-serve-conn".to_string())
                            .spawn(move || {
                                run_session(
                                    stream,
                                    &queue,
                                    &cache,
                                    &stats,
                                    &shutdown,
                                    default_deadline,
                                )
                            });
                    }
                })
                .expect("spawn accept loop")
        };

        Ok(Server {
            addr,
            queue,
            stats,
            shutdown,
            accept_handle: Some(accept_handle),
            supervisor_handle: Some(supervisor_handle),
        })
    }

    /// The actually bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Begin draining: reject new work typed, wake the accept loop, let
    /// workers run out.
    pub fn shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        self.queue.close(&self.stats);
        // Wake the accept loop so it observes the flag and exits.
        let _ = TcpStream::connect(self.addr);
    }

    /// Wait for the accept loop and worker pool to finish. Call after
    /// [`Server::shutdown`]; joining a live server blocks forever.
    pub fn join(mut self) {
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.supervisor_handle.take() {
            let _ = h.join();
        }
    }
}

fn err_json(e: &ServeError) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::str(e.code())),
        ("error", Json::str(e.to_string())),
    ])
}

fn stats_json(s: &StatsSnapshot) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("admitted", Json::num(s.admitted as f64)),
        ("completed", Json::num(s.completed as f64)),
        ("shed_overloaded", Json::num(s.shed_overloaded as f64)),
        ("shed_deadline", Json::num(s.shed_deadline as f64)),
        ("worker_panics", Json::num(s.worker_panics as f64)),
        ("respawns", Json::num(s.respawns as f64)),
        ("batches", Json::num(s.batches as f64)),
        ("batched_rows", Json::num(s.batched_rows as f64)),
    ])
}

/// One connection: read request lines, write response lines, until the
/// client hangs up or asks for shutdown. Per-request state is a counter and
/// an mpsc channel; the plan cache and queue are shared.
fn run_session(
    stream: TcpStream,
    queue: &AdmissionQueue,
    cache: &PlanCache,
    stats: &ServeStats,
    shutdown: &AtomicBool,
    default_deadline: Duration,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    // The accepted socket's local address IS the listening address: the
    // shutdown op uses it to wake the blocked accept loop.
    let listen_addr = stream.local_addr().ok();
    let reader = BufReader::new(stream);
    let mut next_id = 0u64;
    for line in reader.lines() {
        let Ok(line) = line else { return };
        if line.trim().is_empty() {
            continue;
        }
        next_id += 1;
        let reply = handle_line(
            &line,
            next_id,
            queue,
            cache,
            stats,
            shutdown,
            listen_addr,
            default_deadline,
        );
        let mut text = reply.to_string();
        text.push('\n');
        if writer.write_all(text.as_bytes()).is_err() {
            return;
        }
    }
}

fn bad(reason: impl Into<String>) -> ServeError {
    ServeError::BadRequest { reason: reason.into() }
}

#[allow(clippy::too_many_arguments)]
fn handle_line(
    line: &str,
    req_id: u64,
    queue: &AdmissionQueue,
    cache: &PlanCache,
    stats: &ServeStats,
    shutdown: &AtomicBool,
    listen_addr: Option<SocketAddr>,
    default_deadline: Duration,
) -> Json {
    let parsed = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return err_json(&bad(format!("invalid JSON: {e:#}"))),
    };
    let op = match parsed.get("op").and_then(|v| v.as_str()) {
        Ok(op) => op.to_string(),
        Err(_) => return err_json(&bad("missing \"op\"")),
    };
    match op.as_str() {
        "ping" => Json::obj(vec![("ok", Json::Bool(true))]),
        "stats" => stats_json(&stats.snapshot()),
        "shutdown" => {
            if !shutdown.swap(true, Ordering::SeqCst) {
                queue.close(stats);
                // Wake the blocked accept loop so it observes the flag.
                if let Some(addr) = listen_addr {
                    let _ = TcpStream::connect(addr);
                }
            }
            Json::obj(vec![("ok", Json::Bool(true))])
        }
        "model_info" => match model_info(&parsed, cache) {
            Ok(v) => v,
            Err(e) => err_json(&e),
        },
        "infer" => match infer(&parsed, req_id, queue, cache, stats, default_deadline) {
            Ok(v) => v,
            Err(e) => err_json(&e),
        },
        other => err_json(&bad(format!("unknown op {other:?}"))),
    }
}

fn model_info(req: &Json, cache: &PlanCache) -> Result<Json, ServeError> {
    let name = req
        .get("model")
        .and_then(|v| v.as_str())
        .map_err(|_| bad("model_info needs \"model\""))?;
    let hash = cache.resolve(name)?;
    let plan = cache.get(hash)?;
    let net = plan.net();
    let (lo, hi) = net.layers[0].in_quant.int_range();
    let (m, n, p) = net.grid_bits();
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("model", Json::str(name)),
        ("hash", Json::str(hash.to_string())),
        ("input_dim", Json::num(net.input_dim() as f64)),
        ("output_dim", Json::num(net.output_dim() as f64)),
        ("depth", Json::num(net.layers.len() as f64)),
        ("code_lo", Json::num(lo as f64)),
        ("code_hi", Json::num(hi as f64)),
        ("m_bits", Json::num(m as f64)),
        ("n_bits", Json::num(n as f64)),
        ("p_bits", Json::num(p as f64)),
    ]))
}

fn infer(
    req: &Json,
    req_id: u64,
    queue: &AdmissionQueue,
    cache: &PlanCache,
    stats: &ServeStats,
    default_deadline: Duration,
) -> Result<Json, ServeError> {
    let name = req
        .get("model")
        .and_then(|v| v.as_str())
        .map_err(|_| bad("infer needs \"model\""))?;
    let hash = cache.resolve(name)?;
    // Validate against the model's grid before admission: a malformed
    // request must never occupy queue capacity.
    let plan = cache.get(hash)?;
    let k = plan.net().input_dim();
    let (lo, hi) = plan.net().layers[0].in_quant.int_range();
    let rows_json = req
        .get("rows")
        .and_then(|v| v.as_arr())
        .map_err(|_| bad("infer needs \"rows\""))?;
    if rows_json.is_empty() {
        return Err(bad("empty rows"));
    }
    let mut flat: Vec<i64> = Vec::with_capacity(rows_json.len() * k);
    for (ri, row) in rows_json.iter().enumerate() {
        let row = row.as_arr().map_err(|_| bad(format!("row {ri} is not an array")))?;
        if row.len() != k {
            return Err(bad(format!("row {ri} has {} codes, model takes {k}", row.len())));
        }
        for (ci, v) in row.iter().enumerate() {
            let f = v.as_f64().map_err(|_| bad(format!("row {ri} code {ci} is not a number")))?;
            if !f.is_finite() || f != f.trunc() {
                return Err(bad(format!("row {ri} code {ci} is not an integer")));
            }
            let code = f as i64;
            if code < lo || code > hi {
                return Err(bad(format!(
                    "row {ri} code {ci} = {code} outside the model's input grid [{lo}, {hi}]"
                )));
            }
            flat.push(code);
        }
    }
    let budget = match req.opt("deadline_ms") {
        Some(v) => Duration::from_millis(v.as_u64().map_err(|_| bad("bad deadline_ms"))?),
        None => default_deadline,
    };
    let now = Instant::now();
    let (tx, rx) = mpsc::channel();
    let request = JobRequest {
        id: req_id,
        model_hash: hash,
        rows: IntMatrix::from_flat(rows_json.len(), k, flat),
        enqueued: now,
        deadline: now + budget,
        budget_ms: budget.as_millis() as u64,
        responder: tx,
    };
    queue.submit(request).map_err(|e| {
        if matches!(e, ServeError::Overloaded { .. }) {
            stats.shed_overloaded.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        e
    })?;
    stats.admitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    // Admitted: the worker (or the queue's shed/close paths) owns the reply.
    match rx.recv() {
        Ok(Ok(reply)) => {
            let out_dim = reply.outputs.cols();
            let rows: Vec<Json> = reply
                .outputs
                .data()
                .chunks(out_dim)
                .map(Json::from_f32s)
                .collect();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("outputs", Json::arr(rows)),
                ("overflow_events", Json::num(reply.overflow_events as f64)),
                ("batch_seq", Json::num(reply.batch_seq as f64)),
                ("batch_rows", Json::num(reply.batch_rows as f64)),
            ]))
        }
        Ok(Err(e)) => Err(e),
        // The responder was dropped without a reply: a worker died between
        // dequeue and respond in a way catch_unwind could not cover.
        Err(_) => Err(ServeError::WorkerPanicked { batch_seq: 0 }),
    }
}
