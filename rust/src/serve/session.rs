//! The server: TCP listener, connection sessions, worker pool and the
//! supervisor that keeps it alive through worker panics.
//!
//! Thread topology: one accept loop spawns a session thread per connection;
//! session threads decode + validate requests into pooled buffers and
//! submit them to the shared [`AdmissionQueue`]; `workers` batch-worker
//! threads drain it through [`run_worker`], encoding each reply into the
//! request's pooled buffer; one supervisor polls the workers and respawns
//! any that died by panic (a normal worker exit only happens when the
//! queue is closed). Every thread communicates through `Arc`s — there is
//! no global state, so in-process tests can run several servers at once.
//!
//! ## Wire protocols
//!
//! A connection picks its protocol with its first byte, once:
//!
//! * `b'A'` (the binary magic's first byte) — the length-prefixed binary
//!   frame protocol of [`super::wire`]: infer/ping/shutdown/drain/resume,
//!   i64 codes in, f32 outputs out, typed errors as status tags. This is
//!   the allocation-free hot path (`tests/serve_alloc.rs` pins it).
//! * anything else (JSON objects start with `{` or whitespace) —
//!   line-delimited JSON, one request per line, one response line each
//!   (keys sorted — [`crate::json`]). Ops:
//!
//! ```text
//! {"op":"ping"}
//! {"op":"model_info","model":"m"}
//! {"op":"infer","model":"m","rows":[[codes...],...],"deadline_ms":100}
//! {"op":"stats"}
//! {"op":"drain"}
//! {"op":"resume"}
//! {"op":"shutdown"}
//! ```
//!
//! JSON responses carry `"ok":true` plus op-specific fields, or
//! `"ok":false` with the stable [`ServeError::code`] under `"code"` and a
//! human message under `"error"`. Binary replies carry the same errors as
//! [`ServeError::tag`] status bytes with the `Display` text as payload —
//! one error surface, two encodings. Inference inputs are integer codes
//! on the model's layer-0 activation grid (see `model_info` for the grid
//! range); `deadline_ms` is the request's admission-to-execution budget
//! (binary: header field, 0 = server default). `stats`/`model_info` are
//! JSON-only ops — binary clients open a JSON connection for metadata and
//! keep the binary one for data.
//!
//! `drain` flips the admission queue into drain mode — new work is refused
//! with the typed `draining` code while queued and executing requests
//! complete normally — and `resume` flips it back; `ping` acks report the
//! drain flag and the in-flight gauge (both protocols), which is how a
//! router bleeds a replica to zero before restarting it. Connections are
//! also guarded by an optional per-connection idle timeout
//! (`--idle-timeout-ms`): a socket that produces no request bytes for that
//! long gets a typed `idle_timeout` close instead of pinning its session
//! thread forever (slow-loris defence).
//!
//! Both protocols share the serving core: the same pooled buffers, the
//! same admission queue, the same workers. A worker encodes the complete
//! wire reply (JSON line or binary frame, per the request's
//! [`WireFormat`]) into the request's pooled byte buffer; sessions only
//! move bytes between socket and buffer.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::admission::{
    AdmissionQueue, JobRequest, RejectedJob, ReplySlot, ServeStats, StatsSnapshot,
};
use super::batcher::{run_worker, BatchPolicy};
use super::cache::{ModelSource, PlanCache};
use super::error::ServeError;
use super::fault::FaultPlan;
use super::pool::{BufferPool, PooledBuf};
use super::wire::{self, WireFormat};
use crate::json::Json;

/// Server knobs. `Default` is a sane single-host profile.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (tests).
    pub addr: String,
    /// Batch-worker threads.
    pub workers: usize,
    /// Admission-queue capacity (requests, not rows).
    pub queue_capacity: usize,
    /// Maximum input rows per micro-batch.
    pub max_batch_rows: usize,
    /// How long a non-full batch waits for more same-model rows.
    pub batch_window_ms: u64,
    /// Deadline budget applied when a request names none.
    pub default_deadline_ms: u64,
    /// Idle buffers the request pool retains; 0 sizes it automatically
    /// (`queue_capacity + 2 * workers + 8` — a full queue plus every
    /// worker's in-flight batch plus sessions mid-decode).
    pub pool_retain: usize,
    /// Per-connection read/idle timeout in ms; a connection that sends no
    /// request bytes for this long is closed with a typed `idle_timeout`
    /// reply. 0 disables the timeout (the pre-router behaviour).
    pub idle_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            workers: 2,
            queue_capacity: 64,
            max_batch_rows: 64,
            batch_window_ms: 1,
            default_deadline_ms: 1000,
            pool_retain: 0,
            idle_timeout_ms: 0,
        }
    }
}

/// A running server. Dropping it does NOT stop it — call
/// [`Server::shutdown`] then [`Server::join`].
pub struct Server {
    addr: SocketAddr,
    queue: Arc<AdmissionQueue>,
    stats: Arc<ServeStats>,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    supervisor_handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind, load + validate every model, start workers and supervisor.
    /// Model validation failures abort startup with the typed load error —
    /// a server that cannot serve its models should not come up.
    pub fn start(
        cfg: &ServeConfig,
        models: &[(String, ModelSource)],
        fault: FaultPlan,
    ) -> anyhow::Result<Server> {
        anyhow::ensure!(!models.is_empty(), "a2q serve needs at least one --models entry");
        let cache = Arc::new(PlanCache::new(models.len().max(1), fault));
        for (name, source) in models {
            cache
                .insert_model(name, source.clone())
                .map_err(|e| anyhow::anyhow!("model {name:?}: {e}"))?;
        }
        let queue = Arc::new(AdmissionQueue::new(cfg.queue_capacity));
        let stats = Arc::new(ServeStats::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let retain = if cfg.pool_retain > 0 {
            cfg.pool_retain
        } else {
            cfg.queue_capacity + 2 * cfg.workers.max(1) + 8
        };
        let pool = Arc::new(BufferPool::new(retain));
        let policy = BatchPolicy {
            max_rows: cfg.max_batch_rows.max(1),
            window: Duration::from_millis(cfg.batch_window_ms),
        };

        let spawn_worker = {
            let queue = queue.clone();
            let cache = cache.clone();
            let stats = stats.clone();
            move || {
                let queue = queue.clone();
                let cache = cache.clone();
                let stats = stats.clone();
                std::thread::Builder::new()
                    .name("a2q-serve-worker".to_string())
                    .spawn(move || run_worker(queue, cache, stats, policy, fault))
                    .expect("spawn batch worker")
            }
        };
        let mut workers: Vec<JoinHandle<()>> = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            workers.push(spawn_worker());
        }

        // Supervisor: respawn panicked workers until shutdown, then reap.
        let supervisor_handle = {
            let stats = stats.clone();
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("a2q-serve-supervisor".to_string())
                .spawn(move || loop {
                    let mut i = 0;
                    while i < workers.len() {
                        if workers[i].is_finished() {
                            let dead = workers.swap_remove(i);
                            let panicked = dead.join().is_err();
                            if panicked && !shutdown.load(Ordering::SeqCst) {
                                stats.respawns.fetch_add(1, Ordering::Relaxed);
                                workers.push(spawn_worker());
                            }
                        } else {
                            i += 1;
                        }
                    }
                    if shutdown.load(Ordering::SeqCst) && workers.is_empty() {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                })
                .expect("spawn supervisor")
        };

        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let accept_handle = {
            let queue = queue.clone();
            let cache = cache.clone();
            let stats = stats.clone();
            let shutdown = shutdown.clone();
            let default_deadline = Duration::from_millis(cfg.default_deadline_ms.max(1));
            let idle_timeout_ms = cfg.idle_timeout_ms;
            std::thread::Builder::new()
                .name("a2q-serve-accept".to_string())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        let Ok(stream) = stream else { continue };
                        let queue = queue.clone();
                        let cache = cache.clone();
                        let stats = stats.clone();
                        let shutdown = shutdown.clone();
                        let pool = pool.clone();
                        let _ = std::thread::Builder::new()
                            .name("a2q-serve-conn".to_string())
                            .spawn(move || {
                                run_session(
                                    stream,
                                    &queue,
                                    &cache,
                                    &stats,
                                    &shutdown,
                                    default_deadline,
                                    idle_timeout_ms,
                                    fault,
                                    &pool,
                                )
                            });
                    }
                })
                .expect("spawn accept loop")
        };

        Ok(Server {
            addr,
            queue,
            stats,
            shutdown,
            accept_handle: Some(accept_handle),
            supervisor_handle: Some(supervisor_handle),
        })
    }

    /// The actually bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Begin draining: reject new work typed, wake the accept loop, let
    /// workers run out.
    pub fn shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        self.queue.close(&self.stats);
        // Wake the accept loop so it observes the flag and exits.
        let _ = TcpStream::connect(self.addr);
    }

    /// Wait for the accept loop and worker pool to finish. Call after
    /// [`Server::shutdown`]; joining a live server blocks forever.
    pub fn join(mut self) {
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.supervisor_handle.take() {
            let _ = h.join();
        }
    }
}

fn err_json(e: &ServeError) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::str(e.code())),
        ("error", Json::str(e.to_string())),
    ])
}

fn stats_json(s: &StatsSnapshot, draining: bool) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("admitted", Json::num(s.admitted as f64)),
        ("completed", Json::num(s.completed as f64)),
        ("shed_overloaded", Json::num(s.shed_overloaded as f64)),
        ("shed_deadline", Json::num(s.shed_deadline as f64)),
        ("shed_draining", Json::num(s.shed_draining as f64)),
        ("worker_panics", Json::num(s.worker_panics as f64)),
        ("respawns", Json::num(s.respawns as f64)),
        ("batches", Json::num(s.batches as f64)),
        ("batched_rows", Json::num(s.batched_rows as f64)),
        ("in_flight", Json::num(s.in_flight as f64)),
        ("draining", Json::Bool(draining)),
    ])
}

/// The `ping`/`drain`/`resume` ack: liveness plus drain progress, the two
/// facts a router's health probe needs from one round trip.
fn drain_state_json(queue: &AdmissionQueue, stats: &ServeStats) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("draining", Json::Bool(queue.draining())),
        ("in_flight", Json::num(stats.in_flight.load(Ordering::Relaxed) as f64)),
    ])
}

fn bad(reason: impl Into<String>) -> ServeError {
    ServeError::BadRequest { reason: reason.into() }
}

/// Flip the shutdown flag once: close the queue and poke the accept loop.
fn trigger_shutdown(
    queue: &AdmissionQueue,
    stats: &ServeStats,
    shutdown: &AtomicBool,
    listen_addr: Option<SocketAddr>,
) {
    if !shutdown.swap(true, Ordering::SeqCst) {
        queue.close(stats);
        // Wake the blocked accept loop so it observes the flag.
        if let Some(addr) = listen_addr {
            let _ = TcpStream::connect(addr);
        }
    }
}

/// One connection: peek the first byte to pick the protocol, then hand the
/// stream to that protocol's session loop.
#[allow(clippy::too_many_arguments)]
fn run_session(
    stream: TcpStream,
    queue: &AdmissionQueue,
    cache: &PlanCache,
    stats: &ServeStats,
    shutdown: &AtomicBool,
    default_deadline: Duration,
    idle_timeout_ms: u64,
    fault: FaultPlan,
    pool: &Arc<BufferPool>,
) {
    // Slow-loris defence: a connection that stops producing request bytes
    // gets a typed close instead of pinning this thread forever. The
    // timeout surfaces as a WouldBlock/TimedOut read error, which the
    // session loops translate into a typed `idle_timeout` reply.
    if idle_timeout_ms > 0
        && stream.set_read_timeout(Some(Duration::from_millis(idle_timeout_ms))).is_err()
    {
        return;
    }
    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    // The accepted socket's local address IS the listening address: the
    // shutdown op uses it to wake the blocked accept loop.
    let listen_addr = stream.local_addr().ok();
    let mut reader = BufReader::new(stream);
    let first = match reader.fill_buf() {
        Ok([]) => return, // EOF before any request
        Ok(b) => b[0],
        Err(e) => {
            if is_timeout(&e) {
                let mut wbuf = Vec::new();
                let idle = ServeError::IdleTimeout { idle_ms: idle_timeout_ms };
                wire::encode_binary_err(&mut wbuf, 0, &idle);
                let mut w = writer;
                let _ = w.write_all(&wbuf);
            }
            return;
        }
    };
    if first == wire::MAGIC_BYTE0 {
        run_binary_session(
            reader,
            writer,
            queue,
            cache,
            stats,
            shutdown,
            listen_addr,
            default_deadline,
            idle_timeout_ms,
            fault,
            pool,
        );
    } else {
        run_json_session(
            reader,
            writer,
            queue,
            cache,
            stats,
            shutdown,
            listen_addr,
            default_deadline,
            idle_timeout_ms,
            fault,
            pool,
        );
    }
}

/// Whether a read error is the idle-timeout firing (`set_read_timeout`
/// surfaces as WouldBlock on unix, TimedOut on windows).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Reply writer shared by both session loops: counts reply frames so the
/// `conn_drop:N` fault can cut the connection after writing only half of
/// the Nth reply — the deterministic "replica died mid-reply" a router's
/// retry classification must handle. `Err(())` means "close the session".
struct ReplyWriter<W: Write> {
    w: W,
    frames: u64,
    drop_at: Option<u64>,
}

impl<W: Write> ReplyWriter<W> {
    fn new(w: W, fault: &FaultPlan) -> ReplyWriter<W> {
        ReplyWriter { w, frames: 0, drop_at: fault.conn_drop }
    }

    fn write_frame(&mut self, bytes: &[u8]) -> Result<(), ()> {
        self.frames += 1;
        if self.drop_at == Some(self.frames) {
            let _ = self.w.write_all(&bytes[..bytes.len() / 2]);
            let _ = self.w.flush();
            return Err(()); // torn reply: the session closes the socket
        }
        self.w.write_all(bytes).map_err(|_| ())
    }
}

/// What one JSON request produced: either a small control-plane reply
/// (rendered into the connection's reusable write buffer) or an infer
/// reply the worker already encoded into a pooled buffer.
enum LineReply {
    Inline(Json),
    Encoded(PooledBuf),
}

/// The line-JSON session loop. Per-connection reusable state: the read
/// line, the write buffer, and one [`ReplySlot`] re-armed per request.
#[allow(clippy::too_many_arguments)]
fn run_json_session(
    mut reader: BufReader<TcpStream>,
    writer: TcpStream,
    queue: &AdmissionQueue,
    cache: &PlanCache,
    stats: &ServeStats,
    shutdown: &AtomicBool,
    listen_addr: Option<SocketAddr>,
    default_deadline: Duration,
    idle_timeout_ms: u64,
    fault: FaultPlan,
    pool: &Arc<BufferPool>,
) {
    let slot = ReplySlot::new();
    let mut writer = ReplyWriter::new(writer, &fault);
    let mut line = String::new();
    let mut wbuf = String::new();
    let mut next_id = 0u64;
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return,
            Err(e) => {
                if is_timeout(&e) {
                    // Typed close: the client learns why before the socket
                    // goes away (a partially-read line is discarded — the
                    // connection is closing either way).
                    wbuf.clear();
                    let idle = ServeError::IdleTimeout { idle_ms: idle_timeout_ms };
                    err_json(&idle).write_into(&mut wbuf);
                    wbuf.push('\n');
                    let _ = writer.write_frame(wbuf.as_bytes());
                }
                return;
            }
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            continue;
        }
        next_id += 1;
        let reply = handle_line(
            &line,
            next_id,
            queue,
            cache,
            stats,
            shutdown,
            listen_addr,
            default_deadline,
            fault,
            pool,
            &slot,
        );
        match reply {
            LineReply::Encoded(buf) => {
                // The worker wrote the full reply line (newline included).
                if writer.write_frame(buf.reply()).is_err() {
                    return;
                }
                // buf drops here -> storage returns to the pool
            }
            LineReply::Inline(json) => {
                wbuf.clear();
                json.write_into(&mut wbuf);
                wbuf.push('\n');
                if writer.write_frame(wbuf.as_bytes()).is_err() {
                    return;
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_line(
    line: &str,
    req_id: u64,
    queue: &AdmissionQueue,
    cache: &PlanCache,
    stats: &ServeStats,
    shutdown: &AtomicBool,
    listen_addr: Option<SocketAddr>,
    default_deadline: Duration,
    fault: FaultPlan,
    pool: &Arc<BufferPool>,
    slot: &Arc<ReplySlot>,
) -> LineReply {
    let parsed = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return LineReply::Inline(err_json(&bad(format!("invalid JSON: {e:#}")))),
    };
    let op = match parsed.get("op").and_then(|v| v.as_str()) {
        Ok(op) => op.to_string(),
        Err(_) => return LineReply::Inline(err_json(&bad("missing \"op\""))),
    };
    LineReply::Inline(match op.as_str() {
        "ping" => {
            if let Some(stall) = fault.ping_stall_ms {
                std::thread::sleep(Duration::from_millis(stall));
            }
            drain_state_json(queue, stats)
        }
        "stats" => stats_json(&stats.snapshot(), queue.draining()),
        "drain" => {
            queue.set_draining(true);
            drain_state_json(queue, stats)
        }
        "resume" => {
            queue.set_draining(false);
            drain_state_json(queue, stats)
        }
        "shutdown" => {
            trigger_shutdown(queue, stats, shutdown, listen_addr);
            Json::obj(vec![("ok", Json::Bool(true))])
        }
        "model_info" => match model_info(&parsed, cache) {
            Ok(v) => v,
            Err(e) => err_json(&e),
        },
        "infer" => {
            return match infer_json(&parsed, req_id, queue, cache, stats, default_deadline, pool, slot)
            {
                Ok(buf) => LineReply::Encoded(buf),
                Err(e) => LineReply::Inline(err_json(&e)),
            };
        }
        other => err_json(&bad(format!("unknown op {other:?}"))),
    })
}

fn model_info(req: &Json, cache: &PlanCache) -> Result<Json, ServeError> {
    let name = req
        .get("model")
        .and_then(|v| v.as_str())
        .map_err(|_| bad("model_info needs \"model\""))?;
    let hash = cache.resolve(name)?;
    let plan = cache.get(hash)?;
    let net = plan.net();
    let (lo, hi) = net.layers[0].in_quant.int_range();
    let (m, n, p) = net.grid_bits();
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("model", Json::str(name)),
        ("hash", Json::str(hash.to_string())),
        ("input_dim", Json::num(net.input_dim() as f64)),
        ("output_dim", Json::num(net.output_dim() as f64)),
        ("depth", Json::num(net.layers.len() as f64)),
        ("code_lo", Json::num(lo as f64)),
        ("code_hi", Json::num(hi as f64)),
        ("m_bits", Json::num(m as f64)),
        ("n_bits", Json::num(n as f64)),
        ("p_bits", Json::num(p as f64)),
    ]))
}

/// Submit an admissible request and wait for its outcome; shared tail of
/// both protocols' infer paths. On success the returned buffer holds the
/// complete encoded reply.
fn submit_and_wait(
    request: JobRequest,
    queue: &AdmissionQueue,
    stats: &ServeStats,
    slot: &Arc<ReplySlot>,
) -> Result<PooledBuf, ServeError> {
    if let Err(RejectedJob { request, error }) = queue.submit(request) {
        match error {
            ServeError::Overloaded { .. } => {
                stats.shed_overloaded.fetch_add(1, Ordering::Relaxed);
            }
            ServeError::Draining => {
                stats.shed_draining.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
        // Disarm the reply sender (the refusal is reported right here) and
        // let the pooled buffer return to the pool.
        request.cancel();
        return Err(error);
    }
    stats.admitted.fetch_add(1, Ordering::Relaxed);
    // In-flight covers admitted-to-delivered (queued or executing): the
    // gauge a drain bleeds to zero before its replica restarts.
    stats.in_flight.fetch_add(1, Ordering::Relaxed);
    // Admitted: the worker (or the queue's shed/close paths, or the
    // sender's fail-closed drop) owns the reply.
    let outcome = slot.recv();
    stats.in_flight.fetch_sub(1, Ordering::Relaxed);
    match outcome {
        Ok(reply) => Ok(reply.into_buf()),
        Err(e) => Err(e),
    }
}

#[allow(clippy::too_many_arguments)]
fn infer_json(
    req: &Json,
    req_id: u64,
    queue: &AdmissionQueue,
    cache: &PlanCache,
    stats: &ServeStats,
    default_deadline: Duration,
    pool: &Arc<BufferPool>,
    slot: &Arc<ReplySlot>,
) -> Result<PooledBuf, ServeError> {
    let name = req
        .get("model")
        .and_then(|v| v.as_str())
        .map_err(|_| bad("infer needs \"model\""))?;
    let hash = cache.resolve(name)?;
    // Validate against the model's grid before admission: a malformed
    // request must never occupy queue capacity.
    let plan = cache.get(hash)?;
    let k = plan.net().input_dim();
    let (lo, hi) = plan.net().layers[0].in_quant.int_range();
    let rows_json = req
        .get("rows")
        .and_then(|v| v.as_arr())
        .map_err(|_| bad("infer needs \"rows\""))?;
    if rows_json.is_empty() {
        return Err(bad("empty rows"));
    }
    // Decode straight into a pooled buffer (an early validation return
    // drops it back to the pool).
    let mut buf = pool.acquire();
    buf.input_mut().reset(rows_json.len(), k);
    let codes = buf.input_mut().data_mut();
    for (ri, row) in rows_json.iter().enumerate() {
        let row = row.as_arr().map_err(|_| bad(format!("row {ri} is not an array")))?;
        if row.len() != k {
            return Err(bad(format!("row {ri} has {} codes, model takes {k}", row.len())));
        }
        for (ci, v) in row.iter().enumerate() {
            let f = v.as_f64().map_err(|_| bad(format!("row {ri} code {ci} is not a number")))?;
            if !f.is_finite() || f != f.trunc() {
                return Err(bad(format!("row {ri} code {ci} is not an integer")));
            }
            let code = f as i64;
            if code < lo || code > hi {
                return Err(bad(format!(
                    "row {ri} code {ci} = {code} outside the model's input grid [{lo}, {hi}]"
                )));
            }
            codes[ri * k + ci] = code;
        }
    }
    let budget = match req.opt("deadline_ms") {
        Some(v) => Duration::from_millis(v.as_u64().map_err(|_| bad("bad deadline_ms"))?),
        None => default_deadline,
    };
    let request = JobRequest::new(req_id, hash, WireFormat::Json, buf, budget, slot.sender());
    submit_and_wait(request, queue, stats, slot)
}

/// What one binary infer produced (or why it didn't).
enum BinOutcome {
    /// Success: the pooled buffer holds the encoded reply frame.
    Reply(PooledBuf),
    /// Typed refusal; the frame's payload was fully consumed, so the
    /// connection keeps its framing.
    Refused(ServeError),
    /// Transport died mid-frame; close the connection.
    Hangup,
}

/// The binary-frame session loop. Public so the allocation-counting
/// harness (`tests/serve_alloc.rs`) can drive it over in-memory transport;
/// the server itself passes the accepted socket pair.
///
/// Per-request steady state reads the frame header into a stack array,
/// streams codes into a pooled `IntMatrix` through a stack chunk, and
/// writes back the worker-encoded reply bytes — no heap allocation once
/// the pool and scratch are warm.
#[allow(clippy::too_many_arguments)]
pub fn run_binary_session<R: Read, W: Write>(
    mut reader: R,
    writer: W,
    queue: &AdmissionQueue,
    cache: &PlanCache,
    stats: &ServeStats,
    shutdown: &AtomicBool,
    listen_addr: Option<SocketAddr>,
    default_deadline: Duration,
    idle_timeout_ms: u64,
    fault: FaultPlan,
    pool: &Arc<BufferPool>,
) {
    let slot = ReplySlot::new();
    let mut writer = ReplyWriter::new(writer, &fault);
    let mut wbuf: Vec<u8> = Vec::with_capacity(256);
    let mut hdr = [0u8; wire::REQ_HEADER_LEN];
    let mut next_id = 0u64;
    loop {
        let mut prefix = [0u8; wire::PREFIX_LEN];
        if let Err(e) = reader.read_exact(&mut prefix) {
            // Clean EOF between frames, transport death — or the idle
            // timeout, which gets a typed close so the peer learns why.
            if is_timeout(&e) {
                let idle = ServeError::IdleTimeout { idle_ms: idle_timeout_ms };
                wire::encode_binary_err(&mut wbuf, 0, &idle);
                let _ = writer.write_frame(&wbuf);
            }
            return;
        }
        let magic = u32::from_le_bytes([prefix[0], prefix[1], prefix[2], prefix[3]]);
        if let Err(e) = wire::check_magic(magic) {
            // Framing cannot be trusted: reply typed and close.
            wire::encode_binary_err(&mut wbuf, 0, &e);
            let _ = writer.write_frame(&wbuf);
            return;
        }
        let len = u32::from_le_bytes([prefix[4], prefix[5], prefix[6], prefix[7]]) as usize;
        if !(wire::REQ_HEADER_LEN..=wire::MAX_FRAME).contains(&len) {
            wire::encode_binary_err(&mut wbuf, 0, &bad(format!("bad frame length {len}")));
            let _ = writer.write_frame(&wbuf);
            return;
        }
        if reader.read_exact(&mut hdr).is_err() {
            return;
        }
        let payload_len = len - wire::REQ_HEADER_LEN;
        let h = match wire::parse_request_header(&hdr) {
            Ok(h) => h,
            Err(e) => {
                // Unsupported wire version: same framing-loss rule.
                wire::encode_binary_err(&mut wbuf, 0, &e);
                let _ = writer.write_frame(&wbuf);
                return;
            }
        };
        next_id += 1;
        match h.op {
            wire::OP_PING => {
                if wire::drain_payload(&mut reader, payload_len).is_err() {
                    return;
                }
                if let Some(stall) = fault.ping_stall_ms {
                    std::thread::sleep(Duration::from_millis(stall));
                }
                let in_flight = stats.in_flight.load(Ordering::Relaxed);
                wire::encode_pong(&mut wbuf, queue.draining(), in_flight);
                if writer.write_frame(&wbuf).is_err() {
                    return;
                }
            }
            wire::OP_DRAIN | wire::OP_RESUME => {
                if wire::drain_payload(&mut reader, payload_len).is_err() {
                    return;
                }
                queue.set_draining(h.op == wire::OP_DRAIN);
                wire::encode_ok_empty(&mut wbuf, h.op);
                if writer.write_frame(&wbuf).is_err() {
                    return;
                }
            }
            wire::OP_SHUTDOWN => {
                if wire::drain_payload(&mut reader, payload_len).is_err() {
                    return;
                }
                trigger_shutdown(queue, stats, shutdown, listen_addr);
                wire::encode_ok_empty(&mut wbuf, wire::OP_SHUTDOWN);
                if writer.write_frame(&wbuf).is_err() {
                    return;
                }
            }
            wire::OP_INFER => {
                let outcome = infer_binary(
                    &h,
                    payload_len,
                    &mut reader,
                    next_id,
                    queue,
                    cache,
                    stats,
                    default_deadline,
                    pool,
                    &slot,
                );
                match outcome {
                    BinOutcome::Reply(buf) => {
                        if writer.write_frame(buf.reply()).is_err() {
                            return;
                        }
                        // buf drops here -> storage returns to the pool
                    }
                    BinOutcome::Refused(e) => {
                        wire::encode_binary_err(&mut wbuf, wire::OP_INFER, &e);
                        if writer.write_frame(&wbuf).is_err() {
                            return;
                        }
                    }
                    BinOutcome::Hangup => return,
                }
            }
            other => {
                if wire::drain_payload(&mut reader, payload_len).is_err() {
                    return;
                }
                wire::encode_binary_err(&mut wbuf, other, &bad(format!("unknown op {other}")));
                if writer.write_frame(&wbuf).is_err() {
                    return;
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn infer_binary<R: Read>(
    h: &wire::RequestHeader,
    payload_len: usize,
    reader: &mut R,
    req_id: u64,
    queue: &AdmissionQueue,
    cache: &PlanCache,
    stats: &ServeStats,
    default_deadline: Duration,
    pool: &Arc<BufferPool>,
    slot: &Arc<ReplySlot>,
) -> BinOutcome {
    // Frame-consistency first: the payload length is what we must consume
    // to keep framing, so it has to agree with the stated shape.
    let rows = h.rows as usize;
    let cols = h.cols as usize;
    let refuse = |reader: &mut R, e: ServeError| -> BinOutcome {
        if wire::drain_payload(reader, payload_len).is_err() {
            return BinOutcome::Hangup;
        }
        BinOutcome::Refused(e)
    };
    if (rows as u64) * (cols as u64) * 8 != payload_len as u64 {
        return refuse(
            reader,
            bad(format!("payload {payload_len} bytes does not match {rows}x{cols} i64 codes")),
        );
    }
    if rows == 0 {
        return refuse(reader, bad("empty rows"));
    }
    // Validate against the model's grid before admission: a malformed
    // request must never occupy queue capacity.
    let plan = match cache.get(h.model_hash) {
        Ok(plan) => plan,
        Err(e) => return refuse(reader, e),
    };
    let k = plan.net().input_dim();
    if cols != k {
        return refuse(reader, bad(format!("request is {cols} codes wide, model takes {k}")));
    }
    let (lo, hi) = plan.net().layers[0].in_quant.int_range();
    let mut buf = pool.acquire();
    buf.input_mut().reset(rows, cols);
    // read_codes always consumes the whole payload, so a validation
    // failure here still leaves the connection framed.
    match wire::read_codes(reader, rows, cols, lo, hi, buf.input_mut().data_mut()) {
        Err(_) => BinOutcome::Hangup,
        Ok(Err(e)) => BinOutcome::Refused(e),
        Ok(Ok(())) => {
            let budget = if h.deadline_ms == 0 {
                default_deadline
            } else {
                Duration::from_millis(h.deadline_ms)
            };
            let request =
                JobRequest::new(req_id, h.model_hash, WireFormat::Binary, buf, budget, slot.sender());
            match submit_and_wait(request, queue, stats, slot) {
                Ok(reply) => BinOutcome::Reply(reply),
                Err(e) => BinOutcome::Refused(e),
            }
        }
    }
}
