//! Fixed-capacity concurrent plan cache: model hash → ready-to-execute
//! [`SharedNetworkPlan`].
//!
//! The server loads each registered model once, validates it at the trust
//! boundary (the typed [`crate::model::netfile`] / `QNetwork` paths — a
//! malformed export is a [`ServeError::LoadFailed`], never a panic), builds
//! a [`SharedNetworkPlan`] and keeps up to `capacity` plans resident in LRU
//! order. Plans are `Arc`-shared: a worker executing an evicted model's
//! plan keeps it alive; the cache only bounds *resident* plans. Evicted
//! models reload transparently from their recorded [`ModelSource`] on next
//! use, so eviction is a latency event, not a correctness event.
//!
//! Keys are [`fnv1a64`] hashes of the model's identity — the synth spec
//! string or the model file's bytes — so the wire protocol can address
//! models by stable hash as well as by registered name. The binary wire
//! format leans on this: its request header carries the hash directly
//! (`model_hash`), so a binary client resolves a name once via the JSON
//! `model_info` op and then addresses the model hash-only on the data
//! plane — no string lookup on the hot path.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use super::error::ServeError;
use super::fault::FaultPlan;
use crate::accsim::{AccMode, SharedNetworkPlan};
use crate::model::{fnv1a64, load_network, parse_synth_spec, QNetwork};
use crate::rng::Rng;
use crate::tensor::Tensor;

/// Where a model's network comes from when (re)loading.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelSource {
    /// Synthesized from a `name:W0xW1x..:mMnNpP` spec (deterministic seed
    /// derived from the spec hash; calibrated over a deterministic sample).
    Synth(String),
    /// Loaded from a JSON model file written by [`crate::model::save_network`].
    File(PathBuf),
}

/// Rows used for the deterministic calibration sample of synth models.
const CALIBRATION_ROWS: usize = 64;

fn load_source(name: &str, source: &ModelSource) -> Result<QNetwork, ServeError> {
    let fail = |e: anyhow::Error| ServeError::LoadFailed {
        model: name.to_string(),
        reason: format!("{e:#}"),
    };
    match source {
        ModelSource::Synth(spec) => {
            let (_, net_spec) = parse_synth_spec(spec).map_err(fail)?;
            let seed = fnv1a64(spec.as_bytes());
            let mut net = QNetwork::synthesize(&net_spec, seed).map_err(fail)?;
            // Deterministic calibration sample: same spec -> same scales,
            // so a reload after eviction yields a bit-identical network.
            let mut rng = Rng::new(seed ^ 0x9e37_79b9_7f4a_7c15);
            let k = net.input_dim();
            let data: Vec<f32> = (0..CALIBRATION_ROWS * k)
                .map(|_| (rng.uniform() * 2.0 - 1.0) as f32)
                .collect();
            net.calibrate(&Tensor::new(vec![CALIBRATION_ROWS, k], data));
            Ok(net)
        }
        ModelSource::File(path) => load_network(path).map_err(fail),
    }
}

struct CacheState {
    /// Resident plans, most recently used first.
    resident: Vec<(u64, Arc<SharedNetworkPlan>)>,
    /// Registered name → hash (the wire protocol's model addressing).
    aliases: HashMap<String, u64>,
    /// Hash → how to (re)load; kept for every registered model forever.
    sources: HashMap<u64, (String, ModelSource)>,
}

/// The concurrent LRU plan cache.
pub struct PlanCache {
    inner: Mutex<CacheState>,
    capacity: usize,
    fault: FaultPlan,
}

impl PlanCache {
    pub fn new(capacity: usize, fault: FaultPlan) -> PlanCache {
        PlanCache {
            inner: Mutex::new(CacheState {
                resident: Vec::new(),
                aliases: HashMap::new(),
                sources: HashMap::new(),
            }),
            capacity: capacity.max(1),
            fault,
        }
    }

    /// Register a model and eagerly load + validate it (a server should
    /// fail at startup, not on first request, for a bad model). Returns the
    /// model's cache key.
    pub fn insert_model(&self, name: &str, source: ModelSource) -> Result<u64, ServeError> {
        let hash = match &source {
            ModelSource::Synth(spec) => fnv1a64(spec.as_bytes()),
            ModelSource::File(path) => {
                let bytes = std::fs::read(path).map_err(|e| ServeError::LoadFailed {
                    model: name.to_string(),
                    reason: format!("reading {}: {e}", path.display()),
                })?;
                fnv1a64(&bytes)
            }
        };
        {
            let mut st = self.inner.lock().unwrap();
            st.aliases.insert(name.to_string(), hash);
            st.sources.insert(hash, (name.to_string(), source));
        }
        if self.fault.cache_load {
            // Injected load failures must surface per-request as typed
            // errors, not abort server startup — skip the eager load.
            return Ok(hash);
        }
        self.get(hash)?;
        Ok(hash)
    }

    /// Resolve a wire-protocol model reference — a registered name or a
    /// decimal hash — to a cache key.
    pub fn resolve(&self, model: &str) -> Result<u64, ServeError> {
        let st = self.inner.lock().unwrap();
        if let Some(hash) = st.aliases.get(model) {
            return Ok(*hash);
        }
        if let Ok(hash) = model.parse::<u64>() {
            if st.sources.contains_key(&hash) {
                return Ok(hash);
            }
        }
        Err(ServeError::UnknownModel { name: model.to_string() })
    }

    /// Registered model names with their hashes, for the `model_info` op.
    pub fn registered(&self) -> Vec<(String, u64)> {
        let st = self.inner.lock().unwrap();
        let mut v: Vec<(String, u64)> = st.aliases.iter().map(|(n, h)| (n.clone(), *h)).collect();
        v.sort();
        v
    }

    /// Fetch the plan for a cache key, reloading from source after an
    /// eviction. Loading happens *outside* the cache lock so a slow reload
    /// never stalls cache hits for other models (two racing loaders of the
    /// same evicted model both succeed; the second insert wins, both Arcs
    /// are bit-identical by deterministic loading).
    pub fn get(&self, hash: u64) -> Result<Arc<SharedNetworkPlan>, ServeError> {
        let (name, source) = {
            let mut st = self.inner.lock().unwrap();
            if let Some(pos) = st.resident.iter().position(|(h, _)| *h == hash) {
                let entry = st.resident.remove(pos);
                let plan = entry.1.clone();
                st.resident.insert(0, entry);
                return Ok(plan);
            }
            match st.sources.get(&hash) {
                Some((name, source)) => (name.clone(), source.clone()),
                None => {
                    return Err(ServeError::UnknownModel { name: format!("#{hash:016x}") })
                }
            }
        };
        if self.fault.cache_load {
            return Err(ServeError::LoadFailed {
                model: name,
                reason: "injected fault: cache_load".to_string(),
            });
        }
        let net = load_source(&name, &source)?;
        let p_bits = net.grid_bits().2;
        let plan = Arc::new(SharedNetworkPlan::new(Arc::new(net), &[AccMode::Wrap { p_bits }]));
        let mut st = self.inner.lock().unwrap();
        st.resident.retain(|(h, _)| *h != hash);
        st.resident.insert(0, (hash, plan.clone()));
        while st.resident.len() > self.capacity {
            st.resident.pop();
        }
        Ok(plan)
    }

    /// Number of plans currently resident.
    pub fn resident_len(&self) -> usize {
        self.inner.lock().unwrap().resident.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, widths: &str) -> String {
        format!("{name}:{widths}:m4n4p16")
    }

    #[test]
    fn synth_models_load_resolve_and_survive_eviction_bit_identically() {
        let cache = PlanCache::new(1, FaultPlan::none());
        let h_a = cache.insert_model("a", ModelSource::Synth(spec("a", "8x6x3"))).unwrap();
        let plan_a = cache.get(h_a).unwrap();
        let h_b = cache.insert_model("b", ModelSource::Synth(spec("b", "5x4"))).unwrap();
        assert_ne!(h_a, h_b);
        assert_eq!(cache.resident_len(), 1, "capacity 1 evicts the older plan");
        assert_eq!(cache.resolve("a").unwrap(), h_a);
        assert_eq!(cache.resolve(&h_b.to_string()).unwrap(), h_b);
        assert_eq!(
            cache.resolve("nope").unwrap_err(),
            ServeError::UnknownModel { name: "nope".to_string() }
        );
        // Reload after eviction is deterministic: same outputs as the plan
        // loaded before eviction.
        let reloaded = cache.get(h_a).unwrap();
        let x = crate::accsim::IntMatrix::from_flat(2, 8, (0..16).map(|v| v % 5).collect());
        let before = plan_a.execute(&x);
        let after = reloaded.execute(&x);
        assert_eq!(before[0].out.data(), after[0].out.data());
        assert_eq!(before[0].layer_stats, after[0].layer_stats);
    }

    #[test]
    fn cache_load_fault_is_a_typed_error_not_a_panic() {
        let cache = PlanCache::new(2, FaultPlan::from_spec(Some("cache_load")));
        // Registration succeeds (the fault must not abort startup)...
        let hash = cache.insert_model("a", ModelSource::Synth(spec("a", "6x3"))).unwrap();
        // ...but every load attempt fails typed.
        let err = cache.get(hash).unwrap_err();
        match &err {
            ServeError::LoadFailed { model, reason } => {
                assert_eq!(model, "a");
                assert!(reason.contains("injected fault"), "{reason}");
            }
            other => panic!("expected LoadFailed, got {other:?}"),
        }
        assert_eq!(err.code(), "load_failed");
    }

    #[test]
    fn bad_sources_surface_descriptive_load_errors() {
        let cache = PlanCache::new(2, FaultPlan::none());
        let err = cache
            .insert_model("bad", ModelSource::Synth("bad:8x4:m99n4p16".to_string()))
            .unwrap_err();
        assert_eq!(err.code(), "load_failed");
        assert!(err.to_string().contains("bad"), "{err}");
        let err = cache
            .insert_model("ghost", ModelSource::File(PathBuf::from("/nonexistent/x.json")))
            .unwrap_err();
        assert_eq!(err.code(), "load_failed");
    }
}
