//! Typed serving errors: every way `a2q serve` refuses work, as data.
//!
//! The admission-control contract is that overload and faults degrade
//! *latency and availability of individual requests* — never correctness
//! and never the process. That requires every rejection to be a value that
//! travels back to exactly one client: a full queue, a blown deadline, a
//! poisoned batch, a model that failed validation. The [`ServeError::code`]
//! strings are the stable wire protocol (`loadgen` and CI match on them),
//! so renaming one is a protocol break, not a refactor.

/// A request-scoped serving failure. `Clone` so one batch-level failure can
/// fan out to every request in the batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The bounded admission queue is full: shed at the door.
    Overloaded { queued: usize, capacity: usize },
    /// The request's deadline budget expired while it sat in the queue.
    DeadlineExceeded { waited_ms: u64, budget_ms: u64 },
    /// The worker executing this request's micro-batch panicked; the batch
    /// was rejected and the worker respawned.
    WorkerPanicked { batch_seq: u64 },
    /// No model by this name (or hash) is registered.
    UnknownModel { name: String },
    /// The request itself is malformed (bad JSON, wrong input width, ...).
    BadRequest { reason: String },
    /// Loading (or reloading after eviction) the model failed validation.
    LoadFailed { model: String, reason: String },
    /// The server is shutting down: no new work admitted, queue rejected.
    ShuttingDown,
    /// The server is draining: no new work admitted, but in-flight work
    /// completes. A router treats this as safe-to-retry on another replica.
    Draining,
    /// The router has no healthy replica to forward to.
    NoBackend { replicas: usize },
    /// The connection sat idle past the per-connection read timeout and was
    /// closed by the server (slow-loris defence).
    IdleTimeout { idle_ms: u64 },
}

impl ServeError {
    /// Stable wire code, the string clients switch on.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::DeadlineExceeded { .. } => "deadline_exceeded",
            ServeError::WorkerPanicked { .. } => "worker_panicked",
            ServeError::UnknownModel { .. } => "unknown_model",
            ServeError::BadRequest { .. } => "bad_request",
            ServeError::LoadFailed { .. } => "load_failed",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::Draining => "draining",
            ServeError::NoBackend { .. } => "no_backend",
            ServeError::IdleTimeout { .. } => "idle_timeout",
        }
    }

    /// Stable numeric tag of the binary wire protocol's reply `status`
    /// byte (0 means success, so tags start at 1). As frozen as
    /// [`ServeError::code`]: renumbering one is a protocol break.
    pub fn tag(&self) -> u8 {
        match self {
            ServeError::Overloaded { .. } => 1,
            ServeError::DeadlineExceeded { .. } => 2,
            ServeError::WorkerPanicked { .. } => 3,
            ServeError::UnknownModel { .. } => 4,
            ServeError::BadRequest { .. } => 5,
            ServeError::LoadFailed { .. } => 6,
            ServeError::ShuttingDown => 7,
            ServeError::Draining => 8,
            ServeError::NoBackend { .. } => 9,
            ServeError::IdleTimeout { .. } => 10,
        }
    }

    /// The [`ServeError::code`] string a binary reply's `status` tag maps
    /// to (`None` for 0/unknown): how binary clients — `a2q loadgen
    /// --wire binary` — classify rejections identically to JSON clients.
    pub fn code_for_tag(tag: u8) -> Option<&'static str> {
        Some(match tag {
            1 => "overloaded",
            2 => "deadline_exceeded",
            3 => "worker_panicked",
            4 => "unknown_model",
            5 => "bad_request",
            6 => "load_failed",
            7 => "shutting_down",
            8 => "draining",
            9 => "no_backend",
            10 => "idle_timeout",
            _ => return None,
        })
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { queued, capacity } => {
                write!(f, "admission queue full ({queued}/{capacity} requests)")
            }
            ServeError::DeadlineExceeded { waited_ms, budget_ms } => {
                write!(f, "deadline exceeded after {waited_ms}ms of a {budget_ms}ms budget")
            }
            ServeError::WorkerPanicked { batch_seq } => {
                write!(f, "batch worker panicked executing micro-batch {batch_seq}")
            }
            ServeError::UnknownModel { name } => write!(f, "unknown model {name:?}"),
            ServeError::BadRequest { reason } => write!(f, "bad request: {reason}"),
            ServeError::LoadFailed { model, reason } => {
                write!(f, "loading model {model:?} failed: {reason}")
            }
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::Draining => write!(f, "server is draining: no new work admitted"),
            ServeError::NoBackend { replicas } => {
                write!(f, "no healthy backend replica ({replicas} registered)")
            }
            ServeError::IdleTimeout { idle_ms } => {
                write!(f, "connection idle past the {idle_ms}ms read timeout")
            }
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_messages_carry_context() {
        let cases: Vec<(ServeError, &str)> = vec![
            (ServeError::Overloaded { queued: 8, capacity: 8 }, "overloaded"),
            (ServeError::DeadlineExceeded { waited_ms: 250, budget_ms: 200 }, "deadline_exceeded"),
            (ServeError::WorkerPanicked { batch_seq: 3 }, "worker_panicked"),
            (ServeError::UnknownModel { name: "gpt".into() }, "unknown_model"),
            (ServeError::BadRequest { reason: "width".into() }, "bad_request"),
            (ServeError::LoadFailed { model: "m".into(), reason: "NaN".into() }, "load_failed"),
            (ServeError::ShuttingDown, "shutting_down"),
            (ServeError::Draining, "draining"),
            (ServeError::NoBackend { replicas: 3 }, "no_backend"),
            (ServeError::IdleTimeout { idle_ms: 30_000 }, "idle_timeout"),
        ];
        for (e, code) in cases {
            assert_eq!(e.code(), code);
            assert!(!e.to_string().is_empty());
        }
        assert!(ServeError::Overloaded { queued: 8, capacity: 8 }.to_string().contains("8/8"));
    }

    #[test]
    fn binary_tags_round_trip_to_codes() {
        let all = vec![
            ServeError::Overloaded { queued: 1, capacity: 1 },
            ServeError::DeadlineExceeded { waited_ms: 1, budget_ms: 1 },
            ServeError::WorkerPanicked { batch_seq: 1 },
            ServeError::UnknownModel { name: "m".into() },
            ServeError::BadRequest { reason: "r".into() },
            ServeError::LoadFailed { model: "m".into(), reason: "r".into() },
            ServeError::ShuttingDown,
            ServeError::Draining,
            ServeError::NoBackend { replicas: 1 },
            ServeError::IdleTimeout { idle_ms: 1 },
        ];
        let mut seen = std::collections::BTreeSet::new();
        for e in &all {
            let tag = e.tag();
            assert!(tag >= 1, "0 is the success status");
            assert!(seen.insert(tag), "duplicate tag {tag}");
            assert_eq!(ServeError::code_for_tag(tag), Some(e.code()));
        }
        assert_eq!(ServeError::code_for_tag(0), None);
        assert_eq!(ServeError::code_for_tag(200), None);
    }
}
