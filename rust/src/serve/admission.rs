//! Admission control: the bounded queue between connections and batch
//! workers.
//!
//! Overload policy in one sentence: *a request is either admitted and
//! served bit-exactly, or rejected with a typed error at a well-defined
//! point — never silently dropped, never allowed to wedge the server.* The
//! enforcement points:
//!
//! * **At the door** ([`AdmissionQueue::submit`]): the queue holds at most
//!   `capacity` requests. A full queue rejects with
//!   [`ServeError::Overloaded`] immediately — callers get backpressure in
//!   one round trip instead of unbounded memory growth and collapse. The
//!   rejected request comes back to the caller (so its pooled buffer and
//!   reply slot stay under the session's control).
//! * **At dequeue** ([`AdmissionQueue::next_batch`]): every request
//!   carries a deadline; requests whose deadline passed while queued are
//!   shed with [`ServeError::DeadlineExceeded`] *before* any compute is
//!   spent on them. Under sustained overload this is what keeps admitted
//!   traffic's latency bounded: stale work is discarded, not executed.
//!
//! `next_batch` also does the micro-batching: it groups queued requests
//! for one model (plan-cache hash) into a batch of up to `max_rows` input
//! rows, waiting up to a short batching window for more rows to arrive
//! once it holds at least one request. Which model gets the batch rotates
//! round-robin across the distinct queued hashes (in hash order), so a hot
//! model cannot starve a cold one; within the chosen model, requests ship
//! in arrival order.
//!
//! Everything here is steady-state allocation-free: requests carry pooled
//! buffers ([`PooledBuf`]), replies travel through per-connection
//! [`ReplySlot`]s instead of channels, and the queue swaps between two
//! pre-sized `VecDeque`s when it filters (shed, batch extraction).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::error::ServeError;
use super::pool::PooledBuf;
use super::wire::WireFormat;
use crate::accsim::IntMatrix;

/// A successful inference reply. The pooled buffer inside carries the
/// complete encoded wire reply (the worker writes it before responding);
/// the scalar fields exist for in-process callers and diagnostics.
#[derive(Debug)]
pub struct JobReply {
    /// The request's buffer, now holding the encoded reply bytes.
    buf: PooledBuf,
    /// Overflow events summed over every layer of the executing batch (the
    /// bit-exact `OverflowStats` contract surfaced to the client; 0 for an
    /// A2Q-constrained model at its target P).
    pub overflow_events: u64,
    /// Micro-batch sequence number that executed this request.
    pub batch_seq: u64,
    /// Total rows in that micro-batch (for batching diagnostics).
    pub batch_rows: usize,
}

impl JobReply {
    /// Take the buffer (encoded reply bytes + recyclable storage).
    pub fn into_buf(self) -> PooledBuf {
        self.buf
    }

    /// The encoded wire reply bytes.
    pub fn reply_bytes(&self) -> &[u8] {
        self.buf.reply()
    }
}

/// What a request's submitter eventually receives.
pub type JobOutcome = Result<JobReply, ServeError>;

/// A single-slot rendezvous for one request's outcome. Each connection
/// owns one and re-arms it per request ([`ReplySlot::sender`]) — unlike an
/// `mpsc` channel, delivering through it never allocates.
#[derive(Debug, Default)]
pub struct ReplySlot {
    slot: Mutex<Option<JobOutcome>>,
    cv: Condvar,
}

impl ReplySlot {
    pub fn new() -> Arc<ReplySlot> {
        Arc::new(ReplySlot::default())
    }

    /// Arm the slot for one request. Exactly one outcome will arrive: the
    /// sender delivers on [`ReplySender::send`], and its `Drop` fails
    /// closed with [`ServeError::WorkerPanicked`] if the holder vanished
    /// without responding (e.g. a worker unwound past the request).
    pub fn sender(self: &Arc<Self>) -> ReplySender {
        ReplySender { slot: Arc::clone(self), sent: false }
    }

    /// Block until the armed request's outcome arrives.
    pub fn recv(&self) -> JobOutcome {
        let mut g = self.slot.lock().unwrap();
        loop {
            if let Some(out) = g.take() {
                return out;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking take (tests; a disarmed sender delivers nothing).
    pub fn try_recv(&self) -> Option<JobOutcome> {
        self.slot.lock().unwrap().take()
    }
}

/// The delivering half of a [`ReplySlot`], owned by a [`JobRequest`].
#[derive(Debug)]
pub struct ReplySender {
    slot: Arc<ReplySlot>,
    sent: bool,
}

impl ReplySender {
    fn deliver(&mut self, outcome: JobOutcome) {
        if self.sent {
            return;
        }
        self.sent = true;
        *self.slot.slot.lock().unwrap() = Some(outcome);
        self.slot.cv.notify_one();
    }

    /// Deliver the outcome, consuming the sender.
    pub fn send(mut self, outcome: JobOutcome) {
        self.deliver(outcome);
    }

    /// Disarm without delivering — used when a submit is refused and the
    /// session reports the error itself, so a reusable slot isn't polluted
    /// by the drop fail-safe.
    fn disarm(mut self) {
        self.sent = true;
    }
}

impl Drop for ReplySender {
    fn drop(&mut self) {
        if !self.sent {
            // Fail closed: a request whose sender evaporated (worker
            // unwind, dropped batch) still gets a typed reply. batch_seq 0
            // marks "never reached a batch / batch unknown".
            self.deliver(Err(ServeError::WorkerPanicked { batch_seq: 0 }));
        }
    }
}

/// One admitted inference request, owning its pooled input/reply buffer.
#[derive(Debug)]
pub struct JobRequest {
    /// Monotone per-connection request id (diagnostics).
    pub id: u64,
    /// Plan-cache key of the model to execute.
    pub model_hash: u64,
    /// Which encoding the reply must use.
    pub wire: WireFormat,
    /// Input codes `[rows, input_dim]` decoded onto the model's layer-0
    /// grid, plus the reply byte buffer the worker will encode into.
    buf: PooledBuf,
    /// Moment the request was accepted into the queue.
    pub enqueued: Instant,
    /// Hard deadline: shed (never execute) past this instant.
    pub deadline: Instant,
    /// Deadline budget in ms as the client stated it (error reporting).
    pub budget_ms: u64,
    responder: ReplySender,
}

impl JobRequest {
    pub fn new(
        id: u64,
        model_hash: u64,
        wire: WireFormat,
        buf: PooledBuf,
        budget: Duration,
        responder: ReplySender,
    ) -> JobRequest {
        let now = Instant::now();
        JobRequest {
            id,
            model_hash,
            wire,
            buf,
            enqueued: now,
            deadline: now + budget,
            budget_ms: budget.as_millis() as u64,
            responder,
        }
    }

    /// The decoded input codes.
    pub fn input(&self) -> &IntMatrix {
        self.buf.input()
    }

    /// Input row count (what admission batching sums).
    pub fn rows(&self) -> usize {
        self.buf.input().rows()
    }

    /// The reply byte buffer the worker encodes the wire reply into.
    pub fn reply_buf_mut(&mut self) -> &mut Vec<u8> {
        self.buf.reply_mut()
    }

    /// Deliver success: the encoded reply (already in the buffer) plus its
    /// batch accounting travel back to the session; the buffer returns to
    /// the pool once the session has written it out.
    pub fn respond_ok(self, overflow_events: u64, batch_seq: u64, batch_rows: usize) {
        let JobRequest { buf, responder, .. } = self;
        responder.send(Ok(JobReply { buf, overflow_events, batch_seq, batch_rows }));
    }

    /// Deliver a typed refusal. The pooled buffer returns to the pool here.
    pub fn reject(self, err: ServeError) {
        let JobRequest { responder, .. } = self;
        responder.send(Err(err));
        // self.buf dropped -> pool
    }

    /// Abandon without delivering (submit refused; the session reports the
    /// error itself and will re-arm the same slot for its next request).
    pub fn cancel(self) {
        let JobRequest { responder, .. } = self;
        responder.disarm();
        // self.buf dropped -> pool
    }
}

/// A refused [`AdmissionQueue::submit`]: the request comes back with the
/// typed reason, leaving buffer recycling and error reporting to the
/// caller.
#[derive(Debug)]
pub struct RejectedJob {
    pub request: JobRequest,
    pub error: ServeError,
}

/// Counters the server exposes via the `stats` op. All relaxed: they are
/// diagnostics, not synchronization.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub admitted: AtomicU64,
    pub completed: AtomicU64,
    pub shed_overloaded: AtomicU64,
    pub shed_deadline: AtomicU64,
    pub shed_draining: AtomicU64,
    pub worker_panics: AtomicU64,
    pub respawns: AtomicU64,
    pub batches: AtomicU64,
    pub batched_rows: AtomicU64,
    /// Gauge, not a counter: requests admitted whose outcome has not yet
    /// been delivered (queued or executing). What a drain bleeds to zero.
    pub in_flight: AtomicU64,
}

/// A point-in-time copy of [`ServeStats`] (what the wire protocol carries).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub admitted: u64,
    pub completed: u64,
    pub shed_overloaded: u64,
    pub shed_deadline: u64,
    pub shed_draining: u64,
    pub worker_panics: u64,
    pub respawns: u64,
    pub batches: u64,
    pub batched_rows: u64,
    pub in_flight: u64,
}

impl ServeStats {
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            shed_overloaded: self.shed_overloaded.load(Ordering::Relaxed),
            shed_deadline: self.shed_deadline.load(Ordering::Relaxed),
            shed_draining: self.shed_draining.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_rows: self.batched_rows.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
        }
    }
}

struct QueueState {
    queue: VecDeque<JobRequest>,
    /// Scratch deque for in-place filtering (shed, batch extraction): the
    /// kept requests move here, then the deques swap. Pre-sized like
    /// `queue`, so filtering never allocates.
    spare: VecDeque<JobRequest>,
    closed: bool,
    /// Drain mode: new submits are refused typed (`Draining`) while queued
    /// and executing work completes normally — unlike `close`, nothing
    /// already admitted is rejected. Reversible via `set_draining(false)`.
    draining: bool,
    /// Model hash the previous batch served — the round-robin cursor.
    last_model: Option<u64>,
}

/// The bounded MPSC(-ish) admission queue: many connection threads submit,
/// a few batch workers drain.
pub struct AdmissionQueue {
    inner: Mutex<QueueState>,
    cv: Condvar,
    capacity: usize,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        let capacity = capacity.max(1);
        AdmissionQueue {
            inner: Mutex::new(QueueState {
                queue: VecDeque::with_capacity(capacity),
                spare: VecDeque::with_capacity(capacity),
                closed: false,
                draining: false,
                last_model: None,
            }),
            cv: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit a request, or hand it back typed — full queue and draining
    /// server are the caller's to report, the request never enters.
    pub fn submit(&self, req: JobRequest) -> Result<(), RejectedJob> {
        let mut st = self.inner.lock().unwrap();
        if st.closed {
            return Err(RejectedJob { request: req, error: ServeError::ShuttingDown });
        }
        if st.draining {
            return Err(RejectedJob { request: req, error: ServeError::Draining });
        }
        if st.queue.len() >= self.capacity {
            let error =
                ServeError::Overloaded { queued: st.queue.len(), capacity: self.capacity };
            return Err(RejectedJob { request: req, error });
        }
        st.queue.push_back(req);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Number of requests currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Enter or leave drain mode (see `QueueState::draining`).
    pub fn set_draining(&self, draining: bool) {
        self.inner.lock().unwrap().draining = draining;
    }

    /// Whether the queue is refusing new work as `Draining`.
    pub fn draining(&self) -> bool {
        self.inner.lock().unwrap().draining
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: all queued requests are rejected `ShuttingDown`,
    /// subsequent submits fail, and blocked workers wake up to exit.
    pub fn close(&self, stats: &ServeStats) {
        {
            let mut st = self.inner.lock().unwrap();
            st.closed = true;
            // Slot delivery is a non-blocking store+notify, so rejecting
            // in-lock is fine and keeps the drain atomic.
            while let Some(req) = st.queue.pop_front() {
                req.reject(ServeError::ShuttingDown);
            }
        }
        let _ = stats; // drained requests were admitted; completion stats untouched
        self.cv.notify_all();
    }

    /// Shed every queued request whose deadline has passed, replying
    /// `DeadlineExceeded` to each. Runs under the queue lock; slot
    /// delivery is non-blocking, and the double-buffer swap keeps the
    /// filter allocation-free.
    fn shed_expired(st: &mut QueueState, now: Instant, stats: &ServeStats) {
        if st.queue.iter().all(|r| r.deadline > now) {
            return;
        }
        debug_assert!(st.spare.is_empty());
        while let Some(req) = st.queue.pop_front() {
            if req.deadline <= now {
                stats.shed_deadline.fetch_add(1, Ordering::Relaxed);
                let waited_ms = now.duration_since(req.enqueued).as_millis() as u64;
                let budget_ms = req.budget_ms;
                req.reject(ServeError::DeadlineExceeded { waited_ms, budget_ms });
            } else {
                st.spare.push_back(req);
            }
        }
        std::mem::swap(&mut st.queue, &mut st.spare);
    }

    /// The model hash the next batch should serve: the smallest queued
    /// hash strictly greater than the last served one, wrapping to the
    /// smallest overall — a round-robin walk over whatever distinct models
    /// are queued, in hash order. One O(n) scan, no allocation.
    fn rotation_head(st: &QueueState) -> Option<u64> {
        let mut min_all: Option<u64> = None;
        let mut next_above: Option<u64> = None;
        for r in st.queue.iter() {
            let h = r.model_hash;
            min_all = Some(min_all.map_or(h, |m| m.min(h)));
            if let Some(last) = st.last_model {
                if h > last {
                    next_above = Some(next_above.map_or(h, |m| m.min(h)));
                }
            }
        }
        next_above.or(min_all)
    }

    /// Dequeue the next deadline-aware micro-batch into `batch` (cleared
    /// first): requests sharing the rotation-head model, up to `max_rows`
    /// total input rows. Waits up to `window` after the first request is
    /// available to let a fuller batch form (skipped when the batch is
    /// already full or the queue is closing). Returns the global monotone
    /// 1-based batch sequence number (the unit fault injection and
    /// `WorkerPanicked` reporting speak in), or `None` only when the queue
    /// is closed and drained — the worker's exit signal.
    ///
    /// The out-parameter batch (workers keep one sized to the queue
    /// capacity) makes the dequeue path allocation-free in steady state.
    pub fn next_batch(
        &self,
        max_rows: usize,
        window: Duration,
        stats: &ServeStats,
        batch: &mut Vec<JobRequest>,
    ) -> Option<u64> {
        batch.clear();
        let max_rows = max_rows.max(1);
        let mut st = self.inner.lock().unwrap();
        loop {
            loop {
                Self::shed_expired(&mut st, Instant::now(), stats);
                if !st.queue.is_empty() {
                    break;
                }
                if st.closed {
                    return None;
                }
                // Bounded wait so periodic expiry sheds don't depend on
                // new arrivals to wake us.
                let (guard, _timeout) =
                    self.cv.wait_timeout(st, Duration::from_millis(50)).unwrap();
                st = guard;
            }
            let head_model = Self::rotation_head(&st).expect("non-empty queue has a head");
            // Give the batch a short window to fill (only helpful while
            // the queued rows for this model are below the batch size).
            let model_rows = |st: &QueueState| -> usize {
                st.queue
                    .iter()
                    .filter(|r| r.model_hash == head_model)
                    .map(|r| r.rows())
                    .sum()
            };
            let mut queued_rows = model_rows(&st);
            if queued_rows < max_rows && !st.closed && !window.is_zero() {
                let fill_deadline = Instant::now() + window;
                while queued_rows < max_rows && !st.closed {
                    let now = Instant::now();
                    if now >= fill_deadline {
                        break;
                    }
                    let (guard, _timeout) =
                        self.cv.wait_timeout(st, fill_deadline - now).unwrap();
                    st = guard;
                    Self::shed_expired(&mut st, Instant::now(), stats);
                    queued_rows = model_rows(&st);
                }
                Self::shed_expired(&mut st, Instant::now(), stats);
            }
            // The window wait may have shed the head model (or the whole
            // queue) — re-pick from the top then.
            if !st.queue.iter().any(|r| r.model_hash == head_model) {
                continue;
            }
            // Extract same-model requests in arrival order up to max_rows;
            // everything else keeps its position for the next call.
            debug_assert!(st.spare.is_empty());
            let mut rows = 0usize;
            while let Some(req) = st.queue.pop_front() {
                let take = req.model_hash == head_model
                    && (batch.is_empty() || rows + req.rows() <= max_rows);
                if take {
                    rows += req.rows();
                    batch.push(req);
                } else {
                    st.spare.push_back(req);
                }
            }
            std::mem::swap(&mut st.queue, &mut st.spare);
            st.last_model = Some(head_model);
            let seq = stats.batches.fetch_add(1, Ordering::Relaxed) + 1;
            stats.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
            return Some(seq);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: u64, rows: usize, budget: Duration) -> (JobRequest, Arc<ReplySlot>) {
        let slot = ReplySlot::new();
        let buf = PooledBuf::detached(IntMatrix::zeros(rows, 4));
        let r = JobRequest::new(id, model, WireFormat::Json, buf, budget, slot.sender());
        (r, slot)
    }

    const LONG: Duration = Duration::from_secs(60);

    #[test]
    fn full_queue_rejects_typed_and_keeps_admitted_work() {
        let q = AdmissionQueue::new(2);
        let stats = ServeStats::default();
        let (a, _ra) = req(1, 7, 1, LONG);
        let (b, _rb) = req(2, 7, 1, LONG);
        let (c, rc) = req(3, 7, 1, LONG);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        let rejected = q.submit(c).unwrap_err();
        assert_eq!(rejected.error, ServeError::Overloaded { queued: 2, capacity: 2 });
        assert_eq!(rejected.error.code(), "overloaded");
        // The refused request comes back intact; cancelling it neither
        // replies nor loses the buffer.
        rejected.request.cancel();
        assert!(rc.try_recv().is_none(), "cancel() must not manufacture a reply");
        // The two admitted requests still come out as one micro-batch.
        let mut batch = Vec::new();
        let seq = q.next_batch(8, Duration::ZERO, &stats, &mut batch).unwrap();
        assert_eq!(seq, 1, "batch sequence numbers are 1-based and monotone");
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn expired_requests_are_shed_before_any_compute() {
        let q = AdmissionQueue::new(8);
        let stats = ServeStats::default();
        let (a, ra) = req(1, 7, 1, Duration::ZERO); // born expired
        let (b, _rb) = req(2, 7, 1, LONG);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        let mut batch = Vec::new();
        q.next_batch(8, Duration::ZERO, &stats, &mut batch).unwrap();
        assert_eq!(batch.len(), 1, "expired request must not reach a worker");
        assert_eq!(batch[0].id, 2);
        match ra.recv() {
            Err(ServeError::DeadlineExceeded { budget_ms, .. }) => assert_eq!(budget_ms, 0),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(stats.snapshot().shed_deadline, 1);
    }

    #[test]
    fn batches_group_by_model_and_respect_max_rows() {
        let q = AdmissionQueue::new(16);
        let stats = ServeStats::default();
        let mut slots = Vec::new();
        for (id, model, rows) in [(1, 7, 3), (2, 9, 1), (3, 7, 3), (4, 7, 3)] {
            let (r, slot) = req(id, model, rows, LONG);
            slots.push(slot);
            q.submit(r).unwrap();
        }
        let mut batch = Vec::new();
        // Model 7 is first in rotation: takes ids 1 and 3 (3+3 rows), id 4
        // would exceed 6.
        q.next_batch(6, Duration::ZERO, &stats, &mut batch).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        batch.drain(..).for_each(JobRequest::cancel);
        // Rotation moves on to model 9.
        q.next_batch(6, Duration::ZERO, &stats, &mut batch).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        batch.drain(..).for_each(JobRequest::cancel);
        let seq = q.next_batch(6, Duration::ZERO, &stats, &mut batch).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
        assert_eq!(seq, 3);
        batch.drain(..).for_each(JobRequest::cancel);
        // An oversized single request still ships alone rather than starving.
        let (big, _rbig) = req(9, 7, 50, LONG);
        q.submit(big).unwrap();
        q.next_batch(6, Duration::ZERO, &stats, &mut batch).unwrap();
        assert_eq!(batch[0].id, 9);
    }

    #[test]
    fn round_robin_interleaves_a_hot_model_with_a_cold_one() {
        let q = AdmissionQueue::new(16);
        let stats = ServeStats::default();
        let (hot, cold) = (5u64, 9u64);
        let mut slots = Vec::new();
        // Hot model floods the queue ahead of the cold model's requests.
        for (id, model) in [(1, hot), (2, hot), (3, hot), (4, cold), (5, cold)] {
            let (r, slot) = req(id, model, 1, LONG);
            slots.push(slot);
            q.submit(r).unwrap();
        }
        let mut order = Vec::new();
        let mut batch = Vec::new();
        for _ in 0..5 {
            q.next_batch(1, Duration::ZERO, &stats, &mut batch).unwrap();
            assert_eq!(batch.len(), 1);
            order.push(batch[0].id);
            batch.drain(..).for_each(JobRequest::cancel);
        }
        // Head-of-line draining would serve 1,2,3 before the cold model
        // ever ran; rotation alternates models every batch.
        assert_eq!(order, vec![1, 4, 2, 5, 3], "models must interleave round-robin");
    }

    #[test]
    fn dropped_requests_fail_closed_and_cancel_disarms() {
        let (r, slot) = req(1, 7, 1, LONG);
        drop(r); // e.g. a worker unwound while holding the batch
        match slot.recv() {
            Err(ServeError::WorkerPanicked { batch_seq }) => assert_eq!(batch_seq, 0),
            other => panic!("expected the fail-closed WorkerPanicked, got {other:?}"),
        }
        // The same slot re-arms cleanly afterwards, and cancel() disarms
        // the fail-safe so the next request sees a clean slot.
        let buf = PooledBuf::detached(IntMatrix::zeros(1, 4));
        let r = JobRequest::new(2, 7, WireFormat::Json, buf, LONG, slot.sender());
        r.cancel();
        assert!(slot.try_recv().is_none());
    }

    #[test]
    fn drain_refuses_new_work_but_keeps_admitted_work_and_is_reversible() {
        let q = AdmissionQueue::new(4);
        let stats = ServeStats::default();
        let (a, _ra) = req(1, 7, 1, LONG);
        q.submit(a).unwrap();
        q.set_draining(true);
        assert!(q.draining());
        // New work is refused typed, already-admitted work is untouched.
        let (b, rb) = req(2, 7, 1, LONG);
        let rejected = q.submit(b).unwrap_err();
        assert_eq!(rejected.error, ServeError::Draining);
        assert_eq!(rejected.error.code(), "draining");
        rejected.request.cancel();
        assert!(rb.try_recv().is_none());
        assert_eq!(q.len(), 1, "drain must not reject queued requests");
        let mut batch = Vec::new();
        q.next_batch(4, Duration::ZERO, &stats, &mut batch).unwrap();
        assert_eq!(batch.len(), 1, "queued work still executes while draining");
        batch.drain(..).for_each(JobRequest::cancel);
        // Resume re-admits.
        q.set_draining(false);
        let (c, _rc) = req(3, 7, 1, LONG);
        q.submit(c).unwrap();
    }

    #[test]
    fn close_rejects_queued_and_future_work_and_wakes_workers() {
        let q = std::sync::Arc::new(AdmissionQueue::new(4));
        let stats = ServeStats::default();
        let (a, ra) = req(1, 7, 1, LONG);
        q.submit(a).unwrap();
        q.close(&stats);
        assert_eq!(ra.recv().unwrap_err(), ServeError::ShuttingDown);
        let (b, _rb) = req(2, 7, 1, LONG);
        assert_eq!(q.submit(b).unwrap_err().error, ServeError::ShuttingDown);
        // A drained closed queue returns None (worker exit signal) without
        // blocking.
        let mut batch = Vec::new();
        assert!(q.next_batch(4, Duration::ZERO, &stats, &mut batch).is_none());
    }
}
