//! Admission control: the bounded queue between connections and batch
//! workers.
//!
//! Overload policy in one sentence: *a request is either admitted and
//! served bit-exactly, or rejected with a typed error at a well-defined
//! point — never silently dropped, never allowed to wedge the server.* The
//! enforcement points:
//!
//! * **At the door** ([`AdmissionQueue::submit`]): the queue holds at most
//!   `capacity` requests. A full queue rejects with
//!   [`ServeError::Overloaded`] immediately — callers get backpressure in
//!   one round trip instead of unbounded memory growth and collapse.
//! * **At dequeue** ([`AdmissionQueue::next_batch`]): every request
//!   carries a deadline; requests whose deadline passed while queued are
//!   shed with [`ServeError::DeadlineExceeded`] *before* any compute is
//!   spent on them. Under sustained overload this is what keeps admitted
//!   traffic's latency bounded: stale work is discarded, not executed.
//!
//! `next_batch` also does the micro-batching: it groups queued requests
//! for the *same model* (plan-cache hash) into one batch of up to
//! `max_rows` input rows, waiting up to a short batching window for more
//! rows to arrive once it holds at least one request. Requests for other
//! models stay queued in arrival order for the next call.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::error::ServeError;
use crate::accsim::IntMatrix;
use crate::tensor::Tensor;

/// A successful inference reply: the final-layer dequantized outputs for
/// this request's rows, plus the overflow accounting of the micro-batch
/// that carried it.
#[derive(Clone, Debug)]
pub struct JobReply {
    /// `[rows, output_dim]` dequantized outputs.
    pub outputs: Tensor,
    /// Overflow events summed over every layer of the executing batch (the
    /// bit-exact `OverflowStats` contract surfaced to the client; 0 for an
    /// A2Q-constrained model at its target P).
    pub overflow_events: u64,
    /// Micro-batch sequence number that executed this request.
    pub batch_seq: u64,
    /// Total rows in that micro-batch (for batching diagnostics).
    pub batch_rows: usize,
}

/// What a request's submitter eventually receives.
pub type JobOutcome = Result<JobReply, ServeError>;

/// One admitted inference request.
pub struct JobRequest {
    /// Monotone request id (diagnostics).
    pub id: u64,
    /// Plan-cache key of the model to execute.
    pub model_hash: u64,
    /// Input codes `[rows, input_dim]` on the model's layer-0 grid.
    pub rows: IntMatrix,
    /// Moment the request was accepted into the queue.
    pub enqueued: Instant,
    /// Hard deadline: shed (never execute) past this instant.
    pub deadline: Instant,
    /// Deadline budget in ms as the client stated it (error reporting).
    pub budget_ms: u64,
    /// Where the outcome goes. Send failures are ignored: a client that
    /// hung up forfeits its reply, nothing else.
    pub responder: Sender<JobOutcome>,
}

impl JobRequest {
    /// Reply to this request, consuming it.
    pub fn respond(self, outcome: JobOutcome) {
        let _ = self.responder.send(outcome);
    }
}

/// Counters the server exposes via the `stats` op. All relaxed: they are
/// diagnostics, not synchronization.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub admitted: AtomicU64,
    pub completed: AtomicU64,
    pub shed_overloaded: AtomicU64,
    pub shed_deadline: AtomicU64,
    pub worker_panics: AtomicU64,
    pub respawns: AtomicU64,
    pub batches: AtomicU64,
    pub batched_rows: AtomicU64,
}

/// A point-in-time copy of [`ServeStats`] (what the wire protocol carries).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub admitted: u64,
    pub completed: u64,
    pub shed_overloaded: u64,
    pub shed_deadline: u64,
    pub worker_panics: u64,
    pub respawns: u64,
    pub batches: u64,
    pub batched_rows: u64,
}

impl ServeStats {
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            shed_overloaded: self.shed_overloaded.load(Ordering::Relaxed),
            shed_deadline: self.shed_deadline.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_rows: self.batched_rows.load(Ordering::Relaxed),
        }
    }
}

struct QueueState {
    queue: VecDeque<JobRequest>,
    closed: bool,
}

/// The bounded MPSC(-ish) admission queue: many connection threads submit,
/// a few batch workers drain.
pub struct AdmissionQueue {
    inner: Mutex<QueueState>,
    cv: Condvar,
    capacity: usize,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(QueueState { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit a request, or reject it typed — full queue and draining
    /// server are the caller's to report, the request never enters.
    pub fn submit(&self, req: JobRequest) -> Result<(), ServeError> {
        let mut st = self.inner.lock().unwrap();
        if st.closed {
            return Err(ServeError::ShuttingDown);
        }
        if st.queue.len() >= self.capacity {
            return Err(ServeError::Overloaded {
                queued: st.queue.len(),
                capacity: self.capacity,
            });
        }
        st.queue.push_back(req);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Number of requests currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: all queued requests are rejected `ShuttingDown`,
    /// subsequent submits fail, and blocked workers wake up to exit.
    pub fn close(&self, stats: &ServeStats) {
        let drained: Vec<JobRequest> = {
            let mut st = self.inner.lock().unwrap();
            st.closed = true;
            st.queue.drain(..).collect()
        };
        for req in drained {
            req.respond(Err(ServeError::ShuttingDown));
        }
        let _ = stats; // drained requests were admitted; completion stats untouched
        self.cv.notify_all();
    }

    /// Shed every queued request whose deadline has passed, replying
    /// `DeadlineExceeded` to each. Must be called with the lock held;
    /// replies are sent after collecting so the lock isn't held across
    /// sends — here sends are channel pushes (non-blocking), so in-lock is
    /// acceptable and keeps the scan atomic.
    fn shed_expired(st: &mut QueueState, now: Instant, stats: &ServeStats) {
        let mut kept = VecDeque::with_capacity(st.queue.len());
        for req in st.queue.drain(..) {
            if req.deadline <= now {
                stats.shed_deadline.fetch_add(1, Ordering::Relaxed);
                let waited_ms = now.duration_since(req.enqueued).as_millis() as u64;
                let budget_ms = req.budget_ms;
                req.respond(Err(ServeError::DeadlineExceeded { waited_ms, budget_ms }));
            } else {
                kept.push_back(req);
            }
        }
        st.queue = kept;
    }

    /// Dequeue the next deadline-aware micro-batch: requests sharing the
    /// oldest queued request's model, up to `max_rows` total input rows.
    /// Waits up to `window` after the first request is available to let a
    /// fuller batch form (skipped when the batch is already full or the
    /// queue is closing). Returns the global monotone 1-based batch
    /// sequence number alongside the batch (the unit fault injection and
    /// `WorkerPanicked` reporting speak in), or `None` only when the queue
    /// is closed and drained — the worker's exit signal.
    pub fn next_batch(
        &self,
        max_rows: usize,
        window: Duration,
        stats: &ServeStats,
    ) -> Option<(u64, Vec<JobRequest>)> {
        let max_rows = max_rows.max(1);
        let mut st = self.inner.lock().unwrap();
        loop {
            Self::shed_expired(&mut st, Instant::now(), stats);
            if !st.queue.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            // Bounded wait so periodic expiry sheds don't depend on new
            // arrivals to wake us.
            let (guard, _timeout) = self.cv.wait_timeout(st, Duration::from_millis(50)).unwrap();
            st = guard;
        }
        // Give the batch a short window to fill (only helpful while the
        // queued rows for this model are below the batch size).
        let head_model = st.queue.front().map(|r| r.model_hash).unwrap();
        let mut queued_rows: usize = st
            .queue
            .iter()
            .filter(|r| r.model_hash == head_model)
            .map(|r| r.rows.rows())
            .sum();
        if queued_rows < max_rows && !st.closed && !window.is_zero() {
            let deadline = Instant::now() + window;
            while queued_rows < max_rows && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
                st = guard;
                Self::shed_expired(&mut st, Instant::now(), stats);
                queued_rows = st
                    .queue
                    .iter()
                    .filter(|r| r.model_hash == head_model)
                    .map(|r| r.rows.rows())
                    .sum();
            }
            Self::shed_expired(&mut st, Instant::now(), stats);
        }
        // Collect same-model requests in arrival order up to max_rows;
        // everything else keeps its position for the next call. The window
        // wait may have shed the whole queue — loop from the top then.
        if st.queue.is_empty() {
            drop(st);
            return self.next_batch(max_rows, window, stats);
        }
        let head_model = st.queue.front().map(|r| r.model_hash).unwrap();
        let mut batch = Vec::new();
        let mut rows = 0usize;
        let mut rest = VecDeque::with_capacity(st.queue.len());
        for req in st.queue.drain(..) {
            let take = req.model_hash == head_model
                && (batch.is_empty() || rows + req.rows.rows() <= max_rows);
            if take {
                rows += req.rows.rows();
                batch.push(req);
            } else {
                rest.push_back(req);
            }
        }
        st.queue = rest;
        let seq = stats.batches.fetch_add(1, Ordering::Relaxed) + 1;
        stats.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
        Some((seq, batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(
        id: u64,
        model: u64,
        rows: usize,
        budget: Duration,
    ) -> (JobRequest, mpsc::Receiver<JobOutcome>) {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        let r = JobRequest {
            id,
            model_hash: model,
            rows: IntMatrix::zeros(rows, 4),
            enqueued: now,
            deadline: now + budget,
            budget_ms: budget.as_millis() as u64,
            responder: tx,
        };
        (r, rx)
    }

    const LONG: Duration = Duration::from_secs(60);

    #[test]
    fn full_queue_rejects_typed_and_keeps_admitted_work() {
        let q = AdmissionQueue::new(2);
        let stats = ServeStats::default();
        let (a, _ra) = req(1, 7, 1, LONG);
        let (b, _rb) = req(2, 7, 1, LONG);
        let (c, _rc) = req(3, 7, 1, LONG);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        let err = q.submit(c).unwrap_err();
        assert_eq!(err, ServeError::Overloaded { queued: 2, capacity: 2 });
        assert_eq!(err.code(), "overloaded");
        // The two admitted requests still come out as one micro-batch.
        let (seq, batch) = q.next_batch(8, Duration::ZERO, &stats).unwrap();
        assert_eq!(seq, 1, "batch sequence numbers are 1-based and monotone");
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn expired_requests_are_shed_before_any_compute() {
        let q = AdmissionQueue::new(8);
        let stats = ServeStats::default();
        let (a, ra) = req(1, 7, 1, Duration::ZERO); // born expired
        let (b, _rb) = req(2, 7, 1, LONG);
        q.submit(a).unwrap();
        q.submit(b).unwrap();
        let (_, batch) = q.next_batch(8, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch.len(), 1, "expired request must not reach a worker");
        assert_eq!(batch[0].id, 2);
        match ra.recv().unwrap() {
            Err(ServeError::DeadlineExceeded { budget_ms, .. }) => assert_eq!(budget_ms, 0),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(stats.snapshot().shed_deadline, 1);
    }

    #[test]
    fn batches_group_by_model_and_respect_max_rows() {
        let q = AdmissionQueue::new(16);
        let stats = ServeStats::default();
        for (id, model, rows) in [(1, 7, 3), (2, 9, 1), (3, 7, 3), (4, 7, 3)] {
            let (r, rx) = req(id, model, rows, LONG);
            std::mem::forget(rx); // keep responders alive without binding names
            q.submit(r).unwrap();
        }
        // Model 7 head: takes ids 1 and 3 (3+3 rows), id 4 would exceed 6.
        let (_, batch) = q.next_batch(6, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        // Model 9 is now the head and batches alone.
        let (_, batch) = q.next_batch(6, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        let (seq, batch) = q.next_batch(6, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
        assert_eq!(seq, 3);
        // An oversized single request still ships alone rather than starving.
        let (big, _rbig) = req(9, 7, 50, LONG);
        q.submit(big).unwrap();
        let (_, batch) = q.next_batch(6, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch[0].id, 9);
    }

    #[test]
    fn close_rejects_queued_and_future_work_and_wakes_workers() {
        let q = std::sync::Arc::new(AdmissionQueue::new(4));
        let stats = ServeStats::default();
        let (a, ra) = req(1, 7, 1, LONG);
        q.submit(a).unwrap();
        q.close(&stats);
        assert_eq!(ra.recv().unwrap().unwrap_err(), ServeError::ShuttingDown);
        let (b, _rb) = req(2, 7, 1, LONG);
        assert_eq!(q.submit(b).unwrap_err(), ServeError::ShuttingDown);
        // A drained closed queue returns None (worker exit signal) without
        // blocking.
        assert!(q.next_batch(4, Duration::ZERO, &stats).is_none());
    }
}
