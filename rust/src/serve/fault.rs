//! Fault injection: the seam that lets tests and CI *prove* recovery.
//!
//! A robustness claim nobody can trigger is an assumption, not a feature.
//! [`FaultPlan`] injects the three failure modes the server must survive —
//! a worker panic mid-batch, pathological batch latency (to force queue
//! buildup and deadline sheds), and a model-load failure — from the
//! `A2Q_FAULT` environment variable, so a CI job can start a deliberately
//! broken server and assert it keeps serving. The spec grammar is a comma
//! list of `key[:value]` tokens:
//!
//! ```text
//! A2Q_FAULT=panic_batch:3,delay_ms:20,cache_load
//! ```
//!
//! `panic_batch:N` panics the worker executing the Nth micro-batch
//! (1-based, once); `delay_ms:D` sleeps every batch D milliseconds before
//! executing; `cache_load` fails every plan-cache load; `conn_drop:N` cuts
//! each connection mid-frame while writing its Nth reply (1-based), so a
//! router sees a torn reply on a live replica; `ping_stall_ms:D` delays
//! every health-probe (`ping`) reply by D milliseconds, so probe-timeout
//! paths are deterministic. Unknown or malformed tokens are ignored (same
//! forgiving policy as `A2Q_STREAM_REFRESH`): a typo'd fault spec must not
//! change production behaviour.

/// The injected-failure schedule a server runs under. `Default` is no
/// faults.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Panic the worker executing this (1-based) micro-batch sequence
    /// number. Fires once: sequence numbers are global and monotone.
    pub panic_batch: Option<u64>,
    /// Sleep this long before executing every micro-batch.
    pub delay_ms: Option<u64>,
    /// Fail every plan-cache model load with a typed `LoadFailed`.
    pub cache_load: bool,
    /// Close each connection after writing only half of its (1-based) Nth
    /// reply frame: the client sees a torn reply from a live replica.
    pub conn_drop: Option<u64>,
    /// Sleep this long before answering every `ping`, stalling health
    /// probes past their timeout without touching the infer path.
    pub ping_stall_ms: Option<u64>,
}

impl FaultPlan {
    /// No faults.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Parse a spec string (`None`/empty -> no faults; unknown tokens
    /// ignored).
    pub fn from_spec(spec: Option<&str>) -> FaultPlan {
        let mut plan = FaultPlan::default();
        let Some(spec) = spec else { return plan };
        for token in spec.split(',') {
            let token = token.trim();
            let (key, value) = match token.split_once(':') {
                Some((k, v)) => (k.trim(), Some(v.trim())),
                None => (token, None),
            };
            match (key, value.and_then(|v| v.parse::<u64>().ok())) {
                ("panic_batch", Some(n)) if n > 0 => plan.panic_batch = Some(n),
                ("delay_ms", Some(d)) => plan.delay_ms = Some(d),
                ("cache_load", _) => plan.cache_load = true,
                ("conn_drop", Some(n)) if n > 0 => plan.conn_drop = Some(n),
                ("ping_stall_ms", Some(d)) => plan.ping_stall_ms = Some(d),
                _ => {} // unknown/malformed token: no behaviour change
            }
        }
        plan
    }

    /// Read the process-wide plan from `A2Q_FAULT`.
    pub fn from_env() -> FaultPlan {
        FaultPlan::from_spec(std::env::var("A2Q_FAULT").ok().as_deref())
    }

    /// True when nothing is injected.
    pub fn is_noop(&self) -> bool {
        *self == FaultPlan::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_round_trips() {
        assert!(FaultPlan::from_spec(None).is_noop());
        assert!(FaultPlan::from_spec(Some("")).is_noop());
        let p = FaultPlan::from_spec(Some("panic_batch:3,delay_ms:20,cache_load"));
        assert_eq!(p.panic_batch, Some(3));
        assert_eq!(p.delay_ms, Some(20));
        assert!(p.cache_load);
        let p = FaultPlan::from_spec(Some("conn_drop:2,ping_stall_ms:250"));
        assert_eq!(p.conn_drop, Some(2));
        assert_eq!(p.ping_stall_ms, Some(250));
        // spacing tolerated, zero delay valid
        let p = FaultPlan::from_spec(Some(" delay_ms:0 , panic_batch:1 "));
        assert_eq!((p.panic_batch, p.delay_ms, p.cache_load), (Some(1), Some(0), false));
    }

    #[test]
    fn malformed_tokens_never_change_behaviour() {
        for bad in [
            "panic_batch",
            "panic_batch:0",
            "panic_batch:x",
            "delay_ms",
            "nope:5",
            "::,",
            "conn_drop:0",
            "conn_drop",
            "ping_stall_ms",
        ] {
            assert!(FaultPlan::from_spec(Some(bad)).is_noop(), "{bad:?}");
        }
        // a bad token next to a good one leaves the good one intact
        let p = FaultPlan::from_spec(Some("bogus:9,delay_ms:5"));
        assert_eq!(p.delay_ms, Some(5));
        assert_eq!(p.panic_batch, None);
    }
}
