//! Test support: a tiny self-cleaning temporary directory (offline
//! replacement for the `tempfile` crate) and shared bench fixtures.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::accsim::{IntMatrix, StreamDelta};
use crate::model::{NetSpec, QNetwork, SynthQuant};
use crate::quant::a2q::a2q_quantize_row;
use crate::quant::QTensor;
use crate::rng::Rng;
use crate::tensor::Tensor;

/// Deterministic quantized-layer fixture for the accsim P-sweep perf
/// instruments. The release bench (`benches/runtime_hotpath.rs`) and the
/// test-suite smoke (`tests/bench_smoke.rs`) both build their workload from
/// this one function so their journal entries measure the same distribution.
pub fn psweep_layer(c_out: usize, k: usize, seed: u64) -> QTensor {
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..c_out * k)
        .map(|_| (rng.normal() * 30.0).round().clamp(-128.0, 127.0) as f32)
        .collect();
    QTensor::from_export(
        &Tensor::new(vec![c_out, k], w),
        &Tensor::new(vec![c_out, 1], vec![0.01; c_out]),
        &Tensor::from_vec(vec![0.0; c_out]),
    )
}

/// Deterministic A2Q-constrained layer fixture: every channel is pushed
/// through the paper's weight quantizer at target accumulator width
/// `p_bits` for `n_bits`-bit unsigned inputs, so the Eq. 15 cap holds and a
/// sweep at or above `p_bits` is provably overflow-free on every channel —
/// the scenario the safe-span GEMM engine collapses to a plain integer
/// matmul. Shared by the release bench (`benches/runtime_hotpath.rs`) and
/// the test-suite smoke (`tests/bench_smoke.rs`).
pub fn psweep_constrained_layer(
    c_out: usize,
    k: usize,
    p_bits: u32,
    n_bits: u32,
    seed: u64,
) -> QTensor {
    let mut rng = Rng::new(seed);
    let mut codes = Vec::with_capacity(c_out * k);
    let mut scales = Vec::with_capacity(c_out);
    for _ in 0..c_out {
        let v: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        // Cap target far above the Eq. 23 ceiling so the accumulator
        // constraint (not t) binds — same regime as QNetwork::synthesize.
        let (w_int, s) = a2q_quantize_row(&v, -6.0, 30.0, 8, n_bits, p_bits, false);
        codes.extend(w_int.iter().map(|w| *w as i64));
        scales.push(s);
    }
    QTensor { codes, scales, bias: vec![0.0; c_out], c_out, k }
}

/// Deterministic calibrated A2Q-constrained network fixture (target P = 16)
/// plus a quantized input batch, shared by the network-forward perf
/// instruments (`benches/network_forward.rs` and `tests/network_smoke.rs`)
/// so their journal entries measure the same distribution. Sweeping below
/// 16 bits overflows (mode groups split); at or above it the bound gate
/// keeps every mode fused with the wide path.
pub fn psweep_network(widths: &[usize], batch: usize, seed: u64) -> (QNetwork, IntMatrix) {
    let spec = NetSpec {
        widths: widths.to_vec(),
        m_bits: 6,
        n_bits: 4,
        p_bits: 16,
        x_signed: false,
        quant: SynthQuant::A2q,
    };
    let mut net = QNetwork::synthesize(&spec, seed).expect("valid bench spec");
    let mut rng = Rng::new(seed ^ 0xCAFE);
    let dim = widths[0];
    let sample =
        Tensor::new(vec![batch, dim], (0..batch * dim).map(|_| rng.uniform() as f32).collect());
    net.calibrate(&sample);
    let x = net.layers[0].in_quant.quantize(&sample);
    (net, x)
}

/// Deterministic sparse-delta tick for the streaming perf instruments:
/// `per_row` feature changes on every batch row of `x`, each drawn as a
/// fresh `n_bits`-bit unsigned code. `old` values are read from the
/// *current* `x` (chaining correctly when the same feature is drawn twice
/// in one tick), so the tick is valid for a session holding exactly `x` —
/// generate, apply to the session, mirror into your `x` copy, repeat.
/// Shared by the release bench (`benches/stream_delta.rs`), the test-suite
/// smoke (`tests/stream_smoke.rs`) and the `a2q stream` CLI so every
/// instrument measures the same delta distribution.
pub fn stream_delta_tick(
    x: &IntMatrix,
    per_row: usize,
    n_bits: u32,
    rng: &mut Rng,
) -> Vec<StreamDelta> {
    let (rows, k) = (x.rows(), x.cols());
    let mut deltas = Vec::with_capacity(rows * per_row);
    if k == 0 || per_row == 0 {
        return deltas;
    }
    let mut pending: HashMap<(usize, usize), i64> = HashMap::new();
    for row in 0..rows {
        for _ in 0..per_row {
            let feature = rng.below(k);
            let old = pending.get(&(row, feature)).copied().unwrap_or_else(|| x.get(row, feature));
            let new = rng.below(1usize << n_bits) as i64;
            pending.insert((row, feature), new);
            deltas.push(StreamDelta { row, feature, old, new });
        }
    }
    deltas
}

/// Apply `deltas` to a plain [`IntMatrix`] (the full-recompute mirror of a
/// stream session's internal state).
pub fn apply_deltas(x: &mut IntMatrix, deltas: &[StreamDelta]) {
    for d in deltas {
        debug_assert_eq!(x.get(d.row, d.feature), d.old, "stale delta in mirror");
        x.set(d.row, d.feature, d.new);
    }
}

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temporary directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "a2q-test-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos())
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans() {
        let p;
        {
            let d = TempDir::new().unwrap();
            p = d.path().to_path_buf();
            std::fs::write(d.path().join("x"), "hi").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
