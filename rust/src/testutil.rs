//! Test support: a tiny self-cleaning temporary directory (offline
//! replacement for the `tempfile` crate) and shared bench fixtures.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::quant::QTensor;
use crate::rng::Rng;
use crate::tensor::Tensor;

/// Deterministic quantized-layer fixture for the accsim P-sweep perf
/// instruments. The release bench (`benches/runtime_hotpath.rs`) and the
/// test-suite smoke (`tests/bench_smoke.rs`) both build their workload from
/// this one function so their journal entries measure the same distribution.
pub fn psweep_layer(c_out: usize, k: usize, seed: u64) -> QTensor {
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..c_out * k)
        .map(|_| (rng.normal() * 30.0).round().clamp(-128.0, 127.0) as f32)
        .collect();
    QTensor::from_export(
        &Tensor::new(vec![c_out, k], w),
        &Tensor::new(vec![c_out, 1], vec![0.01; c_out]),
        &Tensor::from_vec(vec![0.0; c_out]),
    )
}

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temporary directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "a2q-test-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos())
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans() {
        let p;
        {
            let d = TempDir::new().unwrap();
            p = d.path().to_path_buf();
            std::fs::write(d.path().join("x"), "hi").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
