//! Minimal JSON: parse + serialize, no external dependencies.
//!
//! The environment pins the dependency set to the image's offline crate
//! cache (xla + anyhow), so the manifest/config/record plumbing carries its
//! own JSON implementation. Scope: everything `python/compile/aot.py` emits
//! and everything the coordinator persists — objects, arrays, strings with
//! standard escapes, f64 numbers, bools, null. Not a general-purpose
//! validator (it accepts a few super-sets of JSON, e.g. lone NaN is
//! rejected on write but tolerated never on read).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, ensure, Result};

/// A JSON value. Numbers are f64 (ints round-trip exactly to 2^53, far
/// beyond anything in our manifests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    // ------------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow::anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        ensure!(n >= 0.0 && n.fract() == 0.0, "expected unsigned integer, got {n}");
        Ok(n as usize)
    }

    pub fn as_u32(&self) -> Result<u32> {
        Ok(self.as_usize()? as u32)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// `[1, 2, 3]` -> `Vec<usize>` (shape lists).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // -------------------------------------------------------------- serialize

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize into a caller-owned buffer (appended, not cleared): the
    /// reuse surface for per-connection write buffers — same bytes as
    /// [`Json::to_string`].
    pub fn write_into(&self, out: &mut String) {
        self.write(out);
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// The one JSON number formatter: integral values within f64's exact-int
/// window print without a fractional part, everything else as shortest
/// round-trip float. Public (and generic over [`std::fmt::Write`]) so
/// out-of-tree encoders — e.g. the serve worker writing replies straight
/// into pooled byte buffers — produce bytes byte-identical to
/// [`Json::to_string`].
pub fn write_num<W: std::fmt::Write>(out: &mut W, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        // shortest round-trip float formatting
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        ensure!(self.peek()? == b, "expected {:?} at byte {}", b as char, self.pos);
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        ensure!(
            self.bytes[self.pos..].starts_with(lit.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += lit.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?} at byte {}", c as char, self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?} at byte {}", c as char, self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            ensure!(self.pos + 4 <= self.bytes.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // surrogate pairs: JSON from our own writer never
                            // emits them for BMP text; decode best-effort
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // re-consume as UTF-8: back up and take the full char
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_manifest_like() {
        let text = r#"{"name": "cnn", "batch_size": 64, "lr": 0.05,
                       "qlayers": [{"m_bits": 8}, {"m_bits": "M"}],
                       "ok": true, "none": null, "neg": -1.5e-3}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "cnn");
        assert_eq!(v.get("batch_size").unwrap().as_usize().unwrap(), 64);
        assert_eq!(v.get("lr").unwrap().as_f64().unwrap(), 0.05);
        assert_eq!(v.get("qlayers").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("ok").unwrap().as_bool().unwrap());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_pass_through() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn numbers() {
        for (text, want) in [("0", 0.0), ("-12", -12.0), ("3.5", 3.5), ("1e3", 1000.0), ("-2.5E-2", -0.025)] {
            assert_eq!(Json::parse(text).unwrap().as_f64().unwrap(), want, "{text}");
        }
        // integers serialize without exponent/decimal
        assert_eq!(Json::Num(784.0).to_string(), "784");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn nested_deep() {
        let v = Json::parse("[[[[1,2],[3]],[]],[{\"k\":[true]}]]").unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn shape_vec() {
        let v = Json::parse("[128, 16, 16, 3]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![128, 16, 16, 3]);
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_err());
    }
}
