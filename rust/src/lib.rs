//! # A2Q — Accumulator-Aware Quantization with Guaranteed Overflow Avoidance
//!
//! A from-scratch reproduction of Colbert, Pappalardo & Petri-Koenig (2023)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas, build time)** — the A2Q weight quantizer, the baseline
//!   affine quantizer and an MXU-tiled matmul live in
//!   `python/compile/kernels/` and are lowered into the model HLO.
//! * **L2 (JAX, build time)** — the quantized model zoo (mlp / cnn / resnet /
//!   espcn / unet) with STE gradients and SGD/Adam train steps, AOT-exported
//!   to HLO text artifacts by `python/compile/aot.py`.
//! * **L3 (this crate, run time)** — everything else: the PJRT [`runtime`]
//!   that executes the artifacts, the [`coordinator`] that runs training
//!   loops and the (M, N, P) grid search, and the substrates the paper's
//!   evaluation needs: exact integer accumulation simulation ([`accsim`],
//!   single layers and whole [`model::QNetwork`] stacks with inter-layer
//!   requantization), accumulator bit-width bounds ([`quant`]), synthetic datasets
//!   ([`datasets`]), a FINN-style FPGA LUT cost model ([`finn`]), Pareto
//!   frontiers ([`pareto`]), task metrics ([`metrics`]) and per-figure report
//!   generation ([`report`]).
//!
//! Python never runs on the request path: after `make artifacts` the `a2q`
//! binary trains, evaluates, sweeps and reports entirely from Rust.
//!
//! Training is abstracted behind [`runtime::TrainBackend`]
//! (`init / train_step / infer / export` over host-tensor state leaves):
//! the default build trains through the pure-Rust
//! [`runtime::NativeBackend`] (forward/backward for MLP manifests over the
//! shared blocked f32 GEMM core in [`linalg`], batch fan-out across scoped
//! threads, STE through the [`quant::WeightQuantizer`] — paper A2Q and
//! A2Q+), so
//! `a2q train` / `a2q sweep` and every training-backed figure run fully
//! offline; the PJRT executor for the AOT artifacts is the same trait
//! behind the `xla` cargo feature. Bench throughput history is journaled
//! to BENCH_accsim.json via [`perf`] (see EXPERIMENTS.md §Perf).
//! Exported networks are served online by `a2q serve` ([`serve`]): a
//! bounded-queue, micro-batching inference service whose overload and
//! fault behaviour is typed and test-provable.

pub mod accsim;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod finn;
pub mod json;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod pareto;
pub mod perf;
pub mod quant;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testutil;

pub use tensor::Tensor;
