//! The safety-partitioned kernel engine: every P-sweep forward runs as a
//! four-stage pipeline that spends register-simulation work only where the
//! paper's overflow bound cannot prove it away.
//!
//! 1. **Plan-time channel ordering** — each layer's channels are sorted once
//!    (per [`LayerPlan`] / [`NetworkPlan`]) by their integer l1 norm
//!    `Σ|w_int|`, and the weight matrix is packed into GEMM panels in that
//!    order ([`super::gemm`]). At execution one `partition_point` per *row*
//!    over `l1_sorted[c] * max|x_row|` splits the whole channel set into a
//!    provably-safe prefix and a must-simulate tail — the bound test is the
//!    per-(row, channel) gate of the previous engine (Eq. 4-5, also
//!    arXiv:2301.13376 §3) hoisted out of the inner loop: a channel is safe
//!    when even the narrowest simulated register cannot overflow on it.
//! 2. **Packed blocked GEMM for the safe span** — safe channels need only
//!    the wide (exact) dot product, so they run through a cache-blocked
//!    integer GEMM over weights packed once per plan into k-major,
//!    NR-channel panels of `i16`/`i32` codes, with MR-row tiling over the
//!    batch ([`super::gemm::PackedWeights`]). For an A2Q-constrained layer
//!    swept at or above its target width — the paper's headline scenario —
//!    this stage covers *every* channel and the simulator degenerates to a
//!    plain integer matmul. Exact i64 accumulation keeps the GEMM output
//!    bit-identical to the scalar walk.
//! 3. **Register simulation for the remainder** — channels the bound cannot
//!    clear take the fused multi-width traversal ([`fused_dot`]): one pass
//!    over the MACs carries a register per simulated width (wrap is a
//!    shift/sign-extend pair, saturate a compare/clamp), and the
//!    per-channel `min_safe_p` still lets every register at or above the
//!    channel's safe width resolve from the exact sum.
//! 4. **Arena + dynamic scheduling** — rows are split into fixed blocks and
//!    fanned over `std::thread::scope` workers through an atomic-counter
//!    queue, so blocks heavy in must-simulate channels do not straggle
//!    behind a static partition. Each worker owns a scratch arena
//!    ([`SimScratch`] / [`NetWorker`]) reused across blocks, layers and
//!    mode groups, so every batch-sized buffer (activations, outputs,
//!    registers, requantization codes) recycles; only small per-group
//!    bookkeeping (a [`ModePlan`], slot lists) still allocates. Workers
//!    write into
//!    disjoint preallocated output slices and per-block [`OverflowStats`]
//!    slots that merge in block order after the join, so outputs and every
//!    statistics counter are bit-identical to the sequential walk for any
//!    thread count (`abs_err_sum` — a sum of integer-valued f64 terms — is
//!    exact, hence order-independent, while the total stays below 2^53).
//!
//! Stage skipping: rows with `max|x| = 0` (and layers with `k = 0`) gate
//! every channel into stage 2; a plan whose narrowest simulated register
//! still clears a channel set entirely skips stage 3; a plan with *no*
//! per-MAC register (only `Wide`/`SaturateFinal` modes) never simulates at
//! all; single-block batches skip the queue and run inline on the caller's
//! thread.
//!
//! On top of the single-layer [`LayerPlan`], the [`NetworkPlan`] streams
//! row blocks through a whole [`crate::model::QNetwork`]: within a block,
//! modes whose propagated activations are still byte-identical share one
//! fused traversal per layer (all modes start fused at layer 0) and only
//! split after a register has actually corrupted an activation;
//! requantization between layers runs buffer-to-buffer through the worker
//! arena (no `Tensor` round trip), and the last layer's wide output is
//! computed once per mode group and shared across its slots.
//!
//! All kernels are property-tested bit-exact against the per-P scalar
//! references ([`super::matmul::qlinear_forward_ref`] /
//! [`crate::model::network_forward_ref`]) in
//! `rust/tests/property_invariants.rs`, including degenerate shapes (empty
//! batch, `k = 0`, all-zero rows, fully-safe and fully-unsafe layers) at
//! thread counts {1, 2, 7}. Throughput history lives in EXPERIMENTS.md
//! §Perf and BENCH_accsim.json.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::dot::{range, AccMode, DotResult};
use super::gemm::PackedWeights;
use super::intmat::{abs_max_of, IntMatrix};
use super::matmul::MatmulStats;
use super::stats::OverflowStats;
use crate::linalg::KernelPath;
use crate::model::QNetwork;
use crate::quant::QTensor;
use crate::tensor::Tensor;

/// The kernel-dispatch decision made for one layer at plan time, exposed so
/// dispatch is observable instead of silent: which [`KernelPath`] the
/// packed GEMM runs, the layer's measured weight sparsity (the input to the
/// density heuristic, and the quantity the sparse path converts into
/// throughput), and whether packing fell back to the unpacked i64 wide-dot
/// path (codes beyond i32).
#[derive(Clone, Copy, Debug)]
pub struct KernelChoice {
    /// Path the safe-span GEMM dispatches through.
    pub path: KernelPath,
    /// Zero fraction of the layer's weight codes (`QTensor::sparsity`).
    pub sparsity: f64,
    /// True when `PackedWeights::pack` rejected the codes and safe channels
    /// run unpacked wide dots instead of the GEMM.
    pub pack_fallback: bool,
}

/// One per-MAC simulated register of the fused plan.
#[derive(Clone, Copy, Debug)]
struct Reg {
    /// Index into the caller's `modes` array.
    slot: usize,
    p_bits: u32,
    /// Shift for the wrap family: `64 - p_bits`.
    sh: u32,
    /// Clamp rails for the saturate family.
    lo: i64,
    hi: i64,
}

/// A mode list partitioned into register families, sorted so the bound gate
/// can activate a prefix (narrower widths overflow first).
#[derive(Clone, Debug)]
pub struct ModePlan {
    modes: Vec<AccMode>,
    /// Wraparound registers, ascending `p_bits`.
    wrap: Vec<Reg>,
    /// Inner-loop saturating registers, ascending `p_bits`.
    sat: Vec<Reg>,
    /// Modes resolved from the exact sum after the traversal: `Wide` and
    /// `SaturateFinal` never need a per-MAC register.
    finals: Vec<(usize, AccMode)>,
}

impl ModePlan {
    pub fn new(modes: &[AccMode]) -> ModePlan {
        let mut wrap = Vec::new();
        let mut sat = Vec::new();
        let mut finals = Vec::new();
        for (slot, mode) in modes.iter().enumerate() {
            match *mode {
                AccMode::Wide | AccMode::SaturateFinal { .. } => finals.push((slot, *mode)),
                AccMode::Wrap { p_bits } => {
                    debug_assert!((1..=64).contains(&p_bits), "wrap p_bits {p_bits}");
                    wrap.push(Reg { slot, p_bits, sh: 64 - p_bits, lo: 0, hi: 0 });
                }
                AccMode::Saturate { p_bits } => {
                    let (lo, hi) = range(p_bits);
                    sat.push(Reg { slot, p_bits, sh: 0, lo, hi });
                }
            }
        }
        wrap.sort_by_key(|r| r.p_bits);
        sat.sort_by_key(|r| r.p_bits);
        ModePlan { modes: modes.to_vec(), wrap, sat, finals }
    }

    pub fn modes(&self) -> &[AccMode] {
        &self.modes
    }

    /// Number of per-MAC registers a scratch buffer must hold.
    fn scratch_len(&self) -> usize {
        self.wrap.len().max(self.sat.len())
    }

    /// Narrowest per-MAC register width in the plan, `None` when no mode
    /// needs per-MAC simulation (only `Wide`/`SaturateFinal` modes): the
    /// width the stage-1 row partition tests channels against.
    fn min_sim_p(&self) -> Option<u32> {
        match (self.wrap.first().map(|r| r.p_bits), self.sat.first().map(|r| r.p_bits)) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

/// Per-worker register scratch (reused across every dot product).
#[derive(Default)]
struct Scratch {
    wrap_acc: Vec<i64>,
    wrap_ovf: Vec<u32>,
    sat_acc: Vec<i64>,
    sat_ovf: Vec<u32>,
}

impl Scratch {
    fn for_plan(plan: &ModePlan) -> Scratch {
        let mut s = Scratch::default();
        s.ensure(plan.scratch_len());
        s
    }

    /// Grow (never shrink) to hold `n` registers, so one arena serves every
    /// mode group it meets.
    fn ensure(&mut self, n: usize) {
        if self.wrap_acc.len() < n {
            self.wrap_acc.resize(n, 0);
            self.wrap_ovf.resize(n, 0);
            self.sat_acc.resize(n, 0);
            self.sat_ovf.resize(n, 0);
        }
    }
}

/// Smallest accumulator width that provably cannot overflow given the
/// channel's `Σ|w_int|` and the row's `max|x|`: every intermediate partial
/// sum satisfies `|s| <= l1 * xmax`, so width P is safe iff
/// `l1 * xmax <= 2^(P-1) - 1`. Returns 64 (wider than any simulated
/// register) when no width up to 63 is safe.
#[inline]
pub fn min_safe_p(l1: i128, xmax: i64) -> u32 {
    debug_assert!(l1 >= 0 && xmax >= 0);
    let worst = l1 * xmax as i128;
    if worst == 0 {
        return 1;
    }
    let bits = 128 - (worst as u128).leading_zeros();
    (bits + 1).min(64)
}

/// Plain exact dot product: the only arithmetic a provably-safe channel
/// needs (kept branch-free so the compiler can vectorize it).
#[inline]
fn wide_dot(x: &[i64], w: &[i64]) -> i64 {
    let mut acc = 0i64;
    for (xi, wi) in x.iter().zip(w) {
        acc += xi * wi;
    }
    acc
}

/// One traversal of the MACs of `x . w`, updating every register whose
/// width is below `p_safe`; registers at or above `p_safe` (and the
/// `Wide`/`SaturateFinal` modes) are resolved from the exact sum. Writes one
/// [`DotResult`] per plan mode into `out` and returns the wide value.
fn fused_dot(
    plan: &ModePlan,
    x: &[i64],
    w: &[i64],
    p_safe: u32,
    scratch: &mut Scratch,
    out: &mut [DotResult],
) -> i64 {
    debug_assert_eq!(x.len(), w.len());
    debug_assert_eq!(out.len(), plan.modes.len());
    let nw = plan.wrap.partition_point(|r| r.p_bits < p_safe);
    let ns = plan.sat.partition_point(|r| r.p_bits < p_safe);

    let mut wide = 0i64;
    if nw == 0 && ns == 0 {
        // Bound-gated fast path: nothing can overflow, so the whole dot
        // product is a plain wide dot the compiler can vectorize.
        wide = wide_dot(x, w);
    } else {
        let wrap_active = &plan.wrap[..nw];
        let sat_active = &plan.sat[..ns];
        let wrap_acc = &mut scratch.wrap_acc[..nw];
        let wrap_ovf = &mut scratch.wrap_ovf[..nw];
        let sat_acc = &mut scratch.sat_acc[..ns];
        let sat_ovf = &mut scratch.sat_ovf[..ns];
        wrap_acc.fill(0);
        wrap_ovf.fill(0);
        sat_acc.fill(0);
        sat_ovf.fill(0);

        for (xi, wi) in x.iter().zip(w) {
            let prod = xi * wi; // exact: multiplier output is full-width
            wide += prod;
            // Wraparound family: shift/sign-extend per width (~2 ops + an
            // overflow compare), no branches.
            for (j, r) in wrap_active.iter().enumerate() {
                let t = wrap_acc[j] + prod;
                let v = t.wrapping_shl(r.sh) >> r.sh;
                wrap_ovf[j] += (v != t) as u32;
                wrap_acc[j] = v;
            }
            // Saturating family: clamp per width.
            for (j, r) in sat_active.iter().enumerate() {
                let t = sat_acc[j] + prod;
                sat_ovf[j] += ((t < r.lo) | (t > r.hi)) as u32;
                sat_acc[j] = t.clamp(r.lo, r.hi);
            }
        }

        for (j, r) in wrap_active.iter().enumerate() {
            out[r.slot] = DotResult { value: scratch.wrap_acc[j], overflows: scratch.wrap_ovf[j] };
        }
        for (j, r) in sat_active.iter().enumerate() {
            out[r.slot] = DotResult { value: scratch.sat_acc[j], overflows: scratch.sat_ovf[j] };
        }
    }

    // Bound-safe registers: the register model is the identity, so the
    // simulated value IS the wide value with zero overflow events.
    for r in &plan.wrap[nw..] {
        out[r.slot] = DotResult { value: wide, overflows: 0 };
    }
    for r in &plan.sat[ns..] {
        out[r.slot] = DotResult { value: wide, overflows: 0 };
    }
    for (slot, mode) in &plan.finals {
        out[*slot] = match *mode {
            AccMode::Wide => DotResult { value: wide, overflows: 0 },
            AccMode::SaturateFinal { p_bits } => {
                let (lo, hi) = range(p_bits);
                let clipped = wide.clamp(lo, hi);
                DotResult { value: clipped, overflows: u32::from(clipped != wide) }
            }
            _ => unreachable!("finals only hold Wide/SaturateFinal"),
        };
    }
    wide
}

/// Fused multi-width dot-product simulation: one traversal of the MACs,
/// one [`DotResult`] per requested mode. Bit-exact against calling
/// [`super::dot::dot_accumulate`] once per mode.
pub fn dot_accumulate_multi(x: &[i64], w: &[i64], modes: &[AccMode]) -> Vec<DotResult> {
    let plan = ModePlan::new(modes);
    let mut scratch = Scratch::for_plan(&plan);
    let mut out = vec![DotResult { value: 0, overflows: 0 }; modes.len()];
    let l1: i128 = w.iter().map(|v| v.unsigned_abs() as i128).sum();
    let p_safe = min_safe_p(l1, abs_max_of(x));
    fused_dot(&plan, x, w, p_safe, &mut scratch, &mut out);
    out
}

/// Per-layer kernel context built once per plan: the l1-sorted channel
/// order that turns the per-(row, channel) bound gate into one
/// `partition_point` per row, plus the weight panels the safe-span GEMM
/// streams.
///
/// Owned data only — no borrow of the source [`QTensor`] — so plans built
/// over an `Arc<QNetwork>` ([`SharedNetworkPlan`]) can ship across threads.
/// Callers pass the weight tensor back in at execution time; it must be the
/// tensor the kernel was packed from (the sorted order and panels encode
/// its contents).
pub(crate) struct LayerKernel {
    /// Channel ids sorted ascending by integer l1 norm (stable, so the
    /// order — and every downstream result — is deterministic).
    order: Vec<usize>,
    /// `row_l1[order[i]]`, ascending: the partition_point axis.
    l1_sorted: Vec<i128>,
    /// Per-channel l1 norms by original channel id (the per-channel
    /// `min_safe_p` gate inside the simulated span).
    row_l1: Vec<i128>,
    /// Weight codes packed for the safe-span GEMM in `order` (None when
    /// some code exceeds i32; the engine then falls back to unpacked wide
    /// dots for safe channels).
    packed: Option<PackedWeights>,
    /// The plan-time dispatch decision, for observability.
    pub(crate) choice: KernelChoice,
}

impl LayerKernel {
    fn new(w: &QTensor) -> LayerKernel {
        LayerKernel::new_with(w, None)
    }

    /// Build the kernel context, optionally pinning the GEMM dispatch
    /// (`None` = auto: `A2Q_KERNEL` override, then density heuristic).
    fn new_with(w: &QTensor, forced: Option<KernelPath>) -> LayerKernel {
        // One source of truth for the per-channel norm: QTensor::row_l1
        // (Eq. 13), widened to i128 for the overflow-proof bound products.
        let row_l1: Vec<i128> = w.row_l1().into_iter().map(|v| v as i128).collect();
        let mut order: Vec<usize> = (0..w.c_out).collect();
        order.sort_by_key(|&c| row_l1[c]);
        let l1_sorted: Vec<i128> = order.iter().map(|&c| row_l1[c]).collect();
        let packed = match forced {
            Some(path) => PackedWeights::pack_with(w, &order, path),
            None => PackedWeights::pack(w, &order),
        };
        let choice = KernelChoice {
            path: packed.as_ref().map(|p| p.path()).unwrap_or(KernelPath::Scalar),
            sparsity: w.sparsity(),
            pack_fallback: packed.is_none(),
        };
        LayerKernel { order, l1_sorted, row_l1, packed, choice }
    }

    /// Length of the provably-safe prefix of `order` for a row with
    /// `max|x| = xmax`: every simulated register is at least `min_p` bits
    /// wide, so a channel is fully safe iff `l1 * xmax <= 2^(min_p-1) - 1`
    /// — the same test as `min_safe_p(l1, xmax) <= min_p`, hoisted to one
    /// `partition_point` over the sorted norms.
    fn safe_prefix(&self, xmax: i64, min_p: Option<u32>) -> usize {
        // No per-MAC registers: every mode resolves from the exact sum.
        let Some(p) = min_p else { return self.order.len() };
        if p >= 64 {
            // min_safe_p never reports more than 64 bits.
            return self.order.len();
        }
        let cap = (1i128 << (p - 1)) - 1;
        let xm = xmax as i128;
        self.l1_sorted.partition_point(|&l1| l1 * xm <= cap)
    }

    /// Exact wide accumulators of *every* channel for `rows` flat input
    /// rows, written by **original channel id** (`acc[ri * c_out + c]`):
    /// the initial / refresh state of the incremental stream sessions
    /// ([`super::stream`]). Runs the packed safe-span GEMM when the layer
    /// packed (then scatters out of the sorted order), or unpacked wide
    /// dots on the i32-rejected fallback — the same arithmetic stage 2 of
    /// [`simulate_block`] would run, so a maintained accumulator is
    /// bit-identical to a recompute by construction.
    pub(crate) fn accumulate_rows(
        &self,
        w: &QTensor,
        x: &[i64],
        rows: usize,
        scratch: &mut Vec<i64>,
        acc: &mut [i64],
    ) {
        let c_out = w.c_out;
        let k = w.k;
        debug_assert_eq!(x.len(), rows * k);
        debug_assert_eq!(acc.len(), rows * c_out);
        if rows == 0 || c_out == 0 {
            return;
        }
        match &self.packed {
            Some(packed) => {
                scratch.clear();
                scratch.resize(rows * c_out, 0);
                packed.gemm_into(x, rows, c_out, scratch);
                for ri in 0..rows {
                    for (ci, &c) in self.order.iter().enumerate() {
                        acc[ri * c_out + c] = scratch[ri * c_out + ci];
                    }
                }
            }
            None => {
                for ri in 0..rows {
                    let xrow = &x[ri * k..(ri + 1) * k];
                    for (c, a) in acc[ri * c_out..(ri + 1) * c_out].iter_mut().enumerate() {
                        *a = wide_dot(xrow, w.row(c));
                    }
                }
            }
        }
    }
}

/// Per-worker scratch arena for the block kernel, reused across row blocks
/// (and, inside [`NetWorker`], across layers and mode groups): the block
/// kernel itself allocates nothing once these buffers are warm.
#[derive(Default)]
struct SimScratch {
    reg: Scratch,
    dots: Vec<DotResult>,
    /// Safe-span GEMM output, `rows * n_common`.
    gemm: Vec<i64>,
    /// Wide values of the current row, by original channel id.
    wide_int: Vec<i64>,
    /// Simulated-span per-slot values: `[unsafe_idx * n_modes + slot]`.
    sim_vals: Vec<i64>,
    /// Per-channel `w_scale * x_scale`.
    scale: Vec<f32>,
    /// Per-row `max|x|` over the block.
    xmax: Vec<i64>,
    /// Per-row safe-prefix length over the block.
    n_safe: Vec<usize>,
}

/// The single-threaded four-stage block kernel shared by [`LayerPlan`]
/// workers and the per-layer steps of [`NetworkPlan`] workers: simulate
/// `rows` rows of `x . w^T` (flat row-major `x`, `rows * k` long) under
/// every mode of `plan`, writing dequantized per-mode outputs into
/// `mode_out[slot]` and the wide outputs into `wide_out` (each
/// `rows * c_out`), and accumulating per-mode stats into `stats`.
///
/// `acc`, when present, is a maintained exact-wide accumulator block
/// (`rows * c_out`, by original channel id) from an incremental
/// [`super::stream`] session: stage 2 (the safe-span GEMM) is skipped and
/// safe channels read their wide values straight out of `acc` instead.
/// Everything else — the stage-1 partition against the *current* per-row
/// `max|x|`, the stage-3 register simulation of unsafe channels, stats
/// recording and the dequantized epilogue — is the same code either way,
/// so outputs and every [`OverflowStats`] counter are bit-identical to a
/// full recompute by construction (the accumulator invariant
/// `acc[ri * c_out + c] == Σ_j x[ri][j] * w[c][j]` makes the values equal;
/// shared code makes everything downstream equal).
#[allow(clippy::too_many_arguments)]
fn simulate_block(
    kern: &LayerKernel,
    w: &QTensor,
    plan: &ModePlan,
    x: &[i64],
    rows: usize,
    x_scale: f32,
    ws: &mut SimScratch,
    mode_out: &mut [&mut [f32]],
    wide_out: &mut [f32],
    stats: &mut [OverflowStats],
    acc: Option<&[i64]>,
) {
    let c_out = w.c_out;
    let k = w.k;
    let n_modes = plan.modes.len();
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(wide_out.len(), rows * c_out);
    debug_assert_eq!(mode_out.len(), n_modes);
    debug_assert_eq!(stats.len(), n_modes);
    debug_assert!(acc.is_none_or(|a| a.len() == rows * c_out));
    if rows == 0 || c_out == 0 {
        return;
    }
    let min_p = plan.min_sim_p();

    // Stage 1: per-row safe/unsafe partition, plus the block-wide common
    // prefix the multi-row GEMM covers.
    ws.xmax.clear();
    ws.n_safe.clear();
    let mut n_common = c_out;
    for ri in 0..rows {
        let xm = abs_max_of(&x[ri * k..(ri + 1) * k]);
        let ns = kern.safe_prefix(xm, min_p);
        n_common = n_common.min(ns);
        ws.xmax.push(xm);
        ws.n_safe.push(ns);
    }

    // Stage 2: packed blocked GEMM over the common safe prefix (skipped
    // entirely when the caller maintains the accumulators incrementally).
    ws.gemm.clear();
    if n_common > 0 && acc.is_none() {
        match &kern.packed {
            Some(packed) => {
                ws.gemm.resize(rows * n_common, 0);
                packed.gemm_into(x, rows, n_common, &mut ws.gemm);
            }
            None => {
                // Codes beyond i32: keep exactness on the unpacked rows.
                ws.gemm.reserve(rows * n_common);
                for ri in 0..rows {
                    let xrow = &x[ri * k..(ri + 1) * k];
                    for &c in &kern.order[..n_common] {
                        ws.gemm.push(wide_dot(xrow, w.row(c)));
                    }
                }
            }
        }
    }

    ws.scale.clear();
    ws.scale.extend(w.scales.iter().map(|s| s * x_scale));
    ws.wide_int.resize(c_out, 0);
    ws.sim_vals.resize(c_out * n_modes, 0);
    ws.dots.resize(n_modes, DotResult { value: 0, overflows: 0 });
    ws.reg.ensure(plan.scratch_len());

    for ri in 0..rows {
        let xrow = &x[ri * k..(ri + 1) * k];
        let row_off = ri * c_out;
        let xmax = ws.xmax[ri];
        let n_safe = ws.n_safe[ri];

        // Safe-span wides: the maintained accumulators when streaming,
        // else the GEMM prefix plus the per-row remainder the block-wide
        // tile could not cover.
        match acc {
            Some(a) => {
                let arow = &a[row_off..row_off + c_out];
                for &c in &kern.order[..n_safe] {
                    ws.wide_int[c] = arow[c];
                }
            }
            None => {
                for (ci, &c) in kern.order[..n_common].iter().enumerate() {
                    ws.wide_int[c] = ws.gemm[ri * n_common + ci];
                }
                for &c in &kern.order[n_common..n_safe] {
                    ws.wide_int[c] = wide_dot(xrow, w.row(c));
                }
            }
        }

        // Stage 3: register simulation only for the channels the bound
        // cannot clear; per-slot values stashed for the overwrite below.
        for (ui, &c) in kern.order[n_safe..].iter().enumerate() {
            let p_safe = min_safe_p(kern.row_l1[c], xmax);
            let wide = fused_dot(plan, xrow, w.row(c), p_safe, &mut ws.reg, &mut ws.dots);
            ws.wide_int[c] = wide;
            for (slot, d) in ws.dots.iter().enumerate() {
                stats[slot].record(k, d.overflows, d.value, wide);
                ws.sim_vals[ui * n_modes + slot] = d.value;
            }
        }

        // Dequantized wide row (every safe channel's value under every
        // register model).
        for c in 0..c_out {
            wide_out[row_off + c] = ws.wide_int[c] as f32 * ws.scale[c] + w.bias[c];
        }

        // Safe-span stats in bulk: each safe channel would `record(k, 0,
        // wide, wide)` for every wrap/sat register and every Wide mode —
        // dots/macs/outputs bumps with exactly-zero error terms.
        let ns64 = n_safe as u64;
        for r in plan.wrap.iter().chain(plan.sat.iter()) {
            let s = &mut stats[r.slot];
            s.dots += ns64;
            s.macs += ns64 * k as u64;
            s.outputs += ns64;
        }

        // Per-mode rows: the wide row everywhere, then overwrite the
        // simulated span with each register's own values.
        for r in plan.wrap.iter().chain(plan.sat.iter()) {
            let dst = &mut mode_out[r.slot][row_off..row_off + c_out];
            dst.copy_from_slice(&wide_out[row_off..row_off + c_out]);
            for (ui, &c) in kern.order[n_safe..].iter().enumerate() {
                dst[c] = ws.sim_vals[ui * n_modes + r.slot] as f32 * ws.scale[c] + w.bias[c];
            }
        }
        for (slot, mode) in &plan.finals {
            match *mode {
                AccMode::Wide => {
                    let s = &mut stats[*slot];
                    s.dots += ns64;
                    s.macs += ns64 * k as u64;
                    s.outputs += ns64;
                    mode_out[*slot][row_off..row_off + c_out]
                        .copy_from_slice(&wide_out[row_off..row_off + c_out]);
                }
                AccMode::SaturateFinal { p_bits } => {
                    let (lo, hi) = range(p_bits);
                    // Safe-span stats (the simulated span was recorded
                    // through the dots loop above; the clip test still
                    // applies to safe channels).
                    for &c in &kern.order[..n_safe] {
                        let wide = ws.wide_int[c];
                        let clipped = wide.clamp(lo, hi);
                        stats[*slot].record(k, u32::from(clipped != wide), clipped, wide);
                    }
                    let dst = &mut mode_out[*slot][row_off..row_off + c_out];
                    for c in 0..c_out {
                        let clipped = ws.wide_int[c].clamp(lo, hi);
                        dst[c] = clipped as f32 * ws.scale[c] + w.bias[c];
                    }
                }
                _ => unreachable!("finals only hold Wide/SaturateFinal"),
            }
        }
    }
}

/// Rows per scheduler block: small enough that the atomic queue can
/// rebalance simulation-heavy blocks across workers, large enough to
/// amortize a queue grab and feed the GEMM's row tile.
fn row_block_size(batch: usize, threads: usize) -> usize {
    if threads <= 1 {
        return batch.max(1);
    }
    batch.div_ceil(threads * 8).max(1)
}

/// Drain `tasks` across up to `threads` scoped workers through an
/// atomic-counter queue (dynamic scheduling: a worker grabs the next block
/// the moment it finishes its last one). Each worker builds its own scratch
/// via `mk_worker` and `work` consumes each task exactly once; because
/// every task owns disjoint output slices and its own stats slot, results
/// are bit-identical for any thread count.
fn run_queue<T: Send, W>(
    tasks: Vec<Mutex<Option<T>>>,
    threads: usize,
    mk_worker: impl Fn() -> W + Sync,
    work: impl Fn(&mut W, T) + Sync,
) {
    let n = tasks.len();
    if n == 0 {
        return;
    }
    let t = threads.max(1).min(n);
    if t == 1 {
        let mut w = mk_worker();
        for cell in tasks {
            if let Some(task) = cell.into_inner().expect("accsim task mutex poisoned") {
                work(&mut w, task);
            }
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let tasks = &tasks;
    let next = &next;
    let mk_worker = &mk_worker;
    let work = &work;
    std::thread::scope(|s| {
        for _ in 0..t {
            s.spawn(move || {
                let mut w = mk_worker();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let task = tasks[i]
                        .lock()
                        .expect("accsim task mutex poisoned")
                        .take()
                        .expect("row block claimed twice");
                    work(&mut w, task);
                }
            });
        }
    });
}

/// One row block's disjoint output slices plus its stats slot (merged in
/// block order after the join, so totals are thread-count independent).
struct LayerTask<'a> {
    r0: usize,
    r1: usize,
    mode_out: Vec<&'a mut [f32]>,
    wide_out: &'a mut [f32],
    stats: &'a mut [OverflowStats],
    /// Maintained accumulator rows for this block (stream sessions only).
    acc: Option<&'a [i64]>,
}

/// Bounds-aware execution plan for one quantized layer: the mode partition
/// plus the l1-sorted channel order and packed weight panels that drive the
/// safety-partitioned kernel.
pub struct LayerPlan<'w> {
    pub(crate) w: &'w QTensor,
    pub(crate) kern: LayerKernel,
    plan: ModePlan,
}

impl<'w> LayerPlan<'w> {
    pub fn new(w: &'w QTensor, modes: &[AccMode]) -> LayerPlan<'w> {
        LayerPlan::new_with_path(w, modes, None)
    }

    /// [`LayerPlan::new`] with the GEMM kernel dispatch pinned (`None` =
    /// auto). Benches and the kernel-path property tests use this to force
    /// each path through the same plan.
    pub fn new_with_path(
        w: &'w QTensor,
        modes: &[AccMode],
        path: Option<KernelPath>,
    ) -> LayerPlan<'w> {
        LayerPlan { w, kern: LayerKernel::new_with(w, path), plan: ModePlan::new(modes) }
    }

    pub fn modes(&self) -> &[AccMode] {
        self.plan.modes()
    }

    /// The plan-time kernel dispatch decision for this layer.
    pub fn kernel_choice(&self) -> KernelChoice {
        self.kern.choice
    }

    /// Execute over a batch with an explicit worker count (tests use this to
    /// pin thread counts; [`Self::execute`] picks one automatically).
    pub fn execute_threads(&self, x: &IntMatrix, x_scale: f32, threads: usize) -> Vec<MatmulStats> {
        self.execute_threads_acc(x, x_scale, threads, None)
    }

    /// [`Self::execute_threads`] with maintained layer accumulators
    /// (`batch * c_out`, original channel order) supplied by an incremental
    /// [`super::stream::LayerStreamSession`]: the safe-span GEMM is skipped
    /// and safe channels resolve from `acc` instead — bit-identical to the
    /// batch path by the accumulator invariant.
    pub(crate) fn execute_threads_acc(
        &self,
        x: &IntMatrix,
        x_scale: f32,
        threads: usize,
        l0: Option<&[i64]>,
    ) -> Vec<MatmulStats> {
        let batch = x.rows();
        let w = self.w;
        assert_eq!(x.cols(), w.k, "input cols {} vs layer k {}", x.cols(), w.k);
        let c_out = w.c_out;
        debug_assert!(l0.is_none_or(|a| a.len() == batch * c_out));
        let n_modes = self.plan.modes.len();
        if n_modes == 0 {
            return Vec::new();
        }

        let mut mode_bufs: Vec<Vec<f32>> =
            (0..n_modes).map(|_| vec![0f32; batch * c_out]).collect();
        let mut wide_buf = vec![0f32; batch * c_out];
        let mut merged = vec![OverflowStats::default(); n_modes];

        if batch > 0 && c_out > 0 {
            let t = threads.max(1).min(batch);
            let block_rows = row_block_size(batch, t);
            let n_blocks = batch.div_ceil(block_rows);
            let elems = block_rows * c_out;
            let mut block_stats = vec![OverflowStats::default(); n_blocks * n_modes];
            let tasks: Vec<Mutex<Option<LayerTask>>> = {
                let mut mode_iters: Vec<_> =
                    mode_bufs.iter_mut().map(|b| b.chunks_mut(elems)).collect();
                let mut wide_iter = wide_buf.chunks_mut(elems);
                let mut stats_iter = block_stats.chunks_mut(n_modes);
                (0..n_blocks)
                    .map(|bi| {
                        let r0 = bi * block_rows;
                        let r1 = (r0 + block_rows).min(batch);
                        Mutex::new(Some(LayerTask {
                            r0,
                            r1,
                            mode_out: mode_iters
                                .iter_mut()
                                .map(|it| it.next().expect("mode block slice"))
                                .collect(),
                            wide_out: wide_iter.next().expect("wide block slice"),
                            stats: stats_iter.next().expect("stats block slice"),
                            acc: l0.map(|a| &a[r0 * c_out..r1 * c_out]),
                        }))
                    })
                    .collect()
            };
            run_queue(tasks, t, SimScratch::default, |ws, task| {
                let LayerTask { r0, r1, mut mode_out, wide_out, stats, acc } = task;
                simulate_block(
                    &self.kern,
                    self.w,
                    &self.plan,
                    x.rows_slice(r0, r1),
                    r1 - r0,
                    x_scale,
                    ws,
                    &mut mode_out,
                    wide_out,
                    stats,
                    acc,
                );
            });
            for bi in 0..n_blocks {
                for (mi, m) in merged.iter_mut().enumerate() {
                    m.merge(&block_stats[bi * n_modes + mi]);
                }
            }
        }

        let out_wide = Tensor::new(vec![batch, c_out], wide_buf);
        mode_bufs
            .into_iter()
            .zip(merged)
            .map(|(data, stats)| MatmulStats {
                out: Tensor::new(vec![batch, c_out], data),
                out_wide: out_wide.clone(),
                stats,
            })
            .collect()
    }

    /// Execute over a batch, choosing the worker count from the simulated
    /// grid size (small grids run inline — thread spawn would dominate).
    pub fn execute(&self, x: &IntMatrix, x_scale: f32) -> Vec<MatmulStats> {
        let w = self.w;
        self.execute_threads(
            x,
            x_scale,
            worker_count(x.rows(), w.c_out, w.k, self.plan.modes.len()),
        )
    }
}

/// Pick a worker count for a `batch x c_out x k` MAC grid simulated under
/// `n_modes` register models. Honors the `A2Q_ACCSIM_THREADS` environment
/// variable when set.
pub(crate) fn worker_count(batch: usize, c_out: usize, k: usize, n_modes: usize) -> usize {
    if let Some(n) = crate::linalg::env_threads("A2Q_ACCSIM_THREADS") {
        return n;
    }
    // Below ~1M simulated MACs the pass finishes in well under a
    // millisecond; spawning threads would cost more than it saves. The mode
    // count scales the work exactly like the grid does — a 25-width sweep
    // runs 25x the register updates of a single-mode call — so it is part
    // of the product (the previous heuristic ignored it and under-counted
    // sweeps by the mode factor).
    let grid = batch.saturating_mul(c_out).saturating_mul(k);
    if grid.saturating_mul(n_modes.max(1)) < 1_000_000 {
        return 1;
    }
    crate::linalg::hardware_workers()
}

/// Forward one integer batch through a quantized linear layer under *all*
/// requested accumulator models in a single fused pass, returning one
/// [`MatmulStats`] per mode (same order). The per-P loop of the scalar era:
///
/// ```ignore
/// for p in 8..=32 { results.push(qlinear_forward(&x, s, &w, Wrap { p })); }
/// ```
///
/// collapses into one call:
///
/// ```ignore
/// let modes: Vec<_> = (8..=32).map(|p| AccMode::Wrap { p_bits: p }).collect();
/// let results = qlinear_forward_multi(&x, s, &w, &modes);
/// ```
pub fn qlinear_forward_multi(
    x: &IntMatrix,
    x_scale: f32,
    w: &QTensor,
    modes: &[AccMode],
) -> Vec<MatmulStats> {
    LayerPlan::new(w, modes).execute(x, x_scale)
}

/// Result of one network forward under one register model.
#[derive(Clone, Debug)]
pub struct NetworkStats {
    /// Final-layer dequantized outputs `[batch, c_out_last]` with this
    /// mode's activations propagated through every boundary.
    pub out: Tensor,
    /// Final-layer outputs under a wide last-layer register fed the same
    /// propagated activations (the per-mode "local" reference, exactly what
    /// composing [`super::matmul::qlinear_forward_ref`] produces).
    pub out_wide: Tensor,
    /// One [`OverflowStats`] per layer, in depth order.
    pub layer_stats: Vec<OverflowStats>,
}

/// A mode group mid-flight: the slots whose propagated activations are
/// still byte-identical, plus those activations as integer codes.
struct Group {
    slots: Vec<usize>,
    codes: Vec<i64>,
}

/// Per-worker arena for the network engine: group activations, group
/// outputs, register scratch and requantization buffers — every
/// batch-sized allocation — recycle across blocks, layers and mode groups
/// (the previous engine cloned per-slot output vectors and round-tripped
/// every requantization through a `Tensor`). Small per-group bookkeeping
/// (the group's [`ModePlan`] and slice-ref list) is still built per
/// traversal; group counts are bounded by the mode count, so it stays off
/// the MAC-dominated path.
#[derive(Default)]
struct NetWorker {
    sim: SimScratch,
    /// Groups entering the current layer / being assembled for the next.
    cur: Vec<Group>,
    next: Vec<Group>,
    /// Per-group-slot dequantized outputs of the current layer.
    outs: Vec<Vec<f32>>,
    /// The group's shared wide output (computed once per group).
    wide: Vec<f32>,
    /// Per-group-slot stats staging, merged into the task's layer slots.
    gstats: Vec<OverflowStats>,
    /// Requantized-codes staging for the regroup-by-equality step.
    qbuf: Vec<i64>,
    /// Spare buffers recycled between groups.
    code_pool: Vec<Vec<i64>>,
    slot_pool: Vec<Vec<usize>>,
    /// Current group's mode list staging (rebuilt per group, no alloc).
    gmodes: Vec<AccMode>,
    /// Memoized [`ModePlan`] keyed by `plan_modes`: consecutive groups with
    /// the same mode list (always, for single-mode serving plans) reuse it,
    /// so the per-group plan build drops off the steady-state path.
    plan_modes: Vec<AccMode>,
    plan: Option<ModePlan>,
}

/// One row block of a network forward: per-mode final-layer output slices
/// (simulated and wide) plus the block's `[layer][mode]` stats slots.
struct NetTask<'a> {
    r0: usize,
    r1: usize,
    out: Vec<&'a mut [f32]>,
    out_wide: Vec<&'a mut [f32]>,
    stats: &'a mut [OverflowStats],
    /// Maintained layer-0 accumulator rows for this block (stream sessions
    /// only; deeper layers always recompute — the NNUE idiom).
    l0: Option<&'a [i64]>,
}

/// Bounds-aware execution plan for a whole [`QNetwork`]: the multi-layer
/// generalization of [`LayerPlan`]. One batch pass simulates every requested
/// register model through every layer, with inter-layer requantization
/// (each boundary's [`crate::model::ActQuant`]) applied per mode so the
/// next layer sees exactly the activations its register model produced.
///
/// Fusion across modes survives layer boundaries as long as the modes'
/// activations remain byte-identical: all modes start fused at layer 0, and
/// a mode only splits off into its own traversal once its register has
/// actually corrupted an activation somewhere in the block. The safe-span
/// partition is applied per layer from the *propagated* per-row activation
/// max — not a global worst case — so deeper layers whose activations
/// shrink under requantization push more channels onto the GEMM path.
/// Bit-exact against composing the scalar reference per mode
/// ([`crate::model::network_forward_ref`]).
pub struct NetworkPlan<'n> {
    pub(crate) net: &'n QNetwork,
    pub(crate) modes: Vec<AccMode>,
    /// One kernel context (sorted order + packed panels) per layer.
    pub(crate) kernels: Vec<LayerKernel>,
}

impl<'n> NetworkPlan<'n> {
    pub fn new(net: &'n QNetwork, modes: &[AccMode]) -> NetworkPlan<'n> {
        NetworkPlan::new_with_path(net, modes, None)
    }

    /// [`NetworkPlan::new`] with every layer's GEMM kernel dispatch pinned
    /// (`None` = auto per layer).
    pub fn new_with_path(
        net: &'n QNetwork,
        modes: &[AccMode],
        path: Option<KernelPath>,
    ) -> NetworkPlan<'n> {
        NetworkPlan { net, modes: modes.to_vec(), kernels: net_kernels(net, path) }
    }

    pub fn modes(&self) -> &[AccMode] {
        &self.modes
    }

    /// Per-layer plan-time kernel dispatch decisions, in layer order.
    pub fn kernel_choices(&self) -> Vec<KernelChoice> {
        self.kernels.iter().map(|k| k.choice).collect()
    }

    pub fn depth(&self) -> usize {
        self.net.layers.len()
    }

    /// Execute over a batch with an explicit worker count (tests pin thread
    /// counts; [`Self::execute`] picks one from the network's MAC grid).
    pub fn execute_threads(&self, x: &IntMatrix, threads: usize) -> Vec<NetworkStats> {
        self.execute_threads_l0(x, threads, None)
    }

    /// [`Self::execute_threads`] with maintained layer-0 accumulators
    /// (`batch * c_out_0`, original channel order) supplied by an
    /// incremental [`super::stream::StreamSession`]: layer 0 skips its
    /// safe-span GEMM and resolves safe channels from `l0`; every deeper
    /// layer recomputes as usual.
    pub(crate) fn execute_threads_l0(
        &self,
        x: &IntMatrix,
        threads: usize,
        l0: Option<&[i64]>,
    ) -> Vec<NetworkStats> {
        net_execute_threads(self.net, &self.modes, &self.kernels, x, threads, l0)
    }

    /// Execute over a batch, choosing the worker count from the whole
    /// network's simulated MAC grid (small networks run inline).
    pub fn execute(&self, x: &IntMatrix) -> Vec<NetworkStats> {
        self.execute_threads(
            x,
            worker_count(x.rows(), self.net.macs_per_row(), 1, self.modes.len()),
        )
    }
}

/// Build one [`LayerKernel`] per layer of `net` (shared by the borrowing
/// [`NetworkPlan`] and the owning [`SharedNetworkPlan`]).
fn net_kernels(net: &QNetwork, path: Option<KernelPath>) -> Vec<LayerKernel> {
    net.layers.iter().map(|l| LayerKernel::new_with(&l.weights, path)).collect()
}

/// Stream rows `r0..r1` through every layer, writing the final layer's
/// outputs straight into the task's slices; the single-threaded core of the
/// network engine. `l0` is the block's maintained layer-0 accumulator slice
/// when an incremental stream session is driving the forward (only layer 0
/// can consume it: all modes are still fused in one group there, and it is
/// the only layer whose input the session tracks deltas against).
/// `kernels[i]` must have been built from `net.layers[i].weights`.
#[allow(clippy::too_many_arguments)]
fn net_forward_block(
    net: &QNetwork,
    modes: &[AccMode],
    kernels: &[LayerKernel],
    x: &IntMatrix,
    r0: usize,
    r1: usize,
    ws: &mut NetWorker,
    out: &mut [&mut [f32]],
    out_wide: &mut [&mut [f32]],
    stats: &mut [OverflowStats],
    l0: Option<&[i64]>,
) {
    let n_modes = modes.len();
    let depth = net.layers.len();
    let rows = r1 - r0;
    let NetWorker { sim, cur, next, outs, wide, gstats, qbuf, code_pool, slot_pool, gmodes, plan_modes, plan } =
        ws;
    debug_assert!(cur.is_empty() && next.is_empty());

    // Layer 0 input: one group holding every mode over the block's rows.
    {
        let mut codes = code_pool.pop().unwrap_or_default();
        codes.clear();
        codes.extend_from_slice(x.rows_slice(r0, r1));
        let mut slots = slot_pool.pop().unwrap_or_default();
        slots.clear();
        slots.extend(0..n_modes);
        cur.push(Group { slots, codes });
    }

    {
        for (li, layer) in net.layers.iter().enumerate() {
            let kern = &kernels[li];
            let c_out = layer.weights.c_out;
            let last = li + 1 == depth;
            for g in cur.iter() {
                gmodes.clear();
                gmodes.extend(g.slots.iter().map(|&s| modes[s]));
                if plan.is_none() || plan_modes.as_slice() != gmodes.as_slice() {
                    plan_modes.clear();
                    plan_modes.extend_from_slice(gmodes);
                    *plan = Some(ModePlan::new(gmodes));
                }
                let plan: &ModePlan = plan.as_ref().expect("memoized group plan");
                let gn = g.slots.len();
                while outs.len() < gn {
                    outs.push(Vec::new());
                }
                for o in outs[..gn].iter_mut() {
                    o.clear();
                    o.resize(rows * c_out, 0.0);
                }
                wide.clear();
                wide.resize(rows * c_out, 0.0);
                gstats.clear();
                gstats.resize(gn, OverflowStats::default());
                {
                    // Single-mode groups (every group of a serving plan)
                    // borrow their one output slice on the stack; only
                    // multi-mode fan-outs pay for a ref list.
                    let mut one: [&mut [f32]; 1];
                    let mut many: Vec<&mut [f32]>;
                    let refs: &mut [&mut [f32]] = if gn == 1 {
                        one = [outs[0].as_mut_slice()];
                        &mut one
                    } else {
                        many = outs[..gn].iter_mut().map(|v| v.as_mut_slice()).collect();
                        &mut many
                    };
                    simulate_block(
                        kern,
                        &layer.weights,
                        plan,
                        &g.codes,
                        rows,
                        layer.in_quant.scale,
                        sim,
                        refs,
                        wide,
                        gstats,
                        if li == 0 { l0 } else { None },
                    );
                }
                for (gi, &slot) in g.slots.iter().enumerate() {
                    stats[li * n_modes + slot].merge(&gstats[gi]);
                }
                if last {
                    // The wide output is shared by the whole group: computed
                    // once above, copied per slot.
                    for (gi, &slot) in g.slots.iter().enumerate() {
                        out[slot].copy_from_slice(&outs[gi]);
                        out_wide[slot].copy_from_slice(wide);
                    }
                } else {
                    // Requantize each slot onto the next boundary's grid
                    // (buffer to buffer, no Tensor round trip) and regroup:
                    // slots whose register models produced identical
                    // activations stay fused.
                    let nq = &net.layers[li + 1].in_quant;
                    for (gi, &slot) in g.slots.iter().enumerate() {
                        nq.quantize_slice_into(&outs[gi], qbuf);
                        match next.iter().position(|g2| g2.codes == *qbuf) {
                            Some(gi2) => next[gi2].slots.push(slot),
                            None => {
                                let mut codes = code_pool.pop().unwrap_or_default();
                                std::mem::swap(&mut codes, qbuf);
                                let mut slots = slot_pool.pop().unwrap_or_default();
                                slots.clear();
                                slots.push(slot);
                                next.push(Group { slots, codes });
                            }
                        }
                    }
                }
            }
            for g in cur.drain(..) {
                code_pool.push(g.codes);
                slot_pool.push(g.slots);
            }
            std::mem::swap(cur, next);
        }
    }
}

/// The multi-threaded network execute shared by [`NetworkPlan`] and
/// [`SharedNetworkPlan`]: fan row blocks over scoped workers through the
/// atomic queue and merge per-block stats in block order. `kernels[i]` must
/// have been built from `net.layers[i].weights`.
fn net_execute_threads(
    net: &QNetwork,
    modes: &[AccMode],
    kernels: &[LayerKernel],
    x: &IntMatrix,
    threads: usize,
    l0: Option<&[i64]>,
) -> Vec<NetworkStats> {
    let batch = x.rows();
    assert_eq!(
        x.cols(),
        net.input_dim(),
        "input cols {} vs network input dim {}",
        x.cols(),
        net.input_dim()
    );
    {
        let n_modes = modes.len();
        let depth = net.layers.len();
        let c_last = net.output_dim();
        let c0 = net.layers.first().map_or(0, |l| l.weights.c_out);
        debug_assert!(l0.is_none_or(|a| depth >= 1 && a.len() == batch * c0));
        if n_modes == 0 {
            return Vec::new();
        }

        let mut out_bufs: Vec<Vec<f32>> =
            (0..n_modes).map(|_| vec![0f32; batch * c_last]).collect();
        let mut wide_bufs: Vec<Vec<f32>> =
            (0..n_modes).map(|_| vec![0f32; batch * c_last]).collect();
        let mut merged: Vec<Vec<OverflowStats>> =
            (0..n_modes).map(|_| vec![OverflowStats::default(); depth]).collect();

        if batch > 0 {
            let t = threads.max(1).min(batch);
            let block_rows = row_block_size(batch, t);
            let n_blocks = batch.div_ceil(block_rows);
            let elems = block_rows * c_last;
            let stats_len = depth * n_modes;
            let mut block_stats = vec![OverflowStats::default(); n_blocks * stats_len];
            let tasks: Vec<Mutex<Option<NetTask>>> = {
                let mut out_iters: Vec<_> = if elems > 0 {
                    out_bufs.iter_mut().map(|b| b.chunks_mut(elems)).collect()
                } else {
                    Vec::new()
                };
                let mut wide_iters: Vec<_> = if elems > 0 {
                    wide_bufs.iter_mut().map(|b| b.chunks_mut(elems)).collect()
                } else {
                    Vec::new()
                };
                let mut stats_iter = block_stats.chunks_mut(stats_len);
                (0..n_blocks)
                    .map(|bi| {
                        let r0 = bi * block_rows;
                        let r1 = (r0 + block_rows).min(batch);
                        let (out, out_wide) = if elems > 0 {
                            (
                                out_iters
                                    .iter_mut()
                                    .map(|it| it.next().expect("out block slice"))
                                    .collect(),
                                wide_iters
                                    .iter_mut()
                                    .map(|it| it.next().expect("wide block slice"))
                                    .collect(),
                            )
                        } else {
                            // c_out_last == 0: outputs are empty but layer
                            // stats still accumulate.
                            (
                                (0..n_modes).map(|_| Default::default()).collect(),
                                (0..n_modes).map(|_| Default::default()).collect(),
                            )
                        };
                        Mutex::new(Some(NetTask {
                            r0,
                            r1,
                            out,
                            out_wide,
                            stats: stats_iter.next().expect("stats block slice"),
                            l0: l0.map(|a| &a[r0 * c0..r1 * c0]),
                        }))
                    })
                    .collect()
            };
            run_queue(tasks, t, NetWorker::default, |ws, task| {
                let NetTask { r0, r1, mut out, mut out_wide, stats, l0 } = task;
                net_forward_block(
                    net, modes, kernels, x, r0, r1, ws, &mut out, &mut out_wide, stats, l0,
                );
            });
            for bi in 0..n_blocks {
                let base = bi * stats_len;
                for (mi, per_mode) in merged.iter_mut().enumerate() {
                    for (li, slot) in per_mode.iter_mut().enumerate() {
                        slot.merge(&block_stats[base + li * n_modes + mi]);
                    }
                }
            }
        }

        out_bufs
            .into_iter()
            .zip(wide_bufs)
            .zip(merged)
            .map(|((data, wide), layer_stats)| NetworkStats {
                out: Tensor::new(vec![batch, c_last], data),
                out_wide: Tensor::new(vec![batch, c_last], wide),
                layer_stats,
            })
            .collect()
    }
}

/// Opaque warm scratch arena for [`SharedNetworkPlan::execute_warm`]: a
/// per-caller (e.g. per batch worker / per connection) [`NetWorker`] whose
/// batch-sized buffers survive across calls, so steady-state serving
/// allocates only its output tensors.
#[derive(Default)]
pub struct NetScratch(NetWorker);

/// An owning, thread-shareable [`NetworkPlan`]: the network travels as an
/// [`Arc`] and every kernel context is owned data, so one plan built at
/// model-load time can be cached and executed concurrently from many server
/// threads (`Send + Sync`, no locking — execution never mutates the plan).
///
/// Executions delegate to the exact machinery [`NetworkPlan`] runs
/// ([`net_execute_threads`] over the same [`LayerKernel`]s), so results are
/// bit-identical to a borrowing plan over the same network — outputs and
/// every [`OverflowStats`] counter.
pub struct SharedNetworkPlan {
    net: Arc<QNetwork>,
    modes: Vec<AccMode>,
    kernels: Vec<LayerKernel>,
}

impl SharedNetworkPlan {
    pub fn new(net: Arc<QNetwork>, modes: &[AccMode]) -> SharedNetworkPlan {
        SharedNetworkPlan::new_with_path(net, modes, None)
    }

    /// [`SharedNetworkPlan::new`] with every layer's GEMM kernel dispatch
    /// pinned (`None` = auto per layer).
    pub fn new_with_path(
        net: Arc<QNetwork>,
        modes: &[AccMode],
        path: Option<KernelPath>,
    ) -> SharedNetworkPlan {
        let kernels = net_kernels(&net, path);
        SharedNetworkPlan { net, modes: modes.to_vec(), kernels }
    }

    /// The shared network the plan executes.
    pub fn net(&self) -> &QNetwork {
        &self.net
    }

    pub fn modes(&self) -> &[AccMode] {
        &self.modes
    }

    /// Per-layer plan-time kernel dispatch decisions, in layer order.
    pub fn kernel_choices(&self) -> Vec<KernelChoice> {
        self.kernels.iter().map(|k| k.choice).collect()
    }

    pub fn depth(&self) -> usize {
        self.net.layers.len()
    }

    /// Execute over a batch with an explicit worker count.
    pub fn execute_threads(&self, x: &IntMatrix, threads: usize) -> Vec<NetworkStats> {
        net_execute_threads(&self.net, &self.modes, &self.kernels, x, threads, None)
    }

    /// Execute over a batch, choosing the worker count from the network's
    /// simulated MAC grid exactly as [`NetworkPlan::execute`] does.
    pub fn execute(&self, x: &IntMatrix) -> Vec<NetworkStats> {
        self.execute_threads(
            x,
            worker_count(x.rows(), self.net.macs_per_row(), 1, self.modes.len()),
        )
    }

    /// Execute the whole batch inline on the calling thread through a warm
    /// caller-owned scratch arena: the serving path, where each batch
    /// worker keeps one [`NetScratch`] hot across micro-batches (workers
    /// are already the parallelism axis, so per-call thread fan-out would
    /// only fight them). Bit-identical to [`Self::execute`] at any thread
    /// count by the engine's determinism contract.
    pub fn execute_warm(&self, x: &IntMatrix, scratch: &mut NetScratch) -> Vec<NetworkStats> {
        let batch = x.rows();
        assert_eq!(
            x.cols(),
            self.net.input_dim(),
            "input cols {} vs network input dim {}",
            x.cols(),
            self.net.input_dim()
        );
        let n_modes = self.modes.len();
        if n_modes == 0 {
            return Vec::new();
        }
        let depth = self.net.layers.len();
        let c_last = self.net.output_dim();
        let mut out_bufs: Vec<Vec<f32>> =
            (0..n_modes).map(|_| vec![0f32; batch * c_last]).collect();
        let mut wide_bufs: Vec<Vec<f32>> =
            (0..n_modes).map(|_| vec![0f32; batch * c_last]).collect();
        let mut stats = vec![OverflowStats::default(); depth * n_modes];
        if batch > 0 {
            let mut out: Vec<&mut [f32]> =
                out_bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            let mut out_wide: Vec<&mut [f32]> =
                wide_bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            net_forward_block(
                &self.net,
                &self.modes,
                &self.kernels,
                x,
                0,
                batch,
                &mut scratch.0,
                &mut out,
                &mut out_wide,
                &mut stats,
                None,
            );
        }
        out_bufs
            .into_iter()
            .zip(wide_bufs)
            .enumerate()
            .map(|(mi, (data, wide))| NetworkStats {
                out: Tensor::new(vec![batch, c_last], data),
                out_wide: Tensor::new(vec![batch, c_last], wide),
                layer_stats: (0..depth).map(|li| stats[li * n_modes + mi].clone()).collect(),
            })
            .collect()
    }

    /// [`Self::execute_warm`] for single-mode plans, writing into
    /// caller-owned buffers instead of allocating output tensors: `out` and
    /// `out_wide` become the `[batch, output_dim]` flat outputs and
    /// `layer_stats` one [`OverflowStats`] per layer in depth order. With
    /// warm buffers and warm scratch the whole call is allocation-free —
    /// the serve worker's zero-alloc contract (`tests/serve_alloc.rs`).
    /// Bit-identical to [`Self::execute_warm`] (same [`net_forward_block`]
    /// core, same traversal).
    pub fn execute_warm_into(
        &self,
        x: &IntMatrix,
        scratch: &mut NetScratch,
        out: &mut Vec<f32>,
        out_wide: &mut Vec<f32>,
        layer_stats: &mut Vec<OverflowStats>,
    ) {
        assert_eq!(self.modes.len(), 1, "execute_warm_into serves single-mode plans");
        assert_eq!(
            x.cols(),
            self.net.input_dim(),
            "input cols {} vs network input dim {}",
            x.cols(),
            self.net.input_dim()
        );
        let batch = x.rows();
        let c_last = self.net.output_dim();
        let depth = self.net.layers.len();
        out.clear();
        out.resize(batch * c_last, 0.0);
        out_wide.clear();
        out_wide.resize(batch * c_last, 0.0);
        layer_stats.clear();
        layer_stats.resize(depth, OverflowStats::default());
        if batch > 0 {
            let mut o: [&mut [f32]; 1] = [out.as_mut_slice()];
            let mut w: [&mut [f32]; 1] = [out_wide.as_mut_slice()];
            net_forward_block(
                &self.net,
                &self.modes,
                &self.kernels,
                x,
                0,
                batch,
                &mut scratch.0,
                &mut o,
                &mut w,
                layer_stats,
                None,
            );
        }
    }
}

/// Forward one integer batch through a whole quantized network under *all*
/// requested accumulator models, returning one [`NetworkStats`] per mode
/// (same order). The network-level analogue of [`qlinear_forward_multi`]:
///
/// ```ignore
/// let modes: Vec<_> = (8..=32).map(|p| AccMode::Wrap { p_bits: p }).collect();
/// let per_mode = network_forward_multi(&net, &x_int, &modes);
/// for (mode, r) in modes.iter().zip(&per_mode) {
///     for (depth, s) in r.layer_stats.iter().enumerate() { /* per-layer rates */ }
/// }
/// ```
pub fn network_forward_multi(
    net: &QNetwork,
    x: &IntMatrix,
    modes: &[AccMode],
) -> Vec<NetworkStats> {
    NetworkPlan::new(net, modes).execute(x)
}

#[cfg(test)]
mod tests {
    use super::super::dot::dot_accumulate;
    use super::super::matmul::qlinear_forward_ref;
    use super::*;
    use crate::rng::Rng;

    fn all_modes(p: u32) -> Vec<AccMode> {
        vec![
            AccMode::Wide,
            AccMode::Wrap { p_bits: p },
            AccMode::Saturate { p_bits: p },
            AccMode::SaturateFinal { p_bits: p },
        ]
    }

    #[test]
    fn min_safe_p_matches_acc_max() {
        use crate::quant::bounds::acc_max;
        for l1 in [0i128, 1, 7, 127, 128, 1000, 1 << 20] {
            for xmax in [0i64, 1, 3, 255] {
                let p = min_safe_p(l1, xmax);
                let worst = l1 * xmax as i128;
                if p <= 63 {
                    assert!(worst <= acc_max(p) as i128, "l1={l1} xmax={xmax} p={p}");
                }
                if p > 2 && worst > 0 {
                    assert!(
                        worst > acc_max(p - 1) as i128,
                        "p not minimal: l1={l1} xmax={xmax} p={p}"
                    );
                }
            }
        }
    }

    #[test]
    fn safe_prefix_agrees_with_per_channel_gate() {
        // The stage-1 partition must be exactly the old per-(row, channel)
        // test `min_safe_p(l1, xmax) <= min_p` applied along the sorted
        // order, for every xmax and every plan width.
        let mut rng = Rng::new(0x51);
        for _ in 0..200 {
            let c_out = 1 + rng.below(12);
            let k = rng.below(20);
            let w = QTensor {
                codes: (0..c_out * k).map(|_| rng.below(2001) as i64 - 1000).collect(),
                scales: vec![1.0; c_out],
                bias: vec![0.0; c_out],
                c_out,
                k,
            };
            let kern = LayerKernel::new(&w);
            for xmax in [0i64, 1, 3, 255, 1 << 20] {
                for min_p in [None, Some(1), Some(2), Some(8), Some(16), Some(63), Some(64)] {
                    let n_safe = kern.safe_prefix(xmax, min_p);
                    for (ci, &c) in kern.order.iter().enumerate() {
                        let safe = match min_p {
                            None => true,
                            Some(p) => min_safe_p(kern.row_l1[c], xmax) <= p,
                        };
                        assert_eq!(safe, ci < n_safe, "ci={ci} xmax={xmax} min_p={min_p:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn fused_matches_sequential_per_mode() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let k = 1 + rng.below(100);
            let x: Vec<i64> = (0..k).map(|_| rng.below(256) as i64).collect();
            let w: Vec<i64> = (0..k).map(|_| rng.below(255) as i64 - 127).collect();
            let mut modes = Vec::new();
            for p in [4, 8, 12, 16, 24, 32] {
                modes.extend(all_modes(p));
            }
            let fused = dot_accumulate_multi(&x, &w, &modes);
            for (mi, mode) in modes.iter().enumerate() {
                let seq = dot_accumulate(&x, &w, *mode);
                assert_eq!(fused[mi], seq, "{mode:?}");
            }
        }
    }

    #[test]
    fn duplicate_and_unsorted_modes_keep_slots() {
        let x = vec![100i64; 8];
        let w = vec![1i64; 8];
        let modes = [
            AccMode::Wrap { p_bits: 16 },
            AccMode::Wrap { p_bits: 8 },
            AccMode::Wrap { p_bits: 8 },
            AccMode::Wide,
        ];
        let r = dot_accumulate_multi(&x, &w, &modes);
        assert_eq!(r[0], dot_accumulate(&x, &w, modes[0]));
        assert_eq!(r[1], dot_accumulate(&x, &w, modes[1]));
        assert_eq!(r[1], r[2]);
        assert_eq!(r[3].value, 800);
    }

    fn toy_layer() -> QTensor {
        // channel 0: tiny weights (safe at 8 bits for binary inputs);
        // channel 1: huge weights (overflow at 8 bits).
        let w = Tensor::new(vec![2, 4], vec![1.0, -1.0, 2.0, 1.0, 100.0, 100.0, 100.0, 100.0]);
        let s = Tensor::new(vec![2, 1], vec![0.5, 0.25]);
        let b = Tensor::from_vec(vec![0.1, -0.2]);
        QTensor::from_export(&w, &s, &b)
    }

    #[test]
    fn layer_multi_matches_reference_with_gating_and_threads() {
        let w = toy_layer();
        let x = IntMatrix::from_rows(&[vec![1, 0, 1, 1], vec![1, 1, 1, 1], vec![0, 0, 0, 0]]);
        let modes: Vec<AccMode> = (4..=20)
            .flat_map(|p| [AccMode::Wrap { p_bits: p }, AccMode::Saturate { p_bits: p }])
            .collect();
        let plan = LayerPlan::new(&w, &modes);
        for threads in [1, 2, 7] {
            let multi = plan.execute_threads(&x, 0.5, threads);
            for (mi, mode) in modes.iter().enumerate() {
                let r = qlinear_forward_ref(&x, 0.5, &w, *mode);
                assert_eq!(multi[mi].out.data(), r.out.data(), "{mode:?} t={threads}");
                assert_eq!(multi[mi].out_wide.data(), r.out_wide.data(), "{mode:?}");
                assert_eq!(multi[mi].stats.overflow_events, r.stats.overflow_events, "{mode:?}");
                assert_eq!(multi[mi].stats.dots_overflowed, r.stats.dots_overflowed, "{mode:?}");
                assert_eq!(multi[mi].stats.abs_err_sum, r.stats.abs_err_sum, "{mode:?}");
                assert_eq!(multi[mi].stats.dots, r.stats.dots);
                assert_eq!(multi[mi].stats.macs, r.stats.macs);
            }
        }
    }

    #[test]
    fn network_plan_matches_composed_reference() {
        use crate::model::{network_forward_ref, NetSpec, QNetwork, SynthQuant};
        // Unconstrained weights at low P: overflow actually happens, so
        // per-mode activation streams genuinely diverge before the last
        // layer and the group-splitting path is exercised.
        let spec = NetSpec {
            widths: vec![12, 9, 6, 4],
            m_bits: 5,
            n_bits: 4,
            p_bits: 10,
            x_signed: false,
            quant: SynthQuant::Affine,
        };
        let mut net = QNetwork::synthesize(&spec, 21).unwrap();
        let sample =
            Tensor::new(vec![7, 12], (0..84).map(|i| ((i * 13) % 11) as f32 * 0.09).collect());
        net.calibrate(&sample);
        let x = net.layers[0].in_quant.quantize(&sample);

        let modes: Vec<AccMode> = vec![
            AccMode::Wide,
            AccMode::Wrap { p_bits: 8 },
            AccMode::Wrap { p_bits: 12 },
            AccMode::Saturate { p_bits: 8 },
            AccMode::SaturateFinal { p_bits: 8 },
            AccMode::Wrap { p_bits: 8 }, // duplicate keeps its own slot
        ];
        let plan = NetworkPlan::new(&net, &modes);
        for threads in [1, 2, 7] {
            let multi = plan.execute_threads(&x, threads);
            assert_eq!(multi.len(), modes.len());
            for (mi, mode) in modes.iter().enumerate() {
                let r = network_forward_ref(&net, &x, *mode);
                assert_eq!(multi[mi].out.data(), r.out.data(), "{mode:?} t={threads}");
                assert_eq!(multi[mi].out_wide.data(), r.out_wide.data(), "{mode:?}");
                assert_eq!(multi[mi].layer_stats.len(), r.layer_stats.len());
                for (li, (a, b)) in
                    multi[mi].layer_stats.iter().zip(&r.layer_stats).enumerate()
                {
                    assert_eq!(a.overflow_events, b.overflow_events, "{mode:?} layer {li}");
                    assert_eq!(a.dots_overflowed, b.dots_overflowed, "{mode:?} layer {li}");
                    assert_eq!(a.abs_err_sum, b.abs_err_sum, "{mode:?} layer {li}");
                    assert_eq!(a.dots, b.dots, "{mode:?} layer {li}");
                    assert_eq!(a.macs, b.macs, "{mode:?} layer {li}");
                }
            }
            // duplicate modes resolve to identical results
            assert_eq!(multi[1].out.data(), multi[5].out.data());
        }
    }

    #[test]
    fn network_plan_a2q_net_never_splits_from_wide() {
        use crate::model::{NetSpec, QNetwork, SynthQuant};
        let spec = NetSpec {
            widths: vec![10, 8, 3],
            m_bits: 4,
            n_bits: 3,
            p_bits: 12,
            x_signed: false,
            quant: SynthQuant::A2q,
        };
        let mut net = QNetwork::synthesize(&spec, 2).unwrap();
        let sample =
            Tensor::new(vec![4, 10], (0..40).map(|i| (i % 6) as f32 * 0.15).collect());
        net.calibrate(&sample);
        let x = net.layers[0].in_quant.quantize(&sample);
        // At the A2Q target width the theorem holds per layer: zero overflow
        // events anywhere, and the wrap output equals the wide output.
        let modes = [AccMode::Wide, AccMode::Wrap { p_bits: 12 }];
        let r = network_forward_multi(&net, &x, &modes);
        for s in &r[1].layer_stats {
            assert_eq!(s.overflow_events, 0);
        }
        assert_eq!(r[0].out.data(), r[1].out.data());
        assert_eq!(r[1].out.data(), r[1].out_wide.data());
    }

    #[test]
    fn safe_channels_report_zero_overflow() {
        // Σ|w| * max|x| = 5 * 1 = 5 <= acc_max(4) = 7: safe at every P >= 4.
        let w = QTensor::from_export(
            &Tensor::new(vec![1, 4], vec![1.0, -2.0, 1.0, 1.0]),
            &Tensor::new(vec![1, 1], vec![1.0]),
            &Tensor::from_vec(vec![0.0]),
        );
        let x = IntMatrix::from_rows(&[vec![1, 1, 1, 1]]);
        let modes = [AccMode::Wrap { p_bits: 4 }, AccMode::Saturate { p_bits: 5 }];
        for st in qlinear_forward_multi(&x, 1.0, &w, &modes) {
            assert_eq!(st.stats.overflow_events, 0);
            assert_eq!(st.out.data(), st.out_wide.data());
        }
    }

    #[test]
    fn kernel_choice_reports_forced_path_sparsity_and_pack_fallback() {
        let w = toy_layer(); // dense (no zero codes)
        let modes = [AccMode::Wide, AccMode::Wrap { p_bits: 16 }];
        for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
            let plan = LayerPlan::new_with_path(&w, &modes, Some(path));
            let c = plan.kernel_choice();
            assert_eq!(c.path, path);
            assert_eq!(c.sparsity, w.sparsity());
            assert!(!c.pack_fallback);
        }
        // Codes beyond i32: pack falls back, and the choice says so.
        let big = QTensor {
            codes: vec![1, i32::MAX as i64 + 1],
            scales: vec![1.0],
            bias: vec![0.0],
            c_out: 1,
            k: 2,
        };
        let plan = LayerPlan::new(&big, &modes);
        let c = plan.kernel_choice();
        assert!(c.pack_fallback);
        assert_eq!(c.path, KernelPath::Scalar);
        assert_eq!(c.sparsity, 0.0);
    }

    #[test]
    fn shared_plan_matches_borrowing_plan_including_warm_scratch() {
        use crate::testutil::psweep_network;
        let (net, x) = psweep_network(&[10, 8, 4], 6, 3);
        let modes = [
            AccMode::Wide,
            AccMode::Wrap { p_bits: 12 },
            AccMode::Saturate { p_bits: 10 },
            AccMode::SaturateFinal { p_bits: 12 },
        ];
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SharedNetworkPlan>();
        let want = NetworkPlan::new(&net, &modes).execute_threads(&x, 2);
        let shared = SharedNetworkPlan::new(Arc::new(net), &modes);
        let mut scratch = NetScratch::default();
        // Threaded, warm, and warm-again (reused arena) must all be
        // bit-identical to the borrowing plan: outputs and every counter.
        let runs = [
            ("threads", shared.execute_threads(&x, 3)),
            ("warm", shared.execute_warm(&x, &mut scratch)),
            ("warm reuse", shared.execute_warm(&x, &mut scratch)),
        ];
        for (tag, got) in &runs {
            assert_eq!(got.len(), want.len(), "{tag}");
            for (mi, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.out.data(), w.out.data(), "{tag} mode {mi}");
                assert_eq!(g.out_wide.data(), w.out_wide.data(), "{tag} mode {mi}");
                assert_eq!(g.layer_stats.len(), w.layer_stats.len(), "{tag} mode {mi}");
                for (li, (a, b)) in g.layer_stats.iter().zip(&w.layer_stats).enumerate() {
                    assert_eq!(a.overflow_events, b.overflow_events, "{tag} {mi} layer {li}");
                    assert_eq!(a.dots_overflowed, b.dots_overflowed, "{tag} {mi} layer {li}");
                    assert_eq!(a.abs_err_sum, b.abs_err_sum, "{tag} {mi} layer {li}");
                    assert_eq!(a.dots, b.dots, "{tag} {mi} layer {li}");
                    assert_eq!(a.macs, b.macs, "{tag} {mi} layer {li}");
                    assert_eq!(a.outputs, b.outputs, "{tag} {mi} layer {li}");
                }
            }
        }
    }

    #[test]
    fn layer_plan_forced_kernel_paths_are_bit_exact_and_thread_invariant() {
        let mut rng = Rng::new(0xA2B);
        // ~97% sparse constrained layer plus the dense toy layer: both must
        // agree with the scalar-forced plan on every path, bitwise,
        // including all stats, at several thread counts.
        let tight = crate::testutil::psweep_constrained_layer(16, 96, 14, 8, 3);
        assert!(tight.sparsity() > 0.5, "fixture should be sparse");
        let dense = toy_layer();
        for w in [&tight, &dense] {
            let x = IntMatrix::from_flat(
                5,
                w.k,
                (0..5 * w.k).map(|_| rng.below(256) as i64).collect(),
            );
            let modes: Vec<AccMode> = (8..=24).map(|p| AccMode::Wrap { p_bits: p }).collect();
            let base = LayerPlan::new_with_path(w, &modes, Some(KernelPath::Scalar))
                .execute_threads(&x, 1.0, 1);
            for path in [KernelPath::Simd, KernelPath::SparseSimd] {
                let plan = LayerPlan::new_with_path(w, &modes, Some(path));
                for threads in [1, 2, 7] {
                    let multi = plan.execute_threads(&x, 1.0, threads);
                    for (mi, mode) in modes.iter().enumerate() {
                        assert_eq!(
                            multi[mi].out.data(),
                            base[mi].out.data(),
                            "{path:?} {mode:?} t={threads}"
                        );
                        assert_eq!(multi[mi].out_wide.data(), base[mi].out_wide.data());
                        assert_eq!(
                            multi[mi].stats.overflow_events,
                            base[mi].stats.overflow_events
                        );
                        assert_eq!(multi[mi].stats.abs_err_sum, base[mi].stats.abs_err_sum);
                    }
                }
            }
        }
    }
}
