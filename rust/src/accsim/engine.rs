//! The fused multi-P kernel engine: one traversal of the MACs simulates
//! *every* requested accumulator width at once, provably-safe channels skip
//! register simulation entirely, and the batch grid fans out across scoped
//! threads. This is the hot path behind every P-sweep figure (Fig. 2/4/8);
//! before/after throughput is tracked in EXPERIMENTS.md §Perf and
//! BENCH_accsim.json.
//!
//! Three stacked optimizations over the per-P scalar walk
//! ([`super::matmul::qlinear_forward_ref`]):
//!
//! 1. **Multi-P fusion** — the dominant cost of the scalar path is streaming
//!    `x` and `w` through memory once *per width*; a 25-width sweep reads the
//!    same bytes 25 times. The fused kernel carries one register per
//!    requested width, so K extra widths cost a few ALU ops each (wrap is a
//!    shift/sign-extend pair, saturate a compare/clamp) instead of a full
//!    memory pass.
//! 2. **Bound-gated fast paths** — the paper's own overflow bound (Eq. 4-5;
//!    also arXiv:2301.13376 §3): every intermediate partial sum of `x . w`
//!    is bounded by `Σ|w_i| * max|x_i|`, so a channel whose bound fits in
//!    `2^(P-1) - 1` can *never* overflow a P-bit register, under any input
//!    and any MAC ordering. The planner precomputes per-channel `Σ|w_int|`;
//!    at execution each (row, channel) pair derives the smallest safe width
//!    and registers at or above it bypass simulation — when every width is
//!    safe the whole dot product collapses to a plain autovectorizable wide
//!    dot over the flat slices.
//! 3. **Scoped-thread parallelism** — rows of the `batch x c_out` grid are
//!    chunked across `std::thread::scope` workers (dot products are
//!    independent; no new dependencies). Per-worker [`OverflowStats`] are
//!    merged in chunk order: outputs and the integer counters are
//!    bit-identical to the sequential walk regardless of thread count, and
//!    `abs_err_sum` — a sum of integer-valued f64 terms — is exact (hence
//!    also order-independent) while the total stays below 2^53; past that
//!    the chunked merge may round differently from a sequential walk.
//!
//! All kernels are property-tested bit-exact against the per-P reference
//! (`rust/tests/property_invariants.rs`).
//!
//! On top of the single-layer [`LayerPlan`], the [`NetworkPlan`] streams a
//! batch through a whole [`crate::model::QNetwork`] in one pass: rows are
//! chunked across scoped threads *once* and each worker carries its chunk
//! through every layer (simulate -> requantize -> next layer), so there is
//! no per-layer barrier. Within a chunk, modes whose propagated activations
//! are still byte-identical (no register has diverged from the wide result
//! yet — always true at layer 0, and at depth for every provably-safe or
//! wide-enough register) share a single fused MAC traversal; a mode only
//! pays for its own traversal after its register model has actually
//! corrupted an activation. The safe-channel bound gate is applied per
//! layer from the *propagated* per-row activation max — not a global
//! worst case — so deeper layers whose activations shrink under
//! requantization gate more channels onto the wide fast path.

use super::dot::{range, AccMode, DotResult};
use super::intmat::{abs_max_of, IntMatrix};
use super::matmul::MatmulStats;
use super::stats::OverflowStats;
use crate::model::QNetwork;
use crate::quant::QTensor;
use crate::tensor::Tensor;

/// One per-MAC simulated register of the fused plan.
#[derive(Clone, Copy, Debug)]
struct Reg {
    /// Index into the caller's `modes` array.
    slot: usize,
    p_bits: u32,
    /// Shift for the wrap family: `64 - p_bits`.
    sh: u32,
    /// Clamp rails for the saturate family.
    lo: i64,
    hi: i64,
}

/// A mode list partitioned into register families, sorted so the bound gate
/// can activate a prefix (narrower widths overflow first).
#[derive(Clone, Debug)]
pub struct ModePlan {
    modes: Vec<AccMode>,
    /// Wraparound registers, ascending `p_bits`.
    wrap: Vec<Reg>,
    /// Inner-loop saturating registers, ascending `p_bits`.
    sat: Vec<Reg>,
    /// Modes resolved from the exact sum after the traversal: `Wide` and
    /// `SaturateFinal` never need a per-MAC register.
    finals: Vec<(usize, AccMode)>,
}

impl ModePlan {
    pub fn new(modes: &[AccMode]) -> ModePlan {
        let mut wrap = Vec::new();
        let mut sat = Vec::new();
        let mut finals = Vec::new();
        for (slot, mode) in modes.iter().enumerate() {
            match *mode {
                AccMode::Wide | AccMode::SaturateFinal { .. } => finals.push((slot, *mode)),
                AccMode::Wrap { p_bits } => {
                    debug_assert!((1..=64).contains(&p_bits), "wrap p_bits {p_bits}");
                    wrap.push(Reg { slot, p_bits, sh: 64 - p_bits, lo: 0, hi: 0 });
                }
                AccMode::Saturate { p_bits } => {
                    let (lo, hi) = range(p_bits);
                    sat.push(Reg { slot, p_bits, sh: 0, lo, hi });
                }
            }
        }
        wrap.sort_by_key(|r| r.p_bits);
        sat.sort_by_key(|r| r.p_bits);
        ModePlan { modes: modes.to_vec(), wrap, sat, finals }
    }

    pub fn modes(&self) -> &[AccMode] {
        &self.modes
    }

    /// Number of per-MAC registers a scratch buffer must hold.
    fn scratch_len(&self) -> usize {
        self.wrap.len().max(self.sat.len())
    }
}

/// Per-worker register scratch (reused across every dot product).
struct Scratch {
    wrap_acc: Vec<i64>,
    wrap_ovf: Vec<u32>,
    sat_acc: Vec<i64>,
    sat_ovf: Vec<u32>,
}

impl Scratch {
    fn for_plan(plan: &ModePlan) -> Scratch {
        let n = plan.scratch_len();
        Scratch {
            wrap_acc: vec![0; n],
            wrap_ovf: vec![0; n],
            sat_acc: vec![0; n],
            sat_ovf: vec![0; n],
        }
    }
}

/// Smallest accumulator width that provably cannot overflow given the
/// channel's `Σ|w_int|` and the row's `max|x|`: every intermediate partial
/// sum satisfies `|s| <= l1 * xmax`, so width P is safe iff
/// `l1 * xmax <= 2^(P-1) - 1`. Returns 64 (wider than any simulated
/// register) when no width up to 63 is safe.
#[inline]
pub fn min_safe_p(l1: i128, xmax: i64) -> u32 {
    debug_assert!(l1 >= 0 && xmax >= 0);
    let worst = l1 * xmax as i128;
    if worst == 0 {
        return 1;
    }
    let bits = 128 - (worst as u128).leading_zeros();
    (bits + 1).min(64)
}

/// One traversal of the MACs of `x . w`, updating every register whose
/// width is below `p_safe`; registers at or above `p_safe` (and the
/// `Wide`/`SaturateFinal` modes) are resolved from the exact sum. Writes one
/// [`DotResult`] per plan mode into `out` and returns the wide value.
fn fused_dot(
    plan: &ModePlan,
    x: &[i64],
    w: &[i64],
    p_safe: u32,
    scratch: &mut Scratch,
    out: &mut [DotResult],
) -> i64 {
    debug_assert_eq!(x.len(), w.len());
    debug_assert_eq!(out.len(), plan.modes.len());
    let nw = plan.wrap.partition_point(|r| r.p_bits < p_safe);
    let ns = plan.sat.partition_point(|r| r.p_bits < p_safe);

    let mut wide = 0i64;
    if nw == 0 && ns == 0 {
        // Bound-gated fast path: nothing can overflow, so the whole dot
        // product is a plain wide dot the compiler can vectorize.
        for (xi, wi) in x.iter().zip(w) {
            wide += xi * wi;
        }
    } else {
        let wrap_active = &plan.wrap[..nw];
        let sat_active = &plan.sat[..ns];
        let wrap_acc = &mut scratch.wrap_acc[..nw];
        let wrap_ovf = &mut scratch.wrap_ovf[..nw];
        let sat_acc = &mut scratch.sat_acc[..ns];
        let sat_ovf = &mut scratch.sat_ovf[..ns];
        wrap_acc.fill(0);
        wrap_ovf.fill(0);
        sat_acc.fill(0);
        sat_ovf.fill(0);

        for (xi, wi) in x.iter().zip(w) {
            let prod = xi * wi; // exact: multiplier output is full-width
            wide += prod;
            // Wraparound family: shift/sign-extend per width (~2 ops + an
            // overflow compare), no branches.
            for (j, r) in wrap_active.iter().enumerate() {
                let t = wrap_acc[j] + prod;
                let v = t.wrapping_shl(r.sh) >> r.sh;
                wrap_ovf[j] += (v != t) as u32;
                wrap_acc[j] = v;
            }
            // Saturating family: clamp per width.
            for (j, r) in sat_active.iter().enumerate() {
                let t = sat_acc[j] + prod;
                sat_ovf[j] += ((t < r.lo) | (t > r.hi)) as u32;
                sat_acc[j] = t.clamp(r.lo, r.hi);
            }
        }

        for (j, r) in wrap_active.iter().enumerate() {
            out[r.slot] = DotResult { value: scratch.wrap_acc[j], overflows: scratch.wrap_ovf[j] };
        }
        for (j, r) in sat_active.iter().enumerate() {
            out[r.slot] = DotResult { value: scratch.sat_acc[j], overflows: scratch.sat_ovf[j] };
        }
    }

    // Bound-safe registers: the register model is the identity, so the
    // simulated value IS the wide value with zero overflow events.
    for r in &plan.wrap[nw..] {
        out[r.slot] = DotResult { value: wide, overflows: 0 };
    }
    for r in &plan.sat[ns..] {
        out[r.slot] = DotResult { value: wide, overflows: 0 };
    }
    for (slot, mode) in &plan.finals {
        out[*slot] = match *mode {
            AccMode::Wide => DotResult { value: wide, overflows: 0 },
            AccMode::SaturateFinal { p_bits } => {
                let (lo, hi) = range(p_bits);
                let clipped = wide.clamp(lo, hi);
                DotResult { value: clipped, overflows: u32::from(clipped != wide) }
            }
            _ => unreachable!("finals only hold Wide/SaturateFinal"),
        };
    }
    wide
}

/// Fused multi-width dot-product simulation: one traversal of the MACs,
/// one [`DotResult`] per requested mode. Bit-exact against calling
/// [`super::dot::dot_accumulate`] once per mode.
pub fn dot_accumulate_multi(x: &[i64], w: &[i64], modes: &[AccMode]) -> Vec<DotResult> {
    let plan = ModePlan::new(modes);
    let mut scratch = Scratch::for_plan(&plan);
    let mut out = vec![DotResult { value: 0, overflows: 0 }; modes.len()];
    let l1: i128 = w.iter().map(|v| v.unsigned_abs() as i128).sum();
    let p_safe = min_safe_p(l1, abs_max_of(x));
    fused_dot(&plan, x, w, p_safe, &mut scratch, &mut out);
    out
}

/// Results a worker produces for its row chunk.
struct Chunk {
    /// Per-mode dequantized outputs, `rows_in_chunk * c_out` each.
    out: Vec<Vec<f32>>,
    /// Wide-register dequantized outputs for the chunk.
    out_wide: Vec<f32>,
    /// Per-mode overflow statistics for the chunk.
    stats: Vec<OverflowStats>,
}

/// The single-threaded kernel core shared by [`LayerPlan`] workers and the
/// per-layer steps of [`NetworkPlan`] workers: simulate rows `r0..r1` of
/// `x . w^T` under every mode of `plan`, gating each (row, channel) pair on
/// `row_l1[c] * max|x_row|`.
fn simulate_chunk(
    w: &QTensor,
    row_l1: &[i128],
    plan: &ModePlan,
    x: &IntMatrix,
    x_scale: f32,
    r0: usize,
    r1: usize,
) -> Chunk {
    let c_out = w.c_out;
    let k = w.k;
    let n_modes = plan.modes.len();
    let rows = r1 - r0;
    let mut out = vec![vec![0f32; rows * c_out]; n_modes];
    let mut out_wide = vec![0f32; rows * c_out];
    let mut stats = vec![OverflowStats::default(); n_modes];
    let mut scratch = Scratch::for_plan(plan);
    let mut dots = vec![DotResult { value: 0, overflows: 0 }; n_modes];

    for (ri, bi) in (r0..r1).enumerate() {
        let xb = x.row(bi);
        let xmax = abs_max_of(xb);
        for c in 0..c_out {
            let p_safe = min_safe_p(row_l1[c], xmax);
            let wide = fused_dot(plan, xb, w.row(c), p_safe, &mut scratch, &mut dots);
            let scale = w.scales[c] * x_scale;
            let idx = ri * c_out + c;
            out_wide[idx] = wide as f32 * scale + w.bias[c];
            for (mi, d) in dots.iter().enumerate() {
                stats[mi].record(k, d.overflows, d.value, wide);
                out[mi][idx] = d.value as f32 * scale + w.bias[c];
            }
        }
    }
    Chunk { out, out_wide, stats }
}

/// Chunk `batch` rows across up to `threads` scoped workers and collect
/// each worker's result **in row order**, so every stats merge downstream is
/// deterministic for a given thread count (and exact vs the sequential walk
/// while `abs_err_sum` stays below 2^53). Shared by [`LayerPlan`] and
/// [`NetworkPlan`] so the ceil-div chunk sizing and join-order contract live
/// in exactly one place.
fn par_row_chunks<C: Send>(
    batch: usize,
    threads: usize,
    run: impl Fn(usize, usize) -> C + Sync,
) -> Vec<C> {
    if threads <= 1 || batch <= 1 {
        return vec![run(0, batch)];
    }
    let t = threads.min(batch);
    let per = batch.div_euclid(t) + usize::from(batch % t != 0);
    let bounds: Vec<(usize, usize)> = (0..batch)
        .step_by(per.max(1))
        .map(|r0| (r0, (r0 + per).min(batch)))
        .collect();
    let run = &run;
    std::thread::scope(|s| {
        let handles: Vec<_> =
            bounds.iter().map(|&(r0, r1)| s.spawn(move || run(r0, r1))).collect();
        handles.into_iter().map(|h| h.join().expect("accsim worker panicked")).collect()
    })
}

/// Bounds-aware execution plan for one quantized layer: the mode partition
/// plus per-channel `Σ|w_int|` norms that drive the overflow gate.
pub struct LayerPlan<'w> {
    w: &'w QTensor,
    plan: ModePlan,
    /// Per-output-channel l1 norm of the integer codes (i128: overflow-proof
    /// for any K at any weight width).
    row_l1: Vec<i128>,
}

impl<'w> LayerPlan<'w> {
    pub fn new(w: &'w QTensor, modes: &[AccMode]) -> LayerPlan<'w> {
        // One source of truth for the per-channel norm: QTensor::row_l1
        // (Eq. 13), widened to i128 for the overflow-proof bound products.
        let row_l1 = w.row_l1().into_iter().map(|v| v as i128).collect();
        LayerPlan { w, plan: ModePlan::new(modes), row_l1 }
    }

    pub fn modes(&self) -> &[AccMode] {
        self.plan.modes()
    }

    /// Simulate rows `r0..r1` of the batch; the single-threaded kernel core.
    fn simulate_rows(&self, x: &IntMatrix, x_scale: f32, r0: usize, r1: usize) -> Chunk {
        simulate_chunk(self.w, &self.row_l1, &self.plan, x, x_scale, r0, r1)
    }

    /// Execute over a batch with an explicit worker count (tests use this to
    /// pin thread counts; [`Self::execute`] picks one automatically).
    pub fn execute_threads(&self, x: &IntMatrix, x_scale: f32, threads: usize) -> Vec<MatmulStats> {
        let batch = x.rows();
        assert_eq!(x.cols(), self.w.k, "input cols {} vs layer k {}", x.cols(), self.w.k);
        let c_out = self.w.c_out;
        let n_modes = self.plan.modes.len();

        let chunks: Vec<Chunk> =
            par_row_chunks(batch, threads, |r0, r1| self.simulate_rows(x, x_scale, r0, r1));

        // Stitch chunk outputs back into [batch, c_out] tensors per mode.
        let mut out_wide = Vec::with_capacity(batch * c_out);
        for ch in &chunks {
            out_wide.extend_from_slice(&ch.out_wide);
        }
        let out_wide = Tensor::new(vec![batch, c_out], out_wide);

        (0..n_modes)
            .map(|mi| {
                let mut data = Vec::with_capacity(batch * c_out);
                let mut stats = OverflowStats::default();
                for ch in &chunks {
                    data.extend_from_slice(&ch.out[mi]);
                    stats.merge(&ch.stats[mi]);
                }
                MatmulStats {
                    out: Tensor::new(vec![batch, c_out], data),
                    out_wide: out_wide.clone(),
                    stats,
                }
            })
            .collect()
    }

    /// Execute over a batch, choosing the worker count from the grid size
    /// (small grids run inline — thread spawn would dominate).
    pub fn execute(&self, x: &IntMatrix, x_scale: f32) -> Vec<MatmulStats> {
        self.execute_threads(x, x_scale, worker_count(x.rows(), self.w.c_out, self.w.k))
    }
}

/// Pick a worker count for a `batch x c_out x k` MAC grid. Honors the
/// `A2Q_ACCSIM_THREADS` environment variable when set.
fn worker_count(batch: usize, c_out: usize, k: usize) -> usize {
    if let Ok(v) = std::env::var("A2Q_ACCSIM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    // Below ~1M MACs the sim finishes in well under a millisecond; spawning
    // threads would cost more than it saves.
    if batch.saturating_mul(c_out).saturating_mul(k) < 1_000_000 {
        return 1;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Forward one integer batch through a quantized linear layer under *all*
/// requested accumulator models in a single fused pass, returning one
/// [`MatmulStats`] per mode (same order). The per-P loop of the scalar era:
///
/// ```ignore
/// for p in 8..=32 { results.push(qlinear_forward(&x, s, &w, Wrap { p })); }
/// ```
///
/// collapses into one call:
///
/// ```ignore
/// let modes: Vec<_> = (8..=32).map(|p| AccMode::Wrap { p_bits: p }).collect();
/// let results = qlinear_forward_multi(&x, s, &w, &modes);
/// ```
pub fn qlinear_forward_multi(
    x: &IntMatrix,
    x_scale: f32,
    w: &QTensor,
    modes: &[AccMode],
) -> Vec<MatmulStats> {
    LayerPlan::new(w, modes).execute(x, x_scale)
}

/// Result of one network forward under one register model.
#[derive(Clone, Debug)]
pub struct NetworkStats {
    /// Final-layer dequantized outputs `[batch, c_out_last]` with this
    /// mode's activations propagated through every boundary.
    pub out: Tensor,
    /// Final-layer outputs under a wide last-layer register fed the same
    /// propagated activations (the per-mode "local" reference, exactly what
    /// composing [`super::matmul::qlinear_forward_ref`] produces).
    pub out_wide: Tensor,
    /// One [`OverflowStats`] per layer, in depth order.
    pub layer_stats: Vec<OverflowStats>,
}

/// Per-worker results for one row chunk of a network forward.
struct NetChunk {
    /// Per-mode final-layer outputs, `rows_in_chunk * c_out_last` each.
    out: Vec<Vec<f32>>,
    /// Per-mode wide final-layer outputs.
    out_wide: Vec<Vec<f32>>,
    /// `[layer][mode]` overflow statistics for the chunk.
    layer_stats: Vec<Vec<OverflowStats>>,
}

/// Bounds-aware execution plan for a whole [`QNetwork`]: the multi-layer
/// generalization of [`LayerPlan`]. One batch pass simulates every requested
/// register model through every layer, with inter-layer requantization
/// (each boundary's [`crate::model::ActQuant`]) applied per mode so the
/// next layer sees exactly the activations its register model produced.
///
/// Fusion across modes survives layer boundaries as long as the modes'
/// activations remain byte-identical: all modes start fused at layer 0, and
/// a mode only splits off into its own MAC traversal once its register has
/// actually corrupted an activation somewhere in the chunk. Bit-exact
/// against composing the scalar reference per mode
/// ([`crate::model::network_forward_ref`]).
pub struct NetworkPlan<'n> {
    net: &'n QNetwork,
    modes: Vec<AccMode>,
    /// Per-layer per-channel `Σ|w_int|` norms driving the bound gate.
    layer_l1: Vec<Vec<i128>>,
}

impl<'n> NetworkPlan<'n> {
    pub fn new(net: &'n QNetwork, modes: &[AccMode]) -> NetworkPlan<'n> {
        let layer_l1 = net
            .layers
            .iter()
            .map(|l| l.weights.row_l1().into_iter().map(|v| v as i128).collect())
            .collect();
        NetworkPlan { net, modes: modes.to_vec(), layer_l1 }
    }

    pub fn modes(&self) -> &[AccMode] {
        &self.modes
    }

    pub fn depth(&self) -> usize {
        self.net.layers.len()
    }

    /// Stream rows `r0..r1` through every layer; the single-threaded core.
    fn forward_chunk(&self, x: &IntMatrix, r0: usize, r1: usize) -> NetChunk {
        let n_modes = self.modes.len();
        let depth = self.net.layers.len();
        let rows = r1 - r0;
        let cols = x.cols();
        let chunk = IntMatrix::from_flat(rows, cols, x.data()[r0 * cols..r1 * cols].to_vec());
        // Mode groups: slots whose propagated activations are still
        // byte-identical share one fused traversal per layer.
        let mut groups: Vec<(Vec<usize>, IntMatrix)> = vec![((0..n_modes).collect(), chunk)];
        let mut layer_stats = vec![vec![OverflowStats::default(); n_modes]; depth];
        let mut out = vec![Vec::new(); n_modes];
        let mut out_wide = vec![Vec::new(); n_modes];

        for (li, layer) in self.net.layers.iter().enumerate() {
            let last = li + 1 == depth;
            let mut next: Vec<(Vec<usize>, IntMatrix)> = Vec::new();
            for (slots, gx) in groups {
                let gmodes: Vec<AccMode> = slots.iter().map(|&s| self.modes[s]).collect();
                let plan = ModePlan::new(&gmodes);
                let ch = simulate_chunk(
                    &layer.weights,
                    &self.layer_l1[li],
                    &plan,
                    &gx,
                    layer.in_quant.scale,
                    0,
                    rows,
                );
                for (gi, &slot) in slots.iter().enumerate() {
                    layer_stats[li][slot].merge(&ch.stats[gi]);
                }
                if last {
                    for (gi, &slot) in slots.iter().enumerate() {
                        out[slot] = ch.out[gi].clone();
                        out_wide[slot] = ch.out_wide.clone();
                    }
                } else {
                    // Requantize each mode's activations onto the next
                    // boundary's grid, then regroup: modes whose register
                    // models produced identical activations stay fused.
                    let nq = &self.net.layers[li + 1].in_quant;
                    for (gi, &slot) in slots.iter().enumerate() {
                        let t = Tensor::new(vec![rows, layer.weights.c_out], ch.out[gi].clone());
                        let q = nq.quantize(&t);
                        match next.iter().position(|(_, m)| *m == q) {
                            Some(g) => next[g].0.push(slot),
                            None => next.push((vec![slot], q)),
                        }
                    }
                }
            }
            groups = next;
        }
        NetChunk { out, out_wide, layer_stats }
    }

    /// Execute over a batch with an explicit worker count (tests pin thread
    /// counts; [`Self::execute`] picks one from the network's MAC grid).
    pub fn execute_threads(&self, x: &IntMatrix, threads: usize) -> Vec<NetworkStats> {
        let batch = x.rows();
        assert_eq!(
            x.cols(),
            self.net.input_dim(),
            "input cols {} vs network input dim {}",
            x.cols(),
            self.net.input_dim()
        );
        let n_modes = self.modes.len();
        let depth = self.net.layers.len();
        let c_last = self.net.output_dim();

        let chunks: Vec<NetChunk> =
            par_row_chunks(batch, threads, |r0, r1| self.forward_chunk(x, r0, r1));

        (0..n_modes)
            .map(|mi| {
                let mut data = Vec::with_capacity(batch * c_last);
                let mut wide = Vec::with_capacity(batch * c_last);
                let mut stats = vec![OverflowStats::default(); depth];
                for ch in &chunks {
                    data.extend_from_slice(&ch.out[mi]);
                    wide.extend_from_slice(&ch.out_wide[mi]);
                    for (li, s) in stats.iter_mut().enumerate() {
                        s.merge(&ch.layer_stats[li][mi]);
                    }
                }
                NetworkStats {
                    out: Tensor::new(vec![batch, c_last], data),
                    out_wide: Tensor::new(vec![batch, c_last], wide),
                    layer_stats: stats,
                }
            })
            .collect()
    }

    /// Execute over a batch, choosing the worker count from the whole
    /// network's MAC grid (small networks run inline).
    pub fn execute(&self, x: &IntMatrix) -> Vec<NetworkStats> {
        self.execute_threads(x, worker_count(x.rows(), self.net.macs_per_row(), 1))
    }
}

/// Forward one integer batch through a whole quantized network under *all*
/// requested accumulator models, returning one [`NetworkStats`] per mode
/// (same order). The network-level analogue of [`qlinear_forward_multi`]:
///
/// ```ignore
/// let modes: Vec<_> = (8..=32).map(|p| AccMode::Wrap { p_bits: p }).collect();
/// let per_mode = network_forward_multi(&net, &x_int, &modes);
/// for (mode, r) in modes.iter().zip(&per_mode) {
///     for (depth, s) in r.layer_stats.iter().enumerate() { /* per-layer rates */ }
/// }
/// ```
pub fn network_forward_multi(
    net: &QNetwork,
    x: &IntMatrix,
    modes: &[AccMode],
) -> Vec<NetworkStats> {
    NetworkPlan::new(net, modes).execute(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::dot::dot_accumulate;
    use super::super::matmul::qlinear_forward_ref;
    use crate::rng::Rng;

    fn all_modes(p: u32) -> Vec<AccMode> {
        vec![
            AccMode::Wide,
            AccMode::Wrap { p_bits: p },
            AccMode::Saturate { p_bits: p },
            AccMode::SaturateFinal { p_bits: p },
        ]
    }

    #[test]
    fn min_safe_p_matches_acc_max() {
        use crate::quant::bounds::acc_max;
        for l1 in [0i128, 1, 7, 127, 128, 1000, 1 << 20] {
            for xmax in [0i64, 1, 3, 255] {
                let p = min_safe_p(l1, xmax);
                let worst = l1 * xmax as i128;
                if p <= 63 {
                    assert!(worst <= acc_max(p) as i128, "l1={l1} xmax={xmax} p={p}");
                }
                if p > 2 && worst > 0 {
                    assert!(
                        worst > acc_max(p - 1) as i128,
                        "p not minimal: l1={l1} xmax={xmax} p={p}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_matches_sequential_per_mode() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let k = 1 + rng.below(100);
            let x: Vec<i64> = (0..k).map(|_| rng.below(256) as i64).collect();
            let w: Vec<i64> = (0..k).map(|_| rng.below(255) as i64 - 127).collect();
            let mut modes = Vec::new();
            for p in [4, 8, 12, 16, 24, 32] {
                modes.extend(all_modes(p));
            }
            let fused = dot_accumulate_multi(&x, &w, &modes);
            for (mi, mode) in modes.iter().enumerate() {
                let seq = dot_accumulate(&x, &w, *mode);
                assert_eq!(fused[mi], seq, "{mode:?}");
            }
        }
    }

    #[test]
    fn duplicate_and_unsorted_modes_keep_slots() {
        let x = vec![100i64; 8];
        let w = vec![1i64; 8];
        let modes = [
            AccMode::Wrap { p_bits: 16 },
            AccMode::Wrap { p_bits: 8 },
            AccMode::Wrap { p_bits: 8 },
            AccMode::Wide,
        ];
        let r = dot_accumulate_multi(&x, &w, &modes);
        assert_eq!(r[0], dot_accumulate(&x, &w, modes[0]));
        assert_eq!(r[1], dot_accumulate(&x, &w, modes[1]));
        assert_eq!(r[1], r[2]);
        assert_eq!(r[3].value, 800);
    }

    fn toy_layer() -> QTensor {
        // channel 0: tiny weights (safe at 8 bits for binary inputs);
        // channel 1: huge weights (overflow at 8 bits).
        let w = Tensor::new(vec![2, 4], vec![1.0, -1.0, 2.0, 1.0, 100.0, 100.0, 100.0, 100.0]);
        let s = Tensor::new(vec![2, 1], vec![0.5, 0.25]);
        let b = Tensor::from_vec(vec![0.1, -0.2]);
        QTensor::from_export(&w, &s, &b)
    }

    #[test]
    fn layer_multi_matches_reference_with_gating_and_threads() {
        let w = toy_layer();
        let x = IntMatrix::from_rows(&[vec![1, 0, 1, 1], vec![1, 1, 1, 1], vec![0, 0, 0, 0]]);
        let modes: Vec<AccMode> = (4..=20)
            .flat_map(|p| [AccMode::Wrap { p_bits: p }, AccMode::Saturate { p_bits: p }])
            .collect();
        let plan = LayerPlan::new(&w, &modes);
        for threads in [1, 2, 7] {
            let multi = plan.execute_threads(&x, 0.5, threads);
            for (mi, mode) in modes.iter().enumerate() {
                let r = qlinear_forward_ref(&x, 0.5, &w, *mode);
                assert_eq!(multi[mi].out.data(), r.out.data(), "{mode:?} t={threads}");
                assert_eq!(multi[mi].out_wide.data(), r.out_wide.data(), "{mode:?}");
                assert_eq!(multi[mi].stats.overflow_events, r.stats.overflow_events, "{mode:?}");
                assert_eq!(multi[mi].stats.dots_overflowed, r.stats.dots_overflowed, "{mode:?}");
                assert_eq!(multi[mi].stats.abs_err_sum, r.stats.abs_err_sum, "{mode:?}");
                assert_eq!(multi[mi].stats.dots, r.stats.dots);
                assert_eq!(multi[mi].stats.macs, r.stats.macs);
            }
        }
    }

    #[test]
    fn network_plan_matches_composed_reference() {
        use crate::model::{network_forward_ref, NetSpec, QNetwork};
        // Unconstrained weights at low P: overflow actually happens, so
        // per-mode activation streams genuinely diverge before the last
        // layer and the group-splitting path is exercised.
        let spec = NetSpec {
            widths: vec![12, 9, 6, 4],
            m_bits: 5,
            n_bits: 4,
            p_bits: 10,
            x_signed: false,
            constrained: false,
        };
        let mut net = QNetwork::synthesize(&spec, 21).unwrap();
        let sample =
            Tensor::new(vec![7, 12], (0..84).map(|i| ((i * 13) % 11) as f32 * 0.09).collect());
        net.calibrate(&sample);
        let x = net.layers[0].in_quant.quantize(&sample);

        let modes: Vec<AccMode> = vec![
            AccMode::Wide,
            AccMode::Wrap { p_bits: 8 },
            AccMode::Wrap { p_bits: 12 },
            AccMode::Saturate { p_bits: 8 },
            AccMode::SaturateFinal { p_bits: 8 },
            AccMode::Wrap { p_bits: 8 }, // duplicate keeps its own slot
        ];
        let plan = NetworkPlan::new(&net, &modes);
        for threads in [1, 2, 5] {
            let multi = plan.execute_threads(&x, threads);
            assert_eq!(multi.len(), modes.len());
            for (mi, mode) in modes.iter().enumerate() {
                let r = network_forward_ref(&net, &x, *mode);
                assert_eq!(multi[mi].out.data(), r.out.data(), "{mode:?} t={threads}");
                assert_eq!(multi[mi].out_wide.data(), r.out_wide.data(), "{mode:?}");
                assert_eq!(multi[mi].layer_stats.len(), r.layer_stats.len());
                for (li, (a, b)) in
                    multi[mi].layer_stats.iter().zip(&r.layer_stats).enumerate()
                {
                    assert_eq!(a.overflow_events, b.overflow_events, "{mode:?} layer {li}");
                    assert_eq!(a.dots_overflowed, b.dots_overflowed, "{mode:?} layer {li}");
                    assert_eq!(a.abs_err_sum, b.abs_err_sum, "{mode:?} layer {li}");
                    assert_eq!(a.dots, b.dots, "{mode:?} layer {li}");
                    assert_eq!(a.macs, b.macs, "{mode:?} layer {li}");
                }
            }
            // duplicate modes resolve to identical results
            assert_eq!(multi[1].out.data(), multi[5].out.data());
        }
    }

    #[test]
    fn network_plan_a2q_net_never_splits_from_wide() {
        use crate::model::{NetSpec, QNetwork};
        let spec = NetSpec {
            widths: vec![10, 8, 3],
            m_bits: 4,
            n_bits: 3,
            p_bits: 12,
            x_signed: false,
            constrained: true,
        };
        let mut net = QNetwork::synthesize(&spec, 2).unwrap();
        let sample =
            Tensor::new(vec![4, 10], (0..40).map(|i| (i % 6) as f32 * 0.15).collect());
        net.calibrate(&sample);
        let x = net.layers[0].in_quant.quantize(&sample);
        // At the A2Q target width the theorem holds per layer: zero overflow
        // events anywhere, and the wrap output equals the wide output.
        let modes = [AccMode::Wide, AccMode::Wrap { p_bits: 12 }];
        let r = network_forward_multi(&net, &x, &modes);
        for s in &r[1].layer_stats {
            assert_eq!(s.overflow_events, 0);
        }
        assert_eq!(r[0].out.data(), r[1].out.data());
        assert_eq!(r[1].out.data(), r[1].out_wide.data());
    }

    #[test]
    fn safe_channels_report_zero_overflow() {
        // Σ|w| * max|x| = 5 * 1 = 5 <= acc_max(4) = 7: safe at every P >= 4.
        let w = QTensor::from_export(
            &Tensor::new(vec![1, 4], vec![1.0, -2.0, 1.0, 1.0]),
            &Tensor::new(vec![1, 1], vec![1.0]),
            &Tensor::from_vec(vec![0.0]),
        );
        let x = IntMatrix::from_rows(&[vec![1, 1, 1, 1]]);
        let modes = [AccMode::Wrap { p_bits: 4 }, AccMode::Saturate { p_bits: 5 }];
        for st in qlinear_forward_multi(&x, 1.0, &w, &modes) {
            assert_eq!(st.stats.overflow_events, 0);
            assert_eq!(st.out.data(), st.out_wide.data());
        }
    }
}
