//! Associativity study (paper Fig. 8 / Appendix A.1): randomly re-order the
//! additions of a dot product under saturating accumulation and measure how
//! the result distribution spreads — saturation at the inner loop makes the
//! result order-dependent, while the outer-loop model (and any overflow-free
//! execution) is order-invariant.

use super::dot::{dot_accumulate, AccMode, DotResult};
use crate::rng::Rng;

/// Distribution of dot-product results over random permutations.
#[derive(Clone, Debug)]
pub struct ReorderStudy {
    /// Result of each random permutation (inner-loop model).
    pub inner_values: Vec<i64>,
    /// Result of the outer-loop (final-only) model — order-invariant.
    pub outer_value: i64,
    /// Wide-register reference.
    pub wide_value: i64,
}

impl ReorderStudy {
    pub fn mean_abs_err_inner(&self) -> f64 {
        let n = self.inner_values.len().max(1) as f64;
        self.inner_values
            .iter()
            .map(|v| (v - self.wide_value).abs() as f64)
            .sum::<f64>()
            / n
    }

    pub fn abs_err_outer(&self) -> f64 {
        (self.outer_value - self.wide_value).abs() as f64
    }

    /// Number of distinct results across permutations (1 == deterministic).
    pub fn distinct_inner(&self) -> usize {
        let mut v = self.inner_values.clone();
        v.sort_unstable();
        v.dedup();
        v.len()
    }
}

/// Reusable permutation/gather scratch for re-ordering studies.
///
/// A study over many dot products (e.g. every `batch x c_out` pair of a
/// layer, as Fig. 8 does) previously re-allocated the index and gather
/// buffers per dot product; one scratch now serves the whole sweep, resized
/// only when the dot length grows.
#[derive(Clone, Debug, Default)]
pub struct ReorderScratch {
    idx: Vec<usize>,
    xp: Vec<i64>,
    wp: Vec<i64>,
}

impl ReorderScratch {
    pub fn new() -> ReorderScratch {
        ReorderScratch::default()
    }

    /// Size the buffers for dot length `k` and reset the permutation to the
    /// identity, so studies are deterministic regardless of what the scratch
    /// was used for before.
    pub fn reset(&mut self, k: usize) {
        self.idx.clear();
        self.idx.extend(0..k);
        self.xp.resize(k, 0);
        self.wp.resize(k, 0);
    }

    /// Shuffle the current permutation in place (cumulative, matching the
    /// original study's sampling sequence for a given RNG stream).
    pub fn shuffle(&mut self, rng: &mut Rng) {
        rng.shuffle(&mut self.idx);
    }

    /// The current permutation.
    pub fn perm(&self) -> &[usize] {
        &self.idx
    }

    /// Gather `x`/`w` through the current permutation into the reused flat
    /// buffers and return them.
    pub fn gathered(&mut self, x: &[i64], w: &[i64]) -> (&[i64], &[i64]) {
        debug_assert_eq!(x.len(), self.idx.len());
        debug_assert_eq!(w.len(), self.idx.len());
        for (j, &i) in self.idx.iter().enumerate() {
            self.xp[j] = x[i];
            self.wp[j] = w[i];
        }
        (&self.xp, &self.wp)
    }

    /// Run `n_perms` random re-orderings of the MACs of `x . w` under an
    /// inner-loop saturating P-bit register, plus the outer-loop / wide
    /// models, reusing this scratch across permutations (and across calls).
    pub fn study(
        &mut self,
        x: &[i64],
        w: &[i64],
        p_bits: u32,
        n_perms: usize,
        seed: u64,
    ) -> ReorderStudy {
        assert_eq!(x.len(), w.len());
        let wide = dot_accumulate(x, w, AccMode::Wide).value;
        let outer = dot_accumulate(x, w, AccMode::SaturateFinal { p_bits }).value;

        let mut rng = Rng::new(seed);
        self.reset(x.len());
        let mut inner_values = Vec::with_capacity(n_perms);
        for _ in 0..n_perms {
            self.shuffle(&mut rng);
            let (xp, wp) = self.gathered(x, w);
            let DotResult { value, .. } =
                dot_accumulate(xp, wp, AccMode::Saturate { p_bits });
            inner_values.push(value);
        }

        ReorderStudy { inner_values, outer_value: outer, wide_value: wide }
    }
}

/// Run `n_perms` random re-orderings of the MACs of `x . w` under an
/// inner-loop saturating P-bit register, plus the outer-loop / wide models.
///
/// Convenience wrapper allocating a one-shot [`ReorderScratch`]; sweeps over
/// many dot products should hold a scratch and call [`ReorderScratch::study`].
pub fn reorder_study(
    x: &[i64],
    w: &[i64],
    p_bits: u32,
    n_perms: usize,
    seed: u64,
) -> ReorderStudy {
    ReorderScratch::new().study(x, w, p_bits, n_perms, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_overflow_means_order_invariant() {
        let x: Vec<i64> = (0..32).map(|i| (i % 3) - 1).collect();
        let w: Vec<i64> = (0..32).map(|i| (i % 5) - 2).collect();
        // sum |x||w| <= 64 << 2^15 so a 16-bit register never clips.
        let s = reorder_study(&x, &w, 16, 50, 42);
        assert_eq!(s.distinct_inner(), 1);
        assert_eq!(s.inner_values[0], s.wide_value);
        assert_eq!(s.abs_err_outer(), 0.0);
    }

    #[test]
    fn saturation_spreads_under_overflow() {
        // Alternating big +/- terms: prefix magnitude far exceeds 8 bits, so
        // different orders pin the register at different times.
        let x: Vec<i64> = (0..64).map(|i| if i % 2 == 0 { 100 } else { -100 }).collect();
        let w = vec![1i64; 64];
        let s = reorder_study(&x, &w, 8, 200, 7);
        assert!(s.distinct_inner() > 1, "expected order dependence");
        assert!(s.mean_abs_err_inner() > 0.0);
        // outer-loop model sees a zero final sum -> no clipping at all
        assert_eq!(s.abs_err_outer(), 0.0);
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let x: Vec<i64> = (0..48).map(|i| (i * 29 % 160) - 80).collect();
        let w: Vec<i64> = (0..48).map(|i| (i * 11 % 9) - 4).collect();
        let mut scratch = ReorderScratch::new();
        let a = scratch.study(&x, &w, 9, 30, 3);
        let b = scratch.study(&x, &w, 9, 30, 3); // dirty scratch, same seed
        let fresh = reorder_study(&x, &w, 9, 30, 3);
        assert_eq!(a.inner_values, fresh.inner_values);
        assert_eq!(b.inner_values, fresh.inner_values);
    }

    #[test]
    fn deterministic_given_seed() {
        let x: Vec<i64> = (0..40).map(|i| (i * 37 % 200) - 100).collect();
        let w: Vec<i64> = (0..40).map(|i| (i * 13 % 7) - 3).collect();
        let a = reorder_study(&x, &w, 10, 25, 5);
        let b = reorder_study(&x, &w, 10, 25, 5);
        assert_eq!(a.inner_values, b.inner_values);
    }
}
