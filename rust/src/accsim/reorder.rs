//! Associativity study (paper Fig. 8 / Appendix A.1): randomly re-order the
//! additions of a dot product under saturating accumulation and measure how
//! the result distribution spreads — saturation at the inner loop makes the
//! result order-dependent, while the outer-loop model (and any overflow-free
//! execution) is order-invariant.

use super::dot::{dot_accumulate, AccMode, DotResult};
use crate::rng::Rng;

/// Distribution of dot-product results over random permutations.
#[derive(Clone, Debug)]
pub struct ReorderStudy {
    /// Result of each random permutation (inner-loop model).
    pub inner_values: Vec<i64>,
    /// Result of the outer-loop (final-only) model — order-invariant.
    pub outer_value: i64,
    /// Wide-register reference.
    pub wide_value: i64,
}

impl ReorderStudy {
    pub fn mean_abs_err_inner(&self) -> f64 {
        let n = self.inner_values.len().max(1) as f64;
        self.inner_values
            .iter()
            .map(|v| (v - self.wide_value).abs() as f64)
            .sum::<f64>()
            / n
    }

    pub fn abs_err_outer(&self) -> f64 {
        (self.outer_value - self.wide_value).abs() as f64
    }

    /// Number of distinct results across permutations (1 == deterministic).
    pub fn distinct_inner(&self) -> usize {
        let mut v = self.inner_values.clone();
        v.sort_unstable();
        v.dedup();
        v.len()
    }
}

/// Run `n_perms` random re-orderings of the MACs of `x . w` under an
/// inner-loop saturating P-bit register, plus the outer-loop / wide models.
pub fn reorder_study(
    x: &[i64],
    w: &[i64],
    p_bits: u32,
    n_perms: usize,
    seed: u64,
) -> ReorderStudy {
    assert_eq!(x.len(), w.len());
    let wide = dot_accumulate(x, w, AccMode::Wide).value;
    let outer = dot_accumulate(x, w, AccMode::SaturateFinal { p_bits }).value;

    let mut rng = Rng::new(seed);
    let mut idx: Vec<usize> = (0..x.len()).collect();
    let mut xp = vec![0i64; x.len()];
    let mut wp = vec![0i64; w.len()];
    let inner_values = (0..n_perms)
        .map(|_| {
            rng.shuffle(&mut idx);
            for (j, &i) in idx.iter().enumerate() {
                xp[j] = x[i];
                wp[j] = w[i];
            }
            let DotResult { value, .. } =
                dot_accumulate(&xp, &wp, AccMode::Saturate { p_bits });
            value
        })
        .collect();

    ReorderStudy { inner_values, outer_value: outer, wide_value: wide }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_overflow_means_order_invariant() {
        let x: Vec<i64> = (0..32).map(|i| (i % 3) - 1).collect();
        let w: Vec<i64> = (0..32).map(|i| (i % 5) - 2).collect();
        // sum |x||w| <= 64 << 2^15 so a 16-bit register never clips.
        let s = reorder_study(&x, &w, 16, 50, 42);
        assert_eq!(s.distinct_inner(), 1);
        assert_eq!(s.inner_values[0], s.wide_value);
        assert_eq!(s.abs_err_outer(), 0.0);
    }

    #[test]
    fn saturation_spreads_under_overflow() {
        // Alternating big +/- terms: prefix magnitude far exceeds 8 bits, so
        // different orders pin the register at different times.
        let x: Vec<i64> = (0..64).map(|i| if i % 2 == 0 { 100 } else { -100 }).collect();
        let w = vec![1i64; 64];
        let s = reorder_study(&x, &w, 8, 200, 7);
        assert!(s.distinct_inner() > 1, "expected order dependence");
        assert!(s.mean_abs_err_inner() > 0.0);
        // outer-loop model sees a zero final sum -> no clipping at all
        assert_eq!(s.abs_err_outer(), 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let x: Vec<i64> = (0..40).map(|i| (i * 37 % 200) - 100).collect();
        let w: Vec<i64> = (0..40).map(|i| (i * 13 % 7) - 3).collect();
        let a = reorder_study(&x, &w, 10, 25, 5);
        let b = reorder_study(&x, &w, 10, 25, 5);
        assert_eq!(a.inner_values, b.inner_values);
    }
}
