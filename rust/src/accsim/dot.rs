//! MAC-by-MAC dot-product simulation with a P-bit accumulator register.

/// Accumulator register model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccMode {
    /// Wide i64 reference register (no overflow at our magnitudes).
    Wide,
    /// Wraparound two's-complement arithmetic at `p_bits`.
    Wrap { p_bits: u32 },
    /// Saturating (clipping) arithmetic at `p_bits`, applied to every
    /// intermediate partial sum (inner-most loop, Appendix A).
    Saturate { p_bits: u32 },
    /// Saturation applied only to the *final* result (outer-most loop) —
    /// the approximation prior work uses that ignores partial sums; kept for
    /// the Fig. 8 comparison.
    SaturateFinal { p_bits: u32 },
}

/// Outcome of one simulated dot product.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DotResult {
    /// Final register value.
    pub value: i64,
    /// Number of MACs whose partial sum left the P-bit range (each one either
    /// wrapped or clipped depending on the mode).
    pub overflows: u32,
}

/// Representable range of a signed P-bit register: `[-2^(P-1), 2^(P-1)-1]`.
#[inline]
pub(crate) fn range(p_bits: u32) -> (i64, i64) {
    debug_assert!((2..=63).contains(&p_bits), "p_bits {p_bits} out of range");
    let hi = (1i64 << (p_bits - 1)) - 1;
    (-hi - 1, hi)
}

/// Two's-complement wraparound of `v` into P bits.
///
/// Implemented as shift-based sign extension (`(v << (64-P)) >> (64-P)`),
/// which is exact for P in 1..=64 and ~16x faster than the modular-arithmetic
/// formulation it replaced (i128 `rem_euclid` costs a division per MAC; see
/// EXPERIMENTS.md §Perf).
#[inline]
pub fn wrap_to(v: i64, p_bits: u32) -> i64 {
    debug_assert!((1..=64).contains(&p_bits));
    let sh = 64 - p_bits;
    v.wrapping_shl(sh) >> sh
}

/// Simulate `sum_i x[i] * w[i]` MAC by MAC under the given register model.
///
/// Inputs are i64 but must individually fit the data types being modelled
/// (the caller quantizes); products are taken exactly, and only the
/// *accumulator* is subject to the register model — matching Fig. 1's
/// fixed-point pipeline where the multiplier output is full-width.
pub fn dot_accumulate(x: &[i64], w: &[i64], mode: AccMode) -> DotResult {
    debug_assert_eq!(x.len(), w.len());
    match mode {
        AccMode::Wide => {
            let mut acc = 0i64;
            for (xi, wi) in x.iter().zip(w) {
                acc += xi * wi;
            }
            DotResult { value: acc, overflows: 0 }
        }
        AccMode::Wrap { p_bits } => {
            let mut acc = 0i64;
            let mut overflows = 0u32;
            for (xi, wi) in x.iter().zip(w) {
                let wide = acc + xi * wi; // exact in i64
                acc = wrap_to(wide, p_bits);
                // branchless: wrapped != wide  <=>  the partial sum left the
                // P-bit range (one cmov instead of a data-dependent branch)
                overflows += (acc != wide) as u32;
            }
            DotResult { value: acc, overflows }
        }
        AccMode::Saturate { p_bits } => {
            let (lo, hi) = range(p_bits);
            let mut acc = 0i64;
            let mut overflows = 0;
            for (xi, wi) in x.iter().zip(w) {
                let wide = acc + xi * wi;
                if wide < lo || wide > hi {
                    overflows += 1;
                }
                acc = wide.clamp(lo, hi);
            }
            DotResult { value: acc, overflows }
        }
        AccMode::SaturateFinal { p_bits } => {
            let (lo, hi) = range(p_bits);
            let mut acc = 0i64;
            for (xi, wi) in x.iter().zip(w) {
                acc += xi * wi;
            }
            let clipped = acc.clamp(lo, hi);
            DotResult {
                value: clipped,
                overflows: u32::from(clipped != acc),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_matches_naive() {
        let x = vec![1, -2, 3, 4];
        let w = vec![5, 6, -7, 8];
        let r = dot_accumulate(&x, &w, AccMode::Wide);
        assert_eq!(r.value, 5 - 12 - 21 + 32);
        assert_eq!(r.overflows, 0);
    }

    #[test]
    fn wrap_is_twos_complement() {
        assert_eq!(wrap_to(128, 8), -128);
        assert_eq!(wrap_to(127, 8), 127);
        assert_eq!(wrap_to(-129, 8), 127);
        assert_eq!(wrap_to(256, 8), 0);
        assert_eq!(wrap_to(-32769, 16), 32767);
    }

    #[test]
    fn no_overflow_when_within_bound() {
        // sum |x||w| = 100 < 2^(8-1) - 1 = 127 -> all modes agree, 0 overflow.
        let x = vec![5i64; 10];
        let w = vec![2i64; 10];
        for mode in [
            AccMode::Wide,
            AccMode::Wrap { p_bits: 8 },
            AccMode::Saturate { p_bits: 8 },
            AccMode::SaturateFinal { p_bits: 8 },
        ] {
            let r = dot_accumulate(&x, &w, mode);
            assert_eq!(r.value, 100, "{mode:?}");
            assert_eq!(r.overflows, 0, "{mode:?}");
        }
    }

    #[test]
    fn wrap_and_saturate_diverge_on_overflow() {
        let x = vec![100i64; 4];
        let w = vec![1i64; 4]; // partials: 100, 200, 300, 400 under 8-bit reg
        let wrap = dot_accumulate(&x, &w, AccMode::Wrap { p_bits: 8 });
        let sat = dot_accumulate(&x, &w, AccMode::Saturate { p_bits: 8 });
        let wide = dot_accumulate(&x, &w, AccMode::Wide);
        assert_eq!(wide.value, 400);
        assert_eq!(sat.value, 127); // pinned at the rail
        assert_eq!(wrap.value, wrap_to(400, 8));
        assert!(wrap.overflows > 0 && sat.overflows > 0);
    }

    #[test]
    fn intermediate_overflow_detected_even_if_final_fits() {
        // partials: 120, 240 (overflow), 120 -> final fits in 8 bits but the
        // inner loop overflowed; Saturate catches it, SaturateFinal cannot.
        let x = vec![120i64, 120, -120];
        let w = vec![1i64, 1, 1];
        let inner = dot_accumulate(&x, &w, AccMode::Saturate { p_bits: 8 });
        let outer = dot_accumulate(&x, &w, AccMode::SaturateFinal { p_bits: 8 });
        assert_eq!(outer.overflows, 0);
        assert_eq!(outer.value, 120);
        assert!(inner.overflows > 0);
        assert_eq!(inner.value, 7); // clamped at 127 then -120
    }

    #[test]
    fn saturate_order_dependent_wide_not() {
        // Appendix A.1: clipping breaks associativity.
        let x = vec![120i64, 120, -120, -120];
        let w = vec![1i64; 4];
        let fwd = dot_accumulate(&x, &w, AccMode::Saturate { p_bits: 8 });
        let rev_x: Vec<i64> = x.iter().rev().copied().collect();
        let rev = dot_accumulate(&rev_x, &w, AccMode::Saturate { p_bits: 8 });
        assert_ne!(fwd.value, rev.value);
        let wf = dot_accumulate(&x, &w, AccMode::Wide);
        let wr = dot_accumulate(&rev_x, &w, AccMode::Wide);
        assert_eq!(wf.value, wr.value);
    }
}
