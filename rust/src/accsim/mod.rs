//! Exact integer accumulation simulator (the substrate behind paper Fig. 2,
//! Fig. 8 and Appendix A).
//!
//! Simulates the MAC-by-MAC behaviour of a P-bit accumulator register at the
//! *inner-most loop* — i.e. every intermediate partial sum passes through the
//! register, not just the final dot-product result. Three register models:
//!
//! * [`AccMode::Wide`]      — an i64 reference register (the "32-bit" gold
//!   result at our magnitudes; exact for every P <= 63).
//! * [`AccMode::Wrap`]      — wraparound two's-complement at P bits, the
//!   default hardware behaviour whose numerical errors the paper studies.
//! * [`AccMode::Saturate`]  — clip-on-accumulate at P bits, the industry
//!   "saturation arithmetic" baseline; breaks associativity (Appendix A.1).
//!
//! All simulation is in i64 with explicit wrapping/clamping, so results are
//! bit-exact and platform-independent.

pub mod dot;
pub mod matmul;
pub mod reorder;
pub mod stats;

pub use dot::{dot_accumulate, AccMode, DotResult};
pub use matmul::{qlinear_forward, MatmulStats};
pub use reorder::reorder_study;
pub use stats::OverflowStats;
