//! Exact integer accumulation simulation (the substrate behind paper Fig. 2,
//! Fig. 8 and Appendix A), built as a batched kernel engine.
//!
//! Simulates the MAC-by-MAC behaviour of a P-bit accumulator register at the
//! *inner-most loop* — i.e. every intermediate partial sum passes through the
//! register, not just the final dot-product result. Three register models:
//!
//! * [`AccMode::Wide`]      — an i64 reference register (the "32-bit" gold
//!   result at our magnitudes; exact for every P <= 63).
//! * [`AccMode::Wrap`]      — wraparound two's-complement at P bits, the
//!   default hardware behaviour whose numerical errors the paper studies.
//! * [`AccMode::Saturate`]  — clip-on-accumulate at P bits, the industry
//!   "saturation arithmetic" baseline; breaks associativity (Appendix A.1).
//!
//! All simulation is in i64 with explicit wrapping/clamping, so results are
//! bit-exact and platform-independent.
//!
//! Layout: [`dot`] holds the scalar single-register walk (the reference
//! semantics); [`engine`] is the safety-partitioned kernel engine — each
//! layer's channels are l1-sorted once per plan so one `partition_point`
//! per row splits them into a provably-safe span (driven through the
//! packed blocked integer GEMM in [`gemm`]) and a must-simulate remainder
//! (one fused MAC traversal carrying every requested width), with row
//! blocks fanned over scoped threads through an atomic work queue and
//! per-worker scratch arenas. Batched inputs travel as a flat row-major
//! [`IntMatrix`]. P-sweeps should call [`qlinear_forward_multi`] /
//! [`dot_accumulate_multi`]; whole-network sweeps go through
//! [`NetworkPlan`] / [`network_forward_multi`], which stream a batch
//! through every layer of a [`crate::model::QNetwork`] (with inter-layer
//! requantization) in one thread-scoped pass. [`stream`] adds NNUE-style
//! incremental sessions over the same engine: maintained first-layer
//! accumulators updated per sparse input delta (feature-major column
//! kernels in [`gemm`]), bit-identical to a full recompute. Throughput
//! history lives in EXPERIMENTS.md §Perf / §Perf-Stream and
//! BENCH_accsim.json.

pub mod dot;
pub mod engine;
pub mod gemm;
pub mod intmat;
pub mod matmul;
pub mod reorder;
pub mod stats;
pub mod stream;

pub use dot::{dot_accumulate, AccMode, DotResult};
pub use engine::{
    dot_accumulate_multi, min_safe_p, network_forward_multi, qlinear_forward_multi, KernelChoice,
    LayerPlan, ModePlan, NetScratch, NetworkPlan, NetworkStats, SharedNetworkPlan,
};
pub use gemm::{FeatureMajorWeights, PackedWeights};
// The GEMM kernel dispatch enum lives with the float core in
// `crate::linalg::kernel`; re-export it here because the integer engine's
// plan APIs (`LayerPlan::new_with_path` etc.) take it too.
pub use crate::linalg::KernelPath;
pub use intmat::IntMatrix;
pub use matmul::{
    qlinear_forward, qlinear_forward_ref, quantize_code, quantize_inputs, MatmulStats,
};
pub use reorder::{reorder_study, ReorderScratch, ReorderStudy};
pub use stats::OverflowStats;
pub use stream::{
    LayerStreamSession, StreamDelta, StreamError, StreamSession, DEFAULT_REFRESH_THRESHOLD,
};
