//! Packed blocked integer GEMM: the fast path for channels the paper's
//! overflow bound proves safe.
//!
//! For an A2Q-constrained layer at (or above) its target accumulator width,
//! *every* channel is provably overflow-free, so the whole forward collapses
//! to a plain integer matrix multiply — no register simulation, no per-MAC
//! bookkeeping. This module supplies that multiply as a cache-blocked kernel
//! over weights packed once per plan:
//!
//! * **Packing** — [`PackedWeights::pack`] lays the weight codes out in
//!   channel-tile panels of [`NR`] channels, k-major within a panel
//!   (`panel[kk * NR + j]` is MAC step `kk` of packed channel `j`), in the
//!   caller's channel order (the engine passes its l1-sorted order so a safe
//!   span is always a packed-channel *prefix*). Codes are narrowed to `i16`
//!   when they fit (the common case: weights are ≤8-bit codes), else `i32`,
//!   quartering/halving memory traffic versus the `i64` rows the register
//!   simulator walks. Packing returns `None` for codes beyond `i32` and the
//!   engine falls back to unpacked wide dots.
//! * **Microkernel** — [`PackedWeights::gemm_into`] drives an
//!   [`MR`]`x`[`NR`] register tile: each panel is streamed once per row
//!   block, every loaded `x` value feeds [`NR`] channel lanes and every
//!   loaded weight feeds [`MR`] batch rows. The inner loop is plain
//!   `i64 += i64 * widen(code)` arithmetic with no branches, so the
//!   autovectorizer can unroll it; exact integer addition keeps the result
//!   bit-identical to any other MAC order, which is what lets the engine's
//!   bit-exactness property tests treat GEMM and scalar paths as one.
//!
//! Accumulation stays in `i64` — identical to the wide reference register —
//! so the GEMM output *is* the `AccMode::Wide` result for those channels.

use crate::quant::QTensor;

// The MR×NR register tile is shared with the blocked *float* GEMM core in
// `crate::linalg` (the native training backend's engine): one tiling
// geometry, two element domains.
pub use crate::linalg::{MR, NR};

/// Weight codes packed at the narrowest width that holds every code.
enum Panels {
    I16(Vec<i16>),
    I32(Vec<i32>),
}

/// Weight codes packed once per plan into NR-channel, k-major panels.
pub struct PackedWeights {
    panels: Panels,
    /// Number of packed channels (panels are zero-padded past it).
    n_ch: usize,
    /// MAC depth shared by every channel.
    k: usize,
}

impl PackedWeights {
    /// Pack rows of `w` in `order` (a permutation of `0..w.c_out`). Returns
    /// `None` when some code exceeds `i32` — callers then keep the unpacked
    /// `i64` path.
    pub fn pack(w: &QTensor, order: &[usize]) -> Option<PackedWeights> {
        debug_assert_eq!(order.len(), w.c_out);
        let lo = w.codes.iter().copied().min().unwrap_or(0);
        let hi = w.codes.iter().copied().max().unwrap_or(0);
        let panels = if lo >= i16::MIN as i64 && hi <= i16::MAX as i64 {
            Panels::I16(pack_panels(w, order, |v| v as i16))
        } else if lo >= i32::MIN as i64 && hi <= i32::MAX as i64 {
            Panels::I32(pack_panels(w, order, |v| v as i32))
        } else {
            return None;
        };
        Some(PackedWeights { panels, n_ch: order.len(), k: w.k })
    }

    /// Number of packed channels.
    pub fn channels(&self) -> usize {
        self.n_ch
    }

    /// Wide (i64) dot products of `rows` batch rows (`x`, flat row-major,
    /// `rows * k` long) against the packed-channel prefix `0..n_pref`,
    /// written to `out[ri * n_pref + ci]` (`ci` in packed order). Bit-exact
    /// against summing `x[ri] . w[order[ci]]` in any order.
    pub fn gemm_into(&self, x: &[i64], rows: usize, n_pref: usize, out: &mut [i64]) {
        debug_assert!(n_pref <= self.n_ch);
        debug_assert_eq!(x.len(), rows * self.k);
        debug_assert_eq!(out.len(), rows * n_pref);
        match &self.panels {
            Panels::I16(p) => gemm_span(p, self.k, x, rows, n_pref, out),
            Panels::I32(p) => gemm_span(p, self.k, x, rows, n_pref, out),
        }
    }
}

/// Lay `w`'s rows out in `order` as NR-channel k-major panels, zero-padding
/// the tail panel (zero weights contribute nothing and are never read back).
fn pack_panels<T: Copy + Default>(
    w: &QTensor,
    order: &[usize],
    cast: impl Fn(i64) -> T,
) -> Vec<T> {
    let k = w.k;
    let n_panels = order.len().div_ceil(NR);
    let mut data = vec![T::default(); n_panels * k * NR];
    for (ci, &c) in order.iter().enumerate() {
        let (pi, j) = (ci / NR, ci % NR);
        let base = pi * k * NR;
        for (kk, &code) in w.row(c).iter().enumerate() {
            data[base + kk * NR + j] = cast(code);
        }
    }
    data
}

/// The blocked kernel over one packed element type: MR x NR register tiles,
/// panels streamed once per row block.
fn gemm_span<T: Copy + Into<i64>>(
    panels: &[T],
    k: usize,
    x: &[i64],
    rows: usize,
    n_pref: usize,
    out: &mut [i64],
) {
    if rows == 0 || n_pref == 0 {
        return;
    }
    let n_panels = n_pref.div_ceil(NR);
    for pi in 0..n_panels {
        let c0 = pi * NR;
        let nc = NR.min(n_pref - c0);
        let panel = &panels[pi * k * NR..(pi + 1) * k * NR];
        let mut r0 = 0;
        while r0 < rows {
            let mr = MR.min(rows - r0);
            let mut acc = [0i64; MR * NR];
            for kk in 0..k {
                let wrow = &panel[kk * NR..kk * NR + NR];
                for mi in 0..mr {
                    let xv = x[(r0 + mi) * k + kk];
                    let lane = &mut acc[mi * NR..mi * NR + NR];
                    for j in 0..NR {
                        let wv: i64 = wrow[j].into();
                        lane[j] += xv * wv;
                    }
                }
            }
            for mi in 0..mr {
                for j in 0..nc {
                    out[(r0 + mi) * n_pref + c0 + j] = acc[mi * NR + j];
                }
            }
            r0 += mr;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    fn naive_dot(x: &[i64], w: &[i64]) -> i64 {
        x.iter().zip(w).map(|(a, b)| a * b).sum()
    }

    fn random_layer(c_out: usize, k: usize, amp: i64, rng: &mut Rng) -> QTensor {
        let w: Vec<f32> = (0..c_out * k)
            .map(|_| (rng.below((2 * amp + 1) as usize) as i64 - amp) as f32)
            .collect();
        QTensor::from_export(
            &Tensor::new(vec![c_out, k], w),
            &Tensor::new(vec![c_out, 1], vec![1.0; c_out]),
            &Tensor::from_vec(vec![0.0; c_out]),
        )
    }

    #[test]
    fn gemm_matches_naive_dots_over_random_shapes_and_prefixes() {
        let mut rng = Rng::new(0x6E);
        for case in 0..40 {
            let c_out = 1 + rng.below(20);
            let k = rng.below(70);
            // amp 3000 forces the i16 packing on some cases and i32 on others
            let amp = if case % 2 == 0 { 7 } else { 40_000 };
            let w = random_layer(c_out, k, amp, &mut rng);
            let order: Vec<usize> = {
                let mut o: Vec<usize> = (0..c_out).collect();
                rng.shuffle(&mut o);
                o
            };
            let packed = PackedWeights::pack(&w, &order).expect("codes fit i32");
            assert_eq!(packed.channels(), c_out);

            let rows = rng.below(7);
            let x: Vec<i64> =
                (0..rows * k).map(|_| rng.below(511) as i64 - 255).collect();
            for n_pref in [0, 1, c_out / 2, c_out] {
                let mut out = vec![0i64; rows * n_pref];
                packed.gemm_into(&x, rows, n_pref, &mut out);
                for ri in 0..rows {
                    for ci in 0..n_pref {
                        let expect = naive_dot(&x[ri * k..(ri + 1) * k], w.row(order[ci]));
                        assert_eq!(
                            out[ri * n_pref + ci],
                            expect,
                            "case {case} row {ri} packed-ch {ci}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn pack_rejects_codes_beyond_i32() {
        let w = QTensor {
            codes: vec![1, i32::MAX as i64 + 1],
            scales: vec![1.0],
            bias: vec![0.0],
            c_out: 1,
            k: 2,
        };
        assert!(PackedWeights::pack(&w, &[0]).is_none());
    }

    #[test]
    fn k_zero_and_empty_rows_are_fine() {
        let w = QTensor { codes: vec![], scales: vec![1.0; 3], bias: vec![0.0; 3], c_out: 3, k: 0 };
        let packed = PackedWeights::pack(&w, &[2, 0, 1]).unwrap();
        let mut out = vec![7i64; 2 * 3];
        packed.gemm_into(&[], 2, 3, &mut out);
        assert_eq!(out, vec![0i64; 6]);
        let mut empty: Vec<i64> = vec![];
        packed.gemm_into(&[], 0, 3, &mut empty);
    }
}
