//! Packed blocked integer GEMM: the fast path for channels the paper's
//! overflow bound proves safe.
//!
//! For an A2Q-constrained layer at (or above) its target accumulator width,
//! *every* channel is provably overflow-free, so the whole forward collapses
//! to a plain integer matrix multiply — no register simulation, no per-MAC
//! bookkeeping. This module supplies that multiply as a cache-blocked kernel
//! over weights packed once per plan:
//!
//! * **Packing** — [`PackedWeights::pack`] lays the weight codes out in
//!   channel-tile panels of [`NR`] channels, k-major within a panel
//!   (`panel[kk * NR + j]` is MAC step `kk` of packed channel `j`), in the
//!   caller's channel order (the engine passes its l1-sorted order so a safe
//!   span is always a packed-channel *prefix*). Codes are narrowed to `i16`
//!   when they fit (the common case: weights are ≤8-bit codes), else `i32`,
//!   quartering/halving memory traffic versus the `i64` rows the register
//!   simulator walks. Packing returns `None` for codes beyond `i32` and the
//!   engine falls back to unpacked wide dots.
//! * **Microkernel dispatch** — [`PackedWeights::gemm_into`] drives an
//!   [`MR`]`x`[`NR`] register tile per panel, routed through the layer's
//!   [`KernelPath`] (fixed at pack time: explicit force, then the
//!   `A2Q_KERNEL` env override, then the weight-density heuristic):
//!   - *Scalar* — the original branch-free `i64 += i64 * widen(code)`
//!     blocked loop, kept as the portable fallback and property-test
//!     reference;
//!   - *Simd* — the explicit i16 pairwise-widening microkernel
//!     ([`crate::linalg::kernel`]) when runtime detection finds AVX2/NEON,
//!     the packed codes exclude `-32768` (so `madd` pair sums are exact in
//!     i32), and every `x` narrows to ±32767 — otherwise the scalar tile
//!     runs;
//!   - *SparseSimd* — panels at or below the density threshold traverse a
//!     compressed k-major nonzero list built at pack time (A2Q's L1 budget
//!     makes constrained layers mostly zeros), dense panels keep the SIMD
//!     tile.
//!
//! Accumulation stays in `i64` — identical to the wide reference register —
//! and every product is exact, so *all* paths are bit-identical to any
//! other MAC order: the GEMM output *is* the `AccMode::Wide` result for
//! those channels regardless of dispatch.
//!
//! [`FeatureMajorWeights`] is the *transposed* sibling for the streaming
//! engine ([`crate::accsim::stream`]): the same codes laid out
//! column-major (one contiguous column per input feature), so an input
//! delta `d` on feature `j` updates every channel's maintained accumulator
//! with one `acc += w[:, j] * d` pass — dispatched through the same
//! [`KernelPath`] (scalar reference, AVX2/NEON delta kernels, or a
//! compressed nonzero-column walk for sparse A2Q layers).

use std::cell::RefCell;

use crate::linalg::kernel::{self, build_sparse_panels, PanelKind, SparsePanels};
use crate::linalg::{simd_available, KernelPath};
use crate::quant::QTensor;

// The MR×NR register tile is shared with the blocked *float* GEMM core in
// `crate::linalg` (the native training backend's engine): one tiling
// geometry, two element domains.
pub use crate::linalg::{MR, NR};

thread_local! {
    /// Per-thread scratch for the i16-narrowed `x` operand of the SIMD
    /// tile, so engine workers never contend and steady-state calls do not
    /// re-allocate.
    static X16: RefCell<Vec<i16>> = const { RefCell::new(Vec::new()) };
}

/// Weight codes packed at the narrowest width that holds every code.
enum Panels {
    I16(Vec<i16>),
    I32(Vec<i32>),
}

/// Weight codes packed once per plan into NR-channel, k-major panels.
pub struct PackedWeights {
    panels: Panels,
    /// Number of packed channels (panels are zero-padded past it).
    n_ch: usize,
    /// MAC depth shared by every channel.
    k: usize,
    /// Kernel path fixed at pack time.
    path: KernelPath,
    /// Nonzero fraction of the weight codes (1.0 - `QTensor::sparsity`).
    density: f64,
    /// Whether the i16 SIMD tile may run: every code fits i16 *and* no
    /// code is -32768 (which could overflow the i32 `madd` pair sum).
    i16_simd_ok: bool,
    /// Compressed panels (populated only on the `SparseSimd` path), values
    /// pre-widened to i64.
    sparse: SparsePanels<i64>,
}

impl PackedWeights {
    /// Pack rows of `w` in `order` (a permutation of `0..w.c_out`) with
    /// auto kernel dispatch (see [`KernelPath::choose`]). Returns `None`
    /// when some code exceeds `i32` — callers then keep the unpacked `i64`
    /// path.
    pub fn pack(w: &QTensor, order: &[usize]) -> Option<PackedWeights> {
        let density = 1.0 - w.sparsity();
        PackedWeights::pack_with(w, order, KernelPath::choose(density))
    }

    /// [`PackedWeights::pack`] with the kernel path pinned explicitly
    /// (plans and benches use this to force a specific dispatch).
    pub fn pack_with(w: &QTensor, order: &[usize], path: KernelPath) -> Option<PackedWeights> {
        debug_assert_eq!(order.len(), w.c_out);
        let lo = w.codes.iter().copied().min().unwrap_or(0);
        let hi = w.codes.iter().copied().max().unwrap_or(0);
        let (panels, i16_simd_ok) = if lo >= i16::MIN as i64 && hi <= i16::MAX as i64 {
            (Panels::I16(pack_panels(w, order, |v| v as i16)), lo > i16::MIN as i64)
        } else if lo >= i32::MIN as i64 && hi <= i32::MAX as i64 {
            (Panels::I32(pack_panels(w, order, |v| v as i32)), false)
        } else {
            return None;
        };
        let (n_ch, k) = (order.len(), w.k);
        let sparse = if path == KernelPath::SparseSimd {
            match &panels {
                Panels::I16(p) => widen_sparse(p, k, n_ch),
                Panels::I32(p) => widen_sparse(p, k, n_ch),
            }
        } else {
            SparsePanels::default()
        };
        let density = 1.0 - w.sparsity();
        Some(PackedWeights { panels, n_ch, k, path, density, i16_simd_ok, sparse })
    }

    /// Number of packed channels.
    pub fn channels(&self) -> usize {
        self.n_ch
    }

    /// The kernel path fixed at pack time.
    pub fn path(&self) -> KernelPath {
        self.path
    }

    /// Nonzero fraction of the packed weight codes.
    pub fn density(&self) -> f64 {
        self.density
    }

    /// Wide (i64) dot products of `rows` batch rows (`x`, flat row-major,
    /// `rows * k` long) against the packed-channel prefix `0..n_pref`,
    /// written to `out[ri * n_pref + ci]` (`ci` in packed order). Bit-exact
    /// against summing `x[ri] . w[order[ci]]` in any order, on every
    /// kernel path.
    pub fn gemm_into(&self, x: &[i64], rows: usize, n_pref: usize, out: &mut [i64]) {
        debug_assert!(n_pref <= self.n_ch);
        debug_assert_eq!(x.len(), rows * self.k);
        debug_assert_eq!(out.len(), rows * n_pref);
        if rows == 0 || n_pref == 0 {
            return;
        }
        let want_simd = self.path != KernelPath::Scalar
            && self.i16_simd_ok
            && matches!(self.panels, Panels::I16(_))
            && simd_available();
        X16.with(|cell| {
            let mut x16 = cell.borrow_mut();
            let use_simd = want_simd && narrow_i16(x, &mut x16);
            self.gemm_panels(x, &x16, use_simd, rows, n_pref, out);
        });
    }

    /// The per-panel tile loop behind [`PackedWeights::gemm_into`], with
    /// the narrowed operand and dispatch decision already resolved.
    fn gemm_panels(
        &self,
        x: &[i64],
        x16: &[i16],
        use_simd: bool,
        rows: usize,
        n_pref: usize,
        out: &mut [i64],
    ) {
        let k = self.k;
        for pi in 0..n_pref.div_ceil(NR) {
            let c0 = pi * NR;
            let nc = NR.min(n_pref - c0);
            let kind = self.sparse.kind(pi);
            let mut r0 = 0;
            while r0 < rows {
                let mr = MR.min(rows - r0);
                let mut acc = [0i64; MR * NR];
                match kind {
                    PanelKind::Sparse { start, end } => {
                        for e in start..end {
                            let kk = self.sparse.k_idx[e] as usize;
                            let lane = self.sparse.lane[e] as usize;
                            let wv = self.sparse.val[e];
                            for mi in 0..mr {
                                acc[mi * NR + lane] += x[(r0 + mi) * k + kk] * wv;
                            }
                        }
                    }
                    PanelKind::Dense => match &self.panels {
                        Panels::I16(p) if use_simd => kernel::dense_tile_i16(
                            &p[pi * k * NR..(pi + 1) * k * NR],
                            k,
                            x16,
                            r0,
                            mr,
                            &mut acc,
                        ),
                        Panels::I16(p) => {
                            scalar_tile(&p[pi * k * NR..(pi + 1) * k * NR], k, x, r0, mr, &mut acc)
                        }
                        Panels::I32(p) => {
                            scalar_tile(&p[pi * k * NR..(pi + 1) * k * NR], k, x, r0, mr, &mut acc)
                        }
                    },
                }
                for mi in 0..mr {
                    for j in 0..nc {
                        out[(r0 + mi) * n_pref + c0 + j] = acc[mi * NR + j];
                    }
                }
                r0 += mr;
            }
        }
    }
}

/// Feature-major weight columns at the narrowest width that holds every
/// code (`i32` feeds the SIMD delta kernels; wider codes keep an exact
/// scalar `i64` column).
enum FeatCols {
    I32(Vec<i32>),
    I64(Vec<i64>),
}

/// Weight codes packed once per stream session into contiguous
/// *feature-major* columns: `cols[j * c_out + c]` is `w[c][j]`, channels in
/// their **original** order (matching the engine's channel-indexed
/// accumulator layout, not the l1-sorted packed order).
///
/// This is the NNUE-style update operand: for a sparse input delta
/// `{(j, old, new)}` the maintained per-row accumulators move by
/// `acc[c] += w[c][j] * (new - old)` for every channel at once —
/// [`FeatureMajorWeights::apply_delta`] is exactly that column AXPY, exact
/// in i64 on every path and therefore bit-identical to recomputing the
/// dots from scratch. On [`KernelPath::SparseSimd`] the columns are stored
/// compressed (A2Q-constrained layers are 70–95% zeros, so most of each
/// column is skippable); on [`KernelPath::Simd`] a 4-lane widening
/// multiply-add kernel runs when the codes fit `i32`.
pub struct FeatureMajorWeights {
    cols: FeatCols,
    c_out: usize,
    k: usize,
    /// Kernel path fixed at pack time.
    path: KernelPath,
    /// Nonzero fraction of the weight codes.
    density: f64,
    /// CSC layout (populated only on the `SparseSimd` path): column `j`'s
    /// nonzeros are `ch/val[col_ptr[j]..col_ptr[j + 1]]`.
    col_ptr: Vec<usize>,
    ch: Vec<u32>,
    val: Vec<i64>,
}

impl FeatureMajorWeights {
    /// Pack `w` feature-major with auto kernel dispatch (see
    /// [`KernelPath::choose`]). Unlike [`PackedWeights::pack`] this never
    /// fails: codes beyond `i32` simply keep the exact scalar i64 column.
    pub fn pack(w: &QTensor) -> FeatureMajorWeights {
        let density = 1.0 - w.sparsity();
        FeatureMajorWeights::pack_with(w, KernelPath::choose(density))
    }

    /// [`FeatureMajorWeights::pack`] with the kernel path pinned
    /// explicitly (stream sessions pass their layer plan's resolved path
    /// so `A2Q_KERNEL` forcing reaches the delta kernels too).
    pub fn pack_with(w: &QTensor, path: KernelPath) -> FeatureMajorWeights {
        let (c_out, k) = (w.c_out, w.k);
        assert!(c_out <= u32::MAX as usize, "channel count {c_out} exceeds the CSC index width");
        let lo = w.codes.iter().copied().min().unwrap_or(0);
        let hi = w.codes.iter().copied().max().unwrap_or(0);
        let cols = if lo >= i32::MIN as i64 && hi <= i32::MAX as i64 {
            FeatCols::I32(feat_major(w, |v| v as i32))
        } else {
            FeatCols::I64(feat_major(w, |v| v))
        };
        let (col_ptr, ch, val) = if path == KernelPath::SparseSimd {
            let mut col_ptr = Vec::with_capacity(k + 1);
            let (mut ch, mut val) = (Vec::new(), Vec::new());
            col_ptr.push(0);
            for j in 0..k {
                for c in 0..c_out {
                    let v = w.codes[c * k + j];
                    if v != 0 {
                        ch.push(c as u32);
                        val.push(v);
                    }
                }
                col_ptr.push(ch.len());
            }
            (col_ptr, ch, val)
        } else {
            (Vec::new(), Vec::new(), Vec::new())
        };
        let density = 1.0 - w.sparsity();
        FeatureMajorWeights { cols, c_out, k, path, density, col_ptr, ch, val }
    }

    /// Number of output channels (the column length).
    pub fn channels(&self) -> usize {
        self.c_out
    }

    /// Number of input features (the column count).
    pub fn features(&self) -> usize {
        self.k
    }

    /// The kernel path fixed at pack time.
    pub fn path(&self) -> KernelPath {
        self.path
    }

    /// Nonzero fraction of the packed weight codes.
    pub fn density(&self) -> f64 {
        self.density
    }

    /// `acc[c] += w[c][feature] * d` for every channel `c`, exact in i64
    /// and bit-identical across paths (every product is exact, and `+` on
    /// disjoint channels has no ordering freedom). `acc` is indexed by
    /// original channel id and must be `channels()` long.
    pub fn apply_delta(&self, feature: usize, d: i64, acc: &mut [i64]) {
        debug_assert!(feature < self.k, "feature {feature} of {}", self.k);
        debug_assert_eq!(acc.len(), self.c_out);
        if d == 0 {
            return;
        }
        if self.path == KernelPath::SparseSimd {
            for e in self.col_ptr[feature]..self.col_ptr[feature + 1] {
                acc[self.ch[e] as usize] += self.val[e] * d;
            }
            return;
        }
        let (j0, j1) = (feature * self.c_out, (feature + 1) * self.c_out);
        match &self.cols {
            FeatCols::I32(cols) => kernel::delta_col_i32(
                &cols[j0..j1],
                d,
                acc,
                self.path == KernelPath::Simd && simd_available(),
            ),
            FeatCols::I64(cols) => kernel::delta_col_scalar_i64(&cols[j0..j1], d, acc),
        }
    }
}

/// Transpose `w`'s row-major codes into feature-major columns.
fn feat_major<T: Copy + Default>(w: &QTensor, cast: impl Fn(i64) -> T) -> Vec<T> {
    let (c_out, k) = (w.c_out, w.k);
    let mut cols = vec![T::default(); k * c_out];
    for c in 0..c_out {
        for (j, &code) in w.row(c).iter().enumerate() {
            cols[j * c_out + c] = cast(code);
        }
    }
    cols
}

/// Narrow the i64 `x` operand to the i16 SIMD range. Values outside
/// ±32767 (including i16::MIN, excluded for the same `madd` pair-sum
/// reason as the weights) reject the whole call back to the scalar tile.
fn narrow_i16(x: &[i64], buf: &mut Vec<i16>) -> bool {
    buf.clear();
    buf.reserve(x.len());
    for &v in x {
        if !(-(i16::MAX as i64)..=i16::MAX as i64).contains(&v) {
            return false;
        }
        buf.push(v as i16);
    }
    true
}

/// Build the compressed layout over packed panels and widen the stored
/// values to i64 so the sparse traversal is element-type agnostic.
fn widen_sparse<T: Copy + Default + PartialEq + Into<i64>>(
    panels: &[T],
    k: usize,
    n: usize,
) -> SparsePanels<i64> {
    let mut sp = SparsePanels::<T>::default();
    build_sparse_panels(&mut sp, panels, k, n);
    SparsePanels {
        kinds: sp.kinds,
        k_idx: sp.k_idx,
        lane: sp.lane,
        val: sp.val.into_iter().map(Into::into).collect(),
    }
}

/// Lay `w`'s rows out in `order` as NR-channel k-major panels, zero-padding
/// the tail panel (zero weights contribute nothing and are never read back).
fn pack_panels<T: Copy + Default>(
    w: &QTensor,
    order: &[usize],
    cast: impl Fn(i64) -> T,
) -> Vec<T> {
    let k = w.k;
    let n_panels = order.len().div_ceil(NR);
    let mut data = vec![T::default(); n_panels * k * NR];
    for (ci, &c) in order.iter().enumerate() {
        let (pi, j) = (ci / NR, ci % NR);
        let base = pi * k * NR;
        for (kk, &code) in w.row(c).iter().enumerate() {
            data[base + kk * NR + j] = cast(code);
        }
    }
    data
}

/// The original blocked scalar tile over one packed element type — the
/// reference every other path is pinned against.
fn scalar_tile<T: Copy + Into<i64>>(
    panel: &[T],
    k: usize,
    x: &[i64],
    r0: usize,
    mr: usize,
    acc: &mut [i64; MR * NR],
) {
    for kk in 0..k {
        let wrow = &panel[kk * NR..kk * NR + NR];
        for mi in 0..mr {
            let xv = x[(r0 + mi) * k + kk];
            let lane = &mut acc[mi * NR..mi * NR + NR];
            for j in 0..NR {
                let wv: i64 = wrow[j].into();
                lane[j] += xv * wv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    fn naive_dot(x: &[i64], w: &[i64]) -> i64 {
        x.iter().zip(w).map(|(a, b)| a * b).sum()
    }

    fn random_layer(c_out: usize, k: usize, amp: i64, rng: &mut Rng) -> QTensor {
        let w: Vec<f32> = (0..c_out * k)
            .map(|_| (rng.below((2 * amp + 1) as usize) as i64 - amp) as f32)
            .collect();
        QTensor::from_export(
            &Tensor::new(vec![c_out, k], w),
            &Tensor::new(vec![c_out, 1], vec![1.0; c_out]),
            &Tensor::from_vec(vec![0.0; c_out]),
        )
    }

    /// Like [`random_layer`] but keeping only `keep` of the entries
    /// nonzero, to exercise the sparse panel layout at known densities.
    fn sparse_layer(c_out: usize, k: usize, amp: i64, keep: f64, rng: &mut Rng) -> QTensor {
        let w: Vec<f32> = (0..c_out * k)
            .map(|_| {
                if rng.uniform() < keep {
                    (rng.below((2 * amp + 1) as usize) as i64 - amp) as f32
                } else {
                    0.0
                }
            })
            .collect();
        QTensor::from_export(
            &Tensor::new(vec![c_out, k], w),
            &Tensor::new(vec![c_out, 1], vec![1.0; c_out]),
            &Tensor::from_vec(vec![0.0; c_out]),
        )
    }

    #[test]
    fn gemm_matches_naive_dots_over_random_shapes_and_prefixes() {
        let mut rng = Rng::new(0x6E);
        for case in 0..40 {
            let c_out = 1 + rng.below(20);
            let k = rng.below(70);
            // amp 3000 forces the i16 packing on some cases and i32 on others
            let amp = if case % 2 == 0 { 7 } else { 40_000 };
            let w = random_layer(c_out, k, amp, &mut rng);
            let order: Vec<usize> = {
                let mut o: Vec<usize> = (0..c_out).collect();
                rng.shuffle(&mut o);
                o
            };
            let packed = PackedWeights::pack(&w, &order).expect("codes fit i32");
            assert_eq!(packed.channels(), c_out);

            let rows = rng.below(7);
            let x: Vec<i64> =
                (0..rows * k).map(|_| rng.below(511) as i64 - 255).collect();
            for n_pref in [0, 1, c_out / 2, c_out] {
                let mut out = vec![0i64; rows * n_pref];
                packed.gemm_into(&x, rows, n_pref, &mut out);
                for ri in 0..rows {
                    for ci in 0..n_pref {
                        let expect = naive_dot(&x[ri * k..(ri + 1) * k], w.row(order[ci]));
                        assert_eq!(
                            out[ri * n_pref + ci],
                            expect,
                            "case {case} row {ri} packed-ch {ci}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn forced_paths_are_bit_exact_across_densities_and_shapes() {
        let mut rng = Rng::new(0x51);
        for keep in [0.0, 0.5, 1.0] {
            for case in 0..12 {
                let c_out = 1 + rng.below(20);
                let k = rng.below(70);
                // i32 panels on every third case: SIMD must fall back and
                // still match.
                let amp = if case % 3 == 2 { 40_000 } else { 7 };
                let w = sparse_layer(c_out, k, amp, keep, &mut rng);
                let order: Vec<usize> = {
                    let mut o: Vec<usize> = (0..c_out).collect();
                    rng.shuffle(&mut o);
                    o
                };
                let rows = rng.below(7);
                let x: Vec<i64> =
                    (0..rows * k).map(|_| rng.below(511) as i64 - 255).collect();
                let scalar =
                    PackedWeights::pack_with(&w, &order, KernelPath::Scalar).expect("fits i32");
                for path in [KernelPath::Simd, KernelPath::SparseSimd] {
                    let packed = PackedWeights::pack_with(&w, &order, path).expect("fits i32");
                    assert_eq!(packed.path(), path);
                    assert!((packed.density() - (1.0 - w.sparsity())).abs() < 1e-12);
                    for n_pref in [0, 1, c_out / 2, c_out] {
                        let mut want = vec![0i64; rows * n_pref];
                        scalar.gemm_into(&x, rows, n_pref, &mut want);
                        let mut got = vec![0i64; rows * n_pref];
                        packed.gemm_into(&x, rows, n_pref, &mut got);
                        assert_eq!(
                            got, want,
                            "{path:?} keep={keep} case {case} n_pref={n_pref}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn simd_falls_back_when_codes_or_inputs_exceed_the_i16_tile_range() {
        // -32768 fits the i16 *pack* but is excluded from the SIMD tile
        // (madd pair-sum overflow); oversized x rejects narrowing. Both
        // must silently ride the scalar tile and stay bit-exact.
        let k = 11;
        let mut codes: Vec<i64> = (0..2 * k).map(|i| (i as i64 % 7) - 3).collect();
        codes[3] = i16::MIN as i64;
        let w = QTensor { codes, scales: vec![1.0; 2], bias: vec![0.0; 2], c_out: 2, k };
        let order = [0usize, 1];
        let scalar = PackedWeights::pack_with(&w, &order, KernelPath::Scalar).unwrap();
        let simd = PackedWeights::pack_with(&w, &order, KernelPath::Simd).unwrap();
        let x: Vec<i64> = (0..3 * k).map(|i| i as i64 * 17 - 80).collect();
        let (mut want, mut got) = (vec![0i64; 3 * 2], vec![0i64; 3 * 2]);
        scalar.gemm_into(&x, 3, 2, &mut want);
        simd.gemm_into(&x, 3, 2, &mut got);
        assert_eq!(got, want, "-32768 weight code");

        let w2 = QTensor {
            codes: (0..2 * k as i64).map(|i| i % 5 - 2).collect(),
            scales: vec![1.0; 2],
            bias: vec![0.0; 2],
            c_out: 2,
            k,
        };
        let scalar2 = PackedWeights::pack_with(&w2, &order, KernelPath::Scalar).unwrap();
        let simd2 = PackedWeights::pack_with(&w2, &order, KernelPath::Simd).unwrap();
        let xb: Vec<i64> = (0..3 * k).map(|i| i as i64 * 10_000).collect();
        scalar2.gemm_into(&xb, 3, 2, &mut want);
        simd2.gemm_into(&xb, 3, 2, &mut got);
        assert_eq!(got, want, "x beyond ±32767");
    }

    #[test]
    fn pack_rejects_codes_beyond_i32_on_every_path() {
        let w = QTensor {
            codes: vec![1, i32::MAX as i64 + 1],
            scales: vec![1.0],
            bias: vec![0.0],
            c_out: 1,
            k: 2,
        };
        assert!(PackedWeights::pack(&w, &[0]).is_none());
        for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
            assert!(PackedWeights::pack_with(&w, &[0], path).is_none(), "{path:?}");
        }
    }

    #[test]
    fn k_zero_and_empty_rows_are_fine() {
        let w = QTensor { codes: vec![], scales: vec![1.0; 3], bias: vec![0.0; 3], c_out: 3, k: 0 };
        for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
            let packed = PackedWeights::pack_with(&w, &[2, 0, 1], path).unwrap();
            let mut out = vec![7i64; 2 * 3];
            packed.gemm_into(&[], 2, 3, &mut out);
            assert_eq!(out, vec![0i64; 6], "{path:?}");
            let mut empty: Vec<i64> = vec![];
            packed.gemm_into(&[], 0, 3, &mut empty);
        }
    }

    #[test]
    fn feature_major_delta_matches_column_recompute_on_every_path() {
        let mut rng = Rng::new(0x77);
        for keep in [0.1, 0.6, 1.0] {
            for case in 0..8 {
                let c_out = 1 + rng.below(20);
                let k = 1 + rng.below(40);
                // i32-overflowing amp on every third case pins the scalar
                // i64 column fallback against the same reference.
                let amp = if case % 3 == 2 { 40_000 } else { 7 };
                let w = sparse_layer(c_out, k, amp, keep, &mut rng);
                for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
                    let fmw = FeatureMajorWeights::pack_with(&w, path);
                    assert_eq!(fmw.path(), path);
                    assert_eq!(fmw.channels(), c_out);
                    assert_eq!(fmw.features(), k);
                    assert!((fmw.density() - (1.0 - w.sparsity())).abs() < 1e-12);
                    let mut acc: Vec<i64> =
                        (0..c_out).map(|_| rng.below(1001) as i64 - 500).collect();
                    let mut want = acc.clone();
                    for _ in 0..4 {
                        let j = rng.below(k);
                        let d = rng.below(131_071) as i64 - 65_535;
                        fmw.apply_delta(j, d, &mut acc);
                        for (c, wv) in want.iter_mut().enumerate() {
                            *wv += w.row(c)[j] * d;
                        }
                    }
                    assert_eq!(acc, want, "{path:?} keep={keep} case {case}");
                }
            }
        }
    }

    #[test]
    fn feature_major_handles_codes_beyond_i32_and_zero_delta() {
        // Row 0 = [1, i32::MAX + 7], row 1 = [-3, 0]: forces the i64
        // column layout on every path (PackedWeights would reject this).
        let w = QTensor {
            codes: vec![1, i32::MAX as i64 + 7, -3, 0],
            scales: vec![1.0; 2],
            bias: vec![0.0; 2],
            c_out: 2,
            k: 2,
        };
        for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
            let fmw = FeatureMajorWeights::pack_with(&w, path);
            let mut acc = vec![10i64, -4];
            fmw.apply_delta(1, 0, &mut acc);
            assert_eq!(acc, vec![10, -4], "{path:?}: zero delta must be a no-op");
            fmw.apply_delta(1, -2, &mut acc);
            assert_eq!(acc, vec![10 - 2 * (i32::MAX as i64 + 7), -4], "{path:?} feature 1");
            fmw.apply_delta(0, 3, &mut acc);
            assert_eq!(acc, vec![10 - 2 * (i32::MAX as i64 + 7) + 3, -4 - 9], "{path:?} feature 0");
        }
    }
}
