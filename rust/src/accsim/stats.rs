//! Aggregated overflow statistics across a batch of simulated dot products.

/// Running overflow/error statistics for a simulated layer execution.
///
/// `PartialEq` is exact (including `abs_err_sum`): the engine's determinism
/// contract makes whole-struct equality the right assertion for
/// bit-identity tests.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OverflowStats {
    /// Total dot products simulated.
    pub dots: u64,
    /// Total MACs executed.
    pub macs: u64,
    /// MAC-level overflow events (partial sum left the P-bit range).
    pub overflow_events: u64,
    /// Dot products with at least one overflow.
    pub dots_overflowed: u64,
    /// Sum of |simulated - wide| over all outputs, in the integer domain.
    pub abs_err_sum: f64,
    /// Count of outputs compared for abs_err_sum.
    pub outputs: u64,
}

impl OverflowStats {
    pub fn record(&mut self, k: usize, overflows: u32, sim: i64, wide: i64) {
        self.dots += 1;
        self.macs += k as u64;
        self.overflow_events += overflows as u64;
        if overflows > 0 {
            self.dots_overflowed += 1;
        }
        // Difference in i128: a wrapped value near -2^62 against a large wide
        // value can push the i64 subtraction past i64::MIN (panic in debug,
        // wrong sum in release).
        self.abs_err_sum += (sim as i128 - wide as i128).unsigned_abs() as f64;
        self.outputs += 1;
    }

    pub fn merge(&mut self, other: &OverflowStats) {
        self.dots += other.dots;
        self.macs += other.macs;
        self.overflow_events += other.overflow_events;
        self.dots_overflowed += other.dots_overflowed;
        self.abs_err_sum += other.abs_err_sum;
        self.outputs += other.outputs;
    }

    /// Overflows per dot product (the y-axis of paper Fig. 2 top).
    pub fn overflow_rate(&self) -> f64 {
        if self.dots == 0 {
            0.0
        } else {
            self.overflow_events as f64 / self.dots as f64
        }
    }

    /// Fraction of dot products that overflowed at least once.
    pub fn dot_overflow_fraction(&self) -> f64 {
        if self.dots == 0 {
            0.0
        } else {
            self.dots_overflowed as f64 / self.dots as f64
        }
    }

    /// Mean absolute integer error versus the wide register.
    pub fn mean_abs_err(&self) -> f64 {
        if self.outputs == 0 {
            0.0
        } else {
            self.abs_err_sum / self.outputs as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates() {
        let mut s = OverflowStats::default();
        s.record(10, 0, 5, 5);
        s.record(10, 3, 2, 9);
        assert_eq!(s.dots, 2);
        assert_eq!(s.macs, 20);
        assert_eq!(s.overflow_rate(), 1.5);
        assert_eq!(s.dot_overflow_fraction(), 0.5);
        assert_eq!(s.mean_abs_err(), 3.5);
    }

    #[test]
    fn record_survives_extreme_sim_wide_gap() {
        // |sim - wide| > i64::MAX: must not overflow the subtraction.
        let mut s = OverflowStats::default();
        s.record(1, 1, i64::MIN + 10, i64::MAX - 10);
        assert!(s.abs_err_sum > 1.8e19);
    }

    #[test]
    fn merge() {
        let mut a = OverflowStats::default();
        a.record(4, 1, 0, 1);
        let mut b = OverflowStats::default();
        b.record(6, 0, 2, 2);
        a.merge(&b);
        assert_eq!(a.dots, 2);
        assert_eq!(a.macs, 10);
        assert_eq!(a.overflow_events, 1);
    }
}
