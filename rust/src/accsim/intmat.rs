//! Flat row-major integer matrix: the batch currency of the accsim kernel
//! engine.
//!
//! The original simulator passed inputs as `Vec<Vec<i64>>`, which scatters
//! rows across the heap and defeats both prefetching and autovectorization
//! of the bound-gated wide-dot fast path. `IntMatrix` is a single
//! contiguous `Vec<i64>` plus a shape, so every kernel works on flat
//! `&[i64]` slices (see EXPERIMENTS.md §Perf).

/// Row-major dense i64 matrix `[rows, cols]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IntMatrix {
    data: Vec<i64>,
    rows: usize,
    cols: usize,
}

impl IntMatrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        IntMatrix { data: vec![0; rows * cols], rows, cols }
    }

    /// Build from a flat row-major buffer; panics on element-count mismatch.
    pub fn from_flat(rows: usize, cols: usize, data: Vec<i64>) -> Self {
        assert_eq!(
            rows * cols,
            data.len(),
            "shape [{rows}, {cols}] vs {} elements",
            data.len()
        );
        IntMatrix { data, rows, cols }
    }

    /// Empty `[0, 0]` matrix with `cap` elements of reserved storage — the
    /// seed of a pooled request buffer that will be [`IntMatrix::reset`]
    /// many times without reallocating.
    pub fn with_capacity(cap: usize) -> Self {
        IntMatrix { data: Vec::with_capacity(cap), rows: 0, cols: 0 }
    }

    /// Reshape in place to an all-zero `[rows, cols]` matrix, reusing the
    /// existing storage. Steady-state allocation-free once the buffer has
    /// grown to the working-set shape (the pooled-decode contract of the
    /// serve hot path).
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.data.clear();
        self.data.resize(rows * cols, 0);
        self.rows = rows;
        self.cols = cols;
    }

    /// Reshape in place to an *empty* `[0, cols]` matrix, reusing storage:
    /// the starting state for [`IntMatrix::append_rows`] concatenation.
    pub fn clear_rows(&mut self, cols: usize) {
        self.data.clear();
        self.rows = 0;
        self.cols = cols;
    }

    /// Append every row of `other` (same `cols`); amortized allocation-free
    /// once capacity covers the largest batch concatenated through it.
    pub fn append_rows(&mut self, other: &IntMatrix) {
        assert_eq!(other.cols, self.cols, "append cols {} vs {}", other.cols, self.cols);
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
    }

    /// Take back the flat storage (pool recycling of a spent buffer).
    pub fn into_data(self) -> Vec<i64> {
        self.data
    }

    /// Gather nested rows into flat storage (migration helper; every row
    /// must have the same length).
    pub fn from_rows(rows: &[Vec<i64>]) -> Self {
        let cols = rows.first().map_or(0, |r| r.len());
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows: {} vs {cols}", r.len());
            data.extend_from_slice(r);
        }
        IntMatrix { data, rows: rows.len(), cols }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Flat row-major storage.
    pub fn data(&self) -> &[i64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i64] {
        &mut self.data
    }

    /// Row `r` as a flat slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[i64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Rows `r0..r1` as one contiguous flat slice (`(r1 - r0) * cols`
    /// long): the zero-copy row-block view the blocked kernel engine
    /// tiles over.
    #[inline]
    pub fn rows_slice(&self, r0: usize, r1: usize) -> &[i64] {
        debug_assert!(r0 <= r1 && r1 <= self.rows, "rows {r0}..{r1} of {}", self.rows);
        &self.data[r0 * self.cols..r1 * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [i64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Element at `(r, c)`; panics out of bounds.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i64 {
        assert!(r < self.rows && c < self.cols, "({r}, {c}) out of [{}, {}]", self.rows, self.cols);
        self.data[r * self.cols + c]
    }

    /// Overwrite the element at `(r, c)`; panics out of bounds.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i64) {
        assert!(r < self.rows && c < self.cols, "({r}, {c}) out of [{}, {}]", self.rows, self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Iterate rows as flat slices (handles `cols == 0` gracefully).
    pub fn iter_rows(&self) -> impl Iterator<Item = &[i64]> + '_ {
        let cols = self.cols;
        (0..self.rows).map(move |r| &self.data[r * cols..(r + 1) * cols])
    }

    /// Largest |element| in row `r` (0 for an empty row) — the `max|x|`
    /// factor of the per-channel overflow bound. Saturates at `i64::MAX`
    /// (only reachable for `i64::MIN` entries, far outside any N-bit grid).
    #[inline]
    pub fn row_abs_max(&self, r: usize) -> i64 {
        abs_max_of(self.row(r))
    }

    /// Largest |element| in the whole matrix.
    pub fn abs_max(&self) -> i64 {
        abs_max_of(&self.data)
    }
}

/// Saturating max-|v| of a slice.
#[inline]
pub(crate) fn abs_max_of(v: &[i64]) -> i64 {
    v.iter()
        .map(|x| x.unsigned_abs())
        .max()
        .unwrap_or(0)
        .min(i64::MAX as u64) as i64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_rows_round_trip() {
        let m = IntMatrix::from_rows(&[vec![1, 2, 3], vec![-4, 5, -6]]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.row(0), &[1, 2, 3]);
        assert_eq!(m.row(1), &[-4, 5, -6]);
        assert_eq!(m.data(), &[1, 2, 3, -4, 5, -6]);
        let collected: Vec<&[i64]> = m.iter_rows().collect();
        assert_eq!(collected.len(), 2);
        assert_eq!(collected[1], &[-4, 5, -6]);
    }

    #[test]
    fn abs_max_handles_i64_min() {
        // unsigned_abs avoids the i64::MIN negation overflow; the result
        // saturates instead of wrapping negative.
        let m = IntMatrix::from_flat(1, 2, vec![i64::MIN, 3]);
        assert_eq!(m.row_abs_max(0), i64::MAX);
        let small = IntMatrix::from_flat(1, 3, vec![-7, 2, 5]);
        assert_eq!(small.row_abs_max(0), 7);
        assert_eq!(small.abs_max(), 7);
    }

    #[test]
    fn rows_slice_views_contiguous_blocks() {
        let m = IntMatrix::from_rows(&[vec![1, 2], vec![3, 4], vec![5, 6]]);
        assert_eq!(m.rows_slice(0, 3), m.data());
        assert_eq!(m.rows_slice(1, 3), &[3, 4, 5, 6]);
        assert_eq!(m.rows_slice(2, 2), &[] as &[i64]);
        let z = IntMatrix::zeros(2, 0);
        assert_eq!(z.rows_slice(0, 2), &[] as &[i64]);
    }

    #[test]
    fn reset_and_append_reuse_storage() {
        let mut m = IntMatrix::with_capacity(12);
        let cap_ptr = m.data.as_ptr();
        m.reset(3, 4);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 4);
        assert!(m.data().iter().all(|&v| v == 0));
        assert_eq!(m.data.as_ptr(), cap_ptr, "reset within capacity must not reallocate");
        m.data_mut()[11] = 9;
        m.reset(2, 4);
        assert_eq!(m.rows(), 2);
        assert!(m.data().iter().all(|&v| v == 0), "reset must rezero reused storage");

        m.clear_rows(2);
        assert!(m.is_empty());
        m.append_rows(&IntMatrix::from_rows(&[vec![1, 2]]));
        m.append_rows(&IntMatrix::from_rows(&[vec![3, 4], vec![5, 6]]));
        assert_eq!(m.rows(), 3);
        assert_eq!(m.data(), &[1, 2, 3, 4, 5, 6]);
        assert_eq!(m.into_data(), vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn empty_shapes() {
        let m = IntMatrix::zeros(3, 0);
        assert_eq!(m.iter_rows().count(), 3);
        assert_eq!(m.row(1), &[] as &[i64]);
        assert_eq!(m.row_abs_max(0), 0);
        let e = IntMatrix::zeros(0, 4);
        assert!(e.is_empty());
        assert_eq!(e.abs_max(), 0);
    }
}
