//! Batched integer linear-layer simulation: the deployment-side forward pass
//! of a quantized dense layer under a P-bit accumulator, used to measure the
//! *actual* numerical error wraparound/saturation would inflict (Fig. 2).
//!
//! Inputs are a flat row-major [`IntMatrix`] `[batch, k]`. The fused
//! multi-width engine ([`super::engine`]) does the heavy lifting;
//! [`qlinear_forward_ref`] keeps the original MAC-by-MAC per-P walk as the
//! bit-exactness reference and the perf baseline (EXPERIMENTS.md §Perf).

use super::dot::{dot_accumulate, AccMode};
use super::engine::qlinear_forward_multi;
use super::intmat::IntMatrix;
use super::stats::OverflowStats;
use crate::quant::QTensor;
use crate::tensor::Tensor;

/// Result of a simulated quantized linear forward.
#[derive(Clone, Debug)]
pub struct MatmulStats {
    /// Dequantized outputs `[batch, c_out]` under the simulated register.
    pub out: Tensor,
    /// Dequantized outputs under the wide reference register.
    pub out_wide: Tensor,
    /// Overflow statistics across all batch x c_out dot products.
    pub stats: OverflowStats,
}

/// Forward one batch of *integer* inputs `x_int [batch, k]` through a
/// quantized linear layer under the given accumulator model.
///
/// `x_scale` is the (per-tensor) input scale so outputs dequantize to
/// `acc * s_w[c] * s_x + bias[c]` — the requantization step of Fig. 1 with
/// the bias applied in float, as FINN's threshold stage does.
///
/// Single-mode convenience over [`qlinear_forward_multi`]; sweeping several
/// accumulator widths should use the multi call directly so the MACs are
/// traversed once instead of once per width.
pub fn qlinear_forward(
    x_int: &IntMatrix,
    x_scale: f32,
    w: &QTensor,
    mode: AccMode,
) -> MatmulStats {
    qlinear_forward_multi(x_int, x_scale, w, std::slice::from_ref(&mode))
        .pop()
        .expect("one mode in, one result out")
}

/// The pre-engine scalar kernel: simulate one register model by walking
/// every MAC, one full traversal per call (so a P-sweep re-reads the
/// weights once per width). Kept verbatim as (a) the ground truth the fused
/// engine is property-tested against and (b) the baseline the speedup in
/// EXPERIMENTS.md §Perf is measured from.
pub fn qlinear_forward_ref(
    x_int: &IntMatrix,
    x_scale: f32,
    w: &QTensor,
    mode: AccMode,
) -> MatmulStats {
    let batch = x_int.rows();
    assert_eq!(x_int.cols(), w.k, "input cols {} vs k {}", x_int.cols(), w.k);
    let mut out = Tensor::zeros(vec![batch, w.c_out]);
    let mut out_wide = Tensor::zeros(vec![batch, w.c_out]);
    let mut stats = OverflowStats::default();

    for (bi, xb) in x_int.iter_rows().enumerate() {
        for c in 0..w.c_out {
            let row = w.row(c);
            let sim = dot_accumulate(xb, row, mode);
            let wide = dot_accumulate(xb, row, AccMode::Wide);
            stats.record(w.k, sim.overflows, sim.value, wide.value);
            let scale = w.scales[c] * x_scale;
            out.data_mut()[bi * w.c_out + c] = sim.value as f32 * scale + w.bias[c];
            out_wide.data_mut()[bi * w.c_out + c] =
                wide.value as f32 * scale + w.bias[c];
        }
    }
    MatmulStats { out, out_wide, stats }
}

/// The scalar requantization step every quantizer entry point shares:
/// rescale -> round to nearest -> clamp into the integer grid. One
/// definition, so the batch quantizer ([`quantize_inputs`]) and the
/// engine's buffer-to-buffer requantization
/// ([`crate::model::ActQuant::quantize_slice_into`]) are bit-identical by
/// construction.
#[inline]
pub fn quantize_code(v: f32, scale: f32, lo: i64, hi: i64) -> i64 {
    ((v / scale).round() as i64).clamp(lo, hi)
}

/// Quantize a float input batch to integers on an N-bit unsigned grid with
/// the given scale (the standard activation quantizer of paper Eq. 1, z=0),
/// producing the flat [`IntMatrix`] the kernel engine consumes.
pub fn quantize_inputs(x: &Tensor, scale: f32, n_bits: u32, x_signed: bool) -> IntMatrix {
    let (lo, hi) = if x_signed {
        (-(1i64 << (n_bits - 1)), (1i64 << (n_bits - 1)) - 1)
    } else {
        (0, (1i64 << n_bits) - 1)
    };
    let data = x.data().iter().map(|v| quantize_code(*v, scale, lo, hi)).collect();
    IntMatrix::from_flat(x.rows(), x.cols(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> QTensor {
        // 2 channels, k=3; channel 0 small weights, channel 1 big.
        let w = Tensor::new(vec![2, 3], vec![1.0, 1.0, 1.0, 100.0, 100.0, 100.0]);
        let s = Tensor::new(vec![2, 1], vec![1.0, 1.0]);
        let b = Tensor::from_vec(vec![0.0, 0.0]);
        QTensor::from_export(&w, &s, &b)
    }

    #[test]
    fn wide_equals_float_matmul() {
        let w = layer();
        let x = IntMatrix::from_rows(&[vec![1i64, 2, 3]]);
        let r = qlinear_forward(&x, 1.0, &w, AccMode::Wide);
        assert_eq!(r.out.data(), &[6.0, 600.0]);
        assert_eq!(r.stats.overflow_events, 0);
    }

    #[test]
    fn overflow_only_on_big_channel() {
        let w = layer();
        let x = IntMatrix::from_rows(&[vec![1i64, 1, 1]]);
        // 8-bit register: channel 0 sums to 3 (fine); channel 1 partials
        // 100, 200, 300 overflow.
        let r = qlinear_forward(&x, 1.0, &w, AccMode::Wrap { p_bits: 8 });
        assert_eq!(r.out.data()[0], 3.0);
        assert_ne!(r.out.data()[1], 300.0);
        assert_eq!(r.out_wide.data()[1], 300.0);
        assert!(r.stats.overflow_events >= 1);
        assert_eq!(r.stats.dot_overflow_fraction(), 0.5);
    }

    #[test]
    fn fused_wrapper_matches_reference() {
        let w = layer();
        let x = IntMatrix::from_rows(&[vec![1i64, 1, 1], vec![0, 1, 0]]);
        for mode in [
            AccMode::Wide,
            AccMode::Wrap { p_bits: 8 },
            AccMode::Saturate { p_bits: 8 },
            AccMode::SaturateFinal { p_bits: 8 },
        ] {
            let a = qlinear_forward(&x, 1.0, &w, mode);
            let b = qlinear_forward_ref(&x, 1.0, &w, mode);
            assert_eq!(a.out.data(), b.out.data(), "{mode:?}");
            assert_eq!(a.out_wide.data(), b.out_wide.data(), "{mode:?}");
            assert_eq!(a.stats.overflow_events, b.stats.overflow_events, "{mode:?}");
        }
    }

    #[test]
    fn input_quantization_clamps() {
        let x = Tensor::new(vec![1, 4], vec![0.0, 0.4, 0.9, 5.0]);
        let q = quantize_inputs(&x, 1.0, 1, false); // 1-bit unsigned: {0, 1}
        assert_eq!(q.row(0), &[0, 0, 1, 1]);
    }

    #[test]
    fn dequant_uses_both_scales_and_bias() {
        let w = Tensor::new(vec![1, 2], vec![2.0, -1.0]);
        let s = Tensor::new(vec![1, 1], vec![0.5]);
        let b = Tensor::from_vec(vec![1.0]);
        let q = QTensor::from_export(&w, &s, &b);
        let x = IntMatrix::from_rows(&[vec![3, 1]]);
        let r = qlinear_forward(&x, 0.25, &q, AccMode::Wide);
        // acc = 2*3 - 1 = 5; out = 5 * 0.5 * 0.25 + 1.0 = 1.625
        assert_eq!(r.out.data(), &[1.625]);
    }
}
