//! Batched integer linear-layer simulation: the deployment-side forward pass
//! of a quantized dense layer under a P-bit accumulator, used to measure the
//! *actual* numerical error wraparound/saturation would inflict (Fig. 2).

use super::dot::{dot_accumulate, AccMode};
use super::stats::OverflowStats;
use crate::quant::QTensor;
use crate::tensor::Tensor;

/// Result of a simulated quantized linear forward.
#[derive(Clone, Debug)]
pub struct MatmulStats {
    /// Dequantized outputs `[batch, c_out]` under the simulated register.
    pub out: Tensor,
    /// Dequantized outputs under the wide reference register.
    pub out_wide: Tensor,
    /// Overflow statistics across all batch x c_out dot products.
    pub stats: OverflowStats,
}

/// Forward one batch of *integer* inputs `x_int [batch, k]` through a
/// quantized linear layer under the given accumulator model.
///
/// `x_scale` is the (per-tensor) input scale so outputs dequantize to
/// `acc * s_w[c] * s_x + bias[c]` — the requantization step of Fig. 1 with
/// the bias applied in float, as FINN's threshold stage does.
pub fn qlinear_forward(
    x_int: &[Vec<i64>],
    x_scale: f32,
    w: &QTensor,
    mode: AccMode,
) -> MatmulStats {
    let batch = x_int.len();
    let mut out = Tensor::zeros(vec![batch, w.c_out]);
    let mut out_wide = Tensor::zeros(vec![batch, w.c_out]);
    let mut stats = OverflowStats::default();

    for (bi, xb) in x_int.iter().enumerate() {
        assert_eq!(xb.len(), w.k, "input length {} vs k {}", xb.len(), w.k);
        for c in 0..w.c_out {
            let row = w.row(c);
            let sim = dot_accumulate(xb, row, mode);
            let wide = dot_accumulate(xb, row, AccMode::Wide);
            stats.record(w.k, sim.overflows, sim.value, wide.value);
            let scale = w.scales[c] * x_scale;
            out.data_mut()[bi * w.c_out + c] = sim.value as f32 * scale + w.bias[c];
            out_wide.data_mut()[bi * w.c_out + c] =
                wide.value as f32 * scale + w.bias[c];
        }
    }
    MatmulStats { out, out_wide, stats }
}

/// Quantize a float input batch to integers on an N-bit unsigned grid with
/// the given scale (the standard activation quantizer of paper Eq. 1, z=0).
pub fn quantize_inputs(x: &Tensor, scale: f32, n_bits: u32, x_signed: bool) -> Vec<Vec<i64>> {
    let (lo, hi) = if x_signed {
        (-(1i64 << (n_bits - 1)), (1i64 << (n_bits - 1)) - 1)
    } else {
        (0, (1i64 << n_bits) - 1)
    };
    (0..x.rows())
        .map(|r| {
            x.row(r)
                .iter()
                .map(|v| ((v / scale).round() as i64).clamp(lo, hi))
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> QTensor {
        // 2 channels, k=3; channel 0 small weights, channel 1 big.
        let w = Tensor::new(vec![2, 3], vec![1.0, 1.0, 1.0, 100.0, 100.0, 100.0]);
        let s = Tensor::new(vec![2, 1], vec![1.0, 1.0]);
        let b = Tensor::from_vec(vec![0.0, 0.0]);
        QTensor::from_export(&w, &s, &b)
    }

    #[test]
    fn wide_equals_float_matmul() {
        let w = layer();
        let x = vec![vec![1i64, 2, 3]];
        let r = qlinear_forward(&x, 1.0, &w, AccMode::Wide);
        assert_eq!(r.out.data(), &[6.0, 600.0]);
        assert_eq!(r.stats.overflow_events, 0);
    }

    #[test]
    fn overflow_only_on_big_channel() {
        let w = layer();
        let x = vec![vec![1i64, 1, 1]];
        // 8-bit register: channel 0 sums to 3 (fine); channel 1 partials
        // 100, 200, 300 overflow.
        let r = qlinear_forward(&x, 1.0, &w, AccMode::Wrap { p_bits: 8 });
        assert_eq!(r.out.data()[0], 3.0);
        assert_ne!(r.out.data()[1], 300.0);
        assert_eq!(r.out_wide.data()[1], 300.0);
        assert!(r.stats.overflow_events >= 1);
        assert_eq!(r.stats.dot_overflow_fraction(), 0.5);
    }

    #[test]
    fn input_quantization_clamps() {
        let x = Tensor::new(vec![1, 4], vec![0.0, 0.4, 0.9, 5.0]);
        let q = quantize_inputs(&x, 1.0, 1, false); // 1-bit unsigned: {0, 1}
        assert_eq!(q[0], vec![0, 0, 1, 1]);
    }

    #[test]
    fn dequant_uses_both_scales_and_bias() {
        let w = Tensor::new(vec![1, 2], vec![2.0, -1.0]);
        let s = Tensor::new(vec![1, 1], vec![0.5]);
        let b = Tensor::from_vec(vec![1.0]);
        let q = QTensor::from_export(&w, &s, &b);
        let r = qlinear_forward(&[vec![3, 1]], 0.25, &q, AccMode::Wide);
        // acc = 2*3 - 1 = 5; out = 5 * 0.5 * 0.25 + 1.0 = 1.625
        assert_eq!(r.out.data(), &[1.625]);
    }
}
