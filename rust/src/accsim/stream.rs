//! NNUE-style incremental accumulation sessions for streaming sparse-delta
//! inference.
//!
//! The engine's batch path recomputes every dot product from scratch on
//! every call. Streaming workloads (the `a2q serve` scenario) change only a
//! handful of input features between consecutive forwards — exactly the
//! regime efficient-evaluation NNUE engines exploit: *accumulate once, then
//! update only changed features*. A session here owns the current input
//! batch plus one exact i64 accumulator per `(row, channel)` of the first
//! layer, and a sparse delta `{(row, feature, old, new)}` moves every
//! channel of that row by one feature-major column AXPY,
//! `acc[c] += w[c][j] * (new - old)` (see
//! [`super::gemm::FeatureMajorWeights`], dispatched through the layer's
//! [`crate::linalg::KernelPath`]). A forward then hands the maintained
//! accumulators to the engine, which skips its safe-span GEMM (stage 2) and
//! resolves provably-safe channels straight from them.
//!
//! **Determinism contract.** The incremental path is bit-identical to a
//! full batch recompute — outputs *and* every [`super::OverflowStats`]
//! counter — at any thread count and under any forced kernel path. This is
//! by construction, not by tolerance: every delta product is exact in i64
//! (i64 addition is commutative and associative, so maintained accumulators
//! equal recomputed dots exactly), and the engine re-runs its per-row
//! safety partition (stage 1) and fused register simulation (stage 3)
//! against the session's *current* input matrix — only the arithmetic
//! source of the already-exact safe-span wides changes. A delta that grows
//! a row's `max|x|` therefore flips channels from the safe prefix back
//! into the simulated remainder exactly as a recompute would, and the
//! Eq. 15 guarantee is re-checked, never cached.
//!
//! **Refresh-threshold policy.** Incremental updates win only while deltas
//! are sparse: one delta costs `O(c_out)` (dense column) or
//! `O(nnz(column))` (sparse path), so a tick touching most of a row's `k`
//! features costs more than the packed GEMM that recomputes the row in one
//! pass. Each [`StreamSession::apply`] call counts deltas per row; rows at
//! or above `threshold * k` deltas are *refreshed* — recomputed through the
//! layer's batch kernel ([`LayerKernel::accumulate_rows`]) — while rows
//! below it take the incremental column walks. The default threshold is
//! [`DEFAULT_REFRESH_THRESHOLD`], overridable per process with the
//! `A2Q_STREAM_REFRESH` environment variable (read at session creation,
//! never cached: `0.0` refreshes every touched row, any value `> 1.0`
//! disables refresh entirely) and per session with
//! `with_refresh_threshold` (which wins over the environment). Either way
//! the result is bit-identical; the threshold only picks which exact
//! arithmetic computes it.
//!
//! [`StreamSession`] streams a whole [`NetworkPlan`] (accumulators are
//! maintained for layer 0, whose input the session tracks; deeper layers
//! recompute as usual — the NNUE idiom, where only the first layer sees
//! the sparse input encoding); [`LayerStreamSession`] is the single-layer
//! variant over a [`LayerPlan`]. Throughput history lives in EXPERIMENTS.md
//! §Perf-Stream and the `accsim/stream_delta_*` rows of BENCH_accsim.json.

use std::collections::HashMap;

use super::engine::{worker_count, LayerKernel, LayerPlan, NetworkPlan, NetworkStats};
use super::gemm::FeatureMajorWeights;
use super::intmat::IntMatrix;
use super::matmul::MatmulStats;
use crate::quant::QTensor;

/// A rejected delta tick: the client handed the session something that
/// cannot be applied. Server-grade callers (the `a2q serve` ingest path)
/// reply with these instead of aborting the process — a bad client delta is
/// load to shed, not a crash. The session is left **unchanged** on error:
/// validation runs over the whole tick before any state moves, so a
/// rejected tick can simply be dropped and the session keeps serving.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamError {
    /// `delta.row` is outside the session's batch.
    RowOutOfRange { row: usize, rows: usize },
    /// `delta.feature` is outside the tracked layer's input features.
    FeatureOutOfRange { feature: usize, features: usize },
    /// `delta.old` does not match the value the session holds (the
    /// self-checking protocol: a producer that dropped or reordered ticks
    /// fails loudly instead of silently diverging from the batch
    /// reference).
    StaleDelta { row: usize, feature: usize, held: i64, claimed: i64 },
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::RowOutOfRange { row, rows } => {
                write!(f, "delta row {row} out of range (batch has {rows} rows)")
            }
            StreamError::FeatureOutOfRange { feature, features } => {
                write!(f, "delta feature {feature} out of range (layer has {features} features)")
            }
            StreamError::StaleDelta { row, feature, held, claimed } => write!(
                f,
                "stale delta: row {row} feature {feature} holds {held} but delta claims old {claimed}"
            ),
        }
    }
}

impl std::error::Error for StreamError {}

/// Default refresh threshold: a row is refreshed through the batch kernel
/// once a single `apply` call delivers deltas to at least half its
/// features.
pub const DEFAULT_REFRESH_THRESHOLD: f64 = 0.5;

/// One sparse input change: `x[row][feature]` moves from `old` to `new`
/// (integer codes on the layer-0 input grid).
///
/// Carrying `old` makes the protocol self-checking: the session asserts it
/// against its own state, so a producer that drops or reorders ticks fails
/// loudly instead of silently diverging from the batch reference. Repeated
/// deltas to the same `(row, feature)` within one call chain in order
/// (each `old` must match the running value).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamDelta {
    /// Batch row the change applies to.
    pub row: usize,
    /// Input feature (column of the session's input matrix).
    pub feature: usize,
    /// The code currently stored at `(row, feature)`.
    pub old: i64,
    /// The replacement code.
    pub new: i64,
}

/// Parse a refresh threshold, falling back to the default on anything
/// non-finite, negative, or unparseable.
fn refresh_threshold_from(s: Option<&str>) -> f64 {
    s.and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|t| t.is_finite() && *t >= 0.0)
        .unwrap_or(DEFAULT_REFRESH_THRESHOLD)
}

/// The process-wide default threshold: `A2Q_STREAM_REFRESH` when set and
/// valid, else [`DEFAULT_REFRESH_THRESHOLD`]. Read on every call (session
/// creation is off the hot path), so tests and long-lived processes see
/// changes immediately — unlike the OnceLock-cached `A2Q_KERNEL`.
fn env_refresh_threshold() -> f64 {
    let v = std::env::var("A2Q_STREAM_REFRESH").ok();
    refresh_threshold_from(v.as_deref())
}

/// The session core shared by [`StreamSession`] and [`LayerStreamSession`]:
/// the current input batch, the maintained per-`(row, channel)` exact wide
/// accumulators of the tracked layer, and the feature-major update operand.
struct StreamAcc {
    /// Current input codes (updated in place by deltas).
    x: IntMatrix,
    /// Exact i64 accumulators, `rows * c_out`, original channel order —
    /// invariant: `acc[r * c_out + c] == x.row(r) . w.row(c)` after every
    /// `apply`.
    acc: Vec<i64>,
    /// Feature-major columns of the tracked layer's weights.
    fmw: FeatureMajorWeights,
    /// Rows receiving `>= refresh_threshold * k` deltas in one call are
    /// recomputed through the batch kernel instead of updated per column.
    refresh_threshold: f64,
    /// Cumulative count of row refreshes (observability for the policy).
    refreshes: u64,
    /// Per-row delta counts for the current `apply` call (reset after).
    counts: Vec<u32>,
    /// Rows with a nonzero count in the current `apply` call.
    touched: Vec<usize>,
    /// Scratch for the refresh GEMM.
    scratch: Vec<i64>,
    /// Validation scratch: `(row, feature) -> running value` over one tick.
    pending: HashMap<(usize, usize), i64>,
}

impl StreamAcc {
    fn new(x: IntMatrix, fmw: FeatureMajorWeights, kern: &LayerKernel, w: &QTensor) -> StreamAcc {
        let rows = x.rows();
        let c_out = fmw.channels();
        let mut st = StreamAcc {
            acc: vec![0; rows * c_out],
            fmw,
            refresh_threshold: env_refresh_threshold(),
            refreshes: 0,
            counts: vec![0; rows],
            touched: Vec::new(),
            scratch: Vec::new(),
            pending: HashMap::new(),
            x,
        };
        kern.accumulate_rows(w, st.x.data(), rows, &mut st.scratch, &mut st.acc);
        st
    }

    /// Validate one tick against the session's *current* state without
    /// mutating anything: every index in range, every `old` matching the
    /// running value (repeated deltas to one cell chain in order through
    /// the pending map). Returning `Ok` here guarantees the mutation pass
    /// cannot fail, which is what makes `apply` atomic per tick.
    fn validate(&mut self, deltas: &[StreamDelta]) -> Result<(), StreamError> {
        let rows = self.x.rows();
        let k = self.x.cols();
        self.pending.clear();
        for d in deltas {
            if d.row >= rows {
                self.pending.clear();
                return Err(StreamError::RowOutOfRange { row: d.row, rows });
            }
            if d.feature >= k {
                self.pending.clear();
                return Err(StreamError::FeatureOutOfRange { feature: d.feature, features: k });
            }
            let cur = self
                .pending
                .get(&(d.row, d.feature))
                .copied()
                .unwrap_or_else(|| self.x.get(d.row, d.feature));
            if cur != d.old {
                self.pending.clear();
                return Err(StreamError::StaleDelta {
                    row: d.row,
                    feature: d.feature,
                    held: cur,
                    claimed: d.old,
                });
            }
            self.pending.insert((d.row, d.feature), d.new);
        }
        self.pending.clear();
        Ok(())
    }

    /// Apply one tick of deltas: validate the whole tick first (rejecting
    /// it unapplied on any bad delta), then count per-row touches and
    /// either walk the touched columns (below the refresh cap) or recompute
    /// the row through the batch kernel (at or above it).
    fn apply(
        &mut self,
        kern: &LayerKernel,
        w: &QTensor,
        deltas: &[StreamDelta],
    ) -> Result<(), StreamError> {
        self.validate(deltas)?;
        let k = self.x.cols();
        let c_out = self.fmw.channels();
        for d in deltas {
            if self.counts[d.row] == 0 {
                self.touched.push(d.row);
            }
            self.counts[d.row] = self.counts[d.row].saturating_add(1);
        }
        let cap = self.refresh_threshold * k as f64;
        for d in deltas {
            // Internal invariant, not client validation: `validate` already
            // accepted the tick, so the chain must hold here.
            debug_assert_eq!(
                self.x.get(d.row, d.feature),
                d.old,
                "validated delta went stale mid-apply"
            );
            self.x.set(d.row, d.feature, d.new);
            if (self.counts[d.row] as f64) < cap {
                let arow = &mut self.acc[d.row * c_out..(d.row + 1) * c_out];
                self.fmw.apply_delta(d.feature, d.new - d.old, arow);
            }
        }
        for &r in &self.touched {
            if (self.counts[r] as f64) >= cap {
                self.refreshes += 1;
                kern.accumulate_rows(
                    w,
                    self.x.row(r),
                    1,
                    &mut self.scratch,
                    &mut self.acc[r * c_out..(r + 1) * c_out],
                );
            }
            self.counts[r] = 0;
        }
        self.touched.clear();
        Ok(())
    }
}

/// Incremental streaming session over a whole [`NetworkPlan`]: maintains
/// exact layer-0 accumulators across sparse input deltas and forwards the
/// current batch bit-identically to [`NetworkPlan::execute`] on the same
/// input. See the module doc for the policy and determinism contract.
pub struct StreamSession<'p, 'n> {
    plan: &'p NetworkPlan<'n>,
    st: StreamAcc,
}

impl<'p, 'n> StreamSession<'p, 'n> {
    /// Open a session on `plan` with initial batch `x` (quantized layer-0
    /// input codes, `[batch, input_dim]`), paying one full layer-0
    /// accumulation up front. Panics on an empty network or a shape
    /// mismatch.
    pub fn new(plan: &'p NetworkPlan<'n>, x: IntMatrix) -> StreamSession<'p, 'n> {
        assert!(plan.depth() >= 1, "stream session needs at least one layer");
        assert_eq!(
            x.cols(),
            plan.net.input_dim(),
            "input cols {} vs network input dim {}",
            x.cols(),
            plan.net.input_dim()
        );
        let kern = &plan.kernels[0];
        let w0 = &plan.net.layers[0].weights;
        // Pack with the plan's resolved path so `A2Q_KERNEL` / forced
        // dispatch reaches the delta kernels too.
        let fmw = FeatureMajorWeights::pack_with(w0, kern.choice.path);
        StreamSession { st: StreamAcc::new(x, fmw, kern, w0), plan }
    }

    /// Override the refresh threshold for this session (wins over the
    /// `A2Q_STREAM_REFRESH` environment default). `0.0` refreshes every
    /// touched row; any value `> 1.0` never refreshes.
    pub fn with_refresh_threshold(mut self, t: f64) -> Self {
        assert!(t.is_finite() && t >= 0.0, "refresh threshold {t} must be finite and >= 0");
        self.st.refresh_threshold = t;
        self
    }

    /// Apply one tick of sparse deltas to the session's input (and its
    /// maintained layer-0 accumulators). A tick with an out-of-range index
    /// or a stale `old` value is rejected whole — typed [`StreamError`],
    /// session unchanged — so a bad client delta never aborts a server.
    pub fn apply(&mut self, deltas: &[StreamDelta]) -> Result<(), StreamError> {
        self.st.apply(&self.plan.kernels[0], &self.plan.net.layers[0].weights, deltas)
    }

    /// The session's current input batch.
    pub fn x(&self) -> &IntMatrix {
        &self.st.x
    }

    /// The active refresh threshold.
    pub fn refresh_threshold(&self) -> f64 {
        self.st.refresh_threshold
    }

    /// Cumulative number of row refreshes taken instead of incremental
    /// updates.
    pub fn refreshed_rows(&self) -> u64 {
        self.st.refreshes
    }

    /// Forward the current batch with an explicit worker count —
    /// bit-identical to `plan.execute_threads(session.x(), threads)` at
    /// any `threads`.
    pub fn forward_threads(&self, threads: usize) -> Vec<NetworkStats> {
        self.plan.execute_threads_l0(&self.st.x, threads, Some(&self.st.acc))
    }

    /// Forward the current batch, choosing the worker count exactly as
    /// [`NetworkPlan::execute`] does.
    pub fn forward(&self) -> Vec<NetworkStats> {
        self.forward_threads(worker_count(
            self.st.x.rows(),
            self.plan.net.macs_per_row(),
            1,
            self.plan.modes().len(),
        ))
    }
}

/// Single-layer incremental streaming session over a [`LayerPlan`]: the
/// [`StreamSession`] contract for one quantized layer (bit-identical to
/// [`LayerPlan::execute`] on the same input).
pub struct LayerStreamSession<'p, 'w> {
    plan: &'p LayerPlan<'w>,
    x_scale: f32,
    st: StreamAcc,
}

impl<'p, 'w> LayerStreamSession<'p, 'w> {
    /// Open a session on `plan` with initial batch `x` (integer input
    /// codes at scale `x_scale`), paying one full accumulation up front.
    pub fn new(plan: &'p LayerPlan<'w>, x: IntMatrix, x_scale: f32) -> LayerStreamSession<'p, 'w> {
        let w = plan.w;
        assert_eq!(x.cols(), w.k, "input cols {} vs layer k {}", x.cols(), w.k);
        let fmw = FeatureMajorWeights::pack_with(w, plan.kern.choice.path);
        LayerStreamSession { st: StreamAcc::new(x, fmw, &plan.kern, w), x_scale, plan }
    }

    /// Override the refresh threshold for this session (wins over the
    /// `A2Q_STREAM_REFRESH` environment default).
    pub fn with_refresh_threshold(mut self, t: f64) -> Self {
        assert!(t.is_finite() && t >= 0.0, "refresh threshold {t} must be finite and >= 0");
        self.st.refresh_threshold = t;
        self
    }

    /// Apply one tick of sparse deltas. A tick with an out-of-range index
    /// or a stale `old` value is rejected whole — typed [`StreamError`],
    /// session unchanged.
    pub fn apply(&mut self, deltas: &[StreamDelta]) -> Result<(), StreamError> {
        self.st.apply(&self.plan.kern, self.plan.w, deltas)
    }

    /// The session's current input batch.
    pub fn x(&self) -> &IntMatrix {
        &self.st.x
    }

    /// The active refresh threshold.
    pub fn refresh_threshold(&self) -> f64 {
        self.st.refresh_threshold
    }

    /// Cumulative number of row refreshes taken instead of incremental
    /// updates.
    pub fn refreshed_rows(&self) -> u64 {
        self.st.refreshes
    }

    /// Forward the current batch with an explicit worker count —
    /// bit-identical to `plan.execute_threads(session.x(), x_scale,
    /// threads)` at any `threads`.
    pub fn forward_threads(&self, threads: usize) -> Vec<MatmulStats> {
        self.plan.execute_threads_acc(&self.st.x, self.x_scale, threads, Some(&self.st.acc))
    }

    /// Forward the current batch, choosing the worker count exactly as
    /// [`LayerPlan::execute`] does.
    pub fn forward(&self) -> Vec<MatmulStats> {
        let w = self.plan.w;
        self.forward_threads(worker_count(
            self.st.x.rows(),
            w.c_out,
            w.k,
            self.plan.modes().len(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accsim::AccMode;
    use crate::rng::Rng;
    use crate::testutil::psweep_constrained_layer;

    const X_SCALE: f32 = 0.05;

    fn modes() -> Vec<AccMode> {
        vec![AccMode::Wide, AccMode::Wrap { p_bits: 14 }, AccMode::Saturate { p_bits: 12 }]
    }

    fn input(rows: usize, k: usize, n_bits: u32, seed: u64) -> IntMatrix {
        let mut rng = Rng::new(seed);
        IntMatrix::from_flat(
            rows,
            k,
            (0..rows * k).map(|_| rng.below(1usize << n_bits) as i64).collect(),
        )
    }

    /// The session's forward must equal the batch recompute on the
    /// session's current input — outputs and every stats counter — at
    /// several pinned thread counts.
    fn assert_matches_batch(session: &LayerStreamSession<'_, '_>, plan: &LayerPlan<'_>, ctx: &str) {
        for threads in [1, 2, 7] {
            let want = plan.execute_threads(session.x(), X_SCALE, threads);
            let got = session.forward_threads(threads);
            assert_eq!(got.len(), want.len(), "{ctx} t={threads}");
            for (mi, (g, w)) in got.iter().zip(&want).enumerate() {
                let tag = format!("{ctx} t={threads} mode {mi}");
                assert_eq!(g.out.data(), w.out.data(), "{tag}");
                assert_eq!(g.out_wide.data(), w.out_wide.data(), "{tag}");
                assert_eq!(g.stats.dots, w.stats.dots, "{tag}");
                assert_eq!(g.stats.macs, w.stats.macs, "{tag}");
                assert_eq!(g.stats.overflow_events, w.stats.overflow_events, "{tag}");
                assert_eq!(g.stats.dots_overflowed, w.stats.dots_overflowed, "{tag}");
                assert_eq!(g.stats.abs_err_sum, w.stats.abs_err_sum, "{tag}");
                assert_eq!(g.stats.outputs, w.stats.outputs, "{tag}");
            }
        }
    }

    #[test]
    fn empty_delta_set_is_a_no_op() {
        let w = psweep_constrained_layer(12, 24, 14, 4, 3);
        let plan = LayerPlan::new(&w, &modes());
        let mut s = LayerStreamSession::new(&plan, input(5, 24, 4, 9), X_SCALE);
        let before = s.x().clone();
        s.apply(&[]).unwrap();
        assert_eq!(*s.x(), before);
        assert_eq!(s.refreshed_rows(), 0);
        assert_matches_batch(&s, &plan, "empty tick");
    }

    #[test]
    fn repeated_deltas_to_one_feature_chain_in_one_call() {
        let w = psweep_constrained_layer(12, 24, 14, 4, 3);
        let plan = LayerPlan::new(&w, &modes());
        let mut s =
            LayerStreamSession::new(&plan, input(5, 24, 4, 9), X_SCALE).with_refresh_threshold(1.1);
        let a = s.x().get(2, 7);
        s.apply(&[
            StreamDelta { row: 2, feature: 7, old: a, new: a + 3 },
            StreamDelta { row: 2, feature: 7, old: a + 3, new: 1 },
            StreamDelta { row: 2, feature: 7, old: 1, new: 9 },
        ])
        .unwrap();
        assert_eq!(s.x().get(2, 7), 9);
        assert_eq!(s.refreshed_rows(), 0, "threshold > 1 must never refresh");
        assert_matches_batch(&s, &plan, "chained repeats");
    }

    #[test]
    fn full_row_tick_refreshes_and_stays_bit_exact() {
        let w = psweep_constrained_layer(12, 24, 14, 4, 3);
        let plan = LayerPlan::new(&w, &modes());
        // Pin the default threshold explicitly: the CI kernel matrix runs
        // the suite under forced A2Q_STREAM_REFRESH values.
        let mut s = LayerStreamSession::new(&plan, input(5, 24, 4, 9), X_SCALE)
            .with_refresh_threshold(DEFAULT_REFRESH_THRESHOLD);
        // Every feature of row 1 changes: at the default threshold this
        // must take the batch-recompute fallback, not 24 column walks.
        let tick: Vec<StreamDelta> = (0..24)
            .map(|j| StreamDelta { row: 1, feature: j, old: s.x().get(1, j), new: (j as i64) % 13 })
            .collect();
        s.apply(&tick).unwrap();
        assert_eq!(s.refreshed_rows(), 1);
        assert_matches_batch(&s, &plan, "full-row refresh");
    }

    #[test]
    fn always_refresh_threshold_refreshes_every_touched_row() {
        let w = psweep_constrained_layer(12, 24, 14, 4, 3);
        let plan = LayerPlan::new(&w, &modes());
        let mut s =
            LayerStreamSession::new(&plan, input(5, 24, 4, 9), X_SCALE).with_refresh_threshold(0.0);
        let (a, b) = (s.x().get(0, 3), s.x().get(4, 11));
        s.apply(&[
            StreamDelta { row: 0, feature: 3, old: a, new: a + 1 },
            StreamDelta { row: 4, feature: 11, old: b, new: 0 },
        ])
        .unwrap();
        assert_eq!(s.refreshed_rows(), 2);
        assert_matches_batch(&s, &plan, "always-refresh");
    }

    #[test]
    fn deltas_flip_rows_between_safe_and_simulated_partitions() {
        // Codes quantized for 4-bit inputs, then a delta pushes one row's
        // max|x| far past the grid: channels that were provably safe under
        // Eq. 15 fall back into the register-simulated remainder, and the
        // session must track that through its *updated* per-row bound
        // check — overflow counters move, and still match the recompute.
        let w = psweep_constrained_layer(10, 16, 14, 4, 5);
        let plan = LayerPlan::new(&w, &modes());
        let mut s =
            LayerStreamSession::new(&plan, input(4, 16, 4, 2), X_SCALE).with_refresh_threshold(1.1);
        let base = plan.execute_threads(s.x(), X_SCALE, 1);
        // Spike a feature some channel actually reads, so the 2^20 code is
        // guaranteed to reach (and overflow) the 14-bit wrap register.
        let j = (0..16)
            .find(|&j| (0..10).any(|c| w.row(c)[j] != 0))
            .expect("constrained layer has a nonzero column");
        let old = s.x().get(2, j);
        s.apply(&[StreamDelta { row: 2, feature: j, old, new: 1 << 20 }]).unwrap();
        assert_matches_batch(&s, &plan, "safe -> simulated");
        let spiked = plan.execute_threads(s.x(), X_SCALE, 1);
        assert!(
            spiked[1].stats.overflow_events > base[1].stats.overflow_events,
            "the spike must actually push the wrap register into overflow"
        );
        // And back: restoring the old code must re-enter the safe span.
        s.apply(&[StreamDelta { row: 2, feature: j, old: 1 << 20, new: old }]).unwrap();
        assert_matches_batch(&s, &plan, "simulated -> safe");
    }

    #[test]
    fn bad_deltas_return_typed_errors_and_leave_the_session_unchanged() {
        let w = psweep_constrained_layer(6, 8, 14, 4, 3);
        let plan = LayerPlan::new(&w, &modes());
        let mut s = LayerStreamSession::new(&plan, input(2, 8, 4, 9), X_SCALE);
        let cur = s.x().get(0, 0);
        let before = s.x().clone();

        // Stale old value: the error names both sides of the mismatch.
        let err = s.apply(&[StreamDelta { row: 0, feature: 0, old: cur + 1, new: 0 }]).unwrap_err();
        assert_eq!(err, StreamError::StaleDelta { row: 0, feature: 0, held: cur, claimed: cur + 1 });
        assert!(err.to_string().contains("stale delta"), "{err}");

        // Out-of-range row and feature.
        let err = s.apply(&[StreamDelta { row: 2, feature: 0, old: 0, new: 0 }]).unwrap_err();
        assert_eq!(err, StreamError::RowOutOfRange { row: 2, rows: 2 });
        let err = s.apply(&[StreamDelta { row: 0, feature: 8, old: 0, new: 0 }]).unwrap_err();
        assert_eq!(err, StreamError::FeatureOutOfRange { feature: 8, features: 8 });

        // A tick where only the *last* delta is bad must mutate nothing:
        // validation covers the whole tick before any state moves.
        let good = s.x().get(1, 3);
        let err = s
            .apply(&[
                StreamDelta { row: 1, feature: 3, old: good, new: good + 1 },
                StreamDelta { row: 0, feature: 0, old: cur + 7, new: 0 },
            ])
            .unwrap_err();
        assert!(matches!(err, StreamError::StaleDelta { .. }), "{err:?}");
        assert_eq!(*s.x(), before, "rejected tick must leave the session untouched");
        assert_eq!(s.refreshed_rows(), 0);
        assert_matches_batch(&s, &plan, "after rejections");

        // The session keeps serving: a subsequent valid tick applies cleanly.
        s.apply(&[StreamDelta { row: 0, feature: 0, old: cur, new: cur + 2 }]).unwrap();
        assert_eq!(s.x().get(0, 0), cur + 2);
        assert_matches_batch(&s, &plan, "valid tick after rejections");
    }

    #[test]
    fn refresh_threshold_parsing_and_precedence() {
        assert_eq!(refresh_threshold_from(Some("0.25")), 0.25);
        assert_eq!(refresh_threshold_from(Some(" 1.5 ")), 1.5);
        assert_eq!(refresh_threshold_from(Some("0")), 0.0);
        // Invalid values fall back to the default instead of poisoning the
        // policy: negative, NaN, infinity, garbage, empty, absent.
        for bad in [Some("-1"), Some("NaN"), Some("inf"), Some("fast"), Some(""), None] {
            assert_eq!(refresh_threshold_from(bad), DEFAULT_REFRESH_THRESHOLD, "{bad:?}");
        }
        // The builder wins over whatever the environment said.
        let w = psweep_constrained_layer(6, 8, 14, 4, 3);
        let plan = LayerPlan::new(&w, &modes());
        let s = LayerStreamSession::new(&plan, input(2, 8, 4, 9), X_SCALE)
            .with_refresh_threshold(0.75);
        assert_eq!(s.refresh_threshold(), 0.75);
    }
}
