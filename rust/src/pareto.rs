//! Pareto-frontier extraction for the paper's trade-off plots (Figs. 4, 6):
//! minimize cost (accumulator bits / LUTs) while maximizing task performance.

/// One evaluated design point.
#[derive(Clone, Debug, PartialEq)]
pub struct Point<T> {
    /// Cost axis (lower is better): accumulator bits, LUTs, ...
    pub cost: f64,
    /// Performance axis (higher is better): accuracy, PSNR, ...
    pub perf: f64,
    /// Payload describing the configuration.
    pub tag: T,
}

/// True iff `a` dominates `b`: no worse on both axes, strictly better on one.
pub fn dominates<T>(a: &Point<T>, b: &Point<T>) -> bool {
    (a.cost <= b.cost && a.perf >= b.perf) && (a.cost < b.cost || a.perf > b.perf)
}

/// Extract the Pareto frontier (max perf per cost), sorted by cost ascending.
///
/// Ties on cost keep only the best perf; the returned frontier is strictly
/// increasing in both cost and perf.
pub fn frontier<T: Clone>(points: &[Point<T>]) -> Vec<Point<T>> {
    let mut sorted: Vec<&Point<T>> = points.iter().collect();
    sorted.sort_by(|a, b| {
        a.cost
            .partial_cmp(&b.cost)
            .unwrap()
            .then(b.perf.partial_cmp(&a.perf).unwrap())
    });
    let mut out: Vec<Point<T>> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for p in sorted {
        if p.perf > best {
            best = p.perf;
            out.push(p.clone());
        }
    }
    out
}

/// Max observed perf at cost <= budget (a vertical slice of the frontier,
/// how the paper reads "best attainable accuracy at a resource budget").
pub fn best_at_budget<T>(points: &[Point<T>], budget: f64) -> Option<&Point<T>> {
    points
        .iter()
        .filter(|p| p.cost <= budget)
        .max_by(|a, b| a.perf.partial_cmp(&b.perf).unwrap())
}

/// Area-style dominance check between two frontiers: `a` dominates `b` if at
/// every cost where b has a point, a achieves at least that perf at no more
/// cost (used to assert "A2Q provides a dominant Pareto frontier").
pub fn frontier_dominates<T>(a: &[Point<T>], b: &[Point<T>], tol: f64) -> bool {
    b.iter().all(|pb| {
        a.iter()
            .any(|pa| pa.cost <= pb.cost + tol && pa.perf >= pb.perf - tol)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(cost: f64, perf: f64) -> Point<u32> {
        Point { cost, perf, tag: 0 }
    }

    #[test]
    fn dominance_basics() {
        assert!(dominates(&p(1.0, 2.0), &p(2.0, 1.0)));
        assert!(!dominates(&p(1.0, 1.0), &p(1.0, 1.0))); // equal: no strict edge
        assert!(!dominates(&p(1.0, 1.0), &p(0.5, 2.0)));
    }

    #[test]
    fn frontier_extraction() {
        let pts = vec![p(1.0, 0.5), p(2.0, 0.9), p(2.0, 0.7), p(3.0, 0.8), p(4.0, 0.95)];
        let f = frontier(&pts);
        let pairs: Vec<(f64, f64)> = f.iter().map(|q| (q.cost, q.perf)).collect();
        assert_eq!(pairs, vec![(1.0, 0.5), (2.0, 0.9), (4.0, 0.95)]);
    }

    #[test]
    fn frontier_strictly_monotone() {
        let pts: Vec<Point<u32>> =
            (0..50).map(|i| p((i % 10) as f64, ((i * 7) % 13) as f64 / 13.0)).collect();
        let f = frontier(&pts);
        for w in f.windows(2) {
            assert!(w[1].cost > w[0].cost);
            assert!(w[1].perf > w[0].perf);
        }
    }

    #[test]
    fn budget_slice() {
        let pts = vec![p(1.0, 0.5), p(2.0, 0.9), p(4.0, 0.95)];
        assert_eq!(best_at_budget(&pts, 2.5).unwrap().perf, 0.9);
        assert!(best_at_budget(&pts, 0.5).is_none());
    }

    #[test]
    fn frontier_domination() {
        let a = vec![p(1.0, 0.6), p(2.0, 0.9)];
        let b = vec![p(1.5, 0.55), p(2.5, 0.85)];
        assert!(frontier_dominates(&a, &b, 1e-9));
        assert!(!frontier_dominates(&b, &a, 1e-9));
    }
}
