//! `a2q` — the leader binary: train QNNs for low-precision accumulation,
//! sweep the (M, N, P) design space, estimate FPGA resources, simulate
//! overflow, and regenerate every figure of the paper.
//!
//! Training runs on a [`a2q::runtime::TrainBackend`]: the pure-Rust native
//! backend by default (no artifacts, no XLA toolchain), or the PJRT
//! executor for AOT-compiled HLO artifacts (`make artifacts` + `--features
//! xla`, `--backend xla`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use a2q::accsim::{dot_accumulate_multi, AccMode, NetworkPlan};
use a2q::cli::Args;
use a2q::config::RunConfig;
use a2q::coordinator::{MetricsSink, RunRecord, Trainer};
use a2q::datasets;
use a2q::finn::estimate::{estimate_network, AccumulatorPolicy, DEFAULT_CYCLES_BUDGET};
use a2q::finn::estimate_qnetwork;
use a2q::model::{QNetwork, SynthQuant};
use a2q::quant::bounds::{data_type_bound, weight_bound, DotShape};
use a2q::report;
use a2q::rng::Rng;
use a2q::runtime::{
    artifact::discover_models, make_backend, native::native_models, BackendKind, ModelManifest,
};
use a2q::serve::{
    BackendSpec, FaultPlan, LoadgenConfig, ModelSource, RetryPolicy, Router, RouterConfig,
    ServeConfig, Server,
};
use a2q::Tensor;

const USAGE: &str = "\
a2q — accumulator-aware quantization (A2Q) reproduction

USAGE: a2q [--artifacts DIR] [--results DIR] <command> [flags]

COMMANDS:
  train      --model mlp|mlp3|... --alg a2q|a2q_plus|qat|float --m 6 --n 6
             --p 16 --steps 300 --seed 0 [--backend native|xla]
             [--config run.json] (native backend trains registry MLPs with
             no artifacts; exports chain into the accsim + FINN substrates)
  sweep      --models mlp,mlp3 [--steps 200] [--mn 6,8]
             [--offsets 0,2,4,6,8,10] [--float-ref true] [--sink runs.jsonl]
             [--backend native|xla] [--config sweep.json] [--workers N]
             (native sweeps fan configs over a worker pool — results are
              identical at any worker count; xla pins one worker)
  figure     <fig2|fig3|fig4|fig5|fig6|fig7|fig8|all>
             [--sink runs.jsonl] [--steps 200] [--seed 0]
             [--backend native|xla]
  estimate   --model M --m 6 --n 6 --p 16
  bounds     --k 784 --m 8 --n 1 [--signed] [--l1 NORM]
  accsim     --k 784 --p 16 --m 8 --n 1 --seed 0 [--psweep 8:32]
             (all register models simulated in one fused MAC traversal)
  netsim     --layers 784,64,16,2 --m 4 --n 4 --p 16 [--psweep 8:20]
             [--samples 256] [--seed 0] [--threads T] [--unconstrained]
             [--quantizer a2q|a2q_plus] [--dataset synth_mnist]
             (whole QNetwork under every width in one threaded pass: per-layer
              overflow/sparsity, fig2/fig3 network CSVs, FINN LUT estimate)
  stream     --c-out 64 --k 64 --p 14 --n 8 --batch 64 --ticks 200
             [--density 0.05] [--threads 1] [--seed 7] [--refresh R]
             [--kernel scalar|simd|sparse]
             (NNUE-style incremental streaming bench on an A2Q-constrained
              layer: maintained accumulators updated per sparse delta vs a
              full recompute every tick, verified bit-identical at the end;
              --refresh overrides the row-refresh threshold, --density is
              the fraction of features changed per row per tick)
  serve      --models NAME=FILE.json|NAME:W0xW1x..:mMnNpP[,...]
             [--addr 127.0.0.1:7878] [--workers 2] [--queue-cap 64]
             [--max-batch-rows 64] [--batch-window-ms 1]
             [--deadline-ms 1000] [--pool-retain 0] [--idle-timeout-ms 0]
             (long-running TCP inference service over exported or synthetic
              networks: bounded admission queue with typed overloaded /
              deadline_exceeded rejections, deadline-aware micro-batching
              with round-robin model rotation, panic-isolated workers with
              automatic respawn; speaks line-JSON and the zero-copy binary
              frame protocol on the same port (first byte negotiates);
              --pool-retain 0 auto-sizes the request buffer pool;
              --idle-timeout-ms closes silent connections typed;
              A2Q_FAULT=panic_batch:N,delay_ms:D,cache_load,conn_drop:N,
              ping_stall_ms:D injects faults; blocks until a client sends
              {\"op\":\"shutdown\"})
  route      --backend ADDR [--backend ADDR]... | --spawn SPEC[,SPEC...]
             [--addr 127.0.0.1:7979] [--workers 2]
             [--probe-interval-ms 50] [--probe-timeout-ms 250]
             [--breaker 3] [--retry-max 3] [--retry-base-ms 2]
             [--retry-cap-ms 50] [--hedge-ms 0] [--connect-timeout-ms 1000]
             [--deadline-ms 1000] [--respawn true]
             (fault-tolerant shard router over N a2q serve replicas:
              health-probes every replica, breaks the circuit on
              consecutive failures, retries safe-to-retry outcomes with
              decorrelated-jitter backoff, optionally hedges slow infers,
              and drains/restarts replicas with zero in-flight loss;
              --backend attaches running replicas, --spawn starts children
              on ephemeral ports (same SPEC grammar as serve --models) and
              respawns them when they die; clients connect to the router
              exactly as they would to a replica — either wire protocol;
              blocks until a client sends shutdown)
  ctl        <ping|stats|drain|resume|shutdown> [--addr 127.0.0.1:7979]
             [--backend ADDR] [--journal LABEL]
             (one-shot JSON control-plane client for a2q serve/route:
              prints the reply line and exits nonzero on ok=false;
              drain/resume against a router take --backend (a replica
              address from ctl stats); ctl stats --journal route/ records
              route/retry_rate to BENCH_accsim.json for perf gating)
  loadgen    --model NAME [--addr 127.0.0.1:7878] [--rps 200]
             [--duration-ms 2000] [--connections 4] [--rows 4]
             [--deadline-ms 200] [--connect-timeout-ms 1000] [--seed 1]
             [--wire json|binary] [--journal LABEL] [--shutdown]
             (open-loop load against a running a2q serve or route: prints a
              JSON report with p50/p99 latency, rows/s, typed shed counts
              and transport-fault classes (conn_refused/conn_reset/timeout);
              --wire picks the protocol driven (default json);
              --journal LABEL records serve/LABEL_* rows — or LABEL*
              verbatim when LABEL ends in '/' (e.g. route/) — to
              BENCH_accsim.json and refreshes EXPERIMENTS.md §Perf-Serve;
              --shutdown stops the server afterwards)
  models     (list native registry + artifacts-dir models)
  perfcheck  --require FAST:SLOW[,FAST:SLOW...] [--require ...]
             [--journal BENCH_accsim.json]
             (assert journaled bench FAST is at least as fast as SLOW;
              --require repeats and each takes a comma list; CI uses this
              to pin the blocked train path ahead of the scalar reference
              and the sparse kernel ahead of the dense blocked one)
";

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(raw, &["signed", "float-ref", "unconstrained", "shutdown", "respawn"])?;
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let results = PathBuf::from(args.str_or("results", "results"));
    let cmd = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("missing command\n{USAGE}"))?
        .clone();

    match cmd.as_str() {
        "train" => cmd_train(&args, &artifacts),
        "sweep" => cmd_sweep(&args, &artifacts, &results),
        "figure" => cmd_figure(&args, &artifacts, &results),
        "estimate" => cmd_estimate(&args, &artifacts),
        "bounds" => cmd_bounds(&args),
        "accsim" => cmd_accsim(&args),
        "netsim" => cmd_netsim(&args, &results),
        "stream" => cmd_stream(&args),
        "serve" => cmd_serve(&args),
        "route" => cmd_route(&args),
        "ctl" => cmd_ctl(&args),
        "loadgen" => cmd_loadgen(&args),
        "models" => cmd_models(&artifacts),
        "perfcheck" => cmd_perfcheck(&args),
        other => anyhow::bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn backend_kind(args: &Args) -> Result<BackendKind> {
    match args.opt_str("backend") {
        Some(s) => s.parse(),
        None => Ok(BackendKind::default_kind()),
    }
}

fn cmd_train(args: &Args, artifacts: &Path) -> Result<()> {
    args.check_known(&[
        "artifacts", "results", "model", "alg", "m", "n", "p", "steps", "seed", "config",
        "lr", "n-train", "n-test", "backend",
    ])?;
    let rc = match args.opt_str("config") {
        Some(path) => RunConfig::load(&PathBuf::from(path))?,
        None => {
            let mut rc = RunConfig::new(
                &args.str_or("model", "mlp"),
                &args.str_or("alg", "a2q"),
                args.num_or("m", 6u32)?,
                args.num_or("n", 6u32)?,
                args.num_or("p", 16u32)?,
                args.num_or("steps", 300u64)?,
            );
            rc.seed = args.num_or("seed", 0u64)?;
            if let Some(lr) = args.opt_str("lr") {
                rc.lr = Some(lr.parse()?);
            }
            rc.n_train = args.num_or("n-train", rc.n_train)?;
            rc.n_test = args.num_or("n-test", rc.n_test)?;
            rc
        }
    };
    let backend = make_backend(backend_kind(args)?, artifacts)?;
    let trainer = Trainer::new(backend.as_ref(), &rc)?;
    let outcome = trainer.run(&rc)?;
    let record = RunRecord::from_outcome(&outcome);
    println!("{}", record.to_json().to_string());

    // Exported dense networks flow straight into the accsim + FINN
    // substrates: simulate the target width and price the deployment.
    if let Some(exported) = &outcome.exported {
        match QNetwork::from_exported(&rc.model, exported, &trainer.manifest, rc.bits()) {
            Ok(mut net) => {
                let n_eval = trainer.dataset.len(datasets::Split::Test).min(128);
                let idx: Vec<usize> = (0..n_eval).collect();
                let b = trainer.dataset.gather(datasets::Split::Test, &idx);
                net.calibrate(&b.x);
                let x = net.layers[0].in_quant.quantize(&b.x);
                let plan =
                    NetworkPlan::new(&net, &[AccMode::Wide, AccMode::Wrap { p_bits: rc.p }]);
                let sims = plan.execute(&x);
                let events: u64 = sims[1].layer_stats.iter().map(|s| s.overflow_events).sum();
                println!(
                    "[train] accsim wraparound at target P={}: {events} overflow events over \
                     {n_eval} test rows ({})",
                    rc.p,
                    if events == 0 { "guarantee holds in simulation" } else { "OVERFLOWING" },
                );
                let policy = AccumulatorPolicy::A2qTarget(rc.p);
                let est = estimate_qnetwork(&net, policy, DEFAULT_CYCLES_BUDGET);
                println!(
                    "[train] FINN LUT estimate at A2Q target P: compute {:.0} memory {:.0} \
                     total {:.0}",
                    est.total.compute,
                    est.total.memory,
                    est.total_luts()
                );
            }
            Err(e) => println!("[train] export does not chain into a QNetwork: {e}"),
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args, artifacts: &Path, results: &Path) -> Result<()> {
    use a2q::config::SweepConfig;
    use a2q::coordinator::{run_sweep, run_sweep_with_workers};

    args.check_known(&[
        "artifacts", "results", "models", "steps", "mn", "offsets", "float-ref", "config",
        "sink", "seed", "n-train", "n-test", "backend", "workers",
    ])?;
    let kind = backend_kind(args)?;
    let mut cfg = match args.opt_str("config") {
        Some(path) => SweepConfig::load(&PathBuf::from(path))?,
        None => {
            let models = match args.opt_str("models") {
                Some(s) => s.split(',').map(|m| m.trim().to_string()).collect(),
                None => match kind {
                    // native default: the in-process registry; xla default:
                    // whatever artifacts exist on disk
                    BackendKind::Native => {
                        native_models().iter().map(|m| m.to_string()).collect()
                    }
                    BackendKind::Pjrt => discover_models(artifacts)?,
                },
            };
            let mut c = SweepConfig::default_grid(models, args.num_or("steps", 200u64)?);
            c.mn_values = args.list_or("mn", "6,8")?;
            c.p_offsets = args.list_or("offsets", "0,2,4,6,8,10")?;
            c.seed = args.num_or("seed", 0u64)?;
            c.n_train = args.num_or("n-train", c.n_train)?;
            c.n_test = args.num_or("n-test", c.n_test)?;
            c
        }
    };
    if args.bool_or("float-ref", true)? && !cfg.algs.iter().any(|a| a == "float") {
        cfg.algs.push("float".into());
    }
    let sink_path = results.join(args.str_or("sink", "runs.jsonl"));
    let records = match args.opt_str("workers") {
        Some(w) => {
            let workers: usize = w.parse().map_err(|e| anyhow::anyhow!("--workers {w:?}: {e}"))?;
            anyhow::ensure!(workers > 0, "--workers must be positive");
            run_sweep_with_workers(cfg, kind, artifacts.to_path_buf(), sink_path, true, workers)?
        }
        None => run_sweep(cfg, kind, artifacts.to_path_buf(), sink_path, true)?,
    };
    println!("[sweep] {} total records", records.len());
    Ok(())
}

/// Assert ordering constraints between journaled bench records: every
/// `FAST:SLOW` pair requires `FAST`'s median ns/iter to be at most
/// `SLOW`'s. CI runs this after seeding the journal so a perf regression
/// (e.g. the blocked train path losing to the scalar reference) fails the
/// build with a precise message.
fn cmd_perfcheck(args: &Args) -> Result<()> {
    args.check_known(&["artifacts", "results", "journal", "require"])?;
    let path = args
        .opt_str("journal")
        .map(PathBuf::from)
        .unwrap_or_else(a2q::perf::bench_json_path);
    let journal = a2q::perf::parse_journal(&std::fs::read_to_string(&path)?)?;
    let specs = args.all_strs("require");
    anyhow::ensure!(
        !specs.is_empty(),
        "perfcheck needs at least one --require FAST:SLOW[,FAST:SLOW...]"
    );
    let find = |name: &str| {
        journal
            .iter()
            .find(|r| r.name == name)
            .ok_or_else(|| anyhow::anyhow!("no bench record {name:?} in {}", path.display()))
    };
    for spec in &specs {
        for pair in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (fast, slow) = pair
                .trim()
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("--require pair {pair:?} is not FAST:SLOW"))?;
            let (f, s) = (find(fast.trim())?, find(slow.trim())?);
            anyhow::ensure!(
                f.ns_per_iter <= s.ns_per_iter,
                "{} ({:.0} ns/iter) is slower than {} ({:.0} ns/iter)",
                f.name,
                f.ns_per_iter,
                s.name,
                s.ns_per_iter
            );
            println!(
                "[perfcheck] ok: {} {:.0} ns/iter <= {} {:.0} ns/iter ({:.2}x)",
                f.name,
                f.ns_per_iter,
                s.name,
                s.ns_per_iter,
                s.ns_per_iter / f.ns_per_iter.max(1.0)
            );
        }
    }
    Ok(())
}

fn cmd_figure(args: &Args, artifacts: &Path, results: &Path) -> Result<()> {
    args.check_known(&["artifacts", "results", "sink", "steps", "seed", "backend"])?;
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("figure needs an id (fig2..fig8 or all)"))?
        .clone();
    let steps = args.num_or("steps", 200u64)?;
    let seed = args.num_or("seed", 0u64)?;
    let want = |x: &str| id == x || id == "all";
    let mut matched = false;

    if want("fig2") {
        matched = true;
        let backend = make_backend(backend_kind(args)?, artifacts)?;
        let p_values: Vec<u32> = (10..=20).collect();
        let rep = report::fig2::run(backend.as_ref(), &p_values, steps, 256, seed)?;
        report::fig2::emit(&rep, results)?;
        println!("[fig2] wide acc {:.4}; wrote {}/fig2.csv", rep.acc_wide, results.display());
    }
    if want("fig3") {
        matched = true;
        let ks: Vec<usize> = (5..=14).map(|e| 1usize << e).collect();
        let rows = report::fig3::run(&ks, &[4, 5, 6, 7, 8], 1000, seed);
        report::fig3::emit(&rows, results)?;
        println!("[fig3] {} rows; wrote {}/fig3.csv", rows.len(), results.display());
    }
    if want("fig4") || want("fig5") || want("fig6") || want("fig7") {
        matched = true;
        let sink = MetricsSink::new(results.join(args.str_or("sink", "runs.jsonl")));
        let records = sink.load()?;
        anyhow::ensure!(
            !records.is_empty(),
            "no sweep records at {:?}; run `a2q sweep` first",
            sink.path()
        );
        let kind = backend_kind(args)?;
        let mut largest_k = BTreeMap::new();
        let mut geoms = BTreeMap::new();
        let mut models: Vec<String> = records.iter().map(|r| r.config.model.clone()).collect();
        models.sort();
        models.dedup();
        for m in &models {
            let manifest = kind.load_manifest(artifacts, m)?;
            largest_k.insert(m.clone(), manifest.largest_k);
            geoms.insert(m.clone(), manifest.geoms()?);
        }
        if want("fig4") || want("fig5") {
            let f4 = report::fig45::fig4(&records, &largest_k);
            report::fig45::emit_fig4(&f4, results)?;
            let f5 = report::fig45::fig5(&records);
            report::fig45::emit_fig5(&f5, results)?;
            println!("[fig4/5] {} models; wrote fig4_*.csv, fig5.csv", f4.len());
        }
        if want("fig6") || want("fig7") {
            let f6 = report::fig67::fig6(&records, &geoms);
            report::fig67::emit(&f6, results)?;
            for m in &f6 {
                if let Some((red, rel)) = report::fig67::headline_reduction(m, 0.95) {
                    println!(
                        "[fig6] {}: {:.2}x LUT reduction at {:.1}% of float perf",
                        m.model,
                        red,
                        rel * 100.0
                    );
                }
            }
        }
    }
    if want("fig8") {
        matched = true;
        let backend = make_backend(backend_kind(args)?, artifacts)?;
        let rep = report::fig8::run(backend.as_ref(), 12, 200, steps, 128, seed)?;
        report::fig8::emit(&rep, results)?;
        let (lo, hi) = rep.inner_acc_spread();
        println!(
            "[fig8] inner acc spread [{lo:.4}, {hi:.4}], outer acc {:.4}, wide {:.4}",
            rep.outer_acc, rep.acc_wide
        );
    }
    anyhow::ensure!(matched, "unknown figure {id:?} (fig2..fig8 or all)");
    Ok(())
}

fn cmd_estimate(args: &Args, artifacts: &Path) -> Result<()> {
    args.check_known(&["artifacts", "results", "model", "m", "n", "p"])?;
    let model = args.str_or("model", "cnn");
    let (m, n, p) = (
        args.num_or("m", 6u32)?,
        args.num_or("n", 6u32)?,
        args.num_or("p", 16u32)?,
    );
    let manifest = ModelManifest::load(artifacts, &model)
        .or_else(|e| a2q::runtime::native::native_manifest(&model).ok_or(e))?;
    let geoms = manifest.geoms()?;
    println!("{model} at M={m} N={n} P={p} (cycles budget {DEFAULT_CYCLES_BUDGET}):");
    println!("{:<10} {:>12} {:>12} {:>12}", "policy", "compute", "memory", "total");
    for (name, policy) in [
        ("fixed32", AccumulatorPolicy::Fixed32),
        ("datatype", AccumulatorPolicy::DataTypeBound),
        ("a2q", AccumulatorPolicy::A2qTarget(p)),
    ] {
        let est = estimate_network(&geoms, (m, n, p), policy, None, DEFAULT_CYCLES_BUDGET);
        println!(
            "{:<10} {:>12.0} {:>12.0} {:>12.0}",
            name, est.total.compute, est.total.memory, est.total_luts()
        );
    }
    Ok(())
}

fn cmd_bounds(args: &Args) -> Result<()> {
    args.check_known(&["artifacts", "results", "k", "m", "n", "signed", "l1"])?;
    let shape = DotShape {
        k: args.num_or("k", 784usize)?,
        m_bits: args.num_or("m", 8u32)?,
        n_bits: args.num_or("n", 8u32)?,
        x_signed: args.bool_or("signed", false)?,
    };
    println!("data-type bound (Eq. 8): P >= {}", data_type_bound(shape));
    if let Some(l1) = args.opt_str("l1") {
        let l1: f64 = l1.parse()?;
        println!(
            "weight bound (Eq. 12) at ||w||_1 = {l1}: P >= {}",
            weight_bound(l1, shape.n_bits, shape.x_signed)
        );
    }
    Ok(())
}

fn cmd_accsim(args: &Args) -> Result<()> {
    args.check_known(&["artifacts", "results", "k", "p", "m", "n", "seed", "psweep"])?;
    let k = args.num_or("k", 784usize)?;
    let p = args.num_or("p", 16u32)?;
    let m = args.num_or("m", 8u32)?;
    let n = args.num_or("n", 1u32)?;
    let mut rng = Rng::new(args.num_or("seed", 0u64)?);
    let wmax = (1i64 << (m - 1)) - 1;
    let xmax = (1i64 << n) - 1;
    let x: Vec<i64> = (0..k).map(|_| rng.below((xmax + 1) as usize) as i64).collect();
    let w: Vec<i64> = (0..k)
        .map(|_| rng.below((2 * wmax + 1) as usize) as i64 - wmax)
        .collect();

    // All requested register models run in ONE traversal of the MACs via the
    // fused engine; `--psweep LO:HI` adds a whole wraparound width sweep.
    let mut modes =
        vec![AccMode::Wide, AccMode::Wrap { p_bits: p }, AccMode::Saturate { p_bits: p }];
    if let Some(spec) = args.opt_str("psweep") {
        let (lo, hi) = spec
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("--psweep expects LO:HI, got {spec:?}"))?;
        let (lo, hi) = (lo.trim().parse::<u32>()?, hi.trim().parse::<u32>()?);
        anyhow::ensure!((2..=63).contains(&lo) && lo <= hi && hi <= 63, "--psweep range {spec:?}");
        modes.extend((lo..=hi).map(|pb| AccMode::Wrap { p_bits: pb }));
    }
    let results = dot_accumulate_multi(&x, &w, &modes);
    for (mode, r) in modes.iter().zip(&results) {
        println!("{mode:?}: value={} overflows={}", r.value, r.overflows);
    }
    println!(
        "data-type bound for this shape: P >= {}",
        data_type_bound(DotShape { k, m_bits: m, n_bits: n, x_signed: false })
    );
    Ok(())
}

/// End-to-end multi-layer simulation in the default (no-XLA) build: a
/// synthesized + calibrated [`a2q::model::QNetwork`] forwarded under every
/// requested accumulator width in one fused threaded pass
/// ([`a2q::accsim::NetworkPlan`]), with per-layer overflow/sparsity tables,
/// the fig2/fig3 network CSVs, and a FINN LUT estimate fed directly from
/// the network.
fn cmd_netsim(args: &Args, results: &Path) -> Result<()> {
    use a2q::datasets::Split;
    use a2q::model::NetSpec;

    args.check_known(&[
        "artifacts", "results", "layers", "m", "n", "p", "psweep", "samples", "seed", "threads",
        "unconstrained", "quantizer", "dataset",
    ])?;
    let widths: Vec<usize> = args.list_or("layers", "784,64,16,2")?;
    let m = args.num_or("m", 4u32)?;
    let n = args.num_or("n", 4u32)?;
    let p = args.num_or("p", 16u32)?;
    let samples = args.num_or("samples", 256usize)?.max(1);
    let seed = args.num_or("seed", 0u64)?;
    let quant = if args.bool_or("unconstrained", false)? {
        SynthQuant::Affine
    } else {
        match args.str_or("quantizer", "a2q").as_str() {
            "a2q" => SynthQuant::A2q,
            "a2q_plus" => SynthQuant::A2qPlus,
            other => anyhow::bail!("--quantizer expects a2q|a2q_plus, got {other:?}"),
        }
    };
    let spec = NetSpec { widths, m_bits: m, n_bits: n, p_bits: p, x_signed: false, quant };
    let mut net = QNetwork::synthesize(&spec, seed)?;

    // Calibration + eval inputs: the synthetic dataset's test split when the
    // network's input width matches its sample size, uniform noise otherwise.
    let ds_name = args.str_or("dataset", "synth_mnist");
    let ds = datasets::by_name(&ds_name, 64, samples, seed)?;
    let xd: usize = ds.x_shape.iter().product();
    let (x_float, labels) = if xd == net.input_dim() {
        let n_eval = samples.min(ds.len(Split::Test));
        let idx: Vec<usize> = (0..n_eval).collect();
        let b = ds.gather(Split::Test, &idx);
        (b.x, Some(b.y.data().to_vec()))
    } else {
        println!(
            "[netsim] {ds_name} samples are {xd}-dim, network wants {}: using uniform noise",
            net.input_dim()
        );
        let mut rng = Rng::new(seed ^ 0x6E75);
        let dim = net.input_dim();
        let data: Vec<f32> = (0..samples * dim).map(|_| rng.uniform() as f32).collect();
        (Tensor::new(vec![samples, dim], data), None)
    };
    net.calibrate(&x_float);
    let x_int = net.layers[0].in_quant.quantize(&x_float);

    let (lo, hi) = match args.opt_str("psweep") {
        Some(s) => {
            let (lo, hi) = s
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("--psweep expects LO:HI, got {s:?}"))?;
            (lo.trim().parse::<u32>()?, hi.trim().parse::<u32>()?)
        }
        // Default: a window around the target (registers from 2 to 63 bits
        // are simulable, so every valid target yields a range containing it).
        None => (p.saturating_sub(6).max(2), (p + 2).min(63)),
    };
    anyhow::ensure!((2..=63).contains(&lo) && lo <= hi && hi <= 63, "psweep range {lo}:{hi}");
    let p_values: Vec<u32> = (lo..=hi).collect();
    let threads = args.opt_str("threads").map(|t| t.parse::<usize>()).transpose()?;

    let rep = report::fig2::run_network(&net, &x_int, labels.as_deref(), &p_values, threads);
    report::fig2::emit_network(&rep, results)?;
    let bounds_rows = report::fig3::run_network(&net);
    report::fig3::emit_network(&bounds_rows, results)?;

    println!(
        "[netsim] {} layers {:?}, M={m} N={n} target P={p}, {} samples, {} modes{}",
        net.depth(),
        spec.widths,
        x_int.rows(),
        1 + 2 * p_values.len(),
        match quant {
            SynthQuant::A2q => " (A2Q-constrained)",
            SynthQuant::A2qPlus => " (A2Q+-constrained, zero-centered)",
            SynthQuant::Affine => " (unconstrained QAT)",
        },
    );
    for r in &bounds_rows {
        println!(
            "  {:<8} K={:<5} ||w||1={:<9.0} sparsity={:.3} dt-bound P>={:<2} wn-bound P>={:<2}",
            r.name, r.k, r.l1_max, r.sparsity, r.data_type_bound, r.weight_bound
        );
    }
    if let Some(aw) = rep.acc_wide {
        println!("  wide-register accuracy: {aw:.4}");
    }
    println!("  per-layer wraparound overflow rate by P:");
    for &pb in &p_values {
        let per_layer: Vec<String> = rep
            .rows
            .iter()
            .filter(|r| r.p_bits == pb)
            .map(|r| format!("L{}={:.4}", r.layer, r.overflow_rate_wrap))
            .collect();
        let acc = rep
            .rows
            .iter()
            .find(|r| r.p_bits == pb)
            .and_then(|r| r.acc_wrap)
            .map(|a| format!(" acc={a:.4}"))
            .unwrap_or_default();
        println!("    P={pb:<2} {}{acc}", per_layer.join(" "));
    }
    println!("  wrote {}/fig2_network.csv and fig3_network.csv", results.display());

    println!("  FINN LUT estimate (cycles budget {DEFAULT_CYCLES_BUDGET}):");
    println!("  {:<10} {:>12} {:>12} {:>12}", "policy", "compute", "memory", "total");
    for (name, policy) in [
        ("fixed32", AccumulatorPolicy::Fixed32),
        ("datatype", AccumulatorPolicy::DataTypeBound),
        ("weightnorm", AccumulatorPolicy::WeightNorm),
        ("a2q", AccumulatorPolicy::A2qTarget(p)),
    ] {
        let est = estimate_qnetwork(&net, policy, DEFAULT_CYCLES_BUDGET);
        println!(
            "  {:<10} {:>12.0} {:>12.0} {:>12.0}",
            name,
            est.total.compute,
            est.total.memory,
            est.total_luts()
        );
    }
    Ok(())
}

/// Streaming sparse-delta bench: open an incremental
/// [`a2q::accsim::LayerStreamSession`] on an A2Q-constrained layer, drive
/// `--ticks` delta ticks (each changing `--density` of every row's
/// features) through both the incremental path and a full recompute fed an
/// identically seeded delta stream, report rows/s for both, and verify the
/// final states bit-identical — outputs and overflow counters.
fn cmd_stream(args: &Args) -> Result<()> {
    use std::time::Instant;

    use a2q::accsim::{IntMatrix, KernelPath, LayerPlan, LayerStreamSession};
    use a2q::testutil::{apply_deltas, psweep_constrained_layer, stream_delta_tick};

    args.check_known(&[
        "artifacts", "results", "c-out", "k", "p", "n", "batch", "ticks", "density", "threads",
        "seed", "kernel", "refresh",
    ])?;
    let c_out = args.num_or("c-out", 64usize)?;
    let k = args.num_or("k", 64usize)?;
    let p = args.num_or("p", 14u32)?;
    let n = args.num_or("n", 8u32)?;
    let batch = args.num_or("batch", 64usize)?.max(1);
    let ticks = args.num_or("ticks", 200usize)?.max(1);
    let threads = args.num_or("threads", 1usize)?.max(1);
    let seed = args.num_or("seed", 7u64)?;
    let density: f64 = args.str_or("density", "0.05").parse()?;
    anyhow::ensure!((0.0..=1.0).contains(&density), "--density must be in [0, 1]");
    anyhow::ensure!(c_out > 0 && k > 0, "--c-out and --k must be positive");
    let path = match args.opt_str("kernel") {
        Some(s) => Some(KernelPath::parse(&s).ok_or_else(|| {
            anyhow::anyhow!("--kernel expects scalar|simd|sparse, got {s:?}")
        })?),
        None => None,
    };

    let w = psweep_constrained_layer(c_out, k, p, n, seed);
    let modes = [AccMode::Wide, AccMode::Wrap { p_bits: p }];
    let plan = LayerPlan::new_with_path(&w, &modes, path);
    let x_scale = 0.05f32;
    let per_row = ((k as f64 * density).round() as usize).clamp(1, k);

    let mut rng = Rng::new(seed ^ 0x57AE);
    let x0 = IntMatrix::from_flat(
        batch,
        k,
        (0..batch * k).map(|_| rng.below(1usize << n) as i64).collect(),
    );

    let mut session = LayerStreamSession::new(&plan, x0.clone(), x_scale);
    if let Some(r) = args.opt_str("refresh") {
        session = session.with_refresh_threshold(r.parse()?);
    }

    // Incremental loop: ticks are generated from the session's own state
    // inside the timed region (the full loop pays the same generation
    // cost from an identically seeded stream, so the comparison is fair).
    let mut srng = Rng::new(seed ^ 0x7100);
    let t0 = Instant::now();
    for _ in 0..ticks {
        let tick = stream_delta_tick(session.x(), per_row, n, &mut srng);
        session.apply(&tick)?;
        std::hint::black_box(session.forward_threads(threads));
    }
    let inc = t0.elapsed();

    // Full-recompute loop over the same delta stream.
    let mut frng = Rng::new(seed ^ 0x7100);
    let mut xf = x0;
    let t1 = Instant::now();
    for _ in 0..ticks {
        let tick = stream_delta_tick(&xf, per_row, n, &mut frng);
        apply_deltas(&mut xf, &tick);
        std::hint::black_box(plan.execute_threads(&xf, x_scale, threads));
    }
    let full = t1.elapsed();

    // Both loops consumed identical streams, so the final states must be
    // bit-identical — outputs and every overflow counter.
    anyhow::ensure!(session.x() == &xf, "incremental input state diverged from the mirror");
    let got = session.forward_threads(threads);
    let want = plan.execute_threads(&xf, x_scale, threads);
    for (mi, (g, wnt)) in got.iter().zip(&want).enumerate() {
        anyhow::ensure!(
            g.out.data() == wnt.out.data()
                && g.out_wide.data() == wnt.out_wide.data()
                && g.stats.overflow_events == wnt.stats.overflow_events
                && g.stats.dots_overflowed == wnt.stats.dots_overflowed
                && g.stats.abs_err_sum == wnt.stats.abs_err_sum,
            "incremental forward diverged from full recompute in mode {mi}"
        );
    }

    let rows = (batch * ticks) as f64;
    let (inc_s, full_s) = (inc.as_secs_f64(), full.as_secs_f64());
    let choice = plan.kernel_choice();
    println!(
        "[stream] layer {c_out}x{k} P={p} N={n} sparsity={:.3} kernel={:?} threads={threads}",
        choice.sparsity, choice.path
    );
    println!(
        "[stream] {ticks} ticks x {batch} rows at density {density} ({per_row} deltas/row), \
         refresh threshold {:.2}, {} rows refreshed",
        session.refresh_threshold(),
        session.refreshed_rows()
    );
    println!(
        "[stream] incremental: {:.1} rows/s   full recompute: {:.1} rows/s   speedup {:.2}x",
        rows / inc_s.max(1e-9),
        rows / full_s.max(1e-9),
        full_s / inc_s.max(1e-9)
    );
    println!("[stream] bit-identity verified: outputs and overflow counters match");
    Ok(())
}

/// Parse one `--models` entry: `name=path.json` (exported model file) or a
/// `name:W0xW1x..:mMnNpP` synth spec.
fn parse_model_entry(entry: &str) -> Result<(String, ModelSource)> {
    if let Some((name, path)) = entry.split_once('=') {
        anyhow::ensure!(!name.is_empty(), "empty model name in {entry:?}");
        return Ok((name.to_string(), ModelSource::File(PathBuf::from(path))));
    }
    let (name, _) = a2q::model::parse_synth_spec(entry)?;
    Ok((name, ModelSource::Synth(entry.to_string())))
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.check_known(&[
        "artifacts", "results", "models", "addr", "workers", "queue-cap", "max-batch-rows",
        "batch-window-ms", "deadline-ms", "pool-retain", "idle-timeout-ms",
    ])?;
    let models: Vec<(String, ModelSource)> = args
        .str_or("models", "")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| parse_model_entry(s.trim()))
        .collect::<Result<_>>()?;
    let cfg = ServeConfig {
        addr: args.str_or("addr", "127.0.0.1:7878"),
        workers: args.num_or("workers", 2usize)?,
        queue_capacity: args.num_or("queue-cap", 64usize)?,
        max_batch_rows: args.num_or("max-batch-rows", 64usize)?,
        batch_window_ms: args.num_or("batch-window-ms", 1u64)?,
        default_deadline_ms: args.num_or("deadline-ms", 1000u64)?,
        pool_retain: args.num_or("pool-retain", 0usize)?,
        idle_timeout_ms: args.num_or("idle-timeout-ms", 0u64)?,
    };
    let fault = FaultPlan::from_env();
    if !fault.is_noop() {
        println!("[serve] fault injection active: {fault:?}");
    }
    let server = Server::start(&cfg, &models, fault)?;
    println!("[serve] listening on {}", server.addr());
    for (name, source) in &models {
        println!("[serve] model {name} <- {source:?}");
    }
    println!(
        "[serve] workers={} queue-cap={} max-batch-rows={} batch-window={}ms",
        cfg.workers, cfg.queue_capacity, cfg.max_batch_rows, cfg.batch_window_ms
    );
    // Block until a client sends {"op":"shutdown"}.
    server.join();
    println!("[serve] shut down cleanly");
    Ok(())
}

fn cmd_route(args: &Args) -> Result<()> {
    args.check_known(&[
        "artifacts", "results", "backend", "spawn", "addr", "workers", "probe-interval-ms",
        "probe-timeout-ms", "breaker", "retry-max", "retry-base-ms", "retry-cap-ms", "hedge-ms",
        "connect-timeout-ms", "deadline-ms", "respawn",
    ])?;
    let mut specs: Vec<BackendSpec> = args
        .all_strs("backend")
        .into_iter()
        .map(BackendSpec::Attached)
        .collect();
    let workers = args.num_or("workers", 2usize)?;
    for group in args.all_strs("spawn") {
        for spec in group.split(',').filter(|s| !s.trim().is_empty()) {
            let spec = spec.trim();
            // Validate the model grammar up front so a typo fails the router
            // with one error instead of N dead children.
            parse_model_entry(spec)?;
            specs.push(BackendSpec::Spawn { models: spec.to_string(), workers });
        }
    }
    anyhow::ensure!(!specs.is_empty(), "route needs at least one --backend or --spawn SPEC");
    let cfg = RouterConfig {
        addr: args.str_or("addr", "127.0.0.1:7979"),
        probe_interval_ms: args.num_or("probe-interval-ms", 50u64)?,
        probe_timeout_ms: args.num_or("probe-timeout-ms", 250u64)?,
        breaker_threshold: args.num_or("breaker", 3u32)?,
        retry: RetryPolicy {
            max_attempts: args.num_or("retry-max", 3u32)?,
            base_ms: args.num_or("retry-base-ms", 2u64)?,
            cap_ms: args.num_or("retry-cap-ms", 50u64)?,
        },
        hedge_ms: args.num_or("hedge-ms", 0u64)?,
        connect_timeout_ms: args.num_or("connect-timeout-ms", 1000u64)?,
        default_deadline_ms: args.num_or("deadline-ms", 1000u64)?,
        respawn: args.bool_or("respawn", true)?,
    };
    let router = Router::start(&cfg, &specs)?;
    println!("[route] listening on {}", router.addr());
    for snap in router.replicas().snapshot() {
        let kind = if snap.spawned { "spawned" } else { "attached" };
        println!("[route] backend {} ({kind})", snap.addr);
    }
    println!(
        "[route] probe={}ms breaker={} retries={} hedge={}ms",
        cfg.probe_interval_ms, cfg.breaker_threshold, cfg.retry.max_attempts, cfg.hedge_ms
    );
    // Block until a client sends {"op":"shutdown"} (or a binary shutdown op).
    router.join();
    println!("[route] shut down cleanly");
    Ok(())
}

fn cmd_ctl(args: &Args) -> Result<()> {
    args.check_known(&["artifacts", "results", "addr", "backend", "journal"])?;
    let op = args.positional.get(1).map(String::as_str).unwrap_or("");
    anyhow::ensure!(
        matches!(op, "ping" | "stats" | "drain" | "resume" | "shutdown"),
        "a2q ctl needs an op: ping|stats|drain|resume|shutdown"
    );
    let addr = args.str_or("addr", "127.0.0.1:7979");
    let mut fields = vec![("op", a2q::json::Json::str(op))];
    if let Some(backend) = args.opt_str("backend") {
        fields.push(("backend", a2q::json::Json::str(backend)));
    }
    let mut line = a2q::json::Json::obj(fields).to_string();
    line.push('\n');

    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(5)))?;
    let mut writer = stream.try_clone()?;
    writer.write_all(line.as_bytes())?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply)?;
    let reply = reply.trim_end();
    anyhow::ensure!(!reply.is_empty(), "{addr} closed the connection without a reply");
    println!("{reply}");
    let parsed = a2q::json::Json::parse(reply)?;
    let ok = parsed.get("ok")?.as_bool()?;
    anyhow::ensure!(ok, "{op} against {addr} returned ok=false");

    if let Some(label) = args.opt_str("journal") {
        anyhow::ensure!(op == "stats", "--journal only applies to ctl stats");
        let forwarded = parsed.get("forwarded")?.as_f64()?;
        let retries = parsed.get("retries")?.as_f64()?;
        let rate = if forwarded > 0.0 { retries / forwarded } else { 0.0 };
        let name = if label.ends_with('/') {
            format!("{label}retry_rate")
        } else {
            format!("{label}/retry_rate")
        };
        let rec = a2q::perf::BenchRecord {
            name: name.clone(),
            ns_per_iter: rate,
            mac_per_s: None,
            sparsity: None,
        };
        let path = a2q::perf::record_benches(&[rec])?;
        eprintln!("[ctl] journaled {name}={rate:.4} to {}", path.display());
    }
    Ok(())
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    args.check_known(&[
        "artifacts", "results", "addr", "model", "rps", "duration-ms", "connections", "rows",
        "deadline-ms", "connect-timeout-ms", "seed", "wire", "journal", "shutdown",
    ])?;
    let wire = match args.str_or("wire", "json").as_str() {
        "json" => a2q::serve::WireFormat::Json,
        "binary" => a2q::serve::WireFormat::Binary,
        other => anyhow::bail!("--wire must be json or binary, got {other:?}"),
    };
    let cfg = LoadgenConfig {
        addr: args.str_or("addr", "127.0.0.1:7878"),
        model: args.str_or("model", "synth"),
        rps: args.num_or("rps", 200.0f64)?,
        duration_ms: args.num_or("duration-ms", 2000u64)?,
        connections: args.num_or("connections", 4usize)?,
        rows_per_req: args.num_or("rows", 4usize)?,
        deadline_ms: args.num_or("deadline-ms", 200u64)?,
        connect_timeout_ms: args.num_or("connect-timeout-ms", 1000u64)?,
        seed: args.num_or("seed", 1u64)?,
        wire,
    };
    let report = a2q::serve::run_loadgen(&cfg)?;
    let server_stats = a2q::serve::loadgen::fetch_server_stats(&cfg.addr).ok();
    if let Some(label) = args.opt_str("journal") {
        let path = a2q::serve::loadgen::journal_report(&label, &report)?;
        eprintln!("[loadgen] journaled {label} metrics to {}", path.display());
    }
    if args.bool_or("shutdown", false)? {
        a2q::serve::loadgen::send_shutdown(&cfg.addr)?;
        eprintln!("[loadgen] sent shutdown to {}", cfg.addr);
    }
    let line = a2q::serve::loadgen::report_json(&report, server_stats.as_ref()).to_string();
    println!("{line}");
    Ok(())
}

fn cmd_models(artifacts: &Path) -> Result<()> {
    let mut names: Vec<String> = native_models().iter().map(|m| m.to_string()).collect();
    if let Ok(found) = discover_models(artifacts) {
        for m in found {
            if !names.contains(&m) {
                names.push(m);
            }
        }
    }
    names.sort();
    for m in names {
        // Artifact manifests take precedence over the registry (matching
        // cmd_estimate), so the listing describes what an xla backend would
        // actually train; registry-only models resolve natively.
        let manifest = ModelManifest::load(artifacts, &m)
            .or_else(|e| a2q::runtime::native::native_manifest(&m).ok_or(e))?;
        println!(
            "{:<8} task={:<9} bs={:<4} K*={:<5} layers={} dataset={}",
            m,
            manifest.task,
            manifest.batch_size,
            manifest.largest_k,
            manifest.qlayers.len(),
            datasets::default_for_model(&m),
        );
    }
    Ok(())
}
