//! The training-backend abstraction: `init / train_step / infer / export`
//! over host-tensor [`TrainState`] leaves, with two implementations —
//!
//! * [`super::native::NativeBackend`] — pure-Rust manual forward/backward
//!   for dense (MLP) manifests; always available, the default build's
//!   training engine;
//! * [`super::engine::Engine`] (`xla` feature) — the PJRT executor for the
//!   AOT-compiled HLO artifacts, converting leaves to literals at its
//!   boundary.
//!
//! The coordinator ([`crate::coordinator::Trainer`], sweeps) and the
//! training-backed figure drivers are generic over this trait, so
//! `a2q train` / `a2q sweep` work in the default build and trained networks
//! flow straight into [`crate::accsim::NetworkPlan`] /
//! [`crate::finn::estimate_qnetwork`].

use std::path::Path;

use anyhow::Result;

use super::artifact::ModelManifest;
use super::state::{ExportedLayer, TrainState};
use crate::tensor::Tensor;

/// One training backend. Object-safe: the coordinator holds `&dyn
/// TrainBackend` so sweep workers can construct whichever backend the run
/// asks for behind one channel protocol.
pub trait TrainBackend {
    /// Short backend identifier ("native" / "pjrt") for logs.
    fn name(&self) -> &'static str;

    /// Resolve a model manifest (artifact file or native registry).
    fn manifest(&self, model: &str) -> Result<ModelManifest>;

    /// Fresh training state from a seed.
    fn init(&self, manifest: &ModelManifest, seed: f32) -> Result<TrainState>;

    /// One optimizer step; state advances in place, returns the loss.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &mut TrainState,
        x: &Tensor,
        y: &Tensor,
        bits: (u32, u32, u32),
        lr: f32,
    ) -> Result<f32>;

    /// Forward pass at the given bit widths.
    fn infer(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &TrainState,
        x: &Tensor,
        bits: (u32, u32, u32),
    ) -> Result<Tensor>;

    /// Export integer weights + scales + biases for deployment analysis.
    fn export(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &TrainState,
        bits: (u32, u32, u32),
    ) -> Result<Vec<ExportedLayer>>;
}

/// Which backend a run executes on. `Send + Copy` so sweep scheduler
/// threads can carry it into the worker that actually constructs the
/// backend (PJRT handles are not `Send`; the kind is).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust manual forward/backward (default build).
    Native,
    /// PJRT execution of AOT HLO artifacts (`xla` feature).
    Pjrt,
}

impl BackendKind {
    /// The default for this build: PJRT when compiled with the `xla`
    /// feature (previous behaviour), native otherwise.
    pub fn default_kind() -> BackendKind {
        if cfg!(feature = "xla") {
            BackendKind::Pjrt
        } else {
            BackendKind::Native
        }
    }

    /// Resolve a manifest the way this backend would: the native registry
    /// first for native runs (deterministic regardless of artifacts on
    /// disk), the artifact file for PJRT.
    pub fn load_manifest(self, artifacts_dir: &Path, model: &str) -> Result<ModelManifest> {
        match self {
            BackendKind::Native => match super::native::native_manifest(model) {
                Some(m) => Ok(m),
                None => ModelManifest::load(artifacts_dir, model),
            },
            BackendKind::Pjrt => ModelManifest::load(artifacts_dir, model),
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" | "pjrt" => Ok(BackendKind::Pjrt),
            other => anyhow::bail!("unknown backend {other:?} (native | xla)"),
        }
    }
}

/// Construct a backend of the given kind rooted at an artifacts directory.
pub fn make_backend(kind: BackendKind, artifacts_dir: &Path) -> Result<Box<dyn TrainBackend>> {
    match kind {
        BackendKind::Native => Ok(Box::new(super::native::NativeBackend::new(artifacts_dir))),
        #[cfg(feature = "xla")]
        BackendKind::Pjrt => Ok(Box::new(super::engine::Engine::new(artifacts_dir)?)),
        #[cfg(not(feature = "xla"))]
        BackendKind::Pjrt => anyhow::bail!(
            "the xla backend needs a build with `cargo build --features xla` (and the real \
             xla bindings in place of rust/vendor/xla); use `--backend native` here"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_defaults() {
        assert_eq!("native".parse::<BackendKind>().unwrap(), BackendKind::Native);
        assert_eq!("xla".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert!("magic".parse::<BackendKind>().is_err());
        #[cfg(not(feature = "xla"))]
        assert_eq!(BackendKind::default_kind(), BackendKind::Native);
    }

    #[test]
    fn native_kind_resolves_registry_manifests_without_artifacts() {
        let dir = crate::testutil::TempDir::new().unwrap();
        let m = BackendKind::Native.load_manifest(dir.path(), "mlp").unwrap();
        assert_eq!(m.name, "mlp");
        assert!(BackendKind::Native.load_manifest(dir.path(), "no_such_model").is_err());
    }
}
