//! Host-side training-state currency shared by every [`super::TrainBackend`].
//!
//! `TrainState` is a flat list of host [`Tensor`] leaves in the manifest's
//! `state` layout order — the common interchange every backend consumes and
//! produces. The PJRT engine uploads/downloads literals at its boundary; the
//! native backend operates on the leaves directly.

use anyhow::Result;

use super::artifact::ModelManifest;
use crate::quant::QTensor;
use crate::tensor::Tensor;

/// Training state: the flattened (params, optimizer, step) leaves as host
/// tensors, in the manifest `state` layout order.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub leaves: Vec<Tensor>,
}

impl TrainState {
    /// Slice out the parameter leaves (for infer/export calls), in the
    /// manifest `params` layout order.
    pub fn params<'a>(&'a self, manifest: &ModelManifest) -> Vec<&'a Tensor> {
        manifest.param_indices().into_iter().map(|i| &self.leaves[i]).collect()
    }

    /// Every leaf as a host tensor (checkpointing). Kept for API continuity
    /// with the literal-resident era; the leaves already *are* host tensors.
    pub fn to_tensors(&self) -> Result<Vec<Tensor>> {
        Ok(self.leaves.clone())
    }

    /// Rebuild from host tensors (checkpoint restore).
    pub fn from_tensors(tensors: &[Tensor]) -> Result<Self> {
        Ok(TrainState { leaves: tensors.to_vec() })
    }
}

/// One quantized layer as exported for deployment.
#[derive(Clone, Debug)]
pub struct ExportedLayer {
    pub name: String,
    /// Integer codes `[c_out, k]` (exact integers carried in f32).
    pub w_int: Tensor,
    /// Per-channel scales `[c_out, 1]`.
    pub s: Tensor,
    /// Float bias `[c_out]`.
    pub b: Tensor,
}

impl ExportedLayer {
    pub fn to_qtensor(&self) -> QTensor {
        QTensor::from_export(&self.w_int, &self.s, &self.b)
    }

    /// Validating conversion for exports that crossed a trust boundary
    /// (files on disk, serve-time model loads): typed errors instead of the
    /// asserts/silent-rounding of [`Self::to_qtensor`].
    pub fn try_to_qtensor(&self) -> Result<QTensor> {
        QTensor::try_from_export(&self.w_int, &self.s, &self.b)
            .map_err(|e| e.context(format!("layer {}", self.name)))
    }
}
