//! The PJRT execution engine: one CPU client, a compile cache keyed by
//! artifact file, and the [`TrainBackend`] implementation that executes the
//! four artifact kinds.
//!
//! Since the backend refactor, training state lives as *host tensors*
//! ([`TrainState`]) — the common currency every backend shares — and this
//! engine converts leaves to [`xla::Literal`]s at its boundary on every
//! call. That trades the old literal-resident hot path for backend
//! uniformity; the conversion is an O(state) memcpy per step, small next to
//! artifact execution (see EXPERIMENTS.md §Perf history).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::artifact::ModelManifest;
use super::backend::TrainBackend;
use super::literal::{literal_to_tensor, tensor_to_literal};
use super::state::{ExportedLayer, TrainState};
use crate::tensor::Tensor;

/// PJRT engine with a compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU engine rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {file}: {e}"))?,
        );
        self.cache.lock().unwrap().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute an artifact; outputs are the decomposed result tuple
    /// (artifacts are lowered with `return_tuple=True`).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        file: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(file)?;
        let result = exe
            .execute(inputs)
            .map_err(|e| anyhow::anyhow!("executing {file}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("downloading result of {file}: {e}"))?;
        Ok(lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {file}: {e}"))?)
    }

    /// Upload the state leaves plus trailing host tensors as one literal
    /// input list.
    fn upload(state_leaves: &[&Tensor], extra: &[&Tensor]) -> Result<Vec<xla::Literal>> {
        state_leaves
            .iter()
            .chain(extra.iter())
            .map(|t| tensor_to_literal(t))
            .collect()
    }
}

impl TrainBackend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self, model: &str) -> Result<ModelManifest> {
        ModelManifest::load(&self.dir, model)
    }

    /// Run the init artifact: fresh training state from a seed.
    fn init(&self, manifest: &ModelManifest, seed: f32) -> Result<TrainState> {
        let out = self.run(&manifest.init, &[tensor_to_literal(&Tensor::scalar(seed))?])?;
        anyhow::ensure!(
            out.len() == manifest.state.len(),
            "init returned {} leaves, manifest says {}",
            out.len(),
            manifest.state.len()
        );
        let leaves = out.iter().map(literal_to_tensor).collect::<Result<Vec<_>>>()?;
        Ok(TrainState { leaves })
    }

    /// One SGD/Adam step; state advances in place, returns the loss.
    fn train_step(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &mut TrainState,
        x: &Tensor,
        y: &Tensor,
        bits: (u32, u32, u32),
        lr: f32,
    ) -> Result<f32> {
        let file = manifest.alg(alg)?.train.clone();
        let bits_t = Tensor::from_vec(vec![bits.0 as f32, bits.1 as f32, bits.2 as f32]);
        let lr_t = Tensor::scalar(lr);
        let leaves: Vec<&Tensor> = state.leaves.iter().collect();
        let inputs = Self::upload(&leaves, &[x, y, &bits_t, &lr_t])?;
        let mut out = self.run(&file, &inputs)?;
        anyhow::ensure!(
            out.len() == state.leaves.len() + 1,
            "train step returned {} outputs, expected {}",
            out.len(),
            state.leaves.len() + 1
        );
        let loss = literal_to_tensor(&out.pop().unwrap())?.item();
        state.leaves = out.iter().map(literal_to_tensor).collect::<Result<Vec<_>>>()?;
        Ok(loss)
    }

    /// Forward pass at the given bit widths.
    fn infer(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &TrainState,
        x: &Tensor,
        bits: (u32, u32, u32),
    ) -> Result<Tensor> {
        let file = manifest.alg(alg)?.infer.clone();
        let bits_t = Tensor::from_vec(vec![bits.0 as f32, bits.1 as f32, bits.2 as f32]);
        let inputs = Self::upload(&state.params(manifest), &[x, &bits_t])?;
        let out = self.run(&file, &inputs)?;
        anyhow::ensure!(out.len() == 1, "infer returned {} outputs", out.len());
        literal_to_tensor(&out[0])
    }

    /// Export integer weights + scales + biases for deployment analysis.
    fn export(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &TrainState,
        bits: (u32, u32, u32),
    ) -> Result<Vec<ExportedLayer>> {
        let file = manifest
            .alg(alg)?
            .export
            .clone()
            .ok_or_else(|| anyhow::anyhow!("{alg} has no export artifact"))?;
        let bits_t = Tensor::from_vec(vec![bits.0 as f32, bits.1 as f32, bits.2 as f32]);
        let inputs = Self::upload(&state.params(manifest), &[&bits_t])?;
        let out = self.run(&file, &inputs)?;
        anyhow::ensure!(
            out.len() == 3 * manifest.qlayers.len(),
            "export returned {} tensors, expected {}",
            out.len(),
            3 * manifest.qlayers.len()
        );
        let mut layers = Vec::with_capacity(manifest.qlayers.len());
        for (i, q) in manifest.qlayers.iter().enumerate() {
            layers.push(ExportedLayer {
                name: q.name.clone(),
                w_int: literal_to_tensor(&out[3 * i])?,
                s: literal_to_tensor(&out[3 * i + 1])?,
                b: literal_to_tensor(&out[3 * i + 2])?,
            });
        }
        Ok(layers)
    }
}
