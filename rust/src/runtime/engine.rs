//! The PJRT execution engine: one CPU client, a compile cache keyed by
//! artifact file, and typed entry points for the four artifact kinds.
//!
//! Hot-path design: training state lives as [`xla::Literal`]s and flows
//! straight from one `train_step` execution into the next — the only
//! per-step host conversions are the batch upload and the scalar loss
//! download (see EXPERIMENTS.md §Perf).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::artifact::ModelManifest;
use super::literal::{literal_to_tensor, tensor_to_literal};
use crate::quant::QTensor;
use crate::tensor::Tensor;

/// Training state: the flattened (params, optimizer, step) leaves, resident
/// as literals between steps.
pub struct TrainState {
    pub leaves: Vec<xla::Literal>,
}

impl TrainState {
    /// Slice out the parameter leaves (for infer/export calls).
    pub fn params<'a>(&'a self, manifest: &ModelManifest) -> Vec<&'a xla::Literal> {
        manifest
            .param_indices()
            .into_iter()
            .map(|i| &self.leaves[i])
            .collect()
    }

    /// Download every leaf to a host tensor (checkpointing).
    pub fn to_tensors(&self) -> Result<Vec<Tensor>> {
        self.leaves.iter().map(literal_to_tensor).collect()
    }

    /// Rebuild device state from host tensors (checkpoint restore).
    pub fn from_tensors(tensors: &[Tensor]) -> Result<Self> {
        let leaves = tensors
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(TrainState { leaves })
    }
}

/// One quantized layer as exported for deployment.
#[derive(Clone, Debug)]
pub struct ExportedLayer {
    pub name: String,
    /// Integer codes `[c_out, k]` (exact integers carried in f32).
    pub w_int: Tensor,
    /// Per-channel scales `[c_out, 1]`.
    pub s: Tensor,
    /// Float bias `[c_out]`.
    pub b: Tensor,
}

impl ExportedLayer {
    pub fn to_qtensor(&self) -> QTensor {
        QTensor::from_export(&self.w_int, &self.s, &self.b)
    }
}

/// PJRT engine with a compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU engine rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self, model: &str) -> Result<ModelManifest> {
        ModelManifest::load(&self.dir, model)
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {file}: {e}"))?,
        );
        self.cache.lock().unwrap().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute an artifact; outputs are the decomposed result tuple
    /// (artifacts are lowered with `return_tuple=True`).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        file: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(file)?;
        let result = exe
            .execute(inputs)
            .map_err(|e| anyhow::anyhow!("executing {file}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("downloading result of {file}: {e}"))?;
        Ok(lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {file}: {e}"))?)
    }

    /// Run the init artifact: fresh training state from a seed.
    pub fn init(&self, manifest: &ModelManifest, seed: f32) -> Result<TrainState> {
        let leaves = self.run(&manifest.init, &[tensor_to_literal(&Tensor::scalar(seed))?])?;
        anyhow::ensure!(
            leaves.len() == manifest.state.len(),
            "init returned {} leaves, manifest says {}",
            leaves.len(),
            manifest.state.len()
        );
        Ok(TrainState { leaves })
    }

    /// One SGD/Adam step; state advances in place, returns the loss.
    pub fn train_step(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &mut TrainState,
        x: &Tensor,
        y: &Tensor,
        bits: (u32, u32, u32),
        lr: f32,
    ) -> Result<f32> {
        let file = manifest.alg(alg)?.train.clone();
        let bits_t = Tensor::from_vec(vec![bits.0 as f32, bits.1 as f32, bits.2 as f32]);
        let extra = [
            tensor_to_literal(x)?,
            tensor_to_literal(y)?,
            tensor_to_literal(&bits_t)?,
            tensor_to_literal(&Tensor::scalar(lr))?,
        ];
        let inputs: Vec<&xla::Literal> =
            state.leaves.iter().chain(extra.iter()).collect();
        let mut out = self.run(&file, &inputs)?;
        anyhow::ensure!(
            out.len() == state.leaves.len() + 1,
            "train step returned {} outputs, expected {}",
            out.len(),
            state.leaves.len() + 1
        );
        let loss = literal_to_tensor(&out.pop().unwrap())?.item();
        state.leaves = out;
        Ok(loss)
    }

    /// Forward pass at the given bit widths.
    pub fn infer(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &TrainState,
        x: &Tensor,
        bits: (u32, u32, u32),
    ) -> Result<Tensor> {
        let file = manifest.alg(alg)?.infer.clone();
        let bits_t = Tensor::from_vec(vec![bits.0 as f32, bits.1 as f32, bits.2 as f32]);
        let extra = [tensor_to_literal(x)?, tensor_to_literal(&bits_t)?];
        let inputs: Vec<&xla::Literal> = state
            .params(manifest)
            .into_iter()
            .chain(extra.iter())
            .collect();
        let out = self.run(&file, &inputs)?;
        anyhow::ensure!(out.len() == 1, "infer returned {} outputs", out.len());
        literal_to_tensor(&out[0])
    }

    /// Export integer weights + scales + biases for deployment analysis.
    pub fn export(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &TrainState,
        bits: (u32, u32, u32),
    ) -> Result<Vec<ExportedLayer>> {
        let file = manifest
            .alg(alg)?
            .export
            .clone()
            .ok_or_else(|| anyhow::anyhow!("{alg} has no export artifact"))?;
        let bits_t = Tensor::from_vec(vec![bits.0 as f32, bits.1 as f32, bits.2 as f32]);
        let extra = [tensor_to_literal(&bits_t)?];
        let inputs: Vec<&xla::Literal> = state
            .params(manifest)
            .into_iter()
            .chain(extra.iter())
            .collect();
        let out = self.run(&file, &inputs)?;
        anyhow::ensure!(
            out.len() == 3 * manifest.qlayers.len(),
            "export returned {} tensors, expected {}",
            out.len(),
            3 * manifest.qlayers.len()
        );
        let mut layers = Vec::with_capacity(manifest.qlayers.len());
        for (i, q) in manifest.qlayers.iter().enumerate() {
            layers.push(ExportedLayer {
                name: q.name.clone(),
                w_int: literal_to_tensor(&out[3 * i])?,
                s: literal_to_tensor(&out[3 * i + 1])?,
                b: literal_to_tensor(&out[3 * i + 2])?,
            });
        }
        Ok(layers)
    }
}
