//! The training runtime: one [`TrainBackend`] abstraction
//! (`init / train_step / infer / export` over host-tensor [`TrainState`]
//! leaves) with two implementations, plus the artifact manifests both
//! consume.
//!
//! * [`artifact`] — serde types for the manifests (`<model>.json`) plus
//!   artifact/model discovery; always available.
//! * [`state`]    — [`TrainState`] (host-tensor leaves, the inter-backend
//!   currency) and [`ExportedLayer`] (deployment export triple).
//! * [`backend`]  — the [`TrainBackend`] trait, [`BackendKind`] selection
//!   and [`make_backend`] construction.
//! * [`native`]   — the pure-Rust backend: manual forward/backward for
//!   dense (MLP) manifests with STE through the
//!   [`crate::quant::WeightQuantizer`], in-process model registry
//!   ([`native::native_manifest`]); the default build's training engine.
//! * [`engine`] / [`literal`] (`xla` feature) — the PJRT CPU client with a
//!   compile cache, executing the AOT-compiled HLO-text artifacts produced
//!   by `python/compile/aot.py`. Interchange is HLO *text*: jax >= 0.5
//!   serializes HloModuleProto with 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//!   /opt/xla-example/README.md and DESIGN.md).

pub mod artifact;
pub mod backend;
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod literal;
pub mod native;
pub mod state;

pub use artifact::{AlgArtifacts, ModelManifest, QLayerMeta};
pub use backend::{make_backend, BackendKind, TrainBackend};
pub use native::{ComputePath, NativeBackend};
pub use state::{ExportedLayer, TrainState};
#[cfg(feature = "xla")]
pub use engine::Engine;
#[cfg(feature = "xla")]
pub use literal::{literal_to_tensor, tensor_to_literal};
