//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//!
//! * [`artifact`] — serde types for the artifact manifests (`<model>.json`)
//!   plus artifact discovery;
//! * [`literal`]  — [`crate::tensor::Tensor`] <-> [`xla::Literal`] transport;
//! * [`engine`]   — the PJRT CPU client with a compile cache, and the typed
//!   entry points (`init` / `train_step` / `infer` / `export`) the
//!   coordinator drives.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! The PJRT-backed pieces ([`engine`], [`literal`]) are gated behind the
//! `xla` cargo feature so the default build needs no XLA toolchain;
//! [`artifact`] (manifest parsing, model discovery) is always available.

pub mod artifact;
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod literal;

pub use artifact::{AlgArtifacts, ModelManifest, QLayerMeta};
#[cfg(feature = "xla")]
pub use engine::{Engine, ExportedLayer, TrainState};
#[cfg(feature = "xla")]
pub use literal::{literal_to_tensor, tensor_to_literal};
