//! The native pure-Rust training backend: manual forward/backward for dense
//! (MLP) manifests with STE through the weight quantizer, so the default
//! (no-XLA) build trains A2Q/A2Q+/QAT/float end to end.
//!
//! Semantics (mirroring the L2 JAX models at MLP scale):
//!
//! * **Weights** — per-channel direction `v` with log2-scale `d` and
//!   log2-norm `t` leaves. `a2q`/`a2q_plus` quantize through the
//!   [`WeightQuantizer`] trait (forward bit-exact against
//!   [`crate::quant::a2q::a2q_quantize_row`] for `a2q`); `qat` uses the
//!   per-channel affine quantizer with no accumulator cap; `float` uses `v`
//!   raw. Backward is the clipped straight-through estimator with the
//!   weight-norm parametrization differentiated exactly
//!   ([`crate::quant::quantizer`]), so `d` and `t` train by gradient.
//! * **Activations** — hidden boundaries are quantized ReLUs on the layer's
//!   unsigned N-bit grid with a *dynamic* per-batch scale
//!   (`s_a = max(relu(z)) / (2^N - 1)`, treated as a constant by the
//!   backward pass); the float algorithm uses plain ReLU.
//! * **Loss/optimizer** — softmax cross-entropy over the manifest's
//!   classify head; SGD with 0.9 momentum or Adam, per the manifest, with
//!   momentum/moment slots living in the manifest state layout
//!   (`mom/...`, `m/...`, `v/...`) exactly like the artifact models, so
//!   warmup recalibration and checkpointing are backend-agnostic.
//!   Quantizer log-parameters (`d`, `t`) step at [`QPARAM_LR_MULT`] times
//!   the weight LR with elementwise gradient clipping — the native stand-in
//!   for the scale-free treatment the artifact models give them.
//!
//! **Compute paths** — the hot path runs the three per-layer GEMM shapes
//! (forward `A·Wᵀ`, input-grad `dZ·W`, weight-grad `dZᵀ·A`) through the
//! shared blocked f32 core in [`crate::linalg`]: quantized weights are
//! packed once per `train_step` into register-tile panels, per-layer
//! activations/gradients live in flat scratch matrices reused across steps
//! (a [`Workspace`] behind a mutex), and the batch dimension fans out over
//! `std::thread::scope` workers. Forward/input-grad rows are independent
//! and weight-grad reduction uses a fixed block order
//! ([`crate::linalg::grad_reduce`]), so training is **bit-identical at any
//! thread count**. The original scalar triple loop survives as
//! [`ComputePath::Scalar`] — the reference the property tests and the
//! `train_step` bench compare the blocked engine against.
//!
//! Models come from the in-process registry ([`native_manifest`]: `mlp`,
//! `mlp3`, `mlp3_adam`) or from any artifact manifest whose quantized
//! layers are all dense.

pub mod models;

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{ensure, Result};

pub use models::{native_manifest, native_models};

use super::artifact::ModelManifest;
use super::backend::TrainBackend;
use super::state::{ExportedLayer, TrainState};
use crate::linalg::{self, GradScratch, KernelPath, PackedB};
use crate::quant::quantizer::{quantizer_for_alg, WeightQuantizer};
use crate::rng::Rng;
use crate::tensor::Tensor;

const LN2: f32 = std::f32::consts::LN_2;
/// LR multiplier for the per-channel quantizer log-parameters `d`/`t`.
pub const QPARAM_LR_MULT: f32 = 0.1;
/// Elementwise gradient clip for `d`/`t` (log2-domain parameters).
const QPARAM_GRAD_CLIP: f32 = 10.0;
const SGD_MOMENTUM: f32 = 0.9;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Which compute engine drives the dense forward/backward.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputePath {
    /// The original single-threaded scalar triple loop. Retained as the
    /// reference the blocked engine is property-tested (and benchmarked)
    /// against; not the path production runs take.
    Scalar,
    /// Packed blocked GEMM through [`crate::linalg`] with the batch fanned
    /// over scoped worker threads. The default.
    Blocked,
}

/// Reusable per-backend scratch: flat per-layer activation/pre-activation
/// matrices, packed weight panels and gradient buffers, grown on demand and
/// reused across `train_step`/`infer` calls. Lives behind a mutex because
/// the [`TrainBackend`] API takes `&self`.
#[derive(Default)]
struct Workspace {
    /// `acts[l]`: input to layer `l`, flat `[batch, k_l]` (`acts[0]` = batch).
    acts: Vec<Vec<f32>>,
    /// `zs[l]`: pre-activations of layer `l`, flat `[batch, c_out_l]`.
    zs: Vec<Vec<f32>>,
    /// Forward-packed quantized weights per layer (NT panels: `z = a·Wᵀ`).
    fwd_packs: Vec<PackedB>,
    /// Input-grad pack of the current layer (NN panels: `dA = dZ·W`).
    grad_pack: PackedB,
    /// dL/dz of the current layer / of the previous layer (ping-pong).
    d_act: Vec<f32>,
    d_prev: Vec<f32>,
    /// Per-layer gradient staging: wrt quantized weights, bias, and the
    /// quantizer leaves.
    g_w: Vec<f32>,
    g_b: Vec<f32>,
    g_v: Vec<f32>,
    g_d: Vec<f32>,
    g_t: Vec<f32>,
    /// Softmax row scratch.
    exps: Vec<f32>,
    /// Block partials for the fixed-order weight-grad reduction.
    grad_scratch: GradScratch,
}

/// Pure-Rust training backend over host-tensor state leaves.
pub struct NativeBackend {
    dir: PathBuf,
    path: ComputePath,
    /// Explicit worker-thread pin for the blocked path (`None` = pick from
    /// the job size, `A2Q_NATIVE_THREADS` overrides).
    threads: Option<usize>,
    /// Explicit GEMM kernel-path pin for the blocked path's packs (`None`
    /// = auto dispatch per pack; `A2Q_KERNEL` overrides inside auto).
    kernel: Option<KernelPath>,
    ws: Mutex<Workspace>,
}

impl NativeBackend {
    /// Create a backend on the blocked+threaded path; `artifacts_dir` is
    /// only consulted for models not in the native registry.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Self {
        NativeBackend {
            dir: artifacts_dir.as_ref().to_path_buf(),
            path: ComputePath::Blocked,
            threads: None,
            kernel: None,
            ws: Mutex::new(Workspace::default()),
        }
    }

    /// Select the compute path (tests and the `train_step` bench use
    /// [`ComputePath::Scalar`] as the reference).
    pub fn with_compute(mut self, path: ComputePath) -> Self {
        self.path = path;
        self
    }

    /// Pin the blocked path's worker-thread count (results are
    /// bit-identical for any pin; this only moves wall-clock).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads.max(1));
        self
    }

    /// Pin the blocked path's GEMM kernel dispatch — forward, weight-grad
    /// and input-grad packs all follow it (benches use this to compare
    /// scalar vs SIMD vs sparse on identical training runs).
    pub fn with_kernel(mut self, kernel: KernelPath) -> Self {
        self.kernel = Some(kernel);
        self
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    fn workers(&self, rows: usize, flops_per_row: usize) -> usize {
        match self.threads {
            Some(n) => n,
            None => linalg::gemm_workers(rows.saturating_mul(flops_per_row)),
        }
    }
}

/// One dense layer's resolved view of the manifest: state-leaf indices plus
/// the bit widths at the current (M, N, P) grid point.
struct LayerRef {
    v: usize,
    d: usize,
    t: usize,
    b: usize,
    c_out: usize,
    k: usize,
    m: u32,
    n_in: u32,
    p: u32,
    x_signed: bool,
}

fn find_leaf(manifest: &ModelManifest, path: &str) -> Result<usize> {
    manifest
        .state
        .iter()
        .position(|e| e.path == path)
        .ok_or_else(|| anyhow::anyhow!("manifest {} has no state leaf {path}", manifest.name))
}

fn resolve(spec: &super::artifact::BitsSpecJson, bits: (u32, u32, u32)) -> Result<u32> {
    Ok(spec.to_bitspec()?.resolve(bits.0, bits.1, bits.2))
}

/// Resolve every quantized layer of a manifest the native backend can
/// train: all-dense, chained, with the standard `params/<name>/{v,d,t,b}`
/// leaves.
fn layer_refs(manifest: &ModelManifest, bits: (u32, u32, u32)) -> Result<Vec<LayerRef>> {
    ensure!(!manifest.qlayers.is_empty(), "manifest {} has no layers", manifest.name);
    let mut out = Vec::with_capacity(manifest.qlayers.len());
    for (i, q) in manifest.qlayers.iter().enumerate() {
        ensure!(
            q.kind == "dense",
            "native backend trains dense (MLP) manifests only; layer {} of {} is {:?} — \
             use the xla backend for conv models",
            q.name,
            manifest.name,
            q.kind
        );
        if i > 0 {
            ensure!(
                q.k == manifest.qlayers[i - 1].c_out,
                "layer {} input dim {} does not chain to previous c_out {}",
                q.name,
                q.k,
                manifest.qlayers[i - 1].c_out
            );
        }
        out.push(LayerRef {
            v: find_leaf(manifest, &format!("params/{}/v", q.name))?,
            d: find_leaf(manifest, &format!("params/{}/d", q.name))?,
            t: find_leaf(manifest, &format!("params/{}/t", q.name))?,
            b: find_leaf(manifest, &format!("params/{}/b", q.name))?,
            c_out: q.c_out,
            k: q.k,
            m: resolve(&q.m_bits, bits)?,
            n_in: resolve(&q.n_bits, bits)?,
            p: resolve(&q.p_bits, bits)?,
            x_signed: q.x_signed,
        });
    }
    Ok(out)
}

/// Dequantized weights of one layer under one algorithm.
struct LayerWeights {
    /// Integer codes `[c_out, k]` (f32 carrying exact integers; raw float
    /// weights for the float algorithm).
    w_int: Vec<f32>,
    /// Per-channel scales.
    s: Vec<f32>,
    /// Dequantized weights `[c_out, k]` the forward multiplies with.
    wq: Vec<f32>,
}

/// What the backward pass needs from one forward, beyond the staged
/// activations in the [`Workspace`].
struct ForwardInfo {
    batch: usize,
    weights: Vec<LayerWeights>,
}

fn quantize_layer(
    alg: &str,
    v: &Tensor,
    d: &Tensor,
    t: &Tensor,
    lr_ref: &LayerRef,
) -> Result<LayerWeights> {
    let (c_out, k) = (lr_ref.c_out, lr_ref.k);
    match alg {
        "float" => Ok(LayerWeights {
            w_int: v.data().to_vec(),
            s: vec![1.0; c_out],
            wq: v.data().to_vec(),
        }),
        "qat" => {
            let hi = 2f32.powi(lr_ref.m as i32 - 1) - 1.0;
            let lo = -(2f32.powi(lr_ref.m as i32 - 1));
            let mut w_int = Vec::with_capacity(c_out * k);
            let mut s = Vec::with_capacity(c_out);
            let mut wq = Vec::with_capacity(c_out * k);
            for c in 0..c_out {
                let sc = 2f32.powf(d.data()[c]);
                for &x in v.row(c) {
                    let u = (x / sc).round().clamp(lo, hi);
                    w_int.push(u);
                    wq.push(u * sc);
                }
                s.push(sc);
            }
            Ok(LayerWeights { w_int, s, wq })
        }
        _ => {
            let q = quantizer_for_alg(alg)
                .ok_or_else(|| anyhow::anyhow!("unknown training algorithm {alg:?}"))?;
            let mut w_int = Vec::with_capacity(c_out * k);
            let mut s = Vec::with_capacity(c_out);
            let mut wq = Vec::with_capacity(c_out * k);
            for c in 0..c_out {
                let (codes, sc) = q.quantize_row(
                    v.row(c),
                    d.data()[c],
                    t.data()[c],
                    lr_ref.m,
                    lr_ref.n_in,
                    lr_ref.p,
                    lr_ref.x_signed,
                );
                wq.extend(codes.iter().map(|w| w * sc));
                w_int.extend(codes);
                s.push(sc);
            }
            Ok(LayerWeights { w_int, s, wq })
        }
    }
}

/// Scalar reference forward: `z[B, c_out] = a[B, k] @ w[c_out, k]^T + bias`.
/// The [`ComputePath::Scalar`] twin of the packed blocked kernel — kept
/// bit-stable so property tests can anchor on it.
fn dense_forward_ref(
    a: &[f32],
    batch: usize,
    k: usize,
    w: &[f32],
    c_out: usize,
    bias: &[f32],
    z: &mut [f32],
) {
    debug_assert_eq!(z.len(), batch * c_out);
    for r in 0..batch {
        let ar = &a[r * k..(r + 1) * k];
        let zr = &mut z[r * c_out..(r + 1) * c_out];
        for c in 0..c_out {
            let wr = &w[c * k..(c + 1) * k];
            let mut acc = 0.0f32;
            for (ai, wi) in ar.iter().zip(wr) {
                acc += ai * wi;
            }
            zr[c] = acc + bias[c];
        }
    }
}

/// Stable softmax cross-entropy into reusable buffers: returns the mean
/// loss, leaves dL/dlogits in `dz`.
fn softmax_ce(
    logits: &[f32],
    batch: usize,
    classes: usize,
    labels: &[f32],
    dz: &mut Vec<f32>,
    exps: &mut Vec<f32>,
) -> f32 {
    dz.clear();
    dz.resize(batch * classes, 0.0);
    let mut loss = 0.0f64;
    for r in 0..batch {
        let row = &logits[r * classes..(r + 1) * classes];
        let max = row.iter().fold(f32::NEG_INFINITY, |a, x| a.max(*x));
        exps.clear();
        exps.extend(row.iter().map(|x| (x - max).exp()));
        let sum: f32 = exps.iter().sum();
        let label = (labels[r] as usize).min(classes - 1);
        loss -= ((exps[label] / sum).max(1e-30) as f64).ln();
        let dr = &mut dz[r * classes..(r + 1) * classes];
        for c in 0..classes {
            dr[c] = (exps[c] / sum - if c == label { 1.0 } else { 0.0 }) / batch as f32;
        }
    }
    (loss / batch as f64) as f32
}

/// Two disjoint mutable leaves out of the state vector.
fn two_mut(leaves: &mut [Tensor], i: usize, j: usize) -> (&mut Tensor, &mut Tensor) {
    assert_ne!(i, j);
    if i < j {
        let (a, b) = leaves.split_at_mut(j);
        (&mut a[i], &mut b[0])
    } else {
        let (a, b) = leaves.split_at_mut(i);
        (&mut b[0], &mut a[j])
    }
}

impl NativeBackend {
    /// Flatten a batch tensor to `[B, k]`, validating against the first
    /// layer's input dimension.
    fn flatten_batch<'a>(x: &'a Tensor, k0: usize) -> Result<(&'a [f32], usize)> {
        ensure!(!x.shape().is_empty() && !x.is_empty(), "empty input batch");
        let batch = x.shape()[0];
        ensure!(
            batch > 0 && x.len() == batch * k0,
            "batch of {} elements does not flatten to [{batch}, {k0}]",
            x.len()
        );
        Ok((x.data(), batch))
    }

    /// Forward the batch through every layer, staging activations and
    /// pre-activations in the workspace. Quantized weights are packed once
    /// here and reused by the whole step.
    fn forward(
        &self,
        manifest: &ModelManifest,
        layers: &[LayerRef],
        alg: &str,
        leaves: &[Tensor],
        x: &Tensor,
        ws: &mut Workspace,
    ) -> Result<ForwardInfo> {
        ensure!(
            manifest.task == "classify",
            "native backend supports classify manifests; {} is {:?}",
            manifest.name,
            manifest.task
        );
        let (xdata, batch) = Self::flatten_batch(x, layers[0].k)?;
        let depth = layers.len();
        ws.acts.resize_with(depth, Vec::new);
        ws.zs.resize_with(depth, Vec::new);
        ws.fwd_packs.resize_with(depth, PackedB::new);
        ws.acts[0].clear();
        ws.acts[0].extend_from_slice(xdata);
        let mut weights: Vec<LayerWeights> = Vec::with_capacity(depth);
        for (l, lref) in layers.iter().enumerate() {
            let lw = quantize_layer(alg, &leaves[lref.v], &leaves[lref.d], &leaves[lref.t], lref)?;
            let (c_out, k) = (lref.c_out, lref.k);
            let bias = leaves[lref.b].data();
            {
                let a = &ws.acts[l];
                let z = &mut ws.zs[l];
                z.clear();
                z.resize(batch * c_out, 0.0);
                match self.path {
                    ComputePath::Scalar => dense_forward_ref(a, batch, k, &lw.wq, c_out, bias, z),
                    ComputePath::Blocked => {
                        let pack = &mut ws.fwd_packs[l];
                        pack.force_path(self.kernel);
                        pack.pack_t(&lw.wq, c_out, k);
                        linalg::matmul_par(pack, a, batch, z, self.workers(batch, c_out * k));
                        linalg::add_bias(z, batch, c_out, bias);
                    }
                }
            }
            weights.push(lw);
            if l + 1 < depth {
                let m = ws.zs[l].iter().fold(0.0f32, |a, v| a.max(*v));
                let z = &ws.zs[l];
                let a_next = &mut ws.acts[l + 1];
                a_next.clear();
                if alg == "float" {
                    a_next.extend(z.iter().map(|v| v.max(0.0)));
                } else {
                    // quantized ReLU on the next layer's unsigned N-bit grid,
                    // dynamic per-batch scale (constant to the backward pass)
                    let n_next = layers[l + 1].n_in.min(31);
                    let qmax = ((1u64 << n_next) - 1) as f32;
                    let s_a = if m > 0.0 { m / qmax } else { 1.0 };
                    a_next.extend(z.iter().map(|v| (v / s_a).round().clamp(0.0, qmax) * s_a));
                }
            }
        }
        Ok(ForwardInfo { batch, weights })
    }

    /// Apply one optimizer step to the leaf at `idx` with gradient `grad`.
    #[allow(clippy::too_many_arguments)]
    fn apply_update(
        &self,
        manifest: &ModelManifest,
        leaves: &mut [Tensor],
        idx: usize,
        suffix: &str,
        grad: &[f32],
        lr: f32,
        step: f32,
    ) -> Result<()> {
        match manifest.optimizer.as_str() {
            "adam" => {
                let mi = find_leaf(manifest, &format!("m/{suffix}"));
                let vi = find_leaf(manifest, &format!("v/{suffix}"));
                match (mi, vi) {
                    (Ok(mi), Ok(vi)) => {
                        let t = step.max(1.0);
                        let upd: Vec<f32> = {
                            let (m, vv) = two_mut(leaves, mi, vi);
                            let (md, vd) = (m.data_mut(), vv.data_mut());
                            let mut upd = Vec::with_capacity(grad.len());
                            for i in 0..grad.len() {
                                md[i] = ADAM_B1 * md[i] + (1.0 - ADAM_B1) * grad[i];
                                vd[i] = ADAM_B2 * vd[i] + (1.0 - ADAM_B2) * grad[i] * grad[i];
                                let mhat = md[i] / (1.0 - ADAM_B1.powf(t));
                                let vhat = vd[i] / (1.0 - ADAM_B2.powf(t));
                                upd.push(lr * mhat / (vhat.sqrt() + ADAM_EPS));
                            }
                            upd
                        };
                        let p = leaves[idx].data_mut();
                        for (pi, ui) in p.iter_mut().zip(&upd) {
                            *pi -= ui;
                        }
                    }
                    _ => {
                        // no moment slots in the layout: plain SGD
                        let p = leaves[idx].data_mut();
                        for (pi, gi) in p.iter_mut().zip(grad) {
                            *pi -= lr * gi;
                        }
                    }
                }
            }
            _ => {
                // SGD (with momentum when the layout carries a slot)
                if let Ok(momi) = find_leaf(manifest, &format!("mom/{suffix}")) {
                    let (p, mom) = two_mut(leaves, idx, momi);
                    let (pd, md) = (p.data_mut(), mom.data_mut());
                    for i in 0..grad.len() {
                        md[i] = SGD_MOMENTUM * md[i] + grad[i];
                        pd[i] -= lr * md[i];
                    }
                } else {
                    let p = leaves[idx].data_mut();
                    for (pi, gi) in p.iter_mut().zip(grad) {
                        *pi -= lr * gi;
                    }
                }
            }
        }
        Ok(())
    }
}

impl TrainBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self, model: &str) -> Result<ModelManifest> {
        match native_manifest(model) {
            Some(m) => Ok(m),
            None => ModelManifest::load(&self.dir, model),
        }
    }

    fn init(&self, manifest: &ModelManifest, seed: f32) -> Result<TrainState> {
        // Structural validation at default widths; init itself is grid-free.
        let layers = layer_refs(manifest, (8, 8, 32))?;
        let mut leaves: Vec<Tensor> =
            manifest.state.iter().map(|e| Tensor::zeros(e.shape.clone())).collect();
        let mut rng = Rng::new((seed.to_bits() as u64) ^ 0xA201_57A7);
        for lref in &layers {
            let std = (2.0 / lref.k as f64).sqrt();
            let vdata: Vec<f32> =
                (0..lref.c_out * lref.k).map(|_| (rng.normal() * std) as f32).collect();
            // d/t from the shared init rules (the same helper warmup
            // recalibration uses), at the widest weight grid (M = 8); both
            // train by gradient afterwards.
            let mut dv = Vec::with_capacity(lref.c_out);
            let mut tv = Vec::with_capacity(lref.c_out);
            for c in 0..lref.c_out {
                let row = &vdata[c * lref.k..(c + 1) * lref.k];
                let (d0, t0) = crate::quant::quantizer::init_qparams_row(row, 8);
                dv.push(d0);
                tv.push(t0);
            }
            leaves[lref.v].data_mut().copy_from_slice(&vdata);
            leaves[lref.d].data_mut().copy_from_slice(&dv);
            leaves[lref.t].data_mut().copy_from_slice(&tv);
        }
        Ok(TrainState { leaves })
    }

    fn train_step(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &mut TrainState,
        x: &Tensor,
        y: &Tensor,
        bits: (u32, u32, u32),
        lr: f32,
    ) -> Result<f32> {
        let layers = layer_refs(manifest, bits)?;
        let mut ws_guard = self.ws.lock().unwrap_or_else(|p| p.into_inner());
        let ws = &mut *ws_guard;
        let fwd = self.forward(manifest, &layers, alg, &state.leaves, x, ws)?;
        let depth = layers.len();
        let classes = layers[depth - 1].c_out;
        ensure!(y.len() >= fwd.batch, "labels shorter than batch");
        let loss = softmax_ce(
            &ws.zs[depth - 1],
            fwd.batch,
            classes,
            y.data(),
            &mut ws.d_act,
            &mut ws.exps,
        );

        // advance the step counter first (Adam bias correction uses it)
        let step = match find_leaf(manifest, "step") {
            Ok(si) => {
                let s = state.leaves[si].data_mut();
                s[0] += 1.0;
                s[0]
            }
            Err(_) => 1.0,
        };

        let wd = manifest.weight_decay as f32;
        for l in (0..depth).rev() {
            let lref = &layers[l];
            let (c_out, k, batch) = (lref.c_out, lref.k, fwd.batch);
            let lw = &fwd.weights[l];

            // bias + weight gradients (wrt the *quantized* weights)
            ws.g_b.clear();
            ws.g_b.resize(c_out, 0.0);
            ws.g_w.clear();
            ws.g_w.resize(c_out * k, 0.0);
            match self.path {
                ComputePath::Scalar => {
                    let a_in = &ws.acts[l];
                    for r in 0..batch {
                        let dzr = &ws.d_act[r * c_out..(r + 1) * c_out];
                        let ar = &a_in[r * k..(r + 1) * k];
                        for c in 0..c_out {
                            let g = dzr[c];
                            if g != 0.0 {
                                ws.g_b[c] += g;
                                let row = &mut ws.g_w[c * k..(c + 1) * k];
                                for (ri, ai) in row.iter_mut().zip(ar) {
                                    *ri += g * ai;
                                }
                            }
                        }
                    }
                }
                ComputePath::Blocked => {
                    ws.grad_scratch.force_path(self.kernel);
                    linalg::grad_reduce(
                        &ws.d_act,
                        &ws.acts[l],
                        batch,
                        c_out,
                        k,
                        self.workers(batch, c_out * k),
                        &mut ws.g_w,
                        &mut ws.g_b,
                        &mut ws.grad_scratch,
                    )
                }
            }

            // input gradient (before this layer's weights move)
            let has_d_prev = l > 0;
            if has_d_prev {
                ws.d_prev.clear();
                ws.d_prev.resize(batch * k, 0.0);
                match self.path {
                    ComputePath::Scalar => {
                        for r in 0..batch {
                            let dzr = &ws.d_act[r * c_out..(r + 1) * c_out];
                            let dr = &mut ws.d_prev[r * k..(r + 1) * k];
                            for c in 0..c_out {
                                let g = dzr[c];
                                if g != 0.0 {
                                    let wr = &lw.wq[c * k..(c + 1) * k];
                                    for (di, wi) in dr.iter_mut().zip(wr) {
                                        *di += g * wi;
                                    }
                                }
                            }
                        }
                    }
                    ComputePath::Blocked => {
                        // NN pack: W as a [K = c_out, N = k] operand
                        ws.grad_pack.force_path(self.kernel);
                        ws.grad_pack.pack_nn(&lw.wq, c_out, k);
                        linalg::matmul_par(
                            &ws.grad_pack,
                            &ws.d_act,
                            batch,
                            &mut ws.d_prev,
                            self.workers(batch, c_out * k),
                        );
                    }
                }
            }

            // route dL/dwq through the weight quantizer (STE)
            ws.g_v.clear();
            ws.g_v.resize(c_out * k, 0.0);
            ws.g_d.clear();
            ws.g_d.resize(c_out, 0.0);
            ws.g_t.clear();
            ws.g_t.resize(c_out, 0.0);
            match alg {
                "float" => ws.g_v.copy_from_slice(&ws.g_w),
                "qat" => {
                    let hi = 2f32.powi(lref.m as i32 - 1) - 1.0;
                    let lo = -(2f32.powi(lref.m as i32 - 1));
                    let v = &state.leaves[lref.v];
                    for c in 0..c_out {
                        let sc = lw.s[c];
                        for (i, &x) in v.row(c).iter().enumerate() {
                            let u = (x / sc).round();
                            let gi = ws.g_w[c * k + i];
                            if u < lo || u > hi {
                                ws.g_d[c] += gi * u.clamp(lo, hi) * sc * LN2;
                            } else {
                                ws.g_v[c * k + i] = gi;
                            }
                        }
                    }
                }
                _ => {
                    let q: &dyn WeightQuantizer = quantizer_for_alg(alg)
                        .ok_or_else(|| anyhow::anyhow!("unknown training algorithm {alg:?}"))?;
                    let v = &state.leaves[lref.v];
                    let dt = &state.leaves[lref.d];
                    let tt = &state.leaves[lref.t];
                    for c in 0..c_out {
                        let (gd, gt) = q.grad_row(
                            v.row(c),
                            dt.data()[c],
                            tt.data()[c],
                            lref.m,
                            lref.n_in,
                            lref.p,
                            lref.x_signed,
                            &ws.g_w[c * k..(c + 1) * k],
                            &mut ws.g_v[c * k..(c + 1) * k],
                        );
                        ws.g_d[c] = gd;
                        ws.g_t[c] = gt;
                    }
                }
            }
            if wd > 0.0 {
                for (gi, vi) in ws.g_v.iter_mut().zip(state.leaves[lref.v].data()) {
                    *gi += wd * vi;
                }
            }
            for g in ws.g_d.iter_mut().chain(ws.g_t.iter_mut()) {
                *g = g.clamp(-QPARAM_GRAD_CLIP, QPARAM_GRAD_CLIP);
            }

            let qname = &manifest.qlayers[l].name;
            let qlr = lr * QPARAM_LR_MULT;
            self.apply_update(
                manifest,
                &mut state.leaves,
                lref.v,
                &format!("{qname}/v"),
                &ws.g_v,
                lr,
                step,
            )?;
            self.apply_update(
                manifest,
                &mut state.leaves,
                lref.d,
                &format!("{qname}/d"),
                &ws.g_d,
                qlr,
                step,
            )?;
            self.apply_update(
                manifest,
                &mut state.leaves,
                lref.t,
                &format!("{qname}/t"),
                &ws.g_t,
                qlr,
                step,
            )?;
            self.apply_update(
                manifest,
                &mut state.leaves,
                lref.b,
                &format!("{qname}/b"),
                &ws.g_b,
                lr,
                step,
            )?;

            // through the hidden activation into the previous layer: the
            // STE gate is the ReLU mask (see the forward doc — with dynamic
            // scaling the upper rail never clips)
            if has_d_prev {
                let z_prev = &ws.zs[l - 1];
                for (di, zi) in ws.d_prev.iter_mut().zip(z_prev) {
                    if *zi <= 0.0 {
                        *di = 0.0;
                    }
                }
                std::mem::swap(&mut ws.d_act, &mut ws.d_prev);
            }
        }
        Ok(loss)
    }

    fn infer(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &TrainState,
        x: &Tensor,
        bits: (u32, u32, u32),
    ) -> Result<Tensor> {
        let layers = layer_refs(manifest, bits)?;
        let mut ws_guard = self.ws.lock().unwrap_or_else(|p| p.into_inner());
        let ws = &mut *ws_guard;
        let fwd = self.forward(manifest, &layers, alg, &state.leaves, x, ws)?;
        let classes = layers[layers.len() - 1].c_out;
        Ok(Tensor::new(vec![fwd.batch, classes], ws.zs[layers.len() - 1].clone()))
    }

    fn export(
        &self,
        manifest: &ModelManifest,
        alg: &str,
        state: &TrainState,
        bits: (u32, u32, u32),
    ) -> Result<Vec<ExportedLayer>> {
        ensure!(alg != "float", "the float baseline has no integer export");
        let layers = layer_refs(manifest, bits)?;
        let mut out = Vec::with_capacity(layers.len());
        for (lref, q) in layers.iter().zip(&manifest.qlayers) {
            let lw = quantize_layer(
                alg,
                &state.leaves[lref.v],
                &state.leaves[lref.d],
                &state.leaves[lref.t],
                lref,
            )?;
            out.push(ExportedLayer {
                name: q.name.clone(),
                w_int: Tensor::new(vec![lref.c_out, lref.k], lw.w_int),
                s: Tensor::new(vec![lref.c_out, 1], lw.s),
                b: Tensor::new(vec![lref.c_out], state.leaves[lref.b].data().to_vec()),
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, Split};
    use crate::finn::estimate::BitSpec;
    use crate::quant::a2q::row_satisfies_cap;

    fn backend() -> NativeBackend {
        NativeBackend::new("artifacts")
    }

    fn batch(n: usize) -> (Tensor, Tensor) {
        let ds = datasets::by_name("synth_mnist", 256, 64, 0).unwrap();
        let idx: Vec<usize> = (0..n).collect();
        let b = ds.gather(Split::Train, &idx);
        (b.x, b.y)
    }

    #[test]
    fn init_matches_layout_and_is_seed_dependent() {
        let be = backend();
        let manifest = be.manifest("mlp3").unwrap();
        let s0 = be.init(&manifest, 0.0).unwrap();
        let s1 = be.init(&manifest, 1.0).unwrap();
        assert_eq!(s0.leaves.len(), manifest.state.len());
        for (t, meta) in s0.leaves.iter().zip(&manifest.state) {
            assert_eq!(t.shape(), &meta.shape[..], "leaf {}", meta.path);
        }
        let vi = manifest.state.iter().position(|e| e.path == "params/fc0/v").unwrap();
        assert_ne!(s0.leaves[vi].data(), s1.leaves[vi].data(), "seed must matter");
        let s0b = be.init(&manifest, 0.0).unwrap();
        assert_eq!(s0.leaves[vi].data(), s0b.leaves[vi].data(), "same seed bit-identical");
    }

    #[test]
    fn train_step_decreases_loss_on_repeated_batch_all_algs() {
        let be = backend();
        let manifest = be.manifest("mlp").unwrap();
        let (x, y) = batch(manifest.batch_size);
        for alg in ["a2q", "a2q_plus", "qat", "float"] {
            let mut state = be.init(&manifest, 0.0).unwrap();
            let mut losses = Vec::new();
            for _ in 0..12 {
                let l = be
                    .train_step(&manifest, alg, &mut state, &x, &y, (8, 1, 16), 0.05)
                    .unwrap();
                assert!(l.is_finite(), "{alg}");
                losses.push(l);
            }
            assert!(
                losses.last().unwrap() < losses.first().unwrap(),
                "{alg}: {losses:?}"
            );
        }
    }

    #[test]
    fn multilayer_training_learns_and_stays_finite() {
        let be = backend();
        let manifest = be.manifest("mlp3").unwrap();
        let (x, y) = batch(manifest.batch_size);
        let mut state = be.init(&manifest, 3.0).unwrap();
        let mut losses = Vec::new();
        for _ in 0..20 {
            let l = be.train_step(&manifest, "a2q", &mut state, &x, &y, (4, 4, 14), 0.05).unwrap();
            assert!(l.is_finite());
            losses.push(l);
        }
        assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
    }

    #[test]
    fn infer_is_deterministic_and_bits_matter() {
        let be = backend();
        let manifest = be.manifest("mlp").unwrap();
        let (x, _) = batch(manifest.batch_size);
        let state = be.init(&manifest, 0.0).unwrap();
        let a = be.infer(&manifest, "a2q", &state, &x, (8, 1, 14)).unwrap();
        let b = be.infer(&manifest, "a2q", &state, &x, (8, 1, 14)).unwrap();
        assert_eq!(a.shape(), &[manifest.batch_size, manifest.n_classes]);
        assert_eq!(a.data(), b.data(), "inference must be deterministic");
        let tight = be.infer(&manifest, "a2q", &state, &x, (8, 1, 6)).unwrap();
        assert_ne!(a.data(), tight.data(), "P must influence the a2q forward");
    }

    #[test]
    fn blocked_infer_tracks_the_scalar_reference() {
        let scalar = backend().with_compute(ComputePath::Scalar);
        let blocked = backend();
        let manifest = scalar.manifest("mlp3").unwrap();
        let (x, _) = batch(manifest.batch_size);
        let state = scalar.init(&manifest, 11.0).unwrap();
        let a = scalar.infer(&manifest, "a2q", &state, &x, (4, 4, 14)).unwrap();
        let b = blocked.infer(&manifest, "a2q", &state, &x, (4, 4, 14)).unwrap();
        assert_eq!(a.shape(), b.shape());
        for (s, bl) in a.data().iter().zip(b.data()) {
            let tol = 1e-4 * (1.0 + s.abs());
            assert!((s - bl).abs() <= tol, "scalar {s} vs blocked {bl}");
        }
    }

    #[test]
    fn blocked_train_step_is_thread_count_invariant() {
        let manifest = backend().manifest("mlp3").unwrap();
        let (x, y) = batch(manifest.batch_size);
        let run = |threads: usize| {
            let be = backend().with_threads(threads);
            let mut state = be.init(&manifest, 2.0).unwrap();
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(
                    be.train_step(&manifest, "a2q", &mut state, &x, &y, (4, 4, 14), 0.05).unwrap(),
                );
            }
            (losses, state)
        };
        let (l1, s1) = run(1);
        let (l3, s3) = run(3);
        assert_eq!(l1, l3, "losses must be bit-identical across thread counts");
        for (a, b) in s1.leaves.iter().zip(&s3.leaves) {
            assert_eq!(a.data(), b.data(), "leaves must be bit-identical across thread counts");
        }
    }

    #[test]
    fn forced_kernel_paths_track_the_scalar_reference_on_infer() {
        let scalar = backend().with_compute(ComputePath::Scalar);
        let manifest = scalar.manifest("mlp3").unwrap();
        let (x, _) = batch(manifest.batch_size);
        let state = scalar.init(&manifest, 11.0).unwrap();
        let a = scalar.infer(&manifest, "a2q", &state, &x, (4, 4, 14)).unwrap();
        for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
            let be = backend().with_kernel(path);
            let b = be.infer(&manifest, "a2q", &state, &x, (4, 4, 14)).unwrap();
            assert_eq!(a.shape(), b.shape());
            for (s, bl) in a.data().iter().zip(b.data()) {
                let tol = 1e-4 * (1.0 + s.abs());
                assert!((s - bl).abs() <= tol, "{path:?}: scalar {s} vs blocked {bl}");
            }
        }
    }

    #[test]
    fn forced_kernel_train_steps_stay_thread_count_invariant() {
        let manifest = backend().manifest("mlp3").unwrap();
        let (x, y) = batch(manifest.batch_size);
        let run = |path: KernelPath, threads: usize| {
            let be = backend().with_kernel(path).with_threads(threads);
            let mut state = be.init(&manifest, 2.0).unwrap();
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(
                    be.train_step(&manifest, "a2q", &mut state, &x, &y, (4, 4, 14), 0.05).unwrap(),
                );
            }
            (losses, state)
        };
        for path in [KernelPath::Simd, KernelPath::SparseSimd] {
            let (l1, s1) = run(path, 1);
            let (l3, s3) = run(path, 3);
            assert_eq!(l1, l3, "{path:?}: losses must not depend on thread count");
            for (a, b) in s1.leaves.iter().zip(&s3.leaves) {
                assert_eq!(a.data(), b.data(), "{path:?}: leaves must not depend on thread count");
            }
            assert!(l1.iter().all(|l| l.is_finite()), "{path:?}: {l1:?}");
        }
    }

    #[test]
    fn export_satisfies_cap_for_both_quantizers() {
        let be = backend();
        let manifest = be.manifest("mlp3").unwrap();
        let (x, y) = batch(manifest.batch_size);
        let bits = (4u32, 4u32, 14u32);
        for alg in ["a2q", "a2q_plus"] {
            let mut state = be.init(&manifest, 7.0).unwrap();
            for _ in 0..5 {
                be.train_step(&manifest, alg, &mut state, &x, &y, bits, 0.05).unwrap();
            }
            let layers = be.export(&manifest, alg, &state, bits).unwrap();
            assert_eq!(layers.len(), manifest.qlayers.len());
            for (layer, meta) in layers.iter().zip(&manifest.qlayers) {
                let q = layer.to_qtensor();
                let n = match meta.n_bits.to_bitspec().unwrap() {
                    BitSpec::Fixed(v) => v,
                    _ => bits.1,
                };
                for c in 0..q.c_out {
                    let row: Vec<f32> = q.row(c).iter().map(|w| *w as f32).collect();
                    assert!(
                        row_satisfies_cap(&row, bits.2, n, meta.x_signed),
                        "{alg}/{}/{c}",
                        layer.name
                    );
                }
            }
        }
        assert!(be.export(&manifest, "float", &be.init(&manifest, 0.0).unwrap(), bits).is_err());
    }
}
