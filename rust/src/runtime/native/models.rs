//! The native model registry: MLP manifests synthesized in-process, so the
//! default (no-XLA) build can train without `make artifacts`.
//!
//! Every native manifest follows the artifact conventions exactly — state
//! leaves at `params/<layer>/{v,d,t,b}` with optimizer slots at
//! `mom/<layer>/<leaf>` (SGD momentum) or `m/`+`v/<layer>/<leaf>` (Adam
//! moments) and a trailing `step` scalar, `params` as the
//! `params/`-stripped subsequence, and three export outputs per layer — so
//! the coordinator (recalibration, checkpointing, audit) treats native and
//! artifact-backed models identically.

use std::collections::BTreeMap;

use super::super::artifact::{
    AlgArtifacts, BitsSpecJson, ExportEntry, ModelManifest, QLayerMeta, StateEntry, TrainInputs,
};

/// Models the native backend can synthesize without artifacts.
pub fn native_models() -> &'static [&'static str] {
    &["mlp", "mlp3", "mlp3_adam"]
}

/// Build the native manifest for a registry model, or `None` if unknown.
///
/// * `mlp`  — the paper's Fig. 2 model: one dense layer `fc` 784 -> 2 over
///   binary (1-bit) synth-MNIST pixels.
/// * `mlp3` — a 3-layer stack 784 -> 64 -> 16 -> 2 with N-bit hidden
///   boundaries, exercising inter-layer requantization end to end.
/// * `mlp3_adam` — the `mlp3` geometry trained with Adam (`m/`, `v/`
///   moment slots in the state layout instead of `mom/`), exercising the
///   native backend's Adam update path.
pub fn native_manifest(model: &str) -> Option<ModelManifest> {
    let (widths, names, lr, optimizer): (&[usize], &[&str], f64, &str) = match model {
        "mlp" => (&[784, 2], &["fc"], 0.1, "sgd"),
        "mlp3" => (&[784, 64, 16, 2], &["fc0", "fc1", "fc2"], 0.1, "sgd"),
        // Adam's effective step is ~lr, so it wants a much smaller one than
        // the momentum-SGD models.
        "mlp3_adam" => (&[784, 64, 16, 2], &["fc0", "fc1", "fc2"], 0.005, "adam"),
        _ => return None,
    };
    Some(build_mlp_manifest(model, widths, names, lr, optimizer))
}

fn build_mlp_manifest(
    model: &str,
    widths: &[usize],
    names: &[&str],
    lr: f64,
    optimizer: &str,
) -> ModelManifest {
    assert_eq!(widths.len(), names.len() + 1, "one name per layer");
    let batch_size = 32usize;
    let mut qlayers = Vec::new();
    let mut state = Vec::new();
    let mut params = Vec::new();
    let mut export_outputs = Vec::new();

    for (li, name) in names.iter().enumerate() {
        let (k, c_out) = (widths[li], widths[li + 1]);
        qlayers.push(QLayerMeta {
            name: name.to_string(),
            kind: "dense".into(),
            c_out,
            k,
            m_bits: BitsSpecJson::Var("M".into()),
            // The network input is the dataset's 1-bit binary grid; hidden
            // boundaries ride the runtime N (unsigned post-ReLU grids).
            n_bits: if li == 0 {
                BitsSpecJson::Fixed(1)
            } else {
                BitsSpecJson::Var("N".into())
            },
            p_bits: BitsSpecJson::Var("P".into()),
            x_signed: false,
            out_h: 1,
            out_w: 1,
            kh: 1,
            kw: 1,
            c_in: k,
            stride: 1,
            groups: 1,
        });
        for (leaf, shape) in [
            ("v", vec![c_out, k]),
            ("d", vec![c_out]),
            ("t", vec![c_out]),
            ("b", vec![c_out]),
        ] {
            state.push(StateEntry { path: format!("params/{name}/{leaf}"), shape: shape.clone() });
            params.push(StateEntry { path: format!("{name}/{leaf}"), shape });
        }
        export_outputs.push(ExportEntry {
            layer: name.to_string(),
            tensor: "w_int".into(),
            shape: vec![c_out, k],
        });
        export_outputs.push(ExportEntry {
            layer: name.to_string(),
            tensor: "s".into(),
            shape: vec![c_out, 1],
        });
        export_outputs.push(ExportEntry {
            layer: name.to_string(),
            tensor: "b".into(),
            shape: vec![c_out],
        });
    }
    // optimizer slots mirror the param subtree (momentum for SGD, first and
    // second moments for Adam), then the step counter
    let slot_prefixes: &[&str] = if optimizer == "adam" { &["m", "v"] } else { &["mom"] };
    for prefix in slot_prefixes {
        for p in params.clone() {
            state.push(StateEntry { path: format!("{prefix}/{}", p.path), shape: p.shape });
        }
    }
    state.push(StateEntry { path: "step".into(), shape: vec![] });

    let mut algs = BTreeMap::new();
    for alg in ["a2q", "a2q_plus", "qat"] {
        algs.insert(
            alg.to_string(),
            AlgArtifacts {
                train: "native".into(),
                infer: "native".into(),
                export: Some("native".into()),
            },
        );
    }
    algs.insert(
        "float".into(),
        AlgArtifacts { train: "native".into(), infer: "native".into(), export: None },
    );

    let m = ModelManifest {
        name: model.to_string(),
        input_shape: vec![widths[0]],
        batch_size,
        task: "classify".into(),
        n_classes: *widths.last().unwrap(),
        sr_factor: 1,
        optimizer: optimizer.into(),
        lr,
        weight_decay: 0.0,
        largest_k: widths[..widths.len() - 1].iter().copied().max().unwrap(),
        qlayers,
        init: "native".into(),
        algs,
        state,
        params,
        export_outputs,
        train_inputs: TrainInputs {
            x: vec![batch_size, widths[0]],
            y: vec![batch_size],
            bits: vec![3],
        },
    };
    m.validate().expect("native manifests satisfy the artifact invariants");
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::finn::estimate::BitSpec;

    #[test]
    fn registry_manifests_validate_and_chain() {
        for model in native_models() {
            let m = native_manifest(model).unwrap();
            assert_eq!(m.name, *model);
            assert!(m.algs.contains_key("a2q"));
            assert!(m.algs.contains_key("a2q_plus"));
            assert!(m.algs.contains_key("qat"));
            assert!(m.algs["float"].export.is_none());
            assert!(!m.param_indices().is_empty());
            for w in m.qlayers.windows(2) {
                assert_eq!(w[1].k, w[0].c_out, "{model} layers must chain");
            }
            // every layer carries the runtime accumulator constraint
            for q in &m.qlayers {
                assert_eq!(q.to_geom().unwrap().p_spec, BitSpec::P);
            }
        }
        assert!(native_manifest("resnet").is_none());
    }

    #[test]
    fn mlp3_adam_carries_adam_moment_slots() {
        let m = native_manifest("mlp3_adam").unwrap();
        assert_eq!(m.optimizer, "adam");
        for leaf in ["v", "d", "t", "b"] {
            assert!(m.state.iter().any(|e| e.path == format!("m/fc0/{leaf}")), "m/fc0/{leaf}");
            assert!(m.state.iter().any(|e| e.path == format!("v/fc0/{leaf}")), "v/fc0/{leaf}");
        }
        assert!(m.state.iter().all(|e| !e.path.starts_with("mom/")), "no SGD slots under adam");
        assert_eq!(m.qlayers.len(), 3);
        // the SGD models keep the momentum layout
        let sgd = native_manifest("mlp3").unwrap();
        assert!(sgd.state.iter().any(|e| e.path == "mom/fc0/v"));
        assert!(sgd.state.iter().all(|e| !e.path.starts_with("m/")));
    }

    #[test]
    fn mlp_matches_the_fig2_geometry() {
        let m = native_manifest("mlp").unwrap();
        assert_eq!(m.qlayers.len(), 1);
        assert_eq!(m.qlayers[0].name, "fc");
        assert_eq!(m.qlayers[0].k, 784);
        assert_eq!(m.qlayers[0].n_bits, BitsSpecJson::Fixed(1));
        assert_eq!(m.largest_k, 784);
        assert_eq!(m.n_classes, 2);
    }
}
