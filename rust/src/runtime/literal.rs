//! Transport between host [`Tensor`]s and PJRT [`xla::Literal`]s.
//!
//! The artifact interface is all-f32 (labels ride as f32, integer codes ride
//! as exact small integers in f32), so only f32 conversions are needed.

use crate::tensor::Tensor;
use anyhow::Result;

/// Host tensor -> device literal (f32, row-major).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    if t.shape().is_empty() {
        // rank-0: reshape the 1-element vector to a scalar
        Ok(lit.reshape(&[])?)
    } else {
        let dims: Vec<i64> = t.shape().iter().map(|d| *d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

/// Device literal -> host tensor (must be a dense f32 array).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::new(dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_2d() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn round_trip_scalar() {
        let t = Tensor::scalar(7.5);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back.shape(), &[] as &[usize]);
        assert_eq!(back.item(), 7.5);
    }

    #[test]
    fn round_trip_4d() {
        let t = Tensor::new(vec![2, 2, 2, 1], (0..8).map(|v| v as f32).collect());
        let back = literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap();
        assert_eq!(back, t);
    }
}
