//! Artifact manifests: the JSON contract `python/compile/aot.py` writes and
//! the Rust coordinator trusts (shapes, layer geometry, file names).
//! Parsed with the in-tree [`crate::json`] module.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};

use crate::finn::estimate::{BitSpec, LayerGeom};
use crate::json::Json;

/// `"M"`/`"N"`/`"P"` (runtime grid variable) or a fixed integer width.
#[derive(Clone, Debug, PartialEq)]
pub enum BitsSpecJson {
    Fixed(u32),
    Var(String),
}

impl BitsSpecJson {
    pub fn from_json(v: &Json) -> Result<Self> {
        match v {
            Json::Num(_) => Ok(BitsSpecJson::Fixed(v.as_u32()?)),
            Json::Str(s) => Ok(BitsSpecJson::Var(s.clone())),
            other => anyhow::bail!("bad bit spec {other:?}"),
        }
    }

    pub fn to_bitspec(&self) -> Result<BitSpec> {
        Ok(match self {
            BitsSpecJson::Fixed(v) => BitSpec::Fixed(*v),
            BitsSpecJson::Var(s) => match s.as_str() {
                "M" => BitSpec::M,
                "N" => BitSpec::N,
                "P" => BitSpec::P,
                other => anyhow::bail!("unknown bit spec {other:?}"),
            },
        })
    }
}

/// One quantized layer's geometry (mirrors `models/common.py::QLayer`).
#[derive(Clone, Debug)]
pub struct QLayerMeta {
    pub name: String,
    pub kind: String,
    pub c_out: usize,
    pub k: usize,
    pub m_bits: BitsSpecJson,
    pub n_bits: BitsSpecJson,
    pub p_bits: BitsSpecJson,
    pub x_signed: bool,
    pub out_h: usize,
    pub out_w: usize,
    pub kh: usize,
    pub kw: usize,
    pub c_in: usize,
    pub stride: usize,
    pub groups: usize,
}

impl QLayerMeta {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(QLayerMeta {
            name: v.get("name")?.as_str()?.to_string(),
            kind: v.get("kind")?.as_str()?.to_string(),
            c_out: v.get("c_out")?.as_usize()?,
            k: v.get("k")?.as_usize()?,
            m_bits: BitsSpecJson::from_json(v.get("m_bits")?)?,
            n_bits: BitsSpecJson::from_json(v.get("n_bits")?)?,
            p_bits: BitsSpecJson::from_json(v.get("p_bits")?)?,
            x_signed: v.get("x_signed")?.as_bool()?,
            out_h: v.get("out_h")?.as_usize()?,
            out_w: v.get("out_w")?.as_usize()?,
            kh: v.get("kh")?.as_usize()?,
            kw: v.get("kw")?.as_usize()?,
            c_in: v.get("c_in")?.as_usize()?,
            stride: v.get("stride")?.as_usize()?,
            groups: v.get("groups")?.as_usize()?,
        })
    }

    pub fn to_geom(&self) -> Result<LayerGeom> {
        Ok(LayerGeom {
            name: self.name.clone(),
            kind: self.kind.clone(),
            c_out: self.c_out,
            k: self.k,
            m_spec: self.m_bits.to_bitspec()?,
            n_spec: self.n_bits.to_bitspec()?,
            p_spec: self.p_bits.to_bitspec()?,
            x_signed: self.x_signed,
            out_h: self.out_h,
            out_w: self.out_w,
            kh: self.kh,
            c_in: self.c_in,
            stride: self.stride,
        })
    }
}

/// Artifact file names for one algorithm.
#[derive(Clone, Debug)]
pub struct AlgArtifacts {
    pub train: String,
    pub infer: String,
    pub export: Option<String>,
}

/// One entry of the flattened state/params layout.
#[derive(Clone, Debug, PartialEq)]
pub struct StateEntry {
    pub path: String,
    pub shape: Vec<usize>,
}

impl StateEntry {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(StateEntry {
            path: v.get("path")?.as_str()?.to_string(),
            shape: v.get("shape")?.as_usize_vec()?,
        })
    }
}

/// One output of the export artifact.
#[derive(Clone, Debug)]
pub struct ExportEntry {
    pub layer: String,
    pub tensor: String,
    pub shape: Vec<usize>,
}

/// Static train-step input shapes.
#[derive(Clone, Debug)]
pub struct TrainInputs {
    pub x: Vec<usize>,
    pub y: Vec<usize>,
    pub bits: Vec<usize>,
}

/// Full manifest for one model (`artifacts/<model>.json`).
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub batch_size: usize,
    pub task: String,
    pub n_classes: usize,
    pub sr_factor: usize,
    pub optimizer: String,
    pub lr: f64,
    pub weight_decay: f64,
    pub largest_k: usize,
    pub qlayers: Vec<QLayerMeta>,
    pub init: String,
    pub algs: BTreeMap<String, AlgArtifacts>,
    pub state: Vec<StateEntry>,
    pub params: Vec<StateEntry>,
    pub export_outputs: Vec<ExportEntry>,
    pub train_inputs: TrainInputs,
}

impl ModelManifest {
    /// Load `artifacts/<model>.json`.
    pub fn load(artifacts_dir: &Path, model: &str) -> Result<Self> {
        let path = artifacts_dir.join(format!("{model}.json"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}. Run `make artifacts` first."))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let m = Self::from_json(&v).map_err(|e| anyhow::anyhow!("decoding {path:?}: {e}"))?;
        m.validate()?;
        Ok(m)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let qlayers = v
            .get("qlayers")?
            .as_arr()?
            .iter()
            .map(QLayerMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut algs = BTreeMap::new();
        for (alg, entry) in v.get("algs")?.as_obj()? {
            algs.insert(
                alg.clone(),
                AlgArtifacts {
                    train: entry.get("train")?.as_str()?.to_string(),
                    infer: entry.get("infer")?.as_str()?.to_string(),
                    export: entry
                        .opt("export")
                        .map(|e| e.as_str().map(str::to_string))
                        .transpose()?,
                },
            );
        }
        let parse_entries = |key: &str| -> Result<Vec<StateEntry>> {
            v.get(key)?.as_arr()?.iter().map(StateEntry::from_json).collect()
        };
        let export_outputs = v
            .get("export_outputs")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(ExportEntry {
                    layer: e.get("layer")?.as_str()?.to_string(),
                    tensor: e.get("tensor")?.as_str()?.to_string(),
                    shape: e.get("shape")?.as_usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let ti = v.get("train_inputs")?;
        Ok(ModelManifest {
            name: v.get("name")?.as_str()?.to_string(),
            input_shape: v.get("input_shape")?.as_usize_vec()?,
            batch_size: v.get("batch_size")?.as_usize()?,
            task: v.get("task")?.as_str()?.to_string(),
            n_classes: v.get("n_classes")?.as_usize()?,
            sr_factor: v.get("sr_factor")?.as_usize()?,
            optimizer: v.get("optimizer")?.as_str()?.to_string(),
            lr: v.get("lr")?.as_f64()?,
            weight_decay: v.get("weight_decay")?.as_f64()?,
            largest_k: v.get("largest_k")?.as_usize()?,
            qlayers,
            init: v.get("init")?.as_str()?.to_string(),
            algs,
            state: parse_entries("state")?,
            params: parse_entries("params")?,
            export_outputs,
            train_inputs: TrainInputs {
                x: ti.get("x")?.as_usize_vec()?,
                y: ti.get("y")?.as_usize_vec()?,
                bits: ti.get("bits")?.as_usize_vec()?,
            },
        })
    }

    pub(crate) fn validate(&self) -> Result<()> {
        ensure!(!self.qlayers.is_empty(), "no qlayers in manifest {}", self.name);
        ensure!(!self.state.is_empty(), "empty state layout");
        ensure!(
            self.export_outputs.len() == 3 * self.qlayers.len(),
            "export outputs {} != 3 * {} layers",
            self.export_outputs.len(),
            self.qlayers.len()
        );
        ensure!(
            self.largest_k == self.qlayers.iter().map(|q| q.k).max().unwrap_or(0),
            "largest_k inconsistent"
        );
        // params layout must be a subsequence of state (params/ prefix)
        for p in &self.params {
            ensure!(
                self.state.iter().any(|s| s.path == format!("params/{}", p.path)),
                "param {} missing from state layout",
                p.path
            );
        }
        Ok(())
    }

    pub fn alg(&self, alg: &str) -> Result<&AlgArtifacts> {
        self.algs
            .get(alg)
            .ok_or_else(|| anyhow::anyhow!("model {} has no algorithm {alg:?}", self.name))
    }

    /// Geometry for the FINN estimator.
    pub fn geoms(&self) -> Result<Vec<LayerGeom>> {
        self.qlayers.iter().map(|q| q.to_geom()).collect()
    }

    /// Indices (into the flattened state) of the parameter leaves, in the
    /// same order as the `params` layout — used to slice params out of a
    /// train state for infer/export calls.
    pub fn param_indices(&self) -> Vec<usize> {
        self.params
            .iter()
            .map(|p| {
                let full = format!("params/{}", p.path);
                self.state
                    .iter()
                    .position(|s| s.path == full)
                    .expect("validated above")
            })
            .collect()
    }
}

/// List models available in an artifacts directory.
pub fn discover_models(artifacts_dir: &Path) -> Result<Vec<String>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(artifacts_dir)? {
        let p: PathBuf = entry?.path();
        if p.extension().is_some_and(|e| e == "json") {
            if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                if stem != "index" {
                    out.push(stem.to_string());
                }
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_spec_parsing() {
        let f = BitsSpecJson::from_json(&Json::parse("8").unwrap()).unwrap();
        assert_eq!(f, BitsSpecJson::Fixed(8));
        let v = BitsSpecJson::from_json(&Json::parse("\"P\"").unwrap()).unwrap();
        assert_eq!(v, BitsSpecJson::Var("P".into()));
        assert!(v.to_bitspec().is_ok());
        let bad = BitsSpecJson::from_json(&Json::parse("\"Q\"").unwrap()).unwrap();
        assert!(bad.to_bitspec().is_err());
    }
}
