//! FINN-style FPGA resource estimation (the substrate behind paper §5.3,
//! Figs. 6-7).
//!
//! The paper generates streaming-dataflow accelerators with the FINN
//! compiler and reports Vivado LUT estimates, with the compiler configured
//! to use **LUTs only** (no DSPs/BRAM) — so every cost reduces to LUTs. We
//! rebuild that estimator analytically, following the published FINN-R cost
//! model structure (Blott et al., TRETS 2018; Umuroglu & Jahre 2017):
//!
//! * each layer becomes a matrix-vector-activation unit (MVAU) with `PE`
//!   processing elements x `SIMD` lanes ([`mvau`]);
//! * compute LUTs: LUT-based multipliers scale with `M x N`, the adder tree
//!   and the accumulator registers/carry chains scale with the accumulator
//!   width `P` — this is precisely where A2Q saves compute resources;
//! * memory LUTs: weight storage in LUTRAM scales with `c_out*K*M`;
//!   quantized monotone activations are implemented as *threshold
//!   comparisons* whose storage scales with `c_out * (2^N_out - 1) * P`
//!   ([`thresholds`]) — exponential in activation precision and linear in
//!   accumulator width, the effect Fig. 7 attributes the memory savings to.
//!
//! Absolute numbers are model-based, not Vivado reports; the *relative*
//! shape across (M, N, P) is what Figs. 6-7 exercise (DESIGN.md §3).

pub mod estimate;
pub mod mvau;
pub mod thresholds;

pub use estimate::{
    estimate_network, estimate_qnetwork, AccumulatorPolicy, LayerBits, LayerGeom, NetworkEstimate,
};
pub use mvau::{fold, LutBreakdown, MvauConfig};
