//! Matrix-vector-activation unit (MVAU) LUT cost model.
//!
//! The MVAU (paper Fig. 9b) is FINN's building block for dense and conv
//! layers: `PE` processing elements parallelize output channels, `SIMD`
//! lanes parallelize the dot product. We model a LUT-only instantiation.

/// Stream-folding configuration for one MVAU.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MvauConfig {
    /// Processing elements (parallel output channels), `1..=c_out`.
    pub pe: usize,
    /// SIMD input lanes (parallel MACs per PE), `1..=k`.
    pub simd: usize,
}

/// LUT cost split used by Fig. 7 (control overhead excluded, as the paper
/// does — it is constant per topology).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LutBreakdown {
    pub compute: f64,
    pub memory: f64,
}

impl LutBreakdown {
    pub fn total(&self) -> f64 {
        self.compute + self.memory
    }

    pub fn add(&mut self, other: LutBreakdown) {
        self.compute += other.compute;
        self.memory += other.memory;
    }
}

/// Pick a folding (PE, SIMD) meeting a cycles-per-frame budget.
///
/// FINN balances layer throughputs by folding; we model the same knob with a
/// single budget: each layer needs `c_out*k*out_pixels` MACs per frame and
/// gets `pe*simd` MACs per cycle.
pub fn fold(c_out: usize, k: usize, out_pixels: usize, cycles_budget: usize) -> MvauConfig {
    let macs = (c_out * k * out_pixels) as f64;
    let need = (macs / cycles_budget.max(1) as f64).ceil().max(1.0) as usize;
    let simd = need.min(k).max(1);
    let pe = ((need + simd - 1) / simd).min(c_out).max(1);
    MvauConfig { pe, simd }
}

/// LUTs for one `M x N -> wide` LUT-based multiplier.
///
/// A 6-input-LUT fabric realizes an MxN partial-product multiplier in about
/// `(M*N + 1) / 2` LUTs (two partial-product bits per LUT6 with carry) — the
/// standard first-order estimate Vivado synthesis tracks for small
/// multipliers.
pub fn multiplier_luts(m_bits: u32, n_bits: u32) -> f64 {
    ((m_bits * n_bits + 1) / 2) as f64
}

/// Compute-side LUTs of one MVAU: multipliers + adder tree + accumulator.
///
/// * multipliers: `pe * simd * mul(M, N)`
/// * adder tree: `simd - 1` adders per PE; operand width grows from `M+N`
///   toward `P`, modelled at the accumulator width `P` per FINN-R (the tree
///   is instantiated at full precision to preserve exactness): `~P` LUTs per
///   adder (one LUT per result bit with carry chain).
/// * accumulator: one `P`-bit adder + register per PE.
///
/// The `P` terms are exactly where reducing the accumulator width pays off
/// in compute (paper §5.3.1: "the reductions in compute resources primarily
/// come from the reduced cost of MACs").
pub fn compute_luts(cfg: MvauConfig, m_bits: u32, n_bits: u32, p_bits: u32) -> f64 {
    let mults = (cfg.pe * cfg.simd) as f64 * multiplier_luts(m_bits, n_bits);
    let adder_tree = cfg.pe as f64 * (cfg.simd.saturating_sub(1)) as f64 * p_bits as f64;
    let accumulator = cfg.pe as f64 * p_bits as f64;
    mults + adder_tree + accumulator
}

/// Memory-side LUTs for weight storage: `c_out * k * M` bits in LUTRAM at
/// 64 bits per LUT (Xilinx RAM64X1S-class primitives).
pub fn weight_memory_luts(c_out: usize, k: usize, m_bits: u32) -> f64 {
    ((c_out * k) as f64 * m_bits as f64 / 64.0).ceil()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_respects_limits() {
        let f = fold(64, 128, 16, 4096);
        assert!(f.pe >= 1 && f.pe <= 64);
        assert!(f.simd >= 1 && f.simd <= 128);
        // throughput satisfied
        assert!(f.pe * f.simd * 4096 >= 64 * 128 * 16);
    }

    #[test]
    fn fold_tiny_layer_is_1x1() {
        let f = fold(2, 784, 1, 1_000_000);
        assert_eq!(f, MvauConfig { pe: 1, simd: 1 });
    }

    #[test]
    fn compute_monotone_in_every_bit_width() {
        let cfg = MvauConfig { pe: 4, simd: 16 };
        let base = compute_luts(cfg, 6, 6, 16);
        assert!(compute_luts(cfg, 7, 6, 16) > base);
        assert!(compute_luts(cfg, 6, 7, 16) > base);
        assert!(compute_luts(cfg, 6, 6, 20) > base);
    }

    #[test]
    fn accumulator_width_moves_compute_cost() {
        // 32b -> 16b accumulator on a wide MVAU should save a visible chunk.
        let cfg = MvauConfig { pe: 8, simd: 32 };
        let wide = compute_luts(cfg, 4, 4, 32);
        let narrow = compute_luts(cfg, 4, 4, 16);
        assert!(narrow < wide * 0.75, "{narrow} vs {wide}");
    }

    #[test]
    fn weight_memory() {
        assert_eq!(weight_memory_luts(10, 100, 8), (8000.0f64 / 64.0).ceil());
        assert!(weight_memory_luts(10, 100, 4) < weight_memory_luts(10, 100, 8));
    }
}
