//! Threshold-activation LUT cost model.
//!
//! FINN implements every quantized monotone activation as a bank of
//! threshold comparisons mapping the P-bit accumulator value to an
//! N_out-bit output (paper Fig. 9b, [42]): `2^N_out - 1` thresholds per
//! output channel, each a P-bit constant, compared against the accumulator.
//! Batch norm, biases and scaling factors are absorbed into the thresholds,
//! so this stage *is* the layer's activation memory. Its cost is therefore
//! exponential in activation precision and linear in accumulator width —
//! the dominant memory effect Fig. 7 reports.

/// Number of threshold constants per output channel for an N_out-bit output.
pub fn thresholds_per_channel(n_out_bits: u32) -> u64 {
    (1u64 << n_out_bits) - 1
}

/// Memory LUTs for threshold storage: `c_out * (2^N_out - 1)` thresholds of
/// `P` bits each, in 64-bit-per-LUT distributed RAM.
pub fn threshold_memory_luts(c_out: usize, n_out_bits: u32, p_bits: u32) -> f64 {
    let bits = c_out as u64 * thresholds_per_channel(n_out_bits) * p_bits as u64;
    (bits as f64 / 64.0).ceil()
}

/// Compute LUTs for the comparators: each PE compares the P-bit accumulator
/// against its threshold bank; a P-bit comparator costs ~P/2 LUTs and the
/// unit time-multiplexes the `2^N_out - 1` thresholds, so the *instantiated*
/// comparator cost is per-PE, not per-threshold.
pub fn threshold_compare_luts(pe: usize, p_bits: u32) -> f64 {
    pe as f64 * (p_bits as f64 / 2.0).ceil()
}

/// Stream-buffer memory LUTs: the sliding-window (line) buffer feeding a
/// conv MVAU holds `kh` rows of `in_w * c_in` pixels at `N` bits.
pub fn window_buffer_luts(kh: usize, in_w: usize, c_in: usize, n_bits: u32) -> f64 {
    let bits = (kh * in_w * c_in) as u64 * n_bits as u64;
    (bits as f64 / 64.0).ceil()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_counts() {
        assert_eq!(thresholds_per_channel(1), 1);
        assert_eq!(thresholds_per_channel(4), 15);
        assert_eq!(thresholds_per_channel(8), 255);
    }

    #[test]
    fn memory_exponential_in_activation_bits() {
        let a4 = threshold_memory_luts(64, 4, 16);
        let a8 = threshold_memory_luts(64, 8, 16);
        assert!(a8 > a4 * 15.0, "{a8} vs {a4}");
    }

    #[test]
    fn memory_linear_in_accumulator_bits() {
        let p16 = threshold_memory_luts(64, 4, 16);
        let p32 = threshold_memory_luts(64, 4, 32);
        let ratio = p32 / p16;
        assert!((ratio - 2.0).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn buffers_scale_with_precision() {
        assert!(window_buffer_luts(3, 16, 32, 8) > window_buffer_luts(3, 16, 32, 4));
    }
}
